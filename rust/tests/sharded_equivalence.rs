//! Shard-invariance guarantees of the staged engine: the shard count is
//! an operational knob — labels, sigma, and embeddings are
//! **bit-identical** across shard counts {1, 2, 7}, sources
//! {`Mat`, `BinDataset`, `RemoteSource`, mixed `SegmentedSource`},
//! thread counts {1, 8}, storage profiles, and SIMD dispatch levels, for
//! U-SPEC and for out-of-core U-SENC. The CI determinism matrix re-runs
//! this suite under `USPEC_THREADS` ∈ {1, 2, 8} and with `USPEC_SIMD=0`
//! (forced-scalar) legs; the loopback remote legs run as a separate
//! bounded-timeout step filtered on "remote".

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use uspec::affinity::NativeBackend;
use uspec::data::synthetic::two_moons;
use uspec::linalg::{set_simd_override, Mat};
use uspec::net::{NetOpts, RemoteSource, ServeOpts, ShardServer};
use uspec::pipeline::{DataSource, ExecOpts, Pipeline, SegmentedSource, StorageProfile};
use uspec::streaming::{stream_usenc, BinDataset};
use uspec::usenc::{usenc, UsencParams};
use uspec::uspec::UspecParams;
use uspec::util::par;
use uspec::Result;

/// Serializes tests that flip the global thread override.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the default thread override even when an assertion unwinds.
struct OverrideGuard;

impl Drop for OverrideGuard {
    fn drop(&mut self) {
        par::set_thread_override(0);
    }
}

/// Restores the default SIMD dispatch even when an assertion unwinds.
struct SimdGuard;

impl Drop for SimdGuard {
    fn drop(&mut self) {
        set_simd_override(0);
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("uspec_sharded_eq");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The acceptance matrix: labels bit-identical across shard counts
/// {1, 2, 7} × sources {Mat, BinDataset} × thread counts {1, 8}.
#[test]
fn uspec_bit_identical_across_shards_sources_threads() {
    let _g = lock();
    let _restore = OverrideGuard;
    let ds = two_moons(1500, 0.06, 41);
    let bin = BinDataset::write_mat(&tmp("eq_shards.bin"), &ds.x).unwrap();
    let params = UspecParams { k: 2, p: 150, ..Default::default() };
    let mut baseline: Option<(Vec<u32>, u64, Vec<u32>)> = None;
    for nt in [1usize, 8] {
        par::set_thread_override(nt);
        for shards in [1usize, 2, 7] {
            let pipe = Pipeline::new(&NativeBackend)
                .with_opts(ExecOpts { chunk: 300, shards, ..ExecOpts::default() });
            let mem = pipe.run(&ds.x, &params, 77).unwrap();
            let disk = pipe.run(&bin, &params, 77).unwrap();
            let tag = format!("nt={nt} shards={shards}");
            assert_eq!(mem.labels, disk.labels, "sources diverged at {tag}");
            assert_eq!(mem.sigma.to_bits(), disk.sigma.to_bits(), "sigma at {tag}");
            let emb_bits: Vec<u32> = disk.embedding.data.iter().map(|v| v.to_bits()).collect();
            match &baseline {
                Some((labels, sigma, emb)) => {
                    assert_eq!(&mem.labels, labels, "labels changed at {tag}");
                    assert_eq!(mem.sigma.to_bits(), *sigma, "sigma changed at {tag}");
                    assert_eq!(&emb_bits, emb, "embedding changed at {tag}");
                }
                None => {
                    baseline = Some((mem.labels.clone(), mem.sigma.to_bits(), emb_bits));
                }
            }
        }
    }
}

/// Out-of-core U-SENC: sharded streaming reproduces the in-memory
/// ensemble and consensus exactly, at any shard count.
#[test]
fn usenc_stream_bit_identical_across_shards() {
    let _g = lock();
    let ds = two_moons(800, 0.06, 42);
    let bin = BinDataset::write_mat(&tmp("eq_shards_usenc.bin"), &ds.x).unwrap();
    let params = UsencParams {
        k: 2,
        m: 4,
        k_min: 4,
        k_max: 9,
        base: UspecParams { p: 80, ..Default::default() },
    };
    let mem = usenc(&ds.x, &params, 13, &NativeBackend).unwrap();
    for shards in [1usize, 2, 7] {
        let opts = ExecOpts { chunk: 300, shards, ..ExecOpts::default() };
        let disk = stream_usenc(&bin, &params, opts, 13, &NativeBackend).unwrap();
        assert_eq!(mem.labels, disk.labels, "consensus diverged at shards={shards}");
        assert_eq!(
            mem.ensemble.labelings, disk.ensemble.labelings,
            "base clusterings diverged at shards={shards}"
        );
    }
}

/// A `DataSource` wrapper counting reads and the largest chunk any read
/// materialized — proof that sharding keeps residency bounded (shards ×
/// chunk, never N×d) while reads may come from concurrent shard walkers.
struct TrackingSource<'a> {
    inner: &'a BinDataset,
    max_read_rows: AtomicUsize,
    reads: AtomicUsize,
}

impl DataSource for TrackingSource<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn d(&self) -> usize {
        self.inner.d()
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        self.max_read_rows.fetch_max(len, Ordering::Relaxed);
        self.reads.fetch_add(1, Ordering::Relaxed);
        DataSource::read_rows(self.inner, start, len, buf)
    }
    // as_mat stays None: the engine can never see the resident matrix.
}

#[test]
fn sharded_run_keeps_chunked_residency_and_total_reads() {
    let _g = lock();
    let ds = two_moons(1200, 0.06, 43);
    let bin = BinDataset::write_mat(&tmp("eq_shards_reads.bin"), &ds.x).unwrap();
    let chunk = 128usize;
    let shards = 5usize;
    let params = UspecParams { k: 2, p: 100, ..Default::default() };
    let tracked = TrackingSource {
        inner: &bin,
        max_read_rows: AtomicUsize::new(0),
        reads: AtomicUsize::new(0),
    };
    // Pin the Parallel profile: the exact read bounds below assume no
    // probe reads (an Auto run adds up to 4 of them — see the probe test).
    let pipe = Pipeline::new(&NativeBackend)
        .with_opts(ExecOpts { chunk, shards, storage: StorageProfile::Parallel, net_cache: 0 });
    let res = pipe.run(&tracked, &params, 51).unwrap();
    assert_eq!(res.labels.len(), bin.n());

    // No read ever materialized more than one chunk, sharded or not.
    let max_rows = tracked.max_read_rows.load(Ordering::Relaxed);
    assert!(max_rows <= chunk, "read {max_rows} rows > chunk {chunk}");

    // Read accounting: the selection sweep is one row-ordered pass
    // (⌈n/chunk⌉ reads); the KNR pass splits per shard, so its chunk
    // count is Σ ⌈len_s/chunk⌉ — between ⌈n/chunk⌉ and ⌈n/chunk⌉ + shards.
    let n = bin.n();
    let per_pass = n.div_ceil(chunk);
    let reads = tracked.reads.load(Ordering::Relaxed);
    assert!(
        reads >= 2 * per_pass && reads <= 2 * per_pass + shards,
        "reads={reads}, expected within [{}, {}]",
        2 * per_pass,
        2 * per_pass + shards
    );
}

/// The `Auto` storage probe re-reads rows the walk reads anyway; its
/// overhead is bounded at 4 extra chunk reads per sharded pass and it
/// never widens residency past one chunk.
#[test]
fn auto_probe_adds_at_most_four_chunk_reads() {
    let _g = lock();
    let ds = two_moons(1200, 0.06, 44);
    let bin = BinDataset::write_mat(&tmp("eq_shards_probe.bin"), &ds.x).unwrap();
    let chunk = 128usize;
    let shards = 5usize;
    let params = UspecParams { k: 2, p: 100, ..Default::default() };
    let tracked = TrackingSource {
        inner: &bin,
        max_read_rows: AtomicUsize::new(0),
        reads: AtomicUsize::new(0),
    };
    let pipe = Pipeline::new(&NativeBackend)
        .with_opts(ExecOpts { chunk, shards, storage: StorageProfile::Auto, net_cache: 0 });
    let res = pipe.run(&tracked, &params, 51).unwrap();
    assert_eq!(res.labels.len(), bin.n());

    let max_rows = tracked.max_read_rows.load(Ordering::Relaxed);
    assert!(max_rows <= chunk, "probe read {max_rows} rows > chunk {chunk}");

    let per_pass = bin.n().div_ceil(chunk);
    let reads = tracked.reads.load(Ordering::Relaxed);
    assert!(
        reads >= 2 * per_pass && reads <= 2 * per_pass + shards + 4,
        "reads={reads}, expected within [{}, {}] (walk + probe)",
        2 * per_pass,
        2 * per_pass + shards + 4
    );
}

/// The ISSUE's pinned invariant: the network is just another backing.
/// One dataset served three ways — all-local `BinDataset`, all-remote
/// over a loopback `serve-shard` endpoint, and a mixed composite (rows
/// [0, 700) local + rows [700, 1200) remote) — yields bit-identical
/// labels, sigma, and embedding across thread counts {1, 8} × shard
/// counts {1, 4}. (The CI determinism matrix runs this leg separately
/// under a bounded timeout; "remote" in the name is its filter.)
#[test]
fn uspec_bit_identical_across_local_mixed_remote_backings() {
    let _g = lock();
    let _restore = OverrideGuard;
    let ds = two_moons(1200, 0.06, 46);
    let bin = BinDataset::write_mat(&tmp("eq_shards_remote.bin"), &ds.x).unwrap();
    let served = BinDataset::open(&tmp("eq_shards_remote.bin")).unwrap();
    let server = ShardServer::bind("127.0.0.1:0", std::sync::Arc::new(served)).unwrap();
    let addr = server.addr().to_string();
    let params = UspecParams { k: 2, p: 120, ..Default::default() };
    let mut baseline: Option<(Vec<u32>, u64, Vec<u32>)> = None;
    for nt in [1usize, 8] {
        par::set_thread_override(nt);
        for shards in [1usize, 4] {
            let pipe = Pipeline::new(&NativeBackend)
                .with_opts(ExecOpts { chunk: 256, shards, ..ExecOpts::default() });
            let remote = RemoteSource::connect(&addr).unwrap();
            let mut mixed = SegmentedSource::new();
            mixed.push(BinDataset::open(&tmp("eq_shards_remote.bin")).unwrap(), 0, 700).unwrap();
            mixed.push(RemoteSource::connect(&addr).unwrap(), 700, 500).unwrap();
            for (backing, run) in [
                ("local", pipe.run(&bin, &params, 77).unwrap()),
                ("remote", pipe.run(&remote, &params, 77).unwrap()),
                ("mixed", pipe.run(&mixed, &params, 77).unwrap()),
            ] {
                let tag = format!("nt={nt} shards={shards} backing={backing}");
                let emb_bits: Vec<u32> =
                    run.embedding.data.iter().map(|v| v.to_bits()).collect();
                match &baseline {
                    Some((labels, sigma, emb)) => {
                        assert_eq!(&run.labels, labels, "labels changed at {tag}");
                        assert_eq!(run.sigma.to_bits(), *sigma, "sigma changed at {tag}");
                        assert_eq!(&emb_bits, emb, "embedding changed at {tag}");
                    }
                    None => baseline = Some((run.labels.clone(), run.sigma.to_bits(), emb_bits)),
                }
            }
        }
    }
}

/// The remote fast path is operational end to end: wire compression
/// (`USPEC/2`) and the chunk caches on either side change bytes moved,
/// never results. One dataset, one all-local baseline, then every
/// {compress on/off} × {client cache on/off + server frame cache} ×
/// thread-count {1, 8} combination over a loopback endpoint must
/// reproduce labels, sigma, and embedding bit-exactly. Opts are set
/// explicitly (not via env) so the CI `USPEC_NET_COMPRESS=0` legs still
/// exercise both codec states.
#[test]
fn uspec_bit_identical_remote_compress_cache_matrix() {
    let _g = lock();
    let _restore = OverrideGuard;
    let ds = two_moons(1200, 0.06, 47);
    let bin = BinDataset::write_mat(&tmp("eq_fastpath.bin"), &ds.x).unwrap();
    let params = UspecParams { k: 2, p: 120, ..Default::default() };
    let opts = ExecOpts { chunk: 256, shards: 3, ..ExecOpts::default() };
    let pipe = Pipeline::new(&NativeBackend).with_opts(opts);
    let local = pipe.run(&bin, &params, 77).unwrap();
    let local_emb: Vec<u32> = local.embedding.data.iter().map(|v| v.to_bits()).collect();
    for compress in [false, true] {
        for cache in [0usize, 1 << 20] {
            let served = BinDataset::open(&tmp("eq_fastpath.bin")).unwrap();
            let server = ShardServer::bind_with(
                "127.0.0.1:0",
                std::sync::Arc::new(served),
                ServeOpts { cache_bytes: cache, compress, ..Default::default() },
            )
            .unwrap();
            let addr = server.addr().to_string();
            for nt in [1usize, 8] {
                par::set_thread_override(nt);
                let remote = RemoteSource::connect_with(
                    &addr,
                    NetOpts { cache_bytes: cache, compress, ..NetOpts::default() },
                )
                .unwrap();
                assert_eq!(remote.peer_v2(), compress, "negotiation at compress={compress}");
                let run = pipe.run(&remote, &params, 77).unwrap();
                let tag = format!("compress={compress} cache={cache} nt={nt}");
                assert_eq!(run.labels, local.labels, "labels changed at {tag}");
                assert_eq!(run.sigma.to_bits(), local.sigma.to_bits(), "sigma at {tag}");
                let emb: Vec<u32> = run.embedding.data.iter().map(|v| v.to_bits()).collect();
                assert_eq!(emb, local_emb, "embedding changed at {tag}");
            }
        }
    }
}

/// Out-of-core U-SENC over the full remote fast path (pipelining +
/// compression + both caches on): the m base sweeps re-read the same
/// chunk grid, so the decoded-chunk cache carries most passes — and the
/// consensus must still be the in-memory run, bit for bit.
#[test]
fn usenc_stream_remote_fast_path_matches_in_memory() {
    let _g = lock();
    let ds = two_moons(800, 0.06, 48);
    let bin = BinDataset::write_mat(&tmp("eq_fastpath_usenc.bin"), &ds.x).unwrap();
    let params = UsencParams {
        k: 2,
        m: 5,
        k_min: 4,
        k_max: 9,
        base: UspecParams { p: 80, ..Default::default() },
    };
    let mem = usenc(&ds.x, &params, 13, &NativeBackend).unwrap();
    let server = ShardServer::bind_with(
        "127.0.0.1:0",
        std::sync::Arc::new(bin),
        ServeOpts { cache_bytes: 1 << 20, compress: true, ..Default::default() },
    )
    .unwrap();
    let remote = RemoteSource::connect_with(
        &server.addr().to_string(),
        NetOpts { cache_bytes: 1 << 20, compress: true, ..NetOpts::default() },
    )
    .unwrap();
    assert!(remote.peer_v2());
    let opts = ExecOpts { chunk: 300, shards: 2, net_cache: 1 << 20, ..ExecOpts::default() };
    let streamed = stream_usenc(&remote, &params, opts, 13, &NativeBackend).unwrap();
    assert_eq!(mem.labels, streamed.labels, "consensus diverged over the fast path");
    assert_eq!(
        mem.ensemble.labelings, streamed.ensemble.labelings,
        "base clusterings diverged over the fast path"
    );
    let (hits, misses) = remote.cache_stats();
    assert!(hits > 0, "m={} sweeps never reused a decoded chunk", params.m);
    assert!(misses > 0, "first pass must miss");
}

/// Forcing the scalar kernel tiles (`USPEC_SIMD=0` / `set_simd_override`)
/// is operational too: a sharded out-of-core run produces bit-identical
/// labels, sigma, and embedding whichever tile implementation dispatch
/// picks.
#[test]
fn sharded_run_is_simd_dispatch_invariant() {
    let _g = lock();
    let _simd = SimdGuard;
    let ds = two_moons(1000, 0.06, 45);
    let bin = BinDataset::write_mat(&tmp("eq_shards_simd.bin"), &ds.x).unwrap();
    let params = UspecParams { k: 2, p: 120, ..Default::default() };
    let mut baseline: Option<(Vec<u32>, u64, Vec<u32>)> = None;
    for force_scalar in [false, true] {
        set_simd_override(usize::from(force_scalar));
        for shards in [1usize, 3] {
            let pipe = Pipeline::new(&NativeBackend)
                .with_opts(ExecOpts { chunk: 300, shards, ..ExecOpts::default() });
            let run = pipe.run(&bin, &params, 77).unwrap();
            let tag = format!("force_scalar={force_scalar} shards={shards}");
            let emb_bits: Vec<u32> = run.embedding.data.iter().map(|v| v.to_bits()).collect();
            match &baseline {
                Some((labels, sigma, emb)) => {
                    assert_eq!(&run.labels, labels, "labels changed at {tag}");
                    assert_eq!(run.sigma.to_bits(), *sigma, "sigma changed at {tag}");
                    assert_eq!(&emb_bits, emb, "embedding changed at {tag}");
                }
                None => baseline = Some((run.labels.clone(), run.sigma.to_bits(), emb_bits)),
            }
        }
    }
}
