//! Integration: U-SENC end-to-end — robustness over U-SPEC, coordinator
//! parallelism, consensus over foreign (k-means) ensembles.

use uspec::affinity::NativeBackend;
use uspec::bipartite::EigSolver;
use uspec::coordinator::usenc_coordinated;
use uspec::data::Benchmark;
use uspec::ensemble_baselines::generate_kmeans_ensemble;
use uspec::metrics::nmi;
use uspec::usenc::{consensus_bipartite, usenc, UsencParams};
use uspec::uspec::{uspec, UspecParams};

fn params(k: usize, m: usize, p: usize) -> UsencParams {
    UsencParams {
        k,
        m,
        k_min: (2 * k).max(4),
        k_max: (6 * k).max(8),
        base: UspecParams { p, ..Default::default() },
    }
}

#[test]
fn usenc_more_stable_than_uspec_across_seeds() {
    // The robustness claim: variance of U-SENC quality across seeds is no
    // worse than U-SPEC's on a noisy nonlinear dataset.
    let ds = Benchmark::Tb1m.generate(0.0015, 5); // 1500 points
    let mut us_scores = Vec::new();
    let mut ue_scores = Vec::new();
    for seed in 0..4 {
        let us = uspec(&ds.x, &UspecParams { k: 2, p: 120, ..Default::default() }, seed).unwrap();
        us_scores.push(nmi(&us.labels, &ds.y));
        let ue = usenc(&ds.x, &params(2, 10, 120), seed, &NativeBackend).unwrap();
        ue_scores.push(nmi(&ue.labels, &ds.y));
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    assert!(
        mean(&ue_scores) > mean(&us_scores) - 0.12,
        "usenc {ue_scores:?} vs uspec {us_scores:?}"
    );
    assert!(mean(&ue_scores) > 0.8, "{ue_scores:?}");
}

#[test]
fn coordinated_equals_sequential_and_scales_workers() {
    let ds = Benchmark::Cc5m.generate(0.0002, 7);
    let p = params(3, 6, 100);
    let seq = usenc(&ds.x, &p, 42, &NativeBackend).unwrap();
    for workers in [1usize, 2, 5] {
        let par = usenc_coordinated(&ds.x, &p, 42, &NativeBackend, workers, None).unwrap();
        assert_eq!(seq.labels, par.labels, "workers={workers}");
    }
}

#[test]
fn consensus_works_on_kmeans_ensembles_too() {
    // The consensus function is generic over ensembles (used by the
    // ensemble baselines comparison).
    let ds = Benchmark::Tb1m.generate(0.001, 9);
    let ens = generate_kmeans_ensemble(&ds.x, 8, 6, 14, 3).unwrap();
    let labels = consensus_bipartite(&ens, 2, EigSolver::Auto, 11).unwrap();
    let score = nmi(&labels, &ds.y);
    assert!(score > 0.3, "consensus over k-means ensemble: {score}");
}

#[test]
fn incidence_invariants_hold_after_generation() {
    let ds = Benchmark::Sf2m.generate(0.0003, 11);
    let res = usenc(&ds.x, &params(4, 5, 80), 13, &NativeBackend).unwrap();
    let b = res.ensemble.incidence();
    assert_eq!(b.rows, ds.n());
    assert_eq!(b.nnz(), ds.n() * 5); // exactly m per row (Eq. 19)
    let ks = res.ensemble.ks();
    assert_eq!(b.cols, ks.iter().sum::<usize>());
    // every column non-empty (k-means repair guarantees no empty clusters)
    for (j, s) in b.col_sums().iter().enumerate() {
        assert!(*s > 0.0, "empty cluster column {j}");
    }
}

#[test]
fn ensemble_diversity_nonzero() {
    // Diversity of base clusterings is what makes the ensemble useful —
    // distinct seeds/k draws must give distinct partitions.
    let ds = Benchmark::Tb1m.generate(0.001, 15);
    let res = usenc(&ds.x, &params(2, 6, 100), 17, &NativeBackend).unwrap();
    let mut distinct_pairs = 0;
    let m = res.ensemble.m();
    for i in 0..m {
        for j in 0..i {
            if nmi(&res.ensemble.labelings[i], &res.ensemble.labelings[j]) < 0.999 {
                distinct_pairs += 1;
            }
        }
    }
    assert!(distinct_pairs >= m * (m - 1) / 4, "ensemble not diverse enough");
}
