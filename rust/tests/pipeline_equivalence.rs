//! Pipeline-equivalence guarantees of the staged engine
//! (`uspec::pipeline`): an in-memory `Mat` source and an on-disk
//! `BinDataset` source must produce **bit-identical** labels for a fixed
//! seed — for U-SPEC and for out-of-core U-SENC, at any thread count —
//! and out-of-core runs must never materialize the full N×d matrix.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use uspec::affinity::NativeBackend;
use uspec::data::synthetic::two_moons;
use uspec::linalg::{set_simd_override, Mat};
use uspec::net::{RemoteSource, ShardServer};
use uspec::pipeline::{DataSource, Pipeline};
use uspec::streaming::BinDataset;
use uspec::usenc::{usenc_chunked, UsencParams};
use uspec::uspec::{uspec, UspecParams};
use uspec::util::par;
use uspec::Result;

/// Serializes tests that flip the global thread override.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores the default thread override even when an assertion unwinds,
/// so one failing test cannot leak a stale override into the next.
struct OverrideGuard;

impl Drop for OverrideGuard {
    fn drop(&mut self) {
        par::set_thread_override(0);
    }
}

/// Restores the default SIMD dispatch even when an assertion unwinds.
struct SimdGuard;

impl Drop for SimdGuard {
    fn drop(&mut self) {
        set_simd_override(0);
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("uspec_pipeline_eq");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

/// The runtime SIMD dispatch layer is purely operational: a full U-SPEC
/// run under the dispatched kernels is bit-identical — labels, sigma, and
/// embedding — to the same run forced onto the scalar reference tiles, at
/// one and several threads.
#[test]
fn uspec_simd_dispatch_is_operational() {
    let _g = lock();
    let _restore = OverrideGuard;
    let _simd = SimdGuard;
    let ds = two_moons(1500, 0.06, 25);
    let params = UspecParams { k: 2, p: 150, ..Default::default() };
    let mut baseline: Option<(Vec<u32>, u64, Vec<u32>)> = None;
    for nt in [1usize, 4] {
        par::set_thread_override(nt);
        for force_scalar in [false, true] {
            set_simd_override(usize::from(force_scalar));
            let run =
                Pipeline::new(&NativeBackend).with_chunk(700).run(&ds.x, &params, 77).unwrap();
            let tag = format!("nt={nt} force_scalar={force_scalar}");
            let emb_bits: Vec<u32> = run.embedding.data.iter().map(|v| v.to_bits()).collect();
            match &baseline {
                Some((labels, sigma, emb)) => {
                    assert_eq!(&run.labels, labels, "labels changed at {tag}");
                    assert_eq!(run.sigma.to_bits(), *sigma, "sigma changed at {tag}");
                    assert_eq!(&emb_bits, emb, "embedding changed at {tag}");
                }
                None => baseline = Some((run.labels.clone(), run.sigma.to_bits(), emb_bits)),
            }
        }
    }
}

/// The reduced p×p eigensolve itself is deterministic: lambdas and
/// eigenvectors are bit-identical across thread counts and SIMD dispatch,
/// for both iterative solvers, at a shape above the dense/iterative
/// crossover. This pins the packed f64 gemm + scratch paths directly, not
/// just through end-to-end labels.
#[test]
fn reduced_eig_bit_identical_across_threads_and_simd() {
    use uspec::bipartite::{reduced_eig, EigSolver};
    use uspec::linalg::DMat;
    use uspec::util::rng::Rng;

    let _g = lock();
    let _restore = OverrideGuard;
    let _simd = SimdGuard;
    // Gaussian affinity over 2-D normal points: dense, symmetric, positive
    // degrees; p=200 > 4k+64 so both Auto and Lobpcg take their fast path.
    let (p, k) = (200usize, 3usize);
    let mut rng = Rng::new(0xE16);
    let pts: Vec<(f64, f64)> = (0..p).map(|_| (rng.normal(), rng.normal())).collect();
    let mut e_r = DMat::zeros(p, p);
    for i in 0..p {
        for j in 0..p {
            let dx = pts[i].0 - pts[j].0;
            let dy = pts[i].1 - pts[j].1;
            e_r.set(i, j, (-(dx * dx + dy * dy) / 2.0).exp());
        }
    }
    for solver in [EigSolver::Auto, EigSolver::Lobpcg] {
        let mut baseline: Option<(Vec<u64>, Vec<u64>)> = None;
        for nt in [1usize, 2, 8] {
            par::set_thread_override(nt);
            for force_scalar in [false, true] {
                set_simd_override(usize::from(force_scalar));
                let (lambdas, v) = reduced_eig(&e_r, k, solver, 41).unwrap();
                let lam_bits: Vec<u64> = lambdas.iter().map(|l| l.to_bits()).collect();
                let v_bits: Vec<u64> = v.data.iter().map(|x| x.to_bits()).collect();
                let tag = format!("{solver:?} nt={nt} force_scalar={force_scalar}");
                match &baseline {
                    Some((lb, vb)) => {
                        assert_eq!(&lam_bits, lb, "lambdas changed at {tag}");
                        assert_eq!(&v_bits, vb, "eigvecs changed at {tag}");
                    }
                    None => baseline = Some((lam_bits, v_bits)),
                }
            }
        }
    }
}

#[test]
fn uspec_mat_and_bin_sources_bit_identical_across_threads() {
    let _g = lock();
    let _restore = OverrideGuard;
    let ds = two_moons(1500, 0.06, 21);
    let bin = BinDataset::write_mat(&tmp("eq_uspec.bin"), &ds.x).unwrap();
    let params = UspecParams { k: 2, p: 150, ..Default::default() };
    let mut baseline: Option<Vec<u32>> = None;
    for nt in [1usize, 4] {
        par::set_thread_override(nt);
        let pipe = Pipeline::new(&NativeBackend).with_chunk(700);
        let mem = pipe.run(&ds.x, &params, 77).unwrap();
        let disk = pipe.run(&bin, &params, 77).unwrap();
        assert_eq!(mem.labels, disk.labels, "sources diverged at nt={nt}");
        assert_eq!(mem.sigma.to_bits(), disk.sigma.to_bits(), "sigma at nt={nt}");
        for (a, b) in mem.embedding.data.iter().zip(&disk.embedding.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "embedding at nt={nt}");
        }
        match &baseline {
            Some(b) => assert_eq!(&mem.labels, b, "thread count changed labels (nt={nt})"),
            None => baseline = Some(mem.labels.clone()),
        }
    }
}

#[test]
fn uspec_wrapper_equals_engine_at_any_chunk() {
    let _g = lock();
    let ds = two_moons(1100, 0.06, 22);
    let bin = BinDataset::write_mat(&tmp("eq_chunk.bin"), &ds.x).unwrap();
    let params = UspecParams { k: 2, p: 120, ..Default::default() };
    let wrapped = uspec(&ds.x, &params, 5).unwrap();
    for chunk in [97usize, 512, 8192] {
        let run = Pipeline::new(&NativeBackend).with_chunk(chunk).run(&bin, &params, 5).unwrap();
        assert_eq!(wrapped.labels, run.labels, "chunk={chunk}");
    }
}

/// A loopback `serve-shard` endpoint is indistinguishable from a local
/// file: the remote run is bit-identical to the resident run at every
/// chunk size. ("remote" in the name routes this test to CI's
/// bounded-timeout loopback step.)
#[test]
fn remote_source_is_chunk_invariant_and_matches_local() {
    let _g = lock();
    let ds = two_moons(1100, 0.06, 26);
    let params = UspecParams { k: 2, p: 120, ..Default::default() };
    let resident = uspec(&ds.x, &params, 5).unwrap();
    let server =
        ShardServer::bind("127.0.0.1:0", std::sync::Arc::new(ds.x.clone())).unwrap();
    let remote = RemoteSource::connect(&server.addr().to_string()).unwrap();
    for chunk in [97usize, 512, 8192] {
        let run =
            Pipeline::new(&NativeBackend).with_chunk(chunk).run(&remote, &params, 5).unwrap();
        assert_eq!(resident.labels, run.labels, "labels diverged at chunk={chunk}");
        assert_eq!(
            resident.sigma.to_bits(),
            run.sigma.to_bits(),
            "sigma diverged at chunk={chunk}"
        );
    }
}

#[test]
fn usenc_out_of_core_bit_identical_across_threads() {
    let _g = lock();
    let _restore = OverrideGuard;
    let ds = two_moons(800, 0.06, 23);
    let bin = BinDataset::write_mat(&tmp("eq_usenc.bin"), &ds.x).unwrap();
    let params = UsencParams {
        k: 2,
        m: 4,
        k_min: 4,
        k_max: 9,
        base: UspecParams { p: 80, ..Default::default() },
    };
    let mut baseline: Option<Vec<u32>> = None;
    for nt in [1usize, 4] {
        par::set_thread_override(nt);
        let mem = usenc_chunked(&ds.x, &params, 13, &NativeBackend, 300).unwrap();
        let disk = usenc_chunked(&bin, &params, 13, &NativeBackend, 300).unwrap();
        assert_eq!(mem.labels, disk.labels, "consensus diverged at nt={nt}");
        assert_eq!(
            mem.ensemble.labelings, disk.ensemble.labelings,
            "base clusterings diverged at nt={nt}"
        );
        match &baseline {
            Some(b) => assert_eq!(&mem.labels, b, "thread count changed labels (nt={nt})"),
            None => baseline = Some(mem.labels.clone()),
        }
    }
}

/// A `DataSource` wrapper that records how much of the dataset each read
/// materializes: proof that the engine streams bounded chunks rather than
/// loading the full N×d matrix.
struct TrackingSource<'a> {
    inner: &'a BinDataset,
    max_read_rows: AtomicUsize,
    reads: AtomicUsize,
}

impl<'a> TrackingSource<'a> {
    fn new(inner: &'a BinDataset) -> Self {
        TrackingSource { inner, max_read_rows: AtomicUsize::new(0), reads: AtomicUsize::new(0) }
    }
}

impl DataSource for TrackingSource<'_> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    fn d(&self) -> usize {
        self.inner.d()
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        self.max_read_rows.fetch_max(len, Ordering::Relaxed);
        self.reads.fetch_add(1, Ordering::Relaxed);
        DataSource::read_rows(self.inner, start, len, buf)
    }
    // as_mat stays None: the engine can never see the resident matrix.
}

#[test]
fn usenc_from_disk_has_bounded_resident_chunks_and_one_shared_sweep() {
    let _g = lock();
    let ds = two_moons(1200, 0.06, 24);
    let bin = BinDataset::write_mat(&tmp("eq_bounded.bin"), &ds.x).unwrap();
    let chunk = 256usize;
    let m = 3usize;
    let params = UsencParams {
        k: 2,
        m,
        k_min: 4,
        k_max: 8,
        base: UspecParams { p: 70, ..Default::default() },
    };
    let tracked = TrackingSource::new(&bin);
    let res = usenc_chunked(&tracked, &params, 31, &NativeBackend, chunk).unwrap();
    assert_eq!(res.ensemble.m(), m);
    assert_eq!(res.labels.len(), bin.n());

    // Bounded residency: no read ever materialized more than one chunk,
    // so no full N×d Mat was ever built from the source.
    let max_rows = tracked.max_read_rows.load(Ordering::Relaxed);
    assert!(max_rows <= chunk, "read {max_rows} rows > chunk {chunk}");
    assert!(bin.n() > 4 * chunk, "test must force multi-chunk sweeps");

    // Pass accounting: one shared candidate sweep for all m base
    // clusterers plus one KNR pass per clusterer — not one selection pass
    // per clusterer.
    let chunks_per_pass = bin.n().div_ceil(chunk);
    let reads = tracked.reads.load(Ordering::Relaxed);
    assert_eq!(
        reads,
        (1 + m) * chunks_per_pass,
        "expected 1 shared sweep + {m} KNR passes of {chunks_per_pass} chunks"
    );

    // and it is still the same clustering the in-memory path produces
    let mem = usenc_chunked(&ds.x, &params, 31, &NativeBackend, chunk).unwrap();
    assert_eq!(mem.labels, res.labels);
}
