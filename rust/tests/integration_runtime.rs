//! Integration: the PJRT runtime (AOT-compiled JAX/Pallas artifacts) vs the
//! native backend, and the kernel pool / coordinator composition.
//! Requires `make artifacts` (skipped with a notice otherwise).

use std::sync::Arc;
use uspec::affinity::{DistanceBackend, NativeBackend};
use uspec::data::synthetic::two_moons;
use uspec::linalg::Mat;
use uspec::runtime::{default_artifact_dir, KernelPool, PjrtBackend, Runtime};
use uspec::util::rng::Rng;

fn artifacts_ready() -> bool {
    let ok = default_artifact_dir().join("manifest.json").exists();
    if !ok {
        eprintln!("SKIP: artifacts/ not built — run `make artifacts`");
    }
    ok
}

fn randmat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_vec(r, c, (0..r * c).map(|_| rng.normal() as f32).collect())
}

#[test]
fn pdist_matches_native_across_shapes() {
    if !artifacts_ready() {
        return;
    }
    let mut rt = Runtime::load(default_artifact_dir()).unwrap();
    // shapes exercising padding in every direction: ragged batch, c and d
    // strictly below / exactly at variant sizes
    for &(n, c, d) in &[
        (100usize, 10usize, 2usize),
        (2048, 64, 2),
        (2049, 33, 7),
        (512, 64, 16),
        (300, 200, 50),
        (64, 256, 784),
        (4097, 5, 3),
    ] {
        let x = randmat(n, d, 1000 + n as u64);
        let cm = randmat(c, d, 2000 + c as u64);
        let got = rt.pdist(&x, &cm).unwrap();
        let want = x.sq_dists(&cm);
        assert_eq!(got.rows, n);
        assert_eq!(got.cols, c);
        for i in 0..n {
            for j in 0..c {
                let (a, b) = (got.at(i, j), want.at(i, j));
                assert!(
                    (a - b).abs() <= 1e-3 * (1.0 + b.abs()),
                    "n={n} c={c} d={d} ({i},{j}): pjrt {a} vs native {b}"
                );
            }
        }
    }
}

#[test]
fn dist_top1_matches_native_argmin() {
    if !artifacts_ready() {
        return;
    }
    let mut rt = Runtime::load(default_artifact_dir()).unwrap();
    let x = randmat(700, 5, 11);
    let c = randmat(40, 5, 12);
    let (labels, dists) = rt.dist_top1(&x, &c).unwrap();
    let want = x.sq_dists(&c);
    for i in 0..700 {
        let mut best = 0usize;
        for j in 1..40 {
            if want.at(i, j) < want.at(i, best) {
                best = j;
            }
        }
        assert_eq!(labels[i] as usize, best, "row {i}");
        assert!((dists[i] - want.at(i, best)).abs() < 1e-3 * (1.0 + dists[i].abs()));
    }
}

#[test]
fn kernel_pool_serves_concurrent_requests() {
    if !artifacts_ready() {
        return;
    }
    let pool = KernelPool::start(default_artifact_dir()).unwrap();
    let c = Arc::new(randmat(32, 4, 5));
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..6u64 {
            let pool = pool.clone();
            let c = c.clone();
            handles.push(s.spawn(move || {
                let x = randmat(97 + t as usize, 4, 100 + t);
                let got = pool.pdist(x.clone(), c.clone()).unwrap();
                let want = x.sq_dists(&c);
                for (a, b) in got.data.iter().zip(&want.data) {
                    assert!((a - b).abs() < 1e-3 * (1.0 + b.abs()));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    });
    let (dispatched, rows) = pool.stats();
    assert!(dispatched >= 1);
    assert!(rows >= 6 * 97);
}

#[test]
fn pjrt_backend_runs_uspec_end_to_end() {
    if !artifacts_ready() {
        return;
    }
    let pool = KernelPool::start(default_artifact_dir()).unwrap();
    let backend = PjrtBackend::new(pool);
    let ds = two_moons(1200, 0.06, 21);
    let params = uspec::uspec::UspecParams { k: 2, p: 150, ..Default::default() };
    let res = uspec::uspec::uspec_with_backend(&ds.x, &params, 42, &backend).unwrap();
    let nmi = uspec::metrics::nmi(&res.labels, &ds.y);
    assert!(nmi > 0.85, "pjrt-backed U-SPEC nmi={nmi}");
    assert!(
        backend.kernel_calls.load(std::sync::atomic::Ordering::Relaxed) > 0,
        "expected kernel dispatches on the hot path"
    );
}

#[test]
fn pjrt_and_native_backends_agree_on_labels() {
    if !artifacts_ready() {
        return;
    }
    let pool = KernelPool::start(default_artifact_dir()).unwrap();
    let backend = PjrtBackend::new(pool);
    let ds = two_moons(600, 0.05, 33);
    let params = uspec::uspec::UspecParams { k: 2, p: 80, ..Default::default() };
    let a = uspec::uspec::uspec_with_backend(&ds.x, &params, 7, &backend).unwrap();
    let b = uspec::uspec::uspec_with_backend(&ds.x, &params, 7, &NativeBackend).unwrap();
    // identical seeds + (near-)identical distances → identical partitions
    let agreement = uspec::metrics::nmi(&a.labels, &b.labels);
    assert!(agreement > 0.95, "backend divergence: nmi={agreement}");
}

#[test]
fn backend_falls_back_when_shape_uncovered() {
    if !artifacts_ready() {
        return;
    }
    let pool = KernelPool::start(default_artifact_dir()).unwrap();
    let backend = PjrtBackend::new(pool);
    // c=300 > 256: no artifact — must fall back to native and still be right
    let x = randmat(100, 3, 1);
    let c = randmat(300, 3, 2);
    let got = backend.sq_dists(&x, &c);
    let want = x.sq_dists(&c);
    assert_eq!(got.data, want.data);
    assert!(backend.native_calls.load(std::sync::atomic::Ordering::Relaxed) >= 1);
}
