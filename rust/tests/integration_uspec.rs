//! Integration: the full U-SPEC pipeline across datasets, parameter ranges,
//! selection strategies and KNR modes — the qualitative claims of
//! Tables 4–5, 10–11, 13, 15 at test scale.

use uspec::affinity::SelectStrategy;
use uspec::data::{Benchmark, Dataset};
use uspec::kmeans::{kmeans, KmeansParams};
use uspec::metrics::{ca, nmi};
use uspec::uspec::{uspec, KnrMode, UspecParams};

fn gen(b: Benchmark, scale: f64) -> Dataset {
    b.generate(scale, 1234)
}

#[test]
fn beats_kmeans_on_every_nonlinear_synthetic() {
    // The paper's core qualitative claim across the synthetic suite.
    for b in [Benchmark::Tb1m, Benchmark::Cc5m, Benchmark::Cg10m, Benchmark::Flower20m] {
        let ds = gen(b, 0.0002);
        let params = UspecParams { k: ds.k, p: (ds.n() / 8).max(ds.k), ..Default::default() };
        let us = uspec(&ds.x, &params, 5).unwrap();
        let km = kmeans(&ds.x, &KmeansParams { k: ds.k, ..Default::default() }, 5).unwrap();
        let us_nmi = nmi(&us.labels, &ds.y);
        let km_nmi = nmi(&km.labels, &ds.y);
        assert!(
            us_nmi > km_nmi + 0.05,
            "{}: U-SPEC {us_nmi:.3} should beat k-means {km_nmi:.3}",
            b.name()
        );
    }
}

#[test]
fn quality_improves_with_p() {
    // Table 10's trend: larger p → better approximation.
    let ds = gen(Benchmark::Sf2m, 0.001); // 2000 points
    let mut scores = Vec::new();
    for p in [20usize, 80, 300] {
        let params = UspecParams { k: ds.k, p, ..Default::default() };
        // average over seeds to damp variance
        let mut s = 0.0;
        for seed in 0..3 {
            s += nmi(&uspec(&ds.x, &params, seed).unwrap().labels, &ds.y);
        }
        scores.push(s / 3.0);
    }
    assert!(
        scores[2] > scores[0] - 0.02,
        "p sweep should not degrade strongly: {scores:?}"
    );
    assert!(scores[2] > 0.6, "p=300 should work well: {scores:?}");
}

#[test]
fn all_selection_strategies_work() {
    let ds = gen(Benchmark::Tb1m, 0.001);
    for sel in [
        SelectStrategy::Random,
        SelectStrategy::KmeansFull,
        SelectStrategy::Hybrid { candidate_factor: 10 },
    ] {
        let params =
            UspecParams { k: 2, p: 150, selection: sel, ..Default::default() };
        let res = uspec(&ds.x, &params, 9).unwrap();
        let score = ca(&res.labels, &ds.y);
        assert!(score > 0.7, "{sel:?}: ca={score}");
    }
}

#[test]
fn approx_knr_matches_exact_quality() {
    // Table 15's claim: approximation preserves quality.
    let ds = gen(Benchmark::Cc5m, 0.0004); // 2000 points
    let mut qa = 0.0;
    let mut qe = 0.0;
    for seed in 0..3 {
        let pa = UspecParams { k: 3, p: 200, knr: KnrMode::Approx, ..Default::default() };
        let pe = UspecParams { k: 3, p: 200, knr: KnrMode::Exact, ..Default::default() };
        qa += nmi(&uspec(&ds.x, &pa, seed).unwrap().labels, &ds.y);
        qe += nmi(&uspec(&ds.x, &pe, seed).unwrap().labels, &ds.y);
    }
    assert!((qa - qe).abs() / 3.0 < 0.15, "approx {qa} vs exact {qe}");
}

#[test]
fn real_surrogates_reasonable() {
    // PenDigits-like data should score well; Covertype-like stays low for
    // everyone (Table 4's pattern).
    let easy = gen(Benchmark::PenDigits, 0.1);
    let p1 = UspecParams { k: easy.k, p: 300, ..Default::default() };
    let s_easy = nmi(&uspec(&easy.x, &p1, 3).unwrap().labels, &easy.y);
    assert!(s_easy > 0.5, "PenDigits surrogate: {s_easy}");

    let hard = gen(Benchmark::Covertype, 0.002);
    let p2 = UspecParams { k: hard.k, p: 300, ..Default::default() };
    let s_hard = nmi(&uspec(&hard.x, &p2, 3).unwrap().labels, &hard.y);
    assert!(s_hard < 0.35, "Covertype surrogate should stay hard: {s_hard}");
}

#[test]
fn phase_timing_accounted() {
    let ds = gen(Benchmark::Tb1m, 0.001);
    let res = uspec(&ds.x, &UspecParams { k: 2, p: 100, ..Default::default() }, 1).unwrap();
    for phase in ["select", "knr_index", "knr_query", "affinity", "transfer_cut", "discretize"] {
        assert!(res.timer.get(phase) >= 0.0, "missing phase {phase}");
    }
    assert!(res.timer.total() > 0.0);
}
