//! Clustering-as-a-service acceptance suite: the fit → save → load →
//! assign path is **bit-identical** to in-memory assignment across
//! thread counts {1, 8} × SIMD dispatch {default, forced-scalar} ×
//! chunk sizes {64, 8192} × {in-memory model, artifact roundtrip} for
//! U-SPEC and U-SENC, and the same holds over a loopback `repro serve`
//! daemon (SubmitFit → JobStatus → Assign on the `USPEC/2` framing).
//! The CI determinism matrix re-runs this suite under `USPEC_THREADS` ∈
//! {1, 2, 8} and `USPEC_SIMD` ∈ {0, 1}; the `serve-e2e` job proves the
//! same contract against the release binary over a real socket.

use std::sync::Mutex;
use std::time::Duration;

use uspec::affinity::NativeBackend;
use uspec::config::FitSpec;
use uspec::data::synthetic::two_moons;
use uspec::linalg::set_simd_override;
use uspec::net::serve::{fit_model, MODEL_EXT};
use uspec::net::{ServeClient, ServeConfig, ServeRuntime};
use uspec::pipeline::{ExecOpts, Pipeline};
use uspec::runtime::{load_model, save_model, Model};
use uspec::streaming::BinDataset;
use uspec::usenc::{usenc_fit, UsencParams};
use uspec::uspec::UspecParams;
use uspec::util::par;

/// Serializes tests that flip the global thread/SIMD overrides.
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Restores default dispatch even when an assertion unwinds.
struct Restore;

impl Drop for Restore {
    fn drop(&mut self) {
        par::set_thread_override(0);
        set_simd_override(0);
    }
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("uspec_serve_rt_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn uspec_fit_save_load_assign_bit_identical_across_threads_simd_chunks() {
    let _g = lock();
    let _restore = Restore;
    let train = two_moons(1500, 0.06, 17);
    let query = two_moons(400, 0.06, 99);
    let params = UspecParams { k: 2, p: 150, ..Default::default() };

    // baseline: single-threaded, default chunk, in-memory model
    par::set_thread_override(1);
    let pipe = Pipeline::new(&NativeBackend);
    let fit = pipe.fit(&train.x, &params, 77).unwrap();
    assert_eq!(
        fit.result.labels,
        pipe.run(&train.x, &params, 77).unwrap().labels,
        "fit must produce exactly run's labels"
    );
    let baseline = pipe.assign(&fit.model, &query.x).unwrap();
    assert_eq!(baseline.len(), query.x.rows);
    assert!(baseline.iter().all(|&l| l < 2), "labels in 0..k");

    // the artifact roundtrip is bit-exact
    let path = tmp(&format!("uspec.{MODEL_EXT}"));
    save_model(&path, &Model::Uspec(fit.model.clone())).unwrap();
    let loaded = match load_model(&path).unwrap() {
        Model::Uspec(m) => m,
        other => panic!("loaded wrong kind: {}", other.kind()),
    };
    assert_eq!(loaded, fit.model, "save/load must roundtrip bit-exactly");

    for nt in [1usize, 8] {
        par::set_thread_override(nt);
        for simd in [0usize, 1] {
            set_simd_override(simd);
            for chunk in [64usize, 8192] {
                let pipe = Pipeline::new(&NativeBackend)
                    .with_opts(ExecOpts { chunk, ..ExecOpts::default() });
                let tag = format!("nt={nt} simd={simd} chunk={chunk}");
                let mem = pipe.assign(&fit.model, &query.x).unwrap();
                assert_eq!(mem, baseline, "in-memory assign diverged at {tag}");
                let disk = pipe.assign(&loaded, &query.x).unwrap();
                assert_eq!(disk, baseline, "loaded-model assign diverged at {tag}");
            }
        }
    }
}

#[test]
fn usenc_fit_save_load_consensus_assign_bit_identical() {
    let _g = lock();
    let _restore = Restore;
    let train = two_moons(900, 0.06, 23);
    let query = two_moons(300, 0.06, 5);
    let params = UsencParams {
        k: 2,
        m: 3,
        k_min: 2,
        k_max: 4,
        base: UspecParams { p: 120, ..Default::default() },
    };

    par::set_thread_override(1);
    let fit = usenc_fit(&train.x, &params, 31, &NativeBackend, ExecOpts::default()).unwrap();
    let pipe = Pipeline::new(&NativeBackend);
    let baseline = pipe.assign_consensus(&fit.model, &query.x).unwrap();
    assert_eq!(baseline.len(), query.x.rows);

    let path = tmp(&format!("usenc.{MODEL_EXT}"));
    save_model(&path, &Model::Usenc(fit.model.clone())).unwrap();
    let loaded = match load_model(&path).unwrap() {
        Model::Usenc(m) => m,
        other => panic!("loaded wrong kind: {}", other.kind()),
    };
    assert_eq!(loaded, fit.model, "U-SENC artifact must roundtrip bit-exactly");

    for nt in [1usize, 8] {
        par::set_thread_override(nt);
        for simd in [0usize, 1] {
            set_simd_override(simd);
            for chunk in [64usize, 8192] {
                let pipe = Pipeline::new(&NativeBackend)
                    .with_opts(ExecOpts { chunk, ..ExecOpts::default() });
                let tag = format!("nt={nt} simd={simd} chunk={chunk}");
                let got = pipe.assign_consensus(&loaded, &query.x).unwrap();
                assert_eq!(got, baseline, "consensus assign diverged at {tag}");
            }
        }
    }
}

#[test]
fn corrupted_and_truncated_artifacts_are_rejected_typed() {
    let train = two_moons(400, 0.06, 9);
    let params = UspecParams { k: 2, p: 60, ..Default::default() };
    let fit = Pipeline::new(&NativeBackend).fit(&train.x, &params, 3).unwrap();
    let path = tmp(&format!("corrupt.{MODEL_EXT}"));
    save_model(&path, &Model::Uspec(fit.model)).unwrap();
    let good = std::fs::read(&path).unwrap();

    // flip one payload byte → checksum mismatch
    let mut bad = good.clone();
    let mid = bad.len() / 2;
    bad[mid] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    let err = load_model(&path).unwrap_err();
    assert!(err.to_string().contains("checksum"), "want checksum error, got {err}");

    // truncate → typed truncation error, not a panic
    std::fs::write(&path, &good[..good.len() / 3]).unwrap();
    assert!(load_model(&path).is_err());

    // restore → loads again
    std::fs::write(&path, &good).unwrap();
    assert!(load_model(&path).is_ok());
}

/// The tentpole e2e: a loopback daemon fits a submitted job, persists
/// the artifact, and serves assignments that are bit-for-bit the
/// in-process result; dropping it drains gracefully and a successor
/// reloads the registry from disk.
#[test]
fn serve_daemon_fits_persists_and_assigns_bit_identically_over_loopback() {
    let train = two_moons(800, 0.06, 41);
    let query = two_moons(250, 0.06, 77);
    let data_path = tmp("serve_train.bin");
    BinDataset::write_mat(&data_path, &train.x).unwrap();
    let models_dir = tmp("serve_models");

    let spec = FitSpec {
        method: "u-spec".into(),
        data: data_path.display().to_string(),
        k: 2,
        p: 100,
        k_nn: 5,
        m: 3,
        k_min: 2,
        k_max: 4,
        seed: 7,
    };

    let rt = ServeRuntime::bind(
        "127.0.0.1:0",
        ServeConfig { models_dir: models_dir.clone(), queue_depth: 4 },
    )
    .unwrap();
    let addr = rt.addr().to_string();

    let mut client = ServeClient::connect(&addr).unwrap();
    let job = client.submit_fit(&spec).unwrap();
    let model_id = client.wait_for(job, Duration::from_secs(120)).unwrap();
    assert_eq!(model_id, format!("model-{job:06}"));

    // the daemon's registry and the on-disk artifact both exist
    let listed = client.list_models().unwrap();
    assert!(listed.iter().any(|m| m.id == model_id && m.kind == "uspec"), "{listed:?}");
    let artifact = models_dir.join(format!("{model_id}.{MODEL_EXT}"));
    assert!(artifact.exists(), "fit must persist its artifact");

    // served assignment == in-process assignment, bit-for-bit
    let local_model = match fit_model(&spec).unwrap() {
        Model::Uspec(m) => m,
        other => panic!("wrong kind {}", other.kind()),
    };
    let expect = Pipeline::new(&NativeBackend).assign(&local_model, &query.x).unwrap();
    let served = client.assign(&model_id, &query.x).unwrap();
    assert_eq!(served, expect, "wire assignment must match the in-process path");

    // a second concurrent client sees the same state
    let mut second = ServeClient::connect(&addr).unwrap();
    assert_eq!(second.assign(&model_id, &query.x).unwrap(), expect);

    // typed errors over the wire: unknown model, unknown job, bad data
    let err = client.assign("no-such-model", &query.x).unwrap_err();
    assert!(err.to_string().contains("unknown model"), "{err}");
    let err = client.job_status(9999).unwrap_err();
    assert!(err.to_string().contains("unknown job"), "{err}");
    let bad = FitSpec { data: tmp("missing.bin").display().to_string(), ..spec.clone() };
    let bad_job = client.submit_fit(&bad).unwrap();
    let err = client.wait_for(bad_job, Duration::from_secs(30)).unwrap_err();
    assert!(err.to_string().contains("failed"), "{err}");

    // graceful shutdown, then a successor reloads the registry from disk
    drop(client);
    drop(second);
    drop(rt);
    let rt2 = ServeRuntime::bind(
        "127.0.0.1:0",
        ServeConfig { models_dir: models_dir.clone(), queue_depth: 4 },
    )
    .unwrap();
    assert_eq!(rt2.model_ids(), vec![model_id.clone()]);
    let mut client = ServeClient::connect(&rt2.addr().to_string()).unwrap();
    assert_eq!(
        client.assign(&model_id, &query.x).unwrap(),
        expect,
        "a restarted daemon serves the persisted model identically"
    );
}

#[test]
fn serve_daemon_fits_and_assigns_usenc_consensus_over_loopback() {
    let train = two_moons(600, 0.06, 13);
    let query = two_moons(200, 0.06, 3);
    let data_path = tmp("serve_usenc.bin");
    BinDataset::write_mat(&data_path, &train.x).unwrap();

    let spec = FitSpec {
        method: "u-senc".into(),
        data: data_path.display().to_string(),
        k: 2,
        p: 80,
        k_nn: 5,
        m: 3,
        k_min: 2,
        k_max: 4,
        seed: 19,
    };

    let rt = ServeRuntime::bind(
        "127.0.0.1:0",
        ServeConfig { models_dir: tmp("serve_usenc_models"), queue_depth: 2 },
    )
    .unwrap();
    let mut client = ServeClient::connect(&rt.addr().to_string()).unwrap();
    let job = client.submit_fit(&spec).unwrap();
    let model_id = client.wait_for(job, Duration::from_secs(120)).unwrap();

    let local_model = match fit_model(&spec).unwrap() {
        Model::Usenc(m) => m,
        other => panic!("wrong kind {}", other.kind()),
    };
    let expect =
        Pipeline::new(&NativeBackend).assign_consensus(&local_model, &query.x).unwrap();
    assert_eq!(
        client.assign(&model_id, &query.x).unwrap(),
        expect,
        "served consensus assignment must match the in-process path"
    );
}
