//! Integration: the 14 baselines on shared workloads — the qualitative
//! ordering the paper's Tables 4–9 report, at test scale.

use uspec::affinity::NativeBackend;
use uspec::baselines::SpectralMethod;
use uspec::bench::runner::{run_ensemble, run_spectral};
use uspec::config::RunConfig;
use uspec::data::Benchmark;
use uspec::ensemble_baselines::EnsembleMethod;
use uspec::metrics::nmi;

fn cfg_small() -> RunConfig {
    RunConfig { p: 100, m: 5, k_min: 4, k_max: 10, runs: 1, ..Default::default() }
}

#[test]
fn spectral_methods_on_rings_uspec_wins() {
    // CC (concentric circles) is the separator: kernel-free methods
    // (k-means, EulerSC, FastESC with few features) collapse, graph
    // methods shine — the Table 4 CC-5M column.
    let ds = Benchmark::Cc5m.generate(0.0006, 3); // 3000 points, 3 rings
    let cfg = RunConfig { p: 200, m: 10, k_min: 6, k_max: 14, runs: 1, ..Default::default() };
    let mut scores = std::collections::HashMap::new();
    for m in [
        SpectralMethod::Kmeans,
        SpectralMethod::EulerSc,
        SpectralMethod::Uspec,
        SpectralMethod::Usenc,
    ] {
        let out = run_spectral(m, &ds, &cfg, 7, &NativeBackend).unwrap();
        scores.insert(m.name(), nmi(&out.labels, &ds.y));
    }
    assert!(scores["U-SPEC"] > 0.9, "{scores:?}");
    assert!(scores["U-SENC"] > 0.6, "{scores:?}");
    assert!(scores["k-means"] < 0.1, "{scores:?}");
    assert!(scores["EulerSC"] < 0.5, "{scores:?}");
    assert!(scores["U-SENC"] > scores["k-means"] + 0.5, "{scores:?}");
}

#[test]
fn all_ensemble_methods_beat_chance_on_blobs() {
    let ds = Benchmark::PenDigits.generate(0.09, 5); // ~1000 points, 10 classes
    let cfg = cfg_small();
    for m in EnsembleMethod::ALL {
        let out = run_ensemble(m, &ds, &cfg, 11, &NativeBackend).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.25, "{}: nmi={score}", m.name());
    }
}

#[test]
fn usenc_tops_ensemble_baselines_on_nonlinear_data() {
    // Table 7's headline: U-SENC (U-SPEC base clusterers) beats k-means-
    // based ensembles on nonlinearly separable data.
    let ds = Benchmark::Tb1m.generate(0.0012, 9);
    let cfg = cfg_small();
    let usenc_score = {
        let out = run_ensemble(EnsembleMethod::Usenc, &ds, &cfg, 3, &NativeBackend).unwrap();
        nmi(&out.labels, &ds.y)
    };
    let mut beaten = 0;
    let mut total = 0;
    for m in [EnsembleMethod::Kcc, EnsembleMethod::Ecc, EnsembleMethod::Sec, EnsembleMethod::Lwgp] {
        let out = run_ensemble(m, &ds, &cfg, 3, &NativeBackend).unwrap();
        let s = nmi(&out.labels, &ds.y);
        total += 1;
        if usenc_score >= s - 1e-9 {
            beaten += 1;
        }
    }
    assert!(
        beaten * 2 >= total && usenc_score > 0.6,
        "U-SENC {usenc_score} beat {beaten}/{total}"
    );
}

#[test]
fn sub_matrix_methods_complete_quickly_vs_full_graph() {
    // Table 6's shape: sub-matrix methods (Nyström/LSC/U-SPEC) are far
    // cheaper than the full-graph SC on the same data.
    let ds = Benchmark::Usps.generate(0.1, 13); // ~1100 × 256
    let cfg = cfg_small();
    let t_sc = {
        let t0 = std::time::Instant::now();
        run_spectral(SpectralMethod::Sc, &ds, &cfg, 5, &NativeBackend).unwrap();
        t0.elapsed().as_secs_f64()
    };
    let t_uspec = {
        let t0 = std::time::Instant::now();
        run_spectral(SpectralMethod::Uspec, &ds, &cfg, 5, &NativeBackend).unwrap();
        t0.elapsed().as_secs_f64()
    };
    assert!(
        t_uspec < t_sc,
        "U-SPEC ({t_uspec:.2}s) should be faster than SC ({t_sc:.2}s)"
    );
}
