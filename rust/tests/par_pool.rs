//! Runtime tests for the persistent worker pool (`uspec::util::par`):
//! pooled primitives must match the sequential path exactly — including
//! nested calls and ragged chunk tails — and the clustering pipelines must
//! stay bit-identical for a fixed seed at any thread count.

use std::sync::Mutex;

use uspec::data::synthetic::two_moons;
use uspec::usenc::{usenc, UsencParams};
use uspec::uspec::{uspec, UspecParams};
use uspec::util::par;

/// Serializes tests that flip the global thread override. (Results are
/// thread-count invariant by design, but serializing keeps each test's
/// configuration honest.)
static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

#[test]
fn par_map_matches_sequential_across_thread_counts() {
    let _g = lock();
    for &n in &[0usize, 1, 2, 7, 64, 1000, 4097] {
        par::set_thread_override(1);
        let seq: Vec<u64> = par::par_map(n, |i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
        for nt in [2usize, 3, 8] {
            par::set_thread_override(nt);
            let got = par::par_map(n, |i| (i as u64).wrapping_mul(0x9E3779B97F4A7C15));
            assert_eq!(got, seq, "n={n} nt={nt}");
        }
    }
    par::set_thread_override(0);
}

#[test]
fn par_for_chunks_ragged_tails_cover_everything() {
    let _g = lock();
    // sizes chosen to leave ragged last chunks for every chunk_len
    for &(n, chunk_len) in &[(1usize, 3usize), (10, 3), (1000, 128), (4097, 64), (513, 512)] {
        par::set_thread_override(1);
        let mut seq = vec![0usize; n];
        par::par_for_chunks(&mut seq, chunk_len, |start, ch| {
            for (o, v) in ch.iter_mut().enumerate() {
                *v = (start + o) * 3 + ch.len();
            }
        });
        for nt in [2usize, 8] {
            par::set_thread_override(nt);
            let mut got = vec![0usize; n];
            par::par_for_chunks(&mut got, chunk_len, |start, ch| {
                for (o, v) in ch.iter_mut().enumerate() {
                    *v = (start + o) * 3 + ch.len();
                }
            });
            assert_eq!(got, seq, "n={n} chunk_len={chunk_len} nt={nt}");
        }
    }
    par::set_thread_override(0);
}

#[test]
fn par_reduce_bitwise_invariant_across_thread_counts() {
    let _g = lock();
    let f = |i: usize| (1.0 + i as f64).ln() * if i % 2 == 0 { 1.0 } else { -1.0 };
    par::set_thread_override(1);
    let baseline = par::par_reduce(54_321, 0.0f64, f, |a, b| a + b);
    for nt in [2usize, 3, 8, 32] {
        par::set_thread_override(nt);
        let got = par::par_reduce(54_321, 0.0f64, f, |a, b| a + b);
        assert_eq!(got.to_bits(), baseline.to_bits(), "nt={nt}");
    }
    par::set_thread_override(0);
}

#[test]
fn nested_parallel_calls_match_sequential() {
    let _g = lock();
    par::set_thread_override(8);
    // outer par_map whose tasks use all three primitives
    let got = par::par_map(40, |i| {
        let inner = par::par_map(30, move |j| ((i + 1) * (j + 3)) as u64);
        let rsum = par::par_reduce(30, 0u64, move |j| ((i + 1) * (j + 3)) as u64, |a, b| a + b);
        assert_eq!(inner.iter().sum::<u64>(), rsum);
        let mut buf = vec![0u64; 25];
        par::par_for_chunks(&mut buf, 4, |start, ch| {
            for (o, v) in ch.iter_mut().enumerate() {
                *v = ((start + o) * i) as u64;
            }
        });
        rsum + buf.iter().sum::<u64>()
    });
    par::set_thread_override(1);
    let want = par::par_map(40, |i| {
        let rsum: u64 = (0..30).map(|j| ((i + 1) * (j + 3)) as u64).sum();
        let bsum: u64 = (0..25).map(|o| (o * i) as u64).sum();
        rsum + bsum
    });
    assert_eq!(got, want);
    par::set_thread_override(0);
}

#[test]
fn uspec_bit_identical_across_thread_counts() {
    let _g = lock();
    let ds = two_moons(900, 0.06, 41);
    let params = UspecParams { k: 2, p: 90, ..Default::default() };
    par::set_thread_override(1);
    let base = uspec(&ds.x, &params, 1234).unwrap();
    for nt in [2usize, 8] {
        par::set_thread_override(nt);
        let run = uspec(&ds.x, &params, 1234).unwrap();
        assert_eq!(run.labels, base.labels, "labels differ at nt={nt}");
        assert_eq!(
            run.sigma.to_bits(),
            base.sigma.to_bits(),
            "sigma differs at nt={nt}"
        );
        assert_eq!(run.embedding.rows, base.embedding.rows);
        for (a, b) in run.embedding.data.iter().zip(&base.embedding.data) {
            assert_eq!(a.to_bits(), b.to_bits(), "embedding differs at nt={nt}");
        }
    }
    par::set_thread_override(0);
}

#[test]
fn usenc_deterministic_across_thread_counts() {
    let _g = lock();
    let ds = two_moons(500, 0.06, 17);
    let params = UsencParams {
        k: 2,
        m: 4,
        k_min: 4,
        k_max: 9,
        base: UspecParams { p: 60, ..Default::default() },
    };
    par::set_thread_override(1);
    let base = usenc(&ds.x, &params, 777, &uspec::affinity::NativeBackend).unwrap();
    for nt in [2usize, 8] {
        par::set_thread_override(nt);
        let run = usenc(&ds.x, &params, 777, &uspec::affinity::NativeBackend).unwrap();
        assert_eq!(run.labels, base.labels, "consensus labels differ at nt={nt}");
        assert_eq!(run.ensemble.labelings, base.ensemble.labelings);
    }
    par::set_thread_override(0);
}
