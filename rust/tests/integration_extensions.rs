//! Cross-module integration of the extension features: out-of-core
//! streaming and k-estimation driven through the AOT/PJRT kernel backend,
//! adaptive ensembles through the coordinator's job-derivation stream, and
//! the hypergraph consensus functions on coordinator-generated ensembles.

use uspec::affinity::NativeBackend;
use uspec::coordinator::run_base_clusterers;
use uspec::data::synthetic::{concentric_circles, two_moons};
use uspec::ensemble_baselines::strehl;
use uspec::metrics::nmi;
use uspec::runtime::{default_artifact_dir, KernelPool, PjrtBackend};
use uspec::streaming::{stream_uspec, BinDataset, StreamParams};
use uspec::usenc::adaptive::{usenc_adaptive, AdaptiveParams};
use uspec::usenc::UsencParams;
use uspec::uspec::estimate::estimate_k;
use uspec::uspec::UspecParams;

fn artifacts_ready() -> bool {
    default_artifact_dir().join("manifest.json").exists()
}

fn tmp(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("uspec_ext_integration");
    std::fs::create_dir_all(&dir).unwrap();
    dir.join(name)
}

#[test]
fn streaming_through_pjrt_backend() {
    if !artifacts_ready() {
        eprintln!("[skip] artifacts not built");
        return;
    }
    let ds = two_moons(3000, 0.06, 5);
    let bin = BinDataset::write_mat(&tmp("pjrt_moons.bin"), &ds.x).unwrap();
    let pool = KernelPool::start(default_artifact_dir()).unwrap();
    let backend = PjrtBackend::new(pool);
    let params = StreamParams {
        chunk: 1024,
        shards: 1,
        base: UspecParams { k: 2, p: 200, ..Default::default() },
        ..Default::default()
    };
    let pjrt = stream_uspec(&bin, &params, 11, &backend).unwrap();
    let native = stream_uspec(&bin, &params, 11, &NativeBackend).unwrap();
    let s_pjrt = nmi(&pjrt.labels, &ds.y);
    let s_native = nmi(&native.labels, &ds.y);
    assert!(s_pjrt > 0.85, "pjrt streamed nmi={s_pjrt}");
    // both backends compute the same distances (allclose) → same quality
    assert!(
        (s_pjrt - s_native).abs() < 0.1,
        "pjrt {s_pjrt} vs native {s_native}"
    );
}

#[test]
fn estimate_k_through_pjrt_backend() {
    if !artifacts_ready() {
        eprintln!("[skip] artifacts not built");
        return;
    }
    let ds = concentric_circles(2000, 9);
    let pool = KernelPool::start(default_artifact_dir()).unwrap();
    let backend = PjrtBackend::new(pool);
    let params = UspecParams { p: 400, ..Default::default() };
    let est = estimate_k(&ds.x, &params, 2, 8, 3, &backend).unwrap();
    assert_eq!(est.k, 3, "spectrum {:?}", est.lambdas);
}

#[test]
fn adaptive_usenc_prefix_matches_coordinator_jobs() {
    // the adaptive loop and the coordinator derive base clusterers from
    // the same seed stream: a converged adaptive ensemble must be a prefix
    // of the coordinator's (worker-count-independent) output.
    let ds = two_moons(600, 0.05, 13);
    let params = UsencParams {
        k: 2,
        m: 10,
        k_min: 4,
        k_max: 9,
        base: UspecParams { p: 80, ..Default::default() },
    };
    let ap = AdaptiveParams { batch: 2, m_min: 4, m_max: 6, stability: 2.0, patience: 1 };
    let adaptive = usenc_adaptive(&ds.x, &params, &ap, 31, &NativeBackend).unwrap();
    let coordinated =
        run_base_clusterers(&ds.x, &params, 31, &NativeBackend, 3, None).unwrap();
    assert_eq!(adaptive.ensemble.m(), 6);
    for (i, a) in adaptive.ensemble.labelings.iter().enumerate() {
        assert_eq!(a, &coordinated.labelings[i], "base clustering {i} diverged");
    }
}

#[test]
fn hypergraph_consensus_on_coordinator_ensemble() {
    // full path: coordinator-generated U-SPEC ensemble → all four
    // hypergraph consensus functions produce valid, informative labels.
    let ds = concentric_circles(900, 3);
    let params = UsencParams {
        k: 3,
        m: 6,
        k_min: 6,
        k_max: 12,
        base: UspecParams { p: 90, ..Default::default() },
    };
    let ens = run_base_clusterers(&ds.x, &params, 7, &NativeBackend, 2, None).unwrap();
    for (name, f) in [
        ("cspa", strehl::cspa as fn(&uspec::usenc::Ensemble, usize, u64) -> uspec::Result<Vec<u32>>),
        ("hgpa", strehl::hgpa),
        ("mcla", strehl::mcla),
        ("hbgf", strehl::hbgf),
    ] {
        let labels = f(&ens, 3, 5).unwrap();
        assert_eq!(labels.len(), 900);
        let score = nmi(&labels, &ds.y);
        // U-SPEC bases separate the rings; any reasonable consensus keeps
        // most of that signal.
        assert!(score > 0.5, "{name}: nmi={score}");
    }
}
