//! Failure-injection tests: every layer must fail *loudly and precisely*
//! on bad input — corrupt artifacts, degenerate data, impossible
//! parameters — and never panic or silently produce garbage.

use uspec::affinity::{build_affinity, knr::KnrIndex, select, NativeBackend, SelectStrategy};
use uspec::bipartite::{transfer_cut, EigSolver};
use uspec::data::loader;
use uspec::graphpart::{partition, Graph, PartitionParams};
use uspec::kmeans::{kmeans, KmeansParams};
use uspec::linalg::{Csr, Mat};
use uspec::runtime::{KernelPool, Runtime};
use uspec::streaming::{stream_uspec, BinDataset, StreamParams};
use uspec::usenc::{consensus_bipartite, usenc, Ensemble, UsencParams};
use uspec::uspec::{uspec, UspecParams};
use uspec::Error;

fn tmpdir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join("uspec_failure_tests").join(name);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

// ---------- runtime: artifacts ------------------------------------------

#[test]
fn runtime_missing_dir_is_runtime_error() {
    let Err(err) = Runtime::load("/nonexistent/artifact/dir") else {
        panic!("load of missing dir succeeded")
    };
    assert!(matches!(err, Error::Runtime(_)), "got {err}");
    let Err(err) = KernelPool::start("/nonexistent/artifact/dir") else {
        panic!("pool start on missing dir succeeded")
    };
    assert!(matches!(err, Error::Runtime(_)), "got {err}");
}

#[test]
fn runtime_corrupt_manifest_rejected() {
    let dir = tmpdir("corrupt_manifest");
    std::fs::write(dir.join("manifest.json"), "{ not json !!").unwrap();
    let Err(err) = Runtime::load(&dir) else { panic!("corrupt manifest accepted") };
    assert!(matches!(err, Error::Runtime(_)), "got {err}");
    // structurally valid JSON but missing required keys
    std::fs::write(dir.join("manifest.json"), r#"{"batch": 2048}"#).unwrap();
    let Err(err) = Runtime::load(&dir) else { panic!("incomplete manifest accepted") };
    let msg = format!("{err}");
    assert!(msg.contains("fingerprint"), "unhelpful error: {msg}");
}

#[test]
fn runtime_manifest_pointing_at_missing_hlo() {
    let dir = tmpdir("missing_hlo");
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"fingerprint":"f","batch":8,"artifacts":[
            {"name":"pdist_x","graph":"pdist","file":"gone.hlo.txt",
             "b":8,"c":4,"d":2,"k":null,"inputs":["x","c"],"outputs":1}]}"#,
    )
    .unwrap();
    // loading may defer compilation; executing a matching shape must error,
    // not panic.
    match Runtime::load(&dir) {
        Err(e) => assert!(matches!(e, Error::Runtime(_) | Error::Xla(_) | Error::Io(_))),
        Ok(mut rt) => {
            let x = Mat::zeros(8, 2);
            let c = Mat::zeros(4, 2);
            assert!(rt.pdist(&x, &c).is_err());
        }
    }
}

// ---------- affinity / transfer cut -------------------------------------

#[test]
fn transfer_cut_zero_affinity_row_is_numerical_error() {
    // object 2 has no representative connection at all
    let rows = vec![
        vec![(0u32, 0.9), (1u32, 0.3)],
        vec![(0u32, 0.8), (1u32, 0.1)],
        vec![],
        vec![(1u32, 0.7), (2u32, 0.2)],
    ];
    let b = Csr::from_rows(4, 3, &rows);
    let err = transfer_cut(&b, 2, EigSolver::Dense, 1).unwrap_err();
    assert!(matches!(err, Error::Numerical(_)), "got {err:?}");
    let msg = format!("{err}");
    assert!(msg.contains("object 2"), "error should name the offending row: {msg}");
}

#[test]
fn transfer_cut_drops_unselected_representatives() {
    // representative 2 is never selected: transfer cut must still work by
    // dropping the empty column (and must not panic on the p→p' remap).
    let rows = vec![
        vec![(0u32, 0.9), (1u32, 0.3)],
        vec![(0u32, 0.8), (1u32, 0.1)],
        vec![(0u32, 0.5), (3u32, 0.9)],
        vec![(1u32, 0.7), (3u32, 0.2)],
    ];
    let b = Csr::from_rows(4, 4, &rows);
    let tc = transfer_cut(&b, 2, EigSolver::Dense, 1).unwrap();
    assert_eq!(tc.embedding.rows, 4);
    // but k greater than the *connected* representative count must fail
    assert!(transfer_cut(&b, 4, EigSolver::Dense, 1).is_err());
}

#[test]
fn select_rejects_degenerate_requests() {
    let ds = uspec::data::synthetic::two_moons(50, 0.05, 1);
    assert!(select(&ds.x, SelectStrategy::Random, 0, 5, 1).is_err());
    // p > n clamps or errors — must not panic either way
    let _ = select(&ds.x, SelectStrategy::Random, 500, 5, 1);
}

#[test]
fn knr_index_rejects_empty_reps() {
    let empty = Mat::zeros(0, 2);
    assert!(KnrIndex::build(&empty, 5, 5, &NativeBackend).is_err());
}

// ---------- uspec / usenc -----------------------------------------------

#[test]
fn uspec_impossible_k() {
    let ds = uspec::data::synthetic::two_moons(30, 0.05, 1);
    let params = UspecParams { k: 31, p: 10, ..Default::default() };
    assert!(uspec(&ds.x, &params, 1).is_err());
    let params = UspecParams { k: 0, p: 10, ..Default::default() };
    assert!(uspec(&ds.x, &params, 1).is_err());
}

#[test]
fn uspec_constant_data_does_not_panic() {
    // all points identical: distances are all zero; σ clamps; the pipeline
    // may legitimately fail (zero affinity is fine) but must not panic.
    let x = Mat::from_vec(40, 2, vec![1.5f32; 80]);
    let params = UspecParams { k: 2, p: 8, ..Default::default() };
    let _ = uspec(&x, &params, 3);
}

#[test]
fn usenc_rejects_bad_ranges() {
    let ds = uspec::data::synthetic::two_moons(60, 0.05, 1);
    // k_min > k_max is normalized or rejected, not a panic
    let params = UsencParams {
        k: 2,
        m: 3,
        k_min: 9,
        k_max: 4,
        base: UspecParams { p: 20, ..Default::default() },
    };
    let _ = usenc(&ds.x, &params, 1, &NativeBackend);
    // empty ensemble consensus
    assert!(consensus_bipartite(&Ensemble::default(), 2, EigSolver::Dense, 1).is_err());
    // k exceeding total cluster count
    let mut ens = Ensemble::default();
    ens.push(vec![0, 1, 0, 1]);
    assert!(consensus_bipartite(&ens, 3, EigSolver::Dense, 1).is_err());
}

// ---------- kmeans -------------------------------------------------------

#[test]
fn kmeans_rejects_degenerate() {
    let x = Mat::zeros(10, 2);
    assert!(kmeans(&x, &KmeansParams { k: 0, ..Default::default() }, 1).is_err());
    assert!(kmeans(&x, &KmeansParams { k: 11, ..Default::default() }, 1).is_err());
    let empty = Mat::zeros(0, 2);
    assert!(kmeans(&empty, &KmeansParams { k: 1, ..Default::default() }, 1).is_err());
}

// ---------- graph partitioner -------------------------------------------

#[test]
fn partition_edge_cases() {
    let g = Graph::from_edges(4, &[(0, 1, 1.0), (2, 3, 1.0)]);
    assert!(partition(&g, 0, &PartitionParams::default(), 1).is_err());
    // disconnected graph still partitions (cut 0 achievable)
    let part = partition(&g, 2, &PartitionParams::default(), 1).unwrap();
    assert!(g.edge_cut(&part) <= 1.0 + 1e-12);
    // isolated vertices (no edges at all)
    let iso = Graph::from_edges(5, &[]);
    let part = partition(&iso, 3, &PartitionParams::default(), 1).unwrap();
    assert_eq!(part.len(), 5);
}

// ---------- loaders / on-disk format ------------------------------------

#[test]
fn csv_loader_errors_are_descriptive() {
    let dir = tmpdir("csv");
    let bad_width = dir.join("width.csv");
    std::fs::write(&bad_width, "1.0,2.0,0\n1.0,3\n").unwrap();
    let err = loader::load_csv(&bad_width).unwrap_err();
    let msg = format!("{err}");
    assert!(msg.contains("line 2"), "{msg}");

    let bad_float = dir.join("float.csv");
    std::fs::write(&bad_float, "1.0,abc,0\n").unwrap();
    let err = loader::load_csv(&bad_float).unwrap_err();
    assert!(format!("{err}").contains("bad float"), "{err}");

    assert!(loader::load_csv(std::path::Path::new("/no/such/file.csv")).is_err());
}

#[test]
fn bin_dataset_rejects_header_lies() {
    let dir = tmpdir("bin");
    // header claims more rows than the file holds
    let path = dir.join("lies.bin");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"USPECB01");
    bytes.extend_from_slice(&1000u64.to_le_bytes());
    bytes.extend_from_slice(&2u64.to_le_bytes());
    bytes.extend_from_slice(&[0u8; 64]); // far too short
    std::fs::write(&path, &bytes).unwrap();
    assert!(BinDataset::open(&path).is_err());
    // d = 0
    let path2 = dir.join("d0.bin");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"USPECB01");
    bytes.extend_from_slice(&0u64.to_le_bytes());
    bytes.extend_from_slice(&0u64.to_le_bytes());
    std::fs::write(&path2, &bytes).unwrap();
    assert!(BinDataset::open(&path2).is_err());
}

#[test]
fn stream_uspec_tiny_dataset_errors_cleanly() {
    let dir = tmpdir("stream");
    let x = Mat::from_vec(1, 2, vec![0.0, 0.0]);
    let path = dir.join("one.bin");
    let bin = BinDataset::write_mat(&path, &x).unwrap();
    let params = StreamParams {
        chunk: 8,
        shards: 1,
        base: UspecParams { k: 2, p: 4, ..Default::default() },
        ..Default::default()
    };
    assert!(stream_uspec(&bin, &params, 1, &NativeBackend).is_err());
}

// ---------- affinity construction on adversarial KNR ---------------------

#[test]
fn build_affinity_handles_zero_distances() {
    // duplicate points: d² = 0 everywhere in some rows ⇒ b_ij = 1, σ > 0
    let knr = uspec::affinity::knr::KnrResult {
        idx: vec![0, 1, 0, 1, 0, 1],
        d2: vec![0.0, 0.0, 0.0, 0.5, 0.1, 0.2],
        k: 2,
    };
    let aff = build_affinity(3, 2, 2, &knr);
    assert!(aff.sigma > 0.0);
    for &v in &aff.b.values {
        assert!(v.is_finite() && v > 0.0 && v <= 1.0 + 1e-12);
    }
}
