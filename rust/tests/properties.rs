//! Randomized property tests over the crate's core invariants (DESIGN.md
//! "Key invariants"), driven by the in-tree prop harness.

use uspec::affinity::{build_affinity, knr::KnrIndex, select, NativeBackend, SelectStrategy};
use uspec::bipartite::{full_bipartite_eig, transfer_cut, EigSolver};
use uspec::linalg::{DMat, Mat};
use uspec::metrics::{ca, nmi};
use uspec::prop_assert;
use uspec::usenc::Ensemble;
use uspec::util::prop::run_prop;
use uspec::util::rng::Rng;

fn random_points(rng: &mut Rng, n: usize, d: usize) -> Mat {
    // clustered blobs so graphs are non-degenerate
    let k = 2 + rng.usize(3);
    let centers: Vec<Vec<f64>> =
        (0..k).map(|_| (0..d).map(|_| rng.normal() * 4.0).collect()).collect();
    let mut m = Mat::zeros(n, d);
    for i in 0..n {
        let c = &centers[rng.usize(k)];
        for j in 0..d {
            m.set(i, j, (c[j] + rng.normal() * 0.5) as f32);
        }
    }
    m
}

fn random_labels(rng: &mut Rng, n: usize, k: usize) -> Vec<u32> {
    (0..n).map(|_| rng.usize(k) as u32).collect()
}

#[test]
fn prop_nmi_symmetry_and_permutation_invariance() {
    run_prop("nmi-sym", 40, 101, |rng| {
        let n = 20 + rng.usize(200);
        let ka = 2 + rng.usize(5);
        let a = random_labels(rng, n, ka);
        let kb = 2 + rng.usize(5);
        let b = random_labels(rng, n, kb);
        let forward = nmi(&a, &b);
        let backward = nmi(&b, &a);
        prop_assert!((forward - backward).abs() < 1e-12, "asymmetric: {forward} vs {backward}");
        // permute a's label names
        let perm: Vec<u32> = {
            let mut p: Vec<u32> = (0..10).collect();
            rng.shuffle(&mut p);
            p
        };
        let ap: Vec<u32> = a.iter().map(|&l| perm[l as usize]).collect();
        let permuted = nmi(&ap, &b);
        prop_assert!((forward - permuted).abs() < 1e-12, "not permutation invariant");
        Ok(())
    });
}

#[test]
fn prop_ca_bounds_and_optimality() {
    run_prop("ca-bounds", 40, 202, |rng| {
        let n = 10 + rng.usize(100);
        let k = 2 + rng.usize(4);
        let truth = random_labels(rng, n, k);
        let pred = random_labels(rng, n, k);
        let acc = ca(&pred, &truth);
        prop_assert!((0.0..=1.0).contains(&acc), "out of range {acc}");
        // CA under the identity matching is a lower bound of optimal CA
        let ident_acc = pred
            .iter()
            .zip(&truth)
            .filter(|(a, b)| a == b)
            .count() as f64
            / n as f64;
        prop_assert!(acc + 1e-12 >= ident_acc, "hungarian worse than identity");
        Ok(())
    });
}

#[test]
fn prop_affinity_row_structure() {
    run_prop("affinity-rows", 12, 303, |rng| {
        let n = 100 + rng.usize(200);
        let dd = 1 + rng.usize(4);
        let x = random_points(rng, n, dd);
        let p = 10 + rng.usize(20);
        let k_nn = 1 + rng.usize(4.min(p - 1));
        let reps = select(&x, SelectStrategy::Hybrid { candidate_factor: 5 }, p, 10, rng.next_u64())
            .map_err(|e| e.to_string())?;
        let index = KnrIndex::build(&reps, 3 * k_nn, 10, &NativeBackend).map_err(|e| e.to_string())?;
        let res = index.approx_knr(&x, k_nn, &NativeBackend);
        let aff = build_affinity(n, p, res.k, &res);
        prop_assert!(aff.sigma > 0.0, "sigma must be positive");
        for i in 0..n {
            let (cols, vals) = aff.b.row(i);
            prop_assert!(cols.len() == res.k, "row {i} has {} entries", cols.len());
            let set: std::collections::HashSet<_> = cols.iter().collect();
            prop_assert!(set.len() == cols.len(), "duplicate reps in row {i}");
            for &v in vals {
                prop_assert!(v > 0.0 && v <= 1.0 + 1e-9, "affinity out of range: {v}");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_transfer_cut_equals_full_problem() {
    // γ of the reduced problem == γ of the (N+p)-node problem (Eq. 10).
    run_prop("tcut-equivalence", 8, 404, |rng| {
        let n = 60 + rng.usize(60);
        let x = random_points(rng, n, 2);
        let p = 8 + rng.usize(8);
        let k = 2 + rng.usize(2);
        let reps = select(&x, SelectStrategy::Random, p, 10, rng.next_u64())
            .map_err(|e| e.to_string())?;
        let index = KnrIndex::build(&reps, p - 1, 10, &NativeBackend).map_err(|e| e.to_string())?;
        let res = index.approx_knr(&x, 3.min(p), &NativeBackend);
        let aff = build_affinity(n, p, res.k, &res);
        let tc = transfer_cut(&aff.b, k, EigSolver::Dense, 1).map_err(|e| e.to_string())?;
        let (full, _) = full_bipartite_eig(&aff.b, k).map_err(|e| e.to_string())?;
        for (ours, truth) in tc.gammas.iter().zip(&full) {
            prop_assert!((ours - truth).abs() < 1e-5, "gamma mismatch {ours} vs {truth}");
        }
        Ok(())
    });
}

#[test]
fn prop_ensemble_incidence_consistency() {
    run_prop("incidence", 30, 505, |rng| {
        let n = 20 + rng.usize(100);
        let m = 1 + rng.usize(6);
        let mut ens = Ensemble::default();
        for _ in 0..m {
            let k = 2 + rng.usize(6);
            // ensure labels dense 0..k-1
            let mut l = random_labels(rng, n, k);
            for c in 0..k {
                l[c.min(n - 1)] = c as u32;
            }
            ens.push(l);
        }
        let b = ens.incidence();
        prop_assert!(b.nnz() == n * m, "nnz {} != n*m", b.nnz());
        for i in 0..n {
            prop_assert!(b.row(i).0.len() == m, "row {i} wrong degree");
        }
        let cols = b.col_sums();
        let total: f64 = cols.iter().sum();
        prop_assert!((total - (n * m) as f64).abs() < 1e-9, "mass mismatch");
        Ok(())
    });
}

#[test]
fn prop_eigen_residuals_random_laplacians() {
    run_prop("eigen-laplacian", 10, 606, |rng| {
        let p = 10 + rng.usize(30);
        // random affinity → Laplacian
        let mut e = DMat::zeros(p, p);
        for i in 0..p {
            for j in 0..i {
                let v = rng.f64();
                e.set(i, j, v);
                e.set(j, i, v);
            }
        }
        let d: Vec<f64> = (0..p).map(|i| e.row(i).iter().sum::<f64>().max(1e-9)).collect();
        let mut l = DMat::zeros(p, p);
        for i in 0..p {
            for j in 0..p {
                l.set(i, j, if i == j { d[i] - e.at(i, j) } else { -e.at(i, j) });
            }
        }
        let k = 2 + rng.usize(3.min(p - 2));
        let (vals, v) = uspec::linalg::eigen::sym_eig_generalized_smallest(&l, &d, k)
            .map_err(|e| e.to_string())?;
        prop_assert!(vals[0].abs() < 1e-7, "first eigenvalue should be ~0, got {}", vals[0]);
        let lv = l.matmul(&v);
        for c in 0..k {
            for r in 0..p {
                let resid = (lv.at(r, c) - vals[c] * d[r] * v.at(r, c)).abs();
                prop_assert!(resid < 1e-6, "residual {resid} at ({r},{c})");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_kmeans_inertia_and_labels() {
    run_prop("kmeans", 20, 707, |rng| {
        let n = 30 + rng.usize(150);
        let d = 1 + rng.usize(4);
        let k = 1 + rng.usize(6.min(n - 1));
        let x = random_points(rng, n, d);
        let res = uspec::kmeans::kmeans(
            &x,
            &uspec::kmeans::KmeansParams { k, ..Default::default() },
            rng.next_u64(),
        )
        .map_err(|e| e.to_string())?;
        prop_assert!(res.inertia >= 0.0, "negative inertia");
        let mut seen = vec![false; k];
        for &l in &res.labels {
            prop_assert!((l as usize) < k, "label out of range");
            seen[l as usize] = true;
        }
        prop_assert!(seen.iter().all(|&s| s), "empty cluster survived repair");
        Ok(())
    });
}
