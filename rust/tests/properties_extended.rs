//! Randomized property tests over the extended subsystems: the multilevel
//! graph partitioner, hypergraph consensus functions, similarity kernels,
//! extra metrics, the Hungarian solver (vs brute force), CSR algebra, and
//! the out-of-core streaming format. Complements `properties.rs` (core
//! pipeline invariants).

use uspec::affinity::kernel::{build_affinity_kernel, SigmaRule, SimKernel};
use uspec::affinity::knr::KnrResult;
use uspec::graphpart::{partition, Graph, PartitionParams};
use uspec::linalg::{Csr, DMat, Mat};
use uspec::metrics::{
    ari, ca, hungarian, jaccard_index, nmi, pair_counts, pairwise_f, purity, rand_index,
    v_measure,
};
use uspec::prop_assert;
use uspec::usenc::Ensemble;
use uspec::util::prop::run_prop;
use uspec::util::rng::Rng;

fn random_graph(rng: &mut Rng, n: usize, avg_deg: usize) -> Graph {
    let mut edges = Vec::new();
    let m = n * avg_deg / 2;
    for _ in 0..m {
        let a = rng.usize(n) as u32;
        let b = rng.usize(n) as u32;
        if a != b {
            edges.push((a, b, 0.1 + rng.f64()));
        }
    }
    // ensure connectivity-ish: chain
    for v in 1..n {
        edges.push(((v - 1) as u32, v as u32, 0.05));
    }
    Graph::from_edges(n, &edges)
}

fn random_labels(rng: &mut Rng, n: usize, k: usize) -> Vec<u32> {
    (0..n).map(|_| rng.usize(k) as u32).collect()
}

#[test]
fn prop_partition_valid_and_balanced() {
    run_prop("graphpart-valid", 15, 11, |rng| {
        let n = 40 + rng.usize(160);
        let k = 2 + rng.usize(5);
        let g = random_graph(rng, n, 6);
        let part = partition(&g, k, &PartitionParams::default(), rng.next_u64())
            .map_err(|e| e.to_string())?;
        prop_assert!(part.len() == n, "len {} != {n}", part.len());
        prop_assert!(part.iter().all(|&p| (p as usize) < k), "label out of range");
        let cut = g.edge_cut(&part);
        let total: f64 = g.adjwgt.iter().sum::<f64>() / 2.0;
        prop_assert!(cut >= -1e-9 && cut <= total + 1e-9, "cut {cut} vs total {total}");
        // balance within the partitioner's contract (ε=0.10 + merge slack)
        let imb = g.imbalance(&part, k);
        prop_assert!(imb <= 1.8, "imbalance {imb}");
        Ok(())
    });
}

#[test]
fn prop_partition_beats_random_assignment() {
    run_prop("graphpart-cut-quality", 10, 23, |rng| {
        let n = 60 + rng.usize(100);
        let k = 2 + rng.usize(3);
        let g = random_graph(rng, n, 8);
        let part = partition(&g, k, &PartitionParams::default(), rng.next_u64())
            .map_err(|e| e.to_string())?;
        // average cut of random balanced labelings
        let mut rand_cut = 0.0;
        const TRIALS: usize = 5;
        for _ in 0..TRIALS {
            let labels: Vec<u32> = (0..n).map(|v| ((v + rng.usize(n)) % k) as u32).collect();
            rand_cut += g.edge_cut(&labels);
        }
        rand_cut /= TRIALS as f64;
        let cut = g.edge_cut(&part);
        prop_assert!(
            cut <= rand_cut * 1.05 + 1e-9,
            "partitioned cut {cut} worse than random {rand_cut}"
        );
        Ok(())
    });
}

#[test]
fn prop_hungarian_matches_bruteforce() {
    run_prop("hungarian-optimal", 60, 31, |rng| {
        let n = 2 + rng.usize(5); // up to 6 → 720 permutations
        let cost: Vec<i64> = (0..n * n).map(|_| rng.usize(100) as i64).collect();
        let assign = hungarian::solve(&cost, n);
        // validity: a permutation
        let mut seen = vec![false; n];
        for &j in &assign {
            prop_assert!(j < n && !seen[j], "not a permutation: {assign:?}");
            seen[j] = true;
        }
        let got: i64 = assign.iter().enumerate().map(|(i, &j)| cost[i * n + j]).sum();
        // brute force
        let mut perm: Vec<usize> = (0..n).collect();
        let mut best = i64::MAX;
        permute(&mut perm, 0, &mut |p| {
            let c: i64 = p.iter().enumerate().map(|(i, &j)| cost[i * n + j]).sum();
            best = best.min(c);
        });
        prop_assert!(got == best, "hungarian {got} != brute force {best} (n={n})");
        Ok(())
    });
}

fn permute(p: &mut Vec<usize>, i: usize, f: &mut impl FnMut(&[usize])) {
    if i == p.len() {
        f(p);
        return;
    }
    for j in i..p.len() {
        p.swap(i, j);
        permute(p, i + 1, f);
        p.swap(i, j);
    }
}

#[test]
fn prop_metric_identities() {
    run_prop("metric-identities", 50, 41, |rng| {
        let n = 30 + rng.usize(200);
        let ka = 2 + rng.usize(5);
        let kb = 2 + rng.usize(5);
        let a = random_labels(rng, n, ka);
        let b = random_labels(rng, n, kb);
        // pair counts partition C(n,2)
        let (pa, pb, pc, pd) = pair_counts(&a, &b);
        let total = (n * (n - 1) / 2) as f64;
        prop_assert!((pa + pb + pc + pd - total).abs() < 1e-6, "pair counts don't sum");
        // rand index symmetry, bounds
        let ri = rand_index(&a, &b);
        prop_assert!((ri - rand_index(&b, &a)).abs() < 1e-12, "rand not symmetric");
        prop_assert!((0.0..=1.0).contains(&ri), "rand {ri}");
        // jaccard ≤ rand ≤ 1 when d ≥ 0
        let ji = jaccard_index(&a, &b);
        prop_assert!(ji <= ri + 1e-12, "jaccard {ji} > rand {ri}");
        // F1 between precision and recall
        let (p, r, f1) = pairwise_f(&a, &b);
        prop_assert!(f1 <= p.max(r) + 1e-12 && f1 >= (p.min(r) - 1e-12).min(f1), "f1 order");
        // v-measure symmetric in its arguments
        prop_assert!(
            (v_measure(&a, &b) - v_measure(&b, &a)).abs() < 1e-12,
            "v-measure asymmetric"
        );
        // identity fixed points
        prop_assert!((rand_index(&a, &a) - 1.0).abs() < 1e-12, "rand(a,a)");
        prop_assert!((purity(&a, &a) - 1.0).abs() < 1e-12, "purity(a,a)");
        prop_assert!((ari(&a, &a) - 1.0).abs() < 1e-12, "ari(a,a)");
        Ok(())
    });
}

#[test]
fn prop_metrics_invariant_under_relabeling() {
    run_prop("metric-relabel", 40, 43, |rng| {
        let n = 50 + rng.usize(100);
        let k = 2 + rng.usize(4);
        let a = random_labels(rng, n, k);
        let b = random_labels(rng, n, k);
        // random permutation of a's label ids
        let mut perm: Vec<u32> = (0..k as u32).collect();
        rng.shuffle(&mut perm);
        let a2: Vec<u32> = a.iter().map(|&l| perm[l as usize]).collect();
        for (name, f) in [
            ("nmi", nmi as fn(&[u32], &[u32]) -> f64),
            ("ca", ca),
            ("ari", ari),
            ("rand", rand_index),
            ("jaccard", jaccard_index),
            ("purity", purity),
            ("v", v_measure),
        ] {
            let d = (f(&a, &b) - f(&a2, &b)).abs();
            prop_assert!(d < 1e-9, "{name} not relabel-invariant (diff {d})");
        }
        Ok(())
    });
}

#[test]
fn prop_kernels_bounded_and_finite() {
    run_prop("kernel-bounds", 25, 53, |rng| {
        let n = 20 + rng.usize(80);
        let p = 8 + rng.usize(24);
        let k = 1 + rng.usize(4.min(p));
        // synthetic KNR result: ascending distances per row, distinct cols
        let mut idx = Vec::with_capacity(n * k);
        let mut d2 = Vec::with_capacity(n * k);
        for _ in 0..n {
            let cols = rng.sample_indices(p, k);
            let mut ds: Vec<f32> = (0..k).map(|_| rng.f32() * 10.0).collect();
            ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for (c, dist) in cols.iter().zip(&ds) {
                idx.push(*c as u32);
                d2.push(*dist);
            }
        }
        let knr = KnrResult { idx, d2, k };
        for kern in [
            SimKernel::Gaussian(SigmaRule::MeanKnr),
            SimKernel::Gaussian(SigmaRule::MedianKnr),
            SimKernel::Gaussian(SigmaRule::Scaled(2.0)),
            SimKernel::Gaussian(SigmaRule::Fixed(0.7)),
            SimKernel::Laplacian(SigmaRule::MeanKnr),
            SimKernel::SelfTuning,
            SimKernel::InverseQuadratic { eps: 1.0 },
        ] {
            let aff = build_affinity_kernel(n, p, k, &knr, kern);
            prop_assert!(aff.b.nnz() == n * k, "{}: nnz", kern.name());
            prop_assert!(aff.sigma > 0.0, "{}: sigma", kern.name());
            let bounded = matches!(
                kern,
                SimKernel::Gaussian(_) | SimKernel::Laplacian(_) | SimKernel::SelfTuning
            );
            for &v in &aff.b.values {
                prop_assert!(v.is_finite() && v > 0.0, "{}: value {v}", kern.name());
                if bounded {
                    prop_assert!(v <= 1.0 + 1e-12, "{}: value {v} > 1", kern.name());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_csr_tdb_matches_dense() {
    // E_R = Bᵀ diag(w) B — the transfer cut's fused product vs the naive
    // dense evaluation.
    run_prop("csr-tdb", 25, 61, |rng| {
        let n = 10 + rng.usize(40);
        let p = 4 + rng.usize(12);
        let k = 1 + rng.usize(3.min(p));
        let mut rows = Vec::with_capacity(n);
        for _ in 0..n {
            let cols = rng.sample_indices(p, k);
            let mut entries: Vec<(u32, f64)> =
                cols.into_iter().map(|c| (c as u32, 0.1 + rng.f64())).collect();
            entries.sort_by_key(|&(c, _)| c);
            rows.push(entries);
        }
        let b = Csr::from_rows(n, p, &rows);
        let w: Vec<f64> = (0..n).map(|_| 0.1 + rng.f64()).collect();
        let fused = b.tdb(&w);
        // dense reference
        let bd = b.to_dense();
        let mut want = DMat::zeros(p, p);
        for i in 0..p {
            for j in 0..p {
                let mut s = 0.0;
                for r in 0..n {
                    s += bd.at(r, i) * w[r] * bd.at(r, j);
                }
                want.set(i, j, s);
            }
        }
        for i in 0..p {
            for j in 0..p {
                prop_assert!(
                    (fused.at(i, j) - want.at(i, j)).abs() < 1e-9,
                    "tdb mismatch at ({i},{j})"
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_consensus_functions_relabel_invariant() {
    use uspec::ensemble_baselines::strehl::{hbgf, mcla};
    run_prop("consensus-relabel", 12, 71, |rng| {
        let n = 40 + rng.usize(60);
        let m = 3 + rng.usize(3);
        let k = 2 + rng.usize(2);
        // balanced ground truth (round-robin, shuffled) — keeps the optimal
        // consensus inside the partitioner's balance envelope
        let mut truth: Vec<u32> = (0..n).map(|i| (i % k) as u32).collect();
        rng.shuffle(&mut truth);
        let mut ens_a = Ensemble::default();
        let mut ens_b = Ensemble::default();
        for _ in 0..m {
            let noisy: Vec<u32> = truth
                .iter()
                .map(|&l| if rng.f64() < 0.15 { rng.usize(k) as u32 } else { l })
                .collect();
            // permuted copy for ens_b
            let kk = noisy.iter().copied().max().unwrap() as usize + 1;
            let mut perm: Vec<u32> = (0..kk as u32).collect();
            rng.shuffle(&mut perm);
            let permuted: Vec<u32> = noisy.iter().map(|&l| perm[l as usize]).collect();
            ens_a.push(noisy);
            ens_b.push(permuted);
        }
        let seed = rng.next_u64();
        for (name, f) in [
            ("mcla", mcla as fn(&Ensemble, usize, u64) -> uspec::Result<Vec<u32>>),
            ("hbgf", hbgf),
        ] {
            let la = f(&ens_a, k, seed).map_err(|e| e.to_string())?;
            let lb = f(&ens_b, k, seed).map_err(|e| e.to_string())?;
            // Relabeling permutes incidence columns, which shifts the
            // multilevel partitioner's tie-breaking — so demand that BOTH
            // runs recover the planted consensus, not bit equality.
            let qa = nmi(&la, &truth);
            let qb = nmi(&lb, &truth);
            prop_assert!(
                qa > 0.6 && qb > 0.6,
                "{name}: planted consensus lost under relabeling (nmi {qa:.3} / {qb:.3})"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_uspec_deterministic_per_seed() {
    run_prop("uspec-deterministic", 6, 83, |rng| {
        let n = 300 + rng.usize(300);
        let ds = uspec::data::synthetic::two_moons(n, 0.06, rng.next_u64());
        let params = uspec::uspec::UspecParams {
            k: 2,
            p: 60,
            ..Default::default()
        };
        let seed = rng.next_u64();
        let a = uspec::uspec::uspec(&ds.x, &params, seed).map_err(|e| e.to_string())?;
        let b = uspec::uspec::uspec(&ds.x, &params, seed).map_err(|e| e.to_string())?;
        prop_assert!(a.labels == b.labels, "same seed produced different labels");
        Ok(())
    });
}

#[test]
fn prop_bin_dataset_roundtrip_random_shapes() {
    use uspec::streaming::BinDataset;
    run_prop("bin-roundtrip", 15, 97, |rng| {
        let n = 1 + rng.usize(400);
        let d = 1 + rng.usize(12);
        let mut x = Mat::zeros(n, d);
        for v in x.data.iter_mut() {
            *v = rng.f32() * 100.0 - 50.0;
        }
        let dir = std::env::temp_dir().join("uspec_prop_bin");
        std::fs::create_dir_all(&dir).map_err(|e| e.to_string())?;
        let path = dir.join(format!("case_{}.bin", rng.next_u64()));
        let bin = BinDataset::write_mat(&path, &x).map_err(|e| e.to_string())?;
        prop_assert!(bin.n() == n && bin.d() == d, "shape mismatch");
        let chunk = 1 + rng.usize(n);
        let mut collected = Vec::with_capacity(n * d);
        bin.for_each_chunk(chunk, |_, m| {
            collected.extend_from_slice(&m.data);
            Ok(())
        })
        .map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        prop_assert!(collected == x.data, "chunked read differs from written data");
        Ok(())
    });
}
