//! Bipartite graph partitioning via the transfer cut (paper §3.1.3,
//! Li et al. CVPR'12).
//!
//! Given the sparse cross-affinity `B` (N×p) of the bipartite graph
//! G = {X, R, B}, the generalized eigenproblem `L u = γ D u` on the
//! (N+p)-node graph is reduced to `L_R v = λ D_R v` on the p-node graph
//! G_R with `E_R = Bᵀ D_X⁻¹ B`, using the relations
//! γ(2−γ) = λ and u = [h; v], h = T v / (1−γ), T = D_X⁻¹ B.
//!
//! The reduced p×p problem is solved by Chebyshev-filtered subspace
//! iteration on the normalized affinity (default; LOBPCG and a dense
//! tridiagonal-QL solver are selectable via [`EigSolver`], and every fast
//! path falls back to dense); the lift back to the N side costs O(NKk).
//!
//! All block products run on the packed f64 gemm kernels of
//! [`crate::linalg::DMat`]; [`reduced_eig_in`] threads an [`EigScratch`]
//! through the Chebyshev recurrence and Rayleigh–Ritz steps so repeated
//! solves (ensemble members, bench sweeps) stop allocating per iteration.
//! `USPEC_EIG_TRACE=1` prints solver routing and per-stage wall timings.

use crate::linalg::eigen::{sym_eig, sym_eig_generalized_smallest};
use crate::linalg::lobpcg::lobpcg_smallest_in;
use crate::linalg::{orthonormalize_cols, Csr, DGemmScratch, DMat, EigScratch, Mat};
use crate::util::par;
use crate::{ensure_arg, Error, Result};

pub use crate::linalg::eigen::{fast_eig_crossover, FAST_EIG_K_FACTOR, FAST_EIG_MARGIN};

/// Output of the transfer cut: the spectral embedding of the N objects.
#[derive(Debug, Clone)]
pub struct TransferCut {
    /// N×k object embedding (the h_i components of the first k
    /// eigenvectors of the full bipartite problem).
    pub embedding: Mat,
    /// γ eigenvalues of the full problem (ascending, len k).
    pub gammas: Vec<f64>,
    /// λ eigenvalues of the reduced problem (ascending, len k).
    pub lambdas: Vec<f64>,
}

/// Eigen-solver strategy for the reduced p×p problem.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EigSolver {
    /// Always dense (tred2 + tqli). Exact, O(p³).
    Dense,
    /// Subspace iteration on the normalized affinity, dense fallback.
    /// Fast and robust when k ≪ p (the default).
    Auto,
    /// LOBPCG on the normalized Laplacian (diagonal-preconditioned), dense
    /// fallback. Exposed for the `ablation_eig` bench; `Auto` is usually
    /// faster on the degenerate λ≈0 cluster eigenspaces of well-separated
    /// data.
    Lobpcg,
}

/// Solve the reduced generalized problem `L_R v = λ D_R v` for the
/// smallest `k` eigenpairs. Returns (λ, V p×k).
///
/// Fast path (`EigSolver::Auto`): the smallest-k pairs of
/// `I − D^{-1/2} E D^{-1/2}` are the LARGEST-k of the normalized affinity
/// Ŝ = D^{-1/2} E D^{-1/2} (PSD, spectrum in [0, 1]) — computed by blocked
/// subspace iteration with oversampling, which is robust to the k-fold
/// degenerate λ=0 cluster that defeats gradient methods (k well-separated
/// clusters ⇒ k disconnected graph components). O(p²·k·iters) ≪ O(p³).
pub fn reduced_eig(e_r: &DMat, k: usize, solver: EigSolver, seed: u64) -> Result<(Vec<f64>, DMat)> {
    let mut scr = EigScratch::default();
    reduced_eig_in(e_r, k, solver, seed, &mut scr)
}

/// [`reduced_eig`] running the iterative fast paths through a caller-owned
/// [`EigScratch`], so repeated solves (ensemble members, bench sweeps)
/// reuse every block buffer instead of reallocating per call.
pub fn reduced_eig_in(
    e_r: &DMat,
    k: usize,
    solver: EigSolver,
    seed: u64,
    scr: &mut EigScratch,
) -> Result<(Vec<f64>, DMat)> {
    let p = e_r.rows;
    ensure_arg!(k >= 1 && k <= p, "reduced_eig: k={k} out of range for p={p}");
    // degrees of G_R
    let d_r: Vec<f64> = (0..p).map(|i| e_r.row(i).iter().sum()).collect();
    ensure_arg!(
        d_r.iter().all(|&x| x > 0.0),
        "reduced_eig: isolated representative (zero degree)"
    );
    let use_fast =
        matches!(solver, EigSolver::Auto | EigSolver::Lobpcg) && fast_eig_crossover(p, k);
    if crate::util::eig_trace() {
        let chosen = if !use_fast {
            "dense"
        } else if matches!(solver, EigSolver::Lobpcg) {
            "lobpcg"
        } else {
            "chebyshev-subspace"
        };
        eprintln!(
            "[eig] reduced_eig p={p} k={k} solver={solver:?} -> {chosen} \
             (crossover p > {})",
            FAST_EIG_K_FACTOR * k + FAST_EIG_MARGIN
        );
    }
    if use_fast {
        let dis: Vec<f64> = d_r.iter().map(|&x| 1.0 / x.sqrt()).collect();
        if matches!(solver, EigSolver::Lobpcg) {
            // L̂ = I − D^{-1/2} E D^{-1/2}, built fused (no Ŝ temporary) and
            // row-parallel; smallest-k by LOBPCG with Jacobi preconditioning.
            let mut lhat = DMat::zeros(p, p);
            par::par_for_chunks(&mut lhat.data, p, |start, chunk| {
                let i = start / p;
                let di = dis[i];
                let row = e_r.row(i);
                for (j, (o, (&ev, &dj))) in
                    chunk.iter_mut().zip(row.iter().zip(&dis)).enumerate()
                {
                    let shat = ev * di * dj;
                    *o = if i == j { 1.0 - shat } else { -shat };
                }
            });
            let precond: Vec<f64> =
                (0..p).map(|i| 1.0 / lhat.at(i, i).max(1e-12)).collect();
            if let Ok((vals, w)) =
                lobpcg_smallest_in(&lhat, k, Some(&precond), 1e-7, 300, seed ^ 0x10B, scr)
            {
                let vals: Vec<f64> = vals.iter().map(|&l| l.max(0.0)).collect();
                return Ok((vals, scale_rows(&w, &dis)));
            }
        } else {
            // Ŝ = D^{-1/2} E D^{-1/2}, row-parallel.
            let mut s = DMat::zeros(p, p);
            par::par_for_chunks(&mut s.data, p, |start, chunk| {
                let i = start / p;
                let di = dis[i];
                for (o, (&ev, &dj)) in chunk.iter_mut().zip(e_r.row(i).iter().zip(&dis)) {
                    *o = ev * di * dj;
                }
            });
            if let Some((top_vals, w)) = subspace_iteration_largest(&s, k, 1e-6, 150, seed, scr)
            {
                // λ(L̂) = 1 − λ(Ŝ); generalized eigvec v = D^{-1/2} w.
                let vals: Vec<f64> = top_vals.iter().map(|&l| (1.0 - l).max(0.0)).collect();
                return Ok((vals, scale_rows(&w, &dis)));
            }
        }
    }
    // Dense path: L_R = D_R − E_R, built fused and row-parallel.
    let mut l_r = DMat::zeros(p, p);
    par::par_for_chunks(&mut l_r.data, p, |start, chunk| {
        let i = start / p;
        let row = e_r.row(i);
        for (j, (o, &ev)) in chunk.iter_mut().zip(row).enumerate() {
            *o = if i == j { d_r[i] - ev } else { -ev };
        }
    });
    sym_eig_generalized_smallest(&l_r, &d_r, k)
}

/// Row-scale `w` by `dis` (v = D^{-1/2}·w), row-parallel. Pure per-element
/// map, so the result is independent of the thread count.
fn scale_rows(w: &DMat, dis: &[f64]) -> DMat {
    let k = w.cols;
    let mut v = DMat::zeros(w.rows, k);
    if k == 0 {
        return v;
    }
    par::par_for_chunks(&mut v.data, k, |start, chunk| {
        let r = start / k;
        let di = dis[r];
        for (o, &wv) in chunk.iter_mut().zip(w.row(r)) {
            *o = wv * di;
        }
    });
    v
}

/// Chebyshev-filtered blocked subspace iteration for the largest-`k`
/// eigenpairs of a symmetric PSD matrix with spectrum in [0, 1].
///
/// Plain power/subspace iteration converges like (λ_{k+1}/λ_k)^t, which is
/// hopeless when the wanted eigenvalues cluster at 1 (k well-separated
/// clusters ⇒ k eigenvalues ≈ 1; measured: 150 iterations and still 6e-5
/// eigenvalue drift at p=1000). Instead, each outer step applies a
/// degree-`DEG` Chebyshev polynomial that suppresses the unwanted interval
/// [0, a] — T_m grows exponentially outside [-1, 1], so one filtered step
/// is worth ~T_DEG(2λ/a − 1) plain steps. The filter bound `a` is adapted
/// from the (k+1)-th Ritz value each outer iteration. Oversamples the
/// block to ride out the degenerate leading cluster; returns None if it
/// fails to converge (caller falls back to the dense solver).
fn subspace_iteration_largest(
    s: &DMat,
    k: usize,
    tol: f64,
    max_iter: usize,
    seed: u64,
    scr: &mut EigScratch,
) -> Option<(Vec<f64>, DMat)> {
    const DEG: usize = 8; // filter degree (matmuls per outer step)
    let p = s.rows;
    let q = (k + 8).min(p); // oversampled block
    let mut rng = crate::util::rng::Rng::new(seed ^ 0x5B5);
    scr.basis.reshape(p, q);
    for v in scr.basis.data.iter_mut() {
        *v = rng.normal();
    }
    if !orthonormalize_cols(&mut scr.basis, &mut scr.ortho) {
        return None;
    }
    // Warm-up: a few plain iterations so the first Ritz values (and hence
    // the first filter bound) are sane.
    for _ in 0..4 {
        s.matmul_into(&scr.basis, &mut scr.gemm, &mut scr.prod);
        std::mem::swap(&mut scr.basis, &mut scr.prod);
        if !orthonormalize_cols(&mut scr.basis, &mut scr.ortho) {
            return None;
        }
    }
    let (mut hvals, mut prev_vals) = ritz_step(s, k, scr)?;
    let mut best_delta = f64::INFINITY;
    let mut best_vals: Vec<f64> = Vec::new();
    let mut have_best = false;
    let outer_max = (max_iter / DEG).max(4);
    for it in 0..outer_max {
        // Filter bound: the (k+1)-th Ritz value (descending), i.e. the top
        // of the unwanted spectrum as currently estimated. Clamp away from
        // 0 and from the smallest wanted value.
        let lam_kp1 = if q > k { hvals[q - 1 - k] } else { 0.5 };
        let lam_k = prev_vals[k - 1];
        let a = lam_kp1.clamp(1e-4, (lam_k * 0.999).max(1e-4));
        let inv = 2.0 / a;
        // Z_j = T_j(L)·X with L = (2S − aI)/a; three-term recurrence
        // rotating through cheb0/cheb1/cheb2 — no allocation per term.
        scr.cheb0.copy_from(&scr.basis);
        cheb_apply(s, &scr.basis, inv, &mut scr.gemm, &mut scr.cheb1);
        for _ in 2..=DEG {
            cheb_apply(s, &scr.cheb1, inv, &mut scr.gemm, &mut scr.cheb2);
            for (o, v) in scr.cheb2.data.iter_mut().zip(&scr.cheb0.data) {
                *o = 2.0 * *o - *v;
            }
            std::mem::swap(&mut scr.cheb0, &mut scr.cheb1);
            std::mem::swap(&mut scr.cheb1, &mut scr.cheb2);
        }
        std::mem::swap(&mut scr.basis, &mut scr.cheb1);
        if !orthonormalize_cols(&mut scr.basis, &mut scr.ortho) {
            return None;
        }
        let (nh, nvals) = ritz_step(s, k, scr)?;
        hvals = nh;
        let delta: f64 =
            nvals.iter().zip(&prev_vals).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        prev_vals = nvals;
        if crate::util::eig_trace() {
            eprintln!("[eig] outer {it} (deg {DEG}, bound {a:.3e}) delta {delta:.3e}");
        }
        if delta < tol {
            if crate::util::eig_debug() {
                eprintln!(
                    "[eig] chebyshev subspace converged at outer {it} ({} matmuls, delta {delta:.2e})",
                    4 + (it + 1) * (DEG + 1)
                );
            }
            return Some((prev_vals, scr.ritz.clone()));
        }
        if delta < best_delta {
            best_delta = delta;
            best_vals.clone_from(&prev_vals);
            scr.keep.copy_from(&scr.ritz);
            have_best = true;
        }
    }
    // Not fully converged: a near-converged Ritz subspace is still a usable
    // spectral embedding; only give up when clearly unconverged.
    if have_best && best_delta < 1e-4 {
        if crate::util::eig_debug() {
            eprintln!("[eig] chebyshev subspace best-effort (delta {best_delta:.2e})");
        }
        Some((best_vals, scr.keep.clone()))
    } else {
        if crate::util::eig_debug() {
            eprintln!("[eig] chebyshev subspace failed; dense fallback");
        }
        None
    }
}

/// One Rayleigh–Ritz step on `scr.basis` (p×q): projects S onto the basis,
/// solves the dense q×q problem, and writes the rotated top-k Ritz block
/// into `scr.ritz`. Returns (all Ritz values ascending, top-k descending).
fn ritz_step(s: &DMat, k: usize, scr: &mut EigScratch) -> Option<(Vec<f64>, Vec<f64>)> {
    s.matmul_into(&scr.basis, &mut scr.gemm, &mut scr.prod);
    scr.basis.matmul_tn_into(&scr.prod, &mut scr.gemm, &mut scr.small);
    let q = scr.small.rows;
    for i in 0..q {
        for j in 0..i {
            let v = 0.5 * (scr.small.at(i, j) + scr.small.at(j, i));
            scr.small.set(i, j, v);
            scr.small.set(j, i, v);
        }
    }
    let (hvals, hvecs) = sym_eig(&scr.small).ok()?;
    scr.rot.reshape(q, k);
    for r in 0..q {
        let hr = hvecs.row(r);
        for (c, o) in scr.rot.row_mut(r).iter_mut().enumerate() {
            *o = hr[q - 1 - c];
        }
    }
    scr.basis.matmul_into(&scr.rot, &mut scr.gemm, &mut scr.ritz);
    let vals: Vec<f64> = (0..k).map(|c| hvals[q - 1 - c]).collect();
    Some((hvals, vals))
}

/// `out ← L·y` with L = (2S − aI)/a, i.e. `(2/a)·S·y − y`, through the
/// packed gemm. The elementwise epilogue keeps the exact old operation
/// order (one multiply, one subtract per element).
fn cheb_apply(s: &DMat, y: &DMat, inv: f64, gemm: &mut DGemmScratch, out: &mut DMat) {
    s.matmul_into(y, gemm, out);
    for (o, v) in out.data.iter_mut().zip(&y.data) {
        *o = *o * inv - *v;
    }
}

/// Full transfer cut over a sparse cross-affinity `B`.
pub fn transfer_cut(b: &Csr, k: usize, solver: EigSolver, seed: u64) -> Result<TransferCut> {
    let n = b.rows;
    let p = b.cols;
    ensure_arg!(k >= 1, "transfer_cut: k must be >= 1");
    ensure_arg!(k <= p, "transfer_cut: k={k} > p={p}");
    let dx = b.row_sums();
    for (i, &s) in dx.iter().enumerate() {
        if s <= 0.0 {
            return Err(Error::Numerical(format!("transfer_cut: object {i} has zero affinity")));
        }
    }
    let w: Vec<f64> = dx.iter().map(|&s| 1.0 / s).collect();
    // Representatives no object selected have zero degree in G_R; drop
    // them (exact: they carry no affinity mass) and remap columns.
    let col = b.col_sums();
    let owned_b;
    let b = if col.iter().any(|&s| s <= 0.0) {
        let keep: Vec<usize> = (0..p).filter(|&j| col[j] > 0.0).collect();
        ensure_arg!(k <= keep.len(), "transfer_cut: k={k} > connected reps {}", keep.len());
        let mut remap = vec![u32::MAX; p];
        for (new, &old) in keep.iter().enumerate() {
            remap[old] = new as u32;
        }
        let indices: Vec<u32> = b.indices.iter().map(|&c| remap[c as usize]).collect();
        owned_b = Csr {
            rows: n,
            cols: keep.len(),
            indptr: b.indptr.clone(),
            indices,
            values: b.values.clone(),
        };
        &owned_b
    } else {
        b
    };
    // E_R = Bᵀ D_X⁻¹ B — O(N K²)
    let t0 = std::time::Instant::now();
    let e_r = b.tdb(&w);
    let t_build = t0.elapsed();
    let t1 = std::time::Instant::now();
    let (lambdas, v) = reduced_eig(&e_r, k, solver, seed)?;
    let t_solve = t1.elapsed();
    let t2 = std::time::Instant::now();
    // γ(2-γ) = λ ⇒ γ = 1 − sqrt(1−λ); clamp λ into [0, 1).
    let gammas: Vec<f64> = lambdas
        .iter()
        .map(|&l| {
            let l = l.clamp(0.0, 1.0 - 1e-12);
            1.0 - (1.0 - l).sqrt()
        })
        .collect();
    // h_i = T v_i / (1−γ_i), T = D_X⁻¹ B — sparse matvec, O(NKk).
    let mut emb = Mat::zeros(n, k);
    let tv = b.matmul_dense(&v); // N×k, rows scaled below
    par::par_for_chunks(&mut emb.data, k, |start, chunk| {
        let i = start / k;
        let scale = w[i];
        for (c, o) in chunk.iter_mut().enumerate() {
            let denom = (1.0 - gammas[c]).max(1e-9);
            *o = (tv.at(i, c) * scale / denom) as f32;
        }
    });
    if crate::util::eig_trace() {
        // Per-stage wall timings so the dense/iterative routing can be
        // calibrated from real runs, not just solver names.
        eprintln!(
            "[eig] transfer_cut n={n} p={} k={k}: E_R build {:.2}ms | reduced solve {:.2}ms | lift {:.2}ms",
            b.cols,
            t_build.as_secs_f64() * 1e3,
            t_solve.as_secs_f64() * 1e3,
            t2.elapsed().as_secs_f64() * 1e3,
        );
    }
    Ok(TransferCut { embedding: emb, gammas, lambdas })
}

/// Row-normalize a spectral embedding to unit L2 norm (NJW-style) — the
/// discretization preprocessing Huang's reference implementation applies
/// before k-means; removes the 1/(1−γ) column-scale imbalance.
pub fn row_normalize(emb: &mut Mat) {
    let k = emb.cols;
    par::par_for_chunks(&mut emb.data, k, |_start, chunk| {
        let norm: f32 = chunk.iter().map(|v| v * v).sum::<f32>().sqrt();
        if norm > 1e-12 {
            for v in chunk.iter_mut() {
                *v /= norm;
            }
        }
    });
}

/// [`row_normalize`], additionally returning the norm each row was divided
/// by (1.0 for near-zero rows that were left untouched). Feeding the norms
/// back through [`row_scale`] restores the original matrix up to float
/// rounding, which lets callers reuse one buffer for the normalized view
/// instead of cloning an N×k matrix.
pub fn row_normalize_norms(emb: &mut Mat) -> Vec<f32> {
    let k = emb.cols;
    let data = &emb.data;
    let norms: Vec<f32> = par::par_map(emb.rows, |i| {
        let norm: f32 = data[i * k..(i + 1) * k].iter().map(|v| v * v).sum::<f32>().sqrt();
        if norm > 1e-12 {
            norm
        } else {
            1.0
        }
    });
    par::par_for_chunks(&mut emb.data, k, |start, chunk| {
        let norm = norms[start / k];
        for v in chunk.iter_mut() {
            *v /= norm;
        }
    });
    norms
}

/// Multiply each row by its scale (inverse of [`row_normalize_norms`]).
pub fn row_scale(emb: &mut Mat, scales: &[f32]) {
    debug_assert_eq!(scales.len(), emb.rows);
    let k = emb.cols;
    par::par_for_chunks(&mut emb.data, k, |start, chunk| {
        let s = scales[start / k];
        for v in chunk.iter_mut() {
            *v *= s;
        }
    });
}

/// Oracle (test-only scale): solve the FULL (N+p)-node generalized problem
/// `L u = γ D u` densely. Used by the equivalence property tests.
pub fn full_bipartite_eig(b: &Csr, k: usize) -> Result<(Vec<f64>, DMat)> {
    let n = b.rows;
    let p = b.cols;
    let m = n + p;
    let bd = b.to_dense();
    // E = [[0, B],[Bᵀ, 0]]
    let mut e = DMat::zeros(m, m);
    for i in 0..n {
        for j in 0..p {
            e.set(i, n + j, bd.at(i, j));
            e.set(n + j, i, bd.at(i, j));
        }
    }
    let d: Vec<f64> = (0..m).map(|i| e.row(i).iter().sum()).collect();
    ensure_arg!(d.iter().all(|&x| x > 0.0), "full_bipartite_eig: isolated node");
    let mut l = DMat::zeros(m, m);
    for i in 0..m {
        for j in 0..m {
            l.set(i, j, if i == j { d[i] - e.at(i, j) } else { -e.at(i, j) });
        }
    }
    sym_eig_generalized_smallest(&l, &d, k)
}

/// Oracle spectral embedding helper for tiny dense graphs (used by the SC
/// baseline and tests): smallest-k generalized eigenvectors of an affinity.
pub fn ncut_embedding(aff: &DMat, k: usize) -> Result<DMat> {
    let n = aff.rows;
    let d: Vec<f64> = (0..n).map(|i| aff.row(i).iter().sum()).collect();
    ensure_arg!(d.iter().all(|&x| x > 0.0), "ncut: isolated node");
    let mut l = DMat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            l.set(i, j, if i == j { d[i] - aff.at(i, j) } else { -aff.at(i, j) });
        }
    }
    let (_vals, v) = sym_eig_generalized_smallest(&l, &d, k)?;
    Ok(v)
}

/// Eigen-decomposition of a normalized affinity (largest-k), used by
/// Nyström. Returns (vals descending, vectors columns).
pub fn top_eig(a: &DMat, k: usize) -> Result<(Vec<f64>, DMat)> {
    let (vals, vecs) = sym_eig(a)?;
    let n = a.rows;
    let k = k.min(n);
    let mut out_vals = Vec::with_capacity(k);
    let mut out = DMat::zeros(n, k);
    for c in 0..k {
        let src = n - 1 - c;
        out_vals.push(vals[src]);
        for r in 0..n {
            out.set(r, c, vecs.at(r, src));
        }
    }
    Ok((out_vals, out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::{build_affinity, knr::KnrIndex, select, NativeBackend, SelectStrategy};
    use crate::data::synthetic::two_moons;

    fn moon_affinity(n: usize, p: usize, k_nn: usize, seed: u64) -> (crate::data::Dataset, Csr) {
        let ds = two_moons(n, 0.05, seed);
        let reps =
            select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 8 }, p, 15, seed).unwrap();
        let index = KnrIndex::build(&reps, 5 * k_nn, 15, &NativeBackend).unwrap();
        let res = index.approx_knr(&ds.x, k_nn, &NativeBackend);
        let aff = build_affinity(ds.n(), p, k_nn, &res);
        (ds, aff.b)
    }

    #[test]
    fn gamma_lambda_relation() {
        let (_, b) = moon_affinity(300, 30, 4, 3);
        let tc = transfer_cut(&b, 4, EigSolver::Dense, 1).unwrap();
        for (g, l) in tc.gammas.iter().zip(&tc.lambdas) {
            assert!((g * (2.0 - g) - l.clamp(0.0, 1.0)).abs() < 1e-9);
        }
        // first eigenvalue ≈ 0 (connected graph) and ascending
        assert!(tc.lambdas[0].abs() < 1e-6);
        for w in tc.lambdas.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
    }

    #[test]
    fn matches_full_problem_eigenvalues() {
        // The reduced λ must satisfy γ(2−γ)=λ with the γ of the full
        // (N+p)-node problem — the transfer-cut theorem (Eq. 10).
        let (_, b) = moon_affinity(120, 16, 3, 5);
        let tc = transfer_cut(&b, 3, EigSolver::Dense, 1).unwrap();
        let (full_gammas, _) = full_bipartite_eig(&b, 3).unwrap();
        for (ours, full) in tc.gammas.iter().zip(&full_gammas) {
            assert!((ours - full).abs() < 1e-6, "{ours} vs {full}");
        }
    }

    #[test]
    fn embedding_separates_moons() {
        let (ds, b) = moon_affinity(600, 60, 5, 7);
        let tc = transfer_cut(&b, 2, EigSolver::Auto, 3).unwrap();
        let km = crate::kmeans::kmeans(
            &tc.embedding,
            &crate::kmeans::KmeansParams { k: 2, ..Default::default() },
            11,
        )
        .unwrap();
        let nmi = crate::metrics::nmi(&km.labels, &ds.y);
        assert!(nmi > 0.8, "nmi={nmi}");
    }

    #[test]
    fn lobpcg_and_dense_agree() {
        let (_, b) = moon_affinity(500, 80, 5, 9);
        let tc_d = transfer_cut(&b, 3, EigSolver::Dense, 1).unwrap();
        let tc_a = transfer_cut(&b, 3, EigSolver::Auto, 1).unwrap();
        for (a, d) in tc_a.lambdas.iter().zip(&tc_d.lambdas) {
            assert!((a - d).abs() < 1e-5, "{a} vs {d}");
        }
    }

    #[test]
    fn lobpcg_solver_agrees_with_dense() {
        let (_, b) = moon_affinity(500, 90, 5, 13);
        let tc_d = transfer_cut(&b, 3, EigSolver::Dense, 1).unwrap();
        let tc_l = transfer_cut(&b, 3, EigSolver::Lobpcg, 1).unwrap();
        for (l, d) in tc_l.lambdas.iter().zip(&tc_d.lambdas) {
            assert!((l - d).abs() < 1e-4, "lobpcg {l} vs dense {d}");
        }
    }

    #[test]
    fn fast_eig_crossover_boundary() {
        use crate::linalg::lobpcg::lobpcg_smallest;
        // exactly at the threshold: dense; one past it: fast
        for k in [1usize, 3, 10, 50] {
            let boundary = FAST_EIG_K_FACTOR * k + FAST_EIG_MARGIN;
            assert!(!fast_eig_crossover(boundary, k), "p == 4k+64 must stay dense (k={k})");
            assert!(fast_eig_crossover(boundary + 1, k), "p == 4k+65 must go fast (k={k})");
            // lobpcg's small-problem guard is the SAME crossover (it used
            // to hardcode n <= 4k+32): at the boundary it must reject...
            assert!(
                lobpcg_smallest(&DMat::eye(boundary), k, None, 1e-8, 10, 1).is_err(),
                "lobpcg must reject n == 4k+64 (k={k})"
            );
        }
        // ...and one past it, accept (identity: zero residual at once).
        let boundary = FAST_EIG_K_FACTOR + FAST_EIG_MARGIN;
        assert!(lobpcg_smallest(&DMat::eye(boundary + 1), 1, None, 1e-8, 50, 1).is_ok());
    }

    #[test]
    fn rejects_bad_k() {
        let (_, b) = moon_affinity(100, 10, 3, 11);
        assert!(transfer_cut(&b, 0, EigSolver::Dense, 1).is_err());
        assert!(transfer_cut(&b, 11, EigSolver::Dense, 1).is_err());
    }
}
