//! Pluggable similarity kernels for the sparse cross-affinity `B`.
//!
//! The paper fixes the Gaussian kernel with σ = mean object↔KNR distance
//! (Eq. 6). This module generalizes that choice — bandwidth rules
//! ([`SigmaRule`]) and kernel families ([`SimKernel`]) — so the
//! `ablation_kernels` bench can quantify how much of U-SPEC's quality is
//! the pipeline versus the specific kernel. [`super::build_affinity`]
//! remains the paper-exact default
//! (`SimKernel::Gaussian(SigmaRule::MeanKnr)`).

use super::knr::KnrResult;
use super::Affinity;
use crate::linalg::Csr;
use crate::util::par;

/// How the Gaussian/Laplacian bandwidth σ is derived from the KNR
/// distances.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SigmaRule {
    /// σ = mean of all object↔KNR distances (the paper's rule).
    MeanKnr,
    /// σ = median of all object↔KNR distances (robust to outlier rows).
    MedianKnr,
    /// σ = `factor` × the MeanKnr value.
    Scaled(f64),
    /// Fixed user-supplied σ (must be > 0).
    Fixed(f64),
}

impl SigmaRule {
    /// Resolve the rule to a concrete σ given the flat squared-distance
    /// array of the KNR result.
    pub fn resolve(&self, d2: &[f32]) -> f64 {
        let mean = || -> f64 {
            if d2.is_empty() {
                return 1e-12;
            }
            let sum: f64 = d2.iter().map(|&v| (v.max(0.0) as f64).sqrt()).sum();
            (sum / d2.len() as f64).max(1e-12)
        };
        match *self {
            SigmaRule::MeanKnr => mean(),
            SigmaRule::MedianKnr => {
                if d2.is_empty() {
                    return 1e-12;
                }
                let mut d: Vec<f64> = d2.iter().map(|&v| (v.max(0.0) as f64).sqrt()).collect();
                let mid = d.len() / 2;
                d.select_nth_unstable_by(mid, |a, b| a.partial_cmp(b).unwrap());
                d[mid].max(1e-12)
            }
            SigmaRule::Scaled(f) => (f * mean()).max(1e-12),
            SigmaRule::Fixed(s) => s.max(1e-12),
        }
    }
}

/// Similarity kernel applied to the K-nearest-representative distances.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SimKernel {
    /// `exp(−d² / 2σ²)` — the paper's kernel (Eq. 6).
    Gaussian(SigmaRule),
    /// `exp(−d / σ)` — heavier tail, less bandwidth-sensitive.
    Laplacian(SigmaRule),
    /// Self-tuning local scaling (Zelnik-Manor & Perona adapted to the
    /// bipartite setting): `exp(−d²ᵢⱼ / (σᵢ·σⱼ))` with σᵢ = distance from
    /// object i to its K-th nearest representative and σⱼ = mean distance
    /// of representative j to the objects that selected it.
    SelfTuning,
    /// `1 / (d² + ε·σ̄²)` — inverse quadratic, σ̄ from MeanKnr.
    InverseQuadratic {
        /// Regularizer ε as a fraction of σ̄² (e.g. 1.0).
        eps: f64,
    },
}

impl SimKernel {
    pub fn name(&self) -> &'static str {
        match self {
            SimKernel::Gaussian(_) => "gaussian",
            SimKernel::Laplacian(_) => "laplacian",
            SimKernel::SelfTuning => "self-tuning",
            SimKernel::InverseQuadratic { .. } => "inv-quadratic",
        }
    }
}

/// Build the sparse N×p cross-affinity from a KNR result under an
/// arbitrary kernel. Row layout matches [`super::build_affinity`]: exactly
/// `k` entries per row, columns from `knr.idx`.
pub fn build_affinity_kernel(
    n: usize,
    p: usize,
    k: usize,
    knr: &KnrResult,
    kernel: SimKernel,
) -> Affinity {
    debug_assert_eq!(knr.idx.len(), n * k);
    let mut vals = vec![0.0f64; n * k];
    let sigma_used: f64;
    match kernel {
        SimKernel::Gaussian(rule) => {
            let sigma = rule.resolve(&knr.d2);
            sigma_used = sigma;
            let denom = 2.0 * sigma * sigma;
            par::par_for_chunks(&mut vals, k, |start, chunk| {
                let i = start / k;
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = (-(knr.d2[i * k + j].max(0.0) as f64) / denom).exp();
                }
            });
        }
        SimKernel::Laplacian(rule) => {
            let sigma = rule.resolve(&knr.d2);
            sigma_used = sigma;
            par::par_for_chunks(&mut vals, k, |start, chunk| {
                let i = start / k;
                for (j, v) in chunk.iter_mut().enumerate() {
                    let d = (knr.d2[i * k + j].max(0.0) as f64).sqrt();
                    *v = (-d / sigma).exp();
                }
            });
        }
        SimKernel::SelfTuning => {
            // σᵢ: K-th (= furthest kept) representative distance per object.
            let sig_obj: Vec<f64> = par::par_map(n, |i| {
                knr.d2[i * k..(i + 1) * k]
                    .iter()
                    .map(|&v| (v.max(0.0) as f64).sqrt())
                    .fold(0.0, f64::max)
                    .max(1e-12)
            });
            // σⱼ: mean incoming distance per representative.
            let mut sum = vec![0.0f64; p];
            let mut cnt = vec![0u64; p];
            for i in 0..n {
                for j in 0..k {
                    let r = knr.idx[i * k + j] as usize;
                    sum[r] += (knr.d2[i * k + j].max(0.0) as f64).sqrt();
                    cnt[r] += 1;
                }
            }
            let global: f64 = sum.iter().sum::<f64>() / (n * k) as f64;
            let sig_rep: Vec<f64> = (0..p)
                .map(|r| if cnt[r] > 0 { (sum[r] / cnt[r] as f64).max(1e-12) } else { global.max(1e-12) })
                .collect();
            sigma_used = global.max(1e-12);
            par::par_for_chunks(&mut vals, k, |start, chunk| {
                let i = start / k;
                for (j, v) in chunk.iter_mut().enumerate() {
                    let r = knr.idx[i * k + j] as usize;
                    let denom = (sig_obj[i] * sig_rep[r]).max(1e-24);
                    *v = (-(knr.d2[i * k + j].max(0.0) as f64) / denom).exp();
                }
            });
        }
        SimKernel::InverseQuadratic { eps } => {
            let sigma = SigmaRule::MeanKnr.resolve(&knr.d2);
            sigma_used = sigma;
            let reg = (eps * sigma * sigma).max(1e-24);
            par::par_for_chunks(&mut vals, k, |start, chunk| {
                let i = start / k;
                for (j, v) in chunk.iter_mut().enumerate() {
                    *v = 1.0 / (knr.d2[i * k + j].max(0.0) as f64 + reg);
                }
            });
        }
    }
    let b = Csr::from_uniform(n, p, k, knr.idx.clone(), vals);
    Affinity { b, sigma: sigma_used }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::{build_affinity, knr::KnrIndex, select, NativeBackend, SelectStrategy};
    use crate::data::synthetic::two_moons;

    fn knr_fixture() -> (usize, usize, usize, KnrResult) {
        let ds = two_moons(300, 0.05, 3);
        let reps =
            select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 8 }, 40, 10, 7).unwrap();
        let index = KnrIndex::build(&reps, 20, 7, &NativeBackend).unwrap();
        let res = index.approx_knr(&ds.x, 4, &NativeBackend);
        (300, 40, 4, res)
    }

    #[test]
    fn gaussian_mean_matches_paper_default() {
        let (n, p, k, knr) = knr_fixture();
        let a = build_affinity(n, p, k, &knr);
        let b = build_affinity_kernel(n, p, k, &knr, SimKernel::Gaussian(SigmaRule::MeanKnr));
        // summation order differs (parallel reduce vs flat) — ulp-level only
        assert!((a.sigma - b.sigma).abs() < 1e-12 * a.sigma.max(1.0));
        assert_eq!(a.b.indices, b.b.indices);
        for (x, y) in a.b.values.iter().zip(&b.b.values) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
    }

    #[test]
    fn sigma_rules_ordering() {
        let (_, _, _, knr) = knr_fixture();
        let mean = SigmaRule::MeanKnr.resolve(&knr.d2);
        let median = SigmaRule::MedianKnr.resolve(&knr.d2);
        let half = SigmaRule::Scaled(0.5).resolve(&knr.d2);
        let fixed = SigmaRule::Fixed(0.123).resolve(&knr.d2);
        assert!(mean > 0.0 && median > 0.0);
        assert!((half - 0.5 * mean).abs() < 1e-12);
        assert!((fixed - 0.123).abs() < 1e-12);
        // KNR distances are right-skewed ⇒ median ≤ mean (not strict, but
        // holds for moons)
        assert!(median <= mean * 1.2);
    }

    #[test]
    fn all_kernels_produce_valid_affinities() {
        let (n, p, k, knr) = knr_fixture();
        for kernel in [
            SimKernel::Gaussian(SigmaRule::MedianKnr),
            SimKernel::Laplacian(SigmaRule::MeanKnr),
            SimKernel::SelfTuning,
            SimKernel::InverseQuadratic { eps: 1.0 },
        ] {
            let aff = build_affinity_kernel(n, p, k, &knr, kernel);
            assert_eq!(aff.b.nnz(), n * k, "{}", kernel.name());
            assert!(aff.sigma > 0.0, "{}", kernel.name());
            for &v in &aff.b.values {
                assert!(v.is_finite() && v > 0.0, "{}: value {v}", kernel.name());
            }
        }
    }

    #[test]
    fn kernels_are_monotone_decreasing_in_distance() {
        // entries within a row must be non-increasing as d² grows (KNR rows
        // are sorted ascending by distance).
        let (n, p, k, knr) = knr_fixture();
        for kernel in [
            SimKernel::Gaussian(SigmaRule::MeanKnr),
            SimKernel::Laplacian(SigmaRule::MeanKnr),
            SimKernel::InverseQuadratic { eps: 0.5 },
        ] {
            let aff = build_affinity_kernel(n, p, k, &knr, kernel);
            for i in 0..n {
                let (_, vals) = aff.b.row(i);
                for w in vals.windows(2) {
                    assert!(
                        w[0] >= w[1] - 1e-12,
                        "{}: row {i} not monotone: {w:?}",
                        kernel.name()
                    );
                }
            }
        }
    }

    #[test]
    fn self_tuning_clusters_moons() {
        // end-to-end: the self-tuning kernel through the transfer cut still
        // separates the moons.
        let ds = two_moons(600, 0.05, 9);
        let reps =
            select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 8 }, 60, 10, 7).unwrap();
        let index = KnrIndex::build(&reps, 25, 7, &NativeBackend).unwrap();
        let res = index.approx_knr(&ds.x, 5, &NativeBackend);
        let aff = build_affinity_kernel(600, 60, 5, &res, SimKernel::SelfTuning);
        let tc = crate::bipartite::transfer_cut(
            &aff.b,
            2,
            crate::bipartite::EigSolver::Dense,
            3,
        )
        .unwrap();
        let km = crate::kmeans::kmeans(
            &tc.embedding,
            &crate::kmeans::KmeansParams { k: 2, ..Default::default() },
            5,
        )
        .unwrap();
        let score = crate::metrics::nmi(&km.labels, &ds.y);
        assert!(score > 0.8, "nmi={score}");
    }
}
