//! Affinity sub-matrix construction (paper §3.1.1–§3.1.2):
//!
//! * [`select`] — representative selection: random / k-means / **hybrid**
//!   (random pre-sampling of p′ candidates + k-means to p centers).
//! * [`knr`] — K-nearest-representative search: exact (LSC-style, O(Npd))
//!   and the paper's **coarse-to-fine approximation** (O(N·p^½·d)).
//! * [`build_affinity`] — the sparse N×p cross-affinity `B` with a Gaussian
//!   kernel whose bandwidth σ is the mean object↔KNR distance.
//!
//! All distance evaluations go through a [`DistanceBackend`] so the same
//! pipeline runs on the pure-Rust path or on the AOT-compiled Pallas kernel
//! served by [`crate::runtime`].
//!
//! # Complexity and constant factors
//!
//! Asymptotics are the paper's: approximate KNR costs
//! O(N·(z₁ + z₂ + K′)·d) = **O(N·p^½·d)** time and O(N·p^½) memory, exact
//! KNR O(N·p·d). The constant factors are where this module earns the
//! "ultra-scalable" claim:
//!
//! * every distance block runs on the packed register-tiled microkernel
//!   ([`crate::linalg::PackedMat`]), with the representative panel packed
//!   **once** per query (not per batch) on the native backend;
//! * per-row top-K selection is allocation-free
//!   ([`crate::util::argmin_k_into`] with per-group scratch, f32 keys —
//!   no f64 round-trip);
//! * parallel regions dispatch onto the persistent worker pool
//!   ([`crate::util::par`]) — no thread spawn/join inside the per-batch
//!   loop. Step 1's nearest-rep-cluster search is a fused argmin kernel
//!   that never materializes its N×z₁ distance block.

pub mod select;
pub mod knr;
pub mod kernel;

use crate::linalg::{Csr, Mat};
use crate::util::par;

pub use knr::{KnrIndex, KnrResult};
pub use select::{select, SelectStrategy};

/// Pluggable distance engine. `sq_dists(x, c)` returns the full ‖xᵢ−cⱼ‖²
/// block — the single operation the paper's hot path is built from (its
/// "batch processing manner", §3.1.4). Implementations: native Rust
/// ([`NativeBackend`]) and the PJRT artifact pool
/// ([`crate::runtime::PjrtBackend`]).
pub trait DistanceBackend: Sync {
    /// Full pairwise squared-distance block (x.rows × c.rows).
    fn sq_dists(&self, x: &Mat, c: &Mat) -> Mat;

    /// Human-readable name for logs/benches.
    fn name(&self) -> &str {
        "native"
    }

    /// True when `sq_dists` is exactly the in-process packed kernel, so
    /// hot paths may bypass this trait with pre-packed panels
    /// ([`crate::linalg::PackedMat`]). Defaults to `false`: a wrapper or
    /// instrumented backend is never silently skipped just because it
    /// kept the default cosmetic [`Self::name`].
    fn is_native(&self) -> bool {
        false
    }
}

/// Pure-Rust backend (blocked/threaded gemm formulation).
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeBackend;

impl DistanceBackend for NativeBackend {
    fn sq_dists(&self, x: &Mat, c: &Mat) -> Mat {
        x.sq_dists(c)
    }

    fn is_native(&self) -> bool {
        true
    }
}

/// The sparse affinity output of the construction phase.
#[derive(Debug, Clone)]
pub struct Affinity {
    /// Sparse N×p cross-affinity (K non-zeros per row).
    pub b: Csr,
    /// Gaussian bandwidth actually used.
    pub sigma: f64,
}

/// Build the sparse Gaussian cross-affinity `B` from a KNR result
/// (Eq. 5–6 of the paper): `b_ij = exp(−‖xᵢ−rⱼ‖² / 2σ²)` for the K nearest
/// representatives of each object, with σ = mean distance between objects
/// and their K nearest representatives.
pub fn build_affinity(n: usize, p: usize, k: usize, knr: &KnrResult) -> Affinity {
    debug_assert_eq!(knr.idx.len(), n * k);
    // σ: mean of the (true, non-squared) distances
    let sum: f64 = par::par_reduce(
        n,
        0.0f64,
        |i| knr.d2[i * k..(i + 1) * k].iter().map(|&v| (v.max(0.0) as f64).sqrt()).sum::<f64>(),
        |a, b| a + b,
    );
    let sigma = (sum / (n * k) as f64).max(1e-12);
    let denom = 2.0 * sigma * sigma;
    let mut vals = vec![0.0f64; n * k];
    par::par_for_chunks(&mut vals, k, |start, chunk| {
        let i = start / k;
        for (j, v) in chunk.iter_mut().enumerate() {
            *v = (-(knr.d2[i * k + j].max(0.0) as f64) / denom).exp();
        }
    });
    let b = Csr::from_uniform(n, p, k, knr.idx.clone(), vals);
    Affinity { b, sigma }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    #[test]
    fn affinity_structure() {
        let ds = two_moons(500, 0.05, 3);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 50, 10, 7).unwrap();
        let index = knr::KnrIndex::build(&reps, 25, 7, &NativeBackend).unwrap();
        let res = index.approx_knr(&ds.x, 5, &NativeBackend);
        let aff = build_affinity(ds.n(), 50, 5, &res);
        assert_eq!(aff.b.rows, 500);
        assert_eq!(aff.b.cols, 50);
        assert_eq!(aff.b.nnz(), 500 * 5);
        assert!(aff.sigma > 0.0);
        // every row: exactly K entries, all in (0, 1]
        for i in 0..500 {
            let (cols, vals) = aff.b.row(i);
            assert_eq!(cols.len(), 5);
            let set: std::collections::HashSet<_> = cols.iter().collect();
            assert_eq!(set.len(), 5, "duplicate representative in row {i}");
            for &v in vals {
                assert!(v > 0.0 && v <= 1.0 + 1e-12);
            }
        }
    }
}
