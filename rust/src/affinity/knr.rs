//! K-nearest-representative search (paper §3.1.2).
//!
//! [`KnrIndex::build`] runs the two pre-steps: (1) group the p
//! representatives into z₁ = ⌊p^½⌋ rep-clusters via k-means; (2) compute
//! each representative's K′ nearest representative neighbors.
//!
//! [`KnrIndex::approx_knr`] then answers per-object queries with the
//! coarse-to-fine three-step scheme: nearest rep-cluster → nearest
//! representative inside it → top-K among that representative's K′
//! neighborhood. All distance blocks go through the [`DistanceBackend`],
//! batched per rep-cluster / per anchor so the compiled kernel sees dense
//! rectangular work (the paper's "batch processing manner").
//!
//! The query path is allocation-free per row: top-K selection goes through
//! [`argmin_k_into`] with per-group scratch, gather buffers are reused
//! across buckets, and on the native backend the representative panel is
//! packed **once** ([`Mat::pack_rhs`]) and shared by every batch
//! (`exact_knr` additionally parallelizes across batches, with the
//! per-batch gemm running inline on the claiming worker). Every packed
//! kernel below dispatches through the runtime SIMD layer in
//! [`crate::linalg`] — results are bit-identical whichever tile
//! implementation is picked.

use super::DistanceBackend;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::{nearest_packed_into, sq_dists_into, DistScratch, Mat};
use crate::util::{argmin_k_into, par};
use crate::{ensure_arg, Result};

/// Buckets handled per parallel work item in the grouped stages: as many
/// as possible (so one worker reuses its gather/selection buffers across
/// buckets) while still leaving ~4 work items per thread for load
/// balancing. Grouping never changes results — buckets are independent.
fn bucket_group(nbuckets: usize) -> usize {
    nbuckets.div_ceil(par::num_threads() * 4).max(1)
}

/// Preprocessed index over the representative set.
#[derive(Debug, Clone)]
pub struct KnrIndex {
    /// The p×d representatives.
    pub reps: Mat,
    /// z₁×d rep-cluster centers.
    pub rc_centers: Mat,
    /// members[c] = representative ids in rep-cluster c.
    pub members: Vec<Vec<u32>>,
    /// Flattened p×(K′+1) neighbor lists (each representative's K′ nearest
    /// representatives, self included at position 0).
    pub neighbors: Vec<u32>,
    /// K′+1 (row stride of `neighbors`).
    pub nbr_len: usize,
}

/// Per-object K-nearest-representative answer (flattened n×K).
#[derive(Debug, Clone)]
pub struct KnrResult {
    /// Representative column ids, n×K row-major.
    pub idx: Vec<u32>,
    /// Squared distances aligned with `idx`.
    pub d2: Vec<f32>,
    pub k: usize,
}

impl KnrIndex {
    /// Pre-steps 1 & 2. `z1 = ⌊√p⌋` unless overridden, `k_prime` is the
    /// candidate neighborhood size K′ (paper suggests 10·K).
    pub fn build(
        reps: &Mat,
        k_prime: usize,
        kmeans_iters: usize,
        backend: &dyn DistanceBackend,
    ) -> Result<KnrIndex> {
        let p = reps.rows;
        ensure_arg!(p >= 1, "KnrIndex: empty representative set");
        let z1 = ((p as f64).sqrt().floor() as usize).max(1);
        let k_prime = k_prime.min(p - 1);
        // Pre-step 1: rep-clusters via k-means on the representatives.
        let km = kmeans(
            reps,
            &KmeansParams { k: z1, max_iter: kmeans_iters, tol: 1e-3, ..Default::default() },
            0x5EED ^ p as u64,
        )?;
        let mut members: Vec<Vec<u32>> = vec![Vec::new(); z1];
        for (r, &c) in km.labels.iter().enumerate() {
            members[c as usize].push(r as u32);
        }
        // k-means guarantees non-empty clusters (repair step), but guard:
        members.retain(|m| !m.is_empty());
        let rc_centers = if members.len() == z1 {
            km.centers
        } else {
            // rebuild centers for surviving clusters
            let mut c = Mat::zeros(members.len(), reps.cols);
            for (ci, m) in members.iter().enumerate() {
                for &r in m {
                    for t in 0..reps.cols {
                        let v = c.at(ci, t) + reps.at(r as usize, t) / m.len() as f32;
                        c.set(ci, t, v);
                    }
                }
            }
            c
        };
        // Pre-step 2: K′-NN among representatives (exact, O(p²d) — p ≪ N).
        let nbr_len = k_prime + 1;
        let d2 = backend.sq_dists(reps, reps);
        let mut neighbors = vec![0u32; p * nbr_len];
        par::par_for_chunks(&mut neighbors, nbr_len * 32, |start, chunk| {
            let row0 = start / nbr_len;
            let rows = chunk.len() / nbr_len;
            let mut scratch: Vec<u32> = Vec::new();
            let mut order: Vec<u32> = Vec::new();
            for bi in 0..rows {
                let i = row0 + bi;
                argmin_k_into(&d2.data[i * p..(i + 1) * p], nbr_len, &mut scratch, &mut order);
                // ensure self first
                if let Some(pos) = order.iter().position(|&j| j == i as u32) {
                    order.swap(0, pos);
                } else {
                    order.insert(0, i as u32);
                    order.truncate(nbr_len);
                }
                chunk[bi * nbr_len..(bi + 1) * nbr_len].copy_from_slice(&order);
            }
        });
        Ok(KnrIndex { reps: reps.clone(), rc_centers, members, neighbors, nbr_len })
    }

    pub fn p(&self) -> usize {
        self.reps.rows
    }

    pub fn z1(&self) -> usize {
        self.rc_centers.rows
    }

    /// The paper's three-step approximate K-nearest representatives for all
    /// rows of `x`. O(N·(z₁ + z₂ + K′)·d) = O(N·p^½·d).
    pub fn approx_knr(&self, x: &Mat, k: usize, backend: &dyn DistanceBackend) -> KnrResult {
        let n = x.rows;
        let p = self.p();
        let k = k.min(p);
        // ---- Step 1: nearest rep-cluster, batched over all of x ----------
        let nearest_rc = nearest_row_batched(x, &self.rc_centers, backend);

        // ---- Step 2: nearest representative inside that rep-cluster ------
        // Bucket objects by rep-cluster so each bucket runs one dense block;
        // buckets are processed in groups so a worker reuses its gather
        // buffers across buckets.
        let z1 = self.z1();
        let mut buckets: Vec<Vec<u32>> = vec![Vec::new(); z1];
        for (i, &c) in nearest_rc.iter().enumerate() {
            buckets[c as usize].push(i as u32);
        }
        let mut anchor = vec![0u32; n]; // r_l per object
        let group = bucket_group(z1);
        let ngroups = z1.div_ceil(group);
        let per_group: Vec<Vec<(u32, Vec<u32>)>> = par::par_map(ngroups, |g| {
            let lo = g * group;
            let hi = (lo + group).min(z1);
            let mut xb = Mat::zeros(0, x.cols);
            let mut rb = Mat::zeros(0, x.cols);
            let mut out = Vec::new();
            for c in lo..hi {
                let objs = &buckets[c];
                if objs.is_empty() {
                    continue;
                }
                let mem = &self.members[c];
                gather_rows_u32_into(x, objs, &mut xb);
                gather_rows_u32_into(&self.reps, mem, &mut rb);
                let d2 = backend.sq_dists(&xb, &rb);
                let winners: Vec<u32> = (0..objs.len())
                    .map(|bi| {
                        let row = &d2.data[bi * mem.len()..(bi + 1) * mem.len()];
                        let mut best = 0usize;
                        for (j, &v) in row.iter().enumerate().skip(1) {
                            if v < row[best] {
                                best = j;
                            }
                        }
                        mem[best]
                    })
                    .collect();
                out.push((c as u32, winners));
            }
            out
        });
        for group in per_group {
            for (c, winners) in group {
                for (bi, &obj) in buckets[c as usize].iter().enumerate() {
                    anchor[obj as usize] = winners[bi];
                }
            }
        }

        // ---- Step 3: top-K among the anchor's K′ neighborhood -------------
        // Bucket objects by anchor representative; same group-of-buckets
        // structure so scratch and gather buffers amortize across anchors.
        let mut by_anchor: Vec<Vec<u32>> = vec![Vec::new(); p];
        for (i, &a) in anchor.iter().enumerate() {
            by_anchor[a as usize].push(i as u32);
        }
        let mut idx = vec![0u32; n * k];
        let mut d2out = vec![0f32; n * k];
        let group = bucket_group(p);
        let ngroups = p.div_ceil(group);
        let groups: Vec<Vec<(u32, Vec<u32>, Vec<f32>)>> = par::par_map(ngroups, |g| {
            let lo = g * group;
            let hi = (lo + group).min(p);
            let mut xb = Mat::zeros(0, x.cols);
            let mut rb = Mat::zeros(0, x.cols);
            let mut scratch: Vec<u32> = Vec::new();
            let mut order: Vec<u32> = Vec::new();
            let mut out = Vec::new();
            for a in lo..hi {
                let objs = &by_anchor[a];
                if objs.is_empty() {
                    continue;
                }
                let cand = &self.neighbors[a * self.nbr_len..(a + 1) * self.nbr_len];
                gather_rows_u32_into(x, objs, &mut xb);
                gather_rows_u32_into(&self.reps, cand, &mut rb);
                let d2 = backend.sq_dists(&xb, &rb);
                let m = cand.len();
                // If the candidate neighborhood is smaller than K, pad every
                // row with *distinct* fallback representatives (lowest ids
                // not already candidates) so the per-row uniqueness
                // invariant holds; their distances are computed exactly.
                let pad: Vec<u32> = if m < k {
                    let mut in_cand = vec![false; p];
                    for &cj in cand {
                        in_cand[cj as usize] = true;
                    }
                    (0..p as u32).filter(|&r| !in_cand[r as usize]).take(k - m).collect()
                } else {
                    Vec::new()
                };
                let mut ids = Vec::with_capacity(objs.len() * k);
                let mut ds = Vec::with_capacity(objs.len() * k);
                for bi in 0..objs.len() {
                    let row = &d2.data[bi * m..(bi + 1) * m];
                    argmin_k_into(row, k, &mut scratch, &mut order);
                    for &t in &order {
                        ids.push(cand[t as usize]);
                        ds.push(row[t as usize]);
                    }
                    let xrow = xb.row(bi);
                    for &r in &pad {
                        let rrow = self.reps.row(r as usize);
                        let mut s = 0.0f32;
                        for (xv, rv) in xrow.iter().zip(rrow) {
                            let diff = xv - rv;
                            s += diff * diff;
                        }
                        ids.push(r);
                        ds.push(s);
                    }
                }
                out.push((a as u32, ids, ds));
            }
            out
        });
        for group in groups {
            for (a, ids, ds) in group {
                for (bi, &obj) in by_anchor[a as usize].iter().enumerate() {
                    let o = obj as usize * k;
                    idx[o..o + k].copy_from_slice(&ids[bi * k..(bi + 1) * k]);
                    d2out[o..o + k].copy_from_slice(&ds[bi * k..(bi + 1) * k]);
                }
            }
        }
        KnrResult { idx, d2: d2out, k }
    }

    /// Exact K-nearest representatives (LSC-style, O(Npd) + O(NpK)) —
    /// the comparator for Tables 15–16 and the approximation-recall tests.
    pub fn exact_knr(&self, x: &Mat, k: usize, backend: &dyn DistanceBackend) -> KnrResult {
        exact_knr(x, &self.reps, k, backend)
    }
}

/// Exact K-nearest rows of `reps` for every row of `x`. Batches run in
/// parallel; on the native backend each batch reuses one packed
/// representative panel and allocation-free selection scratch.
pub fn exact_knr(x: &Mat, reps: &Mat, k: usize, backend: &dyn DistanceBackend) -> KnrResult {
    let n = x.rows;
    let p = reps.rows;
    let d = x.cols;
    let k = k.min(p);
    if n == 0 || k == 0 {
        return KnrResult { idx: Vec::new(), d2: Vec::new(), k };
    }
    // Pack the representative panel once; every batch reads the same warm
    // panels (native fast path — other backends go through their own
    // sq_dists so compiled-kernel batching still applies).
    let packed = if backend.is_native() { Some(reps.pack_rhs()) } else { None };
    // Batches are the unit of outer parallelism and each batch's gemm runs
    // inline on its claiming worker, so on the native path shrink batches
    // until there are ~4 per thread (floor keeps the gemm tile-efficient).
    // Other backends keep the fixed compiled-kernel batch shape. Batch
    // size never changes results — rows are independent.
    let batch = if packed.is_some() {
        n.div_ceil(par::num_threads() * 4).clamp(512, 4096)
    } else {
        4096usize
    };
    let nb = n.div_ceil(batch);
    let parts: Vec<(Vec<u32>, Vec<f32>)> = par::par_map(nb, |b| {
        let lo = b * batch;
        let hi = ((b + 1) * batch).min(n);
        let rows = hi - lo;
        let dbuf: Vec<f32> = match &packed {
            Some(pk) => {
                let mut scratch = DistScratch::default();
                let mut out = Vec::new();
                sq_dists_into(&x.data[lo * d..hi * d], rows, pk, &mut scratch, &mut out);
                out
            }
            None => {
                let xb = Mat {
                    rows,
                    cols: d,
                    data: x.data[lo * d..hi * d].to_vec(),
                };
                backend.sq_dists(&xb, reps).data
            }
        };
        let mut ids = Vec::with_capacity(rows * k);
        let mut ds = Vec::with_capacity(rows * k);
        let mut scratch: Vec<u32> = Vec::new();
        let mut order: Vec<u32> = Vec::new();
        for bi in 0..rows {
            let row = &dbuf[bi * p..(bi + 1) * p];
            argmin_k_into(row, k, &mut scratch, &mut order);
            for &t in &order {
                ids.push(t);
                ds.push(row[t as usize]);
            }
        }
        (ids, ds)
    });
    let mut idx = Vec::with_capacity(n * k);
    let mut d2 = Vec::with_capacity(n * k);
    for (a, b) in parts {
        idx.extend(a);
        d2.extend(b);
    }
    KnrResult { idx, d2, k }
}

/// Nearest row of `c` for every row of `x`. On the native backend this is
/// the fused packed argmin kernel (no distance block is materialized),
/// writing through caller-reusable scratch; other backends fall back to
/// fixed-size batches through `sq_dists`.
fn nearest_row_batched(x: &Mat, c: &Mat, backend: &dyn DistanceBackend) -> Vec<u32> {
    if backend.is_native() {
        let packed = c.pack_rhs();
        let mut scratch = DistScratch::default();
        let (mut labels, mut dists) = (Vec::new(), Vec::new());
        nearest_packed_into(x, &packed, &mut scratch, &mut labels, &mut dists);
        return labels;
    }
    let n = x.rows;
    let m = c.rows;
    let batch = 8192usize;
    let mut out = vec![0u32; n];
    let mut lo = 0;
    while lo < n {
        let hi = (lo + batch).min(n);
        let xb = Mat { rows: hi - lo, cols: x.cols, data: x.data[lo * x.cols..hi * x.cols].to_vec() };
        let d2 = backend.sq_dists(&xb, c);
        let winners: Vec<u32> = par::par_map(hi - lo, |bi| {
            let row = &d2.data[bi * m..(bi + 1) * m];
            let mut best = 0usize;
            for (j, &v) in row.iter().enumerate().skip(1) {
                if v < row[best] {
                    best = j;
                }
            }
            best as u32
        });
        out[lo..hi].copy_from_slice(&winners);
        lo = hi;
    }
    out
}

/// Gather rows of `m` into `out`, reusing `out`'s allocation.
fn gather_rows_u32_into(m: &Mat, idx: &[u32], out: &mut Mat) {
    out.rows = idx.len();
    out.cols = m.cols;
    out.data.clear();
    out.data.reserve(idx.len() * m.cols);
    for &i in idx {
        out.data.extend_from_slice(m.row(i as usize));
    }
}

/// Recall@K of an approximate KNR against the exact answer (mean fraction
/// of the true K nearest representatives recovered per object).
pub fn recall_at_k(approx: &KnrResult, exact: &KnrResult, n: usize) -> f64 {
    assert_eq!(approx.k, exact.k);
    let k = approx.k;
    let mut hits = 0usize;
    for i in 0..n {
        let a: std::collections::HashSet<u32> =
            approx.idx[i * k..(i + 1) * k].iter().copied().collect();
        for &e in &exact.idx[i * k..(i + 1) * k] {
            if a.contains(&e) {
                hits += 1;
            }
        }
    }
    hits as f64 / (n * k) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::{select, NativeBackend, SelectStrategy};
    use crate::data::synthetic::{concentric_circles, two_moons};
    use crate::util::argmin_k;

    #[test]
    fn index_structure() {
        let ds = two_moons(800, 0.05, 1);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 64, 10, 2).unwrap();
        let idx = KnrIndex::build(&reps, 20, 10, &NativeBackend).unwrap();
        assert_eq!(idx.p(), 64);
        assert_eq!(idx.z1(), 8); // ⌊√64⌋
        let total: usize = idx.members.iter().map(|m| m.len()).sum();
        assert_eq!(total, 64);
        assert_eq!(idx.nbr_len, 21);
        // each neighbor list starts with self
        for r in 0..64 {
            assert_eq!(idx.neighbors[r * 21], r as u32);
        }
    }

    #[test]
    fn exact_knr_is_truly_nearest() {
        let ds = two_moons(300, 0.05, 2);
        let reps = select(&ds.x, SelectStrategy::Random, 40, 10, 3).unwrap();
        let res = exact_knr(&ds.x, &reps, 4, &NativeBackend);
        // brute-force check a few objects
        for i in [0usize, 17, 123, 299] {
            let mut d: Vec<f64> = (0..40)
                .map(|r| {
                    (0..2)
                        .map(|t| (ds.x.at(i, t) - reps.at(r, t)) as f64)
                        .map(|v| v * v)
                        .sum()
                })
                .collect();
            let got = &res.idx[i * 4..(i + 1) * 4];
            let mut want = argmin_k(&d, 4);
            assert_eq!(got.iter().map(|&v| v as usize).collect::<Vec<_>>(), want);
            // distances ascending
            for w in res.d2[i * 4..(i + 1) * 4].windows(2) {
                assert!(w[0] <= w[1] + 1e-6);
            }
            d.clear();
            want.clear();
        }
    }

    #[test]
    fn approx_recall_high_on_clustered_data() {
        let ds = concentric_circles(2000, 4);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 100, 15, 5).unwrap();
        let index = KnrIndex::build(&reps, 50, 15, &NativeBackend).unwrap();
        let approx = index.approx_knr(&ds.x, 5, &NativeBackend);
        let exact = index.exact_knr(&ds.x, 5, &NativeBackend);
        let recall = recall_at_k(&approx, &exact, ds.n());
        assert!(recall > 0.85, "recall {recall}");
    }

    #[test]
    fn approx_equals_exact_when_kprime_is_p() {
        // With K' = p-1 the step-3 candidate set contains all reps of the
        // anchor's neighborhood = all reps, so approx == exact.
        let ds = two_moons(400, 0.05, 6);
        let reps = select(&ds.x, SelectStrategy::Random, 25, 10, 7).unwrap();
        let index = KnrIndex::build(&reps, 24, 10, &NativeBackend).unwrap();
        let approx = index.approx_knr(&ds.x, 3, &NativeBackend);
        let exact = index.exact_knr(&ds.x, 3, &NativeBackend);
        assert!((recall_at_k(&approx, &exact, ds.n()) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn knr_rows_unique_and_valid() {
        let ds = two_moons(500, 0.08, 8);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 5 }, 49, 10, 9).unwrap();
        let index = KnrIndex::build(&reps, 30, 10, &NativeBackend).unwrap();
        let res = index.approx_knr(&ds.x, 5, &NativeBackend);
        for i in 0..ds.n() {
            let ids = &res.idx[i * 5..(i + 1) * 5];
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), 5, "row {i}: {ids:?}");
            assert!(ids.iter().all(|&r| (r as usize) < 49));
        }
    }

    #[test]
    fn tiny_p_padding() {
        // p smaller than K exercises the clamp paths
        let ds = two_moons(100, 0.05, 10);
        let reps = select(&ds.x, SelectStrategy::Random, 3, 5, 11).unwrap();
        let index = KnrIndex::build(&reps, 10, 5, &NativeBackend).unwrap();
        let res = index.approx_knr(&ds.x, 5, &NativeBackend);
        assert_eq!(res.k, 3); // clamped to p
    }

    #[test]
    fn padding_with_small_neighborhood_keeps_rows_unique() {
        // Regression: K′+1 < K used to pad rows by repeating one candidate,
        // breaking per-row uniqueness. Build an index whose neighborhood
        // (K′=2 ⇒ nbr_len=3) is smaller than the K=5 query.
        let ds = two_moons(300, 0.06, 12);
        let reps = select(&ds.x, SelectStrategy::Random, 20, 10, 13).unwrap();
        let index = KnrIndex::build(&reps, 2, 10, &NativeBackend).unwrap();
        assert_eq!(index.nbr_len, 3);
        let res = index.approx_knr(&ds.x, 5, &NativeBackend);
        assert_eq!(res.k, 5);
        for i in 0..ds.n() {
            let ids = &res.idx[i * 5..(i + 1) * 5];
            let set: std::collections::HashSet<_> = ids.iter().collect();
            assert_eq!(set.len(), 5, "row {i} not unique: {ids:?}");
            assert!(ids.iter().all(|&r| (r as usize) < 20));
            // padded distances are real distances, not copies of the last
            // candidate's — all entries finite and non-negative
            for &dv in &res.d2[i * 5..(i + 1) * 5] {
                assert!(dv.is_finite() && dv >= 0.0);
            }
        }
    }
}
