//! Representative selection (paper §3.1.1): random (Nyström-style),
//! k-means on the full data (LSC-K-style, O(Npdt)), and the paper's
//! **hybrid** strategy — random pre-sampling of p′ ≫ p candidates followed
//! by k-means on the candidates only, O(p′·p·d·t) = O(p²dt) for p′ = O(p).

use crate::kmeans::{kmeans, Init, KmeansParams};
use crate::linalg::Mat;
use crate::util::rng::Rng;
use crate::{ensure_arg, Result};

/// How to pick the p representatives.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SelectStrategy {
    /// Uniform sample of p points (Nyström / LSC-R).
    Random,
    /// k-means on the entire dataset; centers are the representatives
    /// (LSC-K). O(Npdt).
    KmeansFull,
    /// Random pre-sampling of `candidate_factor`·p candidates, then k-means
    /// on the candidates (the paper's contribution #1). O(p²dt) for
    /// candidate_factor = O(1).
    Hybrid { candidate_factor: usize },
}

impl SelectStrategy {
    pub fn tag(&self) -> &'static str {
        match self {
            SelectStrategy::Random => "R",
            SelectStrategy::KmeansFull => "K",
            SelectStrategy::Hybrid { .. } => "H",
        }
    }
}

/// Select `p` representatives from `x`. `kmeans_iters` caps the k-means
/// refinement (`t` in the paper's complexity terms).
pub fn select(
    x: &Mat,
    strategy: SelectStrategy,
    p: usize,
    kmeans_iters: usize,
    seed: u64,
) -> Result<Mat> {
    let n = x.rows;
    ensure_arg!(p >= 1, "select: p must be >= 1");
    ensure_arg!(p <= n, "select: p={p} > n={n}");
    let mut rng = Rng::new(seed);
    match strategy {
        SelectStrategy::Random => {
            let idx = rng.sample_indices(n, p);
            Ok(x.gather_rows(&idx))
        }
        SelectStrategy::KmeansFull => {
            let res = kmeans(
                x,
                &KmeansParams { k: p, max_iter: kmeans_iters, tol: 1e-3, init: Init::Random },
                rng.next_u64(),
            )?;
            Ok(res.centers)
        }
        SelectStrategy::Hybrid { candidate_factor } => {
            ensure_arg!(candidate_factor >= 1, "select: candidate_factor must be >= 1");
            let p_prime = (candidate_factor * p).min(n);
            let idx = rng.sample_indices(n, p_prime);
            let candidates = x.gather_rows(&idx);
            if p_prime == p {
                return Ok(candidates);
            }
            let res = kmeans(
                &candidates,
                &KmeansParams { k: p, max_iter: kmeans_iters, tol: 1e-3, init: Init::Random },
                rng.next_u64(),
            )?;
            Ok(res.centers)
        }
    }
}

/// Quantization error of a representative set: mean squared distance from
/// each object to its nearest representative. Used by the Fig. 1
/// comparison (`repro fig1`) — lower = representatives cover the data
/// better.
pub fn quantization_error(x: &Mat, reps: &Mat) -> f64 {
    let (_, d2) = crate::kmeans::assign_batched(x, reps, 8192);
    d2.iter().map(|&v| v as f64).sum::<f64>() / x.rows as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    #[test]
    fn shapes() {
        let ds = two_moons(1000, 0.05, 1);
        for s in [
            SelectStrategy::Random,
            SelectStrategy::KmeansFull,
            SelectStrategy::Hybrid { candidate_factor: 10 },
        ] {
            let reps = select(&ds.x, s, 40, 20, 9).unwrap();
            assert_eq!(reps.rows, 40);
            assert_eq!(reps.cols, 2);
        }
    }

    #[test]
    fn hybrid_beats_random_on_quantization() {
        // Fig. 1's claim: hybrid representatives reflect the distribution
        // better than random. Compare mean quantization error over trials.
        let ds = two_moons(3000, 0.06, 2);
        let trials = 5;
        let (mut qr, mut qh) = (0.0, 0.0);
        for t in 0..trials {
            let r = select(&ds.x, SelectStrategy::Random, 30, 20, 100 + t).unwrap();
            let h = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 10 }, 30, 20, 200 + t).unwrap();
            qr += quantization_error(&ds.x, &r);
            qh += quantization_error(&ds.x, &h);
        }
        assert!(qh < qr, "hybrid {qh} should beat random {qr}");
    }

    #[test]
    fn hybrid_with_factor_one_is_random() {
        let ds = two_moons(500, 0.05, 3);
        let reps = select(&ds.x, SelectStrategy::Hybrid { candidate_factor: 1 }, 20, 20, 5).unwrap();
        assert_eq!(reps.rows, 20);
    }

    #[test]
    fn p_equals_n() {
        let ds = two_moons(30, 0.05, 4);
        let reps = select(&ds.x, SelectStrategy::Random, 30, 5, 1).unwrap();
        assert_eq!(reps.rows, 30);
    }

    #[test]
    fn rejects_bad_p() {
        let ds = two_moons(10, 0.05, 5);
        assert!(select(&ds.x, SelectStrategy::Random, 0, 5, 1).is_err());
        assert!(select(&ds.x, SelectStrategy::Random, 11, 5, 1).is_err());
    }
}
