//! Paper-table drivers: one function per evaluation artifact (Tables 4–16,
//! Figs. 1/3/5). Shared by the CLI (`repro table t4` …) and the
//! `cargo bench` binaries. Output is the paper's row/column structure with
//! measured mean±std cells; infeasible (paper-scale OOM) cells print N/A
//! exactly where the paper reports N/A.

use super::runner::{self, derive};
use super::{Cell, Stats, TablePrinter};
use crate::affinity::{DistanceBackend, NativeBackend, SelectStrategy};
use crate::baselines::SpectralMethod;
use crate::config::{BackendKind, RunConfig};
use crate::data::{Benchmark, Dataset};
use crate::ensemble_baselines::EnsembleMethod;
use crate::metrics::{ca, nmi};
use crate::uspec::KnrMode;
use crate::Result;

/// Everything a table driver needs.
pub struct Harness {
    pub cfg: RunConfig,
    backend: Box<dyn DistanceBackend>,
    /// Kernel pool kept alive for the pjrt backend.
    _pool: Option<std::sync::Arc<crate::runtime::KernelPool>>,
}

impl Harness {
    pub fn new(cfg: RunConfig) -> Result<Harness> {
        let (backend, pool): (Box<dyn DistanceBackend>, _) = match cfg.backend {
            BackendKind::Native => (Box::new(NativeBackend), None),
            BackendKind::Pjrt => {
                let pool = crate::runtime::KernelPool::start(crate::runtime::default_artifact_dir())?;
                (Box::new(crate::runtime::PjrtBackend::new(pool.clone())), Some(pool))
            }
        };
        Ok(Harness { cfg, backend, _pool: pool })
    }

    pub fn backend(&self) -> &dyn DistanceBackend {
        self.backend.as_ref()
    }

    fn dataset(&self, b: Benchmark) -> Dataset {
        b.generate(self.cfg.scale, self.cfg.seed ^ 0xDA7A)
    }

    /// Datasets for the full Tables 4–9 sweep.
    pub fn all_datasets(&self) -> Vec<Benchmark> {
        Benchmark::ALL.to_vec()
    }

    /// The four datasets of the parameter-analysis section (§4.5).
    pub fn sweep_datasets(&self) -> Vec<Benchmark> {
        vec![Benchmark::Mnist, Benchmark::Covertype, Benchmark::Tb1m, Benchmark::Sf2m]
    }
}

/// Measure one method×dataset cell (runs repetitions, aggregates).
fn measure<F>(h: &Harness, ds: &Dataset, runs: usize, mut run_once: F) -> Cell
where
    F: FnMut(u64) -> Result<Vec<u32>>,
{
    let mut nmi_s = Stats::default();
    let mut ca_s = Stats::default();
    let mut secs = Stats::default();
    for r in 0..runs.max(1) {
        let seed = h.cfg.seed.wrapping_add(1000 * r as u64 + 1);
        let t0 = std::time::Instant::now();
        match run_once(seed) {
            Ok(labels) => {
                secs.push(t0.elapsed().as_secs_f64());
                nmi_s.push(nmi(&labels, &ds.y));
                ca_s.push(ca(&labels, &ds.y));
            }
            Err(e) => {
                eprintln!("  [warn] run failed on {}: {e}", ds.name);
                return Cell::na("error");
            }
        }
    }
    Cell::Value { nmi: nmi_s, ca: ca_s, secs }
}

fn spectral_feasible(h: &Harness, m: SpectralMethod, b: Benchmark, ds: &Dataset) -> Option<&'static str> {
    let (pn, pd, _) = b.paper_shape();
    let mem = m.peak_memory_bytes(pn as u64, pd as u64, 1000, ds.k as u64, h.cfg.m as u64);
    if mem > h.cfg.budget_bytes {
        return Some("N/A");
    }
    if ds.n() > runner::local_cap(m.name()) {
        return Some("N/A*");
    }
    None
}

fn ensemble_feasible(h: &Harness, m: EnsembleMethod, b: Benchmark, ds: &Dataset) -> Option<&'static str> {
    let (pn, pd, _) = b.paper_shape();
    let kc = (h.cfg.m * (h.cfg.k_min + h.cfg.k_max) / 2) as u64;
    let mem = m.peak_memory_bytes(pn as u64, pd as u64, h.cfg.m as u64, kc);
    if mem > h.cfg.budget_bytes {
        return Some("N/A");
    }
    if ds.n() > runner::local_cap(m.name()) {
        return Some("N/A*");
    }
    None
}

fn runs_for(h: &Harness, heavy: bool) -> usize {
    if heavy {
        1
    } else {
        h.cfg.runs
    }
}

/// Summary rows: average score, normalized average, average rank — matching
/// the bottom rows of Tables 4/5/7/8. `cells[method][dataset]`.
fn summary_rows(methods: &[String], cells: &[Vec<Cell>], metric: impl Fn(&Cell) -> Option<f64>) -> Vec<Vec<String>> {
    let nm = methods.len();
    let nd = if nm > 0 { cells[0].len() } else { 0 };
    // average + normalized average (only methods with full coverage)
    let mut avg = vec![None::<f64>; nm];
    let mut navg = vec![None::<f64>; nm];
    for mi in 0..nm {
        let vals: Vec<Option<f64>> = (0..nd).map(|di| metric(&cells[mi][di])).collect();
        if vals.iter().all(|v| v.is_some()) {
            avg[mi] = Some(vals.iter().map(|v| v.unwrap()).sum::<f64>() / nd as f64);
        }
    }
    for di in 0..nd {
        let best = (0..nm)
            .filter_map(|mi| metric(&cells[mi][di]))
            .fold(f64::MIN, f64::max);
        if best <= 0.0 {
            continue;
        }
        for mi in 0..nm {
            if avg[mi].is_some() {
                if let Some(v) = metric(&cells[mi][di]) {
                    *navg[mi].get_or_insert(0.0) += v / best / nd as f64;
                }
            }
        }
    }
    // average rank (infeasible methods tie at the bottom, as in the paper)
    let mut ranks = vec![0.0f64; nm];
    for di in 0..nd {
        let mut scored: Vec<(usize, f64)> = (0..nm)
            .map(|mi| (mi, metric(&cells[mi][di]).unwrap_or(f64::NEG_INFINITY)))
            .collect();
        scored.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut rank = 1.0;
        let mut i = 0;
        while i < scored.len() {
            // ties share the same rank
            let mut j = i;
            while j + 1 < scored.len() && (scored[j + 1].1 - scored[i].1).abs() < 1e-12 {
                j += 1;
            }
            let shared = (i..=j).map(|t| rank + (t - i) as f64).sum::<f64>() / (j - i + 1) as f64;
            for t in i..=j {
                ranks[scored[t].0] += shared / nd as f64;
            }
            rank += (j - i + 1) as f64;
            i = j + 1;
        }
    }
    let fmt_opt = |v: Option<f64>| v.map(|x| format!("{:.2}", x * 100.0)).unwrap_or("N/A".into());
    let mut rows = Vec::new();
    let mut r1 = vec!["Avg. score".to_string()];
    let mut r2 = vec!["N-Avg. score".to_string()];
    let mut r3 = vec!["Avg. rank".to_string()];
    for mi in 0..nm {
        r1.push(fmt_opt(avg[mi]));
        r2.push(fmt_opt(navg[mi]));
        r3.push(format!("{:.2}", ranks[mi]));
    }
    rows.push(r1);
    rows.push(r2);
    rows.push(r3);
    rows
}

fn cell_metric_nmi(c: &Cell) -> Option<f64> {
    match c {
        Cell::Value { nmi, .. } => Some(nmi.mean()),
        _ => None,
    }
}

fn cell_metric_ca(c: &Cell) -> Option<f64> {
    match c {
        Cell::Value { ca, .. } => Some(ca.mean()),
        _ => None,
    }
}

fn fmt_cell_metric(c: &Cell, which: &str) -> String {
    match c {
        Cell::NotFeasible(r) => r.to_string(),
        Cell::Value { nmi, ca, secs } => match which {
            "nmi" => nmi.fmt_pm(100.0),
            "ca" => ca.fmt_pm(100.0),
            _ => format!("{:.2}", secs.mean()),
        },
    }
}

/// Tables 4–6: all spectral methods × all ten datasets; prints the NMI,
/// CA, and time tables plus the paper's summary rows.
pub fn spectral_tables(h: &Harness) -> Result<String> {
    let methods = SpectralMethod::ALL;
    let datasets = h.all_datasets();
    let mut cells: Vec<Vec<Cell>> = vec![Vec::new(); methods.len()];
    for (mi, &m) in methods.iter().enumerate() {
        for &b in &datasets {
            let ds = h.dataset(b);
            eprintln!("[t4-6] {} on {} (n={})", m.name(), ds.name, ds.n());
            let cell = match spectral_feasible(h, m, b, &ds) {
                Some(reason) => Cell::na(reason),
                None => {
                    let heavy = matches!(
                        m,
                        SpectralMethod::Sc | SpectralMethod::Escg | SpectralMethod::Usenc
                    );
                    measure(h, &ds, runs_for(h, heavy), |seed| {
                        runner::run_spectral(m, &ds, &h.cfg, seed, h.backend())
                            .map(|o| o.labels)
                    })
                }
            };
            cells[mi].push(cell);
        }
    }
    let method_names: Vec<String> = methods.iter().map(|m| m.name().to_string()).collect();
    let mut out = String::new();
    for (tid, which, metric) in [
        ("Table 4 — NMI(%)", "nmi", true),
        ("Table 5 — CA(%)", "ca", true),
        ("Table 6 — time (s)", "secs", false),
    ] {
        let mut tp = TablePrinter::new(
            std::iter::once("Dataset".to_string()).chain(method_names.clone()).collect(),
        );
        for (di, &b) in datasets.iter().enumerate() {
            let mut row = vec![b.name().to_string()];
            for mi in 0..methods.len() {
                row.push(fmt_cell_metric(&cells[mi][di], which));
            }
            tp.row(row);
        }
        if metric {
            let f: &dyn Fn(&Cell) -> Option<f64> =
                if which == "nmi" { &cell_metric_nmi } else { &cell_metric_ca };
            for r in summary_rows(&method_names, &cells, f) {
                tp.row(r);
            }
        }
        out.push_str(&format!("\n{tid}  (scale={}, runs={})\n", h.cfg.scale, h.cfg.runs));
        out.push_str(&tp.render());
    }
    out.push_str("\nN/A = infeasible at paper-scale 64 GB budget (memory model); N/A* = capped locally (single-core box).\n");
    Ok(out)
}

/// Tables 7–9: ensemble methods × all ten datasets (U-SPEC column included
/// for reference, as in the paper).
pub fn ensemble_tables(h: &Harness) -> Result<String> {
    let methods = EnsembleMethod::ALL;
    let datasets = h.all_datasets();
    let mut cells: Vec<Vec<Cell>> = vec![Vec::new(); methods.len()];
    for (mi, &m) in methods.iter().enumerate() {
        for &b in &datasets {
            let ds = h.dataset(b);
            eprintln!("[t7-9] {} on {} (n={})", m.name(), ds.name, ds.n());
            let cell = match ensemble_feasible(h, m, b, &ds) {
                Some(reason) => Cell::na(reason),
                None => measure(h, &ds, runs_for(h, true), |seed| {
                    runner::run_ensemble(m, &ds, &h.cfg, seed, h.backend()).map(|o| o.labels)
                }),
            };
            cells[mi].push(cell);
        }
    }
    let method_names: Vec<String> = methods.iter().map(|m| m.name().to_string()).collect();
    let mut out = String::new();
    for (tid, which, metric) in [
        ("Table 7 — NMI(%)", "nmi", true),
        ("Table 8 — CA(%)", "ca", true),
        ("Table 9 — time (s)", "secs", false),
    ] {
        let mut tp = TablePrinter::new(
            std::iter::once("Dataset".to_string()).chain(method_names.clone()).collect(),
        );
        for (di, &b) in datasets.iter().enumerate() {
            let mut row = vec![b.name().to_string()];
            for mi in 0..methods.len() {
                row.push(fmt_cell_metric(&cells[mi][di], which));
            }
            tp.row(row);
        }
        if metric {
            let f: &dyn Fn(&Cell) -> Option<f64> =
                if which == "nmi" { &cell_metric_nmi } else { &cell_metric_ca };
            for r in summary_rows(&method_names, &cells, f) {
                tp.row(r);
            }
        }
        out.push_str(&format!("\n{tid}  (m={}, scale={})\n", h.cfg.m, h.cfg.scale));
        out.push_str(&tp.render());
    }
    Ok(out)
}

/// Generic parameter sweep driver: vary one parameter over `values`,
/// running `methods` on the §4.5 datasets.
fn sweep<FSet>(
    h: &Harness,
    title: &str,
    param: &str,
    values: &[usize],
    methods: &[&str],
    set: FSet,
) -> Result<String>
where
    FSet: Fn(&mut RunConfig, usize),
{
    let mut out = String::new();
    for &b in &h.sweep_datasets() {
        let ds = h.dataset(b);
        let mut tp = TablePrinter::new(
            std::iter::once(param.to_string())
                .chain(methods.iter().flat_map(|m| {
                    ["nmi", "ca", "s"].iter().map(move |sfx| format!("{m}:{sfx}"))
                }))
                .collect(),
        );
        for &v in values {
            let mut cfg = h.cfg.clone();
            set(&mut cfg, v);
            let mut row = vec![v.to_string()];
            for m in methods {
                eprintln!("[{title}] {m} {param}={v} on {}", ds.name);
                // skip landmark counts beyond the scaled dataset
                if (param == "p" && v > ds.n() / 2) || (param == "K" && v > cfg.p) {
                    row.extend(["-".to_string(), "-".to_string(), "-".to_string()]);
                    continue;
                }
                let cell = measure(h, &ds, 1, |seed| {
                    runner::run_by_name(m, &ds, &cfg, seed, h.backend()).map(|o| o.labels)
                });
                match &cell {
                    Cell::Value { nmi, ca, secs } => {
                        row.push(format!("{:.2}", nmi.mean() * 100.0));
                        row.push(format!("{:.2}", ca.mean() * 100.0));
                        row.push(format!("{:.2}", secs.mean()));
                    }
                    Cell::NotFeasible(r) => {
                        row.extend([r.to_string(), r.to_string(), r.to_string()])
                    }
                }
            }
            tp.row(row);
        }
        out.push_str(&format!("\n{title} — {}  (n={})\n", ds.name, ds.n()));
        out.push_str(&tp.render());
    }
    Ok(out)
}

/// Table 10: varying number of representatives p.
pub fn sweep_p(h: &Harness) -> Result<String> {
    let values = [100usize, 200, 400, 600, 800, 1000];
    sweep(h, "Table 10", "p", &values, &["Nystrom", "LSC-K", "LSC-R", "U-SPEC", "U-SENC"], |c, v| {
        c.p = v
    })
}

/// Table 11: varying number of nearest representatives K.
pub fn sweep_k(h: &Harness) -> Result<String> {
    let values = [2usize, 3, 4, 5, 6, 7, 8, 9, 10];
    sweep(h, "Table 11", "K", &values, &["Nystrom", "LSC-K", "LSC-R", "U-SPEC", "U-SENC"], |c, v| {
        c.k_nn = v
    })
}

/// Table 12: varying ensemble size m.
pub fn sweep_m(h: &Harness) -> Result<String> {
    let values = [10usize, 20, 30, 40, 50];
    sweep(
        h,
        "Table 12",
        "m",
        &values,
        &["KCC", "PTGP", "ECC", "SEC", "LWGP", "U-SENC"],
        |c, v| c.m = v,
    )
}

/// Tables 13–14: representative selection strategies (H/R/K) for U-SPEC
/// and U-SENC.
pub fn selection_tables(h: &Harness) -> Result<String> {
    let strategies: [(&str, SelectStrategy); 3] = [
        ("H", SelectStrategy::Hybrid { candidate_factor: 10 }),
        ("R", SelectStrategy::Random),
        ("K", SelectStrategy::KmeansFull),
    ];
    let mut out = String::new();
    for (table, method) in [("Table 13 — U-SPEC", "U-SPEC"), ("Table 14 — U-SENC", "U-SENC")] {
        let mut tp = TablePrinter::new(
            std::iter::once("Dataset".to_string())
                .chain(strategies.iter().flat_map(|(tag, _)| {
                    ["nmi", "ca", "s"].iter().map(move |sfx| format!("{tag}:{sfx}"))
                }))
                .collect(),
        );
        for &b in &h.sweep_datasets() {
            let ds = h.dataset(b);
            let mut row = vec![b.name().to_string()];
            for (tag, strat) in &strategies {
                eprintln!("[{table}] {tag} on {}", ds.name);
                let dp = derive(&h.cfg, &ds);
                let cell = measure(h, &ds, 1, |seed| {
                    if method == "U-SPEC" {
                        let mut params = runner::uspec_params(&h.cfg, &dp);
                        params.selection = *strat;
                        crate::uspec::uspec_with_backend(&ds.x, &params, seed, h.backend())
                            .map(|r| r.labels)
                    } else {
                        let mut params = runner::usenc_params(&h.cfg, &dp, ds.n());
                        params.base.selection = *strat;
                        crate::coordinator::usenc_coordinated(
                            &ds.x,
                            &params,
                            seed,
                            h.backend(),
                            h.cfg.workers,
                            None,
                        )
                        .map(|r| r.labels)
                    }
                });
                match &cell {
                    Cell::Value { nmi, ca, secs } => {
                        row.push(format!("{:.2}", nmi.mean() * 100.0));
                        row.push(format!("{:.2}", ca.mean() * 100.0));
                        row.push(format!("{:.2}", secs.mean()));
                    }
                    Cell::NotFeasible(r) => row.extend([r.to_string(), r.to_string(), r.to_string()]),
                }
            }
            tp.row(row);
        }
        out.push_str(&format!("\n{table}: selection strategies (H=hybrid R=random K=k-means)\n"));
        out.push_str(&tp.render());
    }
    Ok(out)
}

/// Tables 15–16: approximate vs exact K-nearest representatives.
pub fn knr_tables(h: &Harness) -> Result<String> {
    let modes: [(&str, KnrMode); 2] = [("A", KnrMode::Approx), ("E", KnrMode::Exact)];
    let mut out = String::new();
    for (table, method) in [("Table 15 — U-SPEC", "U-SPEC"), ("Table 16 — U-SENC", "U-SENC")] {
        let mut tp = TablePrinter::new(
            std::iter::once("Dataset".to_string())
                .chain(modes.iter().flat_map(|(tag, _)| {
                    ["nmi", "ca", "s"].iter().map(move |sfx| format!("{tag}:{sfx}"))
                }))
                .collect(),
        );
        for &b in &h.sweep_datasets() {
            let ds = h.dataset(b);
            let mut row = vec![b.name().to_string()];
            for (tag, mode) in &modes {
                eprintln!("[{table}] {tag} on {}", ds.name);
                let dp = derive(&h.cfg, &ds);
                let cell = measure(h, &ds, 1, |seed| {
                    if method == "U-SPEC" {
                        let mut params = runner::uspec_params(&h.cfg, &dp);
                        params.knr = *mode;
                        crate::uspec::uspec_with_backend(&ds.x, &params, seed, h.backend())
                            .map(|r| r.labels)
                    } else {
                        let mut params = runner::usenc_params(&h.cfg, &dp, ds.n());
                        params.base.knr = *mode;
                        crate::coordinator::usenc_coordinated(
                            &ds.x,
                            &params,
                            seed,
                            h.backend(),
                            h.cfg.workers,
                            None,
                        )
                        .map(|r| r.labels)
                    }
                });
                match &cell {
                    Cell::Value { nmi, ca, secs } => {
                        row.push(format!("{:.2}", nmi.mean() * 100.0));
                        row.push(format!("{:.2}", ca.mean() * 100.0));
                        row.push(format!("{:.2}", secs.mean()));
                    }
                    Cell::NotFeasible(r) => row.extend([r.to_string(), r.to_string(), r.to_string()]),
                }
            }
            tp.row(row);
        }
        out.push_str(&format!("\n{table}: Approximate vs Exact K-nearest representatives\n"));
        out.push_str(&tp.render());
    }
    Ok(out)
}

/// Fig. 1: quantization quality of random / k-means / hybrid selection.
pub fn fig1(h: &Harness) -> Result<String> {
    let ds = h.dataset(Benchmark::Tb1m);
    let p = derive(&h.cfg, &ds).p.min(200);
    let mut tp = TablePrinter::new(vec![
        "strategy".into(),
        "quantization err (mean)".into(),
        "select time (s)".into(),
    ]);
    for (name, strat) in [
        ("random", SelectStrategy::Random),
        ("k-means", SelectStrategy::KmeansFull),
        ("hybrid", SelectStrategy::Hybrid { candidate_factor: 10 }),
    ] {
        let mut qe = Stats::default();
        let mut secs = Stats::default();
        for r in 0..h.cfg.runs.max(3) {
            let t0 = std::time::Instant::now();
            let reps =
                crate::affinity::select(&ds.x, strat, p, 20, h.cfg.seed + 77 * r as u64)?;
            secs.push(t0.elapsed().as_secs_f64());
            qe.push(crate::affinity::select::quantization_error(&ds.x, &reps));
        }
        tp.row(vec![name.into(), format!("{:.5}±{:.5}", qe.mean(), qe.std()), format!("{:.3}", secs.mean())]);
    }
    Ok(format!(
        "\nFig. 1 — representative selection quality on {} (n={}, p={p})\n{}",
        ds.name,
        ds.n(),
        tp.render()
    ))
}

/// Fig. 3: the coarse-to-fine KNR approximation — per-step candidate
/// counts and recall@K against the exact answer.
pub fn fig3(h: &Harness) -> Result<String> {
    let ds = h.dataset(Benchmark::Sf2m);
    let dp = derive(&h.cfg, &ds);
    let reps = crate::affinity::select(
        &ds.x,
        SelectStrategy::Hybrid { candidate_factor: 10 },
        dp.p,
        20,
        h.cfg.seed,
    )?;
    let mut tp = TablePrinter::new(vec![
        "K'".into(),
        "cands/step1 (z1)".into(),
        "cands/step2 (avg z2)".into(),
        "cands/step3 (K'+1)".into(),
        "recall@K".into(),
        "exact cands (p)".into(),
    ]);
    for factor in [2usize, 5, 10, 20] {
        let k_prime = factor * dp.k_nn;
        let index =
            crate::affinity::knr::KnrIndex::build(&reps, k_prime, 20, h.backend())?;
        let approx = index.approx_knr(&ds.x, dp.k_nn, h.backend());
        let exact = index.exact_knr(&ds.x, dp.k_nn, h.backend());
        let recall = crate::affinity::knr::recall_at_k(&approx, &exact, ds.n());
        let z2_avg = index.p() as f64 / index.z1() as f64;
        tp.row(vec![
            k_prime.to_string(),
            index.z1().to_string(),
            format!("{z2_avg:.1}"),
            (index.nbr_len).to_string(),
            format!("{recall:.4}"),
            index.p().to_string(),
        ]);
    }
    Ok(format!(
        "\nFig. 3 — approximate KNR candidate budget vs recall on {} (n={}, p={}, K={})\n{}",
        ds.name,
        ds.n(),
        dp.p,
        dp.k_nn,
        tp.render()
    ))
}

/// Fig. 5: dump 0.1% subsamples of the five synthetic datasets as CSV.
pub fn fig5(h: &Harness, out_dir: &std::path::Path) -> Result<String> {
    std::fs::create_dir_all(out_dir)?;
    let mut lines = String::from("\nFig. 5 — synthetic dataset subsamples (CSV)\n");
    for b in [Benchmark::Tb1m, Benchmark::Sf2m, Benchmark::Cc5m, Benchmark::Cg10m, Benchmark::Flower20m] {
        let ds = h.dataset(b);
        let sub = ds.subsample((ds.n() / 1000).max(500), h.cfg.seed);
        let path = out_dir.join(format!("fig5_{}.csv", b.name()));
        crate::data::loader::save_csv(&sub, &path)?;
        lines.push_str(&format!("  {} -> {} ({} points)\n", b.name(), path.display(), sub.n()));
    }
    Ok(lines)
}

/// Table 3: the dataset inventory.
pub fn datasets_table() -> String {
    let mut tp = TablePrinter::new(vec![
        "Dataset".into(),
        "#Object (paper)".into(),
        "Dimension".into(),
        "#Class".into(),
        "kind".into(),
    ]);
    for b in Benchmark::ALL {
        let (n, d, k) = b.paper_shape();
        tp.row(vec![
            b.name().into(),
            n.to_string(),
            d.to_string(),
            k.to_string(),
            if b.is_synthetic() { "synthetic".into() } else { "real (surrogate)".to_string() },
        ]);
    }
    format!("\nTable 3 — benchmark datasets\n{}", tp.render())
}

/// Dispatch a table by id ("t4".."t16", "fig1", "fig3", "fig5", "t3").
pub fn run_table(h: &Harness, id: &str) -> Result<String> {
    match id.to_ascii_lowercase().as_str() {
        "t3" | "datasets" => Ok(datasets_table()),
        "t4" | "t5" | "t6" | "t4-6" => spectral_tables(h),
        "t7" | "t8" | "t9" | "t7-9" => ensemble_tables(h),
        "t10" => sweep_p(h),
        "t11" => sweep_k(h),
        "t12" => sweep_m(h),
        "t13" | "t14" | "t13-14" => selection_tables(h),
        "t15" | "t16" | "t15-16" => knr_tables(h),
        "fig1" | "fig2" => fig1(h),
        "ablation-consensus" => super::ablations::consensus_ablation(h),
        "ablation-eig" => super::ablations::eig_ablation(h),
        "ablation-kernels" => super::ablations::kernel_ablation(h),
        "ablation-streaming" => super::ablations::streaming_ablation(h),
        "fig3" => fig3(h),
        "fig5" => fig5(h, std::path::Path::new("results")),
        other => Err(crate::Error::InvalidArg(format!("unknown table id '{other}'"))),
    }
}

/// Entry point shared by the `cargo bench` binaries: build a harness from
/// env overrides (USPEC_SCALE / USPEC_RUNS / USPEC_M / USPEC_BACKEND /
/// USPEC_SEED), run the given table ids, print, and persist to
/// `results/<out_name>.txt`.
pub fn bench_main(ids: &[&str], out_name: &str) {
    let mut cfg = RunConfig::default();
    let env_f64 = |k: &str| std::env::var(k).ok().and_then(|v| v.parse::<f64>().ok());
    let env_usize = |k: &str| std::env::var(k).ok().and_then(|v| v.parse::<usize>().ok());
    if let Some(v) = env_f64("USPEC_SCALE") {
        cfg.scale = v;
    }
    if let Some(v) = env_usize("USPEC_RUNS") {
        cfg.runs = v.max(1);
    }
    if let Some(v) = env_usize("USPEC_M") {
        cfg.m = v.max(2);
    }
    if let Some(v) = env_usize("USPEC_SEED") {
        cfg.seed = v as u64;
    }
    if let Ok(v) = std::env::var("USPEC_BACKEND") {
        if let Ok(b) = crate::config::BackendKind::parse(&v) {
            cfg.backend = b;
        }
    }
    let h = match Harness::new(cfg) {
        Ok(h) => h,
        Err(e) => {
            eprintln!("bench harness init failed: {e}");
            std::process::exit(2);
        }
    };
    let mut out = String::new();
    for id in ids {
        match run_table(&h, id) {
            Ok(text) => out.push_str(&text),
            Err(e) => {
                eprintln!("table {id} failed: {e}");
                std::process::exit(2);
            }
        }
    }
    println!("{out}");
    let _ = std::fs::create_dir_all("results");
    let path = format!("results/{out_name}.txt");
    if std::fs::write(&path, &out).is_ok() {
        eprintln!("[saved {path}]");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_harness() -> Harness {
        let mut cfg = RunConfig::default();
        cfg.scale = 0.0001; // floor sizes
        cfg.runs = 1;
        cfg.m = 3;
        cfg.k_min = 3;
        cfg.k_max = 6;
        cfg.p = 60;
        Harness::new(cfg).unwrap()
    }

    #[test]
    fn summary_rows_rank_math() {
        // 2 methods × 2 datasets; method 0 always better
        let mk = |v: f64| {
            let mut s = Stats::default();
            s.push(v);
            Cell::Value { nmi: s.clone(), ca: s.clone(), secs: s }
        };
        let cells = vec![vec![mk(0.9), mk(0.8)], vec![mk(0.5), Cell::na("N/A")]];
        let rows = summary_rows(
            &["A".into(), "B".into()],
            &cells,
            cell_metric_nmi,
        );
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0][1], "85.00"); // avg of method A
        assert_eq!(rows[0][2], "N/A"); // B lacks coverage
        assert_eq!(rows[2][1], "1.00"); // A always rank 1
    }

    #[test]
    fn fig1_runs() {
        let h = tiny_harness();
        let s = fig1(&h).unwrap();
        assert!(s.contains("hybrid"));
    }

    #[test]
    fn datasets_table_lists_all() {
        let s = datasets_table();
        for b in Benchmark::ALL {
            assert!(s.contains(b.name()));
        }
    }

    #[test]
    fn run_table_rejects_unknown() {
        let h = tiny_harness();
        assert!(run_table(&h, "t99").is_err());
    }
}
