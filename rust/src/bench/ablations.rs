//! Ablation benches for the design choices DESIGN.md calls out — beyond
//! the paper's own tables:
//!
//! * **consensus** — the paper's bipartite transfer cut (U-SENC §3.2.2)
//!   versus the classic hypergraph consensus family (CSPA/HGPA/MCLA [18],
//!   HBGF [22]) on identical U-SPEC ensembles.
//! * **eig** — Dense QL vs subspace iteration (`Auto`) vs LOBPCG on the
//!   reduced p×p transfer-cut problem: time and eigenvalue agreement.
//! * **kernels** — Gaussian/Laplacian/self-tuning/inverse-quadratic
//!   similarity kernels and σ rules in the U-SPEC pipeline (Eq. 6 ablated).
//! * **streaming** — the out-of-core two-pass pipeline vs in-memory
//!   U-SPEC: quality parity and resident-memory model.

use super::tables::Harness;
use super::TablePrinter;
use crate::affinity::kernel::{build_affinity_kernel, SigmaRule, SimKernel};
use crate::affinity::{build_affinity, knr::KnrIndex, select, SelectStrategy};
use crate::bench::runner::derive;
use crate::bipartite::{fast_eig_crossover, row_normalize, transfer_cut, EigSolver};
use crate::data::Benchmark;
use crate::ensemble_baselines::strehl;
use crate::kmeans::{kmeans, KmeansParams};
use crate::metrics::{ca, nmi};
use crate::usenc::{consensus_bipartite, generate_ensemble};
use crate::Result;

/// Consensus-function ablation: one shared U-SPEC ensemble per dataset,
/// five consensus functions. CSPA is O(N²) and capped accordingly.
pub fn consensus_ablation(h: &Harness) -> Result<String> {
    const CSPA_CAP: usize = 3000;
    let datasets = [Benchmark::Tb1m, Benchmark::Sf2m, Benchmark::Cc5m];
    let mut tp = TablePrinter::new(
        std::iter::once("Dataset".to_string())
            .chain(["TC(U-SENC)", "CSPA", "HGPA", "MCLA", "HBGF"].iter().flat_map(|m| {
                ["nmi", "ca", "s"].iter().map(move |s| format!("{m}:{s}"))
            }))
            .collect(),
    );
    // single-ensemble consensus comparisons are noisy (one unlucky
    // ensemble flips the ranking) — average over several ensembles.
    let rounds = h.cfg.runs.max(3);
    for &b in &datasets {
        let ds = b.generate(h.cfg.scale, h.cfg.seed ^ 0xDA7A);
        let dp = derive(&h.cfg, &ds);
        let mut params = crate::bench::runner::usenc_params(&h.cfg, &dp, ds.n());
        // Consensus stability needs the paper's m: with k_i ∈ [20,60]
        // fragments over a scaled-down n, small ensembles (m=8) leave the
        // bipartite spectral cut under-determined (NMI varies 0.06–0.97
        // per-ensemble on TB) while m=20 is consistently ≈0.98. The
        // hypergraph baselines are less m-sensitive — that contrast is
        // part of what this ablation shows, so fix m at the paper's 20.
        params.m = params.m.max(20);
        type F = fn(&crate::usenc::Ensemble, usize, u64) -> Result<Vec<u32>>;
        let tc_fn: F = |e, k, s| consensus_bipartite(e, k, EigSolver::Auto, s);
        let fns: [(&str, F); 5] = [
            ("TC", tc_fn),
            ("CSPA", strehl::cspa),
            ("HGPA", strehl::hgpa),
            ("MCLA", strehl::mcla),
            ("HBGF", strehl::hbgf),
        ];
        let mut sums = vec![(0.0f64, 0.0f64, 0.0f64, 0usize); fns.len()];
        for round in 0..rounds {
            let ens_seed = h.cfg.seed.wrapping_add(round as u64 * 7919);
            eprintln!(
                "[ablation-consensus] ensemble {}/{rounds} on {}",
                round + 1,
                ds.name
            );
            let ens = generate_ensemble(&ds.x, &params, ens_seed, h.backend())?;
            for (mi, (name, f)) in fns.iter().enumerate() {
                if *name == "CSPA" && ds.n() > CSPA_CAP {
                    continue;
                }
                let t0 = std::time::Instant::now();
                match f(&ens, dp.k, ens_seed ^ 0xC0) {
                    Ok(labels) => {
                        let s = &mut sums[mi];
                        s.0 += nmi(&labels, &ds.y);
                        s.1 += ca(&labels, &ds.y);
                        s.2 += t0.elapsed().as_secs_f64();
                        s.3 += 1;
                    }
                    Err(e) => eprintln!("  [warn] {name} failed: {e}"),
                }
            }
        }
        let mut row = vec![b.name().to_string()];
        for (mi, (name, _)) in fns.iter().enumerate() {
            let (n_sum, c_sum, t_sum, cnt) = sums[mi];
            if *name == "CSPA" && ds.n() > CSPA_CAP {
                row.extend(["N/A*".into(), "N/A*".into(), "N/A*".into()]);
            } else if cnt == 0 {
                row.extend(["err".into(), "err".into(), "err".into()]);
            } else {
                row.push(format!("{:.2}", n_sum / cnt as f64 * 100.0));
                row.push(format!("{:.2}", c_sum / cnt as f64 * 100.0));
                row.push(format!("{:.2}", t_sum / cnt as f64));
            }
        }
        tp.row(row);
    }
    Ok(format!(
        "\nAblation — consensus functions over identical U-SPEC ensembles \
         (m={}, mean over {rounds} ensembles, consensus time only; \
         N/A* = O(N²) method capped)\n{}",
        h.cfg.m.max(20),
        tp.render()
    ))
}

/// Eigen-solver ablation on the reduced p×p problem.
pub fn eig_ablation(h: &Harness) -> Result<String> {
    let b = Benchmark::Sf2m;
    let ds = b.generate(h.cfg.scale, h.cfg.seed ^ 0xDA7A);
    let k = ds.k;
    let mut tp = TablePrinter::new(vec![
        "p".into(),
        "route".into(),
        "dense:s".into(),
        "auto:s".into(),
        "auto:maxdiff".into(),
        "lobpcg:s".into(),
        "lobpcg:maxdiff".into(),
        "nmi(auto)".into(),
    ]);
    for &p in &[100usize, 200, 400, 800, 1200] {
        let p = p.min(ds.n() / 2);
        eprintln!("[ablation-eig] p={p} on {}", ds.name);
        let reps = select(
            &ds.x,
            SelectStrategy::Hybrid { candidate_factor: 10 },
            p,
            20,
            h.cfg.seed,
        )?;
        let index = KnrIndex::build(&reps, 10 * h.cfg.k_nn, 20, h.backend())?;
        let knr = index.approx_knr(&ds.x, h.cfg.k_nn.min(p), h.backend());
        let aff = build_affinity(ds.n(), p, h.cfg.k_nn.min(p), &knr);
        let time_solver = |s: EigSolver| -> Result<(f64, Vec<f64>, Vec<u32>)> {
            let t0 = std::time::Instant::now();
            let tc = transfer_cut(&aff.b, k, s, h.cfg.seed ^ 0xE1)?;
            let secs = t0.elapsed().as_secs_f64();
            let mut emb = tc.embedding;
            row_normalize(&mut emb);
            let km = kmeans(&emb, &KmeansParams { k, ..Default::default() }, 3)?;
            Ok((secs, tc.lambdas, km.labels))
        };
        let (sd, ld, _) = time_solver(EigSolver::Dense)?;
        let (sa, la, labels_a) = time_solver(EigSolver::Auto)?;
        let (sl, ll, _) = time_solver(EigSolver::Lobpcg)?;
        let maxdiff = |x: &[f64]| -> f64 {
            x.iter().zip(&ld).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max)
        };
        // which side of the dense/iterative crossover this shape lands on
        let route = if fast_eig_crossover(p, k) { "fast" } else { "dense" };
        tp.row(vec![
            p.to_string(),
            route.into(),
            format!("{sd:.4}"),
            format!("{sa:.4}"),
            format!("{:.2e}", maxdiff(&la)),
            format!("{sl:.4}"),
            format!("{:.2e}", maxdiff(&ll)),
            format!("{:.2}", nmi(&labels_a, &ds.y) * 100.0),
        ]);
    }
    Ok(format!(
        "\nAblation — reduced-problem eigensolver (dataset {}, k={k}; \
         route = side of fast_eig_crossover; maxdiff = max |λ−λ_dense|)\n{}",
        ds.name,
        tp.render()
    ))
}

/// Similarity-kernel ablation inside the U-SPEC pipeline.
pub fn kernel_ablation(h: &Harness) -> Result<String> {
    let kernels: [(&str, SimKernel); 6] = [
        ("gauss-mean", SimKernel::Gaussian(SigmaRule::MeanKnr)),
        ("gauss-median", SimKernel::Gaussian(SigmaRule::MedianKnr)),
        ("gauss-0.5x", SimKernel::Gaussian(SigmaRule::Scaled(0.5))),
        ("laplacian", SimKernel::Laplacian(SigmaRule::MeanKnr)),
        ("self-tuning", SimKernel::SelfTuning),
        ("inv-quad", SimKernel::InverseQuadratic { eps: 1.0 }),
    ];
    let mut tp = TablePrinter::new(
        std::iter::once("Dataset".to_string())
            .chain(kernels.iter().flat_map(|(tag, _)| {
                ["nmi", "ca"].iter().map(move |s| format!("{tag}:{s}"))
            }))
            .collect(),
    );
    for &b in &[Benchmark::Tb1m, Benchmark::Sf2m, Benchmark::Cc5m, Benchmark::Mnist] {
        let ds = b.generate(h.cfg.scale, h.cfg.seed ^ 0xDA7A);
        let dp = derive(&h.cfg, &ds);
        let reps = select(
            &ds.x,
            SelectStrategy::Hybrid { candidate_factor: 10 },
            dp.p,
            20,
            h.cfg.seed,
        )?;
        let index = KnrIndex::build(&reps, 10 * dp.k_nn, 20, h.backend())?;
        let knr = index.approx_knr(&ds.x, dp.k_nn, h.backend());
        let mut row = vec![b.name().to_string()];
        for (tag, kern) in &kernels {
            eprintln!("[ablation-kernels] {tag} on {}", ds.name);
            let aff = build_affinity_kernel(ds.n(), dp.p, dp.k_nn, &knr, *kern);
            let res = (|| -> Result<Vec<u32>> {
                let tc = transfer_cut(&aff.b, dp.k, EigSolver::Auto, h.cfg.seed ^ 0x4B)?;
                let mut emb = tc.embedding;
                row_normalize(&mut emb);
                Ok(kmeans(&emb, &KmeansParams { k: dp.k, ..Default::default() }, 3)?.labels)
            })();
            match res {
                Ok(labels) => {
                    row.push(format!("{:.2}", nmi(&labels, &ds.y) * 100.0));
                    row.push(format!("{:.2}", ca(&labels, &ds.y) * 100.0));
                }
                Err(e) => {
                    eprintln!("  [warn] {tag} failed: {e}");
                    row.extend(["err".into(), "err".into()]);
                }
            }
        }
        tp.row(row);
    }
    Ok(format!(
        "\nAblation — similarity kernel / σ rule in U-SPEC (paper default = gauss-mean)\n{}",
        tp.render()
    ))
}

/// Streaming (out-of-core) vs in-memory U-SPEC.
pub fn streaming_ablation(h: &Harness) -> Result<String> {
    let mut tp = TablePrinter::new(vec![
        "Dataset".into(),
        "inmem:nmi".into(),
        "inmem:s".into(),
        "stream:nmi".into(),
        "stream:s".into(),
        "resident/dense".into(),
    ]);
    let dir = std::env::temp_dir().join("uspec_stream_bench");
    std::fs::create_dir_all(&dir)?;
    for &b in &[Benchmark::Tb1m, Benchmark::Sf2m, Benchmark::Cg10m] {
        let ds = b.generate(h.cfg.scale, h.cfg.seed ^ 0xDA7A);
        let dp = derive(&h.cfg, &ds);
        let params = crate::bench::runner::uspec_params(&h.cfg, &dp);
        eprintln!("[ablation-streaming] {}", ds.name);
        let t0 = std::time::Instant::now();
        let mem = crate::uspec::uspec_with_backend(&ds.x, &params, h.cfg.seed, h.backend())?;
        let mem_s = t0.elapsed().as_secs_f64();

        let path = dir.join(format!("{}.bin", b.name().replace('/', "_")));
        let bin = crate::streaming::BinDataset::write_mat(&path, &ds.x)?;
        let sp = crate::streaming::StreamParams {
            chunk: 8192,
            shards: 1,
            base: params.clone(),
            ..Default::default()
        };
        let t1 = std::time::Instant::now();
        let st = crate::streaming::stream_uspec(&bin, &sp, h.cfg.seed, h.backend())?;
        let st_s = t1.elapsed().as_secs_f64();
        let dense = (bin.n() * bin.d() * 4) as u64;
        tp.row(vec![
            b.name().to_string(),
            format!("{:.2}", nmi(&mem.labels, &ds.y) * 100.0),
            format!("{mem_s:.2}"),
            format!("{:.2}", nmi(&st.labels, &ds.y) * 100.0),
            format!("{st_s:.2}"),
            format!("{:.2}", st.peak_bytes as f64 / dense as f64),
        ]);
        let _ = std::fs::remove_file(&path);
    }
    Ok(format!(
        "\nAblation — out-of-core streaming U-SPEC vs in-memory (resident/dense = \
         modeled resident peak over the dense N·d footprint; < 1 ⇒ smaller than \
         holding the data itself for d ≫ K)\n{}",
        tp.render()
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::RunConfig;

    fn tiny_harness() -> Harness {
        let cfg = RunConfig {
            scale: 0.0002,
            runs: 1,
            m: 3,
            k_min: 4,
            k_max: 8,
            ..Default::default()
        };
        Harness::new(cfg).unwrap()
    }

    #[test]
    fn consensus_ablation_renders() {
        let h = tiny_harness();
        let out = consensus_ablation(&h).unwrap();
        assert!(out.contains("CSPA"));
        assert!(out.contains("TB"));
        assert!(!out.contains("err"), "{out}");
    }

    #[test]
    fn kernel_ablation_renders() {
        let h = tiny_harness();
        let out = kernel_ablation(&h).unwrap();
        assert!(out.contains("self-tuning"));
        assert!(!out.contains("err"), "{out}");
    }
}
