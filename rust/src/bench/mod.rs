//! Benchmark harness: run statistics, the method dispatchers shared by the
//! CLI and the `cargo bench` table binaries, and the paper-table drivers
//! (one per Table 4–16 / Fig. 1/3/5). criterion is unavailable offline —
//! [`Stats`] provides warmup/repeat/mean±std measurement instead.

pub mod runner;
pub mod tables;
pub mod ablations;

/// Mean ± population-std over repeated runs.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub runs: Vec<f64>,
}

impl Stats {
    pub fn push(&mut self, v: f64) {
        self.runs.push(v);
    }

    pub fn mean(&self) -> f64 {
        if self.runs.is_empty() {
            return f64::NAN;
        }
        self.runs.iter().sum::<f64>() / self.runs.len() as f64
    }

    pub fn std(&self) -> f64 {
        let n = self.runs.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.runs.iter().map(|v| (v - m) * (v - m)).sum::<f64>() / n as f64).sqrt()
    }

    /// `82.41±1.20`-style cell, matching the paper's table formatting.
    pub fn fmt_pm(&self, scale: f64) -> String {
        if self.runs.is_empty() {
            return "-".into();
        }
        format!("{:.2}±{:.2}", self.mean() * scale, self.std() * scale)
    }
}

/// A measured table cell (NMI/CA in [0,1], seconds) or an N/A marker with
/// the reason the method is infeasible at paper scale.
#[derive(Debug, Clone)]
pub enum Cell {
    Value { nmi: Stats, ca: Stats, secs: Stats },
    NotFeasible(&'static str),
}

impl Cell {
    pub fn na(reason: &'static str) -> Cell {
        Cell::NotFeasible(reason)
    }
}

/// Simple fixed-width table printer (the paper-table look).
pub struct TablePrinter {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl TablePrinter {
    pub fn new(header: Vec<String>) -> TablePrinter {
        TablePrinter { header, rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate().take(ncols) {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i > 0 {
                    line.push_str("  ");
                }
                line.push_str(&format!("{:<width$}", c, width = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (ncols - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }
}

/// Timing helper: median of `iters` timed executions after `warmup` runs.
pub fn time_median<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> f64 {
    for _ in 0..warmup {
        f();
    }
    let mut times: Vec<f64> = (0..iters.max(1))
        .map(|_| {
            let t0 = std::time::Instant::now();
            f();
            t0.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_math() {
        let mut s = Stats::default();
        for v in [1.0, 2.0, 3.0] {
            s.push(v);
        }
        assert!((s.mean() - 2.0).abs() < 1e-12);
        assert!((s.std() - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
        assert_eq!(s.fmt_pm(1.0), "2.00±0.82");
        assert_eq!(Stats::default().fmt_pm(1.0), "-");
    }

    #[test]
    fn printer_aligns() {
        let mut t = TablePrinter::new(vec!["Dataset".into(), "NMI".into()]);
        t.row(vec!["TB-1M".into(), "95.86±0.48".into()]);
        t.row(vec!["Flower-20M".into(), "86.86".into()]);
        let r = t.render();
        assert!(r.contains("Dataset"));
        let lines: Vec<&str> = r.lines().collect();
        assert_eq!(lines.len(), 4);
    }

    #[test]
    fn time_median_positive() {
        let t = time_median(1, 3, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t >= 0.0);
    }
}
