//! Method dispatch for the evaluation harness: one entry point per method
//! name, the feasibility gates (paper-scale memory model + local time
//! guard), and the shared parameter derivation.

use crate::affinity::DistanceBackend;
use crate::baselines::{self, ClusteringOutput, SpectralMethod};
use crate::config::RunConfig;
use crate::data::{Benchmark, Dataset};
use crate::ensemble_baselines::{self, generate_kmeans_ensemble, EnsembleMethod};
use crate::kmeans::{kmeans, KmeansParams};
use crate::usenc::UsencParams;
use crate::uspec::{uspec_with_backend, UspecParams};
use crate::util::timer::PhaseTimer;
use crate::{Error, Result};

/// Parameters shared by the sub-matrix methods, derived per dataset:
/// the paper's p=1000 / K=5 clamped to the (possibly scaled-down) n.
#[derive(Debug, Clone)]
pub struct DerivedParams {
    pub k: usize,
    pub p: usize,
    pub k_nn: usize,
}

pub fn derive(cfg: &RunConfig, ds: &Dataset) -> DerivedParams {
    let k = cfg.k.unwrap_or(ds.k).max(1);
    let p = cfg.p.min(ds.n() / 2).max(k.min(ds.n()));
    DerivedParams { k, p, k_nn: cfg.k_nn.min(p) }
}

/// U-SPEC parameter block from a config.
pub fn uspec_params(_cfg: &RunConfig, dp: &DerivedParams) -> UspecParams {
    UspecParams {
        k: dp.k,
        p: dp.p,
        k_nn: dp.k_nn,
        ..Default::default()
    }
}

/// U-SENC parameter block. Base clusterers use a smaller p (the ensemble
/// amortizes approximation error — paper §3.2.1 keeps p=1000; at scaled n
/// the derive() clamp applies).
pub fn usenc_params(cfg: &RunConfig, dp: &DerivedParams, n: usize) -> UsencParams {
    let k_min = cfg.k_min.min(n.saturating_sub(1)).max(2);
    let k_max = cfg.k_max.clamp(k_min, n);
    UsencParams { k: dp.k, m: cfg.m, k_min, k_max, base: uspec_params(cfg, dp) }
}

/// Paper-scale feasibility: would this method fit the 64 GB budget at the
/// dataset's FULL (Table 3) size? Reproduces the N/A pattern of Tables 4–9.
pub fn feasible_at_paper_scale(
    method_mem: impl Fn(u64, u64) -> u64,
    bench: Option<Benchmark>,
    budget: u64,
) -> bool {
    match bench {
        Some(b) => {
            let (n, d, _) = b.paper_shape();
            method_mem(n as u64, d as u64) <= budget
        }
        None => true, // user datasets: run whatever they give us
    }
}

/// Local time guard: O(N²)+ methods are capped on this (single-core) box
/// regardless of the simulated budget.
pub fn local_cap(method_name: &str) -> usize {
    match method_name {
        "SC" | "ESCG" | "EAC" | "WCT" => 2200,
        _ => usize::MAX,
    }
}

/// Run one spectral-track method (Tables 4–6). Returns labels + timing.
pub fn run_spectral(
    method: SpectralMethod,
    ds: &Dataset,
    cfg: &RunConfig,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<ClusteringOutput> {
    let dp = derive(cfg, ds);
    match method {
        SpectralMethod::Kmeans => {
            let mut timer = PhaseTimer::new();
            let r = timer.time("kmeans", || {
                kmeans(&ds.x, &KmeansParams { k: dp.k, ..Default::default() }, seed)
            })?;
            Ok(ClusteringOutput::new(r.labels, timer))
        }
        SpectralMethod::Sc => baselines::sc::sc(&ds.x, dp.k, dp.k_nn.max(5), seed),
        SpectralMethod::Escg => {
            baselines::escg::escg(&ds.x, dp.k, dp.p.min(ds.n() / 4).max(dp.k), dp.k_nn.max(5), seed)
        }
        SpectralMethod::Nystrom => baselines::nystrom::nystrom(&ds.x, dp.k, dp.p, seed),
        SpectralMethod::LscK => {
            baselines::lsc::lsc(&ds.x, dp.k, dp.p, dp.k_nn, baselines::lsc::LscVariant::K, seed)
        }
        SpectralMethod::LscR => {
            baselines::lsc::lsc(&ds.x, dp.k, dp.p, dp.k_nn, baselines::lsc::LscVariant::R, seed)
        }
        SpectralMethod::FastEsc => baselines::fastesc::fastesc(&ds.x, dp.k, dp.p, seed),
        SpectralMethod::EulerSc => baselines::eulersc::eulersc(&ds.x, dp.k, 1.1, seed),
        SpectralMethod::Uspec => {
            let res = uspec_with_backend(&ds.x, &uspec_params(cfg, &dp), seed, backend)?;
            Ok(ClusteringOutput::new(res.labels, res.timer))
        }
        SpectralMethod::Usenc => {
            let params = usenc_params(cfg, &dp, ds.n());
            let res = crate::coordinator::usenc_coordinated(
                &ds.x,
                &params,
                seed,
                backend,
                cfg.workers,
                None,
            )?;
            Ok(ClusteringOutput::new(res.labels, res.timer))
        }
    }
}

/// Run one ensemble-track method (Tables 7–9). Ensemble generation (by
/// k-means, per the baselines' protocol) is timed as part of the method.
pub fn run_ensemble(
    method: EnsembleMethod,
    ds: &Dataset,
    cfg: &RunConfig,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<ClusteringOutput> {
    let dp = derive(cfg, ds);
    if method == EnsembleMethod::Usenc {
        let params = usenc_params(cfg, &dp, ds.n());
        let res =
            crate::coordinator::usenc_coordinated(&ds.x, &params, seed, backend, cfg.workers, None)?;
        return Ok(ClusteringOutput::new(res.labels, res.timer));
    }
    let mut timer = PhaseTimer::new();
    let k_min = cfg.k_min.min(ds.n().saturating_sub(1)).max(2);
    let k_max = cfg.k_max.clamp(k_min, ds.n());
    let ens = timer.time("generation", || {
        generate_kmeans_ensemble(&ds.x, cfg.m, k_min, k_max, seed)
    })?;
    let out = match method {
        EnsembleMethod::Eac => ensemble_baselines::eac::eac(&ens, dp.k)?,
        EnsembleMethod::Wct => ensemble_baselines::wct::wct(&ens, dp.k)?,
        EnsembleMethod::Kcc => ensemble_baselines::kcc::kcc(&ens, dp.k, seed ^ 0x1)?,
        EnsembleMethod::Ptgp => ensemble_baselines::ptgp::ptgp(&ens, dp.k, seed ^ 0x2)?,
        EnsembleMethod::Ecc => ensemble_baselines::ecc::ecc(&ens, dp.k, seed ^ 0x3)?,
        EnsembleMethod::Sec => ensemble_baselines::sec::sec(&ens, dp.k, seed ^ 0x4)?,
        EnsembleMethod::Lwgp => ensemble_baselines::lwgp::lwgp(&ens, dp.k, seed ^ 0x5)?,
        EnsembleMethod::Usenc => unreachable!(),
    };
    timer.merge(&out.timer);
    Ok(ClusteringOutput::new(out.labels, timer))
}

/// Run any method by name (CLI entry point).
pub fn run_by_name(
    name: &str,
    ds: &Dataset,
    cfg: &RunConfig,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<ClusteringOutput> {
    if let Some(m) = SpectralMethod::from_name(name) {
        return run_spectral(m, ds, cfg, seed, backend);
    }
    if let Some(m) = EnsembleMethod::from_name(name) {
        return run_ensemble(m, ds, cfg, seed, backend);
    }
    Err(Error::InvalidArg(format!(
        "unknown method '{name}' (spectral: {:?}; ensemble: {:?})",
        SpectralMethod::ALL.map(|m| m.name()),
        EnsembleMethod::ALL.map(|m| m.name())
    )))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::metrics::nmi;

    #[test]
    fn dispatch_every_spectral_method() {
        let ds = Benchmark::Tb1m.generate(0.0006, 3); // ~600 points
        let cfg = RunConfig { p: 80, m: 3, k_min: 4, k_max: 8, ..Default::default() };
        for m in SpectralMethod::ALL {
            let out = run_spectral(m, &ds, &cfg, 7, &NativeBackend)
                .unwrap_or_else(|e| panic!("{} failed: {e}", m.name()));
            assert_eq!(out.labels.len(), ds.n(), "{}", m.name());
            let score = nmi(&out.labels, &ds.y);
            assert!(score.is_finite(), "{}", m.name());
        }
    }

    #[test]
    fn dispatch_every_ensemble_method() {
        let ds = Benchmark::Tb1m.generate(0.0005, 4);
        let cfg = RunConfig { p: 60, m: 4, k_min: 4, k_max: 8, ..Default::default() };
        for m in EnsembleMethod::ALL {
            let out = run_ensemble(m, &ds, &cfg, 9, &NativeBackend)
                .unwrap_or_else(|e| panic!("{} failed: {e}", m.name()));
            assert_eq!(out.labels.len(), ds.n(), "{}", m.name());
        }
    }

    #[test]
    fn unknown_method_rejected() {
        let ds = Benchmark::Tb1m.generate(0.0005, 5);
        let cfg = RunConfig::default();
        assert!(run_by_name("nope", &ds, &cfg, 1, &NativeBackend).is_err());
        assert!(run_by_name("U-SPEC", &ds, &cfg, 1, &NativeBackend).is_ok());
    }

    #[test]
    fn derive_clamps() {
        let ds = Benchmark::Tb1m.generate(0.0005, 6);
        let cfg = RunConfig { p: 100_000, ..Default::default() };
        let dp = derive(&cfg, &ds);
        assert!(dp.p <= ds.n() / 2);
        assert!(dp.k_nn <= dp.p);
    }
}
