//! **U-SENC** — Ultra-Scalable Ensemble Clustering (paper §3.2).
//!
//! Ensemble generation runs m diverse U-SPEC base clusterers (independent
//! hybrid representative sets; random per-clusterer cluster count
//! kⁱ ∈ [k_min, k_max]); the consensus function builds the object×cluster
//! bipartite graph B̃ (exactly m ones per row) and partitions it with the
//! same transfer cut. Complexity O(N·m·p^½·d) time, O(N·p^½) memory.
//!
//! Base clusterers can be driven sequentially ([`usenc`]), by the
//! leader/worker scheduler in [`crate::coordinator`], or with an adaptive
//! ensemble size ([`adaptive::usenc_adaptive`]).

pub mod adaptive;

use crate::affinity::DistanceBackend;
use crate::bipartite::{transfer_cut, EigSolver};
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::{Csr, Mat};
use crate::uspec::{uspec_with_backend, UspecParams};
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// An ensemble of base clusterings over the same N objects.
#[derive(Debug, Clone, Default)]
pub struct Ensemble {
    /// labelings[i] has length N with labels densified to 0..kᵢ-1.
    pub labelings: Vec<Vec<u32>>,
}

impl Ensemble {
    pub fn m(&self) -> usize {
        self.labelings.len()
    }

    pub fn n(&self) -> usize {
        self.labelings.first().map(|l| l.len()).unwrap_or(0)
    }

    pub fn push(&mut self, labels: Vec<u32>) {
        if let Some(first) = self.labelings.first() {
            assert_eq!(first.len(), labels.len(), "ensemble labelings must align");
        }
        self.labelings.push(labels);
    }

    /// Per-base-clustering cluster counts.
    pub fn ks(&self) -> Vec<usize> {
        self.labelings
            .iter()
            .map(|l| l.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0))
            .collect()
    }

    /// Total number of clusters k_c = Σ kᵢ.
    pub fn total_clusters(&self) -> usize {
        self.ks().iter().sum()
    }

    /// The object×cluster incidence matrix B̃ (N×k_c, one 1 per base
    /// clustering per row — Eq. 18–19). The N×m column array is filled
    /// pool-parallel over row bands from a single `ks()` resolution (the
    /// cluster-count scan is O(N·m) itself, so recomputing it per use was
    /// measurable at ensemble scale).
    pub fn incidence(&self) -> Csr {
        let n = self.n();
        let m = self.m();
        let ks = self.ks();
        let kc: usize = ks.iter().sum();
        // column offsets per base clustering
        let mut offsets = vec![0usize; m];
        let mut acc = 0;
        for (i, &k) in ks.iter().enumerate() {
            offsets[i] = acc;
            acc += k;
        }
        let mut cols = vec![0u32; n * m];
        let vals = vec![1.0f64; n * m];
        par::par_for_chunks(&mut cols, m * 1024, |start, chunk| {
            let row0 = start / m;
            let rows = chunk.len() / m;
            for r in 0..rows {
                let i = row0 + r;
                let orow = &mut chunk[r * m..(r + 1) * m];
                for (b, v) in orow.iter_mut().enumerate() {
                    *v = (offsets[b] + self.labelings[b][i] as usize) as u32;
                }
            }
        });
        Csr::from_uniform(n, kc, m, cols, vals)
    }
}

/// U-SENC hyper-parameters.
#[derive(Debug, Clone)]
pub struct UsencParams {
    /// Number of clusters in the consensus output.
    pub k: usize,
    /// Ensemble size m (paper default 20).
    pub m: usize,
    /// Base-clusterer cluster-count range [k_min, k_max] (paper: [20, 60]).
    pub k_min: usize,
    pub k_max: usize,
    /// Base U-SPEC parameters (k is overridden per clusterer).
    pub base: UspecParams,
}

impl Default for UsencParams {
    fn default() -> Self {
        UsencParams { k: 2, m: 20, k_min: 20, k_max: 60, base: UspecParams::default() }
    }
}

/// U-SENC output.
#[derive(Debug, Clone)]
pub struct UsencResult {
    pub labels: Vec<u32>,
    pub ensemble: Ensemble,
    pub timer: PhaseTimer,
}

/// Draw the i-th base clusterer's cluster count kⁱ (Eq. 14), clamped to n.
pub fn draw_base_k(rng: &mut Rng, k_min: usize, k_max: usize, n: usize) -> usize {
    let (lo, hi) = (k_min.min(k_max), k_max.max(k_min));
    let tau = rng.f64();
    let k = ((tau * (hi - lo) as f64).floor() as usize + lo).max(2);
    k.min(n)
}

/// Generate the ensemble of m base clusterings via m U-SPEC runs.
pub fn generate_ensemble(
    x: &Mat,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<Ensemble> {
    let mut rng = Rng::new(seed);
    let mut ens = Ensemble::default();
    for i in 0..params.m {
        let ki = draw_base_k(&mut rng, params.k_min, params.k_max, x.rows);
        let base = UspecParams { k: ki, ..params.base.clone() };
        let job_seed = rng.fork(i as u64).next_u64();
        let res = uspec_with_backend(x, &base, job_seed, backend)?;
        ens.push(res.labels);
    }
    Ok(ens)
}

/// Consensus function: partition the object×cluster bipartite graph
/// (§3.2.2). Usable with any ensemble (also the k-means ensembles of the
/// baseline methods).
pub fn consensus_bipartite(
    ensemble: &Ensemble,
    k: usize,
    solver: EigSolver,
    seed: u64,
) -> Result<(Vec<u32>, Mat)> {
    ensure_arg!(ensemble.m() >= 1, "consensus: empty ensemble");
    let n = ensemble.n();
    ensure_arg!(k >= 1 && k <= n, "consensus: bad k={k}");
    let b = ensemble.incidence();
    ensure_arg!(k <= b.cols, "consensus: k={k} > total clusters {}", b.cols);
    let tc = transfer_cut(&b, k, solver, seed)?;
    let mut emb = tc.embedding.clone();
    crate::bipartite::row_normalize(&mut emb);
    let km = kmeans(
        &emb,
        &KmeansParams { k, max_iter: 100, ..Default::default() },
        seed ^ 0xD15C,
    )?;
    Ok((km.labels, tc.embedding))
}

/// Full U-SENC: ensemble generation + bipartite consensus (sequential
/// base-clusterer execution; see [`crate::coordinator`] for the scheduled
/// parallel path).
pub fn usenc(
    x: &Mat,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<UsencResult> {
    let mut timer = PhaseTimer::new();
    let ensemble = timer.time("generation", || generate_ensemble(x, params, seed, backend))?;
    let (labels, _emb) = timer.time("consensus", || {
        consensus_bipartite(&ensemble, params.k, params.base.solver, seed ^ 0xC075)
    })?;
    Ok(UsencResult { labels, ensemble, timer })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::data::synthetic::{concentric_circles, two_moons};
    use crate::metrics::nmi;

    fn small_params(k: usize, m: usize, p: usize) -> UsencParams {
        UsencParams {
            k,
            m,
            k_min: 5,
            k_max: 12,
            base: UspecParams { p, ..Default::default() },
        }
    }

    #[test]
    fn incidence_structure() {
        let mut ens = Ensemble::default();
        ens.push(vec![0, 0, 1, 1]);
        ens.push(vec![0, 1, 1, 2]);
        assert_eq!(ens.m(), 2);
        assert_eq!(ens.ks(), vec![2, 3]);
        assert_eq!(ens.total_clusters(), 5);
        let b = ens.incidence();
        assert_eq!(b.rows, 4);
        assert_eq!(b.cols, 5);
        assert_eq!(b.nnz(), 8); // exactly m per row
        // object 3: cluster 1 of base 0 (col 1), cluster 2 of base 1 (col 2+2=4)
        assert_eq!(b.row(3).0, &[1u32, 4u32]);
        // column sums = cluster sizes
        assert_eq!(b.col_sums(), vec![2.0, 2.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn consensus_label_permutation_invariant() {
        let mut a = Ensemble::default();
        a.push(vec![0, 0, 0, 1, 1, 1, 2, 2, 2]);
        a.push(vec![0, 0, 1, 1, 1, 2, 2, 2, 0]);
        let mut b = Ensemble::default();
        // same partitions, permuted labels
        b.push(vec![2, 2, 2, 0, 0, 0, 1, 1, 1]);
        b.push(vec![1, 1, 2, 2, 2, 0, 0, 0, 1]);
        let (la, _) = consensus_bipartite(&a, 3, EigSolver::Dense, 5).unwrap();
        let (lb, _) = consensus_bipartite(&b, 3, EigSolver::Dense, 5).unwrap();
        assert!((nmi(&la, &lb) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recovers_moons() {
        let ds = two_moons(1200, 0.06, 3);
        let res = usenc(&ds.x, &small_params(2, 6, 120), 17, &NativeBackend).unwrap();
        let score = nmi(&res.labels, &ds.y);
        assert!(score > 0.85, "nmi={score}");
        assert_eq!(res.ensemble.m(), 6);
    }

    #[test]
    fn usenc_at_least_as_good_as_median_base_on_rings() {
        let ds = concentric_circles(1500, 5);
        let params = small_params(3, 8, 150);
        let res = usenc(&ds.x, &params, 23, &NativeBackend).unwrap();
        let consensus_nmi = nmi(&res.labels, &ds.y);
        // The robustness claim: the consensus must beat the average base
        // clustering (whose k is drawn in [5,12] ≠ 3).
        let mean_base: f64 = res
            .ensemble
            .labelings
            .iter()
            .map(|l| nmi(l, &ds.y))
            .sum::<f64>()
            / res.ensemble.m() as f64;
        assert!(consensus_nmi > 0.7, "consensus nmi={consensus_nmi}");
        assert!(
            consensus_nmi > mean_base,
            "consensus {consensus_nmi} should beat mean base {mean_base}"
        );
    }

    #[test]
    fn draw_base_k_in_range() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let k = draw_base_k(&mut rng, 20, 60, 10_000);
            assert!((20..=60).contains(&k));
        }
        // clamped by n
        let k = draw_base_k(&mut rng, 20, 60, 10);
        assert!(k <= 10);
    }

    #[test]
    fn rejects_empty_ensemble() {
        let ens = Ensemble::default();
        assert!(consensus_bipartite(&ens, 2, EigSolver::Dense, 1).is_err());
    }
}
