//! **U-SENC** — Ultra-Scalable Ensemble Clustering (paper §3.2).
//!
//! Ensemble generation runs m diverse U-SPEC base clusterers (independent
//! hybrid representative sets; random per-clusterer cluster count
//! kⁱ ∈ [k_min, k_max]); the consensus function builds the object×cluster
//! bipartite graph B̃ (exactly m ones per row) and partitions it with the
//! same transfer cut. Complexity O(N·m·p^½·d) time, O(N·p^½) memory.
//!
//! Every entry point takes a [`DataSource`], so the ensemble runs
//! in-memory (`&Mat`) and out-of-core (`&BinDataset`) through the same
//! staged engine ([`crate::pipeline`]). The m per-clusterer candidate
//! sweeps are amortized into shared passes over the data
//! ([`Pipeline::sweep_candidates`]) — one pass per group of
//! [`sweep_group_size`] jobs (usually one pass total; the grouping only
//! bounds the m·p′·d candidate residency under [`SWEEP_BUDGET_BYTES`]).
//! Each base clusterer then streams its own KNR pass, so the resident
//! peak stays at single-clusterer scale plus one sweep group's
//! candidates.
//!
//! Base clusterers can be driven sequentially ([`usenc`]), by the
//! leader/worker scheduler in [`crate::coordinator`], or with an adaptive
//! ensemble size ([`adaptive::usenc_adaptive`]).

pub mod adaptive;

use crate::affinity::DistanceBackend;
use crate::bipartite::EigSolver;
use crate::linalg::Csr;
use crate::pipeline::{CandidateSet, DataSource, ExecOpts, Pipeline, SelectStage};
use crate::runtime::model::{UsencBase, UsencModel};
use crate::uspec::UspecParams;
use crate::util::json::Json;
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// An ensemble of base clusterings over the same N objects.
#[derive(Debug, Clone, Default)]
pub struct Ensemble {
    /// labelings[i] has length N with labels densified to 0..kᵢ-1.
    pub labelings: Vec<Vec<u32>>,
}

impl Ensemble {
    pub fn m(&self) -> usize {
        self.labelings.len()
    }

    pub fn n(&self) -> usize {
        self.labelings.first().map(|l| l.len()).unwrap_or(0)
    }

    pub fn push(&mut self, labels: Vec<u32>) {
        if let Some(first) = self.labelings.first() {
            assert_eq!(first.len(), labels.len(), "ensemble labelings must align");
        }
        self.labelings.push(labels);
    }

    /// Per-base-clustering cluster counts.
    pub fn ks(&self) -> Vec<usize> {
        self.labelings
            .iter()
            .map(|l| l.iter().copied().max().map(|m| m as usize + 1).unwrap_or(0))
            .collect()
    }

    /// Total number of clusters k_c = Σ kᵢ.
    pub fn total_clusters(&self) -> usize {
        self.ks().iter().sum()
    }

    /// The object×cluster incidence matrix B̃ (N×k_c, one 1 per base
    /// clustering per row — Eq. 18–19). The N×m column array is filled
    /// pool-parallel over row bands from a single `ks()` resolution (the
    /// cluster-count scan is O(N·m) itself, so recomputing it per use was
    /// measurable at ensemble scale).
    pub fn incidence(&self) -> Csr {
        let n = self.n();
        let m = self.m();
        let ks = self.ks();
        let kc: usize = ks.iter().sum();
        // column offsets per base clustering
        let mut offsets = vec![0usize; m];
        let mut acc = 0;
        for (i, &k) in ks.iter().enumerate() {
            offsets[i] = acc;
            acc += k;
        }
        let mut cols = vec![0u32; n * m];
        let vals = vec![1.0f64; n * m];
        par::par_for_chunks(&mut cols, m * 1024, |start, chunk| {
            let row0 = start / m;
            let rows = chunk.len() / m;
            for r in 0..rows {
                let i = row0 + r;
                let orow = &mut chunk[r * m..(r + 1) * m];
                for (b, v) in orow.iter_mut().enumerate() {
                    *v = (offsets[b] + self.labelings[b][i] as usize) as u32;
                }
            }
        });
        Csr::from_uniform(n, kc, m, cols, vals)
    }
}

/// U-SENC hyper-parameters.
#[derive(Debug, Clone)]
pub struct UsencParams {
    /// Number of clusters in the consensus output.
    pub k: usize,
    /// Ensemble size m (paper default 20).
    pub m: usize,
    /// Base-clusterer cluster-count range [k_min, k_max] (paper: [20, 60]).
    pub k_min: usize,
    pub k_max: usize,
    /// Base U-SPEC parameters (k is overridden per clusterer).
    pub base: UspecParams,
}

impl Default for UsencParams {
    fn default() -> Self {
        UsencParams { k: 2, m: 20, k_min: 20, k_max: 60, base: UspecParams::default() }
    }
}

/// U-SENC output.
#[derive(Debug, Clone)]
pub struct UsencResult {
    pub labels: Vec<u32>,
    pub ensemble: Ensemble,
    pub timer: PhaseTimer,
}

/// Draw the i-th base clusterer's cluster count kⁱ uniformly from the
/// **inclusive** range [k_min, k_max] (Eq. 14), floored at 2 and clamped
/// to n.
pub fn draw_base_k(rng: &mut Rng, k_min: usize, k_max: usize, n: usize) -> usize {
    let (lo, hi) = (k_min.min(k_max), k_max.max(k_min));
    let k = (lo + rng.usize(hi - lo + 1)).max(2);
    k.min(n)
}

/// One base-clusterer job, fully specified before any worker starts.
#[derive(Debug, Clone)]
pub struct JobSpec {
    pub id: usize,
    pub k: usize,
    pub seed: u64,
}

/// The ensemble's job stream: kⁱ draws and per-job seeds. Every driver —
/// sequential ([`generate_ensemble`]), scheduled
/// ([`crate::coordinator::run_base_clusterers`]) and adaptive
/// ([`adaptive::usenc_adaptive`]) — derives its jobs from this one
/// function, so their ensembles are prefixes of each other by
/// construction. Job `i` depends only on draws before it, so deriving
/// more jobs never changes an earlier job.
pub fn derive_jobs(params: &UsencParams, n: usize, seed: u64) -> Vec<JobSpec> {
    let mut rng = Rng::new(seed);
    (0..params.m)
        .map(|i| {
            let k = draw_base_k(&mut rng, params.k_min, params.k_max, n);
            let seed = rng.fork(i as u64).next_u64();
            JobSpec { id: i, k, seed }
        })
        .collect()
}

/// Per-job U-SPEC parameters (the base params with the job's k).
pub fn job_params(params: &UsencParams, job: &JobSpec) -> UspecParams {
    UspecParams { k: job.k, ..params.base.clone() }
}

/// Byte budget for candidate sets held resident during a shared sweep.
/// A sweep keeps every in-flight job's p′×d reservoir in memory at once,
/// so ensembles are swept in groups of at most
/// [`sweep_group_size`] jobs — amortizing disk passes without letting the
/// m·p′·d candidate term outgrow the single-clusterer working set the
/// out-of-core path promises.
pub const SWEEP_BUDGET_BYTES: usize = 256 << 20;

/// How many jobs one shared candidate sweep may carry for a source of
/// `n`×`d` under [`SWEEP_BUDGET_BYTES`] (at least 1 — a single job's
/// candidates are the pipeline's own working set).
pub fn sweep_group_size(params: &UsencParams, n: usize, d: usize) -> usize {
    // Upper bound on a job's candidate rows: clamping can raise p to the
    // job's kⁱ ≤ k_max, so model with the larger of base-p and k_max.
    let p_bound = params.base.p.max(params.k_max).min(n.max(1));
    let stage = SelectStage {
        p: p_bound,
        ..SelectStage::from_params(&params.base)
    };
    let per_job = stage.candidate_size(n).max(1) * d.max(1) * 4;
    (SWEEP_BUDGET_BYTES / per_job).max(1)
}

/// Sweep the candidate reservoirs of `jobs` in one pass over the source
/// (None when the selection strategy cannot sweep, i.e. k-means-full —
/// those jobs select per-run from the resident matrix instead).
pub fn sweep_job_candidates(
    pipe: &Pipeline,
    source: &dyn DataSource,
    params: &UsencParams,
    jobs: &[JobSpec],
) -> Result<Option<Vec<CandidateSet>>> {
    let n = source.n();
    if jobs.is_empty() || !SelectStage::from_params(&params.base).sweeps() {
        return Ok(None);
    }
    let specs: Vec<(usize, u64)> = jobs
        .iter()
        .map(|job| {
            let clamped = job_params(params, job).clamped(n);
            let stage = SelectStage::from_params(&clamped);
            (stage.candidate_size(n), Pipeline::selection_seed(job.seed))
        })
        .collect();
    pipe.sweep_candidates(source, &specs).map(Some)
}

/// Run one job through the engine, resuming from its swept candidates
/// when available.
pub fn run_job(
    pipe: &Pipeline,
    source: &dyn DataSource,
    params: &UsencParams,
    job: &JobSpec,
    cand: Option<&CandidateSet>,
) -> Result<Vec<u32>> {
    let base = job_params(params, job);
    let res = match cand {
        Some(c) => pipe.run_from_candidates(source, &base, job.seed, c)?,
        None => pipe.run(source, &base, job.seed)?,
    };
    Ok(res.labels)
}

/// Generate the ensemble of m base clusterings via m U-SPEC runs over any
/// source, with all m candidate sweeps amortized into one data pass.
pub fn generate_ensemble(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<Ensemble> {
    generate_ensemble_opts(source, params, seed, backend, ExecOpts::default())
}

/// [`generate_ensemble`] with an explicit chunk size (rows resident per
/// sweep step). The chunk never changes the labels — only the working-set
/// size of the passes over the source.
pub fn generate_ensemble_chunked(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    chunk: usize,
) -> Result<Ensemble> {
    generate_ensemble_opts(source, params, seed, backend, ExecOpts::with_chunk(chunk))
}

/// [`generate_ensemble`] with explicit execution knobs ([`ExecOpts`]):
/// chunk size and shard count for every pass over the source. Both are
/// operational — the labels never change; with `shards > 1` each base
/// clusterer's KNR pass walks the source shard-parallel with
/// double-buffered prefetch.
pub fn generate_ensemble_opts(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    opts: ExecOpts,
) -> Result<Ensemble> {
    let pipe = Pipeline::new(backend).with_opts(opts);
    let jobs = derive_jobs(params, source.n(), seed);
    let group = sweep_group_size(params, source.n(), source.d());
    let mut ens = Ensemble::default();
    for group_jobs in jobs.chunks(group.max(1)) {
        let cands = sweep_job_candidates(&pipe, source, params, group_jobs)?;
        for (i, job) in group_jobs.iter().enumerate() {
            let labels = run_job(&pipe, source, params, job, cands.as_ref().map(|c| &c[i]))?;
            ens.push(labels);
        }
    }
    Ok(ens)
}

/// Consensus function: partition the object×cluster bipartite graph
/// (§3.2.2). Usable with any ensemble (also the k-means ensembles of the
/// baseline methods).
pub fn consensus_bipartite(
    ensemble: &Ensemble,
    k: usize,
    solver: EigSolver,
    seed: u64,
) -> Result<Vec<u32>> {
    ensure_arg!(ensemble.m() >= 1, "consensus: empty ensemble");
    let n = ensemble.n();
    ensure_arg!(k >= 1 && k <= n, "consensus: bad k={k}");
    let b = ensemble.incidence();
    ensure_arg!(k <= b.cols, "consensus: k={k} > total clusters {}", b.cols);
    let stage = crate::pipeline::PartitionStage { k, solver, kmeans_iters: 100 };
    let mut timer = PhaseTimer::new();
    stage.run_labels(&b, k, seed, seed ^ 0xD15C, &mut timer)
}

/// Full U-SENC: ensemble generation + bipartite consensus (sequential
/// base-clusterer execution; see [`crate::coordinator`] for the scheduled
/// parallel path). Runs out-of-core when `source` is not resident.
pub fn usenc(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<UsencResult> {
    usenc_opts(source, params, seed, backend, ExecOpts::default())
}

/// [`usenc`] with an explicit chunk size for the data sweeps.
pub fn usenc_chunked(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    chunk: usize,
) -> Result<UsencResult> {
    usenc_opts(source, params, seed, backend, ExecOpts::with_chunk(chunk))
}

/// [`usenc`] with explicit execution knobs (chunk size + shard count).
pub fn usenc_opts(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    opts: ExecOpts,
) -> Result<UsencResult> {
    let mut timer = PhaseTimer::new();
    let ensemble = timer.time("generation", || {
        generate_ensemble_opts(source, params, seed, backend, opts)
    })?;
    let labels = timer.time("consensus", || {
        consensus_bipartite(&ensemble, params.k, params.base.solver, seed ^ 0xC075)
    })?;
    Ok(UsencResult { labels, ensemble, timer })
}

/// A fitted ensemble: the usual result plus the persistable consensus
/// model ([`crate::runtime::model::UsencModel`]) for out-of-sample
/// assignment ([`crate::pipeline::Pipeline::assign_consensus`]).
#[derive(Debug, Clone)]
pub struct UsencFitOutput {
    pub result: UsencResult,
    pub model: UsencModel,
}

/// [`usenc_opts`] that additionally captures a persistable [`UsencModel`]:
/// every base clusterer's U-SPEC model (representatives, σ, per-rep
/// labels) plus a `kⁱ × k` vote table counting the fit-time (base label,
/// consensus label) co-occurrences that weight the consensus assignment
/// vote. The labels are byte-identical to [`usenc_opts`] for the same
/// `(params, seed, opts)` — the base runs go through
/// [`Pipeline::fit`]/[`Pipeline::fit_from_candidates`], which share the
/// exact stage code and seed schedule with the plain runs.
pub fn usenc_fit(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    opts: ExecOpts,
) -> Result<UsencFitOutput> {
    let mut timer = PhaseTimer::new();
    let pipe = Pipeline::new(backend).with_opts(opts);
    let jobs = derive_jobs(params, source.n(), seed);
    let group = sweep_group_size(params, source.n(), source.d());
    let mut ensemble = Ensemble::default();
    let mut base_models = Vec::with_capacity(jobs.len());
    timer.time("generation", || -> Result<()> {
        for group_jobs in jobs.chunks(group.max(1)) {
            let cands = sweep_job_candidates(&pipe, source, params, group_jobs)?;
            for (i, job) in group_jobs.iter().enumerate() {
                let base = job_params(params, job);
                let fit = match cands.as_ref().map(|c| &c[i]) {
                    Some(c) => pipe.fit_from_candidates(source, &base, job.seed, c)?,
                    None => pipe.fit(source, &base, job.seed)?,
                };
                ensemble.push(fit.result.labels);
                base_models.push(fit.model);
            }
        }
        Ok(())
    })?;
    let labels = timer.time("consensus", || {
        consensus_bipartite(&ensemble, params.k, params.base.solver, seed ^ 0xC075)
    })?;
    let kc = params.k;
    let bases: Vec<UsencBase> = base_models
        .into_iter()
        .zip(&ensemble.labelings)
        .map(|(bm, bl)| {
            let mut votes = vec![0u64; bm.k as usize * kc];
            for (i, &b) in bl.iter().enumerate() {
                votes[b as usize * kc + labels[i] as usize] += 1;
            }
            UsencBase {
                k: bm.k,
                k_nn: bm.k_nn,
                sigma: bm.sigma,
                reps: bm.reps,
                rep_labels: bm.rep_labels,
                votes,
            }
        })
        .collect();
    let provenance = Json::obj(vec![
        ("algo", Json::Str("usenc".into())),
        ("k", Json::Num(kc as f64)),
        ("m", Json::Num(bases.len() as f64)),
        ("seed", Json::Str(seed.to_string())),
    ])
    .to_string();
    let model = UsencModel { k: kc as u32, seed, bases, provenance };
    Ok(UsencFitOutput { result: UsencResult { labels, ensemble, timer }, model })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::data::synthetic::{concentric_circles, two_moons};
    use crate::metrics::nmi;

    fn small_params(k: usize, m: usize, p: usize) -> UsencParams {
        UsencParams {
            k,
            m,
            k_min: 5,
            k_max: 12,
            base: UspecParams { p, ..Default::default() },
        }
    }

    #[test]
    fn incidence_structure() {
        let mut ens = Ensemble::default();
        ens.push(vec![0, 0, 1, 1]);
        ens.push(vec![0, 1, 1, 2]);
        assert_eq!(ens.m(), 2);
        assert_eq!(ens.ks(), vec![2, 3]);
        assert_eq!(ens.total_clusters(), 5);
        let b = ens.incidence();
        assert_eq!(b.rows, 4);
        assert_eq!(b.cols, 5);
        assert_eq!(b.nnz(), 8); // exactly m per row
        // object 3: cluster 1 of base 0 (col 1), cluster 2 of base 1 (col 2+2=4)
        assert_eq!(b.row(3).0, &[1u32, 4u32]);
        // column sums = cluster sizes
        assert_eq!(b.col_sums(), vec![2.0, 2.0, 1.0, 2.0, 1.0]);
    }

    #[test]
    fn consensus_label_permutation_invariant() {
        let mut a = Ensemble::default();
        a.push(vec![0, 0, 0, 1, 1, 1, 2, 2, 2]);
        a.push(vec![0, 0, 1, 1, 1, 2, 2, 2, 0]);
        let mut b = Ensemble::default();
        // same partitions, permuted labels
        b.push(vec![2, 2, 2, 0, 0, 0, 1, 1, 1]);
        b.push(vec![1, 1, 2, 2, 2, 0, 0, 0, 1]);
        let la = consensus_bipartite(&a, 3, EigSolver::Dense, 5).unwrap();
        let lb = consensus_bipartite(&b, 3, EigSolver::Dense, 5).unwrap();
        assert!((nmi(&la, &lb) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn recovers_moons() {
        let ds = two_moons(1200, 0.06, 3);
        let res = usenc(&ds.x, &small_params(2, 6, 120), 17, &NativeBackend).unwrap();
        let score = nmi(&res.labels, &ds.y);
        assert!(score > 0.85, "nmi={score}");
        assert_eq!(res.ensemble.m(), 6);
    }

    #[test]
    fn usenc_at_least_as_good_as_median_base_on_rings() {
        let ds = concentric_circles(1500, 5);
        let params = small_params(3, 8, 150);
        let res = usenc(&ds.x, &params, 23, &NativeBackend).unwrap();
        let consensus_nmi = nmi(&res.labels, &ds.y);
        // The robustness claim: the consensus must beat the average base
        // clustering (whose k is drawn in [5,12] ≠ 3).
        let mean_base: f64 = res
            .ensemble
            .labelings
            .iter()
            .map(|l| nmi(l, &ds.y))
            .sum::<f64>()
            / res.ensemble.m() as f64;
        assert!(consensus_nmi > 0.7, "consensus nmi={consensus_nmi}");
        assert!(
            consensus_nmi > mean_base,
            "consensus {consensus_nmi} should beat mean base {mean_base}"
        );
    }

    #[test]
    fn draw_base_k_covers_inclusive_range() {
        let mut rng = Rng::new(1);
        let (mut saw_min, mut saw_max) = (false, false);
        for _ in 0..2000 {
            let k = draw_base_k(&mut rng, 20, 60, 10_000);
            assert!((20..=60).contains(&k));
            saw_min |= k == 20;
            saw_max |= k == 60;
        }
        // the inclusive draw must reach both endpoints (the old draw never
        // produced k_max)
        assert!(saw_min && saw_max, "min seen: {saw_min}, max seen: {saw_max}");
        // clamped by n
        let k = draw_base_k(&mut rng, 20, 60, 10);
        assert!(k <= 10);
        // degenerate range
        assert_eq!(draw_base_k(&mut rng, 7, 7, 100), 7);
    }

    #[test]
    fn chunked_generation_matches_default() {
        let ds = two_moons(500, 0.06, 8);
        let params = small_params(2, 3, 60);
        let a = generate_ensemble(&ds.x, &params, 5, &NativeBackend).unwrap();
        let b = generate_ensemble_chunked(&ds.x, &params, 5, &NativeBackend, 128).unwrap();
        assert_eq!(a.labelings, b.labelings);
        // sharded execution is operational too — same labelings
        let opts = ExecOpts { chunk: 128, shards: 3, ..ExecOpts::default() };
        let c = generate_ensemble_opts(&ds.x, &params, 5, &NativeBackend, opts).unwrap();
        assert_eq!(a.labelings, c.labelings);
    }

    #[test]
    fn fit_matches_plain_usenc_and_captures_a_valid_model() {
        let ds = two_moons(500, 0.06, 8);
        let params = small_params(2, 3, 60);
        let plain = usenc(&ds.x, &params, 5, &NativeBackend).unwrap();
        let fit = usenc_fit(&ds.x, &params, 5, &NativeBackend, ExecOpts::default()).unwrap();
        assert_eq!(plain.labels, fit.result.labels);
        assert_eq!(plain.ensemble.labelings, fit.result.ensemble.labelings);
        fit.model.validate().unwrap();
        assert_eq!(fit.model.bases.len(), 3);
        // every vote table counts exactly n fit points
        for b in &fit.model.bases {
            assert_eq!(b.votes.iter().sum::<u64>(), 500);
        }
    }

    #[test]
    fn rejects_empty_ensemble() {
        let ens = Ensemble::default();
        assert!(consensus_bipartite(&ens, 2, EigSolver::Dense, 1).is_err());
    }
}
