//! Adaptive ensemble size — an extension past the paper's fixed m = 20
//! (§4.5.3 shows quality saturating in m): grow the ensemble in batches
//! and stop once the consensus stabilizes, measured by the NMI between
//! consecutive consensus clusterings. Spends base-clusterer budget only
//! while it still changes the answer.
//!
//! Runs on any [`DataSource`] (in-memory or on-disk). Each growth batch
//! sweeps its base clusterers' candidate reservoirs in one pass over the
//! source, so an adaptive run that converges after r rounds costs r
//! selection passes — not one per base clusterer.

use crate::affinity::DistanceBackend;
use crate::metrics::nmi;
use crate::pipeline::{DataSource, ExecOpts, Pipeline};
use crate::usenc::{
    consensus_bipartite, derive_jobs, run_job, sweep_job_candidates, Ensemble, UsencParams,
};
use crate::{ensure_arg, Result};

/// Stopping policy for [`usenc_adaptive`].
#[derive(Debug, Clone)]
pub struct AdaptiveParams {
    /// Base clusterers added per round (paper's unit of work).
    pub batch: usize,
    /// Minimum ensemble size before stabilization may stop the loop.
    pub m_min: usize,
    /// Hard ceiling on the ensemble size.
    pub m_max: usize,
    /// Stop when NMI(consensusᵣ, consensusᵣ₋₁) ≥ `stability` for
    /// `patience` consecutive rounds.
    pub stability: f64,
    pub patience: usize,
}

impl Default for AdaptiveParams {
    fn default() -> Self {
        AdaptiveParams { batch: 4, m_min: 8, m_max: 40, stability: 0.995, patience: 2 }
    }
}

/// Outcome of the adaptive loop.
#[derive(Debug, Clone)]
pub struct AdaptiveResult {
    pub labels: Vec<u32>,
    pub ensemble: Ensemble,
    /// NMI between consecutive consensus clusterings, one per round after
    /// the first.
    pub stability_trace: Vec<f64>,
    /// True if the loop stopped on stabilization (false = hit m_max).
    pub converged: bool,
}

/// U-SENC with adaptive ensemble size. Base clusterers come from the same
/// job stream as [`crate::usenc::generate_ensemble`]
/// ([`crate::usenc::derive_jobs`]), so a converged adaptive run is a
/// prefix of the fixed-m run.
pub fn usenc_adaptive(
    source: &dyn DataSource,
    params: &UsencParams,
    adaptive: &AdaptiveParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<AdaptiveResult> {
    usenc_adaptive_opts(source, params, adaptive, seed, backend, ExecOpts::default())
}

/// [`usenc_adaptive`] with explicit execution knobs (chunk size + shard
/// count) for the sweeps — the same plumbing as the fixed-m entry points
/// ([`crate::usenc::usenc_opts`]). Operational only: a converged adaptive
/// run stays a prefix of the fixed-m run for any knob values.
pub fn usenc_adaptive_opts(
    source: &dyn DataSource,
    params: &UsencParams,
    adaptive: &AdaptiveParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    opts: ExecOpts,
) -> Result<AdaptiveResult> {
    ensure_arg!(adaptive.batch >= 1, "adaptive: batch must be >= 1");
    ensure_arg!(
        adaptive.m_min >= 2 && adaptive.m_min <= adaptive.m_max,
        "adaptive: bad m range [{}, {}]",
        adaptive.m_min,
        adaptive.m_max
    );
    // stability > 1.0 is allowed: NMI never reaches it, so it disables
    // early stopping (run exactly to m_max).
    ensure_arg!(adaptive.stability > 0.0, "adaptive: stability must be > 0");
    let pipe = Pipeline::new(backend).with_opts(opts);
    // Job i is fixed by the draws before it, so deriving the full m_max
    // stream up front consumes exactly the fixed-m seed schedule.
    let all_jobs = derive_jobs(
        &UsencParams { m: adaptive.m_max, ..params.clone() },
        source.n(),
        seed,
    );
    let mut ens = Ensemble::default();
    let mut prev_labels: Option<Vec<u32>> = None;
    let mut trace = Vec::new();
    let mut stable_rounds = 0usize;
    loop {
        // grow the ensemble by one batch (one shared candidate sweep per
        // budget-bounded group — usually one per batch)
        let grow_to = (ens.m() + adaptive.batch).min(adaptive.m_max);
        let batch_jobs = &all_jobs[ens.m()..grow_to];
        let group = crate::usenc::sweep_group_size(params, source.n(), source.d()).max(1);
        for group_jobs in batch_jobs.chunks(group) {
            let cands = sweep_job_candidates(&pipe, source, params, group_jobs)?;
            for (i, job) in group_jobs.iter().enumerate() {
                let labels = run_job(&pipe, source, params, job, cands.as_ref().map(|c| &c[i]))?;
                ens.push(labels);
            }
        }
        let labels = consensus_bipartite(&ens, params.k, params.base.solver, seed ^ 0xC075)?;
        if let Some(prev) = &prev_labels {
            let s = nmi(prev, &labels);
            trace.push(s);
            if ens.m() >= adaptive.m_min && s >= adaptive.stability {
                stable_rounds += 1;
            } else {
                stable_rounds = 0;
            }
        }
        let converged = stable_rounds >= adaptive.patience;
        if converged || ens.m() >= adaptive.m_max {
            return Ok(AdaptiveResult { labels, ensemble: ens, stability_trace: trace, converged });
        }
        prev_labels = Some(labels);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::data::synthetic::{concentric_circles, two_moons};
    use crate::uspec::UspecParams;

    fn base_params(k: usize, p: usize) -> UsencParams {
        UsencParams {
            k,
            m: 40,
            k_min: 5,
            k_max: 12,
            base: UspecParams { p, ..Default::default() },
        }
    }

    #[test]
    fn converges_early_on_easy_data() {
        let ds = two_moons(1200, 0.05, 3);
        let res = usenc_adaptive(
            &ds.x,
            &base_params(2, 120),
            &AdaptiveParams::default(),
            17,
            &NativeBackend,
        )
        .unwrap();
        assert!(res.converged, "trace {:?}", res.stability_trace);
        assert!(
            res.ensemble.m() < 40,
            "easy data should stop before m_max (got m={})",
            res.ensemble.m()
        );
        let score = crate::metrics::nmi(&res.labels, &ds.y);
        assert!(score > 0.85, "nmi={score}");
    }

    #[test]
    fn respects_m_max() {
        let ds = concentric_circles(600, 7);
        let ap = AdaptiveParams {
            batch: 3,
            m_min: 6,
            m_max: 9,
            stability: 1.1, // unattainable → must run to the ceiling
            patience: 1,
        };
        let res =
            usenc_adaptive(&ds.x, &base_params(3, 80), &ap, 5, &NativeBackend).unwrap();
        assert!(!res.converged);
        assert_eq!(res.ensemble.m(), 9);
    }

    #[test]
    fn prefix_of_fixed_m_seed_stream() {
        // the adaptive ensemble must be a prefix of generate_ensemble's
        // output for the same seed (same job derivation)
        let ds = two_moons(400, 0.05, 9);
        let params = base_params(2, 60);
        let ap = AdaptiveParams { batch: 2, m_min: 4, m_max: 6, stability: 2.0, patience: 1 };
        let res = usenc_adaptive(&ds.x, &params, &ap, 23, &NativeBackend).unwrap();
        let fixed =
            crate::usenc::generate_ensemble(&ds.x, &params, 23, &NativeBackend).unwrap();
        for (a, b) in res.ensemble.labelings.iter().zip(&fixed.labelings) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn rejects_bad_params() {
        let ds = two_moons(100, 0.05, 1);
        let params = base_params(2, 30);
        let bad = AdaptiveParams { batch: 0, ..Default::default() };
        assert!(usenc_adaptive(&ds.x, &params, &bad, 1, &NativeBackend).is_err());
        let bad = AdaptiveParams { m_min: 10, m_max: 5, ..Default::default() };
        assert!(usenc_adaptive(&ds.x, &params, &bad, 1, &NativeBackend).is_err());
    }
}
