//! # uspec — Ultra-Scalable Spectral & Ensemble Clustering
//!
//! A production-grade reproduction of *"Ultra-Scalable Spectral Clustering
//! and Ensemble Clustering"* (Huang et al., IEEE TKDE 2019). The crate
//! implements:
//!
//! * **U-SPEC** ([`uspec::UspecParams`], [`uspec::uspec`]): hybrid
//!   representative selection, approximate K-nearest-representative search,
//!   sparse bipartite affinity, and transfer-cut spectral partitioning —
//!   `O(N·p^½·d)` time, `O(N·p^½)` memory.
//! * **U-SENC** ([`usenc`]): an ensemble of `m` diverse U-SPEC base
//!   clusterers fused through an object×cluster bipartite graph.
//! * Every baseline from the paper's evaluation: SC, ESCG, Nyström, LSC-K,
//!   LSC-R, FastESC, EulerSC ([`baselines`]) and EAC, WCT, KCC, PTGP, ECC,
//!   SEC, LWGP ([`ensemble_baselines`]).
//! * The substrates those need: dense/sparse linear algebra, symmetric
//!   eigensolvers, k-means, clustering metrics (NMI/CA/ARI + Hungarian),
//!   synthetic dataset generators, a scoped thread pool, a PRNG, JSON, and
//!   a benchmarking harness (this build environment is fully offline).
//! * A PJRT **runtime** ([`runtime`]) that loads AOT-compiled JAX/Pallas
//!   kernels (HLO text under `artifacts/`) and serves them to the hot path,
//!   plus a **coordinator** ([`coordinator`]) that schedules ensemble jobs
//!   across a worker pool with batched kernel dispatch.
//! * A **clustering-as-a-service** layer: fitted models persist as
//!   versioned, checksummed artifacts ([`runtime::model`]); out-of-sample
//!   rows are labeled against them ([`pipeline::Pipeline::assign`] /
//!   [`pipeline::Pipeline::assign_consensus`]) bit-identically across
//!   threads, chunk sizes, and SIMD dispatch; and a `repro serve` job
//!   manager ([`net::serve`]) runs fits and assignment queries as a
//!   long-lived daemon over the `USPEC/2` wire protocol.
//!
//! ## Model artifacts and the serve protocol
//!
//! A fitted model ([`pipeline::Pipeline::fit`] → `UspecModel`,
//! [`usenc::usenc_fit`] → `UsencModel`) serializes to a single-file
//! artifact: magic `USPECMDL`, a format-version byte, a kind byte
//! (U-SPEC / U-SENC), the little-endian body (representatives,
//! per-representative labels, sigma as raw f64 bits, seed, and — for
//! ensembles — per-base consensus vote tables, plus a JSON provenance
//! blob), and a trailing FNV-1a checksum over everything before it.
//! [`runtime::save_model`]/[`runtime::load_model`] roundtrip bit-exactly;
//! corrupt, truncated, or version-skewed files are rejected with typed
//! errors before any field is trusted. The `repro serve` daemon speaks
//! four `USPEC/2` opcodes on the [`net::proto`] framing: `SubmitFit`
//! (0x10, JSON fit spec), `JobStatus` (0x11, u64 job id), `Assign`
//! (0x12, model id + f32 rows → u32 labels), and `ListModels` (0x13);
//! see [`net::serve`] for the lifecycle and drain semantics, and
//! `repro serve --models_dir DIR [--queue N]` for the CLI.
//!
//! Python (JAX + Pallas) exists only on the *compile path*
//! (`python/compile`); the rust binary is self-contained once
//! `make artifacts` has produced the HLO text artifacts.
//!
//! ## Environment knobs
//!
//! All runtime tuning is via environment variables, each read once at
//! first use:
//!
//! * `USPEC_THREADS=n` — cap the scoped thread pool at `n` workers
//!   (default: all cores). Results are bit-identical at any setting: every
//!   parallel loop writes disjoint chunks with a fixed per-element
//!   reduction order.
//! * `USPEC_SIMD=0` — force the scalar kernel paths (distance and gemm),
//!   bypassing runtime AVX2/NEON detection. The vector tiles replay the
//!   scalar operation order lanewise, so this changes speed, never bits;
//!   the bench harnesses assert that equivalence where the numbers are
//!   made.
//! * `USPEC_EIG_TRACE=1` — print eigensolver routing (dense vs Chebyshev
//!   subspace vs LOBPCG, with the crossover that decided it), per-outer-
//!   iteration convergence deltas, and per-stage transfer-cut wall timings
//!   (`E_R` build | reduced solve | N×k lift) to stderr.
//! * `USPEC_EIG_DEBUG=1` — print eigensolver convergence summaries and
//!   fallback decisions (quieter than `USPEC_EIG_TRACE`).
//! * `USPEC_NET_TIMEOUT_MS=n` — connect/read/write deadline in
//!   milliseconds for remote shard sources ([`net`]); default 5000.
//!   Operational only: it bounds waiting, never changes any result.
//! * `USPEC_NET_RETRIES=n` — how many times a transient remote-read
//!   failure (disconnect, timeout, corrupt frame) is retried on a fresh
//!   connection before the walk aborts with a typed error; default 3.
//! * `USPEC_NET_COMPRESS=0` — disable `USPEC/2` wire compression on
//!   both client and server; peers fall back to plain `USPEC/1` row
//!   frames. The codec is lossless (byte-shuffle + RLE with bit-exact
//!   reassembly), so this changes bytes on the wire, never results.
//! * `USPEC_NET_POOL=n` — cap the per-source pool of reusable
//!   connections a [`net::RemoteSource`] keeps warm; default 8,
//!   floor 1. Operational only.
//! * `USPEC_NET_IDLE_MS=n` — server-side idle disconnect for a client
//!   connection in milliseconds; default 60000. Operational only. Also
//!   bounds how long a dropping `repro serve` daemon waits for in-flight
//!   queries to drain.
//!
//! The `repro serve` daemon adds two CLI knobs alongside these:
//! `--models_dir DIR` (the artifact store the registry is seeded from at
//! startup and fits persist into) and `--queue N` (the bounded fit-job
//! backlog, default 16 — a submit beyond it is rejected with a typed
//! error instead of buffering unboundedly).
//!
//! ## Quickstart
//!
//! ```no_run
//! use uspec::data::synthetic::two_moons;
//! use uspec::uspec::{uspec, UspecParams};
//!
//! let ds = two_moons(2_000, 0.06, 7);
//! let res = uspec(&ds.x, &UspecParams { k: 2, p: 200, ..Default::default() }, 42).unwrap();
//! let score = uspec::metrics::nmi(&res.labels, &ds.y);
//! assert!(score > 0.9);
//! ```

pub mod util;
pub mod linalg;
pub mod kmeans;
pub mod metrics;
pub mod data;
pub mod affinity;
pub mod bipartite;
pub mod pipeline;
pub mod uspec;
pub mod usenc;
pub mod baselines;
pub mod graphpart;
pub mod ensemble_baselines;
pub mod streaming;
pub mod net;
pub mod runtime;
pub mod coordinator;
pub mod bench;
pub mod config;
pub mod cli;

/// Crate-wide error type (hand-rolled Display/Error impls — `thiserror`
/// is unavailable in this offline build).
#[derive(Debug)]
pub enum Error {
    InvalidArg(String),
    Numerical(String),
    MemoryBudget { need: u64, budget: u64, what: String },
    Runtime(String),
    Io(std::io::Error),
    Xla(String),
    Config(String),
    /// A network-transport failure (connect/read timeout, disconnect,
    /// malformed frame, exhausted retries) on a remote shard source.
    Net(String),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidArg(m) => write!(f, "invalid argument: {m}"),
            Error::Numerical(m) => write!(f, "numerical failure: {m}"),
            Error::MemoryBudget { need, budget, what } => write!(
                f,
                "memory budget exceeded: need {need} bytes, budget {budget} bytes ({what})"
            ),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Net(m) => write!(f, "net: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

/// Convenience: argument-check helper used across the crate.
#[macro_export]
macro_rules! ensure_arg {
    ($cond:expr, $($msg:tt)*) => {
        if !($cond) {
            return Err($crate::Error::InvalidArg(format!($($msg)*)));
        }
    };
}
