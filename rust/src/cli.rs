//! Command-line interface for the `repro` leader binary (clap is
//! unavailable offline — this is a small subcommand + `--key value`
//! parser over [`crate::config::RunConfig`]).

use crate::bench::tables::{self, Harness};
use crate::bench::runner;
use crate::config::RunConfig;
use crate::data::{loader, Benchmark, Dataset};
use crate::metrics::{ari, ca, nmi};
use crate::{Error, Result};
use std::path::Path;

const USAGE: &str = "\
repro — U-SPEC / U-SENC (TKDE'19) coordinator

USAGE:
  repro <command> [--key value ...]

COMMANDS:
  datasets                      print the Table 3 inventory
  gen-data --dataset D --out F  generate a benchmark dataset (CSV, or
                                the USPECB01 binary form when --out
                                ends in .bin)
  cluster  --dataset D --method M
                                run one method, print NMI/CA/ARI/time
  table    --id tN              regenerate a paper table (t3..t16, fig1/3/5)
                                or an ablation (ablation-consensus |
                                ablation-eig | ablation-kernels |
                                ablation-streaming)
  estimate-k --dataset D [--k_max N]
                                eigengap estimate of the cluster count
  stream   --dataset D|F.bin    out-of-core clustering over an on-disk
                                dataset (USPECB01 file, or a benchmark
                                spilled to a temp file); --method u-spec
                                (default) or u-senc; --shards S walks S
                                row ranges in parallel per pass;
                                --source remote://host:port streams from
                                a serve-shard endpoint instead
  serve-shard --data F.bin --addr H:P [--cache BYTES]
                                serve a USPECB01 file's row ranges to
                                remote stream walkers over TCP (port 0
                                picks an ephemeral port); --cache keeps
                                an LRU of encoded reply frames
  serve    --addr H:P --models_dir DIR [--queue N]
                                clustering-as-a-service daemon: accepts
                                SubmitFit/JobStatus/Assign/ListModels
                                over USPEC/2; fitted models persist as
                                artifacts under --models_dir (loaded
                                back at startup); --queue bounds the
                                fit-job backlog [16]
  fit      --data F.bin --out model.bin [--method u-spec|u-senc]
                                fit locally and save a model artifact
  submit-fit --addr H:P --data F.bin [--method ...]
                                enqueue a fit on a serve daemon (--data
                                is the server-visible path); prints the
                                job id
  job-status --addr H:P --job N [--wait SECS]
                                poll one job; --wait blocks until done/
                                failed (nonzero exit on failure)
  assign   --data F.bin (--model ID --addr H:P | --model_file F)
                                [--out labels.txt]
                                label out-of-sample rows with a fitted
                                model — remotely against a serve daemon
                                or locally from an artifact file;
                                bit-identical either way
  list-models --addr H:P        enumerate a serve daemon's registry
  info                          print config + artifact status

COMMON FLAGS (any config key):
  --dataset    benchmark name (Table 3) or a CSV path  [TB-1M]
  --scale      synthetic-size multiplier, 1.0 = paper  [0.002]
  --method     k-means|SC|ESCG|Nystrom|LSC-K|LSC-R|FastESC|EulerSC|
               U-SPEC|U-SENC|EAC|WCT|KCC|PTGP|ECC|SEC|LWGP  [u-spec]
  --k          cluster count (default: ground truth)
  --p          representatives (paper: 1000)
  --k_nn       nearest representatives K (paper: 5)
  --m          ensemble size (paper: 20)
  --backend    native | pjrt (AOT kernels; needs `make artifacts`)
  --workers    coordinator worker threads
  --shards     row-range shards per streaming pass, 1..=n (I/O overlap
               only — labels never depend on it)  [1]
  --storage    walk-planner hint: auto | serial (hdd) | parallel
               (ssd/nvme) | remote (net); auto probes the source unless
               it knows its backend. Operational only, like --shards
               [auto]
  --source     remote://host:port of a serve-shard endpoint for stream
               (labels are bit-identical to the local run)  [null]
  --net_cache  decoded-chunk LRU budget in bytes for a remote source;
               repeat passes over the same row range skip the wire.
               Operational only — 0 disables  [0]
  --runs       repetitions for mean±std
  --seed       master seed
  --config     JSON config file (flags override it)
";

/// Parsed invocation.
pub struct Invocation {
    pub command: String,
    pub cfg: RunConfig,
    pub extra: std::collections::BTreeMap<String, String>,
}

/// Parse argv (excluding argv[0]).
pub fn parse(args: &[String]) -> Result<Invocation> {
    if args.is_empty() {
        return Err(Error::Config(USAGE.into()));
    }
    let command = args[0].clone();
    let mut cfg = RunConfig::default();
    let mut extra = std::collections::BTreeMap::new();
    // first pass: --config file
    let mut i = 1;
    while i + 1 < args.len() + 1 {
        if i < args.len() && args[i] == "--config" {
            if i + 1 >= args.len() {
                return Err(Error::Config("--config needs a path".into()));
            }
            cfg = RunConfig::load(Path::new(&args[i + 1]))?;
        }
        i += 1;
    }
    let mut i = 1;
    while i < args.len() {
        let key = args[i]
            .strip_prefix("--")
            .ok_or_else(|| Error::Config(format!("expected --flag, got '{}'", args[i])))?;
        let value = args
            .get(i + 1)
            .ok_or_else(|| Error::Config(format!("--{key} needs a value")))?;
        match key {
            "config" => {}
            "id" | "out" | "k_max" | "data" | "addr" | "cache" | "model" | "model_file"
            | "job" | "wait" | "models_dir" | "queue" => {
                extra.insert(key.to_string(), value.clone());
            }
            _ => cfg.set(key, value)?,
        }
        i += 2;
    }
    Ok(Invocation { command, cfg, extra })
}

/// A required `--key value` extra, or a typed config error.
fn require<'a>(inv: &'a Invocation, key: &str, msg: &str) -> Result<&'a str> {
    inv.extra.get(key).map(String::as_str).ok_or_else(|| Error::Config(msg.into()))
}

/// An optional numeric extra with a default; non-numeric values are a
/// typed config error, not a silent fallback.
fn parse_extra<T: std::str::FromStr>(inv: &Invocation, key: &str, default: T) -> Result<T> {
    match inv.extra.get(key) {
        Some(v) => v
            .parse()
            .map_err(|_| Error::Config(format!("--{key} wants a number, got '{v}'"))),
        None => Ok(default),
    }
}

/// A required numeric extra.
fn parse_extra_req<T: std::str::FromStr>(inv: &Invocation, key: &str, msg: &str) -> Result<T> {
    let v = require(inv, key, msg)?;
    v.parse().map_err(|_| Error::Config(format!("--{key} wants a number, got '{v}'")))
}

/// Resolve a dataset name (benchmark or CSV path).
pub fn resolve_dataset(cfg: &RunConfig) -> Result<Dataset> {
    if let Some(b) = Benchmark::from_name(&cfg.dataset) {
        return Ok(b.generate(cfg.scale, cfg.seed ^ 0xDA7A));
    }
    let p = Path::new(&cfg.dataset);
    if p.exists() {
        return loader::load_csv(p);
    }
    Err(Error::InvalidArg(format!(
        "unknown dataset '{}' (benchmarks: {:?})",
        cfg.dataset,
        Benchmark::ALL.map(|b| b.name())
    )))
}

/// Execute a parsed invocation; returns the text to print.
pub fn execute(inv: Invocation) -> Result<String> {
    match inv.command.as_str() {
        "help" | "--help" | "-h" => Ok(USAGE.to_string()),
        "datasets" => Ok(tables::datasets_table()),
        "info" => {
            let art = crate::runtime::default_artifact_dir();
            let status = if art.join("manifest.json").exists() {
                let m = crate::runtime::Manifest::load(&art)?;
                format!("{} artifacts (fingerprint {})", m.artifacts.len(), m.fingerprint)
            } else {
                "NOT BUILT — run `make artifacts`".into()
            };
            Ok(format!(
                "config: {}\nartifacts [{}]: {}\nthreads: {}\n",
                inv.cfg.to_json().to_string(),
                art.display(),
                status,
                crate::util::par::num_threads()
            ))
        }
        "gen-data" => {
            let ds = resolve_dataset(&inv.cfg)?;
            let out = inv
                .extra
                .get("out")
                .ok_or_else(|| Error::Config("gen-data needs --out FILE".into()))?;
            // a .bin target writes the streaming/serving USPECB01 form
            // (features only); anything else writes labeled CSV
            if Path::new(out).extension().map(|e| e == "bin").unwrap_or(false) {
                crate::streaming::BinDataset::write_mat(Path::new(out), &ds.x)?;
            } else {
                loader::save_csv(&ds, Path::new(out))?;
            }
            Ok(format!("wrote {} ({} × {}, k={}) to {}", ds.name, ds.n(), ds.d(), ds.k, out))
        }
        "cluster" => {
            let ds = resolve_dataset(&inv.cfg)?;
            let h = Harness::new(inv.cfg.clone())?;
            let mut out = format!(
                "dataset {}: n={} d={} k={}  method={} backend={}\n",
                ds.name,
                ds.n(),
                ds.d(),
                ds.k,
                inv.cfg.method,
                inv.cfg.backend.name()
            );
            for run in 0..inv.cfg.runs {
                let seed = inv.cfg.seed.wrapping_add(run as u64);
                let t0 = std::time::Instant::now();
                let res = runner::run_by_name(&inv.cfg.method, &ds, &inv.cfg, seed, h.backend())?;
                let secs = t0.elapsed().as_secs_f64();
                out.push_str(&format!(
                    "run {run}: NMI={:.4} CA={:.4} ARI={:.4} time={:.3}s  [{}]\n",
                    nmi(&res.labels, &ds.y),
                    ca(&res.labels, &ds.y),
                    ari(&res.labels, &ds.y),
                    secs,
                    res.timer.summary()
                ));
            }
            Ok(out)
        }
        "table" => {
            let id = inv
                .extra
                .get("id")
                .ok_or_else(|| Error::Config("table needs --id tN (t3..t16, fig1/3/5)".into()))?
                .clone();
            let h = Harness::new(inv.cfg)?;
            tables::run_table(&h, &id)
        }
        "estimate-k" => {
            let ds = resolve_dataset(&inv.cfg)?;
            let h = Harness::new(inv.cfg.clone())?;
            let dp = runner::derive(&inv.cfg, &ds);
            let params = runner::uspec_params(&inv.cfg, &dp);
            let k_max = inv
                .extra
                .get("k_max")
                .and_then(|v| v.parse().ok())
                .unwrap_or(20.min(ds.n() / 2).max(3));
            let est = crate::uspec::estimate::estimate_k(
                &ds.x,
                &params,
                2,
                k_max,
                inv.cfg.seed,
                h.backend(),
            )?;
            let spectrum: Vec<String> =
                est.lambdas.iter().map(|l| format!("{l:.3e}")).collect();
            Ok(format!(
                "dataset {}: n={} d={} (true k={})\nestimated k = {} (relative eigengap, gap {:.3e})\nspectrum: [{}]\n",
                ds.name,
                ds.n(),
                ds.d(),
                ds.k,
                est.k,
                est.gap,
                spectrum.join(", ")
            ))
        }
        "stream" => {
            // cluster an on-disk USPECB01 file (or spill a benchmark first)
            if !inv.cfg.method.eq_ignore_ascii_case("u-spec")
                && !inv.cfg.method.eq_ignore_ascii_case("u-senc")
            {
                return Err(Error::Config(format!(
                    "stream supports --method u-spec or u-senc (got '{}')",
                    inv.cfg.method
                )));
            }
            /// Deletes a spilled scratch dataset on every exit path
            /// (later validation and the runs themselves bail with `?`).
            struct SpillGuard(Option<std::path::PathBuf>);

            impl Drop for SpillGuard {
                fn drop(&mut self) {
                    if let Some(p) = self.0.take() {
                        std::fs::remove_file(p).ok();
                    }
                }
            }

            let h = Harness::new(inv.cfg.clone())?;
            // A remote source streams straight off a serve-shard endpoint:
            // no local file, no spill, no ground truth. Malformed specs
            // were rejected at config time; an unreachable endpoint fails
            // here, typed, within the connect timeout × retries.
            if let Some(spec) = &inv.cfg.source {
                let hostport = spec.strip_prefix("remote://").ok_or_else(|| {
                    Error::Config(format!("--source '{spec}': want remote://host:port"))
                })?;
                let remote = crate::net::RemoteSource::connect_with(
                    hostport,
                    crate::net::NetOpts {
                        cache_bytes: inv.cfg.net_cache,
                        ..crate::net::NetOpts::default()
                    },
                )?;
                return stream_run(&inv.cfg, &remote, spec, None, h.backend());
            }
            let path = Path::new(&inv.cfg.dataset);
            let mut spill = SpillGuard(None);
            let is_bin = path.exists() && path.extension().map(|e| e == "bin").unwrap_or(false);
            let (bin, truth) = if is_bin {
                (crate::streaming::BinDataset::open(path)?, None)
            } else {
                let ds = resolve_dataset(&inv.cfg)?;
                // Unique per invocation (pid alone races parallel tests
                // spilling concurrently in one process).
                static SPILL_ID: std::sync::atomic::AtomicUsize =
                    std::sync::atomic::AtomicUsize::new(0);
                let id = SPILL_ID.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let tmp = std::env::temp_dir()
                    .join(format!("uspec_stream_{}_{id}.bin", std::process::id()));
                // Arm the guard first so a failed spill removes the
                // partial file too.
                spill.0 = Some(tmp.clone());
                let bin = crate::streaming::BinDataset::write_mat(&tmp, &ds.x)?;
                (bin, Some(ds))
            };
            stream_run(&inv.cfg, &bin, &inv.cfg.dataset, truth.as_ref(), h.backend())
        }
        "serve-shard" => {
            // Foreground server: load the file, bind, serve until killed.
            let data = inv
                .extra
                .get("data")
                .ok_or_else(|| Error::Config("serve-shard needs --data FILE.bin".into()))?;
            let addr = inv
                .extra
                .get("addr")
                .ok_or_else(|| Error::Config("serve-shard needs --addr host:port".into()))?;
            let cache_bytes = match inv.extra.get("cache") {
                Some(v) => v.parse::<usize>().map_err(|_| {
                    Error::Config(format!("--cache wants a byte count, got '{v}'"))
                })?,
                None => 0,
            };
            let bin = crate::streaming::BinDataset::open(Path::new(data))?;
            let (n, d) = (bin.n(), bin.d());
            let server = crate::net::ShardServer::bind_with(
                addr,
                std::sync::Arc::new(bin),
                crate::net::ServeOpts { cache_bytes, ..Default::default() },
            )?;
            println!("serving {data} (n={n}, d={d}) on {} — ctrl-c to stop", server.addr());
            server.join()?;
            Ok(String::new())
        }
        "serve" => {
            // Foreground job manager: bind, load the model registry,
            // serve fits and assignment queries until killed.
            let addr = require(&inv, "addr", "serve needs --addr host:port")?;
            let models_dir = require(&inv, "models_dir", "serve needs --models_dir DIR")?;
            let queue = parse_extra(&inv, "queue", 16usize)?;
            let rt = crate::net::ServeRuntime::bind(
                addr,
                crate::net::ServeConfig {
                    models_dir: std::path::PathBuf::from(models_dir),
                    queue_depth: queue,
                },
            )?;
            println!(
                "serving models from {models_dir} on {} ({} loaded, queue depth {queue}) — ctrl-c to stop",
                rt.addr(),
                rt.model_ids().len()
            );
            rt.join()?;
            Ok(String::new())
        }
        "fit" => {
            // Local fit → model artifact, the offline twin of submit-fit.
            let data = require(&inv, "data", "fit needs --data FILE.bin")?;
            let out = require(&inv, "out", "fit needs --out MODEL_FILE")?;
            let spec = crate::config::FitSpec::from_config(&inv.cfg, data);
            let model = crate::net::serve::fit_model(&spec)?;
            crate::runtime::save_model(Path::new(out), &model)?;
            Ok(format!(
                "fitted {} model (k={}, d={}) from {data}, saved to {out}\n",
                model.kind(),
                model.k(),
                model.d()
            ))
        }
        "submit-fit" => {
            let addr = require(&inv, "addr", "submit-fit needs --addr host:port")?;
            let data = require(&inv, "data", "submit-fit needs --data FILE.bin (server-visible)")?;
            let spec = crate::config::FitSpec::from_config(&inv.cfg, data);
            spec.validate()?;
            let mut client = crate::net::ServeClient::connect(addr)?;
            let job = client.submit_fit(&spec)?;
            Ok(format!("{job}\n"))
        }
        "job-status" => {
            let addr = require(&inv, "addr", "job-status needs --addr host:port")?;
            let job: u64 = parse_extra_req(&inv, "job", "job-status needs --job N")?;
            let mut client = crate::net::ServeClient::connect(addr)?;
            match inv.extra.get("wait") {
                Some(w) => {
                    let secs: u64 = w.parse().map_err(|_| {
                        Error::Config(format!("--wait wants seconds, got '{w}'"))
                    })?;
                    let model =
                        client.wait_for(job, std::time::Duration::from_secs(secs))?;
                    Ok(format!("job {job} done: model {model}\n"))
                }
                None => {
                    let r = client.job_status(job)?;
                    let detail = match (&r.model, &r.error) {
                        (Some(m), _) => format!(" model {m}"),
                        (None, Some(e)) => format!(" error: {e}"),
                        (None, None) => String::new(),
                    };
                    Ok(format!("job {job} {}{detail}\n", r.status))
                }
            }
        }
        "assign" => {
            // Label out-of-sample rows: remotely (--model + --addr)
            // against a serve daemon, or locally (--model_file) from an
            // artifact. Both paths are bit-identical by construction.
            let data = require(&inv, "data", "assign needs --data FILE.bin")?;
            let bin = crate::streaming::BinDataset::open(Path::new(data))?;
            let labels = match (inv.extra.get("model"), inv.extra.get("model_file")) {
                (Some(model_id), None) => {
                    let addr = require(&inv, "addr", "remote assign needs --addr host:port")?;
                    let mut rows = crate::linalg::Mat::zeros(0, 0);
                    use crate::pipeline::DataSource;
                    bin.read_rows(0, bin.n(), &mut rows)?;
                    let mut client = crate::net::ServeClient::connect(addr)?;
                    client.assign(model_id, &rows)?
                }
                (None, Some(model_file)) => {
                    let model = crate::runtime::load_model(Path::new(model_file))?;
                    let pipe = crate::pipeline::Pipeline::new(&crate::affinity::NativeBackend);
                    match &model {
                        crate::runtime::Model::Uspec(m) => pipe.assign(m, &bin)?,
                        crate::runtime::Model::Usenc(m) => pipe.assign_consensus(m, &bin)?,
                    }
                }
                _ => {
                    return Err(Error::Config(
                        "assign needs exactly one of --model ID (with --addr) or --model_file F"
                            .into(),
                    ))
                }
            };
            let mut text = String::with_capacity(labels.len() * 3);
            for l in &labels {
                text.push_str(&l.to_string());
                text.push('\n');
            }
            match inv.extra.get("out") {
                Some(out) => {
                    std::fs::write(out, &text)?;
                    Ok(format!("wrote {} labels to {out}\n", labels.len()))
                }
                None => Ok(text),
            }
        }
        "list-models" => {
            let addr = require(&inv, "addr", "list-models needs --addr host:port")?;
            let mut client = crate::net::ServeClient::connect(addr)?;
            let models = client.list_models()?;
            if models.is_empty() {
                return Ok("no models registered\n".into());
            }
            let mut out = String::new();
            for m in models {
                out.push_str(&format!("{}  kind={} k={} d={}\n", m.id, m.kind, m.k, m.d));
            }
            Ok(out)
        }
        other => Err(Error::Config(format!("unknown command '{other}'\n\n{USAGE}"))),
    }
}

/// The shared tail of `stream`: run U-SPEC or U-SENC over any source
/// (local file or remote endpoint) and format the report. `truth` is the
/// labeled dataset when the source was spilled from a benchmark.
fn stream_run(
    cfg: &RunConfig,
    src: &dyn crate::pipeline::DataSource,
    display: &str,
    truth: Option<&Dataset>,
    backend: &dyn crate::affinity::DistanceBackend,
) -> Result<String> {
    let k = cfg.k.or(truth.map(|d| d.k)).unwrap_or(2);
    let p = cfg.p.min(src.n() / 2).max(k.min(src.n()));
    let base = crate::uspec::UspecParams { k, p, k_nn: cfg.k_nn.min(p), ..Default::default() };
    let shards = cfg.shards;
    if shards == 0 || shards > src.n() {
        return Err(Error::Config(format!(
            "--shards must be in 1..={} for this dataset (got {shards})",
            src.n()
        )));
    }
    let opts = crate::pipeline::ExecOpts {
        chunk: crate::pipeline::DEFAULT_CHUNK,
        shards,
        storage: cfg.storage,
        net_cache: cfg.net_cache,
    };
    let t0 = std::time::Instant::now();
    let (method, labels, timer_summary, peak) = if cfg.method.eq_ignore_ascii_case("u-senc") {
        let params = crate::usenc::UsencParams {
            k,
            m: cfg.m,
            k_min: cfg.k_min,
            k_max: cfg.k_max,
            base,
        };
        let res = crate::streaming::stream_usenc(src, &params, opts, cfg.seed, backend)?;
        ("U-SENC", res.labels, res.timer.summary(), None)
    } else {
        let sp = crate::streaming::StreamParams {
            chunk: opts.chunk,
            shards,
            storage: opts.storage,
            net_cache: opts.net_cache,
            base,
        };
        let res = crate::streaming::stream_uspec(src, &sp, cfg.seed, backend)?;
        ("U-SPEC", res.labels, res.timer.summary(), Some(res.peak_bytes))
    };
    let secs = t0.elapsed().as_secs_f64();
    let peak = peak
        .map(|b| format!(", resident model {:.1} MB", b as f64 / 1e6))
        .unwrap_or_default();
    let mut out = format!(
        "streamed {method} over {display} (n={} d={}, k={k}, shards={shards}): \
         {secs:.2}s{peak}\n[{timer_summary}]\n",
        src.n(),
        src.d(),
    );
    if let Some(ds) = truth {
        out.push_str(&format!(
            "NMI={:.4} CA={:.4}\n",
            nmi(&labels, &ds.y),
            ca(&labels, &ds.y)
        ));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parse_cluster_flags() {
        let inv = parse(&argv("cluster --dataset TB-1M --method U-SPEC --p 300 --runs 2")).unwrap();
        assert_eq!(inv.command, "cluster");
        assert_eq!(inv.cfg.p, 300);
        assert_eq!(inv.cfg.runs, 2);
    }

    #[test]
    fn parse_storage_flag() {
        let inv = parse(&argv("stream --dataset TB-1M --storage nvme")).unwrap();
        assert_eq!(inv.cfg.storage, crate::pipeline::StorageProfile::Parallel);
        assert!(parse(&argv("stream --dataset TB-1M --storage tape")).is_err());
    }

    #[test]
    fn parse_net_cache_flag() {
        let inv = parse(&argv("stream --dataset TB-1M --net_cache 1048576")).unwrap();
        assert_eq!(inv.cfg.net_cache, 1 << 20);
        assert!(parse(&argv("stream --dataset TB-1M --net_cache nah")).is_err());
    }

    #[test]
    fn parse_rejects_bad() {
        assert!(parse(&[]).is_err());
        assert!(parse(&argv("cluster --p")).is_err());
        assert!(parse(&argv("cluster p 3")).is_err());
        assert!(parse(&argv("cluster --bogus 1")).is_err());
    }

    #[test]
    fn datasets_and_help() {
        let out = execute(parse(&argv("datasets")).unwrap()).unwrap();
        assert!(out.contains("Flower-20M"));
        let help = execute(parse(&argv("help")).unwrap()).unwrap();
        assert!(help.contains("USAGE"));
    }

    #[test]
    fn cluster_small_end_to_end() {
        let inv = parse(&argv(
            "cluster --dataset TB-1M --scale 0.0001 --method U-SPEC --p 60 --runs 1 --seed 3",
        ))
        .unwrap();
        let out = execute(inv).unwrap();
        assert!(out.contains("NMI="), "{out}");
    }

    #[test]
    fn estimate_k_end_to_end() {
        let inv = parse(&argv(
            "estimate-k --dataset CC-5M --scale 0.0004 --p 300 --seed 5 --k_max 8",
        ))
        .unwrap();
        let out = execute(inv).unwrap();
        assert!(out.contains("estimated k = 3"), "{out}");
    }

    #[test]
    fn stream_command_on_benchmark() {
        let inv = parse(&argv("stream --dataset TB-1M --scale 0.001 --seed 7")).unwrap();
        let out = execute(inv).unwrap();
        assert!(out.contains("streamed U-SPEC"), "{out}");
        assert!(out.contains("NMI="), "{out}");
    }

    #[test]
    fn stream_shards_flag_parses_runs_and_validates() {
        // a sharded run matches the unsharded labels (same seed → same NMI line)
        let base = parse(&argv("stream --dataset TB-1M --scale 0.001 --seed 7")).unwrap();
        let plain = execute(base).unwrap();
        let inv =
            parse(&argv("stream --dataset TB-1M --scale 0.001 --seed 7 --shards 3")).unwrap();
        assert_eq!(inv.cfg.shards, 3);
        let sharded = execute(inv).unwrap();
        assert!(sharded.contains("shards=3"), "{sharded}");
        let nmi_line = |s: &str| s.lines().find(|l| l.starts_with("NMI=")).map(String::from);
        assert_eq!(nmi_line(&plain), nmi_line(&sharded), "sharding changed the labels");

        // zero is rejected at flag-parse time, over-n at execution time
        assert!(parse(&argv("stream --dataset TB-1M --shards 0")).is_err());
        let over = parse(&argv(
            "stream --dataset TB-1M --scale 0.001 --seed 7 --shards 99999999",
        ))
        .unwrap();
        let err = execute(over).unwrap_err();
        assert!(err.to_string().contains("--shards"), "{err}");
    }

    #[test]
    fn stream_usenc_on_benchmark() {
        let inv = parse(&argv(
            "stream --dataset TB-1M --scale 0.001 --method u-senc --m 3 --p 60 --seed 7",
        ))
        .unwrap();
        let out = execute(inv).unwrap();
        assert!(out.contains("streamed U-SENC"), "{out}");
        assert!(out.contains("NMI="), "{out}");
    }

    #[test]
    fn stream_command_on_bin_file() {
        let ds = crate::data::synthetic::two_moons(500, 0.05, 3);
        let tmp = std::env::temp_dir().join(format!("uspec_cli_{}.bin", std::process::id()));
        crate::streaming::BinDataset::write_mat(&tmp, &ds.x).unwrap();
        let inv =
            parse(&argv(&format!("stream --dataset {} --k 2 --p 80", tmp.display()))).unwrap();
        let out = execute(inv).unwrap();
        assert!(out.contains("streamed U-SPEC"), "{out}");
        // unlabeled file: no NMI line
        assert!(!out.contains("NMI="), "{out}");
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn serve_shard_requires_data_and_addr() {
        let err = execute(parse(&argv("serve-shard --addr 127.0.0.1:0")).unwrap()).unwrap_err();
        assert!(err.to_string().contains("--data"), "{err}");
        let err = execute(parse(&argv("serve-shard --data x.bin")).unwrap()).unwrap_err();
        assert!(err.to_string().contains("--addr"), "{err}");
        // --cache is validated before the data file is opened
        let err = execute(
            parse(&argv("serve-shard --data x.bin --addr 127.0.0.1:0 --cache lots")).unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("--cache"), "{err}");
    }

    #[test]
    fn stream_source_remote_end_to_end() {
        // serve a spilled benchmark in-process, then stream over the wire
        let ds = crate::data::synthetic::two_moons(500, 0.05, 3);
        let tmp = std::env::temp_dir().join(format!("uspec_cli_net_{}.bin", std::process::id()));
        crate::streaming::BinDataset::write_mat(&tmp, &ds.x).unwrap();
        let bin = crate::streaming::BinDataset::open(&tmp).unwrap();
        // exercise the full fast path: server frame cache + client
        // decoded-chunk cache + (default-on) compression
        let server = crate::net::ShardServer::bind_with(
            "127.0.0.1:0",
            std::sync::Arc::new(bin),
            crate::net::ServeOpts { cache_bytes: 1 << 20, ..Default::default() },
        )
        .unwrap();
        let inv = parse(&argv(&format!(
            "stream --source remote://{} --k 2 --p 80 --net_cache 1048576",
            server.addr()
        )))
        .unwrap();
        assert_eq!(inv.cfg.net_cache, 1 << 20);
        let out = execute(inv).unwrap();
        assert!(out.contains("streamed U-SPEC"), "{out}");
        // remote sources carry no ground truth
        assert!(!out.contains("NMI="), "{out}");
        drop(server);
        std::fs::remove_file(tmp).ok();
    }

    #[test]
    fn stream_source_unreachable_is_a_typed_error() {
        // grab an ephemeral port and release it so nothing listens there
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap()
        };
        let inv = parse(&argv(&format!("stream --source remote://{addr} --k 2"))).unwrap();
        let err = execute(inv).unwrap_err();
        assert!(
            matches!(err, Error::Net(_) | Error::Io(_)),
            "want a transport error, got {err}"
        );
    }

    #[test]
    fn serve_and_assign_flag_validation() {
        let err = execute(parse(&argv("serve --addr 127.0.0.1:0")).unwrap()).unwrap_err();
        assert!(err.to_string().contains("--models_dir"), "{err}");
        let err = execute(parse(&argv("serve --models_dir /tmp/x")).unwrap()).unwrap_err();
        assert!(err.to_string().contains("--addr"), "{err}");
        // --queue is validated before binding anything
        let err = execute(
            parse(&argv("serve --addr 127.0.0.1:0 --models_dir /tmp/x --queue many")).unwrap(),
        )
        .unwrap_err();
        assert!(err.to_string().contains("--queue"), "{err}");
        // assign demands exactly one model source
        let err = execute(parse(&argv("assign --data x.bin")).unwrap()).unwrap_err();
        assert!(err.to_string().contains("exactly one"), "{err}");
        let err = execute(parse(&argv("job-status --addr 127.0.0.1:1 --job soon")).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("--job"), "{err}");
    }

    #[test]
    fn fit_and_assign_locally_end_to_end() {
        let ds = crate::data::synthetic::two_moons(400, 0.05, 3);
        let pid = std::process::id();
        let data = std::env::temp_dir().join(format!("uspec_cli_fit_{pid}.bin"));
        let model = std::env::temp_dir().join(format!("uspec_cli_fit_{pid}.uspecmdl"));
        let labels_out = std::env::temp_dir().join(format!("uspec_cli_fit_{pid}.txt"));
        crate::streaming::BinDataset::write_mat(&data, &ds.x).unwrap();

        let out = execute(
            parse(&argv(&format!(
                "fit --data {} --out {} --k 2 --p 80 --seed 9",
                data.display(),
                model.display()
            )))
            .unwrap(),
        )
        .unwrap();
        assert!(out.contains("fitted uspec model"), "{out}");

        // stdout labels == --out labels == in-process assign
        let inline = execute(
            parse(&argv(&format!(
                "assign --data {} --model_file {}",
                data.display(),
                model.display()
            )))
            .unwrap(),
        )
        .unwrap();
        execute(
            parse(&argv(&format!(
                "assign --data {} --model_file {} --out {}",
                data.display(),
                model.display(),
                labels_out.display()
            )))
            .unwrap(),
        )
        .unwrap();
        assert_eq!(inline, std::fs::read_to_string(&labels_out).unwrap());
        assert_eq!(inline.lines().count(), 400);

        let loaded = crate::runtime::load_model(&model).unwrap();
        let bin = crate::streaming::BinDataset::open(&data).unwrap();
        let pipe = crate::pipeline::Pipeline::new(&crate::affinity::NativeBackend);
        let direct = match &loaded {
            crate::runtime::Model::Uspec(m) => pipe.assign(m, &bin).unwrap(),
            crate::runtime::Model::Usenc(m) => pipe.assign_consensus(m, &bin).unwrap(),
        };
        let expect: String = direct.iter().map(|l| format!("{l}\n")).collect();
        assert_eq!(inline, expect, "CLI assign must match the in-process path");

        for p in [&data, &model, &labels_out] {
            std::fs::remove_file(p).ok();
        }
    }

    #[test]
    fn gen_data_roundtrip() {
        let tmp = std::env::temp_dir().join(format!("uspec_cli_{}.csv", std::process::id()));
        let inv = parse(&argv(&format!(
            "gen-data --dataset SF-2M --scale 0.0001 --out {}",
            tmp.display()
        )))
        .unwrap();
        let out = execute(inv).unwrap();
        assert!(out.contains("wrote"));
        let ds = loader::load_csv(&tmp).unwrap();
        assert_eq!(ds.k, 4);
        std::fs::remove_file(tmp).ok();
    }
}
