//! **PTGP** — Probability Trajectory based Graph Partitioning (Huang et
//! al., TKDE'16). Objects with identical ensemble label vectors collapse
//! into *microclusters* (N → N′ ≪ N); the microcluster co-association is
//! sparsified to each row's elite neighbors; probability trajectories are
//! random-walk rows [P¹ … P^L] whose similarity (PTS) feeds a normalized-
//! cut partition of the microclusters, mapped back to objects.

use crate::baselines::ClusteringOutput;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::DMat;
use crate::usenc::Ensemble;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};
use std::collections::HashMap;

/// Microcluster decomposition: groups of objects sharing the exact same
/// label across every base clustering. Returns (object→micro id, sizes).
pub fn microclusters(ens: &Ensemble) -> (Vec<u32>, Vec<u32>) {
    microclusters_prefix(ens, ens.m())
}

/// Microclusters keyed on the first `prefix` base clusterings only.
fn microclusters_prefix(ens: &Ensemble, prefix: usize) -> (Vec<u32>, Vec<u32>) {
    let n = ens.n();
    let prefix = prefix.clamp(1, ens.m());
    let mut map: HashMap<Vec<u32>, u32> = HashMap::new();
    let mut assign = vec![0u32; n];
    let mut sizes: Vec<u32> = Vec::new();
    for i in 0..n {
        let key: Vec<u32> = ens.labelings[..prefix].iter().map(|l| l[i]).collect();
        let next = map.len() as u32;
        let id = *map.entry(key).or_insert_with(|| {
            sizes.push(0);
            next
        });
        sizes[id as usize] += 1;
        assign[i] = id;
    }
    (assign, sizes)
}

/// Granularity control (the PTGP paper's N′ ≪ N assumption): pick the
/// longest base-clustering prefix whose microcluster count stays ≤ `cap`,
/// so the dense N′×N′ trajectory machinery stays tractable.
pub fn microclusters_capped(ens: &Ensemble, cap: usize) -> (Vec<u32>, Vec<u32>) {
    let mut best = microclusters_prefix(ens, 1);
    for prefix in 2..=ens.m() {
        let cand = microclusters_prefix(ens, prefix);
        if cand.1.len() > cap {
            break;
        }
        best = cand;
    }
    best
}

/// Micro-level co-association (N′×N′) weighted by the base clusterings.
fn micro_coassociation(ens: &Ensemble, assign: &[u32], n_micro: usize) -> DMat {
    // representative label vector per microcluster
    let mut rep = vec![usize::MAX; n_micro];
    for (i, &a) in assign.iter().enumerate() {
        if rep[a as usize] == usize::MAX {
            rep[a as usize] = i;
        }
    }
    let m = ens.m();
    let mut c = DMat::zeros(n_micro, n_micro);
    for a in 0..n_micro {
        for b in 0..n_micro {
            let (ia, ib) = (rep[a], rep[b]);
            let mut same = 0usize;
            for l in &ens.labelings {
                if l[ia] == l[ib] {
                    same += 1;
                }
            }
            c.set(a, b, same as f64 / m as f64);
        }
    }
    c
}

/// Probability-trajectory similarity over the elite-neighbor random walk.
/// `top_t`: elite neighbors kept per row; `walk_len`: trajectory length L.
pub fn pts_similarity(coassoc: &DMat, sizes: &[u32], top_t: usize, walk_len: usize) -> DMat {
    let n = coassoc.rows;
    // sparsify: keep top_t entries per row (off-diagonal), weight by target size
    let mut p = DMat::zeros(n, n);
    for i in 0..n {
        let row: Vec<f64> = (0..n)
            .map(|j| if j == i { f64::NEG_INFINITY } else { coassoc.at(i, j) * sizes[j] as f64 })
            .collect();
        let keys: Vec<f64> = row.iter().map(|&v| -v).collect();
        let keep = crate::util::argmin_k(&keys, top_t.min(n.saturating_sub(1)));
        let mut s = 0.0;
        for &j in &keep {
            if row[j] > 0.0 {
                s += row[j];
            }
        }
        if s <= 0.0 {
            p.set(i, i, 1.0);
            continue;
        }
        for &j in &keep {
            if row[j] > 0.0 {
                p.set(i, j, row[j] / s);
            }
        }
    }
    // trajectories: rows of [P, P², ..., P^L]
    let mut traj: Vec<DMat> = Vec::with_capacity(walk_len);
    let mut cur = p.clone();
    traj.push(cur.clone());
    for _ in 1..walk_len {
        cur = cur.matmul(&p);
        traj.push(cur.clone());
    }
    // PTS = cosine similarity of concatenated trajectory rows
    let mut sim = DMat::zeros(n, n);
    let norms: Vec<f64> = (0..n)
        .map(|i| {
            traj.iter()
                .map(|t| t.row(i).iter().map(|v| v * v).sum::<f64>())
                .sum::<f64>()
                .sqrt()
                .max(1e-12)
        })
        .collect();
    for i in 0..n {
        for j in 0..=i {
            let mut dot = 0.0;
            for t in &traj {
                let (ri, rj) = (t.row(i), t.row(j));
                for q in 0..n {
                    dot += ri[q] * rj[q];
                }
            }
            let v = dot / (norms[i] * norms[j]);
            sim.set(i, j, v);
            sim.set(j, i, v);
        }
    }
    sim
}

/// Run PTGP.
pub fn ptgp(ens: &Ensemble, k: usize, seed: u64) -> Result<ClusteringOutput> {
    ensure_arg!(ens.m() >= 1, "ptgp: empty ensemble");
    let n = ens.n();
    ensure_arg!(k >= 1 && k <= n, "ptgp: bad k");
    let mut timer = PhaseTimer::new();
    let (assign, sizes) = timer.time("microclusters", || microclusters_capped(ens, 2000));
    let n_micro = sizes.len();
    if n_micro <= k {
        // each microcluster its own consensus cluster (degenerate but valid)
        let labels: Vec<u32> = assign.iter().map(|&a| a.min(k as u32 - 1)).collect();
        return Ok(ClusteringOutput::new(labels, timer));
    }
    let coassoc = timer.time("micro_coassoc", || micro_coassociation(ens, &assign, n_micro));
    let sim = timer.time("pts", || {
        let top_t = (n_micro / 10).clamp(3, 40);
        pts_similarity(&coassoc, &sizes, top_t, 3)
    });
    // normalized-cut partition of the microcluster similarity graph,
    // size-weighted so big microclusters count proportionally.
    let labels_micro = timer.time("partition", || -> Result<Vec<u32>> {
        let mut w = sim.clone();
        for i in 0..n_micro {
            for j in 0..n_micro {
                let v = w.at(i, j) * (sizes[i] as f64).sqrt() * (sizes[j] as f64).sqrt();
                w.set(i, j, v);
            }
            let d = w.at(i, i).max(1e-9);
            w.set(i, i, d);
        }
        let emb = crate::bipartite::ncut_embedding(&w, k)?;
        let km = kmeans(
            &emb.to_f32(),
            &KmeansParams { k, max_iter: 100, ..Default::default() },
            seed,
        )?;
        Ok(km.labels)
    })?;
    let labels: Vec<u32> = assign.iter().map(|&a| labels_micro[a as usize]).collect();
    Ok(ClusteringOutput::new(labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    #[test]
    fn microclusters_group_identical_rows() {
        let mut ens = Ensemble::default();
        ens.push(vec![0, 0, 1, 1, 1]);
        ens.push(vec![0, 0, 0, 1, 1]);
        let (assign, sizes) = microclusters(&ens);
        assert_eq!(assign[0], assign[1]);
        assert_ne!(assign[1], assign[2]);
        assert_eq!(assign[3], assign[4]);
        assert_eq!(sizes.iter().sum::<u32>(), 5);
        assert_eq!(sizes.len(), 3);
    }

    #[test]
    fn perfect_ensemble_recovered() {
        let truth = vec![0u32, 0, 0, 1, 1, 1, 2, 2, 2];
        let mut ens = Ensemble::default();
        for _ in 0..4 {
            ens.push(truth.clone());
        }
        let out = ptgp(&ens, 3, 3).unwrap();
        assert!((nmi(&out.labels, &truth) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn consensus_on_moons() {
        let ds = two_moons(400, 0.06, 3);
        let ens = generate_kmeans_ensemble(&ds.x, 10, 6, 12, 5).unwrap();
        let out = ptgp(&ens, 2, 7).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.3, "nmi={score}");
    }

    #[test]
    fn pts_rows_unit_self_similarity() {
        let mut ens = Ensemble::default();
        ens.push(vec![0, 0, 1, 1, 2, 2]);
        ens.push(vec![0, 1, 1, 2, 2, 0]);
        let (assign, sizes) = microclusters(&ens);
        let c = micro_coassociation(&ens, &assign, sizes.len());
        let s = pts_similarity(&c, &sizes, 3, 2);
        for i in 0..sizes.len() {
            assert!((s.at(i, i) - 1.0).abs() < 1e-9);
        }
    }
}
