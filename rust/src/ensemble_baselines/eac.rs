//! **EAC** — Evidence Accumulation Clustering (Fred & Jain, TPAMI'05):
//! co-association matrix + average-linkage agglomerative consensus.

use super::coassoc::coassociation;
use super::linkage::average_linkage;
use crate::baselines::ClusteringOutput;
use crate::usenc::Ensemble;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Run EAC on a pre-generated ensemble.
pub fn eac(ens: &Ensemble, k: usize) -> Result<ClusteringOutput> {
    ensure_arg!(ens.m() >= 1, "eac: empty ensemble");
    ensure_arg!(k >= 1 && k <= ens.n(), "eac: bad k");
    let mut timer = PhaseTimer::new();
    let c = timer.time("coassoc", || coassociation(ens));
    let labels = timer.time("linkage", || average_linkage(&c, k));
    Ok(ClusteringOutput::new(labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    #[test]
    fn consensus_on_moons_beats_single_kmeans() {
        let ds = two_moons(400, 0.06, 1);
        let ens = generate_kmeans_ensemble(&ds.x, 10, 6, 14, 3).unwrap();
        let out = eac(&ens, 2).unwrap();
        let eac_nmi = nmi(&out.labels, &ds.y);
        let km = crate::kmeans::kmeans(
            &ds.x,
            &crate::kmeans::KmeansParams { k: 2, ..Default::default() },
            3,
        )
        .unwrap();
        let km_nmi = nmi(&km.labels, &ds.y);
        // EAC chains k-means fragments back together on nonconvex shapes.
        assert!(eac_nmi > km_nmi, "eac {eac_nmi} vs kmeans {km_nmi}");
    }

    #[test]
    fn perfect_ensemble_gives_perfect_consensus() {
        let truth = vec![0u32, 0, 0, 1, 1, 1, 2, 2, 2];
        let mut ens = Ensemble::default();
        for _ in 0..3 {
            ens.push(truth.clone());
        }
        let out = eac(&ens, 3).unwrap();
        assert!((nmi(&out.labels, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_bad_args() {
        let ens = Ensemble::default();
        assert!(eac(&ens, 2).is_err());
    }
}
