//! **KCC** — K-means-based Consensus Clustering (Wu et al., TKDE'15).
//! With the U_c utility, the consensus problem is exactly k-means over the
//! rows of the binary object×cluster incidence matrix B̃ — which is how we
//! realize it (the unified-view theorem of the KCC paper).

use crate::baselines::ClusteringOutput;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::Mat;
use crate::usenc::Ensemble;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Densify the ensemble incidence into an N×k_c f32 matrix.
pub fn incidence_dense(ens: &Ensemble) -> Mat {
    let b = ens.incidence();
    let mut x = Mat::zeros(b.rows, b.cols);
    for i in 0..b.rows {
        let (cols, vals) = b.row(i);
        for (c, v) in cols.iter().zip(vals) {
            x.set(i, *c as usize, *v as f32);
        }
    }
    x
}

/// Run KCC (U_c utility = plain k-means on B̃).
pub fn kcc(ens: &Ensemble, k: usize, seed: u64) -> Result<ClusteringOutput> {
    ensure_arg!(ens.m() >= 1, "kcc: empty ensemble");
    ensure_arg!(k >= 1 && k <= ens.n(), "kcc: bad k");
    let mut timer = PhaseTimer::new();
    let x = timer.time("binary_matrix", || incidence_dense(ens));
    let km = timer.time("kmeans", || {
        kmeans(&x, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    #[test]
    fn perfect_ensemble_recovered() {
        let truth = vec![0u32, 0, 0, 0, 1, 1, 1, 1];
        let mut ens = Ensemble::default();
        for _ in 0..4 {
            ens.push(truth.clone());
        }
        let out = kcc(&ens, 2, 3).unwrap();
        assert!((nmi(&out.labels, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn consensus_on_moons() {
        let ds = two_moons(400, 0.06, 4);
        let ens = generate_kmeans_ensemble(&ds.x, 10, 6, 12, 5).unwrap();
        let out = kcc(&ens, 2, 7).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.2, "nmi={score}"); // KCC is weak on nonconvex data (Table 7)
    }

    #[test]
    fn incidence_dense_row_sums_equal_m() {
        let ds = two_moons(100, 0.05, 6);
        let ens = generate_kmeans_ensemble(&ds.x, 5, 3, 6, 7).unwrap();
        let x = incidence_dense(&ens);
        for i in 0..100 {
            let s: f32 = x.row(i).iter().sum();
            assert_eq!(s, 5.0);
        }
    }
}
