//! Average-linkage agglomerative clustering on a dense similarity matrix
//! (Lance–Williams update). Substrate for EAC and WCT. O(N²) memory,
//! O(N² log N)-ish time with the nearest-neighbor cache — fine at the
//! scales where the N×N co-association itself is feasible.

use crate::linalg::DMat;

/// Cut an average-linkage dendrogram over similarity `s` at `k` clusters.
/// Returns dense labels 0..k-1.
pub fn average_linkage(s: &DMat, k: usize) -> Vec<u32> {
    let n = s.rows;
    assert_eq!(s.rows, s.cols);
    assert!(k >= 1 && k <= n, "average_linkage: bad k={k} for n={n}");
    // Working similarity matrix; sim[i][j] for active clusters.
    let mut sim = s.clone();
    let mut size = vec![1usize; n];
    let mut active = vec![true; n];
    // parent mapping for final label extraction
    let mut members: Vec<Vec<u32>> = (0..n).map(|i| vec![i as u32]).collect();
    // nearest-neighbor cache: best[j] = (best similarity, argmax) over active i≠j
    let mut best: Vec<(f64, usize)> = (0..n)
        .map(|i| {
            let mut b = (f64::NEG_INFINITY, usize::MAX);
            for j in 0..n {
                if j != i && sim.at(i, j) > b.0 {
                    b = (sim.at(i, j), j);
                }
            }
            b
        })
        .collect();
    let mut clusters = n;
    while clusters > k {
        // find globally most similar active pair via the cache
        let mut bi = usize::MAX;
        let mut bv = f64::NEG_INFINITY;
        for i in 0..n {
            if active[i] && best[i].0 > bv {
                bv = best[i].0;
                bi = i;
            }
        }
        let bj = best[bi].1;
        debug_assert!(active[bj]);
        // merge bj into bi (average linkage)
        let (si, sj) = (size[bi] as f64, size[bj] as f64);
        for t in 0..n {
            if active[t] && t != bi && t != bj {
                let v = (si * sim.at(bi, t) + sj * sim.at(bj, t)) / (si + sj);
                sim.set(bi, t, v);
                sim.set(t, bi, v);
            }
        }
        active[bj] = false;
        size[bi] += size[bj];
        let moved = std::mem::take(&mut members[bj]);
        members[bi].extend(moved);
        // refresh caches referencing bi/bj
        for i in 0..n {
            if !active[i] {
                continue;
            }
            if i == bi || best[i].1 == bi || best[i].1 == bj {
                let mut b = (f64::NEG_INFINITY, usize::MAX);
                for j in 0..n {
                    if active[j] && j != i && sim.at(i, j) > b.0 {
                        b = (sim.at(i, j), j);
                    }
                }
                best[i] = b;
            }
        }
        clusters -= 1;
    }
    let mut labels = vec![0u32; n];
    let mut next = 0u32;
    for i in 0..n {
        if active[i] {
            for &obj in &members[i] {
                labels[obj as usize] = next;
            }
            next += 1;
        }
    }
    labels
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Block-diagonal similarity: two obvious groups.
    fn two_blocks() -> DMat {
        let mut s = DMat::zeros(6, 6);
        for i in 0..6 {
            for j in 0..6 {
                let same = (i < 3) == (j < 3);
                s.set(i, j, if same { 0.9 } else { 0.1 });
            }
        }
        s
    }

    #[test]
    fn recovers_blocks() {
        let labels = average_linkage(&two_blocks(), 2);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[4]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn k_equals_n_and_one() {
        let s = two_blocks();
        let l1 = average_linkage(&s, 1);
        assert!(l1.iter().all(|&l| l == 0));
        let ln = average_linkage(&s, 6);
        let set: std::collections::HashSet<_> = ln.iter().collect();
        assert_eq!(set.len(), 6);
    }

    #[test]
    fn chain_merge_order() {
        // three points on a line in similarity space: 0~1 strong, 1~2 weak
        let mut s = DMat::zeros(3, 3);
        s.set(0, 1, 0.9);
        s.set(1, 0, 0.9);
        s.set(1, 2, 0.2);
        s.set(2, 1, 0.2);
        let labels = average_linkage(&s, 2);
        assert_eq!(labels[0], labels[1]);
        assert_ne!(labels[0], labels[2]);
    }
}
