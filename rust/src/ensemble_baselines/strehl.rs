//! The three hypergraph consensus functions of Strehl & Ghosh (JMLR'03,
//! ref. [18] of the paper): **CSPA**, **HGPA**, and **MCLA**. All three
//! reduce ensemble consensus to a graph partitioning problem solved here
//! by the multilevel partitioner in [`crate::graphpart`] (the original
//! implementations call METIS/hMETIS, ref. [23]).
//!
//! These are provided beyond the paper's own baseline set (Tables 7–9) for
//! the consensus-function ablation bench (`ablation_consensus`): the same
//! U-SPEC ensembles fused by the bipartite transfer cut (U-SENC) versus
//! the classic hypergraph family.

use crate::graphpart::{partition, Graph, PartitionParams};
use crate::usenc::Ensemble;
use crate::{ensure_arg, Result};

/// CSPA — cluster-based similarity partitioning. Builds the N×N
/// co-association similarity and partitions its graph with METIS-style
/// k-way partitioning. O(N²·m) time and O(N²) memory: like EAC/WCT it is
/// infeasible past ~10⁵ objects (which is exactly why the paper's
/// consensus operates on the N×k_c bipartite graph instead).
pub fn cspa(ens: &Ensemble, k: usize, seed: u64) -> Result<Vec<u32>> {
    ensure_arg!(ens.m() >= 1, "cspa: empty ensemble");
    let n = ens.n();
    ensure_arg!(k >= 1 && k <= n, "cspa: bad k={k} for n={n}");
    let co = super::coassoc::coassociation(ens);
    let mut edges = Vec::new();
    for i in 0..n {
        for j in (i + 1)..n {
            let w = co.at(i, j);
            if w > 0.0 {
                edges.push((i as u32, j as u32, w));
            }
        }
    }
    let g = Graph::from_edges(n, &edges);
    partition(&g, k, &PartitionParams::default(), seed)
}

/// HGPA — hypergraph partitioning. Each cluster in the ensemble is a
/// hyperedge over its members; the minimum hyperedge cut with balanced
/// parts is approximated via the standard *star expansion*: one auxiliary
/// vertex per hyperedge connected to its members with weight 1/|C|, and
/// (near-)zero vertex weight so balance is computed over objects only.
pub fn hgpa(ens: &Ensemble, k: usize, seed: u64) -> Result<Vec<u32>> {
    ensure_arg!(ens.m() >= 1, "hgpa: empty ensemble");
    let n = ens.n();
    ensure_arg!(k >= 1 && k <= n, "hgpa: bad k={k} for n={n}");
    let b = ens.incidence();
    let kc = b.cols;
    // vertices: 0..n objects, n..n+kc hyperedge stars
    let mut sizes = vec![0usize; kc];
    for idx in &b.indices {
        sizes[*idx as usize] += 1;
    }
    let mut edges = Vec::with_capacity(b.nnz());
    for i in 0..n {
        let (cols, _) = b.row(i);
        for &c in cols {
            let sz = sizes[c as usize].max(1);
            edges.push((i as u32, (n + c as usize) as u32, 1.0 / sz as f64));
        }
    }
    let mut g = Graph::from_edges(n + kc, &edges);
    for v in n..n + kc {
        g.vwgt[v] = 1e-6; // stars are (almost) weightless for balance
    }
    let part = partition(&g, k, &PartitionParams::default(), seed)?;
    Ok(part[..n].to_vec())
}

/// MCLA — meta-clustering. Clusters become vertices of a meta-graph with
/// binary-Jaccard edge weights; the meta-graph is partitioned into k
/// meta-clusters; each object joins the meta-cluster in which it
/// participates most strongly (average incidence, ties → lower id).
pub fn mcla(ens: &Ensemble, k: usize, seed: u64) -> Result<Vec<u32>> {
    ensure_arg!(ens.m() >= 1, "mcla: empty ensemble");
    let n = ens.n();
    ensure_arg!(k >= 1 && k <= n, "mcla: bad k={k} for n={n}");
    let b = ens.incidence();
    let kc = b.cols;
    ensure_arg!(k <= kc, "mcla: k={k} > total clusters {kc}");
    // cluster membership lists
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); kc];
    for i in 0..n {
        let (cols, _) = b.row(i);
        for &c in cols {
            members[c as usize].push(i as u32);
        }
    }
    // pairwise Jaccard between clusters (via sorted-list intersection)
    let mut edges = Vec::new();
    for a in 0..kc {
        for c in (a + 1)..kc {
            let inter = intersect_count(&members[a], &members[c]);
            if inter == 0 {
                continue;
            }
            let union = members[a].len() + members[c].len() - inter;
            edges.push((a as u32, c as u32, inter as f64 / union as f64));
        }
    }
    let mut g = Graph::from_edges(kc, &edges);
    // meta-graph vertex weight = cluster size (balances object mass)
    for c in 0..kc {
        g.vwgt[c] = members[c].len().max(1) as f64;
    }
    let meta = partition(&g, k, &PartitionParams::default(), seed)?;
    // association strength of each object with each meta-cluster
    let mut meta_sizes = vec![0usize; k];
    for &p in &meta {
        meta_sizes[p as usize] += 1;
    }
    let mut labels = vec![0u32; n];
    let mut assoc = vec![0.0f64; k];
    for i in 0..n {
        for a in assoc.iter_mut() {
            *a = 0.0;
        }
        let (cols, _) = b.row(i);
        for &c in cols {
            let p = meta[c as usize] as usize;
            assoc[p] += 1.0 / meta_sizes[p].max(1) as f64;
        }
        let best = assoc
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap().then(b.0.cmp(&a.0)))
            .map(|(p, _)| p)
            .unwrap_or(0);
        labels[i] = best as u32;
    }
    Ok(labels)
}

/// HBGF — hybrid bipartite graph formulation (Fern & Brodley, ICML'04,
/// ref. [22]): objects AND clusters are vertices of one bipartite graph
/// (edge (x, C) = 1 iff x ∈ C) partitioned jointly by METIS-style k-way
/// partitioning; the object labels are read off the joint partition.
/// This is the *graph-partitioning* counterpart of the paper's spectral
/// transfer cut over the same graph.
pub fn hbgf(ens: &Ensemble, k: usize, seed: u64) -> Result<Vec<u32>> {
    ensure_arg!(ens.m() >= 1, "hbgf: empty ensemble");
    let n = ens.n();
    ensure_arg!(k >= 1 && k <= n, "hbgf: bad k={k} for n={n}");
    let b = ens.incidence();
    let kc = b.cols;
    let mut edges = Vec::with_capacity(b.nnz());
    for i in 0..n {
        let (cols, _) = b.row(i);
        for &c in cols {
            edges.push((i as u32, (n + c as usize) as u32, 1.0));
        }
    }
    let mut g = Graph::from_edges(n + kc, &edges);
    // Fern & Brodley balance over objects; cluster vertices carry the mass
    // of their members on the other side — weight both sides equally.
    for v in n..n + kc {
        g.vwgt[v] = 1e-6;
    }
    let part = partition(&g, k, &PartitionParams::default(), seed)?;
    Ok(part[..n].to_vec())
}

/// Sorted-slice intersection size.
fn intersect_count(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut c) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    /// A clean 3-cluster ensemble where all bases agree.
    fn agreeing_ensemble(n_per: usize, m: usize) -> (Ensemble, Vec<u32>) {
        let truth: Vec<u32> =
            (0..3 * n_per).map(|i| (i / n_per) as u32).collect();
        let mut ens = Ensemble::default();
        for _ in 0..m {
            ens.push(truth.clone());
        }
        (ens, truth)
    }

    #[test]
    fn all_recover_unanimous_ensemble() {
        let (ens, truth) = agreeing_ensemble(30, 4);
        for (name, f) in [
            ("cspa", cspa as fn(&Ensemble, usize, u64) -> Result<Vec<u32>>),
            ("hgpa", hgpa),
            ("mcla", mcla),
            ("hbgf", hbgf),
        ] {
            let labels = f(&ens, 3, 7).unwrap();
            let score = nmi(&labels, &truth);
            assert!(score > 0.99, "{name}: nmi={score}");
        }
    }

    /// Three far-apart Gaussian blobs: k-means with k∈[4,8] over-clusters,
    /// but fragments never span blobs, so every consensus function must
    /// reassemble the blobs exactly.
    fn blobs(n_per: usize, seed: u64) -> (crate::linalg::Mat, Vec<u32>) {
        let mut rng = crate::util::rng::Rng::new(seed);
        let centers = [(0.0, 0.0), (25.0, 0.0), (0.0, 25.0)];
        let n = 3 * n_per;
        let mut x = crate::linalg::Mat::zeros(n, 2);
        let mut y = vec![0u32; n];
        for i in 0..n {
            let c = i / n_per;
            y[i] = c as u32;
            x.set(i, 0, (centers[c].0 + rng.normal()) as f32);
            x.set(i, 1, (centers[c].1 + rng.normal()) as f32);
        }
        (x, y)
    }

    #[test]
    fn consensus_on_kmeans_ensemble() {
        let (x, y) = blobs(120, 11);
        let ens = generate_kmeans_ensemble(&x, 8, 4, 8, 3).unwrap();
        for (name, f) in [
            ("cspa", cspa as fn(&Ensemble, usize, u64) -> Result<Vec<u32>>),
            ("mcla", mcla),
            ("hbgf", hbgf),
            ("hgpa", hgpa),
        ] {
            let labels = f(&ens, 3, 5).unwrap();
            let score = nmi(&labels, &y);
            assert!(score > 0.8, "{name}: nmi={score}");
            assert_eq!(labels.len(), 360);
        }
    }

    #[test]
    fn consensus_on_moons_uspec_ensemble_beats_random() {
        // Nonlinear moons: fragments from k-means cross the moons, so the
        // hypergraph family is *expected* to be weak here — this is exactly
        // the gap U-SENC's diverse U-SPEC generation closes (ablation
        // bench `ablation_consensus`). We only require valid output.
        let ds = two_moons(300, 0.05, 11);
        let ens = generate_kmeans_ensemble(&ds.x, 6, 4, 8, 3).unwrap();
        for f in [cspa as fn(&Ensemble, usize, u64) -> Result<Vec<u32>>, mcla, hbgf] {
            let labels = f(&ens, 2, 5).unwrap();
            assert_eq!(labels.len(), 300);
            assert!(labels.iter().all(|&l| l < 2));
        }
    }

    #[test]
    fn label_range_and_errors() {
        let (ens, _) = agreeing_ensemble(10, 2);
        let labels = mcla(&ens, 3, 1).unwrap();
        assert!(labels.iter().all(|&l| l < 3));
        assert!(cspa(&Ensemble::default(), 2, 1).is_err());
        assert!(hgpa(&ens, 0, 1).is_err());
        assert!(mcla(&ens, 31, 1).is_err()); // k > n
    }

    #[test]
    fn intersect_count_basic() {
        assert_eq!(intersect_count(&[1, 3, 5], &[2, 3, 5, 9]), 2);
        assert_eq!(intersect_count(&[], &[1]), 0);
        assert_eq!(intersect_count(&[7], &[7]), 1);
    }

    #[test]
    fn mcla_jaccard_metagraph_sane() {
        // two bases with identical partitions → their clusters pair up with
        // Jaccard 1.0 and mcla reproduces the partition exactly.
        let mut ens = Ensemble::default();
        ens.push(vec![0, 0, 0, 1, 1, 1]);
        ens.push(vec![1, 1, 1, 0, 0, 0]); // same partition, swapped labels
        let labels = mcla(&ens, 2, 9).unwrap();
        let truth = vec![0, 0, 0, 1, 1, 1];
        assert!((nmi(&labels, &truth) - 1.0).abs() < 1e-9);
    }
}
