//! The seven baseline ensemble-clustering methods of the paper's §4.4
//! (Tables 7–9): EAC, WCT, KCC, PTGP, ECC, SEC, LWGP. All consume an
//! [`Ensemble`] of base clusterings; following the baselines' own papers
//! (and the paper's experimental protocol), their ensembles are generated
//! by k-means with per-clusterer random k ∈ [k_min, k_max].

pub mod linkage;
pub mod coassoc;
pub mod eac;
pub mod wct;
pub mod kcc;
pub mod ecc;
pub mod sec;
pub mod ptgp;
pub mod lwgp;
pub mod strehl;

use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::Mat;
use crate::usenc::{draw_base_k, Ensemble};
use crate::util::rng::Rng;
use crate::Result;

/// Identifier for every method in Tables 7–9.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EnsembleMethod {
    Eac,
    Wct,
    Kcc,
    Ptgp,
    Ecc,
    Sec,
    Lwgp,
    Usenc,
}

impl EnsembleMethod {
    pub const ALL: [EnsembleMethod; 8] = [
        EnsembleMethod::Eac,
        EnsembleMethod::Wct,
        EnsembleMethod::Kcc,
        EnsembleMethod::Ptgp,
        EnsembleMethod::Ecc,
        EnsembleMethod::Sec,
        EnsembleMethod::Lwgp,
        EnsembleMethod::Usenc,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            EnsembleMethod::Eac => "EAC",
            EnsembleMethod::Wct => "WCT",
            EnsembleMethod::Kcc => "KCC",
            EnsembleMethod::Ptgp => "PTGP",
            EnsembleMethod::Ecc => "ECC",
            EnsembleMethod::Sec => "SEC",
            EnsembleMethod::Lwgp => "LWGP",
            EnsembleMethod::Usenc => "U-SENC",
        }
    }

    pub fn from_name(s: &str) -> Option<EnsembleMethod> {
        EnsembleMethod::ALL.iter().copied().find(|m| m.name().eq_ignore_ascii_case(s))
    }

    /// Peak-memory model (bytes) at problem size n with ensemble size m and
    /// k_c total base clusters. EAC/WCT materialize the N×N co-association
    /// (the paper's N/A cut-off above MNIST); the rest are O(N·(m+k_c)).
    pub fn peak_memory_bytes(&self, n: u64, d: u64, m: u64, kc: u64) -> u64 {
        let f = 8u64;
        match self {
            EnsembleMethod::Eac | EnsembleMethod::Wct => f * n * n + f * n * d,
            // sparse incidence (m non-zeros/row) + k_c-wide centroid table
            EnsembleMethod::Kcc | EnsembleMethod::Ecc | EnsembleMethod::Sec => {
                f * n * m + f * kc * 64 + f * n * d
            }
            EnsembleMethod::Ptgp => f * n * (m + 4) + f * n * d, // microcluster-side is ≪ N
            EnsembleMethod::Lwgp => f * n * (m + 4) + f * n * d,
            EnsembleMethod::Usenc => {
                let sp = 32u64; // √p at p=1000
                f * n * (sp + m) + f * n * d
            }
        }
    }
}

/// Generate an ensemble of `m` k-means base clusterings with random
/// kⁱ ∈ [k_min, k_max] — the base-clusterer protocol of all seven baseline
/// papers (paper §4.2, last bullet).
pub fn generate_kmeans_ensemble(
    x: &Mat,
    m: usize,
    k_min: usize,
    k_max: usize,
    seed: u64,
) -> Result<Ensemble> {
    let mut rng = Rng::new(seed);
    let mut ens = Ensemble::default();
    for i in 0..m {
        let ki = draw_base_k(&mut rng, k_min, k_max, x.rows);
        let r = kmeans(
            x,
            &KmeansParams { k: ki, max_iter: 30, tol: 1e-3, ..Default::default() },
            rng.fork(i as u64).next_u64(),
        )?;
        ens.push(r.labels);
    }
    Ok(ens)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    #[test]
    fn kmeans_ensemble_shape() {
        let ds = two_moons(300, 0.05, 1);
        let ens = generate_kmeans_ensemble(&ds.x, 5, 4, 9, 7).unwrap();
        assert_eq!(ens.m(), 5);
        assert_eq!(ens.n(), 300);
        for k in ens.ks() {
            assert!((4..=9).contains(&k), "k={k}");
        }
    }

    #[test]
    fn memory_model_na_pattern() {
        // EAC/WCT: fit MNIST (70k), fail Covertype (581k) — Table 7.
        let budget = 64u64 * (1 << 30);
        assert!(EnsembleMethod::Eac.peak_memory_bytes(70_000, 784, 20, 800) <= budget);
        assert!(EnsembleMethod::Wct.peak_memory_bytes(581_012, 54, 20, 800) > budget);
        // everything else fits Flower-20M
        for m in [
            EnsembleMethod::Kcc,
            EnsembleMethod::Ptgp,
            EnsembleMethod::Ecc,
            EnsembleMethod::Sec,
            EnsembleMethod::Lwgp,
            EnsembleMethod::Usenc,
        ] {
            assert!(m.peak_memory_bytes(20_000_000, 2, 20, 800) <= budget, "{}", m.name());
        }
    }
}
