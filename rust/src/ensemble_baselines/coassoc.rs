//! Co-association matrix (Fred & Jain's evidence accumulation): the N×N
//! matrix whose (i,j) entry is the fraction of base clusterings that put
//! i and j in the same cluster. O(N²m) time, O(N²) memory — the substrate
//! of EAC and WCT (and the reason they go N/A past MNIST scale).

use crate::linalg::DMat;
use crate::usenc::Ensemble;
use crate::util::par;

/// Dense co-association matrix, entries in [0, 1], unit diagonal.
pub fn coassociation(ens: &Ensemble) -> DMat {
    let n = ens.n();
    let m = ens.m();
    let mut c = DMat::zeros(n, n);
    let inv = 1.0 / m as f64;
    par::par_for_chunks(&mut c.data, n, |start, chunk| {
        let i = start / n;
        for (j, v) in chunk.iter_mut().enumerate() {
            let mut same = 0usize;
            for l in &ens.labelings {
                if l[i] == l[j] {
                    same += 1;
                }
            }
            *v = same as f64 * inv;
        }
    });
    c
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Ensemble {
        let mut e = Ensemble::default();
        e.push(vec![0, 0, 1, 1]);
        e.push(vec![0, 1, 1, 1]);
        e
    }

    #[test]
    fn values() {
        let c = coassociation(&toy());
        assert_eq!(c.at(0, 0), 1.0);
        assert_eq!(c.at(0, 1), 0.5); // together in base 0 only
        assert_eq!(c.at(2, 3), 1.0);
        assert_eq!(c.at(0, 2), 0.0);
        // symmetric
        for i in 0..4 {
            for j in 0..4 {
                assert_eq!(c.at(i, j), c.at(j, i));
            }
        }
    }
}
