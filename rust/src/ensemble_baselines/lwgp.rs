//! **LWGP** — Locally Weighted Graph Partitioning (Huang et al., TCYB'18).
//! Each base cluster is weighted by its *ensemble-driven cluster index*
//! (ECI) — the exponential of its negative mean entropy against the other
//! base clusterings; reliable clusters (consistently reproduced across the
//! ensemble) get weight ≈ 1, noisy ones are damped. The weighted
//! object×cluster bipartite graph is then partitioned by the transfer cut.

use crate::baselines::ClusteringOutput;
use crate::bipartite::{transfer_cut, EigSolver};
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::Csr;
use crate::usenc::Ensemble;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// ECI of every cluster in the ensemble (flattened over the incidence
/// column order). `theta` is the damping parameter (0.4 in the original).
pub fn cluster_eci(ens: &Ensemble, theta: f64) -> Vec<f64> {
    let n = ens.n();
    let m = ens.m();
    let ks = ens.ks();
    let kc: usize = ks.iter().sum();
    let mut offsets = vec![0usize; m];
    let mut acc = 0;
    for (t, &kt) in ks.iter().enumerate() {
        offsets[t] = acc;
        acc += kt;
    }
    // member lists per cluster
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); kc];
    for i in 0..n {
        for (t, l) in ens.labelings.iter().enumerate() {
            members[offsets[t] + l[i] as usize].push(i as u32);
        }
    }
    // entropy of cluster C against base clustering t':
    //   H_{t'}(C) = −Σ_j p_j log2 p_j,  p_j = |C ∩ C'_j| / |C|
    let mut eci = vec![0.0f64; kc];
    for (c, mem) in members.iter().enumerate() {
        if mem.is_empty() {
            continue;
        }
        let mut h = 0.0;
        for l in &ens.labelings {
            let mut counts: std::collections::HashMap<u32, usize> = std::collections::HashMap::new();
            for &i in mem {
                *counts.entry(l[i as usize]).or_insert(0) += 1;
            }
            for (_, &cnt) in counts.iter() {
                let p = cnt as f64 / mem.len() as f64;
                h -= p * p.log2();
            }
        }
        eci[c] = (-h / (theta * m as f64)).exp();
    }
    eci
}

/// Run LWGP: ECI-weighted bipartite graph + transfer cut.
pub fn lwgp(ens: &Ensemble, k: usize, seed: u64) -> Result<ClusteringOutput> {
    ensure_arg!(ens.m() >= 1, "lwgp: empty ensemble");
    let n = ens.n();
    ensure_arg!(k >= 1 && k <= n, "lwgp: bad k");
    let mut timer = PhaseTimer::new();
    let eci = timer.time("eci", || cluster_eci(ens, 0.4));
    let b = timer.time("weighted_graph", || {
        let raw = ens.incidence();
        // scale column j by ECI_j
        let mut vals = raw.values.clone();
        for (v, c) in vals.iter_mut().zip(raw.indices.iter()) {
            *v *= eci[*c as usize].max(1e-9);
        }
        Csr { rows: raw.rows, cols: raw.cols, indptr: raw.indptr, indices: raw.indices, values: vals }
    });
    ensure_arg!(k <= b.cols, "lwgp: k > total clusters");
    let tc = timer.time("transfer_cut", || transfer_cut(&b, k, EigSolver::Auto, seed))?;
    let mut emb = tc.embedding.clone();
    crate::bipartite::row_normalize(&mut emb);
    let km = timer.time("discretize", || {
        kmeans(&emb, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed ^ 0x1)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    #[test]
    fn eci_rewards_consistent_clusters() {
        let mut ens = Ensemble::default();
        // cluster {0,1,2} reproduced identically in both clusterings,
        // objects 3..6 split inconsistently
        ens.push(vec![0, 0, 0, 1, 1, 2, 2]);
        ens.push(vec![0, 0, 0, 1, 2, 1, 2]);
        let eci = cluster_eci(&ens, 0.4);
        // cluster 0 of base 0 (cols 0) is perfectly stable -> ECI = 1
        assert!((eci[0] - 1.0).abs() < 1e-12, "{:?}", eci);
        // the noisy clusters have lower ECI
        assert!(eci[1] < 1.0);
    }

    #[test]
    fn perfect_ensemble_recovered() {
        let truth = vec![0u32, 0, 0, 1, 1, 1, 2, 2, 2];
        let mut ens = Ensemble::default();
        for _ in 0..4 {
            ens.push(truth.clone());
        }
        let out = lwgp(&ens, 3, 5).unwrap();
        assert!((nmi(&out.labels, &truth) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn strong_consensus_on_moons() {
        // LWGP is the strongest baseline in Table 7; expect a solid score.
        let ds = two_moons(500, 0.06, 4);
        let ens = generate_kmeans_ensemble(&ds.x, 10, 6, 14, 5).unwrap();
        let out = lwgp(&ens, 2, 9).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.4, "nmi={score}");
    }
}
