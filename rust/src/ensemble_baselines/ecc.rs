//! **ECC** — Entropy-based Consensus Clustering (Liu et al.,
//! Bioinformatics'17). The entropy utility makes the consensus a hard-EM
//! fit of a mixture of products of categoricals: each consensus cluster
//! keeps, per base clustering, a distribution over that clustering's
//! labels; objects are assigned by categorical log-likelihood. (This is
//! the Bregman-divergence k-means the KCC unified view associates with the
//! U_H utility.)

use crate::baselines::ClusteringOutput;
use crate::usenc::Ensemble;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Run ECC for `k` consensus clusters.
pub fn ecc(ens: &Ensemble, k: usize, seed: u64) -> Result<ClusteringOutput> {
    ensure_arg!(ens.m() >= 1, "ecc: empty ensemble");
    let n = ens.n();
    ensure_arg!(k >= 1 && k <= n, "ecc: bad k");
    let mut timer = PhaseTimer::new();
    let m = ens.m();
    let ks = ens.ks();
    let mut rng = Rng::new(seed);
    // Initialize from the first base clustering (folded onto k labels) —
    // a far better EM start than uniform noise; ties broken randomly.
    let mut labels: Vec<u32> = ens.labelings[0].iter().map(|&l| l % k as u32).collect();
    // ensure every consensus cluster is seeded
    for c in 0..k {
        if !labels.iter().any(|&l| l == c as u32) {
            let i = rng.usize(n);
            labels[i] = c as u32;
        }
    }
    let eps = 1e-6;

    timer.time("hard_em", || {
        // offsets into a flat θ[k][Σ kᵢ] table
        let mut offsets = vec![0usize; m];
        let mut acc = 0;
        for (t, &kt) in ks.iter().enumerate() {
            offsets[t] = acc;
            acc += kt;
        }
        let kc = acc;
        for _iter in 0..50 {
            // M step: per consensus-cluster categorical distributions
            let mut counts = vec![0.0f64; k * kc];
            let mut sizes = vec![0.0f64; k];
            for i in 0..n {
                let c = labels[i] as usize;
                sizes[c] += 1.0;
                for (t, l) in ens.labelings.iter().enumerate() {
                    counts[c * kc + offsets[t] + l[i] as usize] += 1.0;
                }
            }
            // log θ with Laplace smoothing
            let mut logtheta = vec![0.0f64; k * kc];
            for c in 0..k {
                for t in 0..m {
                    let kt = ks[t];
                    let denom = sizes[c] + eps * kt as f64;
                    for j in 0..kt {
                        let p = (counts[c * kc + offsets[t] + j] + eps) / denom.max(eps);
                        logtheta[c * kc + offsets[t] + j] = p.ln();
                    }
                }
            }
            // E step (hard): assign by max log-likelihood
            let mut changed = 0usize;
            for i in 0..n {
                let mut best = 0usize;
                let mut best_ll = f64::NEG_INFINITY;
                for c in 0..k {
                    let mut ll = 0.0;
                    for (t, l) in ens.labelings.iter().enumerate() {
                        ll += logtheta[c * kc + offsets[t] + l[i] as usize];
                    }
                    if ll > best_ll {
                        best_ll = ll;
                        best = c;
                    }
                }
                if labels[i] != best as u32 {
                    labels[i] = best as u32;
                    changed += 1;
                }
            }
            if changed == 0 {
                break;
            }
        }
    });
    Ok(ClusteringOutput::new(labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    #[test]
    fn perfect_ensemble_recovered() {
        let truth = vec![0u32, 0, 0, 1, 1, 1, 2, 2, 2];
        let mut ens = Ensemble::default();
        for _ in 0..5 {
            ens.push(truth.clone());
        }
        let out = ecc(&ens, 3, 11).unwrap();
        assert!((nmi(&out.labels, &truth) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn consensus_runs_on_kmeans_ensemble() {
        let ds = two_moons(300, 0.06, 1);
        let ens = generate_kmeans_ensemble(&ds.x, 8, 5, 10, 3).unwrap();
        let out = ecc(&ens, 2, 5).unwrap();
        assert_eq!(out.labels.len(), 300);
        let score = nmi(&out.labels, &ds.y);
        assert!(score >= 0.0); // ECC is weak on nonconvex data; just sanity
    }

    #[test]
    fn rejects_bad() {
        assert!(ecc(&Ensemble::default(), 2, 1).is_err());
    }
}
