//! **WCT** — Weighted Connected Triple (Iam-On et al., TPAMI'11): refines
//! the co-association matrix with cluster-level link information. Two
//! clusters that share many members with a common third cluster form a
//! "connected triple"; object pairs that never co-occur still receive
//! similarity through the WCT score of their host clusters.

use super::linkage::average_linkage;
use crate::baselines::ClusteringOutput;
use crate::linalg::DMat;
use crate::usenc::Ensemble;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Cluster-level WCT similarity over all k_c clusters of the ensemble.
/// wct(a, b) = Σ_c min(J(a,c), J(b,c)) / max_triple, J = Jaccard overlap.
pub fn cluster_wct(ens: &Ensemble) -> DMat {
    let b = ens.incidence();
    let kc = b.cols;
    let n = ens.n();
    // membership sets per cluster (bitset-free: sorted vecs)
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); kc];
    for i in 0..n {
        for &c in b.row(i).0 {
            members[c as usize].push(i as u32);
        }
    }
    // pairwise Jaccard between clusters (k_c is small: Σkᵢ ≈ m·k̄)
    let mut jac = DMat::zeros(kc, kc);
    for a in 0..kc {
        for c in (a + 1)..kc {
            let inter = intersect_size(&members[a], &members[c]);
            if inter == 0 {
                continue;
            }
            let uni = members[a].len() + members[c].len() - inter;
            let j = inter as f64 / uni as f64;
            jac.set(a, c, j);
            jac.set(c, a, j);
        }
    }
    // connected-triple accumulation
    let mut wct = DMat::zeros(kc, kc);
    let mut maxv = 0.0f64;
    for a in 0..kc {
        for bq in (a + 1)..kc {
            let mut s = 0.0;
            for c in 0..kc {
                if c != a && c != bq {
                    s += jac.at(a, c).min(jac.at(bq, c));
                }
            }
            wct.set(a, bq, s);
            wct.set(bq, a, s);
            maxv = maxv.max(s);
        }
    }
    if maxv > 0.0 {
        for v in wct.data.iter_mut() {
            *v /= maxv;
        }
    }
    wct
}

/// Refined co-association: pairs in the same cluster contribute 1; pairs in
/// different clusters contribute `dc · wct` of their host clusters
/// (dc = decay constant, 0.8 in the original paper).
pub fn refined_coassociation(ens: &Ensemble, dc: f64) -> DMat {
    let n = ens.n();
    let m = ens.m();
    let wct = cluster_wct(ens);
    // per-base-clustering column offsets
    let ks = ens.ks();
    let mut offsets = vec![0usize; m];
    let mut acc = 0;
    for (i, &k) in ks.iter().enumerate() {
        offsets[i] = acc;
        acc += k;
    }
    let mut out = DMat::zeros(n, n);
    let inv = 1.0 / m as f64;
    crate::util::par::par_for_chunks(&mut out.data, n, |start, chunk| {
        let i = start / n;
        for (j, v) in chunk.iter_mut().enumerate() {
            let mut s = 0.0;
            for (t, l) in ens.labelings.iter().enumerate() {
                if l[i] == l[j] {
                    s += 1.0;
                } else {
                    let ca = offsets[t] + l[i] as usize;
                    let cb = offsets[t] + l[j] as usize;
                    s += dc * wct.at(ca, cb);
                }
            }
            *v = s * inv;
        }
    });
    out
}

/// Run WCT consensus.
pub fn wct(ens: &Ensemble, k: usize) -> Result<ClusteringOutput> {
    ensure_arg!(ens.m() >= 1, "wct: empty ensemble");
    ensure_arg!(k >= 1 && k <= ens.n(), "wct: bad k");
    let mut timer = PhaseTimer::new();
    let c = timer.time("refined_coassoc", || refined_coassociation(ens, 0.8));
    let labels = timer.time("linkage", || average_linkage(&c, k));
    Ok(ClusteringOutput::new(labels, timer))
}

fn intersect_size(a: &[u32], b: &[u32]) -> usize {
    let (mut i, mut j, mut c) = (0, 0, 0);
    while i < a.len() && j < b.len() {
        match a[i].cmp(&b[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                c += 1;
                i += 1;
                j += 1;
            }
        }
    }
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    #[test]
    fn refined_at_least_plain_coassoc() {
        let ds = two_moons(200, 0.06, 1);
        let ens = generate_kmeans_ensemble(&ds.x, 6, 4, 8, 3).unwrap();
        let plain = super::super::coassoc::coassociation(&ens);
        let refined = refined_coassociation(&ens, 0.8);
        for i in 0..200 {
            for j in 0..200 {
                assert!(refined.at(i, j) >= plain.at(i, j) - 1e-12);
                assert!(refined.at(i, j) <= 1.0 + 1e-9);
            }
        }
    }

    #[test]
    fn consensus_reasonable() {
        let ds = two_moons(300, 0.06, 2);
        let ens = generate_kmeans_ensemble(&ds.x, 8, 6, 12, 5).unwrap();
        let out = wct(&ens, 2).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.3, "nmi={score}");
    }

    #[test]
    fn intersect_helper() {
        assert_eq!(intersect_size(&[1, 3, 5], &[3, 4, 5, 6]), 2);
        assert_eq!(intersect_size(&[], &[1]), 0);
    }
}
