//! **SEC** — Spectral Ensemble Clustering (Liu et al., TKDE'17): spectral
//! clustering of the co-association matrix, shown by the original paper to
//! be equivalent to weighted k-means over rows of the (degree-normalized)
//! incidence matrix — which is how we realize it, avoiding the N×N
//! co-association entirely.

use crate::baselines::ClusteringOutput;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::Mat;
use crate::usenc::Ensemble;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Spectral-normalized incidence: column j of B̃ scaled by 1/√(col_sum_j)
/// (the D_C^{-1/2} normalization of the co-association's normalized cut).
pub fn normalized_incidence(ens: &Ensemble) -> Mat {
    let b = ens.incidence();
    let col = b.col_sums();
    let scale: Vec<f32> =
        col.iter().map(|&s| if s > 0.0 { (1.0 / s.sqrt()) as f32 } else { 0.0 }).collect();
    let mut x = Mat::zeros(b.rows, b.cols);
    for i in 0..b.rows {
        let (cols, vals) = b.row(i);
        for (c, v) in cols.iter().zip(vals) {
            x.set(i, *c as usize, *v as f32 * scale[*c as usize]);
        }
    }
    x
}

/// Run SEC.
pub fn sec(ens: &Ensemble, k: usize, seed: u64) -> Result<ClusteringOutput> {
    ensure_arg!(ens.m() >= 1, "sec: empty ensemble");
    ensure_arg!(k >= 1 && k <= ens.n(), "sec: bad k");
    let mut timer = PhaseTimer::new();
    let x = timer.time("normalize", || normalized_incidence(ens));
    let km = timer.time("weighted_kmeans", || {
        kmeans(&x, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::ensemble_baselines::generate_kmeans_ensemble;
    use crate::metrics::nmi;

    #[test]
    fn perfect_ensemble_recovered() {
        let truth = vec![0u32, 0, 0, 0, 1, 1, 1, 1, 1, 1];
        let mut ens = Ensemble::default();
        for _ in 0..4 {
            ens.push(truth.clone());
        }
        let out = sec(&ens, 2, 3).unwrap();
        assert!((nmi(&out.labels, &truth) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn column_normalization_downweights_big_clusters() {
        let mut ens = Ensemble::default();
        ens.push(vec![0, 0, 0, 0, 0, 0, 0, 1]); // heavily imbalanced base
        let x = normalized_incidence(&ens);
        assert!(x.at(7, 1) > x.at(0, 0)); // small cluster gets larger weight
    }

    #[test]
    fn runs_on_kmeans_ensemble() {
        let ds = two_moons(300, 0.06, 2);
        let ens = generate_kmeans_ensemble(&ds.x, 8, 5, 10, 7).unwrap();
        let out = sec(&ens, 2, 9).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score >= 0.0 && out.labels.len() == 300);
    }
}
