//! Dense symmetric eigensolver: Householder tridiagonalization (`tred2`)
//! followed by implicit-shift QL iteration (`tqli`). This replaces MATLAB's
//! `eig`/`eigs` on the reduced p×p (or k_c×k_c) transfer-cut problems.
//!
//! Also provides the *generalized* symmetric solve `L v = λ D v` with
//! diagonal `D`, via the congruence transform `D^{-1/2} L D^{-1/2}`.

use crate::linalg::dense::DMat;
use crate::util::par;
use crate::{Error, Result};

/// `fast_eig_crossover` slope: the iterative solvers win once `p`
/// exceeds roughly this many multiples of `k` …
pub const FAST_EIG_K_FACTOR: usize = 4;
/// … plus this constant margin (covers the iterative setup overhead on
/// small problems).
pub const FAST_EIG_MARGIN: usize = 64;

/// `true` when a p×p reduced problem asking for `k` eigenpairs is large
/// enough that an iterative solver (Chebyshev subspace iteration /
/// LOBPCG) beats the dense O(p³) `tred2`+`tqli` solve. The **single**
/// dense/iterative crossover: `bipartite::reduced_eig` routes on it and
/// `lobpcg_smallest` rejects below it, so the two can never disagree.
/// `USPEC_EIG_TRACE=1` prints which side each decomposition took.
pub fn fast_eig_crossover(p: usize, k: usize) -> bool {
    p > FAST_EIG_K_FACTOR * k + FAST_EIG_MARGIN
}

/// Full eigen-decomposition of a symmetric matrix.
/// Returns eigenvalues ascending and the matrix whose *columns* are the
/// corresponding orthonormal eigenvectors.
pub fn sym_eig(a: &DMat) -> Result<(Vec<f64>, DMat)> {
    let n = a.rows;
    if n == 0 {
        return Ok((Vec::new(), DMat::zeros(0, 0)));
    }
    if a.rows != a.cols {
        return Err(Error::InvalidArg(format!("sym_eig: non-square {}x{}", a.rows, a.cols)));
    }
    let mut z = a.clone();
    let (mut d, mut e) = tred2(&mut z);
    tqli(&mut d, &mut e, &mut z)?;
    // Sort ascending, permute columns of z accordingly.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&i, &j| d[i].partial_cmp(&d[j]).unwrap_or(std::cmp::Ordering::Equal));
    let vals: Vec<f64> = order.iter().map(|&i| d[i]).collect();
    let mut vecs = DMat::zeros(n, n);
    for (newc, &oldc) in order.iter().enumerate() {
        for r in 0..n {
            vecs.set(r, newc, z.at(r, oldc));
        }
    }
    Ok((vals, vecs))
}

/// Smallest-k eigenpairs of the generalized problem `L v = λ D v` with
/// diagonal `D` (entries > 0). Returns (λ[..k], V n×k).
pub fn sym_eig_generalized_smallest(
    l: &DMat,
    d_diag: &[f64],
    k: usize,
) -> Result<(Vec<f64>, DMat)> {
    let n = l.rows;
    if d_diag.len() != n {
        return Err(Error::InvalidArg("generalized eig: diag size".into()));
    }
    let dinv_sqrt: Vec<f64> = d_diag
        .iter()
        .map(|&x| if x > 1e-300 { 1.0 / x.sqrt() } else { 0.0 })
        .collect();
    // S = D^{-1/2} L D^{-1/2}, built row-parallel (disjoint row ranges,
    // per-element arithmetic independent of the chunking — the n² serial
    // at/set loop this replaces dominated the setup at p ≥ 1000).
    let mut s = DMat::zeros(n, n);
    par::par_for_chunks(&mut s.data, n * 16, |start, chunk| {
        let row0 = start / n;
        for (bi, orow) in chunk.chunks_mut(n).enumerate() {
            let i = row0 + bi;
            let li = l.row(i);
            let di = dinv_sqrt[i];
            for ((o, &lv), &dj) in orow.iter_mut().zip(li).zip(&dinv_sqrt) {
                *o = lv * di * dj;
            }
        }
    });
    let (vals, vecs) = sym_eig(&s)?;
    let k = k.min(n);
    // Back-scale the eigenvectors v = D^{-1/2} w, row-parallel likewise.
    let mut v = DMat::zeros(n, k);
    if k > 0 {
        par::par_for_chunks(&mut v.data, k, |start, chunk| {
            let r = start / k;
            let dr = dinv_sqrt[r];
            for (o, &w) in chunk.iter_mut().zip(&vecs.row(r)[..k]) {
                *o = w * dr;
            }
        });
    }
    Ok((vals[..k].to_vec(), v))
}

/// Householder reduction of symmetric `a` (destroyed; replaced by the
/// accumulated orthogonal transform) to tridiagonal form. Returns
/// (diagonal, sub-diagonal with e[0]=0). Numerical Recipes `tred2`.
fn tred2(a: &mut DMat) -> (Vec<f64>, Vec<f64>) {
    let n = a.rows;
    let mut d = vec![0.0f64; n];
    let mut e = vec![0.0f64; n];
    for i in (1..n).rev() {
        let l = i - 1;
        let mut h = 0.0;
        if l > 0 {
            let scale: f64 = (0..=l).map(|k| a.at(i, k).abs()).sum();
            if scale == 0.0 {
                e[i] = a.at(i, l);
            } else {
                for k in 0..=l {
                    let v = a.at(i, k) / scale;
                    a.set(i, k, v);
                    h += v * v;
                }
                let mut f = a.at(i, l);
                let g = if f >= 0.0 { -h.sqrt() } else { h.sqrt() };
                e[i] = scale * g;
                h -= f * g;
                a.set(i, l, f - g);
                f = 0.0;
                for j in 0..=l {
                    a.set(j, i, a.at(i, j) / h);
                    let mut g = 0.0;
                    for k in 0..=j {
                        g += a.at(j, k) * a.at(i, k);
                    }
                    for k in (j + 1)..=l {
                        g += a.at(k, j) * a.at(i, k);
                    }
                    e[j] = g / h;
                    f += e[j] * a.at(i, j);
                }
                let hh = f / (h + h);
                for j in 0..=l {
                    let f = a.at(i, j);
                    let g = e[j] - hh * f;
                    e[j] = g;
                    for k in 0..=j {
                        let v = a.at(j, k) - (f * e[k] + g * a.at(i, k));
                        a.set(j, k, v);
                    }
                }
            }
        } else {
            e[i] = a.at(i, l);
        }
        d[i] = h;
    }
    d[0] = 0.0;
    e[0] = 0.0;
    for i in 0..n {
        if d[i] != 0.0 {
            for j in 0..i {
                let mut g = 0.0;
                for k in 0..i {
                    g += a.at(i, k) * a.at(k, j);
                }
                for k in 0..i {
                    let v = a.at(k, j) - g * a.at(k, i);
                    a.set(k, j, v);
                }
            }
        }
        d[i] = a.at(i, i);
        a.set(i, i, 1.0);
        for j in 0..i {
            a.set(j, i, 0.0);
            a.set(i, j, 0.0);
        }
    }
    (d, e)
}

#[inline]
fn pythag(a: f64, b: f64) -> f64 {
    a.hypot(b)
}

/// Implicit-shift QL on a tridiagonal (d = diag, e = subdiag with e[0]
/// unused); accumulates rotations into `z`. Numerical Recipes `tqli`.
fn tqli(d: &mut [f64], e: &mut [f64], z: &mut DMat) -> Result<()> {
    let n = d.len();
    if n == 0 {
        return Ok(());
    }
    for i in 1..n {
        e[i - 1] = e[i];
    }
    e[n - 1] = 0.0;
    for l in 0..n {
        let mut iter = 0;
        loop {
            // Find a small sub-diagonal element to split.
            let mut m = l;
            while m + 1 < n {
                let dd = d[m].abs() + d[m + 1].abs();
                if e[m].abs() <= f64::EPSILON * dd {
                    break;
                }
                m += 1;
            }
            if m == l {
                break;
            }
            iter += 1;
            if iter > 50 {
                return Err(Error::Numerical("tqli: >50 iterations".into()));
            }
            let mut g = (d[l + 1] - d[l]) / (2.0 * e[l]);
            let mut r = pythag(g, 1.0);
            g = d[m] - d[l] + e[l] / (g + if g >= 0.0 { r.abs() } else { -r.abs() });
            let (mut s, mut c) = (1.0f64, 1.0f64);
            let mut p = 0.0f64;
            for i in (l..m).rev() {
                let mut f = s * e[i];
                let b = c * e[i];
                r = pythag(f, g);
                e[i + 1] = r;
                if r == 0.0 {
                    d[i + 1] -= p;
                    e[m] = 0.0;
                    break;
                }
                s = f / r;
                c = g / r;
                g = d[i + 1] - p;
                r = (d[i] - g) * s + 2.0 * c * b;
                p = s * r;
                d[i + 1] = g + p;
                g = c * r - b;
                for k in 0..n {
                    f = z.at(k, i + 1);
                    z.set(k, i + 1, s * z.at(k, i) + c * f);
                    z.set(k, i, c * z.at(k, i) - s * f);
                }
            }
            if r == 0.0 && m > l + 1 {
                continue;
            }
            d[l] -= p;
            e[l] = g;
            e[m] = 0.0;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_sym(n: usize, rng: &mut Rng) -> DMat {
        let mut a = DMat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        a
    }

    #[test]
    fn diagonal_matrix() {
        let mut a = DMat::zeros(4, 4);
        for (i, &v) in [3.0, -1.0, 2.0, 0.5].iter().enumerate() {
            a.set(i, i, v);
        }
        let (vals, _) = sym_eig(&a).unwrap();
        assert_eq!(vals.iter().map(|v| (v * 1e9).round() / 1e9).collect::<Vec<_>>(), vec![-1.0, 0.5, 2.0, 3.0]);
    }

    #[test]
    fn residuals_and_orthonormality() {
        let mut rng = Rng::new(9);
        for &n in &[1usize, 2, 5, 20, 60] {
            let a = random_sym(n, &mut rng);
            let (vals, v) = sym_eig(&a).unwrap();
            // ascending
            for w in vals.windows(2) {
                assert!(w[0] <= w[1] + 1e-12);
            }
            // A v = λ v
            let av = a.matmul(&v);
            for c in 0..n {
                for r in 0..n {
                    let want = vals[c] * v.at(r, c);
                    assert!(
                        (av.at(r, c) - want).abs() < 1e-8 * (1.0 + vals[c].abs()),
                        "n={n} resid ({r},{c}): {} vs {}",
                        av.at(r, c),
                        want
                    );
                }
            }
            // VᵀV = I
            let vtv = v.transpose().matmul(&v);
            assert!(vtv.frob_dist(&DMat::eye(n)) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn known_2x2() {
        let a = DMat::from_vec(2, 2, vec![2.0, 1.0, 1.0, 2.0]);
        let (vals, _) = sym_eig(&a).unwrap();
        assert!((vals[0] - 1.0).abs() < 1e-12);
        assert!((vals[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn generalized_matches_direct() {
        let mut rng = Rng::new(10);
        let n = 12;
        // Laplacian-like PSD matrix
        let b = random_sym(n, &mut rng);
        let l = b.matmul(&b.transpose());
        let d: Vec<f64> = (0..n).map(|_| rng.f64() + 0.5).collect();
        let (vals, v) = sym_eig_generalized_smallest(&l, &d, 3).unwrap();
        // check L v = λ D v
        let lv = l.matmul(&v);
        for c in 0..3 {
            for r in 0..n {
                let want = vals[c] * d[r] * v.at(r, c);
                assert!((lv.at(r, c) - want).abs() < 1e-7 * (1.0 + vals[c].abs()), "{} {}", lv.at(r, c), want);
            }
        }
    }

    #[test]
    fn trace_preserved() {
        let mut rng = Rng::new(13);
        let a = random_sym(30, &mut rng);
        let tr: f64 = (0..30).map(|i| a.at(i, i)).sum();
        let (vals, _) = sym_eig(&a).unwrap();
        assert!((vals.iter().sum::<f64>() - tr).abs() < 1e-8);
    }
}
