//! LOBPCG (locally optimal block preconditioned conjugate gradient) for the
//! smallest-k eigenpairs of a symmetric matrix — the fast path for the
//! p×p / k_c×k_c transfer-cut problems when k ≪ p. Falls back to the dense
//! solver ([`super::eigen::sym_eig`]) on stagnation; the U-SPEC pipeline
//! asks for `k+1` vectors so the cluster-count eigengap is always covered.

use crate::linalg::dense::DMat;
use crate::linalg::eigen::sym_eig;
use crate::{Error, Result};

/// Matrix-free operator interface: y = A·x for a block of vectors.
pub trait SymOp {
    fn dim(&self) -> usize;
    /// Apply to a block X (n×b), returning A·X (n×b).
    fn apply(&self, x: &DMat) -> DMat;
}

impl SymOp for DMat {
    fn dim(&self) -> usize {
        self.rows
    }
    fn apply(&self, x: &DMat) -> DMat {
        self.matmul(x)
    }
}

/// B-orthonormalize columns of `x` in place via Cholesky-free repeated
/// Gram–Schmidt; returns false if the block is rank deficient.
fn orthonormalize(x: &mut DMat) -> bool {
    let (n, b) = (x.rows, x.cols);
    for c in 0..b {
        for _pass in 0..2 {
            for prev in 0..c {
                let mut dot = 0.0;
                for r in 0..n {
                    dot += x.at(r, prev) * x.at(r, c);
                }
                for r in 0..n {
                    let v = x.at(r, c) - dot * x.at(r, prev);
                    x.set(r, c, v);
                }
            }
        }
        let norm: f64 = (0..n).map(|r| x.at(r, c) * x.at(r, c)).sum::<f64>().sqrt();
        if norm < 1e-12 {
            return false;
        }
        for r in 0..n {
            x.set(r, c, x.at(r, c) / norm);
        }
    }
    true
}

fn hstack(blocks: &[&DMat]) -> DMat {
    let n = blocks[0].rows;
    let total: usize = blocks.iter().map(|b| b.cols).sum();
    let mut out = DMat::zeros(n, total);
    let mut off = 0;
    for b in blocks {
        for r in 0..n {
            for c in 0..b.cols {
                out.set(r, off + c, b.at(r, c));
            }
        }
        off += b.cols;
    }
    out
}

fn cols(m: &DMat, lo: usize, hi: usize) -> DMat {
    let mut out = DMat::zeros(m.rows, hi - lo);
    for r in 0..m.rows {
        for c in lo..hi {
            out.set(r, c - lo, m.at(r, c));
        }
    }
    out
}

/// Smallest `k` eigenpairs of the symmetric operator `op`.
/// `diag_precond`: optional diagonal preconditioner (e.g. 1/diag(A)).
/// Returns (λ ascending, V n×k with orthonormal columns).
pub fn lobpcg_smallest(
    op: &dyn SymOp,
    k: usize,
    diag_precond: Option<&[f64]>,
    tol: f64,
    max_iter: usize,
    seed: u64,
) -> Result<(Vec<f64>, DMat)> {
    let n = op.dim();
    let k = k.min(n);
    if k == 0 {
        return Ok((Vec::new(), DMat::zeros(n, 0)));
    }
    // Small problems: dense solve is both faster and exact.
    if n <= 4 * k + 32 {
        return Err(Error::Numerical("lobpcg: problem too small, use dense".into()));
    }
    let mut rng = crate::util::rng::Rng::new(seed);
    let mut x = DMat::zeros(n, k);
    for v in x.data.iter_mut() {
        *v = rng.normal();
    }
    if !orthonormalize(&mut x) {
        return Err(Error::Numerical("lobpcg: degenerate start".into()));
    }
    let mut p_block: Option<DMat> = None;
    let mut lambda = vec![0.0f64; k];
    let mut prev_res = f64::INFINITY;
    let mut stagnant = 0;

    for _it in 0..max_iter {
        let ax = op.apply(&x);
        // Rayleigh quotients per column.
        for c in 0..k {
            let mut num = 0.0;
            for r in 0..n {
                num += x.at(r, c) * ax.at(r, c);
            }
            lambda[c] = num;
        }
        // Residuals R = AX - X Λ
        let mut r_block = ax.clone();
        for c in 0..k {
            for r in 0..n {
                let v = r_block.at(r, c) - lambda[c] * x.at(r, c);
                r_block.set(r, c, v);
            }
        }
        let res_norm: f64 = r_block.data.iter().map(|v| v * v).sum::<f64>().sqrt();
        if res_norm < tol {
            break;
        }
        if res_norm > prev_res * 0.999 {
            stagnant += 1;
            if stagnant > 8 {
                break; // caller validates; dense fallback happens upstream
            }
        } else {
            stagnant = 0;
        }
        prev_res = res_norm;
        // Precondition residuals.
        if let Some(pre) = diag_precond {
            for c in 0..k {
                for r in 0..n {
                    r_block.set(r, c, r_block.at(r, c) * pre[r]);
                }
            }
        }
        if !orthonormalize(&mut r_block) {
            break;
        }
        // Subspace S = [X, R, P]
        let s = match &p_block {
            Some(p) => hstack(&[&x, &r_block, p]),
            None => hstack(&[&x, &r_block]),
        };
        let mut s_orth = s.clone();
        if !orthonormalize(&mut s_orth) {
            break;
        }
        // Rayleigh–Ritz on the subspace: solve (Sᵀ A S) c = θ c.
        let as_ = op.apply(&s_orth);
        let h = s_orth.transpose().matmul(&as_);
        // symmetrize
        let mut hs = h.clone();
        for i in 0..hs.rows {
            for j in 0..hs.cols {
                let v = 0.5 * (h.at(i, j) + h.at(j, i));
                hs.set(i, j, v);
            }
        }
        let (_vals, vecs) = sym_eig(&hs)?;
        let c_best = cols(&vecs, 0, k);
        let x_new = s_orth.matmul(&c_best);
        // New conjugate direction: the component of X_new outside old X.
        let mut p_new = x_new.clone();
        for c in 0..k {
            for r in 0..n {
                p_new.set(r, c, p_new.at(r, c) - x.at(r, c));
            }
        }
        x = x_new;
        if !orthonormalize(&mut x) {
            break;
        }
        if orthonormalize(&mut p_new) {
            p_block = Some(p_new);
        } else {
            p_block = None;
        }
    }
    // Final Rayleigh–Ritz to return consistent (λ, V) sorted ascending.
    let ax = op.apply(&x);
    let h = x.transpose().matmul(&ax);
    let mut hs = h.clone();
    for i in 0..k {
        for j in 0..k {
            hs.set(i, j, 0.5 * (h.at(i, j) + h.at(j, i)));
        }
    }
    let (vals, vecs) = sym_eig(&hs)?;
    let v = x.matmul(&cols(&vecs, 0, k));
    Ok((vals[..k].to_vec(), v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Random PSD with known spectrum via Q Λ Qᵀ.
    fn psd_with_spectrum(n: usize, spec: &[f64], rng: &mut Rng) -> DMat {
        let mut q = DMat::zeros(n, n);
        for v in q.data.iter_mut() {
            *v = rng.normal();
        }
        assert!(orthonormalize(&mut q));
        let mut lam = DMat::zeros(n, n);
        for (i, &s) in spec.iter().enumerate() {
            lam.set(i, i, s);
        }
        q.matmul(&lam).matmul(&q.transpose())
    }

    #[test]
    fn finds_smallest_eigenpairs() {
        let mut rng = Rng::new(21);
        let n = 80;
        let spec: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 + 0.1).collect();
        let a = psd_with_spectrum(n, &spec, &mut rng);
        let (vals, v) = lobpcg_smallest(&a, 4, None, 1e-10, 300, 7).unwrap();
        for (i, &l) in vals.iter().enumerate() {
            assert!((l - spec[i]).abs() < 1e-6, "λ{i}: {l} vs {}", spec[i]);
        }
        // residual check
        let av = a.matmul(&v);
        for c in 0..4 {
            for r in 0..n {
                assert!((av.at(r, c) - vals[c] * v.at(r, c)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn agrees_with_dense() {
        let mut rng = Rng::new(22);
        let n = 100;
        let mut a = DMat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        // shift to PSD-ish; eigen order unaffected
        let (dvals, _) = sym_eig(&a).unwrap();
        let (lvals, _) = lobpcg_smallest(&a, 3, None, 1e-11, 500, 3).unwrap();
        for i in 0..3 {
            assert!((dvals[i] - lvals[i]).abs() < 1e-6, "{} vs {}", dvals[i], lvals[i]);
        }
    }

    #[test]
    fn rejects_tiny_problem() {
        let a = DMat::eye(5);
        assert!(lobpcg_smallest(&a, 2, None, 1e-8, 10, 1).is_err());
    }
}
