//! LOBPCG (locally optimal block preconditioned conjugate gradient) for the
//! smallest-k eigenpairs of a symmetric matrix — the fast path for the
//! p×p / k_c×k_c transfer-cut problems when k ≪ p. Falls back to the dense
//! solver ([`super::eigen::sym_eig`]) on stagnation; the U-SPEC pipeline
//! asks for `k+1` vectors so the cluster-count eigengap is always covered.
//!
//! All block products run on the packed f64 gemm kernels
//! ([`DMat::matmul_into`] and friends) through a caller-supplied
//! [`EigScratch`], so an iteration allocates only its q×q projected
//! eigenproblem. The small-problem guard routes through the same
//! [`fast_eig_crossover`] constants as `bipartite::reduced_eig` — one
//! crossover, not two.

use crate::linalg::dense::{orthonormalize_cols, DGemmScratch, DMat, EigScratch};
use crate::linalg::eigen::{fast_eig_crossover, sym_eig};
use crate::{Error, Result};

/// Matrix-free operator interface: y = A·x for a block of vectors.
pub trait SymOp {
    fn dim(&self) -> usize;
    /// Apply to a block X (n×b), returning A·X (n×b).
    fn apply(&self, x: &DMat) -> DMat;
    /// Apply into a caller buffer, packing through `scratch`. The default
    /// falls back to the allocating [`SymOp::apply`]; dense operators
    /// override it with the allocation-free gemm.
    fn apply_into(&self, x: &DMat, _scratch: &mut DGemmScratch, out: &mut DMat) {
        *out = self.apply(x);
    }
}

impl SymOp for DMat {
    fn dim(&self) -> usize {
        self.rows
    }
    fn apply(&self, x: &DMat) -> DMat {
        self.matmul(x)
    }
    fn apply_into(&self, x: &DMat, scratch: &mut DGemmScratch, out: &mut DMat) {
        self.matmul_into(x, scratch, out);
    }
}

/// Concatenate blocks side by side into `out` (reshaped as needed): one
/// `memcpy` per (row, block) instead of the element-wise `at`/`set` loop
/// this replaces.
fn hstack_into(blocks: &[&DMat], out: &mut DMat) {
    let n = blocks[0].rows;
    let total: usize = blocks.iter().map(|b| b.cols).sum();
    out.reshape(n, total);
    for r in 0..n {
        let orow = out.row_mut(r);
        let mut off = 0;
        for b in blocks {
            orow[off..off + b.cols].copy_from_slice(b.row(r));
            off += b.cols;
        }
    }
}

/// Copy columns `lo..hi` of `m` into `out` (reshaped as needed), one
/// `memcpy` per row.
fn cols_into(m: &DMat, lo: usize, hi: usize, out: &mut DMat) {
    out.reshape(m.rows, hi - lo);
    for r in 0..m.rows {
        out.row_mut(r).copy_from_slice(&m.row(r)[lo..hi]);
    }
}

/// Symmetrize a square matrix in place: `h ← (h + hᵀ)/2`.
fn symmetrize(h: &mut DMat) {
    let q = h.rows;
    debug_assert_eq!(h.cols, q);
    for i in 0..q {
        for j in 0..i {
            let v = 0.5 * (h.at(i, j) + h.at(j, i));
            h.set(i, j, v);
            h.set(j, i, v);
        }
    }
}

/// Smallest `k` eigenpairs of the symmetric operator `op`.
/// `diag_precond`: optional diagonal preconditioner (e.g. 1/diag(A)).
/// Returns (λ ascending, V n×k with orthonormal columns). Allocating
/// convenience wrapper over [`lobpcg_smallest_in`].
pub fn lobpcg_smallest(
    op: &dyn SymOp,
    k: usize,
    diag_precond: Option<&[f64]>,
    tol: f64,
    max_iter: usize,
    seed: u64,
) -> Result<(Vec<f64>, DMat)> {
    let mut scr = EigScratch::default();
    lobpcg_smallest_in(op, k, diag_precond, tol, max_iter, seed, &mut scr)
}

/// [`lobpcg_smallest`] running every block product and assembly through
/// `scr` — per iteration only the q×q projected eigenproblem allocates.
pub fn lobpcg_smallest_in(
    op: &dyn SymOp,
    k: usize,
    diag_precond: Option<&[f64]>,
    tol: f64,
    max_iter: usize,
    seed: u64,
    scr: &mut EigScratch,
) -> Result<(Vec<f64>, DMat)> {
    let n = op.dim();
    let k = k.min(n);
    if k == 0 {
        return Ok((Vec::new(), DMat::zeros(n, 0)));
    }
    // Below the dense/iterative crossover the dense solve is both faster
    // and exact — same constants as `bipartite::reduced_eig`'s routing.
    if !fast_eig_crossover(n, k) {
        return Err(Error::Numerical("lobpcg: problem too small, use dense".into()));
    }
    let mut rng = crate::util::rng::Rng::new(seed);
    scr.basis.reshape(n, k);
    for v in scr.basis.data.iter_mut() {
        *v = rng.normal();
    }
    if !orthonormalize_cols(&mut scr.basis, &mut scr.ortho) {
        return Err(Error::Numerical("lobpcg: degenerate start".into()));
    }
    let mut have_p = false;
    let mut lambda = vec![0.0f64; k];
    let mut prev_res = f64::INFINITY;
    let mut stagnant = 0;

    for _it in 0..max_iter {
        op.apply_into(&scr.basis, &mut scr.gemm, &mut scr.prod);
        // Rayleigh quotients per column (row-major sweep; per-column
        // accumulation order over rows is unchanged).
        lambda.fill(0.0);
        for r in 0..n {
            let xr = scr.basis.row(r);
            let ar = scr.prod.row(r);
            for ((l, &xv), &av) in lambda.iter_mut().zip(xr).zip(ar) {
                *l += xv * av;
            }
        }
        // Residuals R = AX - X Λ
        scr.resid.copy_from(&scr.prod);
        for r in 0..n {
            let xr = scr.basis.row(r);
            let rr = scr.resid.row_mut(r);
            for ((o, &xv), &l) in rr.iter_mut().zip(xr).zip(&lambda) {
                *o -= l * xv;
            }
        }
        let res_norm: f64 = scr.resid.data.iter().map(|v| v * v).sum::<f64>().sqrt();
        if res_norm < tol {
            break;
        }
        if res_norm > prev_res * 0.999 {
            stagnant += 1;
            if stagnant > 8 {
                break; // caller validates; dense fallback happens upstream
            }
        } else {
            stagnant = 0;
        }
        prev_res = res_norm;
        // Precondition residuals.
        if let Some(pre) = diag_precond {
            for (r, &p) in pre.iter().enumerate().take(n) {
                for v in scr.resid.row_mut(r) {
                    *v *= p;
                }
            }
        }
        if !orthonormalize_cols(&mut scr.resid, &mut scr.ortho) {
            break;
        }
        // Subspace S = [X, R, P], orthonormalized in place.
        if have_p {
            hstack_into(&[&scr.basis, &scr.resid, &scr.dir], &mut scr.wide);
        } else {
            hstack_into(&[&scr.basis, &scr.resid], &mut scr.wide);
        }
        if !orthonormalize_cols(&mut scr.wide, &mut scr.ortho) {
            break;
        }
        // Rayleigh–Ritz on the subspace: solve (Sᵀ A S) c = θ c.
        op.apply_into(&scr.wide, &mut scr.gemm, &mut scr.wide2);
        scr.wide.matmul_tn_into(&scr.wide2, &mut scr.gemm, &mut scr.small);
        symmetrize(&mut scr.small);
        let (_vals, vecs) = sym_eig(&scr.small)?;
        cols_into(&vecs, 0, k, &mut scr.rot);
        scr.wide.matmul_into(&scr.rot, &mut scr.gemm, &mut scr.ritz);
        // New conjugate direction: the component of X_new outside old X.
        scr.dir.copy_from(&scr.ritz);
        for r in 0..n {
            let xr = scr.basis.row(r);
            let dr = scr.dir.row_mut(r);
            for (o, &xv) in dr.iter_mut().zip(xr) {
                *o -= xv;
            }
        }
        std::mem::swap(&mut scr.basis, &mut scr.ritz);
        if !orthonormalize_cols(&mut scr.basis, &mut scr.ortho) {
            break;
        }
        have_p = orthonormalize_cols(&mut scr.dir, &mut scr.ortho);
    }
    // Final Rayleigh–Ritz to return consistent (λ, V) sorted ascending.
    op.apply_into(&scr.basis, &mut scr.gemm, &mut scr.prod);
    scr.basis.matmul_tn_into(&scr.prod, &mut scr.gemm, &mut scr.small);
    symmetrize(&mut scr.small);
    let (vals, vecs) = sym_eig(&scr.small)?;
    cols_into(&vecs, 0, k, &mut scr.rot);
    scr.basis.matmul_into(&scr.rot, &mut scr.gemm, &mut scr.ritz);
    Ok((vals[..k].to_vec(), scr.ritz.clone()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::eigen::{FAST_EIG_K_FACTOR, FAST_EIG_MARGIN};
    use crate::util::rng::Rng;

    /// Random PSD with known spectrum via Q Λ Qᵀ.
    fn psd_with_spectrum(n: usize, spec: &[f64], rng: &mut Rng) -> DMat {
        let mut q = DMat::zeros(n, n);
        for v in q.data.iter_mut() {
            *v = rng.normal();
        }
        let mut scratch = Vec::new();
        assert!(orthonormalize_cols(&mut q, &mut scratch));
        let mut lam = DMat::zeros(n, n);
        for (i, &s) in spec.iter().enumerate() {
            lam.set(i, i, s);
        }
        q.matmul(&lam).matmul(&q.transpose())
    }

    #[test]
    fn finds_smallest_eigenpairs() {
        let mut rng = Rng::new(21);
        // comfortably above the crossover (4·4 + 64 = 80 would reject)
        let n = 128;
        let spec: Vec<f64> = (0..n).map(|i| i as f64 * 0.5 + 0.1).collect();
        let a = psd_with_spectrum(n, &spec, &mut rng);
        let (vals, v) = lobpcg_smallest(&a, 4, None, 1e-10, 300, 7).unwrap();
        for (i, &l) in vals.iter().enumerate() {
            assert!((l - spec[i]).abs() < 1e-6, "λ{i}: {l} vs {}", spec[i]);
        }
        // residual check
        let av = a.matmul(&v);
        for c in 0..4 {
            for r in 0..n {
                assert!((av.at(r, c) - vals[c] * v.at(r, c)).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn agrees_with_dense() {
        let mut rng = Rng::new(22);
        let n = 100;
        let mut a = DMat::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let v = rng.normal();
                a.set(i, j, v);
                a.set(j, i, v);
            }
        }
        // shift to PSD-ish; eigen order unaffected
        let (dvals, _) = sym_eig(&a).unwrap();
        let (lvals, _) = lobpcg_smallest(&a, 3, None, 1e-11, 500, 3).unwrap();
        for i in 0..3 {
            assert!((dvals[i] - lvals[i]).abs() < 1e-6, "{} vs {}", dvals[i], lvals[i]);
        }
    }

    #[test]
    fn rejects_tiny_problem() {
        let a = DMat::eye(5);
        assert!(lobpcg_smallest(&a, 2, None, 1e-8, 10, 1).is_err());
    }

    /// The small-problem guard is the shared crossover, not a private
    /// constant: rejection flips exactly at `fast_eig_crossover`.
    #[test]
    fn guard_is_the_shared_crossover() {
        let k = 2;
        let boundary = FAST_EIG_K_FACTOR * k + FAST_EIG_MARGIN;
        assert!(lobpcg_smallest(&DMat::eye(boundary), k, None, 1e-8, 10, 1).is_err());
        assert!(lobpcg_smallest(&DMat::eye(boundary + 1), k, None, 1e-8, 50, 1).is_ok());
    }
}
