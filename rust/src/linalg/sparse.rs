//! CSR sparse matrices — the cross-affinity matrix `B` (N×p, K non-zeros
//! per row) and the ensemble incidence matrix `B̃` (N×k_c, m non-zeros per
//! row) live here, together with the fused products the transfer cut needs.

use crate::linalg::dense::DMat;
use crate::util::par;

/// Compressed sparse row matrix (f64 values, usize col indices).
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f64>,
}

impl Csr {
    /// Build from per-row (col, value) lists.
    pub fn from_rows(rows: usize, cols: usize, row_entries: &[Vec<(u32, f64)>]) -> Csr {
        assert_eq!(row_entries.len(), rows);
        let nnz: usize = row_entries.iter().map(|r| r.len()).sum();
        let mut indptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::with_capacity(nnz);
        let mut values = Vec::with_capacity(nnz);
        indptr.push(0);
        for row in row_entries {
            for &(c, v) in row {
                debug_assert!((c as usize) < cols);
                indices.push(c);
                values.push(v);
            }
            indptr.push(indices.len());
        }
        Csr { rows, cols, indptr, indices, values }
    }

    /// Build a uniform-degree CSR: every row has exactly `k` entries given
    /// by parallel arrays `cols_flat[r*k + j]`, `vals_flat[r*k + j]`.
    pub fn from_uniform(rows: usize, cols: usize, k: usize, cols_flat: Vec<u32>, vals_flat: Vec<f64>) -> Csr {
        assert_eq!(cols_flat.len(), rows * k);
        assert_eq!(vals_flat.len(), rows * k);
        let indptr = (0..=rows).map(|r| r * k).collect();
        Csr { rows, cols, indptr, indices: cols_flat, values: vals_flat }
    }

    #[inline]
    pub fn nnz(&self) -> usize {
        self.values.len()
    }

    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
        (&self.indices[lo..hi], &self.values[lo..hi])
    }

    /// Row sums (the diagonal of D_X for a bipartite cross-affinity).
    pub fn row_sums(&self) -> Vec<f64> {
        par::par_map(self.rows, |i| self.row(i).1.iter().sum())
    }

    /// Column sums.
    pub fn col_sums(&self) -> Vec<f64> {
        let mut sums = vec![0.0f64; self.cols];
        for (j, v) in self.indices.iter().zip(&self.values) {
            sums[*j as usize] += *v;
        }
        sums
    }

    /// Sparse · dense: y = A · x, where x is rows=cols of A.
    pub fn matmul_dense(&self, x: &DMat) -> DMat {
        assert_eq!(self.cols, x.rows);
        let n = x.cols;
        let mut out = DMat::zeros(self.rows, n);
        par::par_for_chunks(&mut out.data, n, |start, chunk| {
            let i = start / n;
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                let xr = x.row(*c as usize);
                for j in 0..n {
                    chunk[j] += v * xr[j];
                }
            }
        });
        out
    }

    /// The transfer-cut core product `E = Bᵀ · diag(w) · B` (cols×cols,
    /// dense output). Parallelized over *output* rows through a transient
    /// column index, so each E row is accumulated by exactly one worker in
    /// ascending input-row order — the result is bit-identical for every
    /// thread count (the old row-block-partial scheme folded partials in a
    /// thread-count-dependent grouping). Cost O(nnz · K) = O(N·K²) for
    /// uniform degree K, plus one O(nnz) transpose pass.
    pub fn tdb(&self, w: &[f64]) -> DMat {
        assert_eq!(w.len(), self.rows);
        let p = self.cols;
        let nnz = self.nnz();
        // CSC-style column index: for column c, the (row, value) pairs of
        // its non-zeros, rows ascending (built by a row-major sweep).
        let mut col_ptr = vec![0usize; p + 1];
        for &c in &self.indices {
            col_ptr[c as usize + 1] += 1;
        }
        for j in 0..p {
            col_ptr[j + 1] += col_ptr[j];
        }
        // 8 bytes/nnz transient (row id + flat nnz offset); the value
        // itself is re-read from `self.values` so it is not duplicated.
        assert!(nnz <= u32::MAX as usize, "tdb: nnz exceeds u32 index space");
        let mut col_rows = vec![0u32; nnz];
        let mut col_pos = vec![0u32; nnz];
        let mut cursor = col_ptr.clone();
        for i in 0..self.rows {
            let lo = self.indptr[i];
            for (off, c) in self.indices[lo..self.indptr[i + 1]].iter().enumerate() {
                let dst = cursor[*c as usize];
                col_rows[dst] = i as u32;
                col_pos[dst] = (lo + off) as u32;
                cursor[*c as usize] += 1;
            }
        }
        let mut e = DMat::zeros(p, p);
        par::par_for_chunks(&mut e.data, p, |start, chunk| {
            let ca = start / p;
            // E[ca, cb] = Σ_i w[i] · B[i,ca] · B[i,cb]
            for idx in col_ptr[ca]..col_ptr[ca + 1] {
                let i = col_rows[idx] as usize;
                let va = self.values[col_pos[idx] as usize] * w[i];
                if va == 0.0 {
                    continue;
                }
                let (cols, vals) = self.row(i);
                for (cb, vb) in cols.iter().zip(vals) {
                    chunk[*cb as usize] += va * vb;
                }
            }
        });
        e
    }

    /// Dense representation (tests / tiny problems only).
    pub fn to_dense(&self) -> DMat {
        let mut d = DMat::zeros(self.rows, self.cols);
        for i in 0..self.rows {
            let (cols, vals) = self.row(i);
            for (c, v) in cols.iter().zip(vals) {
                d.set(i, *c as usize, *v);
            }
        }
        d
    }

    /// Scale rows in place by `s[i]`.
    pub fn scale_rows(&mut self, s: &[f64]) {
        assert_eq!(s.len(), self.rows);
        for i in 0..self.rows {
            let (lo, hi) = (self.indptr[i], self.indptr[i + 1]);
            for v in &mut self.values[lo..hi] {
                *v *= s[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_csr(rows: usize, cols: usize, per_row: usize, rng: &mut Rng) -> Csr {
        let entries: Vec<Vec<(u32, f64)>> = (0..rows)
            .map(|_| {
                rng.sample_indices(cols, per_row)
                    .into_iter()
                    .map(|c| (c as u32, rng.f64() + 0.1))
                    .collect()
            })
            .collect();
        Csr::from_rows(rows, cols, &entries)
    }

    #[test]
    fn row_and_col_sums() {
        let m = Csr::from_rows(2, 3, &[vec![(0, 1.0), (2, 2.0)], vec![(1, 3.0)]]);
        assert_eq!(m.row_sums(), vec![3.0, 3.0]);
        assert_eq!(m.col_sums(), vec![1.0, 3.0, 2.0]);
        assert_eq!(m.nnz(), 3);
    }

    #[test]
    fn tdb_matches_dense() {
        let mut rng = Rng::new(5);
        let b = random_csr(40, 9, 4, &mut rng);
        let w: Vec<f64> = (0..40).map(|_| rng.f64() + 0.5).collect();
        let e = b.tdb(&w);
        // dense reference: Bᵀ diag(w) B
        let bd = b.to_dense();
        let mut wd = DMat::zeros(40, 40);
        for i in 0..40 {
            wd.set(i, i, w[i]);
        }
        let want = bd.transpose().matmul(&wd).matmul(&bd);
        assert!(e.frob_dist(&want) < 1e-9, "dist {}", e.frob_dist(&want));
    }

    #[test]
    fn matmul_dense_matches() {
        let mut rng = Rng::new(6);
        let a = random_csr(15, 8, 3, &mut rng);
        let x = DMat::from_vec(8, 2, (0..16).map(|i| i as f64 * 0.3 - 1.0).collect());
        let y = a.matmul_dense(&x);
        let want = a.to_dense().matmul(&x);
        assert!(y.frob_dist(&want) < 1e-10);
    }

    #[test]
    fn uniform_ctor() {
        let m = Csr::from_uniform(2, 4, 2, vec![1, 3, 0, 2], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(m.row(0), (&[1u32, 3u32][..], &[1.0, 2.0][..]));
        assert_eq!(m.row(1), (&[0u32, 2u32][..], &[3.0, 4.0][..]));
    }

    #[test]
    fn scale_rows_works() {
        let mut m = Csr::from_rows(2, 2, &[vec![(0, 2.0)], vec![(1, 3.0)]]);
        m.scale_rows(&[0.5, 2.0]);
        assert_eq!(m.values, vec![1.0, 6.0]);
    }
}
