//! Dense/sparse linear algebra and symmetric eigensolvers.
//!
//! Data matrices (`Mat`) are `f32` row-major — datasets here reach tens of
//! millions of rows, so the element type matches the AOT kernels and halves
//! memory traffic. Small spectral problems (`DMat`, p×p or k_c×k_c) are
//! solved in `f64` for eigen stability.

pub mod dense;
pub mod sparse;
pub mod eigen;
pub mod lobpcg;

pub use dense::{
    nearest_packed, nearest_packed_into, orthonormalize_cols, pack_rhs_slice, set_simd_override,
    sq_dists_into, DGemmScratch, DMat, DistScratch, EigScratch, Mat, PackedMat, ORTHO_RANK_TOL,
};
pub use sparse::Csr;
