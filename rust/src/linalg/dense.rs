//! Row-major dense matrices: `Mat` (f32, data-scale) and `DMat` (f64,
//! eigen-scale) plus the blocked, threaded kernels the clustering hot
//! paths need (gemm with transposed RHS, row norms, pairwise distances).

use crate::util::par;

/// f32 row-major matrix. The workhorse container for datasets,
/// representatives, eigenvector embeddings.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Gather a subset of rows into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Squared L2 norm of every row.
    pub fn row_sqnorms(&self) -> Vec<f32> {
        par::par_map(self.rows, |i| {
            self.row(i).iter().map(|&v| v * v).sum::<f32>()
        })
    }

    /// `self · otherᵀ` (m×d · (n×d)ᵀ = m×n), blocked and threaded. The RHS
    /// is given row-major with rows as the *output columns*, which is the
    /// natural layout for pairwise-distance style products (both operands
    /// are collections of d-vectors) and is unit-stride in the inner loop.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dim mismatch");
        let m = self.rows;
        let n = other.rows;
        let d = self.cols;
        let mut out = Mat::zeros(m, n);
        // Each thread owns a contiguous band of output rows.
        par::par_for_chunks(&mut out.data, n * 64.max(1), |start, chunk| {
            let row0 = start / n;
            let nrows = chunk.len() / n;
            for bi in 0..nrows {
                let i = row0 + bi;
                let a = self.row(i);
                let orow = &mut chunk[bi * n..(bi + 1) * n];
                // 4-way j-unrolled dot products; LLVM vectorizes the d loop.
                let mut j = 0;
                while j + 4 <= n {
                    let (b0, b1, b2, b3) =
                        (other.row(j), other.row(j + 1), other.row(j + 2), other.row(j + 3));
                    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0, 0.0, 0.0);
                    for t in 0..d {
                        let av = a[t];
                        s0 += av * b0[t];
                        s1 += av * b1[t];
                        s2 += av * b2[t];
                        s3 += av * b3[t];
                    }
                    orow[j] = s0;
                    orow[j + 1] = s1;
                    orow[j + 2] = s2;
                    orow[j + 3] = s3;
                    j += 4;
                }
                while j < n {
                    let b = other.row(j);
                    let mut s = 0.0f32;
                    for t in 0..d {
                        s += a[t] * b[t];
                    }
                    orow[j] = s;
                    j += 1;
                }
            }
        });
        out
    }

    /// Pairwise squared Euclidean distances `‖xᵢ − cⱼ‖²` (m×n), computed as
    /// ‖x‖² + ‖c‖² − 2·x·cᵀ — the same formulation the L1 Pallas kernel
    /// uses. Negative values from cancellation are clamped to 0.
    pub fn sq_dists(&self, centers: &Mat) -> Mat {
        let xn = self.row_sqnorms();
        let cn = centers.row_sqnorms();
        let mut g = self.matmul_nt(centers);
        let n = centers.rows;
        par::par_for_chunks(&mut g.data, n, |start, chunk| {
            let i = start / n;
            for (j, v) in chunk.iter_mut().enumerate() {
                *v = (xn[i] + cn[j] - 2.0 * *v).max(0.0);
            }
        });
        g
    }

    /// Convert to f64.
    pub fn to_f64(&self) -> DMat {
        DMat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f64).collect(),
        }
    }
}

/// f64 row-major matrix for the small spectral problems.
#[derive(Debug, Clone, PartialEq)]
pub struct DMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = DMat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        DMat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn transpose(&self) -> DMat {
        let mut t = DMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Plain gemm `self · other`.
    pub fn matmul(&self, other: &DMat) -> DMat {
        assert_eq!(self.cols, other.rows, "matmul inner dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = DMat::zeros(m, n);
        par::par_for_chunks(&mut out.data, n, |start, chunk| {
            let i = start / n;
            let a = self.row(i);
            for (t, &av) in a.iter().enumerate().take(k) {
                if av == 0.0 {
                    continue;
                }
                let b = other.row(t);
                for j in 0..n {
                    chunk[j] += av * b[j];
                }
            }
        });
        out
    }

    /// `selfᵀ · self` (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> DMat {
        let (m, n) = (self.rows, self.cols);
        let mut g = DMat::zeros(n, n);
        for r in 0..m {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..n {
                    g.data[i * n + j] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    pub fn to_f32(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Frobenius norm of (self - other).
    pub fn frob_dist(&self, other: &DMat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: usize, c: usize, rng: &mut Rng) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.f32() - 0.5).collect())
    }

    #[test]
    fn matmul_nt_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, n, d) in &[(1usize, 1usize, 1usize), (3, 5, 4), (17, 9, 7), (64, 33, 13)] {
            let a = randmat(m, d, &mut rng);
            let b = randmat(n, d, &mut rng);
            let g = a.matmul_nt(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..d).map(|t| a.at(i, t) * b.at(j, t)).sum();
                    assert!((g.at(i, j) - want).abs() < 1e-4, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn sq_dists_matches_direct() {
        let mut rng = Rng::new(12);
        let x = randmat(23, 6, &mut rng);
        let c = randmat(7, 6, &mut rng);
        let d2 = x.sq_dists(&c);
        for i in 0..23 {
            for j in 0..7 {
                let want: f32 = (0..6)
                    .map(|t| {
                        let diff = x.at(i, t) - c.at(j, t);
                        diff * diff
                    })
                    .sum();
                assert!((d2.at(i, j) - want).abs() < 1e-4);
                assert!(d2.at(i, j) >= 0.0);
            }
        }
    }

    #[test]
    fn dmat_matmul_and_gram() {
        let a = DMat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = DMat::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
        let g = a.gram();
        let want = a.transpose().matmul(&a);
        assert!(g.frob_dist(&want) < 1e-12);
    }

    #[test]
    fn gather_rows_works() {
        let m = Mat::from_vec(3, 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![4.0, 5.0, 0.0, 1.0]);
    }
}
