//! Row-major dense matrices: `Mat` (f32, data-scale) and `DMat` (f64,
//! eigen-scale) plus the blocked, threaded kernels the clustering hot
//! paths need (gemm with transposed RHS, row norms, pairwise distances).
//!
//! # The packed distance microkernel
//!
//! `matmul_nt` / `sq_dists` run on a cache-blocked, register-tiled
//! microkernel: the RHS (representatives / centers) is packed once into
//! [`NR`]-wide column panels ([`PackedMat`]) laid out so the innermost
//! loop reads one contiguous `NR`-vector per feature step, and each
//! [`MR`]×[`NR`] output tile is accumulated in registers across the full
//! feature dimension (f32 ops shaped so LLVM emits FMA/SIMD). The squared
//! distance `‖x‖² + ‖c‖² − 2·x·c` is fused into the tile epilogue — the
//! gemm block never makes a second memory pass.
//!
//! Batched callers (`exact_knr`, `nearest_row_batched`, k-means assign)
//! should pack the RHS **once** via [`Mat::pack_rhs`] and feed batches
//! through [`sq_dists_into`] / [`nearest_packed`], which also lets them
//! reuse output buffers across batches (zero allocation per batch).
//!
//! The full packed RHS is held in cache across a row tile
//! (`rows·cols·4` bytes — ≤ ~0.4 MB at the paper's p=1000, d≤100 shapes,
//! comfortably L2-resident). Shapes far beyond that would want an extra
//! column-blocking level, which the paper's pipeline never produces.

use crate::util::par;

/// Microkernel tile height (rows of the LHS per register tile).
pub const MR: usize = 4;
/// Microkernel tile width (packed RHS panel width).
pub const NR: usize = 8;

/// Output rows processed per parallel work item in the gemm drivers.
const ROWS_PER_CHUNK: usize = 16;

/// RHS matrix packed into `NR`-wide panels for the distance microkernel.
///
/// Panel `q` covers RHS rows `q·NR .. q·NR+NR` (zero-padded past the end)
/// and stores them feature-major: element `[t·NR + r]` is RHS row
/// `q·NR + r`, feature `t`. Row squared norms ride along so the fused
/// squared-distance epilogue needs no extra lookups.
#[derive(Debug, Clone)]
pub struct PackedMat {
    /// Logical RHS rows (output columns of `A·Bᵀ`).
    pub rows: usize,
    /// Feature dimension.
    pub cols: usize,
    panels: Vec<f32>,
    sqnorms: Vec<f32>,
}

impl PackedMat {
    /// Row squared norms of the packed matrix.
    pub fn sqnorms(&self) -> &[f32] {
        &self.sqnorms
    }
}

/// Pack `rows`×`cols` row-major `data` into NR-wide panels (see
/// [`PackedMat`]).
pub fn pack_rhs_slice(data: &[f32], rows: usize, cols: usize) -> PackedMat {
    debug_assert_eq!(data.len(), rows * cols);
    let npanels = rows.div_ceil(NR).max(1);
    let mut panels = vec![0f32; npanels * cols * NR];
    let mut sqnorms = vec![0f32; rows];
    for q in 0..npanels {
        let panel = &mut panels[q * cols * NR..(q + 1) * cols * NR];
        let base = q * NR;
        let live = NR.min(rows.saturating_sub(base));
        for r in 0..live {
            let row = &data[(base + r) * cols..(base + r + 1) * cols];
            let mut s = 0.0f32;
            for (t, &v) in row.iter().enumerate() {
                panel[t * NR + r] = v;
                s += v * v;
            }
            sqnorms[base + r] = s;
        }
    }
    PackedMat { rows, cols, panels, sqnorms }
}

/// `MR`-row register tile: dot products of four LHS rows against one
/// packed panel. The per-feature loop reads one contiguous `NR`-vector of
/// the panel and broadcasts four LHS scalars — the shape LLVM turns into
/// FMA/SIMD.
#[inline(always)]
fn tile_4xnr(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], panel: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0f32; NR]; MR];
    for ((((pb, &x0), &x1), &x2), &x3) in
        panel.chunks_exact(NR).zip(a0).zip(a1).zip(a2).zip(a3)
    {
        for c in 0..NR {
            acc[0][c] += x0 * pb[c];
            acc[1][c] += x1 * pb[c];
            acc[2][c] += x2 * pb[c];
            acc[3][c] += x3 * pb[c];
        }
    }
    acc
}

/// Single-row tail tile.
#[inline(always)]
fn tile_1xnr(a: &[f32], panel: &[f32]) -> [f32; NR] {
    let mut acc = [0f32; NR];
    for (pb, &x) in panel.chunks_exact(NR).zip(a) {
        for c in 0..NR {
            acc[c] += x * pb[c];
        }
    }
    acc
}

/// Blocked, threaded `A·Bᵀ` against a packed RHS, writing into `out`
/// (`m`×`packed.rows` row-major). With `FUSE`, the epilogue rewrites each
/// tile as clamped squared distances using `xn` (LHS row squared norms)
/// and the packed row norms.
fn gemm_nt_packed_into<const FUSE: bool>(
    a: &[f32],
    m: usize,
    d: usize,
    packed: &PackedMat,
    xn: &[f32],
    out: &mut [f32],
) {
    let n = packed.rows;
    debug_assert_eq!(packed.cols, d);
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(out.len(), m * n);
    if FUSE {
        debug_assert_eq!(xn.len(), m);
    }
    if m == 0 || n == 0 {
        return;
    }
    let npanels = n.div_ceil(NR).max(1);
    let cn = &packed.sqnorms;
    par::par_for_chunks(out, n * ROWS_PER_CHUNK, |start, chunk| {
        let row0 = start / n;
        let nrows = chunk.len() / n;
        let mut r = 0;
        // MR-row register tiles over the band.
        while r + MR <= nrows {
            let i0 = row0 + r;
            let a0 = &a[i0 * d..(i0 + 1) * d];
            let a1 = &a[(i0 + 1) * d..(i0 + 2) * d];
            let a2 = &a[(i0 + 2) * d..(i0 + 3) * d];
            let a3 = &a[(i0 + 3) * d..(i0 + 4) * d];
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = tile_4xnr(a0, a1, a2, a3, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                for (rr, accr) in acc.iter().enumerate() {
                    let orow = &mut chunk[(r + rr) * n + jb..(r + rr) * n + jb + cr];
                    if FUSE {
                        let x = xn[i0 + rr];
                        for (c, o) in orow.iter_mut().enumerate() {
                            *o = (x + cn[jb + c] - 2.0 * accr[c]).max(0.0);
                        }
                    } else {
                        orow.copy_from_slice(&accr[..cr]);
                    }
                }
            }
            r += MR;
        }
        // Tail rows.
        while r < nrows {
            let i0 = row0 + r;
            let arow = &a[i0 * d..(i0 + 1) * d];
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = tile_1xnr(arow, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                let orow = &mut chunk[r * n + jb..r * n + jb + cr];
                if FUSE {
                    let x = xn[i0];
                    for (c, o) in orow.iter_mut().enumerate() {
                        *o = (x + cn[jb + c] - 2.0 * acc[c]).max(0.0);
                    }
                } else {
                    orow.copy_from_slice(&acc[..cr]);
                }
            }
            r += 1;
        }
    });
}

/// Reusable scratch for batched packed-distance calls — holds the LHS row
/// norms so per-batch calls allocate nothing once warm.
#[derive(Debug, Default)]
pub struct DistScratch {
    xn: Vec<f32>,
}

/// Squared distances of `rows` row-major LHS rows (`x`, length
/// `rows·packed.cols`) against a pre-packed RHS, written into `out`
/// (resized to `rows·packed.rows`). Batched callers keep `packed`,
/// `scratch` and `out` across batches so the steady state is
/// allocation-free and never re-touches cold RHS memory.
pub fn sq_dists_into(
    x: &[f32],
    rows: usize,
    packed: &PackedMat,
    scratch: &mut DistScratch,
    out: &mut Vec<f32>,
) {
    let d = packed.cols;
    debug_assert_eq!(x.len(), rows * d);
    scratch.xn.clear();
    scratch.xn.extend((0..rows).map(|i| {
        x[i * d..(i + 1) * d].iter().map(|&v| v * v).sum::<f32>()
    }));
    // Every element is overwritten by the kernel; only grow/shrink when the
    // shape actually changed so warm batches skip the memset.
    if out.len() != rows * packed.rows {
        out.clear();
        out.resize(rows * packed.rows, 0.0);
    }
    gemm_nt_packed_into::<true>(x, rows, d, packed, &scratch.xn, out);
}

/// Fused nearest-row search against a packed RHS: per LHS row, the argmin
/// index and min squared distance — the distance block itself is never
/// materialized. Ties resolve to the lowest index (same contract as a
/// forward scan over `sq_dists`).
pub fn nearest_packed(x: &Mat, packed: &PackedMat) -> (Vec<u32>, Vec<f32>) {
    let m = x.rows;
    let d = x.cols;
    let n = packed.rows;
    assert_eq!(d, packed.cols, "nearest_packed dim mismatch");
    assert!(n >= 1, "nearest_packed: empty RHS");
    let xn = x.row_sqnorms();
    let npanels = n.div_ceil(NR).max(1);
    let cn = &packed.sqnorms;
    let a = &x.data;
    let mut best: Vec<(u32, f32)> = vec![(0, f32::INFINITY); m];
    par::par_for_chunks(&mut best, ROWS_PER_CHUNK * MR, |start, chunk| {
        let mut r = 0;
        while r + MR <= chunk.len() {
            let i0 = start + r;
            let a0 = &a[i0 * d..(i0 + 1) * d];
            let a1 = &a[(i0 + 1) * d..(i0 + 2) * d];
            let a2 = &a[(i0 + 2) * d..(i0 + 3) * d];
            let a3 = &a[(i0 + 3) * d..(i0 + 4) * d];
            let mut bests = [(0u32, f32::INFINITY); MR];
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = tile_4xnr(a0, a1, a2, a3, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                for (rr, accr) in acc.iter().enumerate() {
                    let xv = xn[i0 + rr];
                    for c in 0..cr {
                        let v = (xv + cn[jb + c] - 2.0 * accr[c]).max(0.0);
                        if v < bests[rr].1 {
                            bests[rr] = ((jb + c) as u32, v);
                        }
                    }
                }
            }
            chunk[r..r + MR].copy_from_slice(&bests);
            r += MR;
        }
        while r < chunk.len() {
            let i0 = start + r;
            let arow = &a[i0 * d..(i0 + 1) * d];
            let mut bi = (0u32, f32::INFINITY);
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = tile_1xnr(arow, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                for c in 0..cr {
                    let v = (xn[i0] + cn[jb + c] - 2.0 * acc[c]).max(0.0);
                    if v < bi.1 {
                        bi = ((jb + c) as u32, v);
                    }
                }
            }
            chunk[r] = bi;
            r += 1;
        }
    });
    let mut labels = Vec::with_capacity(m);
    let mut dists = Vec::with_capacity(m);
    for (l, v) in best {
        labels.push(l);
        dists.push(v);
    }
    (labels, dists)
}

/// f32 row-major matrix. The workhorse container for datasets,
/// representatives, eigenvector embeddings.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Gather a subset of rows into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Squared L2 norm of every row.
    pub fn row_sqnorms(&self) -> Vec<f32> {
        par::par_map(self.rows, |i| {
            self.row(i).iter().map(|&v| v * v).sum::<f32>()
        })
    }

    /// Pack this matrix as the RHS of the distance microkernel (see
    /// [`PackedMat`]). Batched callers pack once and reuse across batches.
    pub fn pack_rhs(&self) -> PackedMat {
        pack_rhs_slice(&self.data, self.rows, self.cols)
    }

    /// `self · otherᵀ` (m×d · (n×d)ᵀ = m×n) on the packed register-tiled
    /// microkernel. The RHS is given row-major with rows as the *output
    /// columns*, the natural layout for pairwise-distance style products.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dim mismatch");
        let packed = other.pack_rhs();
        self.matmul_nt_packed(&packed)
    }

    /// `self · packedᵀ` against an already-packed RHS.
    pub fn matmul_nt_packed(&self, packed: &PackedMat) -> Mat {
        assert_eq!(self.cols, packed.cols, "matmul_nt inner dim mismatch");
        let mut out = Mat::zeros(self.rows, packed.rows);
        gemm_nt_packed_into::<false>(&self.data, self.rows, self.cols, packed, &[], &mut out.data);
        out
    }

    /// Pairwise squared Euclidean distances `‖xᵢ − cⱼ‖²` (m×n), computed as
    /// ‖x‖² + ‖c‖² − 2·x·cᵀ — the same formulation the L1 Pallas kernel
    /// uses, fused into the gemm tile epilogue (no second memory pass).
    /// Negative values from cancellation are clamped to 0.
    pub fn sq_dists(&self, centers: &Mat) -> Mat {
        let packed = centers.pack_rhs();
        self.sq_dists_packed(&packed)
    }

    /// [`Mat::sq_dists`] against an already-packed RHS.
    pub fn sq_dists_packed(&self, packed: &PackedMat) -> Mat {
        assert_eq!(self.cols, packed.cols, "sq_dists dim mismatch");
        let xn = self.row_sqnorms();
        let mut out = Mat::zeros(self.rows, packed.rows);
        gemm_nt_packed_into::<true>(&self.data, self.rows, self.cols, packed, &xn, &mut out.data);
        out
    }

    /// Convert to f64.
    pub fn to_f64(&self) -> DMat {
        DMat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f64).collect(),
        }
    }
}

/// f64 row-major matrix for the small spectral problems.
#[derive(Debug, Clone, PartialEq)]
pub struct DMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = DMat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        DMat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn transpose(&self) -> DMat {
        let mut t = DMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Plain gemm `self · other`.
    pub fn matmul(&self, other: &DMat) -> DMat {
        assert_eq!(self.cols, other.rows, "matmul inner dim mismatch");
        let (m, k, n) = (self.rows, self.cols, other.cols);
        let mut out = DMat::zeros(m, n);
        par::par_for_chunks(&mut out.data, n, |start, chunk| {
            let i = start / n;
            let a = self.row(i);
            for (t, &av) in a.iter().enumerate().take(k) {
                if av == 0.0 {
                    continue;
                }
                let b = other.row(t);
                for j in 0..n {
                    chunk[j] += av * b[j];
                }
            }
        });
        out
    }

    /// `selfᵀ · self` (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> DMat {
        let (m, n) = (self.rows, self.cols);
        let mut g = DMat::zeros(n, n);
        for r in 0..m {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..n {
                    g.data[i * n + j] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    pub fn to_f32(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Frobenius norm of (self - other).
    pub fn frob_dist(&self, other: &DMat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: usize, c: usize, rng: &mut Rng) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.f32() - 0.5).collect())
    }

    #[test]
    fn matmul_nt_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, n, d) in &[(1usize, 1usize, 1usize), (3, 5, 4), (17, 9, 7), (64, 33, 13)] {
            let a = randmat(m, d, &mut rng);
            let b = randmat(n, d, &mut rng);
            let g = a.matmul_nt(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..d).map(|t| a.at(i, t) * b.at(j, t)).sum();
                    assert!((g.at(i, j) - want).abs() < 1e-4, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn sq_dists_matches_direct() {
        let mut rng = Rng::new(12);
        let x = randmat(23, 6, &mut rng);
        let c = randmat(7, 6, &mut rng);
        let d2 = x.sq_dists(&c);
        for i in 0..23 {
            for j in 0..7 {
                let want: f32 = (0..6)
                    .map(|t| {
                        let diff = x.at(i, t) - c.at(j, t);
                        diff * diff
                    })
                    .sum();
                assert!((d2.at(i, j) - want).abs() < 1e-4);
                assert!(d2.at(i, j) >= 0.0);
            }
        }
    }

    #[test]
    fn dmat_matmul_and_gram() {
        let a = DMat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = DMat::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
        let g = a.gram();
        let want = a.transpose().matmul(&a);
        assert!(g.frob_dist(&want) < 1e-12);
    }

    #[test]
    fn packed_matches_unpacked_at_awkward_shapes() {
        // shapes straddling the MR/NR tile boundaries, including d=0-free
        // tiny cases and single-row/column extremes
        let mut rng = Rng::new(21);
        for &(m, n, d) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (4, 8, 16),
            (5, 9, 3),
            (16, 33, 10),
            (65, 100, 100),
            (130, 17, 1),
        ] {
            let a = randmat(m, d, &mut rng);
            let b = randmat(n, d, &mut rng);
            let packed = b.pack_rhs();
            assert_eq!(packed.rows, n);
            assert_eq!(packed.cols, d);
            // packed sqnorms match direct
            for (j, &s) in packed.sqnorms().iter().enumerate() {
                let want: f32 = b.row(j).iter().map(|&v| v * v).sum();
                assert!((s - want).abs() <= 1e-4 * want.abs().max(1.0));
            }
            let g = a.matmul_nt_packed(&packed);
            let d2 = a.sq_dists_packed(&packed);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..d).map(|t| a.at(i, t) * b.at(j, t)).sum();
                    assert!((g.at(i, j) - want).abs() < 1e-3, "gemm ({i},{j}) m={m} n={n} d={d}");
                    let wd: f32 = (0..d)
                        .map(|t| {
                            let diff = a.at(i, t) - b.at(j, t);
                            diff * diff
                        })
                        .sum();
                    assert!(
                        (d2.at(i, j) - wd).abs() < 1e-3,
                        "sqd ({i},{j}) m={m} n={n} d={d}"
                    );
                }
            }
        }
    }

    #[test]
    fn sq_dists_into_reuses_buffers() {
        let mut rng = Rng::new(22);
        let x = randmat(37, 9, &mut rng);
        let c = randmat(11, 9, &mut rng);
        let packed = c.pack_rhs();
        let mut scratch = DistScratch::default();
        let mut out = Vec::new();
        // two batches through the same scratch/out
        for (lo, hi) in [(0usize, 20usize), (20, 37)] {
            sq_dists_into(&x.data[lo * 9..hi * 9], hi - lo, &packed, &mut scratch, &mut out);
            assert_eq!(out.len(), (hi - lo) * 11);
            let full = x.sq_dists(&c);
            for bi in 0..hi - lo {
                for j in 0..11 {
                    assert!((out[bi * 11 + j] - full.at(lo + bi, j)).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn nearest_packed_matches_scan() {
        let mut rng = Rng::new(23);
        for &(m, n, d) in &[(1usize, 1usize, 2usize), (9, 5, 3), (70, 23, 12), (128, 8, 4)] {
            let x = randmat(m, d, &mut rng);
            let c = randmat(n, d, &mut rng);
            let packed = c.pack_rhs();
            let (labels, dists) = nearest_packed(&x, &packed);
            let d2 = x.sq_dists(&c);
            for i in 0..m {
                let row = d2.row(i);
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate().skip(1) {
                    if v < row[best] {
                        best = j;
                    }
                }
                assert_eq!(labels[i] as usize, best, "row {i} m={m} n={n} d={d}");
                assert!((dists[i] - row[best]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gather_rows_works() {
        let m = Mat::from_vec(3, 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![4.0, 5.0, 0.0, 1.0]);
    }
}
