//! Row-major dense matrices: `Mat` (f32, data-scale) and `DMat` (f64,
//! eigen-scale) plus the blocked, threaded kernels the clustering hot
//! paths need (gemm with transposed RHS, row norms, pairwise distances).
//!
//! # The packed distance microkernel
//!
//! `matmul_nt` / `sq_dists` run on a cache-blocked, register-tiled
//! microkernel: the RHS (representatives / centers) is packed once into
//! [`NR`]-wide column panels ([`PackedMat`]) laid out so the innermost
//! loop reads one contiguous `NR`-vector per feature step, and each
//! [`MR`]×[`NR`] output tile is accumulated in registers across the full
//! feature dimension (f32 ops shaped so LLVM emits FMA/SIMD). The squared
//! distance `‖x‖² + ‖c‖² − 2·x·c` is fused into the tile epilogue — the
//! gemm block never makes a second memory pass.
//!
//! Batched callers (`exact_knr`, `nearest_row_batched`, k-means assign)
//! should pack the RHS **once** via [`Mat::pack_rhs`] and feed batches
//! through [`sq_dists_into`] / [`nearest_packed`], which also lets them
//! reuse output buffers across batches (zero allocation per batch).
//!
//! The full packed RHS is held in cache across a row tile
//! (`rows·cols·4` bytes — ≤ ~0.4 MB at the paper's p=1000, d≤100 shapes,
//! comfortably L2-resident). Shapes far beyond that would want an extra
//! column-blocking level, which the paper's pipeline never produces.
//!
//! # Runtime SIMD dispatch and the bit-identity contract
//!
//! The tile kernels exist in three interchangeable implementations —
//! portable scalar, AVX2 (`x86_64`, runtime-detected via
//! `is_x86_feature_detected!`), and NEON (`aarch64`) — selected once per
//! process into a cached [`SimdLevel`] and dispatched at tile
//! granularity, so the blocked drivers stay single-source. `USPEC_SIMD=0`
//! (once-read, via [`crate::util::simd_allowed`]) forces the scalar
//! fallback; [`set_simd_override`] is the test/bench hook that can flip
//! the choice after first use.
//!
//! All three paths are **bit-identical by construction**, preserving the
//! repo's standing invariant that every speed knob is purely operational:
//!
//! - The scalar tiles accumulate in a fixed `NR`-lane order: lane `c`
//!   only ever combines with lane `c`, one IEEE multiply then one IEEE
//!   add per feature step. One 8-wide AVX2 vector (or two 4-wide NEON
//!   vectors) per tile row executes exactly that lanewise sequence.
//! - The vector tiles deliberately use separate `mul` + `add`, **never**
//!   `fmadd`: a fused multiply-add rounds once where the scalar path
//!   rounds twice, which would diverge in the last bit. (Detection still
//!   gates on `avx2 && fma` so the dispatch predicate matches the
//!   feature set the CI `-C target-feature=+avx2,+fma` check leg
//!   compiles for.)
//! - The epilogues — distance fusion `(‖x‖² + ‖c‖² − 2·acc).max(0)` and
//!   the argmin scan — are shared scalar code over the per-tile
//!   accumulator array, so clamping and tie-breaking (lowest index win)
//!   are byte-for-byte the same on every path.
//!
//! # The packed f64 eigensolver kernels
//!
//! The transfer-cut eigensolvers (`bipartite::reduced_eig`,
//! `linalg::lobpcg`) run their p-sized products on a second packed tile
//! layer over f64: [`DMat::matmul_into`] / [`DMat::matmul_nt_into`] /
//! [`DMat::matmul_tn_into`] pack the RHS into [`DNR`]-wide feature-major
//! panels (reusing a caller-held [`DGemmScratch`], so iterative solvers
//! pack into the same buffer every iteration) and drive [`MR`]×[`DNR`]
//! register tiles through the same [`SimdLevel`] dispatch as the f32
//! layer — one 256-bit `_pd` vector (AVX2) or two `float64x2_t` (NEON)
//! per tile row, strictly `mul` then `add`, replaying the scalar tile's
//! lanewise op order. The same bit-identity contract therefore holds:
//! `USPEC_SIMD=0` / [`set_simd_override`] flip only throughput, never a
//! bit of any eigenvector, and output rows are written over disjoint
//! ranges so thread count is equally inert.
//!
//! [`EigScratch`] bundles the per-solver working set (packing buffers,
//! orthonormalization transpose scratch, and the named block buffers the
//! Chebyshev recurrence / Rayleigh–Ritz step / LOBPCG iteration cycle
//! through) so a whole reduced solve allocates only its final result
//! once warm. [`orthonormalize_cols`] is the shared two-pass blocked
//! Gram–Schmidt both solvers use — one rank-deficiency contract
//! ([`ORTHO_RANK_TOL`]) instead of the two divergent copies that
//! previously lived in `bipartite` and `lobpcg`.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::util::par;

/// Microkernel tile height (rows of the LHS per register tile).
pub const MR: usize = 4;
/// Microkernel tile width (packed RHS panel width).
pub const NR: usize = 8;
/// f64 microkernel tile width (packed RHS panel width of the `DMat`
/// gemm). Half of [`NR`]: one 256-bit AVX2 vector holds 4 doubles.
pub const DNR: usize = 4;

/// Output rows processed per parallel work item in the gemm drivers.
const ROWS_PER_CHUNK: usize = 16;

/// The vector instruction set the distance tiles dispatch to. Resolved
/// once per process from CPU detection ∧ `USPEC_SIMD` (see module docs),
/// then consulted per kernel call so [`set_simd_override`] can still
/// force the scalar path afterwards.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SimdLevel {
    Scalar,
    #[cfg(target_arch = "x86_64")]
    Avx2,
    #[cfg(target_arch = "aarch64")]
    Neon,
}

/// `0` = default dispatch, anything else = force the scalar tiles.
static SIMD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Test/bench hook mirroring `par::set_thread_override`: a non-zero
/// `mode` forces the scalar tiles from the next kernel call on, `0`
/// restores the default choice (CPU detection ∧ `USPEC_SIMD`). Unlike
/// the env knob this is not latched at first use, so A/B comparisons can
/// flip it mid-process. There is deliberately no "force vector" mode —
/// that would crash on hardware without the detected feature set.
pub fn set_simd_override(mode: usize) {
    SIMD_OVERRIDE.store(mode, Ordering::Relaxed);
}

/// CPU detection ∧ `USPEC_SIMD`, computed once and cached.
fn detected_level() -> SimdLevel {
    static LEVEL: OnceLock<SimdLevel> = OnceLock::new();
    *LEVEL.get_or_init(|| {
        if !crate::util::simd_allowed() {
            return SimdLevel::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return SimdLevel::Avx2;
        }
        #[cfg(target_arch = "aarch64")]
        if std::arch::is_aarch64_feature_detected!("neon") {
            return SimdLevel::Neon;
        }
        SimdLevel::Scalar
    })
}

/// The level the next kernel call will dispatch to. Drivers hoist this
/// out of their parallel loops (one relaxed atomic load per call).
#[inline]
fn simd_level() -> SimdLevel {
    if SIMD_OVERRIDE.load(Ordering::Relaxed) != 0 {
        SimdLevel::Scalar
    } else {
        detected_level()
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2 tiles: one 256-bit vector per tile row holds the full
    //! `NR = 8` accumulator lane set, stepped one feature at a time with
    //! a broadcast LHS scalar — the exact lanewise op sequence of the
    //! scalar tiles. Deliberately `mul` + `add`, **not** `fmadd`: FMA's
    //! single rounding would break the bit-identity contract (module
    //! docs) with the scalar fallback's two roundings per step.

    use super::{DNR, MR, NR};
    use std::arch::x86_64::{
        _mm256_add_pd, _mm256_add_ps, _mm256_loadu_pd, _mm256_loadu_ps, _mm256_mul_pd,
        _mm256_mul_ps, _mm256_set1_pd, _mm256_set1_ps, _mm256_setzero_pd, _mm256_setzero_ps,
        _mm256_storeu_pd, _mm256_storeu_ps,
    };

    /// `MR`-row register tile (vector twin of the scalar `tile_4xnr`).
    ///
    /// # Safety
    /// Caller must have verified AVX2 support (`is_x86_feature_detected!`,
    /// cached in `SimdLevel`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile_4xnr(
        a0: &[f32],
        a1: &[f32],
        a2: &[f32],
        a3: &[f32],
        panel: &[f32],
    ) -> [[f32; NR]; MR] {
        let d = a0.len();
        debug_assert!(a1.len() == d && a2.len() == d && a3.len() == d);
        debug_assert!(panel.len() >= d * NR);
        let p = panel.as_ptr();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut acc2 = _mm256_setzero_ps();
        let mut acc3 = _mm256_setzero_ps();
        for t in 0..d {
            let pv = _mm256_loadu_ps(p.add(t * NR));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_set1_ps(*a0.get_unchecked(t)), pv));
            acc1 = _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_set1_ps(*a1.get_unchecked(t)), pv));
            acc2 = _mm256_add_ps(acc2, _mm256_mul_ps(_mm256_set1_ps(*a2.get_unchecked(t)), pv));
            acc3 = _mm256_add_ps(acc3, _mm256_mul_ps(_mm256_set1_ps(*a3.get_unchecked(t)), pv));
        }
        let mut out = [[0f32; NR]; MR];
        _mm256_storeu_ps(out[0].as_mut_ptr(), acc0);
        _mm256_storeu_ps(out[1].as_mut_ptr(), acc1);
        _mm256_storeu_ps(out[2].as_mut_ptr(), acc2);
        _mm256_storeu_ps(out[3].as_mut_ptr(), acc3);
        out
    }

    /// Single-row tail tile (vector twin of the scalar `tile_1xnr`).
    ///
    /// # Safety
    /// Same AVX2 requirement as [`tile_4xnr`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile_1xnr(a: &[f32], panel: &[f32]) -> [f32; NR] {
        let d = a.len();
        debug_assert!(panel.len() >= d * NR);
        let p = panel.as_ptr();
        let mut acc = _mm256_setzero_ps();
        for t in 0..d {
            let pv = _mm256_loadu_ps(p.add(t * NR));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(_mm256_set1_ps(*a.get_unchecked(t)), pv));
        }
        let mut out = [0f32; NR];
        _mm256_storeu_ps(out.as_mut_ptr(), acc);
        out
    }

    /// f64 `MR`-row register tile: one 256-bit `_pd` vector covers the
    /// full `DNR = 4` accumulator lane set. Same mul-then-add discipline
    /// as the f32 tiles — no `fmadd` — to stay bit-identical with the
    /// scalar `tile64_4x`.
    ///
    /// # Safety
    /// Same AVX2 requirement as [`tile_4xnr`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile64_4x(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        panel: &[f64],
    ) -> [[f64; DNR]; MR] {
        let d = a0.len();
        debug_assert!(a1.len() == d && a2.len() == d && a3.len() == d);
        debug_assert!(panel.len() >= d * DNR);
        let p = panel.as_ptr();
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut acc2 = _mm256_setzero_pd();
        let mut acc3 = _mm256_setzero_pd();
        for t in 0..d {
            let pv = _mm256_loadu_pd(p.add(t * DNR));
            acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(_mm256_set1_pd(*a0.get_unchecked(t)), pv));
            acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(_mm256_set1_pd(*a1.get_unchecked(t)), pv));
            acc2 = _mm256_add_pd(acc2, _mm256_mul_pd(_mm256_set1_pd(*a2.get_unchecked(t)), pv));
            acc3 = _mm256_add_pd(acc3, _mm256_mul_pd(_mm256_set1_pd(*a3.get_unchecked(t)), pv));
        }
        let mut out = [[0f64; DNR]; MR];
        _mm256_storeu_pd(out[0].as_mut_ptr(), acc0);
        _mm256_storeu_pd(out[1].as_mut_ptr(), acc1);
        _mm256_storeu_pd(out[2].as_mut_ptr(), acc2);
        _mm256_storeu_pd(out[3].as_mut_ptr(), acc3);
        out
    }

    /// f64 single-row tail tile.
    ///
    /// # Safety
    /// Same AVX2 requirement as [`tile_4xnr`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn tile64_1x(a: &[f64], panel: &[f64]) -> [f64; DNR] {
        let d = a.len();
        debug_assert!(panel.len() >= d * DNR);
        let p = panel.as_ptr();
        let mut acc = _mm256_setzero_pd();
        for t in 0..d {
            let pv = _mm256_loadu_pd(p.add(t * DNR));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(_mm256_set1_pd(*a.get_unchecked(t)), pv));
        }
        let mut out = [0f64; DNR];
        _mm256_storeu_pd(out.as_mut_ptr(), acc);
        out
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON tiles: two 128-bit vectors per tile row cover the `NR = 8`
    //! lane set. Same mul-then-add discipline as the AVX2 tiles — no
    //! `vfmaq` — to stay bit-identical with the scalar fallback.

    use super::{DNR, MR, NR};
    use std::arch::aarch64::{
        float32x4_t, float64x2_t, vaddq_f32, vaddq_f64, vdupq_n_f32, vdupq_n_f64, vld1q_f32,
        vld1q_f64, vmulq_f32, vmulq_f64, vst1q_f32, vst1q_f64,
    };

    /// `MR`-row register tile (vector twin of the scalar `tile_4xnr`).
    ///
    /// # Safety
    /// Caller must have verified NEON support (cached in `SimdLevel`).
    #[target_feature(enable = "neon")]
    pub unsafe fn tile_4xnr(
        a0: &[f32],
        a1: &[f32],
        a2: &[f32],
        a3: &[f32],
        panel: &[f32],
    ) -> [[f32; NR]; MR] {
        let d = a0.len();
        debug_assert!(a1.len() == d && a2.len() == d && a3.len() == d);
        debug_assert!(panel.len() >= d * NR);
        let p = panel.as_ptr();
        let zero = vdupq_n_f32(0.0);
        let mut acc: [[float32x4_t; 2]; MR] = [[zero; 2]; MR];
        for t in 0..d {
            let plo = vld1q_f32(p.add(t * NR));
            let phi = vld1q_f32(p.add(t * NR + 4));
            let xs = [
                *a0.get_unchecked(t),
                *a1.get_unchecked(t),
                *a2.get_unchecked(t),
                *a3.get_unchecked(t),
            ];
            for (accr, &x) in acc.iter_mut().zip(&xs) {
                let xv = vdupq_n_f32(x);
                accr[0] = vaddq_f32(accr[0], vmulq_f32(xv, plo));
                accr[1] = vaddq_f32(accr[1], vmulq_f32(xv, phi));
            }
        }
        let mut out = [[0f32; NR]; MR];
        for (orow, accr) in out.iter_mut().zip(&acc) {
            vst1q_f32(orow.as_mut_ptr(), accr[0]);
            vst1q_f32(orow.as_mut_ptr().add(4), accr[1]);
        }
        out
    }

    /// Single-row tail tile (vector twin of the scalar `tile_1xnr`).
    ///
    /// # Safety
    /// Same NEON requirement as [`tile_4xnr`].
    #[target_feature(enable = "neon")]
    pub unsafe fn tile_1xnr(a: &[f32], panel: &[f32]) -> [f32; NR] {
        let d = a.len();
        debug_assert!(panel.len() >= d * NR);
        let p = panel.as_ptr();
        let zero = vdupq_n_f32(0.0);
        let mut lo = zero;
        let mut hi = zero;
        for t in 0..d {
            let xv = vdupq_n_f32(*a.get_unchecked(t));
            lo = vaddq_f32(lo, vmulq_f32(xv, vld1q_f32(p.add(t * NR))));
            hi = vaddq_f32(hi, vmulq_f32(xv, vld1q_f32(p.add(t * NR + 4))));
        }
        let mut out = [0f32; NR];
        vst1q_f32(out.as_mut_ptr(), lo);
        vst1q_f32(out.as_mut_ptr().add(4), hi);
        out
    }

    /// f64 `MR`-row register tile: two 128-bit vectors per tile row cover
    /// the `DNR = 4` lane set. Mul-then-add only, like the f32 tiles.
    ///
    /// # Safety
    /// Same NEON requirement as [`tile_4xnr`].
    #[target_feature(enable = "neon")]
    pub unsafe fn tile64_4x(
        a0: &[f64],
        a1: &[f64],
        a2: &[f64],
        a3: &[f64],
        panel: &[f64],
    ) -> [[f64; DNR]; MR] {
        let d = a0.len();
        debug_assert!(a1.len() == d && a2.len() == d && a3.len() == d);
        debug_assert!(panel.len() >= d * DNR);
        let p = panel.as_ptr();
        let zero = vdupq_n_f64(0.0);
        let mut acc: [[float64x2_t; 2]; MR] = [[zero; 2]; MR];
        for t in 0..d {
            let plo = vld1q_f64(p.add(t * DNR));
            let phi = vld1q_f64(p.add(t * DNR + 2));
            let xs = [
                *a0.get_unchecked(t),
                *a1.get_unchecked(t),
                *a2.get_unchecked(t),
                *a3.get_unchecked(t),
            ];
            for (accr, &x) in acc.iter_mut().zip(&xs) {
                let xv = vdupq_n_f64(x);
                accr[0] = vaddq_f64(accr[0], vmulq_f64(xv, plo));
                accr[1] = vaddq_f64(accr[1], vmulq_f64(xv, phi));
            }
        }
        let mut out = [[0f64; DNR]; MR];
        for (orow, accr) in out.iter_mut().zip(&acc) {
            vst1q_f64(orow.as_mut_ptr(), accr[0]);
            vst1q_f64(orow.as_mut_ptr().add(2), accr[1]);
        }
        out
    }

    /// f64 single-row tail tile.
    ///
    /// # Safety
    /// Same NEON requirement as [`tile_4xnr`].
    #[target_feature(enable = "neon")]
    pub unsafe fn tile64_1x(a: &[f64], panel: &[f64]) -> [f64; DNR] {
        let d = a.len();
        debug_assert!(panel.len() >= d * DNR);
        let p = panel.as_ptr();
        let zero = vdupq_n_f64(0.0);
        let mut lo = zero;
        let mut hi = zero;
        for t in 0..d {
            let xv = vdupq_n_f64(*a.get_unchecked(t));
            lo = vaddq_f64(lo, vmulq_f64(xv, vld1q_f64(p.add(t * DNR))));
            hi = vaddq_f64(hi, vmulq_f64(xv, vld1q_f64(p.add(t * DNR + 2))));
        }
        let mut out = [0f64; DNR];
        vst1q_f64(out.as_mut_ptr(), lo);
        vst1q_f64(out.as_mut_ptr().add(2), hi);
        out
    }
}

/// Tile-level dispatch on a pre-resolved [`SimdLevel`]. The branch is
/// perfectly predicted (the level never changes inside a kernel call);
/// the tile bodies amortize the non-inlined `target_feature` call over
/// `MR·NR·d` flops.
#[inline(always)]
fn dtile_4xnr(
    level: SimdLevel,
    a0: &[f32],
    a1: &[f32],
    a2: &[f32],
    a3: &[f32],
    panel: &[f32],
) -> [[f32; NR]; MR] {
    match level {
        // SAFETY: the non-scalar variants are only ever constructed after
        // runtime feature detection succeeded (see `detected_level`).
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { avx2::tile_4xnr(a0, a1, a2, a3, panel) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::tile_4xnr(a0, a1, a2, a3, panel) },
        SimdLevel::Scalar => tile_4xnr(a0, a1, a2, a3, panel),
    }
}

/// Single-row twin of [`dtile_4xnr`].
#[inline(always)]
fn dtile_1xnr(level: SimdLevel, a: &[f32], panel: &[f32]) -> [f32; NR] {
    match level {
        // SAFETY: see `dtile_4xnr`.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { avx2::tile_1xnr(a, panel) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::tile_1xnr(a, panel) },
        SimdLevel::Scalar => tile_1xnr(a, panel),
    }
}

/// f64 twin of [`dtile_4xnr`], dispatching the `DMat` gemm tiles.
#[inline(always)]
fn dtile64_4x(
    level: SimdLevel,
    a0: &[f64],
    a1: &[f64],
    a2: &[f64],
    a3: &[f64],
    panel: &[f64],
) -> [[f64; DNR]; MR] {
    match level {
        // SAFETY: see `dtile_4xnr`.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { avx2::tile64_4x(a0, a1, a2, a3, panel) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::tile64_4x(a0, a1, a2, a3, panel) },
        SimdLevel::Scalar => tile64_4x(a0, a1, a2, a3, panel),
    }
}

/// f64 twin of [`dtile_1xnr`].
#[inline(always)]
fn dtile64_1x(level: SimdLevel, a: &[f64], panel: &[f64]) -> [f64; DNR] {
    match level {
        // SAFETY: see `dtile_4xnr`.
        #[cfg(target_arch = "x86_64")]
        SimdLevel::Avx2 => unsafe { avx2::tile64_1x(a, panel) },
        #[cfg(target_arch = "aarch64")]
        SimdLevel::Neon => unsafe { neon::tile64_1x(a, panel) },
        SimdLevel::Scalar => tile64_1x(a, panel),
    }
}

/// RHS matrix packed into `NR`-wide panels for the distance microkernel.
///
/// Panel `q` covers RHS rows `q·NR .. q·NR+NR` (zero-padded past the end)
/// and stores them feature-major: element `[t·NR + r]` is RHS row
/// `q·NR + r`, feature `t`. Row squared norms ride along so the fused
/// squared-distance epilogue needs no extra lookups.
#[derive(Debug, Clone)]
pub struct PackedMat {
    /// Logical RHS rows (output columns of `A·Bᵀ`).
    pub rows: usize,
    /// Feature dimension.
    pub cols: usize,
    panels: Vec<f32>,
    sqnorms: Vec<f32>,
}

impl PackedMat {
    /// Row squared norms of the packed matrix.
    pub fn sqnorms(&self) -> &[f32] {
        &self.sqnorms
    }
}

/// Pack `rows`×`cols` row-major `data` into NR-wide panels (see
/// [`PackedMat`]).
pub fn pack_rhs_slice(data: &[f32], rows: usize, cols: usize) -> PackedMat {
    debug_assert_eq!(data.len(), rows * cols);
    let npanels = rows.div_ceil(NR).max(1);
    let mut panels = vec![0f32; npanels * cols * NR];
    let mut sqnorms = vec![0f32; rows];
    for q in 0..npanels {
        let panel = &mut panels[q * cols * NR..(q + 1) * cols * NR];
        let base = q * NR;
        let live = NR.min(rows.saturating_sub(base));
        for r in 0..live {
            let row = &data[(base + r) * cols..(base + r + 1) * cols];
            let mut s = 0.0f32;
            for (t, &v) in row.iter().enumerate() {
                panel[t * NR + r] = v;
                s += v * v;
            }
            sqnorms[base + r] = s;
        }
    }
    PackedMat { rows, cols, panels, sqnorms }
}

/// `MR`-row register tile: dot products of four LHS rows against one
/// packed panel. The per-feature loop reads one contiguous `NR`-vector of
/// the panel and broadcasts four LHS scalars.
///
/// This is the **reference op order** of the bit-identity contract
/// (module docs): accumulator lane `c` combines only with panel lane `c`,
/// one multiply rounding then one add rounding per feature step. The
/// AVX2/NEON tiles replay exactly this sequence 8 (resp. 2×4) lanes at a
/// time; any reordering here must be mirrored there.
#[inline(always)]
fn tile_4xnr(a0: &[f32], a1: &[f32], a2: &[f32], a3: &[f32], panel: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0f32; NR]; MR];
    for ((((pb, &x0), &x1), &x2), &x3) in
        panel.chunks_exact(NR).zip(a0).zip(a1).zip(a2).zip(a3)
    {
        for c in 0..NR {
            acc[0][c] += x0 * pb[c];
            acc[1][c] += x1 * pb[c];
            acc[2][c] += x2 * pb[c];
            acc[3][c] += x3 * pb[c];
        }
    }
    acc
}

/// Single-row tail tile.
#[inline(always)]
fn tile_1xnr(a: &[f32], panel: &[f32]) -> [f32; NR] {
    let mut acc = [0f32; NR];
    for (pb, &x) in panel.chunks_exact(NR).zip(a) {
        for c in 0..NR {
            acc[c] += x * pb[c];
        }
    }
    acc
}

/// f64 `MR`-row register tile — the **reference op order** of the f64
/// bit-identity contract, exactly like [`tile_4xnr`] for f32: lane `c`
/// combines only with panel lane `c`, one multiply rounding then one add
/// rounding per inner-dimension step. The AVX2/NEON `tile64_*` twins
/// replay this sequence 4 (resp. 2×2) lanes at a time.
#[inline(always)]
fn tile64_4x(a0: &[f64], a1: &[f64], a2: &[f64], a3: &[f64], panel: &[f64]) -> [[f64; DNR]; MR] {
    let mut acc = [[0f64; DNR]; MR];
    for ((((pb, &x0), &x1), &x2), &x3) in
        panel.chunks_exact(DNR).zip(a0).zip(a1).zip(a2).zip(a3)
    {
        for c in 0..DNR {
            acc[0][c] += x0 * pb[c];
            acc[1][c] += x1 * pb[c];
            acc[2][c] += x2 * pb[c];
            acc[3][c] += x3 * pb[c];
        }
    }
    acc
}

/// f64 single-row tail tile.
#[inline(always)]
fn tile64_1x(a: &[f64], panel: &[f64]) -> [f64; DNR] {
    let mut acc = [0f64; DNR];
    for (pb, &x) in panel.chunks_exact(DNR).zip(a) {
        for c in 0..DNR {
            acc[c] += x * pb[c];
        }
    }
    acc
}

/// Pack a `k`×`n` row-major RHS `b` into `DNR`-wide **column** panels for
/// `A·B`: panel `q` covers output columns `q·DNR .. q·DNR+DNR`, stored
/// inner-dimension-major (element `[t·DNR + c]` is `B[t, q·DNR+c]`,
/// zero-padded past `n`). The buffer is reused across calls — only
/// reshaped (with its memset) when the packed size actually changes; the
/// pad lanes are re-zeroed explicitly so a shrinking `n` cannot leak
/// stale values into the tiles.
fn dpack_cols(b: &[f64], k: usize, n: usize, panels: &mut Vec<f64>) {
    debug_assert_eq!(b.len(), k * n);
    let npanels = n.div_ceil(DNR).max(1);
    let need = npanels * k * DNR;
    if panels.len() != need {
        panels.clear();
        panels.resize(need, 0.0);
    }
    for q in 0..npanels {
        let base = q * DNR;
        let live = DNR.min(n.saturating_sub(base));
        let panel = &mut panels[q * k * DNR..(q + 1) * k * DNR];
        for (t, dst) in panel.chunks_exact_mut(DNR).enumerate() {
            dst[..live].copy_from_slice(&b[t * n + base..t * n + base + live]);
            dst[live..].fill(0.0);
        }
    }
}

/// Pack an `n`×`d` row-major RHS `b` into the same panel format as
/// [`dpack_cols`], but gathering **rows** for `A·Bᵀ`: element
/// `[t·DNR + r]` is `B[q·DNR+r, t]`. A tile then computes `A·Bᵀ` columns
/// `q·DNR..` with the identical kernel (and identical arithmetic) as the
/// `A·B` path.
fn dpack_rows(b: &[f64], n: usize, d: usize, panels: &mut Vec<f64>) {
    debug_assert_eq!(b.len(), n * d);
    let npanels = n.div_ceil(DNR).max(1);
    let need = npanels * d * DNR;
    if panels.len() != need {
        panels.clear();
        panels.resize(need, 0.0);
    }
    for q in 0..npanels {
        let base = q * DNR;
        let live = DNR.min(n.saturating_sub(base));
        let panel = &mut panels[q * d * DNR..(q + 1) * d * DNR];
        for (t, dst) in panel.chunks_exact_mut(DNR).enumerate() {
            for (r, pd) in dst[..live].iter_mut().enumerate() {
                *pd = b[(base + r) * d + t];
            }
            dst[live..].fill(0.0);
        }
    }
}

/// Blocked, threaded f64 gemm against pre-packed `DNR`-wide panels,
/// overwriting `out` (`m`×`n` row-major). Single driver for `A·B` and
/// `A·Bᵀ` — the packers above produce the same panel format for both, so
/// both products run the identical tile arithmetic. Output rows are
/// written over disjoint [`par_for_chunks`](par::par_for_chunks) ranges
/// and every element is a full fixed-order reduction over the inner
/// dimension, so results are independent of thread count and chunk
/// boundaries.
fn dgemm_packed_into(a: &[f64], m: usize, kk: usize, panels: &[f64], n: usize, out: &mut [f64]) {
    debug_assert_eq!(a.len(), m * kk);
    debug_assert_eq!(out.len(), m * n);
    if m == 0 || n == 0 {
        return;
    }
    let npanels = n.div_ceil(DNR).max(1);
    let level = simd_level();
    par::par_for_chunks(out, n * ROWS_PER_CHUNK, |start, chunk| {
        let row0 = start / n;
        let nrows = chunk.len() / n;
        let mut r = 0;
        // MR-row register tiles over the band.
        while r + MR <= nrows {
            let i0 = row0 + r;
            let a0 = &a[i0 * kk..(i0 + 1) * kk];
            let a1 = &a[(i0 + 1) * kk..(i0 + 2) * kk];
            let a2 = &a[(i0 + 2) * kk..(i0 + 3) * kk];
            let a3 = &a[(i0 + 3) * kk..(i0 + 4) * kk];
            for q in 0..npanels {
                let panel = &panels[q * kk * DNR..(q + 1) * kk * DNR];
                let acc = dtile64_4x(level, a0, a1, a2, a3, panel);
                let jb = q * DNR;
                let cr = DNR.min(n - jb);
                for (rr, accr) in acc.iter().enumerate() {
                    chunk[(r + rr) * n + jb..(r + rr) * n + jb + cr]
                        .copy_from_slice(&accr[..cr]);
                }
            }
            r += MR;
        }
        // Tail rows.
        while r < nrows {
            let i0 = row0 + r;
            let arow = &a[i0 * kk..(i0 + 1) * kk];
            for q in 0..npanels {
                let panel = &panels[q * kk * DNR..(q + 1) * kk * DNR];
                let acc = dtile64_1x(level, arow, panel);
                let jb = q * DNR;
                let cr = DNR.min(n - jb);
                chunk[r * n + jb..r * n + jb + cr].copy_from_slice(&acc[..cr]);
            }
            r += 1;
        }
    });
}

/// Reusable packing buffers for the f64 gemm family
/// ([`DMat::matmul_into`] and friends): `panels` holds the packed RHS,
/// `lhs_t` the transposed LHS of `matmul_tn_into`. Iterative solvers keep
/// one per solve so every iteration packs into warm memory.
#[derive(Debug, Default)]
pub struct DGemmScratch {
    panels: Vec<f64>,
    lhs_t: Vec<f64>,
}

/// A column whose residual norm after projection falls below this is
/// treated as rank-deficient by [`orthonormalize_cols`] — the single
/// contract shared by every solver (previously `bipartite` used 1e-13
/// and `lobpcg` 1e-12; the stricter threshold won).
pub const ORTHO_RANK_TOL: f64 = 1e-13;

/// Orthonormalize the columns of `x` in place by blocked two-pass
/// classical Gram–Schmidt (CGS2). Returns `false` — leaving `x`
/// unspecified — as soon as a column's residual norm falls below
/// [`ORTHO_RANK_TOL`] (numerical rank deficiency).
///
/// The matrix is transposed once into `scratch` so every column is a
/// contiguous run: the projection coefficients of column `c` against all
/// previous columns are then one streaming sweep (a `c`×`n` gemv) and
/// the subtraction a second, instead of the `cols`-strided element loops
/// this replaces. Two full passes give CGS2 its MGS-grade stability.
/// Entirely sequential with a fixed reduction order, so results never
/// depend on thread count or SIMD dispatch.
pub fn orthonormalize_cols(x: &mut DMat, scratch: &mut Vec<f64>) -> bool {
    let (n, b) = (x.rows, x.cols);
    if b == 0 {
        return true;
    }
    if scratch.len() != b * n + b {
        scratch.clear();
        scratch.resize(b * n + b, 0.0);
    }
    let (qt, g) = scratch.split_at_mut(b * n);
    for r in 0..n {
        for (c, &v) in x.row(r).iter().enumerate() {
            qt[c * n + r] = v;
        }
    }
    for c in 0..b {
        let (prevs, rest) = qt.split_at_mut(c * n);
        let v = &mut rest[..n];
        for _pass in 0..2 {
            for (j, gj) in g[..c].iter_mut().enumerate() {
                let q = &prevs[j * n..(j + 1) * n];
                let mut dot = 0.0;
                for (a, t) in q.iter().zip(v.iter()) {
                    dot += a * t;
                }
                *gj = dot;
            }
            for (j, &gj) in g[..c].iter().enumerate() {
                let q = &prevs[j * n..(j + 1) * n];
                for (o, &qv) in v.iter_mut().zip(q) {
                    *o -= gj * qv;
                }
            }
        }
        let mut norm = 0.0;
        for t in v.iter() {
            norm += t * t;
        }
        let norm = norm.sqrt();
        if norm < ORTHO_RANK_TOL {
            return false;
        }
        for t in v.iter_mut() {
            *t /= norm;
        }
    }
    for r in 0..n {
        for (c, o) in x.row_mut(r).iter_mut().enumerate() {
            *o = qt[c * n + r];
        }
    }
    true
}

/// The full per-solver working set of the reduced eigensolvers
/// (`bipartite::reduced_eig`, Chebyshev subspace iteration, LOBPCG):
/// gemm packing buffers, orthonormalization scratch, and the named block
/// buffers the iterations cycle through. Holding one of these across a
/// solve makes the Chebyshev three-term recurrence, the Rayleigh–Ritz
/// step, and the LOBPCG `[X, R, P]` assembly allocation-free once warm —
/// only the `q`×`q` projected eigenproblem (`q ≈ k+8`) and the final
/// returned eigenvectors still allocate.
///
/// The fields are deliberately crate-visible rather than encapsulated:
/// the solvers borrow several buffers simultaneously (e.g. a gemm from
/// `basis` into `prod` while packing into `gemm`), which only the
/// compiler's disjoint-field borrows allow.
#[derive(Debug, Default)]
pub struct EigScratch {
    pub(crate) gemm: DGemmScratch,
    pub(crate) ortho: Vec<f64>,
    /// Current basis block X (p×q).
    pub(crate) basis: DMat,
    /// Operator product S·X / A·X.
    pub(crate) prod: DMat,
    /// LOBPCG residual block R.
    pub(crate) resid: DMat,
    /// LOBPCG subspace [X, R, P] (p×2q or p×3q).
    pub(crate) wide: DMat,
    /// Operator product on the wide subspace.
    pub(crate) wide2: DMat,
    /// Projected q×q Rayleigh–Ritz matrix.
    pub(crate) small: DMat,
    /// Eigenvector column block extracted from the small problem.
    pub(crate) rot: DMat,
    /// Rotated Ritz basis X·rot.
    pub(crate) ritz: DMat,
    /// Best-so-far Ritz block for best-effort fallbacks.
    pub(crate) keep: DMat,
    /// LOBPCG direction block P.
    pub(crate) dir: DMat,
    /// Chebyshev recurrence term z_{j-1}.
    pub(crate) cheb0: DMat,
    /// Chebyshev recurrence term z_j.
    pub(crate) cheb1: DMat,
    /// Chebyshev recurrence term z_{j+1}.
    pub(crate) cheb2: DMat,
}

/// Blocked, threaded `A·Bᵀ` against a packed RHS, writing into `out`
/// (`m`×`packed.rows` row-major). With `FUSE`, the epilogue rewrites each
/// tile as clamped squared distances using `xn` (LHS row squared norms)
/// and the packed row norms.
fn gemm_nt_packed_into<const FUSE: bool>(
    a: &[f32],
    m: usize,
    d: usize,
    packed: &PackedMat,
    xn: &[f32],
    out: &mut [f32],
) {
    let n = packed.rows;
    debug_assert_eq!(packed.cols, d);
    debug_assert_eq!(a.len(), m * d);
    debug_assert_eq!(out.len(), m * n);
    if FUSE {
        debug_assert_eq!(xn.len(), m);
    }
    if m == 0 || n == 0 {
        return;
    }
    let npanels = n.div_ceil(NR).max(1);
    let cn = &packed.sqnorms;
    let level = simd_level();
    par::par_for_chunks(out, n * ROWS_PER_CHUNK, |start, chunk| {
        let row0 = start / n;
        let nrows = chunk.len() / n;
        let mut r = 0;
        // MR-row register tiles over the band.
        while r + MR <= nrows {
            let i0 = row0 + r;
            let a0 = &a[i0 * d..(i0 + 1) * d];
            let a1 = &a[(i0 + 1) * d..(i0 + 2) * d];
            let a2 = &a[(i0 + 2) * d..(i0 + 3) * d];
            let a3 = &a[(i0 + 3) * d..(i0 + 4) * d];
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = dtile_4xnr(level, a0, a1, a2, a3, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                for (rr, accr) in acc.iter().enumerate() {
                    let orow = &mut chunk[(r + rr) * n + jb..(r + rr) * n + jb + cr];
                    if FUSE {
                        let x = xn[i0 + rr];
                        for (c, o) in orow.iter_mut().enumerate() {
                            *o = (x + cn[jb + c] - 2.0 * accr[c]).max(0.0);
                        }
                    } else {
                        orow.copy_from_slice(&accr[..cr]);
                    }
                }
            }
            r += MR;
        }
        // Tail rows.
        while r < nrows {
            let i0 = row0 + r;
            let arow = &a[i0 * d..(i0 + 1) * d];
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = dtile_1xnr(level, arow, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                let orow = &mut chunk[r * n + jb..r * n + jb + cr];
                if FUSE {
                    let x = xn[i0];
                    for (c, o) in orow.iter_mut().enumerate() {
                        *o = (x + cn[jb + c] - 2.0 * acc[c]).max(0.0);
                    }
                } else {
                    orow.copy_from_slice(&acc[..cr]);
                }
            }
            r += 1;
        }
    });
}

/// Reusable scratch for batched packed-distance calls — holds the LHS row
/// norms (and, for [`nearest_packed_into`], the per-row argmin pairs) so
/// per-batch calls allocate nothing once warm.
#[derive(Debug, Default)]
pub struct DistScratch {
    xn: Vec<f32>,
    best: Vec<(u32, f32)>,
}

/// Squared distances of `rows` row-major LHS rows (`x`, length
/// `rows·packed.cols`) against a pre-packed RHS, written into `out`
/// (resized to `rows·packed.rows`). Batched callers keep `packed`,
/// `scratch` and `out` across batches so the steady state is
/// allocation-free and never re-touches cold RHS memory.
pub fn sq_dists_into(
    x: &[f32],
    rows: usize,
    packed: &PackedMat,
    scratch: &mut DistScratch,
    out: &mut Vec<f32>,
) {
    let d = packed.cols;
    debug_assert_eq!(x.len(), rows * d);
    scratch.xn.clear();
    scratch.xn.extend((0..rows).map(|i| {
        x[i * d..(i + 1) * d].iter().map(|&v| v * v).sum::<f32>()
    }));
    // Every element is overwritten by the kernel; only grow/shrink when the
    // shape actually changed so warm batches skip the memset.
    if out.len() != rows * packed.rows {
        out.clear();
        out.resize(rows * packed.rows, 0.0);
    }
    gemm_nt_packed_into::<true>(x, rows, d, packed, &scratch.xn, out);
}

/// Fused nearest-row search against a packed RHS: per LHS row, the argmin
/// index and min squared distance — the distance block itself is never
/// materialized. Ties resolve to the lowest index (same contract as a
/// forward scan over `sq_dists`). Allocating convenience wrapper over
/// [`nearest_packed_into`]; loops (k-means assignment, batched KNR)
/// should call the `_into` form with persistent buffers instead.
pub fn nearest_packed(x: &Mat, packed: &PackedMat) -> (Vec<u32>, Vec<f32>) {
    let mut scratch = DistScratch::default();
    let mut labels = Vec::new();
    let mut dists = Vec::new();
    nearest_packed_into(x, packed, &mut scratch, &mut labels, &mut dists);
    (labels, dists)
}

/// [`nearest_packed`] writing into caller buffers: `labels`/`dists` are
/// cleared and refilled (capacity reused), `scratch` carries the row
/// norms and argmin pairs across calls. A caller looping over batches or
/// k-means iterations allocates nothing once warm.
pub fn nearest_packed_into(
    x: &Mat,
    packed: &PackedMat,
    scratch: &mut DistScratch,
    labels: &mut Vec<u32>,
    dists: &mut Vec<f32>,
) {
    let m = x.rows;
    let d = x.cols;
    let n = packed.rows;
    assert_eq!(d, packed.cols, "nearest_packed dim mismatch");
    assert!(n >= 1, "nearest_packed: empty RHS");
    scratch.xn.clear();
    scratch
        .xn
        .extend((0..m).map(|i| x.row(i).iter().map(|&v| v * v).sum::<f32>()));
    // Every element is overwritten by the kernel; only reshape on change
    // so warm batches skip the memset.
    if scratch.best.len() != m {
        scratch.best.clear();
        scratch.best.resize(m, (0u32, f32::INFINITY));
    }
    let npanels = n.div_ceil(NR).max(1);
    let cn = &packed.sqnorms;
    let a = &x.data;
    let xn = &scratch.xn;
    let level = simd_level();
    par::par_for_chunks(&mut scratch.best, ROWS_PER_CHUNK * MR, |start, chunk| {
        let mut r = 0;
        while r + MR <= chunk.len() {
            let i0 = start + r;
            let a0 = &a[i0 * d..(i0 + 1) * d];
            let a1 = &a[(i0 + 1) * d..(i0 + 2) * d];
            let a2 = &a[(i0 + 2) * d..(i0 + 3) * d];
            let a3 = &a[(i0 + 3) * d..(i0 + 4) * d];
            let mut bests = [(0u32, f32::INFINITY); MR];
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = dtile_4xnr(level, a0, a1, a2, a3, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                for (rr, accr) in acc.iter().enumerate() {
                    let xv = xn[i0 + rr];
                    for c in 0..cr {
                        let v = (xv + cn[jb + c] - 2.0 * accr[c]).max(0.0);
                        if v < bests[rr].1 {
                            bests[rr] = ((jb + c) as u32, v);
                        }
                    }
                }
            }
            chunk[r..r + MR].copy_from_slice(&bests);
            r += MR;
        }
        while r < chunk.len() {
            let i0 = start + r;
            let arow = &a[i0 * d..(i0 + 1) * d];
            let mut bi = (0u32, f32::INFINITY);
            for q in 0..npanels {
                let panel = &packed.panels[q * d * NR..(q + 1) * d * NR];
                let acc = dtile_1xnr(level, arow, panel);
                let jb = q * NR;
                let cr = NR.min(n - jb);
                for c in 0..cr {
                    let v = (xn[i0] + cn[jb + c] - 2.0 * acc[c]).max(0.0);
                    if v < bi.1 {
                        bi = ((jb + c) as u32, v);
                    }
                }
            }
            chunk[r] = bi;
            r += 1;
        }
    });
    labels.clear();
    labels.extend(scratch.best.iter().map(|&(l, _)| l));
    dists.clear();
    dists.extend(scratch.best.iter().map(|&(_, v)| v));
}

/// f32 row-major matrix. The workhorse container for datasets,
/// representatives, eigenvector embeddings.
#[derive(Debug, Clone, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "Mat::from_vec shape mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f32 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f32) {
        self.data[i * self.cols + j] = v;
    }

    /// Gather a subset of rows into a new matrix.
    pub fn gather_rows(&self, idx: &[usize]) -> Mat {
        let mut out = Mat::zeros(idx.len(), self.cols);
        for (o, &i) in idx.iter().enumerate() {
            out.row_mut(o).copy_from_slice(self.row(i));
        }
        out
    }

    /// Squared L2 norm of every row.
    pub fn row_sqnorms(&self) -> Vec<f32> {
        par::par_map(self.rows, |i| {
            self.row(i).iter().map(|&v| v * v).sum::<f32>()
        })
    }

    /// Pack this matrix as the RHS of the distance microkernel (see
    /// [`PackedMat`]). Batched callers pack once and reuse across batches.
    pub fn pack_rhs(&self) -> PackedMat {
        pack_rhs_slice(&self.data, self.rows, self.cols)
    }

    /// `self · otherᵀ` (m×d · (n×d)ᵀ = m×n) on the packed register-tiled
    /// microkernel. The RHS is given row-major with rows as the *output
    /// columns*, the natural layout for pairwise-distance style products.
    pub fn matmul_nt(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dim mismatch");
        let packed = other.pack_rhs();
        self.matmul_nt_packed(&packed)
    }

    /// `self · packedᵀ` against an already-packed RHS.
    pub fn matmul_nt_packed(&self, packed: &PackedMat) -> Mat {
        assert_eq!(self.cols, packed.cols, "matmul_nt inner dim mismatch");
        let mut out = Mat::zeros(self.rows, packed.rows);
        gemm_nt_packed_into::<false>(&self.data, self.rows, self.cols, packed, &[], &mut out.data);
        out
    }

    /// Pairwise squared Euclidean distances `‖xᵢ − cⱼ‖²` (m×n), computed as
    /// ‖x‖² + ‖c‖² − 2·x·cᵀ — the same formulation the L1 Pallas kernel
    /// uses, fused into the gemm tile epilogue (no second memory pass).
    /// Negative values from cancellation are clamped to 0.
    pub fn sq_dists(&self, centers: &Mat) -> Mat {
        let packed = centers.pack_rhs();
        self.sq_dists_packed(&packed)
    }

    /// [`Mat::sq_dists`] against an already-packed RHS.
    pub fn sq_dists_packed(&self, packed: &PackedMat) -> Mat {
        assert_eq!(self.cols, packed.cols, "sq_dists dim mismatch");
        let xn = self.row_sqnorms();
        let mut out = Mat::zeros(self.rows, packed.rows);
        gemm_nt_packed_into::<true>(&self.data, self.rows, self.cols, packed, &xn, &mut out.data);
        out
    }

    /// Convert to f64.
    pub fn to_f64(&self) -> DMat {
        DMat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f64).collect(),
        }
    }
}

/// f64 row-major matrix for the small spectral problems.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct DMat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl DMat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        DMat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = DMat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Self {
        assert_eq!(data.len(), rows * cols);
        DMat { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn transpose(&self) -> DMat {
        let mut t = DMat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t.data[j * self.rows + i] = self.data[i * self.cols + j];
            }
        }
        t
    }

    /// Re-dimension to `rows`×`cols`, reallocating only when the element
    /// count changes. Contents are unspecified afterwards — this is the
    /// "about to be overwritten" primitive of the `_into` gemm family.
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        if self.data.len() != rows * cols {
            self.data.clear();
            self.data.resize(rows * cols, 0.0);
        }
    }

    /// Become a copy of `src`, reusing this matrix's allocation.
    pub fn copy_from(&mut self, src: &DMat) {
        self.reshape(src.rows, src.cols);
        self.data.copy_from_slice(&src.data);
    }

    /// Plain gemm `self · other` on the packed register-tiled f64 kernel
    /// (branch-free inner loop — the old per-element `av == 0.0` test is
    /// gone with the old element loops). Allocating convenience wrapper;
    /// iterative callers use [`DMat::matmul_into`] with persistent
    /// scratch.
    pub fn matmul(&self, other: &DMat) -> DMat {
        let mut scratch = DGemmScratch::default();
        let mut out = DMat::default();
        self.matmul_into(other, &mut scratch, &mut out);
        out
    }

    /// `self · other` written into `out` (reshaped as needed), packing the
    /// RHS into `scratch`. Once warm, a fixed-shape call allocates
    /// nothing.
    pub fn matmul_into(&self, other: &DMat, scratch: &mut DGemmScratch, out: &mut DMat) {
        assert_eq!(self.cols, other.rows, "matmul inner dim mismatch");
        out.reshape(self.rows, other.cols);
        dpack_cols(&other.data, other.rows, other.cols, &mut scratch.panels);
        dgemm_packed_into(
            &self.data,
            self.rows,
            self.cols,
            &scratch.panels,
            other.cols,
            &mut out.data,
        );
    }

    /// `self · otherᵀ` (m×d · (n×d)ᵀ = m×n). The row-packer lands `other`
    /// in the same panel format as the `A·B` path, so the product is not
    /// just equivalent but **bit-identical** to
    /// `self.matmul(&other.transpose())` — without materializing the
    /// transpose.
    pub fn matmul_nt(&self, other: &DMat) -> DMat {
        let mut scratch = DGemmScratch::default();
        let mut out = DMat::default();
        self.matmul_nt_into(other, &mut scratch, &mut out);
        out
    }

    /// [`DMat::matmul_nt`] writing into caller buffers.
    pub fn matmul_nt_into(&self, other: &DMat, scratch: &mut DGemmScratch, out: &mut DMat) {
        assert_eq!(self.cols, other.cols, "matmul_nt inner dim mismatch");
        out.reshape(self.rows, other.rows);
        dpack_rows(&other.data, other.rows, other.cols, &mut scratch.panels);
        dgemm_packed_into(
            &self.data,
            self.rows,
            self.cols,
            &scratch.panels,
            other.rows,
            &mut out.data,
        );
    }

    /// `selfᵀ · other` ((p×m)ᵀ · p×n = m×n) — the Rayleigh–Ritz
    /// projection shape `Xᵀ(SX)`. The LHS is transposed once into
    /// `scratch` (O(p·m), negligible against the O(p·m·n) product) so the
    /// kernel runs over contiguous rows; arithmetic is bit-identical to
    /// `self.transpose().matmul(other)`.
    pub fn matmul_tn_into(&self, other: &DMat, scratch: &mut DGemmScratch, out: &mut DMat) {
        assert_eq!(self.rows, other.rows, "matmul_tn inner dim mismatch");
        let (m, kk) = (self.cols, self.rows);
        if scratch.lhs_t.len() != m * kk {
            scratch.lhs_t.clear();
            scratch.lhs_t.resize(m * kk, 0.0);
        }
        for r in 0..kk {
            for (c, &v) in self.row(r).iter().enumerate() {
                scratch.lhs_t[c * kk + r] = v;
            }
        }
        out.reshape(m, other.cols);
        dpack_cols(&other.data, other.rows, other.cols, &mut scratch.panels);
        dgemm_packed_into(&scratch.lhs_t, m, kk, &scratch.panels, other.cols, &mut out.data);
    }

    /// `selfᵀ · self` (Gram matrix), exploiting symmetry.
    pub fn gram(&self) -> DMat {
        let (m, n) = (self.rows, self.cols);
        let mut g = DMat::zeros(n, n);
        for r in 0..m {
            let row = self.row(r);
            for i in 0..n {
                let ri = row[i];
                if ri == 0.0 {
                    continue;
                }
                for j in i..n {
                    g.data[i * n + j] += ri * row[j];
                }
            }
        }
        for i in 0..n {
            for j in 0..i {
                g.data[i * n + j] = g.data[j * n + i];
            }
        }
        g
    }

    pub fn to_f32(&self) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| v as f32).collect(),
        }
    }

    /// Frobenius norm of (self - other).
    pub fn frob_dist(&self, other: &DMat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randmat(r: usize, c: usize, rng: &mut Rng) -> Mat {
        Mat::from_vec(r, c, (0..r * c).map(|_| rng.f32() - 0.5).collect())
    }

    #[test]
    fn matmul_nt_matches_naive() {
        let mut rng = Rng::new(11);
        for &(m, n, d) in &[(1usize, 1usize, 1usize), (3, 5, 4), (17, 9, 7), (64, 33, 13)] {
            let a = randmat(m, d, &mut rng);
            let b = randmat(n, d, &mut rng);
            let g = a.matmul_nt(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..d).map(|t| a.at(i, t) * b.at(j, t)).sum();
                    assert!((g.at(i, j) - want).abs() < 1e-4, "({i},{j})");
                }
            }
        }
    }

    #[test]
    fn sq_dists_matches_direct() {
        let mut rng = Rng::new(12);
        let x = randmat(23, 6, &mut rng);
        let c = randmat(7, 6, &mut rng);
        let d2 = x.sq_dists(&c);
        for i in 0..23 {
            for j in 0..7 {
                let want: f32 = (0..6)
                    .map(|t| {
                        let diff = x.at(i, t) - c.at(j, t);
                        diff * diff
                    })
                    .sum();
                assert!((d2.at(i, j) - want).abs() < 1e-4);
                assert!(d2.at(i, j) >= 0.0);
            }
        }
    }

    #[test]
    fn dmat_matmul_and_gram() {
        let a = DMat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = DMat::from_vec(3, 2, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![58.0, 64.0, 139.0, 154.0]);
        let g = a.gram();
        let want = a.transpose().matmul(&a);
        assert!(g.frob_dist(&want) < 1e-12);
    }

    #[test]
    fn packed_matches_unpacked_at_awkward_shapes() {
        // shapes straddling the MR/NR tile boundaries, including d=0-free
        // tiny cases and single-row/column extremes
        let mut rng = Rng::new(21);
        for &(m, n, d) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (4, 8, 16),
            (5, 9, 3),
            (16, 33, 10),
            (65, 100, 100),
            (130, 17, 1),
        ] {
            let a = randmat(m, d, &mut rng);
            let b = randmat(n, d, &mut rng);
            let packed = b.pack_rhs();
            assert_eq!(packed.rows, n);
            assert_eq!(packed.cols, d);
            // packed sqnorms match direct
            for (j, &s) in packed.sqnorms().iter().enumerate() {
                let want: f32 = b.row(j).iter().map(|&v| v * v).sum();
                assert!((s - want).abs() <= 1e-4 * want.abs().max(1.0));
            }
            let g = a.matmul_nt_packed(&packed);
            let d2 = a.sq_dists_packed(&packed);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..d).map(|t| a.at(i, t) * b.at(j, t)).sum();
                    assert!((g.at(i, j) - want).abs() < 1e-3, "gemm ({i},{j}) m={m} n={n} d={d}");
                    let wd: f32 = (0..d)
                        .map(|t| {
                            let diff = a.at(i, t) - b.at(j, t);
                            diff * diff
                        })
                        .sum();
                    assert!(
                        (d2.at(i, j) - wd).abs() < 1e-3,
                        "sqd ({i},{j}) m={m} n={n} d={d}"
                    );
                }
            }
        }
    }

    #[test]
    fn sq_dists_into_reuses_buffers() {
        let mut rng = Rng::new(22);
        let x = randmat(37, 9, &mut rng);
        let c = randmat(11, 9, &mut rng);
        let packed = c.pack_rhs();
        let mut scratch = DistScratch::default();
        let mut out = Vec::new();
        // two batches through the same scratch/out
        for (lo, hi) in [(0usize, 20usize), (20, 37)] {
            sq_dists_into(&x.data[lo * 9..hi * 9], hi - lo, &packed, &mut scratch, &mut out);
            assert_eq!(out.len(), (hi - lo) * 11);
            let full = x.sq_dists(&c);
            for bi in 0..hi - lo {
                for j in 0..11 {
                    assert!((out[bi * 11 + j] - full.at(lo + bi, j)).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn nearest_packed_matches_scan() {
        let mut rng = Rng::new(23);
        for &(m, n, d) in &[(1usize, 1usize, 2usize), (9, 5, 3), (70, 23, 12), (128, 8, 4)] {
            let x = randmat(m, d, &mut rng);
            let c = randmat(n, d, &mut rng);
            let packed = c.pack_rhs();
            let (labels, dists) = nearest_packed(&x, &packed);
            let d2 = x.sq_dists(&c);
            for i in 0..m {
                let row = d2.row(i);
                let mut best = 0usize;
                for (j, &v) in row.iter().enumerate().skip(1) {
                    if v < row[best] {
                        best = j;
                    }
                }
                assert_eq!(labels[i] as usize, best, "row {i} m={m} n={n} d={d}");
                assert!((dists[i] - row[best]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn gather_rows_works() {
        let m = Mat::from_vec(3, 2, vec![0.0, 1.0, 2.0, 3.0, 4.0, 5.0]);
        let g = m.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![4.0, 5.0, 0.0, 1.0]);
    }

    /// Restores the default SIMD dispatch even when an assertion unwinds,
    /// so a failing test cannot leak the forced-scalar mode.
    struct SimdGuard;

    impl Drop for SimdGuard {
        fn drop(&mut self) {
            set_simd_override(0);
        }
    }

    /// The bit-identity contract (module docs): forced-scalar and default
    /// dispatch agree to the bit across awkward shapes — every d in
    /// 1..=9 plus 16 and 100, odd row tails, and column counts that are
    /// not a multiple of the NR=8 panel. On hardware without a vector
    /// path both legs run scalar and the test passes trivially. Other
    /// tests running concurrently may briefly observe the forced-scalar
    /// mode; by this very contract that cannot change their results.
    #[test]
    fn simd_dispatch_bit_identical_to_scalar() {
        let _restore = SimdGuard;
        let mut rng = Rng::new(31);
        let bits = |m: &Mat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let fbits = |v: &[f32]| v.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for &d in &[1usize, 2, 3, 4, 5, 6, 7, 8, 9, 16, 100] {
            for &(m, n) in &[(1usize, 1usize), (5, 9), (13, 23), (33, 100)] {
                let a = randmat(m, d, &mut rng);
                let b = randmat(n, d, &mut rng);
                let packed = b.pack_rhs();
                set_simd_override(1);
                let g_s = a.matmul_nt_packed(&packed);
                let d_s = a.sq_dists_packed(&packed);
                let (l_s, v_s) = nearest_packed(&a, &packed);
                set_simd_override(0);
                let g_v = a.matmul_nt_packed(&packed);
                let d_v = a.sq_dists_packed(&packed);
                let (l_v, v_v) = nearest_packed(&a, &packed);
                assert_eq!(bits(&g_s), bits(&g_v), "gemm m={m} n={n} d={d}");
                assert_eq!(bits(&d_s), bits(&d_v), "sq_dists m={m} n={n} d={d}");
                assert_eq!(l_s, l_v, "nearest labels m={m} n={n} d={d}");
                assert_eq!(fbits(&v_s), fbits(&v_v), "nearest dists m={m} n={n} d={d}");
            }
        }
    }

    fn drandmat(r: usize, c: usize, rng: &mut Rng) -> DMat {
        DMat::from_vec(r, c, (0..r * c).map(|_| rng.f64() - 0.5).collect())
    }

    /// The packed f64 gemm matches a naive triple loop at shapes
    /// straddling the MR/DNR tile boundaries, and the three product
    /// variants (`A·B`, `A·Bᵀ`, `Aᵀ·B`) are bit-identical to each other
    /// through explicit transposes — same panels, same kernel, same
    /// arithmetic.
    #[test]
    fn dmat_packed_matmul_matches_naive_at_awkward_shapes() {
        let mut rng = Rng::new(41);
        let bits = |m: &DMat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for &(m, kk, n) in &[
            (1usize, 1usize, 1usize),
            (3, 5, 4),
            (4, 4, 4),
            (7, 9, 5),
            (17, 23, 13),
            (33, 16, 40),
            (65, 2, 101),
        ] {
            let a = drandmat(m, kk, &mut rng);
            let b = drandmat(kk, n, &mut rng);
            let c = a.matmul(&b);
            assert_eq!((c.rows, c.cols), (m, n));
            for i in 0..m {
                for j in 0..n {
                    let want: f64 = (0..kk).map(|t| a.at(i, t) * b.at(t, j)).sum();
                    assert!(
                        (c.at(i, j) - want).abs() < 1e-12,
                        "({i},{j}) m={m} k={kk} n={n}"
                    );
                }
            }
            let c_nt = a.matmul_nt(&b.transpose());
            assert_eq!(bits(&c), bits(&c_nt), "nt m={m} k={kk} n={n}");
            let at = a.transpose();
            let mut scratch = DGemmScratch::default();
            let mut c_tn = DMat::default();
            at.matmul_tn_into(&b, &mut scratch, &mut c_tn);
            assert_eq!(bits(&c), bits(&c_tn), "tn m={m} k={kk} n={n}");
            // warm re-run through the same scratch reuses the buffers
            at.matmul_tn_into(&b, &mut scratch, &mut c_tn);
            assert_eq!(bits(&c), bits(&c_tn), "tn rerun m={m} k={kk} n={n}");
        }
    }

    /// The f64 bit-identity contract: forced-scalar and default dispatch
    /// agree to the bit across awkward shapes (see
    /// `simd_dispatch_bit_identical_to_scalar` for the f32 twin and the
    /// concurrency caveat).
    #[test]
    fn dmat_simd_dispatch_bit_identical_to_scalar() {
        let _restore = SimdGuard;
        let mut rng = Rng::new(42);
        let bits = |m: &DMat| m.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        for &kk in &[1usize, 2, 3, 4, 5, 7, 8, 16, 100] {
            for &(m, n) in &[(1usize, 1usize), (5, 9), (13, 23), (33, 50)] {
                let a = drandmat(m, kk, &mut rng);
                let b = drandmat(kk, n, &mut rng);
                let bt = b.transpose();
                set_simd_override(1);
                let c_s = a.matmul(&b);
                let n_s = a.matmul_nt(&bt);
                set_simd_override(0);
                let c_v = a.matmul(&b);
                let n_v = a.matmul_nt(&bt);
                assert_eq!(bits(&c_s), bits(&c_v), "matmul m={m} k={kk} n={n}");
                assert_eq!(bits(&n_s), bits(&n_v), "matmul_nt m={m} k={kk} n={n}");
            }
        }
    }

    #[test]
    fn reshape_and_copy_from_reuse_allocations() {
        let mut m = DMat::zeros(4, 6);
        let cap = m.data.capacity();
        m.reshape(6, 4);
        assert_eq!((m.rows, m.cols, m.data.len()), (6, 4, 24));
        assert_eq!(m.data.capacity(), cap, "same element count must not realloc");
        let src = DMat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        m.copy_from(&src);
        assert_eq!(m, src);
    }

    #[test]
    fn orthonormalize_cols_orthonormalizes_and_detects_deficiency() {
        let mut rng = Rng::new(43);
        let mut x = drandmat(20, 5, &mut rng);
        let mut scratch = Vec::new();
        assert!(orthonormalize_cols(&mut x, &mut scratch));
        let g = x.gram();
        for i in 0..5 {
            for j in 0..5 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((g.at(i, j) - want).abs() < 1e-10, "({i},{j})");
            }
        }
        // a duplicated column is rank-deficient
        let mut bad = drandmat(20, 3, &mut rng);
        for r in 0..20 {
            let v = bad.at(r, 0);
            bad.set(r, 2, v);
        }
        assert!(!orthonormalize_cols(&mut bad, &mut scratch));
        // empty block is trivially orthonormal
        let mut empty = DMat::zeros(7, 0);
        assert!(orthonormalize_cols(&mut empty, &mut scratch));
    }

    #[test]
    fn nearest_packed_into_matches_and_reuses_buffers() {
        let mut rng = Rng::new(32);
        let c = randmat(11, 6, &mut rng);
        let packed = c.pack_rhs();
        let mut scratch = DistScratch::default();
        let mut labels = Vec::new();
        let mut dists = Vec::new();
        for &m in &[7usize, 30, 30, 13] {
            let x = randmat(m, 6, &mut rng);
            nearest_packed_into(&x, &packed, &mut scratch, &mut labels, &mut dists);
            let (wl, wv) = nearest_packed(&x, &packed);
            assert_eq!(labels, wl, "labels at m={m}");
            let bits = |v: &[f32]| v.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&dists), bits(&wv), "dists at m={m}");
        }
        // Warm steady state: shrinking batches reuse capacity.
        let caps = (labels.capacity(), dists.capacity());
        let x = randmat(13, 6, &mut rng);
        nearest_packed_into(&x, &packed, &mut scratch, &mut labels, &mut dists);
        assert_eq!((labels.capacity(), dists.capacity()), caps);
    }
}
