//! **SC** — original spectral clustering (von Luxburg's normalized cut
//! formulation): full N×N Gaussian affinity sparsified to the K-nearest
//! neighbors, generalized eigenproblem on the graph Laplacian, k-means
//! discretization. O(N²d) + O(N³): the reference method that motivates
//! everything else in the paper (N/A beyond ~MNIST scale).

use super::ClusteringOutput;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::{DMat, Mat};
use crate::util::argmin_k;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Build the symmetric KNN Gaussian affinity (dense N×N, tests/small-N
/// only). σ = mean distance to the K-th nearest neighbor.
pub fn knn_gaussian_affinity(x: &Mat, k_nn: usize) -> DMat {
    let n = x.rows;
    let d2 = x.sq_dists(x);
    // σ from K-NN distances
    let mut sum_knn = 0.0f64;
    let mut knn_sets: Vec<Vec<usize>> = Vec::with_capacity(n);
    for i in 0..n {
        let row: Vec<f64> = d2.data[i * n..(i + 1) * n].iter().map(|&v| v as f64).collect();
        let top = argmin_k(&row, k_nn + 1); // includes self at distance 0
        let nbrs: Vec<usize> = top.into_iter().filter(|&j| j != i).take(k_nn).collect();
        sum_knn += nbrs.iter().map(|&j| row[j].sqrt()).sum::<f64>();
        knn_sets.push(nbrs);
    }
    let sigma = (sum_knn / (n * k_nn) as f64).max(1e-12);
    let denom = 2.0 * sigma * sigma;
    let mut aff = DMat::zeros(n, n);
    for (i, nbrs) in knn_sets.iter().enumerate() {
        for &j in nbrs {
            let w = (-(d2.at(i, j) as f64) / denom).exp();
            // symmetrize: mutual max
            if w > aff.at(i, j) {
                aff.set(i, j, w);
                aff.set(j, i, w);
            }
        }
    }
    aff
}

/// Run original spectral clustering.
pub fn sc(x: &Mat, k: usize, k_nn: usize, seed: u64) -> Result<ClusteringOutput> {
    let n = x.rows;
    ensure_arg!(k >= 1 && k <= n, "sc: bad k");
    ensure_arg!(n >= 3, "sc: need >= 3 objects");
    let mut timer = PhaseTimer::new();
    let aff = timer.time("affinity", || knn_gaussian_affinity(x, k_nn.max(1)));
    // guard isolated nodes: connect to overall nearest neighbor
    let emb = timer.time("eigen", || crate::bipartite::ncut_embedding(&aff, k))?;
    let embf = emb.to_f32();
    let km = timer.time("discretize", || {
        kmeans(&embf, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{concentric_circles, two_moons};
    use crate::metrics::nmi;

    #[test]
    fn solves_moons() {
        let ds = two_moons(400, 0.05, 1);
        let out = sc(&ds.x, 2, 8, 7).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.9, "nmi={score}");
    }

    #[test]
    fn solves_rings() {
        let ds = concentric_circles(450, 2);
        let out = sc(&ds.x, 3, 8, 7).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.9, "nmi={score}");
    }

    #[test]
    fn affinity_symmetric_nonneg() {
        let ds = two_moons(120, 0.05, 3);
        let a = knn_gaussian_affinity(&ds.x, 5);
        for i in 0..120 {
            assert_eq!(a.at(i, i), 0.0);
            for j in 0..120 {
                assert!(a.at(i, j) >= 0.0);
                assert!((a.at(i, j) - a.at(j, i)).abs() < 1e-15);
            }
        }
    }

    #[test]
    fn rejects_bad_args() {
        let ds = two_moons(50, 0.05, 4);
        assert!(sc(&ds.x, 0, 5, 1).is_err());
        assert!(sc(&ds.x, 51, 5, 1).is_err());
    }
}
