//! **EulerSC** — Euler Spectral Clustering (Wu et al., TBD'18). The paper
//! proves EulerSC is equivalent to *weighted positive Euler k-means*: map
//! each feature through the Euler kernel e^{iαπx} (giving cos/sin pairs)
//! and run k-means in that 2d-dimensional complex embedding. O(Ndkt) time,
//! O(Nd) memory — scales to 20M objects but is locked to the Euler kernel
//! and sensitive to α (Table 4's CG/Flower rows).

use super::ClusteringOutput;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::Mat;
use crate::util::par;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Map data through the Euler kernel: per-dimension min-max normalization
/// to [0,1], then x ↦ (cos(απx), sin(απx)) / √d.
pub fn euler_embed(x: &Mat, alpha: f64) -> Mat {
    let n = x.rows;
    let d = x.cols;
    // per-dim min/max
    let mut mins = vec![f32::INFINITY; d];
    let mut maxs = vec![f32::NEG_INFINITY; d];
    for i in 0..n {
        for (j, &v) in x.row(i).iter().enumerate() {
            if v < mins[j] {
                mins[j] = v;
            }
            if v > maxs[j] {
                maxs[j] = v;
            }
        }
    }
    let scale = (1.0 / (d as f64).sqrt()) as f32;
    let apif = (alpha * std::f64::consts::PI) as f32;
    let mut out = Mat::zeros(n, 2 * d);
    par::par_for_chunks(&mut out.data, 2 * d, |start, chunk| {
        let i = start / (2 * d);
        let row = x.row(i);
        for j in 0..d {
            let range = (maxs[j] - mins[j]).max(1e-12);
            let t = (row[j] - mins[j]) / range;
            let theta = apif * t;
            chunk[2 * j] = theta.cos() * scale;
            chunk[2 * j + 1] = theta.sin() * scale;
        }
    });
    out
}

/// Run EulerSC ≡ positive Euler k-means. `alpha` is the Euler kernel
/// parameter (the original paper tunes it per dataset; 1.1 is its
/// recommended default for normalized features).
pub fn eulersc(x: &Mat, k: usize, alpha: f64, seed: u64) -> Result<ClusteringOutput> {
    ensure_arg!(k >= 1 && k <= x.rows, "eulersc: bad k");
    ensure_arg!(alpha > 0.0, "eulersc: alpha must be > 0");
    let mut timer = PhaseTimer::new();
    let emb = timer.time("euler_embed", || euler_embed(x, alpha));
    let km = timer.time("kmeans", || {
        kmeans(&emb, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{concentric_circles, two_moons};
    use crate::data::{real_surrogate, Benchmark};
    use crate::metrics::nmi;

    #[test]
    fn embed_geometry() {
        let ds = two_moons(100, 0.05, 1);
        let e = euler_embed(&ds.x, 1.1);
        assert_eq!(e.cols, 4);
        // rows have constant norm 1 (unit complex numbers scaled by 1/√d)
        for i in 0..100 {
            let norm: f32 = e.row(i).iter().map(|v| v * v).sum::<f32>();
            assert!((norm - 1.0).abs() < 1e-4, "norm {norm}");
        }
    }

    #[test]
    fn works_on_compact_classes() {
        let ds = real_surrogate::surrogate(Benchmark::PenDigits, 2000, 2);
        let out = eulersc(&ds.x, ds.k, 1.1, 5).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.4, "nmi={score}");
    }

    #[test]
    fn fails_on_rings_like_kmeans() {
        // The paper's Table 4: EulerSC scores 0.00 on CC-5M — the Euler
        // map cannot unfold concentric rings.
        let ds = concentric_circles(2000, 3);
        let out = eulersc(&ds.x, 3, 1.1, 5).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score < 0.4, "rings should stay unsolved, nmi={score}");
    }

    #[test]
    fn rejects_bad_params() {
        let ds = two_moons(30, 0.05, 4);
        assert!(eulersc(&ds.x, 0, 1.1, 1).is_err());
        assert!(eulersc(&ds.x, 2, 0.0, 1).is_err());
    }
}
