//! **LSC** — Landmark-based Spectral Clustering (Cai & Chen, TCYB'15).
//! Select p landmarks (k-means centers → LSC-K, uniform random → LSC-R),
//! compute the FULL dense N×p Gaussian affinity (this is the O(Npd) /
//! O(Np) bottleneck the paper's approximate KNR removes), keep the
//! K-nearest landmarks per object, then solve the same bipartite problem.
//! We reuse the transfer cut for the eigen step — mathematically equivalent
//! to LSC's SVD of the normalized Z, and strictly faster.

use super::ClusteringOutput;
use crate::affinity::{build_affinity, knr::exact_knr, select, NativeBackend, SelectStrategy};
use crate::bipartite::{transfer_cut, EigSolver};
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::Mat;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Landmark selection flavor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LscVariant {
    /// k-means landmark selection over the full dataset (O(Npdt)).
    K,
    /// uniform random landmarks.
    R,
}

/// Run LSC. `p` landmarks, `k_nn` nearest landmarks kept per object.
pub fn lsc(
    x: &Mat,
    k: usize,
    p: usize,
    k_nn: usize,
    variant: LscVariant,
    seed: u64,
) -> Result<ClusteringOutput> {
    let n = x.rows;
    ensure_arg!(k >= 1 && k <= n, "lsc: bad k");
    ensure_arg!(p >= k && p <= n, "lsc: need k <= p <= n");
    let mut timer = PhaseTimer::new();
    let strategy = match variant {
        LscVariant::K => SelectStrategy::KmeansFull,
        LscVariant::R => SelectStrategy::Random,
    };
    let landmarks = timer.time("select", || select(x, strategy, p, 10, seed))?;
    // Exact K-nearest landmarks: requires ALL N×p distances (the paper's
    // Table 2 "Affinity construction O(Npd)" row).
    let knr = timer.time("affinity", || exact_knr(x, &landmarks, k_nn.min(p), &NativeBackend));
    let aff = build_affinity(n, p, knr.k, &knr);
    let tc = timer.time("eigen", || transfer_cut(&aff.b, k, EigSolver::Auto, seed ^ 0x15C))?;
    let km = timer.time("discretize", || {
        kmeans(&tc.embedding, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed ^ 0xD15C)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::metrics::nmi;

    #[test]
    fn lsck_solves_moons() {
        let ds = two_moons(1200, 0.06, 1);
        let out = lsc(&ds.x, 2, 120, 5, LscVariant::K, 3).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.8, "nmi={score}");
    }

    #[test]
    fn lscr_runs_and_is_faster_to_select() {
        let ds = two_moons(1200, 0.06, 2);
        let out_r = lsc(&ds.x, 2, 120, 5, LscVariant::R, 3).unwrap();
        let out_k = lsc(&ds.x, 2, 120, 5, LscVariant::K, 3).unwrap();
        assert!(out_r.timer.get("select") <= out_k.timer.get("select"));
        assert_eq!(out_r.labels.len(), 1200);
    }

    #[test]
    fn lsc_matches_uspec_exact_mode_quality() {
        // U-SPEC with exact KNR and k-means selection ≈ LSC-K by design.
        let ds = two_moons(800, 0.05, 4);
        let lk = lsc(&ds.x, 2, 100, 5, LscVariant::K, 9).unwrap();
        let us = crate::uspec::uspec(
            &ds.x,
            &crate::uspec::UspecParams {
                k: 2,
                p: 100,
                knr: crate::uspec::KnrMode::Exact,
                selection: SelectStrategy::KmeansFull,
                ..Default::default()
            },
            9,
        )
        .unwrap();
        let d = (nmi(&lk.labels, &ds.y) - nmi(&us.labels, &ds.y)).abs();
        assert!(d < 0.25, "quality gap {d}");
    }

    #[test]
    fn rejects_bad_params() {
        let ds = two_moons(40, 0.05, 5);
        assert!(lsc(&ds.x, 0, 10, 3, LscVariant::R, 1).is_err());
        assert!(lsc(&ds.x, 2, 41, 3, LscVariant::R, 1).is_err());
    }
}
