//! **ESCG** — Efficient Spectral Clustering on Graphs (Liu et al.,
//! IJCAI'13), adapted to vector data through the same KNN affinity graph as
//! SC. ESCG picks s ≪ N seed vertices, computes single-source shortest
//! paths from each seed over the affinity graph (edge length = 1/weight),
//! forms supernodes by nearest-seed assignment, and partitions the
//! resulting object×supernode bipartite graph — here with the transfer
//! cut. Still requires the O(N²d) KNN graph, hence the same N/A pattern as
//! SC in Tables 4–6.

use super::sc::knn_gaussian_affinity;
use super::ClusteringOutput;
use crate::bipartite::{transfer_cut, EigSolver};
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::{Csr, Mat};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Multi-source Dijkstra over a dense affinity (length = 1/weight).
/// Returns for each node (nearest seed index, distance).
fn nearest_seed_dijkstra(aff: &crate::linalg::DMat, seeds: &[usize]) -> Vec<(u32, f64)> {
    let n = aff.rows;
    let mut best = vec![(u32::MAX, f64::INFINITY); n];
    // ordered-float via bit tricks in a min-heap of (dist, node, seed)
    let mut heap: BinaryHeap<Reverse<(u64, usize, u32)>> = BinaryHeap::new();
    let key = |d: f64| -> u64 { d.to_bits() }; // monotone for non-negative d
    for (si, &s) in seeds.iter().enumerate() {
        best[s] = (si as u32, 0.0);
        heap.push(Reverse((key(0.0), s, si as u32)));
    }
    while let Some(Reverse((dk, u, si))) = heap.pop() {
        let du = f64::from_bits(dk);
        if du > best[u].1 {
            continue;
        }
        for v in 0..n {
            let w = aff.at(u, v);
            if w <= 0.0 {
                continue;
            }
            let nd = du + 1.0 / w;
            if nd < best[v].1 {
                best[v] = (si, nd);
                heap.push(Reverse((key(nd), v, si)));
            }
        }
    }
    best
}

/// Run ESCG with `s` seeds (supernodes). `k_nn` controls the KNN graph.
pub fn escg(x: &Mat, k: usize, s: usize, k_nn: usize, seed: u64) -> Result<ClusteringOutput> {
    let n = x.rows;
    ensure_arg!(k >= 1 && k <= n, "escg: bad k");
    ensure_arg!(s >= k && s <= n, "escg: need k <= s <= n");
    let mut timer = PhaseTimer::new();
    let aff = timer.time("knn_graph", || knn_gaussian_affinity(x, k_nn.max(1)));
    let mut rng = Rng::new(seed);
    let seeds = rng.sample_indices(n, s);
    let mut assignment = timer.time("shortest_paths", || nearest_seed_dijkstra(&aff, &seeds));
    // KNN components without a seed are unreachable by the walk; attach
    // their nodes to the Euclidean-nearest seed so no node is isolated.
    let seed_mat = x.gather_rows(&seeds);
    for i in 0..n {
        if assignment[i].0 == u32::MAX {
            let xi = Mat { rows: 1, cols: x.cols, data: x.row(i).to_vec() };
            let d2 = xi.sq_dists(&seed_mat);
            let mut best = 0usize;
            for j in 1..s {
                if d2.at(0, j) < d2.at(0, best) {
                    best = j;
                }
            }
            assignment[i] = (best as u32, f64::INFINITY);
        }
    }
    // Bipartite cross-affinity R: r_ij = Σ_{l ∈ supernode j} w(i, l),
    // built sparsely from the dense KNN affinity.
    let b = timer.time("bipartite", || {
        let mut rows: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
        for i in 0..n {
            let mut acc: std::collections::HashMap<u32, f64> = std::collections::HashMap::new();
            // membership term keeps disconnected nodes attached
            let (own, _) = assignment[i];
            if own != u32::MAX {
                *acc.entry(own).or_insert(0.0) += 1e-6;
            }
            for j in 0..n {
                let w = aff.at(i, j);
                if w > 0.0 {
                    let (sj, _) = assignment[j];
                    if sj != u32::MAX {
                        *acc.entry(sj).or_insert(0.0) += w;
                    }
                }
            }
            rows[i] = acc.into_iter().collect();
            rows[i].sort_by_key(|&(c, _)| c);
        }
        Csr::from_rows(n, s, &rows)
    });
    let tc = timer.time("eigen", || transfer_cut(&b, k, EigSolver::Auto, seed ^ 0xE5C))?;
    let km = timer.time("discretize", || {
        kmeans(&tc.embedding, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed ^ 0x9)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::metrics::nmi;

    #[test]
    fn solves_moons() {
        let ds = two_moons(500, 0.05, 1);
        let out = escg(&ds.x, 2, 50, 8, 3).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.75, "nmi={score}");
    }

    #[test]
    fn dijkstra_sane() {
        // 4-node path graph: 0-1-2-3, seeds {0, 3}
        let mut aff = crate::linalg::DMat::zeros(4, 4);
        for (i, j) in [(0, 1), (1, 2), (2, 3)] {
            aff.set(i, j, 1.0);
            aff.set(j, i, 1.0);
        }
        let best = nearest_seed_dijkstra(&aff, &[0, 3]);
        assert_eq!(best[0].0, 0);
        assert_eq!(best[1].0, 0);
        assert_eq!(best[2].0, 1);
        assert_eq!(best[3].0, 1);
        assert_eq!(best[1].1, 1.0);
    }

    #[test]
    fn rejects_bad_params() {
        let ds = two_moons(40, 0.05, 2);
        assert!(escg(&ds.x, 0, 10, 5, 1).is_err());
        assert!(escg(&ds.x, 5, 3, 5, 1).is_err());
        assert!(escg(&ds.x, 2, 41, 5, 1).is_err());
    }
}
