//! **Nyström** spectral clustering (Fowlkes et al. / Chen et al. TPAMI'11):
//! sample p representatives, build the dense N×p Gaussian cross-affinity C,
//! approximate the degree with d̂ = C·(W⁻¹·(Cᵀ·1)), normalize, and extract
//! the top-k eigenvectors via the one-shot orthogonalized Nyström
//! extension. O(Npd) time, O(Np) memory.

use super::ClusteringOutput;
use crate::bipartite::top_eig;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::{DMat, Mat};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Error, Result};

/// Dense Gaussian cross-affinity between all rows of `x` and `reps`,
/// with σ set to the mean pairwise distance of a sample (a standard
/// self-tuning choice matching the paper's Eq. 6 convention).
pub fn gaussian_cross_affinity(x: &Mat, reps: &Mat, sigma: f64) -> DMat {
    let d2 = x.sq_dists(reps);
    let denom = 2.0 * sigma * sigma;
    let mut out = DMat::zeros(x.rows, reps.rows);
    for (o, &v) in out.data.iter_mut().zip(d2.data.iter()) {
        *o = (-(v as f64) / denom).exp();
    }
    out
}

/// Estimate σ as the mean object↔representative distance over a sample.
pub fn estimate_sigma(x: &Mat, reps: &Mat, sample: usize, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let idx = rng.sample_indices(x.rows, sample.min(x.rows));
    let xs = x.gather_rows(&idx);
    let d2 = xs.sq_dists(reps);
    let mean: f64 = d2.data.iter().map(|&v| (v.max(0.0) as f64).sqrt()).sum::<f64>()
        / d2.data.len() as f64;
    mean.max(1e-12)
}

/// Moore–Penrose pseudo-inverse square root of a symmetric PSD matrix.
fn pinv_sqrt(a: &DMat, rcond: f64) -> Result<DMat> {
    let (vals, vecs) = crate::linalg::eigen::sym_eig(a)?;
    let n = a.rows;
    let vmax = vals.iter().cloned().fold(0.0f64, f64::max);
    let mut out = DMat::zeros(n, n);
    for c in 0..n {
        let lam = vals[c];
        if lam > rcond * vmax && lam > 0.0 {
            let s = 1.0 / lam.sqrt();
            for i in 0..n {
                for j in 0..n {
                    let v = out.at(i, j) + vecs.at(i, c) * s * vecs.at(j, c);
                    out.set(i, j, v);
                }
            }
        }
    }
    Ok(out)
}

/// Run Nyström spectral clustering with `p` random representatives.
pub fn nystrom(x: &Mat, k: usize, p: usize, seed: u64) -> Result<ClusteringOutput> {
    let n = x.rows;
    ensure_arg!(k >= 1 && k <= n, "nystrom: bad k");
    ensure_arg!(p >= k && p <= n, "nystrom: need k <= p <= n");
    let mut timer = PhaseTimer::new();
    let mut rng = Rng::new(seed);

    // representatives: uniform random sample
    let rep_idx = rng.sample_indices(n, p);
    let reps = x.gather_rows(&rep_idx);
    let sigma = estimate_sigma(x, &reps, 2000, rng.next_u64());

    // C: N×p cross affinity; W: p×p block among representatives
    let c = timer.time("affinity", || gaussian_cross_affinity(x, &reps, sigma));
    let mut w = DMat::zeros(p, p);
    for (a, &i) in rep_idx.iter().enumerate() {
        for b in 0..p {
            w.set(a, b, c.at(i, b));
        }
    }
    // symmetrize W (it is up to numerical noise)
    for i in 0..p {
        for j in 0..i {
            let v = 0.5 * (w.at(i, j) + w.at(j, i));
            w.set(i, j, v);
            w.set(j, i, v);
        }
    }

    let emb = timer.time("eigen", || -> Result<DMat> {
        // degree estimate: d̂ = C W⁻¹ Cᵀ 1  (Chen et al. §2.2)
        let ones = DMat::from_vec(n, 1, vec![1.0; n]);
        let ct1 = c.transpose().matmul(&ones); // p×1
        let w_pinv_sqrt = pinv_sqrt(&w, 1e-10)?;
        let w_pinv = w_pinv_sqrt.matmul(&w_pinv_sqrt);
        let dhat = c.matmul(&w_pinv.matmul(&ct1)); // n×1
        for (i, v) in dhat.data.iter().enumerate() {
            if *v <= 0.0 {
                return Err(Error::Numerical(format!("nystrom: nonpositive degree at {i}")));
            }
        }
        // normalize: C̄ = D^{-1/2} C
        let mut cbar = c.clone();
        for i in 0..n {
            let s = 1.0 / dhat.at(i, 0).sqrt();
            for j in 0..p {
                cbar.set(i, j, cbar.at(i, j) * s);
            }
        }
        // one-shot orthogonalization: S = W̄^{-1/2} (C̄ᵀC̄) W̄^{-1/2} — use the
        // unnormalized W's pinv-sqrt scaled consistently. Following the
        // standard recipe: S = W^{-1/2} Cᵀ C W^{-1/2} over normalized C.
        let g = cbar.gram(); // p×p = C̄ᵀ C̄
        let s = w_pinv_sqrt.matmul(&g).matmul(&w_pinv_sqrt);
        // symmetrize
        let mut ss = s.clone();
        for i in 0..p {
            for j in 0..p {
                ss.set(i, j, 0.5 * (s.at(i, j) + s.at(j, i)));
            }
        }
        let (vals, u) = top_eig(&ss, k)?;
        // V = C̄ W^{-1/2} U Λ^{-1/2}
        let mut ul = u.clone();
        for cidx in 0..k {
            let lam = vals[cidx].max(1e-12);
            let sc = 1.0 / lam.sqrt();
            for r in 0..p {
                ul.set(r, cidx, ul.at(r, cidx) * sc);
            }
        }
        let v = cbar.matmul(&w_pinv_sqrt.matmul(&ul)); // n×k
        // row-normalize (Ng–Jordan–Weiss style discretization)
        let mut vn = v.clone();
        for i in 0..n {
            let norm: f64 = (0..k).map(|j| v.at(i, j) * v.at(i, j)).sum::<f64>().sqrt();
            if norm > 1e-12 {
                for j in 0..k {
                    vn.set(i, j, v.at(i, j) / norm);
                }
            }
        }
        Ok(vn)
    })?;

    let embf = emb.to_f32();
    let km = timer.time("discretize", || {
        kmeans(&embf, &KmeansParams { k, max_iter: 100, ..Default::default() }, rng.next_u64())
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::data::{real_surrogate, Benchmark};
    use crate::metrics::nmi;

    #[test]
    fn clusters_blob_like_data_well() {
        // Nyström with Gaussian kernel handles compact classes.
        let ds = real_surrogate::surrogate(Benchmark::PenDigits, 2000, 3);
        let out = nystrom(&ds.x, ds.k, 150, 7).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.55, "nmi={score}");
    }

    #[test]
    fn struggles_on_moons_vs_uspec() {
        // With few random reps and one-shot approximation, Nyström is
        // noticeably weaker than U-SPEC on nonlinear shapes (Table 4 TB row).
        let ds = two_moons(1500, 0.07, 5);
        let ny = nystrom(&ds.x, 2, 60, 3).unwrap();
        let us = crate::uspec::uspec(
            &ds.x,
            &crate::uspec::UspecParams { k: 2, p: 150, ..Default::default() },
            3,
        )
        .unwrap();
        let ny_nmi = nmi(&ny.labels, &ds.y);
        let us_nmi = nmi(&us.labels, &ds.y);
        assert!(us_nmi > ny_nmi - 0.05, "uspec {us_nmi} vs nystrom {ny_nmi}");
    }

    #[test]
    fn pinv_sqrt_identity() {
        let a = DMat::eye(5);
        let s = pinv_sqrt(&a, 1e-12).unwrap();
        assert!(s.frob_dist(&DMat::eye(5)) < 1e-10);
    }

    #[test]
    fn rejects_bad_params() {
        let ds = two_moons(50, 0.05, 6);
        assert!(nystrom(&ds.x, 0, 10, 1).is_err());
        assert!(nystrom(&ds.x, 2, 60, 1).is_err());
        assert!(nystrom(&ds.x, 5, 3, 1).is_err());
    }
}
