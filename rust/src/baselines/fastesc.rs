//! **FastESC** — Fast Explicit Spectral Clustering (He et al., TCYB'18):
//! represent objects by p random Fourier features of the Gaussian kernel,
//! z(x) = √(2/p)·cos(Wᵀx + b) with W ~ N(0, σ⁻²) and b ~ U[0, 2π], then
//! perform the eigen-decomposition explicitly on the p×p feature Gram
//! matrix. O(Npd + p³) time, O(Np) memory.

use super::ClusteringOutput;
use crate::bipartite::top_eig;
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::{DMat, Mat};
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

/// Random Fourier feature map of the Gaussian kernel with bandwidth σ.
pub fn fourier_features(x: &Mat, p: usize, sigma: f64, seed: u64) -> Mat {
    let d = x.cols;
    let mut rng = Rng::new(seed);
    // W: d×p frequencies, b: p phases
    let w: Vec<f32> = (0..d * p).map(|_| (rng.normal() / sigma) as f32).collect();
    let b: Vec<f32> = (0..p).map(|_| (rng.f64() * std::f64::consts::TAU) as f32).collect();
    let wmat = Mat::from_vec(p, d, {
        // transpose into p×d rows for matmul_nt
        let mut t = vec![0f32; p * d];
        for i in 0..d {
            for j in 0..p {
                t[j * d + i] = w[i * p + j];
            }
        }
        t
    });
    let mut proj = x.matmul_nt(&wmat); // n×p = X Wᵀ
    let scale = (2.0f32 / p as f32).sqrt();
    crate::util::par::par_for_chunks(&mut proj.data, p, |start, chunk| {
        let _i = start / p;
        for (j, v) in chunk.iter_mut().enumerate() {
            *v = scale * (*v + b[j]).cos();
        }
    });
    proj
}

/// Estimate σ from mean pairwise distance of a subsample.
fn estimate_sigma(x: &Mat, seed: u64) -> f64 {
    let mut rng = Rng::new(seed);
    let s = 500.min(x.rows);
    let idx = rng.sample_indices(x.rows, s);
    let xs = x.gather_rows(&idx);
    let d2 = xs.sq_dists(&xs);
    let mut sum = 0.0f64;
    let mut cnt = 0u64;
    for i in 0..s {
        for j in 0..i {
            sum += (d2.at(i, j).max(0.0) as f64).sqrt();
            cnt += 1;
        }
    }
    (sum / cnt.max(1) as f64).max(1e-9)
}

/// Run FastESC with `p` Fourier features.
pub fn fastesc(x: &Mat, k: usize, p: usize, seed: u64) -> Result<ClusteringOutput> {
    let n = x.rows;
    ensure_arg!(k >= 1 && k <= n, "fastesc: bad k");
    ensure_arg!(p >= k, "fastesc: p={p} < k={k}");
    let mut timer = PhaseTimer::new();
    let sigma = estimate_sigma(x, seed ^ 0x51);
    let phi = timer.time("features", || fourier_features(x, p, sigma, seed));
    let emb = timer.time("eigen", || -> Result<Mat> {
        // degrees of the implicit affinity K ≈ Φ Φᵀ: deg = Φ (Φᵀ 1)
        let phid = phi.to_f64();
        let ones = DMat::from_vec(n, 1, vec![1.0; n]);
        let pt1 = phid.transpose().matmul(&ones); // p×1
        let deg = phid.matmul(&pt1); // n×1
        let mut phin = phid.clone();
        for i in 0..n {
            let dv = deg.at(i, 0);
            let s = if dv > 1e-12 { 1.0 / dv.sqrt() } else { 0.0 };
            for j in 0..p {
                phin.set(i, j, phin.at(i, j) * s);
            }
        }
        // top-k eigenvectors of Φ̄ Φ̄ᵀ via the p×p Gram
        let g = phin.gram();
        let (vals, u) = top_eig(&g, k)?;
        let mut ul = u.clone();
        for c in 0..k {
            let lam = vals[c].max(1e-12);
            let s = 1.0 / lam.sqrt();
            for r in 0..p {
                ul.set(r, c, ul.at(r, c) * s);
            }
        }
        let v = phin.matmul(&ul); // n×k left singular vectors
        Ok(v.to_f32())
    })?;
    let km = timer.time("discretize", || {
        kmeans(&emb, &KmeansParams { k, max_iter: 100, ..Default::default() }, seed ^ 0xFE5C)
    })?;
    Ok(ClusteringOutput::new(km.labels, timer))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{real_surrogate, Benchmark};
    use crate::metrics::nmi;

    #[test]
    fn feature_map_bounded() {
        let ds = crate::data::synthetic::two_moons(200, 0.05, 1);
        let phi = fourier_features(&ds.x, 64, 1.0, 2);
        assert_eq!(phi.rows, 200);
        assert_eq!(phi.cols, 64);
        let bound = (2.0f32 / 64.0).sqrt() + 1e-6;
        for &v in &phi.data {
            assert!(v.abs() <= bound, "{v} out of bound {bound}");
        }
    }

    #[test]
    fn kernel_approximation_quality() {
        // z(x)ᵀz(y) should approximate exp(-‖x-y‖²/2σ²)
        let ds = crate::data::synthetic::two_moons(50, 0.05, 3);
        let sigma = 0.7;
        let phi = fourier_features(&ds.x, 4096, sigma, 4);
        let d2 = ds.x.sq_dists(&ds.x);
        let mut max_err = 0.0f64;
        for i in 0..20 {
            for j in 0..20 {
                let approx: f64 = (0..4096).map(|t| (phi.at(i, t) * phi.at(j, t)) as f64).sum();
                let exact = (-(d2.at(i, j) as f64) / (2.0 * sigma * sigma)).exp();
                max_err = max_err.max((approx - exact).abs());
            }
        }
        assert!(max_err < 0.1, "max kernel err {max_err}");
    }

    #[test]
    fn clusters_gaussian_surrogate() {
        let ds = real_surrogate::surrogate(Benchmark::PenDigits, 2000, 5);
        let out = fastesc(&ds.x, ds.k, 200, 7).unwrap();
        let score = nmi(&out.labels, &ds.y);
        assert!(score > 0.45, "nmi={score}");
    }

    #[test]
    fn rejects_bad_params() {
        let ds = crate::data::synthetic::two_moons(30, 0.05, 6);
        assert!(fastesc(&ds.x, 0, 10, 1).is_err());
        assert!(fastesc(&ds.x, 5, 3, 1).is_err());
    }
}
