//! The seven baseline spectral-clustering methods of the paper's §4.2
//! (Tables 4–6): SC, ESCG, Nyström, LSC-K, LSC-R, FastESC, EulerSC —
//! implemented from their original papers on top of this crate's
//! substrates. Each reports per-phase timing and exposes a peak-memory
//! model used by the bench harness to reproduce the paper's N/A
//! (out-of-memory) pattern at paper-scale sizes.

pub mod sc;
pub mod escg;
pub mod nystrom;
pub mod lsc;
pub mod fastesc;
pub mod eulersc;

use crate::util::timer::PhaseTimer;

/// Uniform output shape for every clustering method in the evaluation.
#[derive(Debug, Clone)]
pub struct ClusteringOutput {
    pub labels: Vec<u32>,
    pub timer: PhaseTimer,
}

impl ClusteringOutput {
    pub fn new(labels: Vec<u32>, timer: PhaseTimer) -> Self {
        ClusteringOutput { labels, timer }
    }
}

/// Identifier for every method in Tables 4–6 (spectral track).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SpectralMethod {
    Kmeans,
    Sc,
    Escg,
    Nystrom,
    LscK,
    LscR,
    FastEsc,
    EulerSc,
    Uspec,
    Usenc,
}

impl SpectralMethod {
    pub const ALL: [SpectralMethod; 10] = [
        SpectralMethod::Kmeans,
        SpectralMethod::Sc,
        SpectralMethod::Escg,
        SpectralMethod::Nystrom,
        SpectralMethod::LscK,
        SpectralMethod::LscR,
        SpectralMethod::FastEsc,
        SpectralMethod::EulerSc,
        SpectralMethod::Uspec,
        SpectralMethod::Usenc,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            SpectralMethod::Kmeans => "k-means",
            SpectralMethod::Sc => "SC",
            SpectralMethod::Escg => "ESCG",
            SpectralMethod::Nystrom => "Nystrom",
            SpectralMethod::LscK => "LSC-K",
            SpectralMethod::LscR => "LSC-R",
            SpectralMethod::FastEsc => "FastESC",
            SpectralMethod::EulerSc => "EulerSC",
            SpectralMethod::Uspec => "U-SPEC",
            SpectralMethod::Usenc => "U-SENC",
        }
    }

    pub fn from_name(s: &str) -> Option<SpectralMethod> {
        SpectralMethod::ALL.iter().copied().find(|m| m.name().eq_ignore_ascii_case(s))
    }

    /// Peak-memory model in bytes at problem size (n, d) with the shared
    /// parameters (p representatives/landmarks, k clusters, m ensemble
    /// size). Mirrors each method's dominant allocations with a ×2
    /// working-set factor for the eigen/manipulation phase — calibrated so
    /// the 64 GB budget reproduces the paper's N/A pattern exactly
    /// (see tests below).
    pub fn peak_memory_bytes(&self, n: u64, d: u64, p: u64, k: u64, m: u64) -> u64 {
        let f = 8u64; // f64 entries, as in the MATLAB reference
        match self {
            SpectralMethod::Kmeans => f * n * (d + k),
            SpectralMethod::EulerSc => f * n * (2 * d + k),
            // full N×N affinity (MATLAB stores one dense copy; the sparse
            // eigensolver works in-place)
            SpectralMethod::Sc | SpectralMethod::Escg => f * n * n + f * n * d,
            // dense N×p sub-matrix + manipulation copies
            SpectralMethod::Nystrom
            | SpectralMethod::LscK
            | SpectralMethod::LscR
            | SpectralMethod::FastEsc => 2 * f * n * p + f * n * d,
            // sparse: N×√p batch buffers + NK affinity
            SpectralMethod::Uspec => {
                let sp = (p as f64).sqrt().ceil() as u64;
                f * n * sp + f * n * d
            }
            SpectralMethod::Usenc => {
                let sp = (p as f64).sqrt().ceil() as u64;
                f * n * sp + f * n * d + f * n * m
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_model_reproduces_paper_na_pattern() {
        // 64 GB budget, paper parameters p=1000, m=20.
        let budget = 64u64 * (1 << 30);
        let fits =
            |m: SpectralMethod, n: u64, d: u64| m.peak_memory_bytes(n, d, 1000, 10, 20) <= budget;
        // SC handles MNIST (70k) but not Covertype (581k) — Table 4.
        assert!(fits(SpectralMethod::Sc, 70_000, 784));
        assert!(!fits(SpectralMethod::Sc, 581_012, 54));
        // Nyström/LSC handle SF-2M but not CC-5M.
        assert!(fits(SpectralMethod::Nystrom, 2_000_000, 2));
        assert!(!fits(SpectralMethod::Nystrom, 5_000_000, 2));
        assert!(fits(SpectralMethod::LscK, 2_000_000, 2));
        assert!(!fits(SpectralMethod::LscR, 5_000_000, 2));
        // U-SPEC / U-SENC / EulerSC / k-means handle Flower-20M.
        for m in [
            SpectralMethod::Uspec,
            SpectralMethod::Usenc,
            SpectralMethod::EulerSc,
            SpectralMethod::Kmeans,
        ] {
            assert!(fits(m, 20_000_000, 2), "{} should fit 20M", m.name());
        }
    }
}
