//! k-means (Lloyd) with k-means++ / random initialization, empty-cluster
//! repair, and a mini-batch variant. Used throughout the paper's pipeline:
//! hybrid representative selection (§3.1.1), rep-cluster construction
//! (§3.1.2 pre-step 1), eigenvector discretization (§3.1.3), and as the
//! base clusterer of every ensemble baseline (§4.4).

use crate::linalg::{nearest_packed_into, DistScratch, Mat};
pub mod hamerly;

pub use hamerly::kmeans_hamerly;

use crate::util::rng::Rng;
use crate::{ensure_arg, Result};

/// Initialization strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Init {
    /// Sample k distinct points uniformly.
    Random,
    /// k-means++ (D² weighting).
    PlusPlus,
}

/// Parameters for [`kmeans`].
#[derive(Debug, Clone)]
pub struct KmeansParams {
    pub k: usize,
    pub max_iter: usize,
    /// Relative inertia improvement below which we stop.
    pub tol: f64,
    pub init: Init,
}

impl Default for KmeansParams {
    fn default() -> Self {
        KmeansParams { k: 8, max_iter: 100, tol: 1e-4, init: Init::PlusPlus }
    }
}

/// Result of a k-means run.
#[derive(Debug, Clone)]
pub struct KmeansResult {
    pub labels: Vec<u32>,
    pub centers: Mat,
    pub inertia: f64,
    pub iterations: usize,
}

/// Assign every row of `x` to its nearest row of `centers`.
/// Returns (labels, squared distance to the winner). Runs on the fused
/// packed argmin kernel — the N×k distance block is never materialized.
pub fn assign(x: &Mat, centers: &Mat) -> (Vec<u32>, Vec<f32>) {
    let packed = centers.pack_rhs();
    crate::linalg::nearest_packed(x, &packed)
}

/// Fused assignment against an already-packed center panel, for callers
/// that assign several batches against the same centers (the Lloyd loop
/// packs once per iteration, [`assign_batched`] once per call). Exact
/// same results as [`assign`] (identical accumulation order and
/// lowest-index tie-breaking).
pub fn assign_packed(x: &Mat, packed: &crate::linalg::PackedMat) -> (Vec<u32>, Vec<f32>) {
    crate::linalg::nearest_packed(x, packed)
}

/// Historical alias for the fused path ([`assign`] now fuses too); kept
/// because perf notes and older callers reference it by name.
pub fn assign_fused(x: &Mat, centers: &Mat) -> (Vec<u32>, Vec<f32>) {
    assign(x, centers)
}

/// Batched assignment that avoids materializing the full N×k distance
/// matrix: processes `batch` rows at a time. This is the shape the AOT
/// kernel path mirrors. Scratch buffers (row norms, per-thread winners,
/// the batch view itself) are reused across batches via
/// [`nearest_packed_into`].
pub fn assign_batched(x: &Mat, centers: &Mat, batch: usize) -> (Vec<u32>, Vec<f32>) {
    let n = x.rows;
    let packed = centers.pack_rhs(); // one packing shared by every batch
    let mut labels = vec![0u32; n];
    let mut dists = vec![0f32; n];
    let mut scratch = DistScratch::default();
    let (mut lb, mut db) = (Vec::new(), Vec::new());
    let mut xb = Mat::zeros(0, x.cols);
    let mut start = 0;
    while start < n {
        let end = (start + batch).min(n);
        xb.rows = end - start;
        xb.data.clear();
        xb.data.extend_from_slice(&x.data[start * x.cols..end * x.cols]);
        nearest_packed_into(&xb, &packed, &mut scratch, &mut lb, &mut db);
        labels[start..end].copy_from_slice(&lb);
        dists[start..end].copy_from_slice(&db);
        start = end;
    }
    (labels, dists)
}

/// k-means++ seeding.
pub fn init_plusplus(x: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let n = x.rows;
    let mut centers = Mat::zeros(k, x.cols);
    let first = rng.usize(n);
    centers.row_mut(0).copy_from_slice(x.row(first));
    let mut mind2: Vec<f64> = {
        let c0 = Mat { rows: 1, cols: x.cols, data: centers.row(0).to_vec() };
        x.sq_dists(&c0).data.iter().map(|&v| v as f64).collect()
    };
    for c in 1..k {
        let total: f64 = mind2.iter().sum();
        let idx = if total <= 0.0 {
            rng.usize(n)
        } else {
            let mut t = rng.f64() * total;
            let mut pick = n - 1;
            for (i, &w) in mind2.iter().enumerate() {
                t -= w;
                if t <= 0.0 {
                    pick = i;
                    break;
                }
            }
            pick
        };
        centers.row_mut(c).copy_from_slice(x.row(idx));
        // Inline scalar update of the running min — a per-center sq_dists
        // call costs more in Mat allocation + thread dispatch than the
        // O(n·d) arithmetic itself (§Perf L3 iteration 2: 112 ms → ~15 ms
        // for n=10⁴, k=10³, d=2).
        let cr = x.row(idx).to_vec();
        let d = x.cols;
        for (i, m) in mind2.iter_mut().enumerate() {
            let row = x.row(i);
            let mut s = 0.0f32;
            for t in 0..d {
                let diff = row[t] - cr[t];
                s += diff * diff;
            }
            let v = s.max(0.0) as f64;
            if v < *m {
                *m = v;
            }
        }
    }
    centers
}

/// Random distinct-point seeding.
pub fn init_random(x: &Mat, k: usize, rng: &mut Rng) -> Mat {
    let idx = rng.sample_indices(x.rows, k);
    x.gather_rows(&idx)
}

/// Lloyd's algorithm. `x` is n×d; requires `k ≤ n`.
pub fn kmeans(x: &Mat, params: &KmeansParams, seed: u64) -> Result<KmeansResult> {
    let n = x.rows;
    let d = x.cols;
    let k = params.k;
    ensure_arg!(k >= 1, "kmeans: k must be >= 1");
    ensure_arg!(k <= n, "kmeans: k={k} > n={n}");
    let mut rng = Rng::new(seed);
    let mut centers = match params.init {
        Init::Random => init_random(x, k, &mut rng),
        Init::PlusPlus => init_plusplus(x, k, &mut rng),
    };
    let mut labels = vec![0u32; n];
    // Assignment buffers persist across Lloyd iterations: the row-norm /
    // winner scratch and the label/distance outputs are allocated once
    // and refilled by `nearest_packed_into` every round.
    let mut scratch = DistScratch::default();
    let mut dists: Vec<f32> = Vec::new();
    let mut inertia = f64::INFINITY;
    let mut iterations = 0;
    for it in 0..params.max_iter {
        iterations = it + 1;
        nearest_packed_into(x, &centers.pack_rhs(), &mut scratch, &mut labels, &mut dists);
        let new_inertia: f64 = dists.iter().map(|&v| v as f64).sum();
        // Update step: mean of members; repair empties with farthest points.
        let mut counts = vec![0u64; k];
        let mut sums = vec![0f64; k * d];
        for i in 0..n {
            let c = labels[i] as usize;
            counts[c] += 1;
            let row = x.row(i);
            let s = &mut sums[c * d..(c + 1) * d];
            for (sv, &xv) in s.iter_mut().zip(row) {
                *sv += xv as f64;
            }
        }
        // Empty-cluster repair: seize the point farthest from its center.
        let empties: Vec<usize> = (0..k).filter(|&c| counts[c] == 0).collect();
        if !empties.is_empty() {
            let mut order = crate::util::argsort_by_f64(
                &dists.iter().map(|&v| -(v as f64)).collect::<Vec<_>>(),
            );
            order.truncate(empties.len());
            for (&c, &i) in empties.iter().zip(order.iter()) {
                let old = labels[i] as usize;
                if counts[old] > 1 {
                    counts[old] -= 1;
                    let row = x.row(i);
                    let s = &mut sums[old * d..(old + 1) * d];
                    for (sv, &xv) in s.iter_mut().zip(row) {
                        *sv -= xv as f64;
                    }
                }
                labels[i] = c as u32;
                counts[c] = 1;
                let s = &mut sums[c * d..(c + 1) * d];
                for (sv, &xv) in s.iter_mut().zip(x.row(i)) {
                    *sv = xv as f64;
                }
            }
        }
        for c in 0..k {
            if counts[c] > 0 {
                let inv = 1.0 / counts[c] as f64;
                let s = &sums[c * d..(c + 1) * d];
                let cr = centers.row_mut(c);
                for (cv, &sv) in cr.iter_mut().zip(s) {
                    *cv = (sv * inv) as f32;
                }
            }
        }
        if inertia.is_finite() && (inertia - new_inertia) <= params.tol * inertia.abs().max(1e-12) {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    Ok(KmeansResult { labels, centers, inertia, iterations })
}

/// Mini-batch k-means (Sculley 2010) — used when the caller wants a quick
/// approximate partition of very large data (KCC/SEC-style base clusterers
/// at full paper scale).
pub fn minibatch_kmeans(
    x: &Mat,
    k: usize,
    batch: usize,
    iters: usize,
    seed: u64,
) -> Result<KmeansResult> {
    let n = x.rows;
    ensure_arg!(k >= 1 && k <= n, "minibatch_kmeans: bad k");
    let mut rng = Rng::new(seed);
    let mut centers = init_plusplus(x, k, &mut rng);
    let mut counts = vec![1u64; k];
    for _ in 0..iters {
        let idx = rng.sample_indices(n, batch.min(n));
        let xb = x.gather_rows(&idx);
        let (lb, _) = assign(&xb, &centers);
        for (bi, &l) in lb.iter().enumerate() {
            let c = l as usize;
            counts[c] += 1;
            let eta = 1.0 / counts[c] as f32;
            let row = xb.row(bi);
            let cr = centers.row_mut(c);
            for (cv, &xv) in cr.iter_mut().zip(row) {
                *cv += eta * (xv - *cv);
            }
        }
    }
    let (labels, dists) = assign(x, &centers);
    let inertia = dists.iter().map(|&v| v as f64).sum();
    Ok(KmeansResult { labels, centers, inertia, iterations: iters })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Three well-separated blobs.
    fn blobs(n_per: usize, seed: u64) -> (Mat, Vec<u32>) {
        let mut rng = Rng::new(seed);
        let centers = [[0.0f32, 0.0], [10.0, 0.0], [0.0, 10.0]];
        let n = n_per * 3;
        let mut m = Mat::zeros(n, 2);
        let mut y = vec![0u32; n];
        for c in 0..3 {
            for i in 0..n_per {
                let r = c * n_per + i;
                m.set(r, 0, centers[c][0] + rng.normal() as f32 * 0.5);
                m.set(r, 1, centers[c][1] + rng.normal() as f32 * 0.5);
                y[r] = c as u32;
            }
        }
        (m, y)
    }

    #[test]
    fn recovers_blobs() {
        let (x, y) = blobs(100, 31);
        let res = kmeans(&x, &KmeansParams { k: 3, ..Default::default() }, 7).unwrap();
        // Perfect recovery up to permutation: NMI = 1.
        let nmi = crate::metrics::nmi(&res.labels, &y);
        assert!(nmi > 0.99, "nmi={nmi}");
        assert!(res.inertia > 0.0);
    }

    #[test]
    fn labels_in_range_and_nonempty() {
        let (x, _) = blobs(50, 32);
        for init in [Init::Random, Init::PlusPlus] {
            let res = kmeans(&x, &KmeansParams { k: 7, init, ..Default::default() }, 3).unwrap();
            let mut seen = vec![false; 7];
            for &l in &res.labels {
                assert!((l as usize) < 7);
                seen[l as usize] = true;
            }
            assert!(seen.iter().all(|&s| s), "empty cluster with {init:?}");
        }
    }

    #[test]
    fn k_equals_n() {
        let (x, _) = blobs(2, 33); // n=6
        let res = kmeans(&x, &KmeansParams { k: 6, ..Default::default() }, 1).unwrap();
        let uniq: std::collections::HashSet<_> = res.labels.iter().collect();
        assert_eq!(uniq.len(), 6);
        assert!(res.inertia < 1e-6);
    }

    #[test]
    fn rejects_bad_k() {
        let (x, _) = blobs(2, 34);
        assert!(kmeans(&x, &KmeansParams { k: 0, ..Default::default() }, 1).is_err());
        assert!(kmeans(&x, &KmeansParams { k: 100, ..Default::default() }, 1).is_err());
    }

    #[test]
    fn batched_assign_matches() {
        let (x, _) = blobs(40, 35);
        let res = kmeans(&x, &KmeansParams { k: 3, ..Default::default() }, 5).unwrap();
        let (l1, d1) = assign(&x, &res.centers);
        let (l2, d2) = assign_batched(&x, &res.centers, 17);
        assert_eq!(l1, l2);
        for (a, b) in d1.iter().zip(&d2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn minibatch_reasonable() {
        let (x, y) = blobs(200, 36);
        let res = minibatch_kmeans(&x, 3, 64, 50, 9).unwrap();
        let nmi = crate::metrics::nmi(&res.labels, &y);
        assert!(nmi > 0.9, "nmi={nmi}");
    }

    #[test]
    fn inertia_nonincreasing_over_iters() {
        let (x, _) = blobs(100, 37);
        // run with increasing max_iter; final inertia must not increase
        let mut prev = f64::INFINITY;
        for mi in [1usize, 2, 5, 20] {
            let res = kmeans(
                &x,
                &KmeansParams { k: 5, max_iter: mi, tol: 0.0, init: Init::Random },
                11,
            )
            .unwrap();
            assert!(res.inertia <= prev + 1e-6, "inertia rose: {} -> {}", prev, res.inertia);
            prev = res.inertia;
        }
    }
}
