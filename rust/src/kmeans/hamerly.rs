//! Hamerly-accelerated Lloyd iterations (Hamerly, SDM'10) — an *exact*
//! k-means accelerator: identical fixed point and (with matching
//! initialization, tie-breaking, and empty-cluster repair) identical
//! per-iteration assignments to plain Lloyd, while skipping most
//! point↔center distance evaluations via two triangle-inequality bounds:
//!
//! * `u[i]` — upper bound on d(xᵢ, c_{a(i)}) (assigned center),
//! * `l[i]` — lower bound on d(xᵢ, c′) for every other center c′,
//! * `s[c]` — half the distance from c to its nearest other center.
//!
//! A point can only change owner if `u[i] > max(s[a(i)], l[i])`; after one
//! exact tightening of `u[i]` most points still skip the full k-scan.
//!
//! Measured trade-off (§Perf round 3, evaluated candidate): at the
//! selection shape (n=10⁴, k=10³) Hamerly is 1.4–1.8× faster than the
//! fused-gemm Lloyd for d ≤ ~4 (clustered data prunes best), but *slower*
//! at d ≥ 16 — the pruned scalar distance loops lose to `assign_fused`'s
//! vectorized blocked gemm. It is therefore provided as an exact
//! alternative rather than the default.
//!
//! Tie-breaking caveat: when a point is exactly equidistant (in f32) to
//! its current owner and an earlier center, Hamerly keeps the owner while
//! Lloyd picks the lower index — so labelings can differ on ties (same
//! inertia). The equality property test uses tie-free shapes.
//!
//! This module deliberately does **not** use the fused
//! [`crate::linalg::nearest_packed_into`] kernel: the initial scan needs
//! the *second*-closest distance too (for the `l[i]` bound), and every
//! later scan prunes per point via bounds the fused kernel cannot see.
//! Its direct-form `dist2` math is load-bearing for the bound
//! invariants — do not swap it for the dot-product form.

use super::{init_plusplus, init_random, Init, KmeansParams, KmeansResult};
use crate::linalg::Mat;
use crate::util::par;
use crate::util::rng::Rng;
use crate::{ensure_arg, Result};

#[inline]
fn dist2(a: &[f32], b: &[f32]) -> f32 {
    let mut s = 0.0f32;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// Exact k-means via Hamerly-bounded Lloyd iterations. Same contract as
/// [`super::kmeans`].
pub fn kmeans_hamerly(x: &Mat, params: &KmeansParams, seed: u64) -> Result<KmeansResult> {
    let n = x.rows;
    let d = x.cols;
    let k = params.k;
    ensure_arg!(k >= 1, "kmeans_hamerly: k must be >= 1");
    ensure_arg!(k <= n, "kmeans_hamerly: k={k} > n={n}");
    let mut rng = Rng::new(seed);
    let mut centers = match params.init {
        Init::Random => init_random(x, k, &mut rng),
        Init::PlusPlus => init_plusplus(x, k, &mut rng),
    };

    // ---- initial exact assignment (one full scan, pool-parallel) ----------
    let mut labels = vec![0u32; n];
    let mut u = vec![0f32; n]; // distance (not squared) upper bound
    let mut l = vec![0f32; n]; // second-closest lower bound
    {
        let centers = &centers;
        let init: Vec<(u32, f32, f32)> = par::par_map(n, |i| {
            let row = x.row(i);
            let (mut b1, mut d1, mut d2s) = (0usize, f32::INFINITY, f32::INFINITY);
            for c in 0..k {
                let dd = dist2(row, centers.row(c));
                if dd < d1 {
                    d2s = d1;
                    d1 = dd;
                    b1 = c;
                } else if dd < d2s {
                    d2s = dd;
                }
            }
            let lb = if d2s.is_finite() { d2s.max(0.0).sqrt() } else { f32::INFINITY };
            (b1 as u32, d1.max(0.0).sqrt(), lb)
        });
        for (i, (b1, ui, li)) in init.into_iter().enumerate() {
            labels[i] = b1;
            u[i] = ui;
            l[i] = li;
        }
    }

    let mut s_half = vec![0f32; k];
    let mut counts = vec![0u64; k];
    let mut sums = vec![0f64; k * d];
    let mut inertia = f64::INFINITY;
    let mut iterations = 0usize;

    for it in 0..params.max_iter {
        iterations = it + 1;
        // ---- s[c]: half-distance to nearest other center (O(k²d), pooled) -
        if k > 1 {
            let centers_ref = &centers;
            let halves: Vec<f32> = par::par_map(k, |c| {
                let mut best = f32::INFINITY;
                for c2 in 0..k {
                    if c2 != c {
                        let dd = dist2(centers_ref.row(c), centers_ref.row(c2));
                        if dd < best {
                            best = dd;
                        }
                    }
                }
                0.5 * best.max(0.0).sqrt()
            });
            s_half.copy_from_slice(&halves);
        }

        // ---- bounded reassignment -----------------------------------------
        for i in 0..n {
            let a = labels[i] as usize;
            let bound = l[i].min(f32::INFINITY).max(s_half[a]);
            if u[i] <= bound {
                continue; // cannot change owner
            }
            // tighten u with one exact distance
            let row = x.row(i);
            let da = dist2(row, centers.row(a)).max(0.0).sqrt();
            u[i] = da;
            if da <= bound {
                continue;
            }
            // full scan
            let (mut b1, mut d1, mut d2s) = (a, da * da, f32::INFINITY);
            for c in 0..k {
                if c == a {
                    continue;
                }
                let dd = dist2(row, centers.row(c));
                if dd < d1 {
                    d2s = d1;
                    d1 = dd;
                    b1 = c;
                } else if dd < d2s {
                    d2s = dd;
                }
            }
            labels[i] = b1 as u32;
            u[i] = d1.max(0.0).sqrt();
            l[i] = if d2s.is_finite() { d2s.max(0.0).sqrt() } else { f32::INFINITY };
        }

        // ---- exact per-point distances (inertia + repair keys) ------------
        // O(n·d): cheap next to the O(n·k·d) scans we skipped; keeps the
        // convergence criterion and the empty-cluster repair identical to
        // plain Lloyd's exact `dists` array.
        let mut dists = vec![0f32; n];
        let mut new_inertia = 0.0f64;
        for i in 0..n {
            let dd = dist2(x.row(i), centers.row(labels[i] as usize)).max(0.0);
            dists[i] = dd;
            new_inertia += dd as f64;
            u[i] = dd.sqrt(); // tightened for free
        }

        // ---- update step (means + Lloyd-identical empty repair) -----------
        for v in counts.iter_mut() {
            *v = 0;
        }
        for v in sums.iter_mut() {
            *v = 0.0;
        }
        for i in 0..n {
            let c = labels[i] as usize;
            counts[c] += 1;
            let row = x.row(i);
            let s = &mut sums[c * d..(c + 1) * d];
            for (sv, &xv) in s.iter_mut().zip(row) {
                *sv += xv as f64;
            }
        }
        let empties: Vec<usize> = (0..k).filter(|&c| counts[c] == 0).collect();
        if !empties.is_empty() {
            let mut order = crate::util::argsort_by_f64(
                &dists.iter().map(|&v| -(v as f64)).collect::<Vec<_>>(),
            );
            order.truncate(empties.len());
            for (&c, &i) in empties.iter().zip(order.iter()) {
                let old = labels[i] as usize;
                if counts[old] > 1 {
                    counts[old] -= 1;
                    let row = x.row(i);
                    let s = &mut sums[old * d..(old + 1) * d];
                    for (sv, &xv) in s.iter_mut().zip(row) {
                        *sv -= xv as f64;
                    }
                }
                labels[i] = c as u32;
                counts[c] = 1;
                let s = &mut sums[c * d..(c + 1) * d];
                for (sv, &xv) in s.iter_mut().zip(x.row(i)) {
                    *sv = xv as f64;
                }
                u[i] = 0.0; // now exactly on the (seized) center
                l[i] = 0.0; // conservative
            }
        }
        // move centers, tracking per-center drift
        let mut max_drift = 0f32;
        let mut drift = vec![0f32; k];
        for c in 0..k {
            if counts[c] == 0 {
                continue;
            }
            let inv = 1.0 / counts[c] as f64;
            let mut dd = 0.0f32;
            {
                let s = &sums[c * d..(c + 1) * d];
                let cr = centers.row_mut(c);
                for (cv, &sv) in cr.iter_mut().zip(s) {
                    let nv = (sv * inv) as f32;
                    let diff = nv - *cv;
                    dd += diff * diff;
                    *cv = nv;
                }
            }
            drift[c] = dd.max(0.0).sqrt();
            if drift[c] > max_drift {
                max_drift = drift[c];
            }
        }
        // ---- bound maintenance --------------------------------------------
        for i in 0..n {
            u[i] += drift[labels[i] as usize];
            l[i] = (l[i] - max_drift).max(0.0);
        }

        if inertia.is_finite()
            && (inertia - new_inertia) <= params.tol * inertia.abs().max(1e-12)
        {
            inertia = new_inertia;
            break;
        }
        inertia = new_inertia;
    }
    Ok(KmeansResult { labels, centers, inertia, iterations })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::kmeans;
    use crate::util::prop::run_prop;

    fn randmat(rng: &mut Rng, n: usize, d: usize, spread: f32) -> Mat {
        let mut m = Mat::zeros(n, d);
        for v in m.data.iter_mut() {
            *v = rng.normal() as f32 * spread;
        }
        m
    }

    #[test]
    fn matches_lloyd_exactly() {
        // Hamerly is an exact accelerator: same init (same seed) ⇒ same
        // labels, inertia and iteration count as plain Lloyd.
        run_prop("hamerly-eq-lloyd", 20, 31, |rng| {
            let n = 100 + rng.usize(300);
            let d = 1 + rng.usize(8);
            let k = 2 + rng.usize(12);
            let x = randmat(rng, n, d, 3.0);
            let seed = rng.next_u64();
            let params = KmeansParams { k, max_iter: 40, tol: 1e-4, ..Default::default() };
            let a = kmeans(&x, &params, seed).map_err(|e| e.to_string())?;
            let b = kmeans_hamerly(&x, &params, seed).map_err(|e| e.to_string())?;
            if a.labels != b.labels {
                return Err(format!(
                    "labels differ (lloyd inertia {}, hamerly {})",
                    a.inertia, b.inertia
                ));
            }
            let rel = (a.inertia - b.inertia).abs() / a.inertia.abs().max(1e-12);
            if rel > 1e-6 {
                return Err(format!("inertia differs: {} vs {}", a.inertia, b.inertia));
            }
            Ok(())
        });
    }

    #[test]
    fn matches_lloyd_at_selection_shape() {
        // the shape that matters: many centers
        let mut rng = Rng::new(9);
        let x = randmat(&mut rng, 2000, 2, 5.0);
        let params = KmeansParams { k: 200, max_iter: 30, tol: 1e-3, ..Default::default() };
        let a = kmeans(&x, &params, 77).unwrap();
        let b = kmeans_hamerly(&x, &params, 77).unwrap();
        assert_eq!(a.labels, b.labels);
        // inertia agrees up to the float-path difference (gemm expansion
        // ‖x‖²+‖c‖²−2xc in Lloyd vs direct (x−c)² in Hamerly)
        assert!(
            (a.inertia - b.inertia).abs() / a.inertia < 1e-5,
            "{} vs {}",
            a.inertia,
            b.inertia
        );
        assert_eq!(a.iterations, b.iterations);
    }

    #[test]
    fn basic_contract() {
        let mut rng = Rng::new(4);
        let x = randmat(&mut rng, 60, 3, 1.0);
        let r = kmeans_hamerly(&x, &KmeansParams { k: 4, ..Default::default() }, 5).unwrap();
        assert_eq!(r.labels.len(), 60);
        assert!(r.labels.iter().all(|&l| l < 4));
        assert!(r.inertia.is_finite() && r.inertia >= 0.0);
        assert!(kmeans_hamerly(&x, &KmeansParams { k: 0, ..Default::default() }, 5).is_err());
        assert!(kmeans_hamerly(&x, &KmeansParams { k: 61, ..Default::default() }, 5).is_err());
    }

    #[test]
    fn k_equals_one_and_k_equals_n() {
        let mut rng = Rng::new(8);
        let x = randmat(&mut rng, 20, 2, 1.0);
        let one = kmeans_hamerly(&x, &KmeansParams { k: 1, ..Default::default() }, 3).unwrap();
        assert!(one.labels.iter().all(|&l| l == 0));
        let all = kmeans_hamerly(&x, &KmeansParams { k: 20, ..Default::default() }, 3).unwrap();
        // every point its own cluster → zero inertia
        assert!(all.inertia < 1e-9, "inertia {}", all.inertia);
    }
}
