//! Multilevel k-way graph partitioning — a METIS-like substrate
//! (Karypis & Kumar, SISC'98; ref. [23] of the paper).
//!
//! The paper's related work builds consensus clusterings by partitioning
//! graphs derived from the ensemble: Strehl & Ghosh's CSPA/HGPA/MCLA [18]
//! and Fern & Brodley's HBGF [22] all call METIS/hMETIS. This module
//! provides that substrate: the classic three-phase multilevel scheme —
//!
//! 1. **Coarsening** by heavy-edge matching until the graph is small,
//! 2. **Initial partitioning** by greedy (boundary-weighted) region
//!    growing on the coarsest graph,
//! 3. **Uncoarsening** with boundary Kernighan–Lin refinement at every
//!    level (gain-driven single-vertex moves under a balance constraint).
//!
//! The objective is the weighted **edge cut** subject to vertex-weight
//! balance `w(part) ≤ (1+ε)·w(V)/k`.

use crate::util::rng::Rng;
use crate::{ensure_arg, Result};

/// Undirected weighted graph in CSR form with vertex weights.
///
/// Invariants: adjacency is symmetric (every edge stored in both
/// directions), no self-loops, `xadj.len() == n+1`.
#[derive(Debug, Clone)]
pub struct Graph {
    /// CSR row pointers (n+1).
    pub xadj: Vec<usize>,
    /// Flattened neighbor lists.
    pub adjncy: Vec<u32>,
    /// Edge weights, parallel to `adjncy`.
    pub adjwgt: Vec<f64>,
    /// Vertex weights (n).
    pub vwgt: Vec<f64>,
}

impl Graph {
    /// Number of vertices.
    #[inline]
    pub fn n(&self) -> usize {
        self.vwgt.len()
    }

    /// Neighbor slice of vertex `v`.
    #[inline]
    pub fn neighbors(&self, v: usize) -> (&[u32], &[f64]) {
        let (lo, hi) = (self.xadj[v], self.xadj[v + 1]);
        (&self.adjncy[lo..hi], &self.adjwgt[lo..hi])
    }

    /// Build a symmetric graph from an undirected edge list. Duplicate
    /// edges are merged by summing weights; self-loops are dropped.
    pub fn from_edges(n: usize, edges: &[(u32, u32, f64)]) -> Graph {
        let mut adj: Vec<Vec<(u32, f64)>> = vec![Vec::new(); n];
        for &(a, b, w) in edges {
            if a == b || w <= 0.0 {
                continue;
            }
            adj[a as usize].push((b, w));
            adj[b as usize].push((a, w));
        }
        let mut xadj = Vec::with_capacity(n + 1);
        let mut adjncy = Vec::new();
        let mut adjwgt = Vec::new();
        xadj.push(0);
        for list in adj.iter_mut() {
            list.sort_unstable_by_key(|&(c, _)| c);
            let mut i = 0;
            while i < list.len() {
                let c = list[i].0;
                let mut w = 0.0;
                while i < list.len() && list[i].0 == c {
                    w += list[i].1;
                    i += 1;
                }
                adjncy.push(c);
                adjwgt.push(w);
            }
            xadj.push(adjncy.len());
        }
        Graph { xadj, adjncy, adjwgt, vwgt: vec![1.0; n] }
    }

    /// Total vertex weight.
    pub fn total_vwgt(&self) -> f64 {
        self.vwgt.iter().sum()
    }

    /// Weighted edge cut of a partition (each cut edge counted once).
    pub fn edge_cut(&self, part: &[u32]) -> f64 {
        debug_assert_eq!(part.len(), self.n());
        let mut cut = 0.0;
        for v in 0..self.n() {
            let (nbrs, wts) = self.neighbors(v);
            for (u, w) in nbrs.iter().zip(wts) {
                if part[v] != part[*u as usize] {
                    cut += w;
                }
            }
        }
        cut / 2.0
    }

    /// Max part weight divided by the ideal `w(V)/k` (1.0 = perfectly
    /// balanced).
    pub fn imbalance(&self, part: &[u32], k: usize) -> f64 {
        let mut pw = vec![0.0f64; k];
        for (v, &p) in part.iter().enumerate() {
            pw[p as usize] += self.vwgt[v];
        }
        let ideal = self.total_vwgt() / k as f64;
        pw.iter().cloned().fold(0.0, f64::max) / ideal.max(1e-300)
    }
}

/// Tuning parameters for [`partition`].
#[derive(Debug, Clone)]
pub struct PartitionParams {
    /// Allowed imbalance ε: part weight ≤ (1+ε)·w(V)/k.
    pub epsilon: f64,
    /// Stop coarsening when the graph has at most `coarse_factor·k`
    /// vertices.
    pub coarse_factor: usize,
    /// Refinement passes per level.
    pub refine_passes: usize,
    /// Independent initial-partition trials on the coarsest graph.
    pub init_trials: usize,
}

impl Default for PartitionParams {
    fn default() -> Self {
        PartitionParams { epsilon: 0.10, coarse_factor: 30, refine_passes: 8, init_trials: 4 }
    }
}

/// One coarsening level: the coarse graph plus the fine→coarse vertex map.
struct Level {
    graph: Graph,
    /// `cmap[fine_v] = coarse_v` for the graph one level finer.
    cmap: Vec<u32>,
}

/// Multilevel k-way partition of `g`. Returns per-vertex part labels in
/// `0..k`.
pub fn partition(g: &Graph, k: usize, params: &PartitionParams, seed: u64) -> Result<Vec<u32>> {
    ensure_arg!(k >= 1, "partition: k must be >= 1");
    let n = g.n();
    ensure_arg!(n > 0, "partition: empty graph");
    if k == 1 {
        return Ok(vec![0; n]);
    }
    if k >= n {
        // one vertex per part (extra parts stay empty)
        return Ok((0..n).map(|v| v as u32).collect());
    }
    let mut rng = Rng::new(seed ^ 0x9E37_79B9_7F4A_7C15);

    // ---- Phase 1: coarsen -------------------------------------------------
    let target = (params.coarse_factor * k).max(32);
    let mut levels: Vec<Level> = Vec::new();
    let mut current = g.clone();
    while current.n() > target {
        let (coarse, cmap) = coarsen_hem(&current, &mut rng);
        // Matching stalled (e.g. star graphs): stop coarsening.
        if coarse.n() as f64 > 0.95 * current.n() as f64 {
            break;
        }
        levels.push(Level { graph: current, cmap });
        current = coarse;
    }

    // ---- Phase 2: initial partition on the coarsest graph -----------------
    let mut best: Option<(f64, Vec<u32>)> = None;
    for trial in 0..params.init_trials.max(1) {
        let mut part = greedy_growing(&current, k, params.epsilon, rng.fork(trial as u64));
        refine_fm(&current, &mut part, k, params.epsilon, params.refine_passes);
        let cut = current.edge_cut(&part);
        if best.as_ref().map(|(c, _)| cut < *c).unwrap_or(true) {
            best = Some((cut, part));
        }
    }
    let mut part = best.expect("at least one trial").1;

    // ---- Phase 3: uncoarsen + refine ---------------------------------------
    for level in levels.iter().rev() {
        let fine_n = level.graph.n();
        let mut fine_part = vec![0u32; fine_n];
        for v in 0..fine_n {
            fine_part[v] = part[level.cmap[v] as usize];
        }
        refine_fm(&level.graph, &mut fine_part, k, params.epsilon, params.refine_passes);
        part = fine_part;
    }
    Ok(part)
}

/// Heavy-edge matching coarsening: visit vertices in random order, match
/// each unmatched vertex to its unmatched neighbor with the heaviest edge
/// (or leave it solo), then contract matched pairs.
fn coarsen_hem(g: &Graph, rng: &mut Rng) -> (Graph, Vec<u32>) {
    let n = g.n();
    let mut order: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut order);
    const UNMATCHED: u32 = u32::MAX;
    let mut mate = vec![UNMATCHED; n];
    for &v in &order {
        if mate[v] != UNMATCHED {
            continue;
        }
        let (nbrs, wts) = g.neighbors(v);
        let mut best = UNMATCHED;
        let mut best_w = f64::NEG_INFINITY;
        for (u, w) in nbrs.iter().zip(wts) {
            let u = *u as usize;
            if mate[u] == UNMATCHED && u != v && *w > best_w {
                best_w = *w;
                best = u as u32;
            }
        }
        if best != UNMATCHED {
            mate[v] = best;
            mate[best as usize] = v as u32;
        } else {
            mate[v] = v as u32; // solo
        }
    }
    // Assign coarse ids (the lower endpoint of each pair owns the id).
    let mut cmap = vec![u32::MAX; n];
    let mut next = 0u32;
    for v in 0..n {
        if cmap[v] != u32::MAX {
            continue;
        }
        let m = mate[v] as usize;
        cmap[v] = next;
        cmap[m] = next; // m == v for solo vertices
        next += 1;
    }
    let cn = next as usize;
    // Contract: coarse vertex weights and merged edge lists.
    let mut cvwgt = vec![0.0f64; cn];
    for v in 0..n {
        cvwgt[cmap[v] as usize] += g.vwgt[v];
    }
    // Accumulate coarse edges with a per-coarse-vertex scatter map.
    let mut xadj = Vec::with_capacity(cn + 1);
    let mut adjncy: Vec<u32> = Vec::with_capacity(g.adjncy.len() / 2 + cn);
    let mut adjwgt: Vec<f64> = Vec::with_capacity(g.adjncy.len() / 2 + cn);
    let mut touch_pos = vec![usize::MAX; cn]; // coarse nbr -> slot in this row
    let mut members: Vec<Vec<u32>> = vec![Vec::new(); cn];
    for v in 0..n {
        members[cmap[v] as usize].push(v as u32);
    }
    xadj.push(0);
    for cv in 0..cn {
        let row_start = adjncy.len();
        for &v in &members[cv] {
            let (nbrs, wts) = g.neighbors(v as usize);
            for (u, w) in nbrs.iter().zip(wts) {
                let cu = cmap[*u as usize] as usize;
                if cu == cv {
                    continue; // contracted edge disappears
                }
                if touch_pos[cu] == usize::MAX || touch_pos[cu] < row_start {
                    touch_pos[cu] = adjncy.len();
                    adjncy.push(cu as u32);
                    adjwgt.push(*w);
                } else {
                    adjwgt[touch_pos[cu]] += *w;
                }
            }
        }
        xadj.push(adjncy.len());
    }
    (Graph { xadj, adjncy, adjwgt, vwgt: cvwgt }, cmap)
}

/// Greedy graph growing: seed k regions at random vertices, repeatedly
/// attach the unassigned vertex with the strongest connection to any
/// under-capacity region. Unreachable leftovers go to the lightest part.
fn greedy_growing(g: &Graph, k: usize, epsilon: f64, mut rng: Rng) -> Vec<u32> {
    let n = g.n();
    let cap = (1.0 + epsilon) * g.total_vwgt() / k as f64;
    const UNASSIGNED: u32 = u32::MAX;
    let mut part = vec![UNASSIGNED; n];
    let mut pw = vec![0.0f64; k];
    // gain[v] = (best part, connection weight) among under-capacity parts
    // maintained lazily through a simple priority scan (coarsest graph is
    // small — O(n²·k) here is cheap and robust).
    let seeds = rng.sample_indices(n, k.min(n));
    for (p, &s) in seeds.iter().enumerate() {
        part[s] = p as u32;
        pw[p] += g.vwgt[s];
    }
    // Frontier-driven growth.
    let mut conn = vec![vec![0.0f64; k]; n]; // connection of v to each part
    let mut frontier: Vec<usize> = Vec::new();
    for (p, &s) in seeds.iter().enumerate() {
        let (nbrs, wts) = g.neighbors(s);
        for (u, w) in nbrs.iter().zip(wts) {
            let u = *u as usize;
            if part[u] == UNASSIGNED {
                if conn[u].iter().all(|&c| c == 0.0) {
                    frontier.push(u);
                }
                conn[u][p] += w;
            }
        }
    }
    let mut assigned = seeds.len();
    while assigned < n {
        // pick the frontier vertex with max connection to an open part
        let mut best_v = usize::MAX;
        let mut best_p = 0usize;
        let mut best_c = f64::NEG_INFINITY;
        frontier.retain(|&v| part[v] == UNASSIGNED);
        for &v in &frontier {
            for p in 0..k {
                if pw[p] + g.vwgt[v] <= cap && conn[v][p] > best_c {
                    best_c = conn[v][p];
                    best_v = v;
                    best_p = p;
                }
            }
        }
        let (v, p) = if best_v == usize::MAX {
            // no frontier vertex fits: take any unassigned vertex, lightest part
            let v = (0..n).find(|&v| part[v] == UNASSIGNED).expect("unassigned exists");
            let p = (0..k).fold(0, |b, p| if pw[p] < pw[b] { p } else { b });
            (v, p)
        } else {
            (best_v, best_p)
        };
        part[v] = p as u32;
        pw[p] += g.vwgt[v];
        assigned += 1;
        let (nbrs, wts) = g.neighbors(v);
        for (u, w) in nbrs.iter().zip(wts) {
            let u = *u as usize;
            if part[u] == UNASSIGNED {
                if conn[u].iter().all(|&c| c == 0.0) {
                    frontier.push(u);
                }
                conn[u][p] += w;
            }
        }
    }
    part
}

/// Boundary Fiduccia–Mattheyses refinement: each pass tentatively moves a
/// sequence of (locked-once) vertices by best gain — *including negative-
/// gain hill-climbing moves* — and rolls back to the best prefix. This is
/// what lets the partitioner escape the local optima that defeat plain
/// positive-gain Kernighan–Lin sweeps (e.g. uniform-weight bipartite
/// incidence graphs, where single moves are rarely profitable in
/// isolation).
fn refine_fm(g: &Graph, part: &mut [u32], k: usize, epsilon: f64, passes: usize) {
    let n = g.n();
    let cap = (1.0 + epsilon) * g.total_vwgt() / k as f64;
    let mut pw = vec![0.0f64; k];
    for v in 0..n {
        pw[part[v] as usize] += g.vwgt[v];
    }
    // conn[v*k + p] = weight from v into part p (kept incrementally)
    let mut conn = vec![0.0f64; n * k];
    for v in 0..n {
        let (nbrs, wts) = g.neighbors(v);
        for (u, w) in nbrs.iter().zip(wts) {
            conn[v * k + part[*u as usize] as usize] += w;
        }
    }
    // Cap the tentative-move sequence so one pass stays near-linear.
    let max_moves = n.min(4 * n / k.max(1) + 64);
    let mut locked = vec![false; n];
    for _pass in 0..passes {
        for l in locked.iter_mut() {
            *l = false;
        }
        let mut moves: Vec<(usize, u32)> = Vec::new(); // (vertex, old part)
        let mut cum = 0.0f64;
        let mut best_cum = 0.0f64;
        let mut best_len = 0usize;
        for _step in 0..max_moves {
            // pick the best-gain feasible move among unlocked boundary vertices
            let mut sel: Option<(usize, usize, f64)> = None;
            for v in 0..n {
                if locked[v] {
                    continue;
                }
                let home = part[v] as usize;
                let base = conn[v * k + home];
                for p in 0..k {
                    if p == home || pw[p] + g.vwgt[v] > cap {
                        continue;
                    }
                    let gain = conn[v * k + p] - base;
                    if gain == 0.0 && conn[v * k + p] == 0.0 {
                        continue; // interior vertex w.r.t. this target
                    }
                    if sel.map(|(_, _, bg)| gain > bg + 1e-12).unwrap_or(true) {
                        sel = Some((v, p, gain));
                    }
                }
            }
            let Some((v, p, gain)) = sel else { break };
            // apply tentatively
            let home = part[v] as usize;
            pw[home] -= g.vwgt[v];
            pw[p] += g.vwgt[v];
            part[v] = p as u32;
            locked[v] = true;
            let (nbrs, wts) = g.neighbors(v);
            for (u, w) in nbrs.iter().zip(wts) {
                let u = *u as usize;
                conn[u * k + home] -= w;
                conn[u * k + p] += w;
            }
            moves.push((v, home as u32));
            cum += gain;
            if cum > best_cum + 1e-12 {
                best_cum = cum;
                best_len = moves.len();
            }
            // stop early when deep underwater with no prospect
            if cum < best_cum - 2.0 * (1.0 + best_cum.abs()) && moves.len() > best_len + 32 {
                break;
            }
        }
        // roll back everything after the best prefix
        for &(v, old) in moves[best_len..].iter().rev() {
            let cur = part[v] as usize;
            let old = old as usize;
            pw[cur] -= g.vwgt[v];
            pw[old] += g.vwgt[v];
            part[v] = old as u32;
            let (nbrs, wts) = g.neighbors(v);
            for (u, w) in nbrs.iter().zip(wts) {
                let u = *u as usize;
                conn[u * k + cur] -= w;
                conn[u * k + old] += w;
            }
        }
        if best_cum <= 1e-12 {
            break; // pass produced no improvement
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two dense cliques joined by a single light edge.
    fn two_cliques(size: usize) -> Graph {
        let mut edges = Vec::new();
        for block in 0..2u32 {
            let off = block * size as u32;
            for i in 0..size as u32 {
                for j in (i + 1)..size as u32 {
                    edges.push((off + i, off + j, 1.0));
                }
            }
        }
        edges.push((0, size as u32, 0.01)); // bridge
        Graph::from_edges(2 * size, &edges)
    }

    /// Ring of `k` cliques, adjacent cliques bridged by one light edge.
    fn clique_ring(k: usize, size: usize) -> Graph {
        let mut edges = Vec::new();
        for b in 0..k as u32 {
            let off = b * size as u32;
            for i in 0..size as u32 {
                for j in (i + 1)..size as u32 {
                    edges.push((off + i, off + j, 1.0));
                }
            }
            let next = ((b as usize + 1) % k) as u32 * size as u32;
            edges.push((off, next, 0.05));
        }
        Graph::from_edges(k * size, &edges)
    }

    #[test]
    fn from_edges_merges_and_symmetrizes() {
        let g = Graph::from_edges(3, &[(0, 1, 1.0), (1, 0, 2.0), (1, 2, 1.0), (2, 2, 9.0)]);
        let (n0, w0) = g.neighbors(0);
        assert_eq!(n0, &[1]);
        assert_eq!(w0, &[3.0]); // merged duplicate
        let (n2, _) = g.neighbors(2);
        assert_eq!(n2, &[1]); // self-loop dropped
        assert_eq!(g.n(), 3);
    }

    #[test]
    fn bisects_two_cliques() {
        let g = two_cliques(40);
        let part = partition(&g, 2, &PartitionParams::default(), 7).unwrap();
        // must cut exactly the bridge
        assert!((g.edge_cut(&part) - 0.01).abs() < 1e-9, "cut={}", g.edge_cut(&part));
        assert!(g.imbalance(&part, 2) < 1.05);
        // each clique uniform
        for block in 0..2 {
            let base = part[block * 40];
            for v in 0..40 {
                assert_eq!(part[block * 40 + v], base);
            }
        }
    }

    #[test]
    fn kway_on_clique_ring() {
        let k = 5;
        let g = clique_ring(k, 30);
        let part = partition(&g, k, &PartitionParams::default(), 3).unwrap();
        // optimal cut = k bridges of 0.05
        let cut = g.edge_cut(&part);
        assert!(cut <= k as f64 * 0.05 + 1e-9, "cut={cut}");
        assert!(g.imbalance(&part, k) <= 1.1 + 1e-9);
    }

    #[test]
    fn respects_vertex_weights() {
        // a path of 4 vertices where vertex 0 is very heavy: balance forces
        // it alone in its part.
        let mut g = Graph::from_edges(4, &[(0, 1, 1.0), (1, 2, 1.0), (2, 3, 1.0)]);
        g.vwgt = vec![10.0, 1.0, 1.0, 1.0];
        let part = partition(&g, 2, &PartitionParams { epsilon: 0.4, ..Default::default() }, 1)
            .unwrap();
        assert_ne!(part[0], part[3]);
    }

    #[test]
    fn k_edge_cases() {
        let g = two_cliques(5);
        assert_eq!(partition(&g, 1, &PartitionParams::default(), 1).unwrap(), vec![0; 10]);
        let p = partition(&g, 10, &PartitionParams::default(), 1).unwrap();
        assert_eq!(p.len(), 10);
        assert!(partition(&g, 0, &PartitionParams::default(), 1).is_err());
    }

    #[test]
    fn partition_deterministic_per_seed() {
        let g = clique_ring(4, 20);
        let a = partition(&g, 4, &PartitionParams::default(), 42).unwrap();
        let b = partition(&g, 4, &PartitionParams::default(), 42).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn coarsening_preserves_total_weight() {
        let g = clique_ring(3, 25);
        let mut rng = Rng::new(5);
        let (coarse, cmap) = coarsen_hem(&g, &mut rng);
        assert!((coarse.total_vwgt() - g.total_vwgt()).abs() < 1e-9);
        assert!(coarse.n() < g.n());
        assert!(cmap.iter().all(|&c| (c as usize) < coarse.n()));
        // edge weight conservation: coarse total edge weight + contracted
        // intra-pair weight = fine total edge weight
        let fine_w: f64 = g.adjwgt.iter().sum::<f64>() / 2.0;
        let coarse_w: f64 = coarse.adjwgt.iter().sum::<f64>() / 2.0;
        assert!(coarse_w <= fine_w + 1e-9);
    }
}
