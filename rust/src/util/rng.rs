//! xoshiro256++ PRNG (Blackman & Vigna) with SplitMix64 seeding.
//!
//! Stand-in for the unavailable `rand` crate. Deterministic across
//! platforms; every stochastic component in the crate threads an explicit
//! seed through this type so experiments are reproducible run-to-run.

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed deterministically from a u64 (SplitMix64 expansion).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-job seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n). Lemire's method.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal (Box–Muller; one value per call, cached spare).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        // Marsaglia polar method.
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm for small
    /// k, partial shuffle otherwise). Returned in random order.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            // partial Fisher–Yates: shuffle the first k positions
            for i in 0..k {
                let j = i + self.usize(n - i);
                all.swap(i, j);
            }
            all.truncate(k);
            all
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.usize(j + 1);
                let v = if chosen.contains(&t) { j } else { t };
                chosen.insert(v);
                out.push(v);
            }
            self.shuffle(&mut out);
            out
        }
    }

    /// Weighted index draw proportional to `weights` (all ≥ 0, sum > 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(1);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn usize_bounds_and_coverage() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.usize(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(4);
        for &(n, k) in &[(10usize, 10usize), (100, 5), (50, 40), (1, 1), (7, 0)] {
            let s = r.sample_indices(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn weighted_respects_zero() {
        let mut r = Rng::new(5);
        for _ in 0..200 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fork_streams_differ() {
        let mut r = Rng::new(6);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        let same = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
