//! Scoped data parallelism over std threads (rayon replacement).
//!
//! `par_map` / `par_for_chunks` split an index range into contiguous chunks
//! and run them on `num_threads()` scoped threads. Work is CPU-bound and
//! chunk costs are near-uniform in this crate, so static partitioning is
//! within noise of work stealing while being far simpler and allocation
//! free on the dispatch path.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use (env `USPEC_THREADS` overrides; defaults
/// to available parallelism).
pub fn num_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("USPEC_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n < 2 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(nt);
    std::thread::scope(|s| {
        for (t, slot) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = t * chunk;
                for (i, o) in slot.iter_mut().enumerate() {
                    *o = Some(f(base + i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Run `f(chunk_start, chunk)` over disjoint mutable chunks of `data`
/// (each of at most `chunk_len` items) in parallel.
pub fn par_for_chunks<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let nt = num_threads();
    if nt <= 1 || n <= chunk_len {
        // Sequential path still honors the ≤chunk_len contract — callers
        // rely on it to recover (row, col) coordinates from chunk offsets.
        let mut start = 0;
        for ch in data.chunks_mut(chunk_len) {
            let len = ch.len();
            f(start, ch);
            start += len;
        }
        return;
    }
    // Atomic cursor over chunk ids gives dynamic load balancing for the
    // (rare) skewed workloads — e.g. ragged last batches.
    let nchunks = n.div_ceil(chunk_len);
    let cursor = AtomicUsize::new(0);
    // SAFETY-free approach: split into chunk list first.
    let mut chunks: Vec<(usize, &mut [T])> = Vec::with_capacity(nchunks);
    let mut rest = data;
    let mut start = 0;
    while !rest.is_empty() {
        let take = chunk_len.min(rest.len());
        let (head, tail) = rest.split_at_mut(take);
        chunks.push((start, head));
        start += take;
        rest = tail;
    }
    let chunks = std::sync::Mutex::new(chunks.into_iter().map(Some).collect::<Vec<_>>());
    std::thread::scope(|s| {
        for _ in 0..nt.min(nchunks) {
            let f = &f;
            let cursor = &cursor;
            let chunks = &chunks;
            s.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= nchunks {
                    break;
                }
                let item = chunks.lock().unwrap()[i].take();
                if let Some((st, ch)) = item {
                    f(st, ch);
                }
            });
        }
    });
}

/// Parallel reduce: `f(i)` mapped over `0..n`, combined with `combine`.
pub fn par_reduce<T: Send + Clone, F, C>(n: usize, identity: T, f: F, combine: C) -> T
where
    F: Fn(usize) -> T + Sync,
    C: Fn(T, T) -> T + Send + Sync,
{
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n < 2 {
        let mut acc = identity;
        for i in 0..n {
            acc = combine(acc, f(i));
        }
        return acc;
    }
    let chunk = n.div_ceil(nt);
    let partials: Vec<T> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for t in 0..nt {
            let f = &f;
            let combine = &combine;
            let identity = identity.clone();
            handles.push(s.spawn(move || {
                let lo = t * chunk;
                let hi = ((t + 1) * chunk).min(n);
                let mut acc = identity;
                for i in lo..hi {
                    acc = combine(acc, f(i));
                }
                acc
            }));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    partials.into_iter().fold(identity, combine)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_order() {
        let v = par_map(1000, |i| i * 2);
        assert_eq!(v.len(), 1000);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 2);
        }
    }

    #[test]
    fn par_map_empty_and_one() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn par_for_chunks_covers_all() {
        let mut data = vec![0usize; 10_001];
        par_for_chunks(&mut data, 128, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i + 1;
            }
        });
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i + 1);
        }
    }

    #[test]
    fn par_reduce_sum() {
        let s = par_reduce(10_000, 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(s, 9999 * 10_000 / 2);
    }
}
