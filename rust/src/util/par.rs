//! Persistent data-parallel runtime (rayon replacement).
//!
//! # Why persistent
//!
//! The hot paths dispatch *many small* parallel regions: several per KNR
//! batch inside [`crate::affinity::knr::KnrIndex::approx_knr`], one per
//! k-means iteration, one per Lanczos matvec. The original implementation
//! spawned and joined fresh OS threads on every call, which put tens of
//! microseconds of `clone(2)`/join latency on every region — more than the
//! region's useful work at batch sizes the paper's "batch processing
//! manner" (§3.1.4) prescribes. This module instead keeps one lazily
//! initialized pool of parked workers alive for the process lifetime; a
//! parallel region is now one mutex push + condvar broadcast, and work is
//! claimed from an atomic-cursor chunk queue (dynamic load balancing for
//! ragged tails at no extra allocation).
//!
//! # Execution model
//!
//! * A region is split into `chunks` (≈ 4 per thread); each chunk is
//!   claimed by `fetch_add` on the job's cursor.
//! * The dispatching thread always participates, so progress never depends
//!   on the workers (concurrent top-level dispatches share one broadcast
//!   slot; late dispatches may receive less help but always complete).
//! * **Nesting**: a parallel call from inside a parallel region runs
//!   inline (sequentially) on the calling thread. This keeps nested
//!   `par_map`/`par_for_chunks` deadlock-free and means callers never need
//!   to care whether they are already on a pool thread.
//! * **Panics** in a task are caught per chunk, the region completes, and
//!   the dispatcher re-raises a `"par: parallel task panicked"` panic.
//!
//! # Determinism
//!
//! All three primitives produce results that are *bit-identical for any
//! thread count* (including 1): `par_map` and `par_for_chunks` write
//! disjoint index ranges, and `par_reduce` folds a fixed bucket partition
//! (a function of `n` only — never of the thread count). This is what lets
//! `uspec`/`usenc` promise fixed-seed reproducibility regardless of
//! `USPEC_THREADS`. `par_reduce` requires `combine(identity, x) == x`.
//!
//! # Env knobs
//!
//! * `USPEC_THREADS` — worker budget (default: available parallelism).
//!   Read once; the pool spawns `USPEC_THREADS − 1` workers on first use.
//! * [`set_thread_override`] — runtime override for tests/benches; caps
//!   how many threads may enter a region but never changes results.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicIsize, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};

/// Chunks per participating thread: enough slack for dynamic balancing of
/// ragged workloads without shrinking chunks into dispatch noise.
const OVERSUB: usize = 4;

/// Fixed upper bound on `par_reduce` buckets (partition depends on `n`
/// only, keeping reductions independent of the thread count).
const REDUCE_BUCKETS: usize = 256;

static OVERRIDE: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use (env `USPEC_THREADS` overrides; defaults
/// to available parallelism). An active [`set_thread_override`] wins.
pub fn num_threads() -> usize {
    let o = OVERRIDE.load(Ordering::Relaxed);
    if o != 0 {
        return o;
    }
    configured_threads()
}

/// The env/hardware thread budget (ignores [`set_thread_override`]); also
/// the size the pool is built with on first use.
fn configured_threads() -> usize {
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let c = CACHED.load(Ordering::Relaxed);
    if c != 0 {
        return c;
    }
    let n = std::env::var("USPEC_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&v| v >= 1)
        .unwrap_or_else(|| {
            std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4)
        });
    CACHED.store(n, Ordering::Relaxed);
    n
}

/// Override the thread count at runtime (`0` clears the override, falling
/// back to `USPEC_THREADS`/hardware). Intended for tests and benches that
/// compare thread counts inside one process. The override caps how many
/// threads may enter a parallel region; it cannot grow the pool beyond the
/// worker count spawned on first use. Results are unaffected either way —
/// see the module docs on determinism.
pub fn set_thread_override(n: usize) {
    OVERRIDE.store(n, Ordering::Relaxed);
}

thread_local! {
    /// True while this thread is executing inside a parallel region —
    /// nested parallel calls then run inline.
    static IN_REGION: Cell<bool> = const { Cell::new(false) };
}

fn in_region() -> bool {
    IN_REGION.with(|f| f.get())
}

/// RAII flag toggle so the dispatcher restores its state even if a chunk
/// panic propagates in a way we did not anticipate.
struct RegionGuard(bool);

impl RegionGuard {
    fn enter() -> RegionGuard {
        let prev = IN_REGION.with(|f| f.replace(true));
        RegionGuard(prev)
    }
}

impl Drop for RegionGuard {
    fn drop(&mut self) {
        let prev = self.0;
        IN_REGION.with(|f| f.set(prev));
    }
}

/// One parallel region. `task` is the caller's closure with its lifetime
/// erased; it is only ever dereferenced for a successfully claimed chunk
/// (`cursor` < `nchunks`), which can only happen while the dispatching
/// caller is still blocked inside [`dispatch`] — so the borrow is live.
struct Job {
    task: *const (dyn Fn(usize) + Sync),
    nchunks: usize,
    cursor: AtomicUsize,
    done: AtomicUsize,
    /// Remaining worker-entry budget (enforces the thread cap).
    helpers: AtomicIsize,
    panicked: AtomicBool,
    done_mx: Mutex<()>,
    done_cv: Condvar,
}

// SAFETY: the raw task pointer is only dereferenced under the claimed-chunk
// protocol described on `Job`; all other fields are Sync primitives.
unsafe impl Send for Job {}
unsafe impl Sync for Job {}

struct PoolState {
    job: Option<Arc<Job>>,
    epoch: u64,
}

struct Pool {
    state: Mutex<PoolState>,
    wake: Condvar,
}

static POOL: OnceLock<&'static Pool> = OnceLock::new();

/// Total parallel regions dispatched through the pool (perf counter for
/// the micro benches).
static DISPATCHES: AtomicUsize = AtomicUsize::new(0);

/// Number of parallel regions dispatched to the pool so far.
pub fn pool_dispatch_count() -> usize {
    DISPATCHES.load(Ordering::Relaxed)
}

fn pool() -> &'static Pool {
    POOL.get_or_init(|| {
        let p: &'static Pool = Box::leak(Box::new(Pool {
            state: Mutex::new(PoolState { job: None, epoch: 0 }),
            wake: Condvar::new(),
        }));
        let workers = configured_threads().saturating_sub(1);
        for w in 0..workers {
            std::thread::Builder::new()
                .name(format!("uspec-par-{w}"))
                .spawn(move || worker_loop(p))
                .expect("par: failed to spawn pool worker");
        }
        p
    })
}

fn worker_loop(pool: &'static Pool) {
    // Everything a worker runs is already inside a region: nested parallel
    // calls from tasks must execute inline.
    IN_REGION.with(|f| f.set(true));
    let mut seen = 0u64;
    loop {
        let job = {
            let mut st = pool.state.lock().unwrap();
            loop {
                if st.epoch != seen {
                    seen = st.epoch;
                    break st.job.clone();
                }
                st = pool.wake.wait(st).unwrap();
            }
        };
        if let Some(job) = job {
            if job.helpers.fetch_sub(1, Ordering::Relaxed) > 0 {
                run_chunks(&job);
            }
        }
    }
}

/// Claim and execute chunks until the cursor is exhausted.
fn run_chunks(job: &Job) {
    loop {
        let i = job.cursor.fetch_add(1, Ordering::Relaxed);
        if i >= job.nchunks {
            return;
        }
        // SAFETY: chunk `i` was claimed, so the dispatcher is still blocked
        // waiting for it — the closure behind `task` is alive.
        let task = unsafe { &*job.task };
        if std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| task(i))).is_err() {
            job.panicked.store(true, Ordering::Relaxed);
        }
        // AcqRel: publishes this chunk's writes to the dispatcher's final
        // Acquire load of `done`.
        let done = job.done.fetch_add(1, Ordering::AcqRel) + 1;
        if done == job.nchunks {
            let _g = job.done_mx.lock().unwrap();
            job.done_cv.notify_all();
        }
    }
}

/// Run `task(chunk_id)` for every `chunk_id in 0..nchunks` across the pool,
/// participating from the calling thread. Blocks until all chunks finished.
fn dispatch(nchunks: usize, nt: usize, task: &(dyn Fn(usize) + Sync)) {
    debug_assert!(nchunks >= 1 && nt >= 2);
    // Erase the caller's lifetime; see `Job` for the validity argument.
    let task: *const (dyn Fn(usize) + Sync) = unsafe {
        std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(task)
    };
    let job = Arc::new(Job {
        task,
        nchunks,
        cursor: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        helpers: AtomicIsize::new(nt as isize - 1),
        panicked: AtomicBool::new(false),
        done_mx: Mutex::new(()),
        done_cv: Condvar::new(),
    });
    DISPATCHES.fetch_add(1, Ordering::Relaxed);
    let pl = pool();
    {
        let mut st = pl.state.lock().unwrap();
        st.job = Some(job.clone());
        st.epoch = st.epoch.wrapping_add(1);
        pl.wake.notify_all();
    }
    // Participate; nested calls made by `task` on this thread run inline.
    {
        let _guard = RegionGuard::enter();
        run_chunks(&job);
    }
    // Wait for straggler chunks still running on workers.
    {
        let mut g = job.done_mx.lock().unwrap();
        while job.done.load(Ordering::Acquire) < job.nchunks {
            g = job.done_cv.wait(g).unwrap();
        }
    }
    // Drop the broadcast slot so the erased closure pointer cannot be
    // observed past this call (unless a newer dispatch already replaced it).
    {
        let mut st = pl.state.lock().unwrap();
        if let Some(cur) = &st.job {
            if Arc::ptr_eq(cur, &job) {
                st.job = None;
            }
        }
    }
    if job.panicked.load(Ordering::Relaxed) {
        panic!("par: parallel task panicked");
    }
}

/// Raw-pointer wrapper so disjoint-range writers can share a base pointer
/// across threads. Crate-visible: the sharded KNR walk
/// (`crate::pipeline`) uses it to land per-shard rows in their global
/// row slots under the same disjoint-range protocol.
pub(crate) struct SendPtr<T>(pub(crate) *mut T);

impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        SendPtr(self.0)
    }
}
impl<T> Copy for SendPtr<T> {}

// SAFETY: used only for writes to provably disjoint index ranges while the
// owning allocation outlives the dispatch.
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Map `f` over `0..n` in parallel, collecting results in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let nt = num_threads().min(n.max(1));
    if nt <= 1 || n < 2 || in_region() {
        return (0..n).map(f).collect();
    }
    let chunk_len = n.div_ceil(nt * OVERSUB).max(1);
    let nchunks = n.div_ceil(chunk_len);
    let mut out: Vec<std::mem::MaybeUninit<T>> = Vec::with_capacity(n);
    // SAFETY: MaybeUninit slots need no initialization; every slot is
    // written exactly once by the disjoint chunk ranges below.
    unsafe { out.set_len(n) };
    let ptr = SendPtr(out.as_mut_ptr());
    dispatch(nchunks, nt, &move |ci: usize| {
        let lo = ci * chunk_len;
        let hi = (lo + chunk_len).min(n);
        for i in lo..hi {
            // SAFETY: disjoint ranges; `out` outlives the blocking dispatch.
            unsafe {
                (*ptr.0.add(i)).write(f(i));
            }
        }
    });
    // SAFETY: dispatch returned without panicking, so all `n` slots are
    // initialized. (On panic the MaybeUninit vec is dropped instead, which
    // frees the buffer without running destructors — leaks, never UB.)
    unsafe {
        let mut out = std::mem::ManuallyDrop::new(out);
        Vec::from_raw_parts(out.as_mut_ptr() as *mut T, n, out.capacity())
    }
}

/// Run `f(chunk_start, chunk)` over disjoint mutable chunks of `data`
/// (each of at most `chunk_len` items) in parallel.
pub fn par_for_chunks<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let nchunks = n.div_ceil(chunk_len);
    let nt = num_threads();
    if nt <= 1 || nchunks <= 1 || in_region() {
        // Sequential path still honors the ≤chunk_len contract — callers
        // rely on it to recover (row, col) coordinates from chunk offsets.
        let mut start = 0;
        for ch in data.chunks_mut(chunk_len) {
            let len = ch.len();
            f(start, ch);
            start += len;
        }
        return;
    }
    let ptr = SendPtr(data.as_mut_ptr());
    dispatch(nchunks, nt.min(nchunks), &move |ci: usize| {
        let lo = ci * chunk_len;
        let hi = (lo + chunk_len).min(n);
        // SAFETY: chunk ranges are disjoint views into `data`, which the
        // blocked caller keeps alive.
        let ch = unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo), hi - lo) };
        f(lo, ch);
    });
}

/// Parallel reduce: `f(i)` mapped over `0..n`, combined with `combine`.
///
/// The reduction folds a **fixed bucket partition** of `0..n` (at most
/// [`REDUCE_BUCKETS`] contiguous ranges, a function of `n` only), then
/// folds the bucket results in order — so the result is bit-identical for
/// every thread count, provided `combine(identity, x) == x`.
pub fn par_reduce<T: Send + Clone, F, C>(n: usize, identity: T, f: F, combine: C) -> T
where
    F: Fn(usize) -> T + Sync,
    C: Fn(T, T) -> T + Send + Sync,
{
    if n == 0 {
        return identity;
    }
    let nbuckets = n.min(REDUCE_BUCKETS);
    let chunk = n.div_ceil(nbuckets);
    let nchunks = n.div_ceil(chunk);
    let bucket = |b: usize| -> T {
        let lo = b * chunk;
        let hi = (lo + chunk).min(n);
        let mut acc = f(lo);
        for i in lo + 1..hi {
            acc = combine(acc, f(i));
        }
        acc
    };
    let partials: Vec<T> = if num_threads() <= 1 || nchunks < 2 || in_region() {
        (0..nchunks).map(bucket).collect()
    } else {
        par_map(nchunks, bucket)
    };
    partials.into_iter().fold(identity, combine)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Serializes the tests that mutate the global thread override, and
    /// guarantees restoration even when the body panics.
    static OVERRIDE_LOCK: Mutex<()> = Mutex::new(());

    fn with_override_lock(f: impl FnOnce()) {
        let _g = OVERRIDE_LOCK.lock().unwrap_or_else(|e| e.into_inner());
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(f));
        set_thread_override(0);
        if let Err(p) = r {
            std::panic::resume_unwind(p);
        }
    }

    #[test]
    fn par_map_order() {
        let v = par_map(1000, |i| i * 2);
        assert_eq!(v.len(), 1000);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(*x, i * 2);
        }
    }

    #[test]
    fn par_map_empty_and_one() {
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
        assert_eq!(par_map(1, |i| i + 5), vec![5]);
    }

    #[test]
    fn par_map_nonclone_results() {
        // results only need Send — exercise with a non-Copy, non-Clone type
        struct NoClone(usize);
        let v = par_map(257, NoClone);
        for (i, x) in v.iter().enumerate() {
            assert_eq!(x.0, i);
        }
    }

    #[test]
    fn par_for_chunks_covers_all() {
        let mut data = vec![0usize; 10_001];
        par_for_chunks(&mut data, 128, |start, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = start + i + 1;
            }
        });
        for (i, x) in data.iter().enumerate() {
            assert_eq!(*x, i + 1);
        }
    }

    #[test]
    fn par_reduce_sum() {
        let s = par_reduce(10_000, 0u64, |i| i as u64, |a, b| a + b);
        assert_eq!(s, 9999 * 10_000 / 2);
    }

    #[test]
    fn nested_calls_run_inline() {
        // A parallel region that itself calls every primitive — must not
        // deadlock and must produce sequential-identical values.
        let v = par_map(64, |i| {
            let inner = par_map(50, move |j| (i * j) as u64);
            let s1: u64 = inner.iter().sum();
            let s2 = par_reduce(50, 0u64, |j| (i * j) as u64, |a, b| a + b);
            assert_eq!(s1, s2);
            let mut buf = vec![0u64; 40];
            par_for_chunks(&mut buf, 7, |start, ch| {
                for (o, x) in ch.iter_mut().enumerate() {
                    *x = (start + o) as u64;
                }
            });
            s1 + buf.iter().sum::<u64>()
        });
        for (i, &x) in v.iter().enumerate() {
            let expect = (0..50).map(|j| (i * j) as u64).sum::<u64>() + (0..40u64).sum::<u64>();
            assert_eq!(x, expect);
        }
    }

    #[test]
    fn reduce_is_thread_count_invariant() {
        with_override_lock(|| {
            // float sum must be bit-identical across overrides
            let f = |i: usize| ((i as f64) * 0.1).sin();
            let baseline = par_reduce(12_345, 0.0f64, f, |a, b| a + b);
            for nt in [1usize, 2, 3, 8, 64] {
                set_thread_override(nt);
                let s = par_reduce(12_345, 0.0f64, f, |a, b| a + b);
                assert_eq!(s.to_bits(), baseline.to_bits(), "nt={nt}");
            }
        });
    }

    #[test]
    fn task_panic_propagates() {
        with_override_lock(|| {
            set_thread_override(2);
            let r = std::panic::catch_unwind(|| {
                par_map(64, |i| {
                    if i == 13 {
                        panic!("boom");
                    }
                    i
                })
            });
            assert!(r.is_err(), "panic in a parallel task must propagate");
        });
    }
}
