//! Small self-contained substrates that replace unavailable third-party
//! crates (the build environment is offline; see DESIGN.md).

pub mod rng;
pub mod json;
pub mod par;
pub mod prop;
pub mod timer;

/// True when `USPEC_EIG_TRACE` was set at first use (per-iteration eigen
/// solver tracing). Read once and cached — the solvers consult this in
/// their outer loops, where a `std::env::var` lookup per iteration is
/// measurable.
pub fn eig_trace() -> bool {
    static FLAG: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FLAG.get_or_init(|| std::env::var("USPEC_EIG_TRACE").is_ok())
}

/// True when `USPEC_EIG_DEBUG` was set at first use (eigen solver
/// convergence diagnostics). Read once and cached, like [`eig_trace`].
pub fn eig_debug() -> bool {
    static FLAG: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FLAG.get_or_init(|| std::env::var("USPEC_EIG_DEBUG").is_ok())
}

/// False when `USPEC_SIMD=0` was set at first use: forces the distance
/// kernels in [`crate::linalg`] onto their scalar fallback even on CPUs
/// where a vector path was detected. Purely operational — the scalar and
/// vector kernels are bit-identical by construction (see the module docs
/// in `linalg/dense.rs`), so this knob exists for A/B timing and for the
/// CI determinism matrix, not for correctness. Read once and cached,
/// like [`eig_trace`]; tests use `linalg::set_simd_override` instead so
/// they can flip the choice after first use.
pub fn simd_allowed() -> bool {
    static FLAG: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *FLAG.get_or_init(|| std::env::var("USPEC_SIMD").map(|v| v != "0").unwrap_or(true))
}

/// Binary search into a sorted `Vec<f64>` of cumulative weights; returns the
/// first index whose cumulative weight exceeds `x`.
pub fn searchsorted(cum: &[f64], x: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = cum.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if cum[mid] <= x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo.min(cum.len().saturating_sub(1))
}

/// `argsort` by key ascending (stable).
pub fn argsort_by_f64(keys: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by(|&a, &b| keys[a].partial_cmp(&keys[b]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// Partial selection: indices of the `k` smallest keys, ascending by key.
/// O(n + k log k) via select_nth.
pub fn argmin_k(keys: &[f64], k: usize) -> Vec<usize> {
    let n = keys.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    if k < n {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            keys[a].partial_cmp(&keys[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    idx.sort_by(|&a, &b| keys[a].partial_cmp(&keys[b]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// Allocation-free [`argmin_k`] over `f32` keys: fills `out` with the
/// indices of the `min(k, keys.len())` smallest keys, ascending by key.
/// `scratch` is the working index buffer; both vectors are cleared and
/// their capacity reused, so a caller looping over rows allocates nothing
/// once warm. This is the KNR per-row hot path — it skips both the
/// per-call `Vec` of [`argmin_k`] and the f32→f64 key round-trip.
pub fn argmin_k_into(keys: &[f32], k: usize, scratch: &mut Vec<u32>, out: &mut Vec<u32>) {
    let n = keys.len();
    let k = k.min(n);
    out.clear();
    if k == 0 {
        return;
    }
    scratch.clear();
    scratch.extend(0..n as u32);
    if k < n {
        scratch.select_nth_unstable_by(k - 1, |&a, &b| {
            keys[a as usize]
                .partial_cmp(&keys[b as usize])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        scratch.truncate(k);
    }
    scratch.sort_by(|&a, &b| {
        keys[a as usize].partial_cmp(&keys[b as usize]).unwrap_or(std::cmp::Ordering::Equal)
    });
    out.extend_from_slice(scratch);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn searchsorted_basics() {
        let cum = vec![0.25, 0.5, 0.75, 1.0];
        assert_eq!(searchsorted(&cum, 0.0), 0);
        assert_eq!(searchsorted(&cum, 0.3), 1);
        assert_eq!(searchsorted(&cum, 0.74), 2);
        assert_eq!(searchsorted(&cum, 0.99), 3);
    }

    #[test]
    fn argsort_orders() {
        let keys = vec![3.0, 1.0, 2.0];
        assert_eq!(argsort_by_f64(&keys), vec![1, 2, 0]);
    }

    #[test]
    fn argmin_k_matches_full_sort() {
        let keys = vec![5.0, 1.0, 4.0, 2.0, 3.0, 0.5];
        assert_eq!(argmin_k(&keys, 3), vec![5, 1, 3]);
        assert_eq!(argmin_k(&keys, 0), Vec::<usize>::new());
        assert_eq!(argmin_k(&keys, 99), argsort_by_f64(&keys));
    }

    #[test]
    fn argmin_k_into_matches_argmin_k() {
        let mut rng = crate::util::rng::Rng::new(5);
        let mut scratch = Vec::new();
        let mut out = Vec::new();
        for _ in 0..50 {
            let n = 1 + rng.usize(40);
            let keys32: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
            let keys64: Vec<f64> = keys32.iter().map(|&v| v as f64).collect();
            for k in [0usize, 1, 3, n / 2, n, n + 7] {
                argmin_k_into(&keys32, k, &mut scratch, &mut out);
                let want = argmin_k(&keys64, k);
                assert_eq!(
                    out.iter().map(|&v| v as usize).collect::<Vec<_>>(),
                    want,
                    "n={n} k={k}"
                );
            }
        }
    }
}
