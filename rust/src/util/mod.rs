//! Small self-contained substrates that replace unavailable third-party
//! crates (the build environment is offline; see DESIGN.md).

pub mod rng;
pub mod json;
pub mod par;
pub mod prop;
pub mod timer;

/// Binary search into a sorted `Vec<f64>` of cumulative weights; returns the
/// first index whose cumulative weight exceeds `x`.
pub fn searchsorted(cum: &[f64], x: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = cum.len();
    while lo < hi {
        let mid = (lo + hi) / 2;
        if cum[mid] <= x {
            lo = mid + 1;
        } else {
            hi = mid;
        }
    }
    lo.min(cum.len().saturating_sub(1))
}

/// `argsort` by key ascending (stable).
pub fn argsort_by_f64(keys: &[f64]) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..keys.len()).collect();
    idx.sort_by(|&a, &b| keys[a].partial_cmp(&keys[b]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

/// Partial selection: indices of the `k` smallest keys, ascending by key.
/// O(n + k log k) via select_nth.
pub fn argmin_k(keys: &[f64], k: usize) -> Vec<usize> {
    let n = keys.len();
    let k = k.min(n);
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..n).collect();
    if k < n {
        idx.select_nth_unstable_by(k - 1, |&a, &b| {
            keys[a].partial_cmp(&keys[b]).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(k);
    }
    idx.sort_by(|&a, &b| keys[a].partial_cmp(&keys[b]).unwrap_or(std::cmp::Ordering::Equal));
    idx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn searchsorted_basics() {
        let cum = vec![0.25, 0.5, 0.75, 1.0];
        assert_eq!(searchsorted(&cum, 0.0), 0);
        assert_eq!(searchsorted(&cum, 0.3), 1);
        assert_eq!(searchsorted(&cum, 0.74), 2);
        assert_eq!(searchsorted(&cum, 0.99), 3);
    }

    #[test]
    fn argsort_orders() {
        let keys = vec![3.0, 1.0, 2.0];
        assert_eq!(argsort_by_f64(&keys), vec![1, 2, 0]);
    }

    #[test]
    fn argmin_k_matches_full_sort() {
        let keys = vec![5.0, 1.0, 4.0, 2.0, 3.0, 0.5];
        assert_eq!(argmin_k(&keys, 3), vec![5, 1, 3]);
        assert_eq!(argmin_k(&keys, 0), Vec::<usize>::new());
        assert_eq!(argmin_k(&keys, 99), argsort_by_f64(&keys));
    }
}
