//! Lightweight phase timing used by the pipelines and the bench harness.

use std::time::Instant;

/// Accumulates named phase durations (seconds).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    pub phases: Vec<(String, f64)>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure and record it under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.phases.push((name.to_string(), t0.elapsed().as_secs_f64()));
        out
    }

    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, t)| t).sum()
    }

    pub fn get(&self, name: &str) -> f64 {
        self.phases.iter().filter(|(n, _)| n == name).map(|(_, t)| t).sum()
    }

    pub fn merge(&mut self, other: &PhaseTimer) {
        for (n, t) in &other.phases {
            self.phases.push((n.clone(), *t));
        }
    }

    pub fn summary(&self) -> String {
        let mut s = String::new();
        for (n, t) in &self.phases {
            s.push_str(&format!("{n}: {t:.4}s  "));
        }
        s.push_str(&format!("| total {:.4}s", self.total()));
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_phases() {
        let mut t = PhaseTimer::new();
        let v = t.time("a", || 42);
        assert_eq!(v, 42);
        t.time("b", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert_eq!(t.phases.len(), 2);
        assert!(t.get("b") >= 0.002);
        assert!(t.total() >= t.get("b"));
        assert!(t.summary().contains("total"));
    }
}
