//! Minimal randomized property-testing helper (proptest replacement).
//!
//! `run_prop(cases, seed, |rng| ...)` executes `cases` randomized trials,
//! each receiving a forked deterministic RNG. On failure it retries the
//! failing case with progressively simpler "sizes" when the property
//! supports a size hint, and always reports the case seed so the exact
//! failure replays with `run_seeded`.

use super::rng::Rng;

/// Run a randomized property `cases` times. The closure returns
/// `Err(message)` to signal a violation.
pub fn run_prop<F>(name: &str, cases: usize, seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut master = Rng::new(seed);
    for case in 0..cases {
        let case_seed = master.next_u64();
        let mut rng = Rng::new(case_seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} (replay seed {case_seed:#x}): {msg}"
            );
        }
    }
}

/// Replay a single case by seed (for debugging a reported failure).
pub fn run_seeded<F>(name: &str, case_seed: u64, f: F)
where
    F: Fn(&mut Rng) -> Result<(), String>,
{
    let mut rng = Rng::new(case_seed);
    if let Err(msg) = f(&mut rng) {
        panic!("property '{name}' failed (seed {case_seed:#x}): {msg}");
    }
}

/// Assert helper for property closures.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($msg:tt)*) => {
        if !($cond) {
            return Err(format!($($msg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial() {
        run_prop("trivial", 50, 1, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("out of range {x}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn reports_failure() {
        run_prop("fails", 50, 2, |rng| {
            if rng.f64() < 0.9 {
                Ok(())
            } else {
                Err("too big".into())
            }
        });
    }
}
