//! Minimal JSON value model + parser + serializer (serde replacement).
//!
//! Used for the artifact manifest (`artifacts/manifest.json`), run configs,
//! and machine-readable benchmark output. Supports the full JSON grammar
//! except for `\u` surrogate pairs outside the BMP (sufficient for our
//! ASCII-only artifacts).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// `obj["a"]["b"]`-style access.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.i += 1;
                let mut arr = Vec::new();
                self.skip_ws();
                if self.peek() == Some(b']') {
                    self.i += 1;
                    return Ok(Json::Arr(arr));
                }
                loop {
                    arr.push(self.value()?);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.i += 1;
                        }
                        Some(b']') => {
                            self.i += 1;
                            return Ok(Json::Arr(arr));
                        }
                        _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
                    }
                }
            }
            Some(b'{') => {
                self.i += 1;
                let mut obj = BTreeMap::new();
                self.skip_ws();
                if self.peek() == Some(b'}') {
                    self.i += 1;
                    return Ok(Json::Obj(obj));
                }
                loop {
                    self.skip_ws();
                    let k = self.string()?;
                    self.skip_ws();
                    self.expect(b':')?;
                    let v = self.value()?;
                    obj.insert(k, v);
                    self.skip_ws();
                    match self.peek() {
                        Some(b',') => {
                            self.i += 1;
                        }
                        Some(b'}') => {
                            self.i += 1;
                            return Ok(Json::Obj(obj));
                        }
                        _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy UTF-8 sequence verbatim
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let src = r#"{"a": 1, "b": [true, null, -2.5e3], "c": {"d": "x\n\"y\""}, "e": "ünïcode"}"#;
        let v = Json::parse(src).unwrap();
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
        assert_eq!(v.get("a").unwrap().as_usize(), Some(1));
        assert_eq!(v.get("b").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("x\n\"y\""));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn numbers() {
        assert_eq!(Json::parse("-0.5").unwrap().as_f64(), Some(-0.5));
        assert_eq!(Json::parse("1e-3").unwrap().as_f64(), Some(1e-3));
        let big = Json::Num(123456789.0).to_string();
        assert_eq!(big, "123456789");
    }
}
