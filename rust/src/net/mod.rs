//! Remote shard execution — the networking subsystem that lets any
//! [`crate::pipeline::DataSource`] live on another machine.
//!
//! Six pieces:
//!
//! * [`proto`] — the `USPEC/1` / `USPEC/2` wire protocol: versioned,
//!   length-framed, checksummed binary messages. Frame layout (all
//!   little-endian): 1 version byte ([`proto::PROTO_VERSION`], or
//!   [`proto::PROTO_V2`] on frames only a v2 peer can decode), 1 opcode
//!   byte, a u32 payload length, the payload, and a trailing u32 FNV-1a
//!   checksum over header + payload. Requests are `Ping`, `Meta`, and
//!   `ReadRows{start, len[, flags]}`; plain row responses carry raw
//!   little-endian f32 values in the `BinDataset` layout, so a served
//!   chunk is bit-exactly the local read.
//! * [`codec`] — the `USPEC/2` lossless row compression (byte-shuffled
//!   f32 planes + run-length coding, no dependencies): `OP_ROWS_C`
//!   payloads decode bit-exactly or fail typed.
//! * [`cache`] — one bounded-byte LRU used on both ends of the wire:
//!   decoded chunks in the client, encoded frames in the server.
//! * [`ShardServer`] (`repro serve-shard --data f.bin --addr host:port
//!   [--cache BYTES]`) — serves row ranges of a shared source to
//!   concurrent clients, thread-per-connection.
//! * [`RemoteSource`] — a `DataSource` whose `read_rows` is a pipelined
//!   framed exchange on a pooled TCP connection (up to
//!   [`client::PIPELINE_DEPTH`] sub-requests in flight), with
//!   connect/read timeouts and bounded retry-with-backoff. Its
//!   [`storage_hint`](crate::pipeline::DataSource::storage_hint) reports
//!   [`crate::pipeline::StorageProfile::Remote`], so the adaptive walk
//!   planner schedules remote shards as a high-latency serial-ish
//!   backend: few walkers, deep prefetch.
//! * [`serve`] (`repro serve --addr host:port --models-dir DIR
//!   [--queue N]`) — the clustering-as-a-service job manager: `USPEC/2`
//!   serve opcodes (`SubmitFit` 0x10, `JobStatus` 0x11, `Assign` 0x12,
//!   `ListModels` 0x13) over the same framing, a bounded fit-job queue
//!   drained by one worker, and concurrent out-of-sample assignment
//!   from an in-memory model registry persisted as
//!   [`crate::runtime::model`] artifacts under `--models-dir`.
//!
//! # `USPEC/2` negotiation and fallback rules
//!
//! `USPEC/2` adds exactly one wire feature — compressed row frames — and
//! is negotiated so that every v1 ↔ v2 pairing works unchanged:
//!
//! 1. **Advertise.** At connect, the client sends `Ping` whose payload
//!    carries its capability bytes (`[0x02]`); the server's `Pong`
//!    payload carries its own. A v1 peer sends an empty payload and
//!    ignores whatever it receives — Ping/Pong payloads were always
//!    tolerated, never interpreted, under `USPEC/1`.
//! 2. **Request.** Only after seeing `0x02` in the Pong (and with
//!    compression enabled — `USPEC_NET_COMPRESS` not `0` and
//!    [`NetOpts::compress`] true) does the client append the flags byte
//!    to `ReadRows` (`FLAG_COMPRESS`). Against a v1 server the 16-byte
//!    request form is used forever — the 17-byte form would be rejected
//!    as malformed.
//! 3. **Respond.** A flagged request is answered with `OP_ROWS_C` (a
//!    [`proto::PROTO_V2`]-stamped frame, [`codec`] payload) **iff** the
//!    encoding is strictly smaller than the raw rows; otherwise the
//!    plain `OP_ROWS` frame is sent — incompressible data never costs
//!    extra bytes. Unflagged requests always get plain `OP_ROWS`, so a
//!    v1 client never receives a frame it cannot decode.
//! 4. **Checksums are unchanged.** Compressed frames carry the same
//!    FNV-1a trailer over header + payload; a corrupt or truncated
//!    compressed stream is rejected typed ([`crate::Error::Net`], the
//!    retryable class) either by the trailer or by the codec's own
//!    token/length validation.
//!
//! The contract this module must keep is the crate's standing
//! invariant: **where a shard lives — and how its bytes travel — is
//! operational, never semantic**. Labels, sigma, and the embedding are
//! bit-identical whether a shard is resident, on disk, or served over a
//! socket, with compression and chunk caches on or off
//! (`rust/tests/sharded_equivalence.rs` pins loopback legs across
//! {all-local, mixed, all-remote} × {compress on/off} × {cache on/off} ×
//! thread counts), and a failing remote read either recovers via retry
//! or aborts the walk with a typed error — never a hang (every socket
//! carries a deadline) and never a silently partial result (frames are
//! size-validated and checksummed).
//!
//! Env knobs (crate docs list all of them): `USPEC_NET_TIMEOUT_MS`
//! bounds connects and socket reads/writes (default 5000);
//! `USPEC_NET_RETRIES` caps transient-failure retries (default 3);
//! `USPEC_NET_COMPRESS=0` forces plain `USPEC/1` frames everywhere;
//! `USPEC_NET_POOL` caps idle pooled connections per source (default 8);
//! `USPEC_NET_IDLE_MS` is the server's per-connection idle timeout
//! (default 60000).

pub mod cache;
pub mod client;
pub mod codec;
pub mod proto;
pub mod serve;
pub mod server;

pub use cache::ByteLru;
pub use client::{NetOpts, RemoteSource};
pub use serve::{JobReport, JobState, ModelInfo, ServeClient, ServeConfig, ServeRuntime};
pub use server::{ServeOpts, ShardServer};

use crate::{ensure_arg, Error, Result};
use std::sync::OnceLock;

/// `USPEC_NET_TIMEOUT_MS` (read once): connect/read/write deadline in
/// milliseconds for remote sources. Default 5000.
pub fn net_timeout_ms() -> u64 {
    static V: OnceLock<u64> = OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("USPEC_NET_TIMEOUT_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(5000)
    })
}

/// `USPEC_NET_RETRIES` (read once): transient-failure retries after the
/// first attempt. Default 3.
pub fn net_retries() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("USPEC_NET_RETRIES").ok().and_then(|v| v.parse().ok()).unwrap_or(3)
    })
}

/// `USPEC_NET_COMPRESS` (read once): `0` forces plain `USPEC/1` frames —
/// servers stop advertising v2, clients stop requesting compressed rows.
/// Anything else (including unset) leaves compression negotiable.
/// Purely operational: compression is lossless, so this knob never
/// changes a label, only bytes on the wire.
pub fn net_compress() -> bool {
    static V: OnceLock<bool> = OnceLock::new();
    *V.get_or_init(|| std::env::var("USPEC_NET_COMPRESS").map(|v| v != "0").unwrap_or(true))
}

/// `USPEC_NET_POOL` (read once): idle connections kept for reuse per
/// [`RemoteSource`]; walkers + prefetch readers rarely need more, and a
/// burst beyond the cap just dials. Default 8, floor 1.
pub fn net_pool() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("USPEC_NET_POOL")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(8usize)
            .max(1)
    })
}

/// `USPEC_NET_IDLE_MS` (read once): the server drops a connection with
/// no complete request inside this window, so an abandoned client can
/// never pin a handler thread forever. Default 60000.
pub fn net_idle_ms() -> u64 {
    static V: OnceLock<u64> = OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("USPEC_NET_IDLE_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(60_000)
    })
}

/// Validate a `host:port` string (the spelling `serve-shard --addr` and
/// `remote://` sources use). Port 0 is allowed — it means "ephemeral"
/// for a server bind (a client connect to port 0 fails at dial time with
/// its own clear error).
pub fn validate_host_port(s: &str) -> Result<()> {
    let (host, port) = s
        .rsplit_once(':')
        .ok_or_else(|| Error::InvalidArg(format!("'{s}': want host:port")))?;
    ensure_arg!(!host.is_empty(), "'{s}': empty host (want host:port)");
    ensure_arg!(
        port.parse::<u16>().is_ok(),
        "'{s}': bad port '{port}' (want 0..=65535)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::pipeline::{for_each_chunk_sharded, DataSource, ShardPlan, StorageProfile};
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    /// A deterministic matrix whose every cell is unique — any
    /// misplaced row or byte shows up as a bit mismatch.
    fn test_mat(n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.set(i, j, (i * d + j) as f32 * 0.5 - 3.0);
            }
        }
        m
    }

    fn serve(x: Mat) -> ShardServer {
        ShardServer::bind("127.0.0.1:0", Arc::new(x)).unwrap()
    }

    fn fast_opts(retries: usize) -> NetOpts {
        NetOpts {
            connect_timeout: Duration::from_millis(2000),
            io_timeout: Duration::from_millis(2000),
            retries,
            backoff: Duration::from_millis(1),
            ..NetOpts::default()
        }
    }

    fn bits(m: &Mat) -> Vec<u32> {
        m.data.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn remote_reads_match_local_bit_exactly() {
        let x = test_mat(97, 3);
        let server = serve(x.clone());
        let remote = RemoteSource::connect(&server.addr().to_string()).unwrap();
        assert_eq!((remote.n(), remote.d()), (97, 3));
        assert!(remote.ping().unwrap() < Duration::from_secs(5));
        let mut got = Mat::zeros(0, 3);
        let mut want = Mat::zeros(0, 3);
        // several ranges over one source: exercises pool reuse too
        for (start, len) in [(0usize, 97usize), (0, 1), (96, 1), (40, 17), (95, 2)] {
            remote.read_rows(start, len, &mut got).unwrap();
            x.read_rows(start, len, &mut want).unwrap();
            assert_eq!((got.rows, got.cols), (len, 3), "[{start}, {}) shape", start + len);
            let a: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "[{start}, {}) bytes", start + len);
        }
        // the planner hint: remote is a high-latency serial-ish backend
        assert_eq!(remote.storage_hint(), Some(StorageProfile::Remote));
    }

    #[test]
    fn out_of_range_requests_are_typed_errors_client_and_server_side() {
        use super::proto::{encode_read_rows, read_frame, write_frame, OP_ERR, OP_READ_ROWS};
        use std::net::TcpStream;

        let server = serve(test_mat(10, 2));
        let remote = RemoteSource::connect(&server.addr().to_string()).unwrap();
        // client-side: rejected before any network traffic
        let mut buf = Mat::zeros(0, 2);
        let err = remote.read_rows(8, 5, &mut buf).unwrap_err();
        assert!(matches!(err, Error::InvalidArg(_)), "{err}");
        // server-side: a raw socket can send what the client never would;
        // the answer is an OP_ERR frame, not a dropped connection
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write_frame(&mut conn, OP_READ_ROWS, &encode_read_rows(8, 5)).unwrap();
        let (op, payload) = read_frame(&mut conn, 1 << 16).unwrap();
        assert_eq!(op, OP_ERR);
        let msg = String::from_utf8_lossy(&payload).to_string();
        assert!(msg.contains("out of range"), "{msg}");
        // unknown opcodes are answered, not ignored
        write_frame(&mut conn, 0x55, &[]).unwrap();
        let (op, payload) = read_frame(&mut conn, 1 << 16).unwrap();
        assert_eq!(op, OP_ERR);
        assert!(String::from_utf8_lossy(&payload).contains("opcode"));
    }

    #[test]
    fn malformed_addresses_are_rejected() {
        assert!(validate_host_port("localhost:9000").is_ok());
        assert!(validate_host_port("127.0.0.1:0").is_ok()); // ephemeral bind
        for bad in ["nohost", ":123", "host:", "host:notaport", "host:99999"] {
            let err = validate_host_port(bad).unwrap_err();
            assert!(matches!(err, Error::InvalidArg(_)), "{bad}: {err}");
            assert!(RemoteSource::connect(bad).is_err(), "{bad} must not connect");
        }
    }

    #[test]
    fn unreachable_endpoint_fails_fast_with_typed_error() {
        // bind-then-drop: the port existed a moment ago, nobody listens now
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t = std::time::Instant::now();
        let err = RemoteSource::connect_with(&addr, fast_opts(1)).unwrap_err();
        assert!(matches!(err, Error::Net(_)), "{err}");
        assert!(err.to_string().contains("attempts"), "{err}");
        // 2 attempts × (fast refusal + 1ms backoff) — well inside the bound
        assert!(t.elapsed() < Duration::from_secs(30), "took {:?}", t.elapsed());
    }

    #[test]
    fn mid_stream_disconnect_recovers_via_retry() {
        let x = test_mat(64, 2);
        let server = ShardServer::bind_with(
            "127.0.0.1:0",
            Arc::new(x.clone()),
            ServeOpts { fail_reads: 2, ..ServeOpts::default() },
        )
        .unwrap();
        let remote = RemoteSource::connect_with(&server.addr().to_string(), fast_opts(3)).unwrap();
        // first read eats both injected failures (truncated frame + abrupt
        // disconnect), then succeeds on a fresh connection — bit-exactly
        let mut got = Mat::zeros(0, 2);
        remote.read_rows(0, 64, &mut got).unwrap();
        let a: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "recovered read must be bit-identical");
        // subsequent reads see a healthy server
        remote.read_rows(10, 5, &mut got).unwrap();
        assert_eq!(got.rows, 5);
    }

    /// A from-scratch `USPEC/1` endpoint, byte-compatible with the PR-6
    /// server: empty Pongs, 16-byte-only ReadRows, plain `OP_ROWS`. The
    /// downgrade tests run a real client against it.
    fn legacy_v1_server(x: Mat) -> (std::net::SocketAddr, Arc<std::sync::atomic::AtomicBool>) {
        use super::proto::{
            encode_meta, encode_rows, read_frame, write_frame, OP_ERR, OP_META, OP_META_RESP,
            OP_PING, OP_PONG, OP_READ_ROWS, OP_ROWS,
        };
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop2.load(std::sync::atomic::Ordering::Relaxed) {
                    break;
                }
                let Ok(mut conn) = conn else { continue };
                let x = x.clone();
                std::thread::spawn(move || {
                    conn.set_read_timeout(Some(Duration::from_secs(10))).ok();
                    loop {
                        let Ok((op, payload)) = read_frame(&mut conn, 64) else { return };
                        let ok = match op {
                            // a v1 server never advertises capabilities
                            OP_PING => write_frame(&mut conn, OP_PONG, &[]).is_ok(),
                            OP_META => write_frame(
                                &mut conn,
                                OP_META_RESP,
                                &encode_meta(x.rows as u64, x.cols as u64),
                            )
                            .is_ok(),
                            OP_READ_ROWS => {
                                // strict v1: exactly 16 bytes or malformed
                                if payload.len() != 16 {
                                    write_frame(&mut conn, OP_ERR, b"ReadRows payload: want 16")
                                        .is_ok()
                                } else {
                                    let start = u64::from_le_bytes(
                                        payload[..8].try_into().unwrap(),
                                    ) as usize;
                                    let len = u64::from_le_bytes(
                                        payload[8..].try_into().unwrap(),
                                    ) as usize;
                                    let mut buf = Mat::zeros(0, x.cols);
                                    match x.read_rows(start, len, &mut buf) {
                                        Ok(()) => write_frame(
                                            &mut conn,
                                            OP_ROWS,
                                            &encode_rows(&buf),
                                        )
                                        .is_ok(),
                                        Err(e) => write_frame(
                                            &mut conn,
                                            OP_ERR,
                                            e.to_string().as_bytes(),
                                        )
                                        .is_ok(),
                                    }
                                }
                            }
                            _ => write_frame(&mut conn, OP_ERR, b"unknown opcode").is_ok(),
                        };
                        if !ok {
                            return;
                        }
                    }
                });
            }
        });
        (addr, stop)
    }

    #[test]
    fn v2_client_downgrades_against_a_legacy_v1_server() {
        let x = test_mat(61, 3);
        let (addr, stop) = legacy_v1_server(x.clone());
        // compression explicitly requested — the empty Pong must veto it
        let opts = NetOpts { compress: true, ..fast_opts(1) };
        let remote = RemoteSource::connect_with(&addr.to_string(), opts).unwrap();
        assert!(!remote.peer_v2(), "legacy server must not negotiate v2");
        let mut got = Mat::zeros(0, 3);
        let mut want = Mat::zeros(0, 3);
        for (start, len) in [(0usize, 61usize), (0, 1), (30, 17)] {
            remote.read_rows(start, len, &mut got).unwrap();
            x.read_rows(start, len, &mut want).unwrap();
            assert_eq!(bits(&got), bits(&want), "[{start}, {})", start + len);
        }
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
        let _ = std::net::TcpStream::connect_timeout(&addr, Duration::from_millis(250));
    }

    #[test]
    fn v1_client_against_a_v2_server_gets_plain_frames() {
        use super::proto::{
            encode_read_rows, read_frame, write_frame, OP_PING, OP_PONG, OP_READ_ROWS, OP_ROWS,
            PROTO_VERSION,
        };
        use std::net::TcpStream;

        let x = test_mat(24, 2);
        let server = ShardServer::bind_with(
            "127.0.0.1:0",
            Arc::new(x.clone()),
            ServeOpts { compress: true, ..ServeOpts::default() },
        )
        .unwrap();
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        // a v1 client pings with an empty payload and ignores Pong caps
        write_frame(&mut conn, OP_PING, &[]).unwrap();
        let (op, _caps) = read_frame(&mut conn, 64).unwrap();
        assert_eq!(op, OP_PONG);
        // an unflagged 16-byte ReadRows must get a plain v1 OP_ROWS frame
        write_frame(&mut conn, OP_READ_ROWS, &encode_read_rows(3, 7)).unwrap();
        // peek the version byte by reading the raw frame ourselves
        let mut raw = vec![0u8; 6];
        std::io::Read::read_exact(&mut conn, &mut raw).unwrap();
        assert_eq!(raw[0], PROTO_VERSION, "v1 client must never see a v2 frame");
        assert_eq!(raw[1], OP_ROWS, "unflagged request must get plain rows");
        let len = u32::from_le_bytes(raw[2..6].try_into().unwrap()) as usize;
        assert_eq!(len, 7 * 2 * 4, "plain payload is raw f32 bytes");
    }

    #[test]
    fn compressed_loopback_reads_are_bit_identical() {
        // sparse rows (two active dims, exact zeros elsewhere → long
        // byte runs after the shuffle) so OP_ROWS_C actually fires;
        // fallbacks to plain frames would pass equality too, but
        // peer_v2 + the codec unit tests pin the compressed path
        let mut x = Mat::zeros(300, 16);
        for i in 0..300 {
            let off = (i % 2) * 2;
            x.set(i, off, 1.5 + (i % 7) as f32 * 1e-4);
            x.set(i, off + 1, -0.75 + (i % 5) as f32 * 1e-4);
        }
        let server = ShardServer::bind_with(
            "127.0.0.1:0",
            Arc::new(x.clone()),
            ServeOpts { compress: true, ..ServeOpts::default() },
        )
        .unwrap();
        let opts = NetOpts { compress: true, ..fast_opts(1) };
        let remote = RemoteSource::connect_with(&server.addr().to_string(), opts).unwrap();
        assert!(remote.peer_v2(), "server must advertise USPEC/2");
        let mut got = Mat::zeros(0, 16);
        let mut want = Mat::zeros(0, 16);
        for (start, len) in [(0usize, 300usize), (0, 1), (299, 1), (140, 33), (0, 5)] {
            remote.read_rows(start, len, &mut got).unwrap();
            x.read_rows(start, len, &mut want).unwrap();
            assert_eq!(bits(&got), bits(&want), "[{start}, {})", start + len);
        }
    }

    #[test]
    fn client_cache_hit_never_touches_the_socket() {
        // wire-read counter on the serving side: with the server's own
        // frame cache off, every frame that crosses the socket is one
        // source read — so a flat count proves the repeat read stayed
        // entirely inside the client's decoded-chunk LRU
        let counting =
            Arc::new(CountingSource { x: test_mat(128, 3), reads: Default::default() });
        let server = ShardServer::bind_with(
            "127.0.0.1:0",
            Arc::clone(&counting) as Arc<dyn DataSource + Send + Sync>,
            ServeOpts::default(),
        )
        .unwrap();
        let opts = NetOpts { cache_bytes: 1 << 20, ..fast_opts(0) };
        let remote = RemoteSource::connect_with(&server.addr().to_string(), opts).unwrap();
        let mut first = Mat::zeros(0, 3);
        remote.read_rows(16, 64, &mut first).unwrap();
        let wire_reads = counting.reads.load(std::sync::atomic::Ordering::Relaxed);
        assert!(wire_reads >= 1);
        let mut again = Mat::zeros(0, 3);
        remote.read_rows(16, 64, &mut again).unwrap();
        assert_eq!(bits(&first), bits(&again), "cached chunk is the decoded original");
        assert_eq!(
            counting.reads.load(std::sync::atomic::Ordering::Relaxed),
            wire_reads,
            "a cache hit must not touch the socket"
        );
        let (hits, misses) = remote.cache_stats();
        assert!(hits >= 1 && misses >= 1, "hits={hits} misses={misses}");
        // a different range is a miss and goes back to the wire
        remote.read_rows(0, 8, &mut again).unwrap();
        assert!(
            counting.reads.load(std::sync::atomic::Ordering::Relaxed) > wire_reads,
            "an uncached range must reach the server"
        );
    }

    /// A source that counts `read_rows` calls — the wire-read counter
    /// behind the client-cache and server-frame-cache tests.
    struct CountingSource {
        x: Mat,
        reads: std::sync::atomic::AtomicUsize,
    }

    impl DataSource for CountingSource {
        fn n(&self) -> usize {
            self.x.rows
        }
        fn d(&self) -> usize {
            self.x.cols
        }
        fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> crate::Result<()> {
            self.reads.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.x.read_rows(start, len, buf)
        }
    }

    #[test]
    fn server_frame_cache_reuses_one_encode_across_clients() {
        let counting =
            Arc::new(CountingSource { x: test_mat(96, 2), reads: Default::default() });
        let server = ShardServer::bind_with(
            "127.0.0.1:0",
            Arc::clone(&counting) as Arc<dyn DataSource + Send + Sync>,
            ServeOpts { cache_bytes: 1 << 20, ..ServeOpts::default() },
        )
        .unwrap();
        let addr = server.addr().to_string();
        let mut buf = Mat::zeros(0, 2);
        let a = RemoteSource::connect_with(&addr, fast_opts(0)).unwrap();
        a.read_rows(0, 96, &mut buf).unwrap();
        let after_first = counting.reads.load(std::sync::atomic::Ordering::Relaxed);
        assert!(after_first >= 1);
        // a second client asking for the same chunk grid: every sub-range
        // frame comes out of the server's LRU — zero new source reads
        let b = RemoteSource::connect_with(&addr, fast_opts(0)).unwrap();
        b.read_rows(0, 96, &mut buf).unwrap();
        let after_second = counting.reads.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(after_first, after_second, "second client must hit the frame cache");
        let bits_b = bits(&buf);
        let mut want = Mat::zeros(0, 2);
        counting.x.read_rows(0, 96, &mut want).unwrap();
        assert_eq!(bits_b, bits(&want), "cached frames decode bit-identically");
    }

    #[test]
    fn exhausted_retries_surface_typed_error_and_abort_the_walk() {
        let x = test_mat(80, 2);
        let always_failing = ServeOpts { fail_reads: usize::MAX, ..ServeOpts::default() };
        let server = ShardServer::bind_with("127.0.0.1:0", Arc::new(x), always_failing).unwrap();
        let remote = RemoteSource::connect_with(&server.addr().to_string(), fast_opts(1)).unwrap();
        // direct read: a typed Net error naming the retry budget
        let mut buf = Mat::zeros(0, 2);
        let err = remote.read_rows(0, 10, &mut buf).unwrap_err();
        assert!(matches!(err, Error::Net(_)), "{err}");
        assert!(err.to_string().contains("2 attempts"), "{err}");
        // through the sharded walk: the first failing shard aborts the
        // whole pass via the existing first-error-wins path — it returns
        // (no hang) and returns Err (no silently partial result)
        let plan = ShardPlan::new(80, 2).unwrap();
        let delivered = Mutex::new(0usize);
        let r = for_each_chunk_sharded(&remote, &plan, 16, |_, m| {
            *delivered.lock().unwrap() += m.rows;
            Ok(())
        });
        assert!(r.is_err(), "walk over a dead remote must fail, not hang");
    }
}
