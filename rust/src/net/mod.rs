//! Remote shard execution — the networking subsystem that lets any
//! [`crate::pipeline::DataSource`] live on another machine.
//!
//! Three pieces:
//!
//! * [`proto`] — the `USPEC/1` wire protocol: versioned, length-framed,
//!   checksummed binary messages. Frame layout (all little-endian):
//!   1 version byte ([`proto::PROTO_VERSION`]), 1 opcode byte, a u32
//!   payload length, the payload, and a trailing u32 FNV-1a checksum
//!   over header + payload. Requests are `Ping`, `Meta`, and
//!   `ReadRows{start, len}`; row responses carry raw little-endian f32
//!   values in the `BinDataset` layout, so a served chunk is bit-exactly
//!   the local read.
//! * [`ShardServer`] (`repro serve-shard --data f.bin --addr host:port`)
//!   — serves row ranges of a shared source to concurrent clients,
//!   thread-per-connection.
//! * [`RemoteSource`] — a `DataSource` whose `read_rows` is a framed
//!   request on a pooled TCP connection, with connect/read timeouts and
//!   bounded retry-with-backoff. Its
//!   [`storage_hint`](crate::pipeline::DataSource::storage_hint) reports
//!   [`crate::pipeline::StorageProfile::Remote`], so the adaptive walk
//!   planner schedules remote shards as a high-latency serial-ish
//!   backend: few walkers, deep prefetch.
//!
//! The contract this module must keep is the crate's standing
//! invariant: **where a shard lives is operational, never semantic**.
//! Labels, sigma, and the embedding are bit-identical whether a shard is
//! resident, on disk, or served over a socket
//! (`rust/tests/sharded_equivalence.rs` pins loopback legs across
//! {all-local, mixed, all-remote} × thread counts), and a failing remote
//! read either recovers via retry or aborts the walk with a typed error
//! — never a hang (every socket carries a deadline) and never a silently
//! partial result (frames are size-validated and checksummed).
//!
//! Env knobs (crate docs list all of them): `USPEC_NET_TIMEOUT_MS`
//! bounds connects and socket reads/writes (default 5000);
//! `USPEC_NET_RETRIES` caps transient-failure retries (default 3).

pub mod client;
pub mod proto;
pub mod server;

pub use client::{NetOpts, RemoteSource};
pub use server::{ServeOpts, ShardServer};

use crate::{ensure_arg, Error, Result};
use std::sync::OnceLock;

/// `USPEC_NET_TIMEOUT_MS` (read once): connect/read/write deadline in
/// milliseconds for remote sources. Default 5000.
pub fn net_timeout_ms() -> u64 {
    static V: OnceLock<u64> = OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("USPEC_NET_TIMEOUT_MS").ok().and_then(|v| v.parse().ok()).unwrap_or(5000)
    })
}

/// `USPEC_NET_RETRIES` (read once): transient-failure retries after the
/// first attempt. Default 3.
pub fn net_retries() -> usize {
    static V: OnceLock<usize> = OnceLock::new();
    *V.get_or_init(|| {
        std::env::var("USPEC_NET_RETRIES").ok().and_then(|v| v.parse().ok()).unwrap_or(3)
    })
}

/// Validate a `host:port` string (the spelling `serve-shard --addr` and
/// `remote://` sources use). Port 0 is allowed — it means "ephemeral"
/// for a server bind (a client connect to port 0 fails at dial time with
/// its own clear error).
pub fn validate_host_port(s: &str) -> Result<()> {
    let (host, port) = s
        .rsplit_once(':')
        .ok_or_else(|| Error::InvalidArg(format!("'{s}': want host:port")))?;
    ensure_arg!(!host.is_empty(), "'{s}': empty host (want host:port)");
    ensure_arg!(
        port.parse::<u16>().is_ok(),
        "'{s}': bad port '{port}' (want 0..=65535)"
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::Mat;
    use crate::pipeline::{for_each_chunk_sharded, DataSource, ShardPlan, StorageProfile};
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    /// A deterministic matrix whose every cell is unique — any
    /// misplaced row or byte shows up as a bit mismatch.
    fn test_mat(n: usize, d: usize) -> Mat {
        let mut m = Mat::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.set(i, j, (i * d + j) as f32 * 0.5 - 3.0);
            }
        }
        m
    }

    fn serve(x: Mat) -> ShardServer {
        ShardServer::bind("127.0.0.1:0", Arc::new(x)).unwrap()
    }

    fn fast_opts(retries: usize) -> NetOpts {
        NetOpts {
            connect_timeout: Duration::from_millis(2000),
            io_timeout: Duration::from_millis(2000),
            retries,
            backoff: Duration::from_millis(1),
        }
    }

    #[test]
    fn remote_reads_match_local_bit_exactly() {
        let x = test_mat(97, 3);
        let server = serve(x.clone());
        let remote = RemoteSource::connect(&server.addr().to_string()).unwrap();
        assert_eq!((remote.n(), remote.d()), (97, 3));
        assert!(remote.ping().unwrap() < Duration::from_secs(5));
        let mut got = Mat::zeros(0, 3);
        let mut want = Mat::zeros(0, 3);
        // several ranges over one source: exercises pool reuse too
        for (start, len) in [(0usize, 97usize), (0, 1), (96, 1), (40, 17), (95, 2)] {
            remote.read_rows(start, len, &mut got).unwrap();
            x.read_rows(start, len, &mut want).unwrap();
            assert_eq!((got.rows, got.cols), (len, 3), "[{start}, {}) shape", start + len);
            let a: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = want.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "[{start}, {}) bytes", start + len);
        }
        // the planner hint: remote is a high-latency serial-ish backend
        assert_eq!(remote.storage_hint(), Some(StorageProfile::Remote));
    }

    #[test]
    fn out_of_range_requests_are_typed_errors_client_and_server_side() {
        use super::proto::{encode_read_rows, read_frame, write_frame, OP_ERR, OP_READ_ROWS};
        use std::net::TcpStream;

        let server = serve(test_mat(10, 2));
        let remote = RemoteSource::connect(&server.addr().to_string()).unwrap();
        // client-side: rejected before any network traffic
        let mut buf = Mat::zeros(0, 2);
        let err = remote.read_rows(8, 5, &mut buf).unwrap_err();
        assert!(matches!(err, Error::InvalidArg(_)), "{err}");
        // server-side: a raw socket can send what the client never would;
        // the answer is an OP_ERR frame, not a dropped connection
        let mut conn = TcpStream::connect(server.addr()).unwrap();
        conn.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        write_frame(&mut conn, OP_READ_ROWS, &encode_read_rows(8, 5)).unwrap();
        let (op, payload) = read_frame(&mut conn, 1 << 16).unwrap();
        assert_eq!(op, OP_ERR);
        let msg = String::from_utf8_lossy(&payload).to_string();
        assert!(msg.contains("out of range"), "{msg}");
        // unknown opcodes are answered, not ignored
        write_frame(&mut conn, 0x55, &[]).unwrap();
        let (op, payload) = read_frame(&mut conn, 1 << 16).unwrap();
        assert_eq!(op, OP_ERR);
        assert!(String::from_utf8_lossy(&payload).contains("opcode"));
    }

    #[test]
    fn malformed_addresses_are_rejected() {
        assert!(validate_host_port("localhost:9000").is_ok());
        assert!(validate_host_port("127.0.0.1:0").is_ok()); // ephemeral bind
        for bad in ["nohost", ":123", "host:", "host:notaport", "host:99999"] {
            let err = validate_host_port(bad).unwrap_err();
            assert!(matches!(err, Error::InvalidArg(_)), "{bad}: {err}");
            assert!(RemoteSource::connect(bad).is_err(), "{bad} must not connect");
        }
    }

    #[test]
    fn unreachable_endpoint_fails_fast_with_typed_error() {
        // bind-then-drop: the port existed a moment ago, nobody listens now
        let addr = {
            let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let t = std::time::Instant::now();
        let err = RemoteSource::connect_with(&addr, fast_opts(1)).unwrap_err();
        assert!(matches!(err, Error::Net(_)), "{err}");
        assert!(err.to_string().contains("attempts"), "{err}");
        // 2 attempts × (fast refusal + 1ms backoff) — well inside the bound
        assert!(t.elapsed() < Duration::from_secs(30), "took {:?}", t.elapsed());
    }

    #[test]
    fn mid_stream_disconnect_recovers_via_retry() {
        let x = test_mat(64, 2);
        let server =
            ShardServer::bind_with("127.0.0.1:0", Arc::new(x.clone()), ServeOpts { fail_reads: 2 })
                .unwrap();
        let remote = RemoteSource::connect_with(&server.addr().to_string(), fast_opts(3)).unwrap();
        // first read eats both injected failures (truncated frame + abrupt
        // disconnect), then succeeds on a fresh connection — bit-exactly
        let mut got = Mat::zeros(0, 2);
        remote.read_rows(0, 64, &mut got).unwrap();
        let a: Vec<u32> = got.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = x.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "recovered read must be bit-identical");
        // subsequent reads see a healthy server
        remote.read_rows(10, 5, &mut got).unwrap();
        assert_eq!(got.rows, 5);
    }

    #[test]
    fn exhausted_retries_surface_typed_error_and_abort_the_walk() {
        let x = test_mat(80, 2);
        let always_failing = ServeOpts { fail_reads: usize::MAX };
        let server = ShardServer::bind_with("127.0.0.1:0", Arc::new(x), always_failing).unwrap();
        let remote = RemoteSource::connect_with(&server.addr().to_string(), fast_opts(1)).unwrap();
        // direct read: a typed Net error naming the retry budget
        let mut buf = Mat::zeros(0, 2);
        let err = remote.read_rows(0, 10, &mut buf).unwrap_err();
        assert!(matches!(err, Error::Net(_)), "{err}");
        assert!(err.to_string().contains("2 attempts"), "{err}");
        // through the sharded walk: the first failing shard aborts the
        // whole pass via the existing first-error-wins path — it returns
        // (no hang) and returns Err (no silently partial result)
        let plan = ShardPlan::new(80, 2).unwrap();
        let delivered = Mutex::new(0usize);
        let r = for_each_chunk_sharded(&remote, &plan, 16, |_, m| {
            *delivered.lock().unwrap() += m.rows;
            Ok(())
        });
        assert!(r.is_err(), "walk over a dead remote must fail, not hang");
    }
}
