//! The serving side of remote shard execution: a [`ShardServer`] binds a
//! TCP listener and answers `USPEC/1` / `USPEC/2` frames
//! ([`crate::net::proto`]) for any shared [`DataSource`] —
//! thread-per-connection on the PR-1 scoped idiom, so concurrent clients
//! (shard walkers, prefetch readers) each stream their own row ranges
//! without serializing each other.
//!
//! The server is deliberately dumb: it owns no clustering logic and no
//! row-range policy. A client asks for rows `[start, start + len)` and
//! gets exactly the bytes a local [`DataSource::read_rows`] would produce
//! (little-endian f32, row-major), so whether a shard is local or served
//! over a socket is invisible to every invariant the engine pins.
//! Requests the source rejects (out-of-range rows) are answered with an
//! `OP_ERR` frame carrying the error text — the client maps those to
//! non-retryable errors, keeping a misbehaving request from looping.
//!
//! Two purely operational fast paths ride on top:
//!
//! * **Compression** ([`ServeOpts::compress`], default from the
//!   `USPEC_NET_COMPRESS` knob): the server advertises `USPEC/2` in its
//!   Pong capability bytes; a request flagged [`FLAG_COMPRESS`] is
//!   answered with an `OP_ROWS_C` frame ([`crate::net::codec`]:
//!   byte-shuffled + run-length coded, bit-exactly invertible) whenever
//!   that is strictly smaller than the raw rows, else with the plain
//!   frame. Unflagged requests always get plain `OP_ROWS`.
//! * **An encoded-frame LRU** ([`ServeOpts::cache_bytes`], default off):
//!   `m` ensemble clients sweeping the same rows reuse one
//!   read + encode + compress pass instead of `m`. Keyed by
//!   `(start, len, compressed?)`; sources are immutable for the server's
//!   lifetime, so a cached frame is exactly what a fresh encode would
//!   produce.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::linalg::Mat;
use crate::pipeline::DataSource;
use crate::{Error, Result};

use super::cache::ByteLru;
use super::proto::{
    decode_read_rows, encode_meta, encode_rows, frame_header_v, read_frame, write_frame,
    write_frame_v, FLAG_COMPRESS, MAX_REQUEST_PAYLOAD, OP_ERR, OP_META, OP_META_RESP, OP_PING,
    OP_PONG, OP_READ_ROWS, OP_ROWS, OP_ROWS_C, PROTO_V2, PROTO_VERSION,
};
use super::{net_compress, net_idle_ms};

/// Serving options; production servers use [`ServeOpts::default`].
#[derive(Debug, Clone, Copy)]
pub struct ServeOpts {
    /// Chaos hook: answer the first `fail_reads` row requests (across all
    /// connections) with a deliberately truncated frame followed by an
    /// abrupt disconnect — the mid-stream failure mode the client's
    /// retry loop must absorb. 0 (the default) serves faithfully.
    pub fail_reads: usize,
    /// Encoded-frame LRU budget in bytes; 0 (the default) disables the
    /// cache. Wired from `repro serve-shard --cache BYTES`.
    pub cache_bytes: usize,
    /// Advertise `USPEC/2` and compress flagged row responses. Defaults
    /// to the `USPEC_NET_COMPRESS` env knob (on unless set to `0`).
    pub compress: bool,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts { fail_reads: 0, cache_bytes: 0, compress: net_compress() }
    }
}

/// The encoded-frame cache: `(start, len, compressed?)` → the exact
/// `(version, opcode, payload)` a fresh encode would produce. `Arc`'d so
/// concurrent handler threads share one copy of each payload.
type FrameCache = Mutex<ByteLru<(u64, u64, bool), (u8, u8, Arc<Vec<u8>>)>>;

/// A running shard server: a bound listener plus its accept thread.
/// Dropping the server shuts it down (the accept loop is woken and
/// joined); [`ShardServer::join`] instead blocks forever, for the
/// `repro serve-shard` foreground process.
pub struct ShardServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ShardServer {
    /// Bind `addr` (`host:port`; port 0 picks an ephemeral port) and
    /// serve `source` to any number of concurrent clients.
    pub fn bind(addr: &str, source: Arc<dyn DataSource + Send + Sync>) -> Result<ShardServer> {
        ShardServer::bind_with(addr, source, ServeOpts::default())
    }

    /// [`ShardServer::bind`] with explicit [`ServeOpts`].
    pub fn bind_with(
        addr: &str,
        source: Arc<dyn DataSource + Send + Sync>,
        opts: ServeOpts,
    ) -> Result<ShardServer> {
        super::validate_host_port(addr)?;
        let listener = TcpListener::bind(addr)
            .map_err(|e| Error::Net(format!("bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Net(format!("bind {addr}: no local addr: {e}")))?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let fail_budget = Arc::new(AtomicUsize::new(opts.fail_reads));
        let cache: Option<Arc<FrameCache>> = (opts.cache_bytes > 0)
            .then(|| Arc::new(Mutex::new(ByteLru::new(opts.cache_bytes))));
        let stop = Arc::clone(&shutdown);
        let accept = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if stop.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(conn) = conn else { continue };
                let src = Arc::clone(&source);
                let budget = Arc::clone(&fail_budget);
                let cache = cache.clone();
                // Handlers are detached: each lives exactly as long as its
                // connection (EOF, error, or idle timeout ends it), and the
                // shared state they hold is Arc'd.
                std::thread::spawn(move || {
                    handle(conn, &*src, &budget, opts, cache.as_deref())
                });
            }
        });
        Ok(ShardServer { addr: local, shutdown, accept: Some(accept) })
    }

    /// The bound address — with the resolved port when `bind` got port 0.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until the process is killed (the `repro serve-shard`
    /// foreground mode). Consumes the server; never returns normally
    /// unless the listener thread dies.
    pub fn join(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| Error::Net("shard server accept thread panicked".into()))?;
        }
        Ok(())
    }
}

impl Drop for ShardServer {
    fn drop(&mut self) {
        let Some(h) = self.accept.take() else { return };
        self.shutdown.store(true, Ordering::Relaxed);
        // The accept loop blocks in `incoming()`; a throwaway connection
        // wakes it so it can observe the flag and exit.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(250));
        let _ = h.join();
    }
}

/// Serve one connection until EOF, an I/O error, or the idle timeout
/// (`USPEC_NET_IDLE_MS`; a connection with no complete request inside
/// the window is dropped — an abandoned client can never pin a handler
/// thread forever).
fn handle(
    mut conn: TcpStream,
    source: &dyn DataSource,
    fail_budget: &AtomicUsize,
    opts: ServeOpts,
    cache: Option<&FrameCache>,
) {
    let idle = Duration::from_millis(net_idle_ms().max(1));
    let _ = conn.set_nodelay(true);
    let _ = conn.set_read_timeout(Some(idle));
    let _ = conn.set_write_timeout(Some(idle));
    let (n, d) = (source.n(), source.d());
    // Pong capability bytes: advertise USPEC/2 iff this server will
    // honor FLAG_COMPRESS (a v1 client ignores the payload entirely).
    let caps: &[u8] = if opts.compress { &[PROTO_V2] } else { &[] };
    let mut buf = Mat::zeros(0, d);
    loop {
        // Requests are tiny; a frame claiming more is corrupt or hostile
        // and ends the connection (the client will retry on a fresh one).
        let Ok((op, payload)) = read_frame(&mut conn, MAX_REQUEST_PAYLOAD) else { return };
        let ok = match op {
            OP_PING => write_frame(&mut conn, OP_PONG, caps).is_ok(),
            OP_META => {
                write_frame(&mut conn, OP_META_RESP, &encode_meta(n as u64, d as u64)).is_ok()
            }
            OP_READ_ROWS => {
                let reply = serve_rows(&payload, source, n, d, &mut buf, opts.compress, cache);
                match reply {
                    Ok((version, rop, rows_payload)) => {
                        if chaos_strike(fail_budget) {
                            // Injected mid-stream failure: a correct header,
                            // half the payload, then a severed connection.
                            let head = frame_header_v(version, rop, rows_payload.len());
                            let _ = std::io::Write::write_all(&mut conn, &head);
                            let _ = std::io::Write::write_all(
                                &mut conn,
                                &rows_payload[..rows_payload.len() / 2],
                            );
                            let _ = std::io::Write::flush(&mut conn);
                            return;
                        }
                        write_frame_v(&mut conn, version, rop, &rows_payload).is_ok()
                    }
                    Err(e) => write_frame(&mut conn, OP_ERR, e.to_string().as_bytes()).is_ok(),
                }
            }
            other => write_frame(
                &mut conn,
                OP_ERR,
                format!("unknown request opcode {other:#04x}").as_bytes(),
            )
            .is_ok(),
        };
        if !ok {
            return;
        }
    }
}

/// Validate and execute one row request; any `Err` becomes an `OP_ERR`
/// frame (the non-retryable class on the client). Returns the frame to
/// send: `(version, opcode, payload)` — compressed when the client asked
/// for it, compression is enabled, and it actually shrinks the bytes.
fn serve_rows(
    payload: &[u8],
    source: &dyn DataSource,
    n: usize,
    d: usize,
    buf: &mut Mat,
    compress_ok: bool,
    cache: Option<&FrameCache>,
) -> Result<(u8, u8, Arc<Vec<u8>>)> {
    let (start, len, flags) = decode_read_rows(payload)?;
    let end = start.checked_add(len).ok_or_else(|| {
        Error::InvalidArg(format!("rows [{start}, start+{len}) overflows"))
    })?;
    if end > n as u64 || len == 0 {
        return Err(Error::InvalidArg(format!(
            "rows [{start}, {end}) out of range (n={n}, len must be >= 1)"
        )));
    }
    // The frame length field is u32: a request whose payload cannot be
    // framed is a caller bug, not something to truncate silently.
    let bytes = len * (d as u64) * 4;
    if bytes > u32::MAX as u64 {
        return Err(Error::InvalidArg(format!(
            "rows [{start}, {end}): payload {bytes} bytes exceeds the u32 frame limit"
        )));
    }
    let want_compress = compress_ok && flags & FLAG_COMPRESS != 0;
    let key = (start, len, want_compress);
    if let Some(cache) = cache {
        if let Some(hit) = lock_cache(cache).get(&key) {
            return Ok(hit.clone());
        }
    }
    source.read_rows(start as usize, len as usize, buf)?;
    let raw = encode_rows(buf);
    let reply = match want_compress.then(|| super::codec::compress(&raw)).flatten() {
        Some(comp) => (PROTO_V2, OP_ROWS_C, Arc::new(comp)),
        None => (PROTO_VERSION, OP_ROWS, Arc::new(raw)),
    };
    if let Some(cache) = cache {
        let weight = reply.2.len();
        lock_cache(cache).insert(key, reply.clone(), weight);
    }
    Ok(reply)
}

fn lock_cache(
    cache: &FrameCache,
) -> std::sync::MutexGuard<'_, ByteLru<(u64, u64, bool), (u8, u8, Arc<Vec<u8>>)>> {
    cache.lock().unwrap_or_else(|e| e.into_inner())
}

/// Consume one failure token if any remain (the `fail_reads` chaos hook).
fn chaos_strike(budget: &AtomicUsize) -> bool {
    budget
        .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |v| v.checked_sub(1))
        .is_ok()
}
