//! The `USPEC/1` + `USPEC/2` wire protocol: versioned, length-framed,
//! checksummed.
//!
//! Every message — request or response — is one frame:
//!
//! ```text
//! offset  size  field
//! 0       1     protocol version  ([`PROTO_VERSION`] = 0x01, or
//!                                  [`PROTO_V2`] = 0x02 for frames only a
//!                                  v2 peer can decode)
//! 1       1     opcode            (request 0x01..=0x03, response 0x81..)
//! 2       4     payload length L  (u32, little-endian)
//! 6       L     payload
//! 6+L     4     FNV-1a checksum   (u32 LE, over bytes [0, 6+L))
//! ```
//!
//! The checksum covers the header *and* the payload, so a corrupted
//! length or opcode is caught as reliably as corrupted row data. All
//! integers are little-endian; plain row payloads are raw little-endian
//! `f32` values, row-major — exactly the
//! [`crate::streaming::BinDataset`] layout, so a served chunk is
//! bit-identical to a local read of the same rows.
//!
//! Request opcodes and their payloads:
//!
//! | opcode | payload | response |
//! |---|---|---|
//! | [`OP_PING`] | capability bytes (may be empty) | [`OP_PONG`], capability bytes |
//! | [`OP_META`] | empty | [`OP_META_RESP`], `u64 n, u64 d` |
//! | [`OP_READ_ROWS`] | `u64 start, u64 len[, u8 flags]` | [`OP_ROWS`] or [`OP_ROWS_C`] |
//!
//! `USPEC/2` extends `USPEC/1` in three backward-compatible steps (see
//! [`crate::net`] for the full negotiation/fallback rules):
//!
//! * Ping/Pong payloads carry **capability bytes** — a v2 peer includes
//!   [`PROTO_V2`]; a v1 peer sends/ignores an empty payload.
//! * A ReadRows request may append one **flags byte**
//!   ([`FLAG_COMPRESS`]: the client accepts compressed responses). Only
//!   sent after the server advertised v2 — a v1 server rejects the
//!   17-byte payload as malformed.
//! * [`OP_ROWS_C`] answers a flagged ReadRows with a
//!   [`crate::net::codec`] payload (byte-shuffled + run-length coded
//!   f32 rows, bit-exactly invertible) in a [`PROTO_V2`] frame. When
//!   compression would not shrink the payload the server answers with a
//!   plain [`OP_ROWS`] instead, so the wire never carries a regression.
//!
//! Any request the server cannot satisfy (out-of-range rows, unknown
//! opcode) is answered with [`OP_ERR`] carrying a UTF-8 message; the
//! client surfaces that as a non-retryable error. Transport failures
//! (disconnects, timeouts, checksum mismatches, malformed compressed
//! streams) are the retryable class — see [`crate::net::RemoteSource`].

use crate::linalg::Mat;
use crate::{Error, Result};
use std::io::{Read, Write};

/// Version byte every baseline frame leads with; an unknown version
/// rejects the frame.
pub const PROTO_VERSION: u8 = 0x01;
/// Version byte on frames only a `USPEC/2` peer can decode (today:
/// [`OP_ROWS_C`]), and the capability byte advertised in Ping/Pong
/// payloads. A v1 peer that somehow receives such a frame rejects it at
/// the framing layer — the designed failure mode if negotiation were
/// ever bypassed.
pub const PROTO_V2: u8 = 0x02;

/// Request: liveness check, empty payload.
pub const OP_PING: u8 = 0x01;
/// Request: dataset shape, empty payload.
pub const OP_META: u8 = 0x02;
/// Request: rows `[start, start + len)`; payload `u64 start, u64 len`.
pub const OP_READ_ROWS: u8 = 0x03;
/// Response to [`OP_PING`], empty payload.
pub const OP_PONG: u8 = 0x81;
/// Response to [`OP_META`]; payload `u64 n, u64 d`.
pub const OP_META_RESP: u8 = 0x82;
/// Response to [`OP_READ_ROWS`]; payload `len·d` little-endian f32s.
pub const OP_ROWS: u8 = 0x83;
/// `USPEC/2` response to a [`FLAG_COMPRESS`]-flagged [`OP_READ_ROWS`];
/// payload is a [`crate::net::codec`] stream, carried in a [`PROTO_V2`]
/// frame.
pub const OP_ROWS_C: u8 = 0x84;
/// Error response to any request; payload is a UTF-8 message.
pub const OP_ERR: u8 = 0xFF;

// --- `USPEC/2` serve opcodes (`repro serve`, [`crate::net::serve`]) ----
// The job-manager daemon speaks the same framing; its frames are stamped
// [`PROTO_V2`] since no v1 peer exists for these opcodes.

/// Request: enqueue a fit job; payload is a UTF-8 JSON
/// [`crate::config::FitSpec`]. Answered with [`OP_JOB_RESP`] (or
/// [`OP_ERR`] when the bounded queue is full / the spec is malformed).
pub const OP_SUBMIT_FIT: u8 = 0x10;
/// Request: job status; payload `u64 job id`. Answered with
/// [`OP_JOB_RESP`].
pub const OP_JOB_STATUS: u8 = 0x11;
/// Request: label out-of-sample rows with a registered model; payload is
/// [`encode_assign`] (`u16 id_len · id · u64 rows · u64 d · rows×d f32`).
/// Answered with [`OP_ASSIGN_RESP`].
pub const OP_ASSIGN: u8 = 0x12;
/// Request: list registered models, empty payload. Answered with
/// [`OP_MODELS_RESP`] (UTF-8 JSON).
pub const OP_LIST_MODELS: u8 = 0x13;
/// Response to [`OP_SUBMIT_FIT`] / [`OP_JOB_STATUS`]; payload is a UTF-8
/// JSON object (`job`, `status`, and `model` / `error` when resolved).
pub const OP_JOB_RESP: u8 = 0x90;
/// Response to [`OP_ASSIGN`]; payload is [`encode_labels`]
/// (`u64 rows · rows×u32 labels`).
pub const OP_ASSIGN_RESP: u8 = 0x91;
/// Response to [`OP_LIST_MODELS`]; payload is a UTF-8 JSON array.
pub const OP_MODELS_RESP: u8 = 0x92;

/// Payload cap for serve-daemon frames: [`OP_ASSIGN`] carries row data
/// (and [`OP_ASSIGN_RESP`] labels), so the tiny [`MAX_REQUEST_PAYLOAD`]
/// cap does not apply — clients chunk their queries under this bound.
pub const MAX_SERVE_PAYLOAD: usize = 16 << 20;

/// ReadRows flags bit: the client accepts [`OP_ROWS_C`] responses.
pub const FLAG_COMPRESS: u8 = 0x01;

/// Frame header length (version + opcode + payload length).
pub const HEADER_LEN: usize = 6;
/// Trailing checksum length.
pub const CHECKSUM_LEN: usize = 4;
/// Payload cap for *request* frames (requests are tiny; a larger claim
/// is a corrupt or hostile frame).
pub const MAX_REQUEST_PAYLOAD: usize = 64;

/// Incremental 32-bit FNV-1a — the per-frame checksum. Not
/// cryptographic; it exists to catch truncation and bit rot on the wire,
/// like the magic/size checks guard the on-disk format.
#[derive(Debug, Clone, Copy)]
pub struct Fnv32(u32);

impl Fnv32 {
    pub fn new() -> Fnv32 {
        Fnv32(0x811C_9DC5)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= u32::from(b);
            self.0 = self.0.wrapping_mul(0x0100_0193);
        }
    }

    pub fn finish(&self) -> u32 {
        self.0
    }
}

impl Default for Fnv32 {
    fn default() -> Self {
        Fnv32::new()
    }
}

/// The 6-byte frame header for `op` with a `payload_len`-byte payload,
/// stamped with `version`.
pub(crate) fn frame_header_v(version: u8, op: u8, payload_len: usize) -> [u8; HEADER_LEN] {
    let mut head = [0u8; HEADER_LEN];
    head[0] = version;
    head[1] = op;
    head[2..6].copy_from_slice(&(payload_len as u32).to_le_bytes());
    head
}

/// The 6-byte baseline ([`PROTO_VERSION`]) frame header.
pub(crate) fn frame_header(op: u8, payload_len: usize) -> [u8; HEADER_LEN] {
    frame_header_v(PROTO_VERSION, op, payload_len)
}

/// Write one complete baseline frame (header, payload, checksum) and
/// flush.
pub fn write_frame(w: &mut impl Write, op: u8, payload: &[u8]) -> std::io::Result<()> {
    write_frame_v(w, PROTO_VERSION, op, payload)
}

/// [`write_frame`] with an explicit version byte — [`PROTO_V2`] for
/// frames only a negotiated v2 peer may receive.
pub fn write_frame_v(
    w: &mut impl Write,
    version: u8,
    op: u8,
    payload: &[u8],
) -> std::io::Result<()> {
    let head = frame_header_v(version, op, payload.len());
    let mut sum = Fnv32::new();
    sum.update(&head);
    sum.update(payload);
    w.write_all(&head)?;
    w.write_all(payload)?;
    w.write_all(&sum.finish().to_le_bytes())?;
    w.flush()
}

/// Read one complete frame, enforcing a known version byte, a payload
/// cap, and the trailing checksum. Transport failures surface as
/// [`Error::Io`]; malformed frames as [`Error::Net`] — both are the
/// retryable class for the client.
pub fn read_frame(r: &mut impl Read, max_payload: usize) -> Result<(u8, Vec<u8>)> {
    let mut head = [0u8; HEADER_LEN];
    r.read_exact(&mut head)?;
    if head[0] != PROTO_VERSION && head[0] != PROTO_V2 {
        return Err(Error::Net(format!(
            "protocol version {:#04x}, want {PROTO_VERSION:#04x} or {PROTO_V2:#04x}",
            head[0]
        )));
    }
    let op = head[1];
    let len = u32::from_le_bytes(head[2..6].try_into().unwrap()) as usize;
    if len > max_payload {
        return Err(Error::Net(format!("frame payload {len} bytes > cap {max_payload}")));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    let mut trailer = [0u8; CHECKSUM_LEN];
    r.read_exact(&mut trailer)?;
    let want = u32::from_le_bytes(trailer);
    let mut sum = Fnv32::new();
    sum.update(&head);
    sum.update(&payload);
    let got = sum.finish();
    if got != want {
        return Err(Error::Net(format!(
            "frame checksum mismatch (got {got:#010x}, frame says {want:#010x})"
        )));
    }
    Ok((op, payload))
}

/// Encode a baseline [`OP_READ_ROWS`] request payload (the only form a
/// v1 server accepts).
pub fn encode_read_rows(start: u64, len: u64) -> [u8; 16] {
    let mut p = [0u8; 16];
    p[..8].copy_from_slice(&start.to_le_bytes());
    p[8..].copy_from_slice(&len.to_le_bytes());
    p
}

/// Encode a `USPEC/2` [`OP_READ_ROWS`] request payload with a trailing
/// flags byte ([`FLAG_COMPRESS`]). Send only after the server advertised
/// [`PROTO_V2`] — a v1 server rejects the 17-byte form.
pub fn encode_read_rows_v2(start: u64, len: u64, flags: u8) -> [u8; 17] {
    let mut p = [0u8; 17];
    p[..16].copy_from_slice(&encode_read_rows(start, len));
    p[16] = flags;
    p
}

/// Decode an [`OP_READ_ROWS`] request payload, either form; the flags
/// byte decodes as 0 for the 16-byte baseline request.
pub fn decode_read_rows(payload: &[u8]) -> Result<(u64, u64, u8)> {
    let flags = match payload.len() {
        16 => 0,
        17 => payload[16],
        n => return Err(Error::Net(format!("ReadRows payload {n} bytes, want 16 or 17"))),
    };
    let start = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let len = u64::from_le_bytes(payload[8..16].try_into().unwrap());
    Ok((start, len, flags))
}

/// Encode an [`OP_META_RESP`] payload.
pub fn encode_meta(n: u64, d: u64) -> [u8; 16] {
    let mut p = [0u8; 16];
    p[..8].copy_from_slice(&n.to_le_bytes());
    p[8..].copy_from_slice(&d.to_le_bytes());
    p
}

/// Decode an [`OP_META_RESP`] payload.
pub fn decode_meta(payload: &[u8]) -> Result<(u64, u64)> {
    if payload.len() != 16 {
        return Err(Error::Net(format!("Meta payload {} bytes, want 16", payload.len())));
    }
    let n = u64::from_le_bytes(payload[..8].try_into().unwrap());
    let d = u64::from_le_bytes(payload[8..].try_into().unwrap());
    Ok((n, d))
}

/// Serialize a row chunk into an [`OP_ROWS`] payload (little-endian f32,
/// row-major — the `BinDataset` layout).
pub fn encode_rows(m: &Mat) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(m.data.len() * 4);
    for v in &m.data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

/// Deserialize an [`OP_ROWS`] payload into `buf`, validating the exact
/// expected size for a `rows × d` chunk.
pub fn decode_rows_into(payload: &[u8], rows: usize, d: usize, buf: &mut Mat) -> Result<()> {
    let expect = rows * d * 4;
    if payload.len() != expect {
        return Err(Error::Net(format!(
            "Rows payload {} bytes, want {expect} ({rows} rows × {d} dims)",
            payload.len()
        )));
    }
    buf.rows = rows;
    buf.cols = d;
    buf.data.clear();
    buf.data
        .extend(payload.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())));
    Ok(())
}

/// Encode an [`OP_ASSIGN`] request: `u16 id_len · id bytes · u64 rows ·
/// u64 d · rows×d` little-endian f32s (bit-exact, like every row payload).
pub fn encode_assign(model_id: &str, m: &Mat) -> Result<Vec<u8>> {
    if model_id.is_empty() || model_id.len() > u16::MAX as usize {
        return Err(Error::InvalidArg(format!(
            "assign: model id must be 1..={} bytes (got {})",
            u16::MAX,
            model_id.len()
        )));
    }
    let mut p = Vec::with_capacity(2 + model_id.len() + 16 + m.data.len() * 4);
    p.extend_from_slice(&(model_id.len() as u16).to_le_bytes());
    p.extend_from_slice(model_id.as_bytes());
    p.extend_from_slice(&(m.rows as u64).to_le_bytes());
    p.extend_from_slice(&(m.cols as u64).to_le_bytes());
    for v in &m.data {
        p.extend_from_slice(&v.to_le_bytes());
    }
    Ok(p)
}

/// Decode an [`OP_ASSIGN`] request payload into `(model id, rows)`.
pub fn decode_assign(payload: &[u8]) -> Result<(String, Mat)> {
    let short = || Error::Net("Assign payload truncated".into());
    if payload.len() < 2 {
        return Err(short());
    }
    let id_len = u16::from_le_bytes(payload[..2].try_into().unwrap()) as usize;
    let rest = payload.get(2..).ok_or_else(short)?;
    if rest.len() < id_len + 16 {
        return Err(short());
    }
    let id = std::str::from_utf8(&rest[..id_len])
        .map_err(|_| Error::Net("Assign model id is not UTF-8".into()))?
        .to_string();
    let rows = u64::from_le_bytes(rest[id_len..id_len + 8].try_into().unwrap()) as usize;
    let d = u64::from_le_bytes(rest[id_len + 8..id_len + 16].try_into().unwrap()) as usize;
    let mut m = Mat::zeros(0, 0);
    decode_rows_into(&rest[id_len + 16..], rows, d, &mut m)
        .map_err(|_| Error::Net("Assign payload row data size mismatch".into()))?;
    Ok((id, m))
}

/// Encode an [`OP_ASSIGN_RESP`] payload: `u64 rows · rows×u32 labels`.
pub fn encode_labels(labels: &[u32]) -> Vec<u8> {
    let mut p = Vec::with_capacity(8 + labels.len() * 4);
    p.extend_from_slice(&(labels.len() as u64).to_le_bytes());
    for l in labels {
        p.extend_from_slice(&l.to_le_bytes());
    }
    p
}

/// Decode an [`OP_ASSIGN_RESP`] payload.
pub fn decode_labels(payload: &[u8]) -> Result<Vec<u32>> {
    if payload.len() < 8 {
        return Err(Error::Net("Labels payload truncated".into()));
    }
    let rows = u64::from_le_bytes(payload[..8].try_into().unwrap()) as usize;
    let body = &payload[8..];
    if body.len() != rows * 4 {
        return Err(Error::Net(format!(
            "Labels payload {} bytes for {rows} rows, want {}",
            body.len(),
            rows * 4
        )));
    }
    Ok(body.chunks_exact(4).map(|b| u32::from_le_bytes(b.try_into().unwrap())).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn assign_payloads_roundtrip() {
        let mut m = Mat::zeros(3, 2);
        m.data.copy_from_slice(&[1.5, -0.0, 2.25, f32::MIN_POSITIVE, -7.0, 0.125]);
        let p = encode_assign("model-000042", &m).unwrap();
        let (id, back) = decode_assign(&p).unwrap();
        assert_eq!(id, "model-000042");
        let a: Vec<u32> = m.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b);
        assert_eq!((back.rows, back.cols), (3, 2));
        // malformed: truncated, bad sizes, empty id
        assert!(decode_assign(&p[..5]).is_err());
        assert!(decode_assign(&p[..p.len() - 1]).is_err());
        assert!(encode_assign("", &m).is_err());
        let labels = vec![0u32, 3, 1, u32::MAX];
        assert_eq!(decode_labels(&encode_labels(&labels)).unwrap(), labels);
        assert!(decode_labels(&[0u8; 7]).is_err());
        let mut bad = encode_labels(&labels);
        bad.pop();
        assert!(decode_labels(&bad).is_err());
    }

    #[test]
    fn frame_roundtrip_all_opcodes() {
        for (op, payload) in [
            (OP_PING, Vec::new()),
            (OP_PING, vec![PROTO_V2]),
            (OP_META, Vec::new()),
            (OP_READ_ROWS, encode_read_rows(7, 13).to_vec()),
            (OP_READ_ROWS, encode_read_rows_v2(7, 13, FLAG_COMPRESS).to_vec()),
            (OP_ROWS, vec![1u8, 2, 3, 4]),
            (OP_ERR, b"nope".to_vec()),
        ] {
            let mut wire = Vec::new();
            write_frame(&mut wire, op, &payload).unwrap();
            let (rop, rpayload) = read_frame(&mut wire.as_slice(), 1 << 20).unwrap();
            assert_eq!((rop, rpayload), (op, payload));
        }
        // v2-stamped frames read back identically (OP_ROWS_C carrier)
        let mut wire = Vec::new();
        write_frame_v(&mut wire, PROTO_V2, OP_ROWS_C, &[5u8, 6, 7]).unwrap();
        assert_eq!(wire[0], PROTO_V2);
        let (op, payload) = read_frame(&mut wire.as_slice(), 1 << 20).unwrap();
        assert_eq!((op, payload), (OP_ROWS_C, vec![5u8, 6, 7]));
    }

    #[test]
    fn corruption_is_detected() {
        let mut wire = Vec::new();
        write_frame(&mut wire, OP_ROWS, &[9u8; 32]).unwrap();
        // flip one payload byte: checksum must catch it
        let mut bad = wire.clone();
        bad[HEADER_LEN + 5] ^= 0x40;
        let err = read_frame(&mut bad.as_slice(), 1 << 20).unwrap_err();
        assert!(err.to_string().contains("checksum"), "{err}");
        // flip the version byte
        let mut bad = wire.clone();
        bad[0] = 0x7F;
        let err = read_frame(&mut bad.as_slice(), 1 << 20).unwrap_err();
        assert!(err.to_string().contains("version"), "{err}");
        // flip a header byte (opcode): also covered by the checksum
        let mut bad = wire.clone();
        bad[1] ^= 0x01;
        assert!(read_frame(&mut bad.as_slice(), 1 << 20).is_err());
        // truncated mid-payload: an Io error (the retryable class)
        let cut = &wire[..HEADER_LEN + 10];
        let err = read_frame(&mut &cut[..], 1 << 20).unwrap_err();
        assert!(matches!(err, crate::Error::Io(_)), "{err}");
    }

    #[test]
    fn oversize_payload_claim_is_rejected_before_allocation() {
        let mut wire = Vec::new();
        write_frame(&mut wire, OP_ROWS, &[0u8; 128]).unwrap();
        let err = read_frame(&mut wire.as_slice(), 64).unwrap_err();
        assert!(err.to_string().contains("cap"), "{err}");
    }

    #[test]
    fn request_and_meta_payload_roundtrip() {
        // 16-byte baseline requests decode with flags 0
        assert_eq!(decode_read_rows(&encode_read_rows(123, 456)).unwrap(), (123, 456, 0));
        // 17-byte v2 requests carry their flags byte through
        assert_eq!(
            decode_read_rows(&encode_read_rows_v2(123, 456, FLAG_COMPRESS)).unwrap(),
            (123, 456, FLAG_COMPRESS)
        );
        assert_eq!(decode_meta(&encode_meta(10_000_000, 64)).unwrap(), (10_000_000, 64));
        assert!(decode_read_rows(&[0u8; 15]).is_err());
        assert!(decode_read_rows(&[0u8; 18]).is_err());
        assert!(decode_meta(&[0u8; 17]).is_err());
    }

    #[test]
    fn rows_payload_is_bit_exact() {
        let mut m = Mat::zeros(3, 2);
        let vals = [1.5f32, -0.0, f32::MIN_POSITIVE, 3.25e-40, 1e30, -7.125];
        m.data.copy_from_slice(&vals);
        let payload = encode_rows(&m);
        let mut back = Mat::zeros(0, 0);
        decode_rows_into(&payload, 3, 2, &mut back).unwrap();
        let a: Vec<u32> = m.data.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = back.data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "f32 values must round-trip bit-exactly");
        // size mismatch is a malformed frame, not a short read
        assert!(decode_rows_into(&payload, 2, 2, &mut back).is_err());
    }
}
