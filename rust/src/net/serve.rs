//! The `repro serve` job manager — clustering as a long-running service.
//!
//! A [`ServeRuntime`] binds a TCP listener on the existing `USPEC/2`
//! framing ([`crate::net::proto`]) and runs two planes concurrently:
//!
//! * **Control/fit plane.** `SubmitFit` enqueues a [`FitSpec`] onto a
//!   **bounded** job queue (depth [`ServeConfig::queue_depth`]; a full
//!   queue rejects the submit with a typed `OP_ERR` instead of buffering
//!   unboundedly). One fit worker drains the queue: it opens the
//!   server-visible [`crate::streaming::BinDataset`], runs
//!   [`Pipeline::fit`] (U-SPEC) or [`crate::usenc::usenc_fit`] (U-SENC)
//!   on the worker pool, persists the model artifact
//!   ([`crate::runtime::model::save_model`]) under
//!   [`ServeConfig::models_dir`], and registers it in the in-memory
//!   registry. `JobStatus` polls the lifecycle:
//!   `queued → running → done | failed`.
//! * **Query plane.** `Assign` labels out-of-sample rows against any
//!   registered model — answered thread-per-connection straight from the
//!   registry ([`Pipeline::assign`] / [`Pipeline::assign_consensus`]),
//!   concurrent with fits and with each other. `ListModels` enumerates
//!   the registry.
//!
//! At bind time the registry is seeded from `models_dir` — every
//! `*.uspecmdl` artifact a previous daemon saved is loaded (corrupt files
//! are skipped with a note on stderr, never served). Model ids are the
//! artifact file stems; fits name theirs `model-<job id>`.
//!
//! **Graceful shutdown.** Dropping the runtime stops accepting, closes
//! the fit queue (queued-but-unstarted jobs stay `queued`; the running
//! fit finishes and is persisted), and *drains in-flight queries*: active
//! connections are counted, and shutdown waits for the count to reach
//! zero (bounded by the idle timeout) before joining the worker — a
//! client mid-`Assign` gets its labels, not a reset connection.
//!
//! The assignment path inherits every determinism invariant the engine
//! pins: a served `Assign` returns exactly the labels an in-process
//! [`Pipeline::assign`] with the same model and rows would — bit-for-bit,
//! across threads, chunk sizes, and SIMD dispatch
//! (`rust/tests/serve_runtime.rs`, CI `serve-e2e`).

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use crate::affinity::NativeBackend;
use crate::config::FitSpec;
use crate::linalg::Mat;
use crate::pipeline::Pipeline;
use crate::runtime::model::{load_model, save_model, Model};
use crate::streaming::BinDataset;
use crate::usenc::{usenc_fit, UsencParams};
use crate::uspec::UspecParams;
use crate::util::json::Json;
use crate::{ensure_arg, Error, Result};

use super::proto::{
    decode_assign, decode_labels, encode_assign, encode_labels, read_frame, write_frame_v,
    MAX_SERVE_PAYLOAD, OP_ASSIGN, OP_ASSIGN_RESP, OP_ERR, OP_JOB_RESP, OP_JOB_STATUS,
    OP_LIST_MODELS, OP_MODELS_RESP, OP_SUBMIT_FIT, PROTO_V2,
};
use super::{net_idle_ms, net_timeout_ms};

/// Artifact file extension under the models dir.
pub const MODEL_EXT: &str = "uspecmdl";

/// Daemon configuration (`repro serve --models-dir DIR [--queue N]`).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Artifact store: fitted models are saved here and the registry is
    /// seeded from it at bind.
    pub models_dir: PathBuf,
    /// Bounded fit-queue depth; a submit beyond it is rejected with a
    /// typed error (backpressure, not unbounded buffering).
    pub queue_depth: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig { models_dir: PathBuf::from("models"), queue_depth: 16 }
    }
}

/// One job's lifecycle, as reported by `JobStatus`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    Queued,
    Running,
    /// Fit finished; the model is registered under this id.
    Done { model: String },
    Failed { error: String },
}

impl JobState {
    fn status(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done { .. } => "done",
            JobState::Failed { .. } => "failed",
        }
    }
}

/// Pending jobs plus the closed flag, under one lock so `close()` and
/// `push` cannot race.
type QueueSlots = (VecDeque<(u64, FitSpec)>, bool);

/// The bounded fit queue: a plain deque + condvar so the worker blocks
/// without spinning and `close()` wakes it for shutdown.
struct FitQueue {
    q: Mutex<QueueSlots>,
    cv: Condvar,
    depth: usize,
}

impl FitQueue {
    fn new(depth: usize) -> FitQueue {
        FitQueue { q: Mutex::new((VecDeque::new(), false)), cv: Condvar::new(), depth }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, QueueSlots> {
        self.q.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Enqueue or reject: a full (or closed) queue is the caller's typed
    /// error, never a silent wait.
    fn push(&self, job: u64, spec: FitSpec) -> Result<()> {
        let mut g = self.lock();
        if g.1 {
            return Err(Error::Net("serve: shutting down, fit queue closed".into()));
        }
        if g.0.len() >= self.depth {
            return Err(Error::InvalidArg(format!(
                "serve: fit queue full ({} jobs queued, depth {}) — retry later",
                g.0.len(),
                self.depth
            )));
        }
        g.0.push_back((job, spec));
        self.cv.notify_one();
        Ok(())
    }

    /// Blocking pop; `None` once closed and drained-or-abandoned.
    fn pop(&self) -> Option<(u64, FitSpec)> {
        let mut g = self.lock();
        loop {
            if g.1 {
                return None; // closed: abandon queued jobs (they stay `queued`)
            }
            if let Some(item) = g.0.pop_front() {
                return Some(item);
            }
            g = self.cv.wait(g).unwrap_or_else(|e| e.into_inner());
        }
    }

    fn close(&self) {
        self.lock().1 = true;
        self.cv.notify_all();
    }
}

/// Shared daemon state: registry, job table, queue, drain counters.
struct ServeState {
    models_dir: PathBuf,
    registry: Mutex<BTreeMap<String, Arc<Model>>>,
    jobs: Mutex<HashMap<u64, JobState>>,
    next_job: AtomicU64,
    queue: FitQueue,
    /// Connections currently inside `handle` — the drain gauge.
    active: AtomicUsize,
    shutdown: AtomicBool,
}

impl ServeState {
    fn lock_registry(&self) -> std::sync::MutexGuard<'_, BTreeMap<String, Arc<Model>>> {
        self.registry.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn lock_jobs(&self) -> std::sync::MutexGuard<'_, HashMap<u64, JobState>> {
        self.jobs.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn set_job(&self, id: u64, state: JobState) {
        self.lock_jobs().insert(id, state);
    }
}

/// A running `repro serve` daemon: listener + accept thread + one fit
/// worker. Dropping it shuts down gracefully (see module docs);
/// [`ServeRuntime::join`] blocks forever for the CLI foreground mode.
pub struct ServeRuntime {
    addr: SocketAddr,
    state: Arc<ServeState>,
    accept: Option<std::thread::JoinHandle<()>>,
    worker: Option<std::thread::JoinHandle<()>>,
}

impl ServeRuntime {
    /// Bind `addr` (`host:port`; port 0 picks an ephemeral port), seed
    /// the registry from `config.models_dir` (created if missing), and
    /// start serving.
    pub fn bind(addr: &str, config: ServeConfig) -> Result<ServeRuntime> {
        super::validate_host_port(addr)?;
        ensure_arg!(config.queue_depth >= 1, "serve: queue depth must be >= 1");
        std::fs::create_dir_all(&config.models_dir)?;
        let listener =
            TcpListener::bind(addr).map_err(|e| Error::Net(format!("bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| Error::Net(format!("bind {addr}: no local addr: {e}")))?;
        let state = Arc::new(ServeState {
            registry: Mutex::new(load_registry(&config.models_dir)),
            models_dir: config.models_dir,
            jobs: Mutex::new(HashMap::new()),
            next_job: AtomicU64::new(1),
            queue: FitQueue::new(config.queue_depth),
            active: AtomicUsize::new(0),
            shutdown: AtomicBool::new(false),
        });
        let accept_state = Arc::clone(&state);
        let accept = std::thread::spawn(move || {
            for conn in listener.incoming() {
                if accept_state.shutdown.load(Ordering::Relaxed) {
                    break;
                }
                let Ok(conn) = conn else { continue };
                let st = Arc::clone(&accept_state);
                std::thread::spawn(move || {
                    st.active.fetch_add(1, Ordering::SeqCst);
                    handle(conn, &st);
                    st.active.fetch_sub(1, Ordering::SeqCst);
                });
            }
        });
        let worker_state = Arc::clone(&state);
        let worker = std::thread::spawn(move || fit_worker(&worker_state));
        Ok(ServeRuntime { addr: local, state, accept: Some(accept), worker: Some(worker) })
    }

    /// The bound address — with the resolved port when `bind` got port 0.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Registered model ids (sorted).
    pub fn model_ids(&self) -> Vec<String> {
        self.state.lock_registry().keys().cloned().collect()
    }

    /// Serve until the process is killed (the `repro serve` foreground
    /// mode). Consumes the runtime; never returns normally unless the
    /// accept thread dies.
    pub fn join(mut self) -> Result<()> {
        if let Some(h) = self.accept.take() {
            h.join().map_err(|_| Error::Net("serve accept thread panicked".into()))?;
        }
        Ok(())
    }
}

impl Drop for ServeRuntime {
    fn drop(&mut self) {
        let Some(h) = self.accept.take() else { return };
        self.state.shutdown.store(true, Ordering::SeqCst);
        self.state.queue.close();
        // The accept loop blocks in `incoming()`; a throwaway connection
        // wakes it so it can observe the flag and exit.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(250));
        let _ = h.join();
        // Drain in-flight queries: the handlers already counted
        // themselves in; wait (bounded by the idle timeout) for them to
        // finish their current exchanges and exit on the shutdown flag.
        let deadline =
            std::time::Instant::now() + Duration::from_millis(net_idle_ms().max(1000));
        while self.state.active.load(Ordering::SeqCst) > 0
            && std::time::Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

/// Seed the registry from the artifact store. Corrupt or foreign files
/// are skipped with a note — a bad artifact must never be served, and
/// one bad file must never take the daemon down.
fn load_registry(dir: &Path) -> BTreeMap<String, Arc<Model>> {
    let mut reg = BTreeMap::new();
    let Ok(entries) = std::fs::read_dir(dir) else { return reg };
    for entry in entries.flatten() {
        let path = entry.path();
        if path.extension().and_then(|e| e.to_str()) != Some(MODEL_EXT) {
            continue;
        }
        let Some(stem) = path.file_stem().and_then(|s| s.to_str()) else { continue };
        match load_model(&path) {
            Ok(model) => {
                reg.insert(stem.to_string(), Arc::new(model));
            }
            Err(e) => eprintln!("serve: skipping {}: {e}", path.display()),
        }
    }
    reg
}

/// The fit worker: drain the queue until it closes. One job at a time —
/// the fit itself is pool-parallel, so serializing jobs keeps the worker
/// pool for the running fit instead of thrashing between fits.
fn fit_worker(state: &ServeState) {
    while let Some((job, spec)) = state.queue.pop() {
        state.set_job(job, JobState::Running);
        match run_fit(state, job, &spec) {
            Ok(model_id) => state.set_job(job, JobState::Done { model: model_id }),
            Err(e) => state.set_job(job, JobState::Failed { error: e.to_string() }),
        }
    }
}

/// Fit a [`FitSpec`] against its on-disk dataset — the one fit path
/// both the daemon's worker and the `repro fit` CLI command go through,
/// so a served fit and a local fit of the same spec produce the same
/// model bit-for-bit.
pub fn fit_model(spec: &FitSpec) -> Result<Model> {
    spec.validate()?;
    let src = BinDataset::open(Path::new(&spec.data))?;
    match spec.method.as_str() {
        "u-spec" => {
            let params = UspecParams {
                k: spec.k,
                p: spec.p,
                k_nn: spec.k_nn,
                ..UspecParams::default()
            };
            let pipe = Pipeline::new(&NativeBackend);
            Ok(Model::Uspec(pipe.fit(&src, &params, spec.seed)?.model))
        }
        "u-senc" => {
            let params = UsencParams {
                k: spec.k,
                m: spec.m,
                k_min: spec.k_min,
                k_max: spec.k_max,
                base: UspecParams { p: spec.p, k_nn: spec.k_nn, ..UspecParams::default() },
            };
            Ok(Model::Usenc(
                usenc_fit(&src, &params, spec.seed, &NativeBackend, Default::default())?.model,
            ))
        }
        other => Err(Error::Config(format!("unknown method '{other}'"))),
    }
}

/// Execute one fit job: fit, persist, register.
fn run_fit(state: &ServeState, job: u64, spec: &FitSpec) -> Result<String> {
    let model = fit_model(spec)?;
    let model_id = format!("model-{job:06}");
    let path = state.models_dir.join(format!("{model_id}.{MODEL_EXT}"));
    save_model(&path, &model)?;
    state.lock_registry().insert(model_id.clone(), Arc::new(model));
    Ok(model_id)
}

/// One JSON job-report payload (`OP_JOB_RESP`).
fn job_json(job: u64, state: &JobState) -> Vec<u8> {
    let mut fields = vec![
        ("job", Json::Num(job as f64)),
        ("status", Json::Str(state.status().into())),
    ];
    match state {
        JobState::Done { model } => fields.push(("model", Json::Str(model.clone()))),
        JobState::Failed { error } => fields.push(("error", Json::Str(error.clone()))),
        _ => {}
    }
    Json::obj(fields).to_string().into_bytes()
}

/// Serve one connection until EOF, an I/O error, the idle timeout, or
/// shutdown. Every response is a [`PROTO_V2`]-stamped frame.
fn handle(mut conn: TcpStream, state: &ServeState) {
    let idle = Duration::from_millis(net_idle_ms().max(1));
    let _ = conn.set_nodelay(true);
    let _ = conn.set_read_timeout(Some(idle));
    let _ = conn.set_write_timeout(Some(idle));
    loop {
        let Ok((op, payload)) = read_frame(&mut conn, MAX_SERVE_PAYLOAD) else { return };
        let reply = dispatch(state, op, &payload);
        let ok = match reply {
            Ok((rop, rpayload)) => write_frame_v(&mut conn, PROTO_V2, rop, &rpayload).is_ok(),
            Err(e) => {
                write_frame_v(&mut conn, PROTO_V2, OP_ERR, e.to_string().as_bytes()).is_ok()
            }
        };
        // In-flight requests were answered above; once shutdown is on,
        // end the connection instead of waiting for the next request —
        // that is the drain the Drop impl observes.
        if !ok || state.shutdown.load(Ordering::SeqCst) {
            return;
        }
    }
}

/// Route one request to its handler; `Err` becomes an `OP_ERR` frame.
fn dispatch(state: &ServeState, op: u8, payload: &[u8]) -> Result<(u8, Vec<u8>)> {
    match op {
        OP_SUBMIT_FIT => {
            let text = std::str::from_utf8(payload)
                .map_err(|_| Error::Net("SubmitFit payload is not UTF-8".into()))?;
            let spec = FitSpec::parse(text)?;
            let job = state.next_job.fetch_add(1, Ordering::SeqCst);
            state.set_job(job, JobState::Queued);
            if let Err(e) = state.queue.push(job, spec) {
                state.lock_jobs().remove(&job);
                return Err(e);
            }
            Ok((OP_JOB_RESP, job_json(job, &JobState::Queued)))
        }
        OP_JOB_STATUS => {
            ensure_arg!(payload.len() == 8, "JobStatus payload: want u64 job id");
            let job = u64::from_le_bytes(payload.try_into().unwrap());
            let jstate = state
                .lock_jobs()
                .get(&job)
                .cloned()
                .ok_or_else(|| Error::InvalidArg(format!("unknown job {job}")))?;
            Ok((OP_JOB_RESP, job_json(job, &jstate)))
        }
        OP_ASSIGN => {
            let (id, rows) = decode_assign(payload)?;
            let model = state
                .lock_registry()
                .get(&id)
                .cloned()
                .ok_or_else(|| Error::InvalidArg(format!("unknown model '{id}'")))?;
            let pipe = Pipeline::new(&NativeBackend);
            let labels = match &*model {
                Model::Uspec(m) => pipe.assign(m, &rows)?,
                Model::Usenc(m) => pipe.assign_consensus(m, &rows)?,
            };
            Ok((OP_ASSIGN_RESP, encode_labels(&labels)))
        }
        OP_LIST_MODELS => {
            let list: Vec<Json> = state
                .lock_registry()
                .iter()
                .map(|(id, m)| {
                    Json::obj(vec![
                        ("id", Json::Str(id.clone())),
                        ("kind", Json::Str(m.kind().into())),
                        ("k", Json::Num(m.k() as f64)),
                        ("d", Json::Num(m.d() as f64)),
                    ])
                })
                .collect();
            Ok((OP_MODELS_RESP, Json::Arr(list).to_string().into_bytes()))
        }
        other => Err(Error::Net(format!("unknown serve opcode {other:#04x}"))),
    }
}

// ---------------------------------------------------------------------------
// Client
// ---------------------------------------------------------------------------

/// A reported job status (the decoded `OP_JOB_RESP`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JobReport {
    pub job: u64,
    /// "queued" | "running" | "done" | "failed".
    pub status: String,
    /// Registered model id once done.
    pub model: Option<String>,
    /// Failure message once failed.
    pub error: Option<String>,
}

impl JobReport {
    fn parse(payload: &[u8]) -> Result<JobReport> {
        let text = std::str::from_utf8(payload)
            .map_err(|_| Error::Net("JobResp payload is not UTF-8".into()))?;
        let v = Json::parse(text).map_err(Error::Net)?;
        Ok(JobReport {
            job: v.get("job").and_then(|j| j.as_f64()).unwrap_or(0.0) as u64,
            status: v
                .get("status")
                .and_then(|s| s.as_str())
                .ok_or_else(|| Error::Net("JobResp: missing status".into()))?
                .to_string(),
            model: v.get("model").and_then(|s| s.as_str()).map(str::to_string),
            error: v.get("error").and_then(|s| s.as_str()).map(str::to_string),
        })
    }
}

/// A registry entry (the decoded `OP_MODELS_RESP` element).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelInfo {
    pub id: String,
    pub kind: String,
    pub k: usize,
    pub d: usize,
}

/// Rows per `Assign` request: bounds the frame payload well under
/// [`MAX_SERVE_PAYLOAD`] for any d the header admits, and chunking is
/// invisible — rows are labeled independently, so the concatenated
/// responses equal one giant query bit-for-bit.
const ASSIGN_CHUNK_BYTES: usize = 4 << 20;

/// A blocking client for a [`ServeRuntime`] — one pooled connection,
/// timeouts from the `USPEC_NET_*` knobs. Used by the `submit-fit`,
/// `job-status`, and `assign --addr` CLI commands and the e2e tests.
pub struct ServeClient {
    conn: TcpStream,
}

impl ServeClient {
    /// Connect to a `repro serve` daemon at `host:port`.
    pub fn connect(addr: &str) -> Result<ServeClient> {
        super::validate_host_port(addr)?;
        use std::net::ToSocketAddrs;
        let resolved = addr
            .to_socket_addrs()
            .map_err(|e| Error::Net(format!("{addr}: resolve failed: {e}")))?
            .next()
            .ok_or_else(|| Error::Net(format!("{addr}: resolved to no address")))?;
        let timeout = Duration::from_millis(net_timeout_ms());
        let conn = TcpStream::connect_timeout(&resolved, timeout)
            .map_err(|e| Error::Net(format!("{addr}: connect failed: {e}")))?;
        conn.set_read_timeout(Some(timeout))?;
        conn.set_write_timeout(Some(timeout))?;
        let _ = conn.set_nodelay(true);
        Ok(ServeClient { conn })
    }

    fn exchange(&mut self, op: u8, payload: &[u8], want: u8) -> Result<Vec<u8>> {
        write_frame_v(&mut self.conn, PROTO_V2, op, payload)?;
        let (rop, rpayload) = read_frame(&mut self.conn, MAX_SERVE_PAYLOAD)?;
        match rop {
            x if x == want => Ok(rpayload),
            OP_ERR => Err(Error::InvalidArg(format!(
                "serve: {}",
                String::from_utf8_lossy(&rpayload)
            ))),
            other => Err(Error::Net(format!("unexpected serve opcode {other:#04x}"))),
        }
    }

    /// Enqueue a fit; returns the job id.
    pub fn submit_fit(&mut self, spec: &FitSpec) -> Result<u64> {
        let payload = spec.to_json().to_string().into_bytes();
        let resp = self.exchange(OP_SUBMIT_FIT, &payload, OP_JOB_RESP)?;
        Ok(JobReport::parse(&resp)?.job)
    }

    /// Poll one job's lifecycle.
    pub fn job_status(&mut self, job: u64) -> Result<JobReport> {
        let resp = self.exchange(OP_JOB_STATUS, &job.to_le_bytes(), OP_JOB_RESP)?;
        JobReport::parse(&resp)
    }

    /// Poll until the job leaves `queued`/`running` or the deadline
    /// passes. `Done` returns the model id; `Failed` is a typed error.
    pub fn wait_for(&mut self, job: u64, deadline: Duration) -> Result<String> {
        let until = std::time::Instant::now() + deadline;
        loop {
            let report = self.job_status(job)?;
            match report.status.as_str() {
                "done" => {
                    return report
                        .model
                        .ok_or_else(|| Error::Net("done without a model id".into()))
                }
                "failed" => {
                    return Err(Error::Runtime(format!(
                        "job {job} failed: {}",
                        report.error.unwrap_or_else(|| "unknown error".into())
                    )))
                }
                _ if std::time::Instant::now() >= until => {
                    return Err(Error::Net(format!(
                        "job {job} still {} after {deadline:?}",
                        report.status
                    )))
                }
                _ => std::thread::sleep(Duration::from_millis(50)),
            }
        }
    }

    /// Label `rows` with a registered model. Requests are chunked under
    /// the frame cap; responses concatenate to exactly the labels one
    /// in-process `assign` would produce.
    pub fn assign(&mut self, model_id: &str, rows: &Mat) -> Result<Vec<u32>> {
        ensure_arg!(rows.rows >= 1 && rows.cols >= 1, "assign: empty query");
        let per = (ASSIGN_CHUNK_BYTES / (rows.cols * 4)).max(1);
        let mut labels = Vec::with_capacity(rows.rows);
        let mut start = 0;
        while start < rows.rows {
            let len = per.min(rows.rows - start);
            let chunk = Mat {
                rows: len,
                cols: rows.cols,
                data: rows.data[start * rows.cols..(start + len) * rows.cols].to_vec(),
            };
            let payload = encode_assign(model_id, &chunk)?;
            let resp = self.exchange(OP_ASSIGN, &payload, OP_ASSIGN_RESP)?;
            let part = decode_labels(&resp)?;
            ensure_arg!(part.len() == len, "assign: server returned {} labels for {len} rows", part.len());
            labels.extend_from_slice(&part);
            start += len;
        }
        Ok(labels)
    }

    /// Enumerate the server's registered models (sorted by id).
    pub fn list_models(&mut self) -> Result<Vec<ModelInfo>> {
        let resp = self.exchange(OP_LIST_MODELS, &[], OP_MODELS_RESP)?;
        let text = std::str::from_utf8(&resp)
            .map_err(|_| Error::Net("ModelsResp payload is not UTF-8".into()))?;
        let v = Json::parse(text).map_err(Error::Net)?;
        let arr = v.as_arr().ok_or_else(|| Error::Net("ModelsResp: want an array".into()))?;
        arr.iter()
            .map(|e| {
                Ok(ModelInfo {
                    id: e
                        .get("id")
                        .and_then(|s| s.as_str())
                        .ok_or_else(|| Error::Net("ModelsResp: missing id".into()))?
                        .to_string(),
                    kind: e
                        .get("kind")
                        .and_then(|s| s.as_str())
                        .unwrap_or("uspec")
                        .to_string(),
                    k: e.get("k").and_then(|n| n.as_usize()).unwrap_or(0),
                    d: e.get("d").and_then(|n| n.as_usize()).unwrap_or(0),
                })
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::model::UspecModel;

    fn spec(data: &str) -> FitSpec {
        FitSpec {
            method: "u-spec".into(),
            data: data.into(),
            k: 2,
            p: 40,
            k_nn: 3,
            m: 3,
            k_min: 2,
            k_max: 4,
            seed: 7,
        }
    }

    #[test]
    fn fit_queue_is_bounded_blocking_and_closeable() {
        let q = FitQueue::new(2);
        q.push(1, spec("a.bin")).unwrap();
        q.push(2, spec("b.bin")).unwrap();
        let err = q.push(3, spec("c.bin")).unwrap_err();
        assert!(err.to_string().contains("queue full"), "{err}");
        assert_eq!(q.pop().unwrap().0, 1, "FIFO order");
        q.push(3, spec("c.bin")).unwrap();
        assert_eq!(q.pop().unwrap().0, 2);
        q.close();
        // closed: queued items are abandoned, pushes rejected, pop wakes
        assert!(q.pop().is_none());
        assert!(q.push(4, spec("d.bin")).is_err());
    }

    #[test]
    fn job_reports_roundtrip_through_the_wire_json() {
        for state in [
            JobState::Queued,
            JobState::Running,
            JobState::Done { model: "model-000007".into() },
            JobState::Failed { error: "no such file".into() },
        ] {
            let r = JobReport::parse(&job_json(42, &state)).unwrap();
            assert_eq!(r.job, 42);
            assert_eq!(r.status, state.status());
            match state {
                JobState::Done { model } => assert_eq!(r.model.as_deref(), Some(&model[..])),
                JobState::Failed { error } => assert_eq!(r.error.as_deref(), Some(&error[..])),
                _ => assert!(r.model.is_none() && r.error.is_none()),
            }
        }
        assert!(JobReport::parse(b"\xff\xfe").is_err(), "non-UTF-8 rejected");
        assert!(JobReport::parse(b"{}").is_err(), "missing status rejected");
    }

    #[test]
    fn registry_seeding_loads_good_artifacts_and_skips_bad_ones() {
        let dir = std::env::temp_dir().join(format!("uspec_serve_reg_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let model = Model::Uspec(UspecModel {
            k: 2,
            k_nn: 2,
            seed: 1,
            sigma: 0.5,
            reps: Mat::from_vec(2, 2, vec![0.0, 0.0, 1.0, 1.0]),
            rep_labels: vec![0, 1],
            provenance: String::new(),
        });
        save_model(&dir.join(format!("good.{MODEL_EXT}")), &model).unwrap();
        std::fs::write(dir.join(format!("corrupt.{MODEL_EXT}")), b"not a model").unwrap();
        std::fs::write(dir.join("ignored.txt"), b"unrelated").unwrap();
        let reg = load_registry(&dir);
        assert_eq!(reg.keys().cloned().collect::<Vec<_>>(), vec!["good".to_string()]);
        assert_eq!(reg["good"].kind(), "uspec");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn bind_rejects_bad_config_before_listening() {
        let cfg = ServeConfig { models_dir: std::env::temp_dir(), queue_depth: 0 };
        assert!(ServeRuntime::bind("127.0.0.1:0", cfg).is_err(), "zero queue depth");
        let cfg = ServeConfig::default();
        assert!(ServeRuntime::bind("no-port-here", cfg).is_err(), "malformed addr");
    }
}
