//! The consuming side of remote shard execution: [`RemoteSource`] is a
//! [`DataSource`] whose `read_rows` crosses the network as `USPEC/1`
//! frames ([`crate::net::proto`]).
//!
//! Robustness model — a remote read must never hang and never return a
//! silently partial chunk:
//!
//! * **Timeouts everywhere.** Connects use [`NetOpts::connect_timeout`];
//!   every established socket carries [`NetOpts::io_timeout`] read/write
//!   deadlines. A dead or wedged server surfaces as an error within one
//!   timeout, not as a stuck walker.
//! * **Bounded retry with backoff.** Transport failures (connect/read
//!   timeouts, disconnects, corrupt frames — [`crate::Error::Io`] and
//!   [`crate::Error::Net`]) are retried up to [`NetOpts::retries`] times
//!   with exponential backoff on a *fresh* connection. Application
//!   errors the server reports (`OP_ERR`: out-of-range rows, bad
//!   request) come back as [`crate::Error::InvalidArg`] and are **not**
//!   retried — resending a bad request cannot fix it.
//! * **Typed surfacing.** Exhausted retries return [`crate::Error::Net`];
//!   through [`crate::pipeline::for_each_chunk_sharded`] that aborts the
//!   whole walk via the existing first-error-wins path, exactly like a
//!   failed disk read.
//!
//! Reads either fill the buffer with the exact bytes a local read would
//! produce (frames are checksummed and size-validated, f32 payloads
//! round-trip bit-exactly) or fail — so every bit-identity invariant the
//! engine pins holds over the wire. A small connection pool amortizes
//! dials across the chunk stream; [`DataSource::storage_hint`] reports
//! [`StorageProfile::Remote`] so the adaptive walk planner schedules few
//! walkers with a deep prefetch queue instead of probing the link.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::linalg::Mat;
use crate::pipeline::{DataSource, StorageProfile};
use crate::{ensure_arg, Error, Result};

use super::proto::{
    decode_meta, decode_rows_into, encode_read_rows, read_frame, write_frame, OP_ERR, OP_META,
    OP_META_RESP, OP_PING, OP_PONG, OP_READ_ROWS, OP_ROWS,
};
use super::{net_retries, net_timeout_ms};

/// Idle connections kept for reuse; walkers + prefetch readers rarely
/// need more, and a burst beyond the cap just dials.
const POOL_CAP: usize = 8;

/// Network behavior knobs. [`NetOpts::default`] reads the env knobs
/// `USPEC_NET_TIMEOUT_MS` and `USPEC_NET_RETRIES` (crate docs) — all
/// operational: they bound waiting, never change any result.
#[derive(Debug, Clone, Copy)]
pub struct NetOpts {
    /// Deadline for establishing a connection.
    pub connect_timeout: Duration,
    /// Read/write deadline on every established socket.
    pub io_timeout: Duration,
    /// Transient-failure retries after the first attempt (0 = one
    /// attempt only).
    pub retries: usize,
    /// Backoff before the first retry; doubles per retry (capped at
    /// 16×).
    pub backoff: Duration,
}

impl Default for NetOpts {
    fn default() -> Self {
        let t = Duration::from_millis(net_timeout_ms());
        let backoff = Duration::from_millis(50);
        NetOpts { connect_timeout: t, io_timeout: t, retries: net_retries(), backoff }
    }
}

/// A [`DataSource`] served by a remote [`crate::net::ShardServer`]. The
/// shape (`n`, `d`) is fetched once at connect time; every `read_rows`
/// is one framed request/response round-trip on a pooled connection.
pub struct RemoteSource {
    addr: SocketAddr,
    /// The `host:port` the caller gave us, for error messages.
    label: String,
    n: usize,
    d: usize,
    opts: NetOpts,
    pool: Mutex<Vec<TcpStream>>,
}

impl RemoteSource {
    /// Connect to `host:port` with default [`NetOpts`] and fetch the
    /// dataset shape. Fails fast (typed, within the connect timeout ×
    /// retries) on a malformed address or an unreachable endpoint.
    pub fn connect(addr: &str) -> Result<RemoteSource> {
        RemoteSource::connect_with(addr, NetOpts::default())
    }

    /// [`RemoteSource::connect`] with explicit [`NetOpts`].
    pub fn connect_with(addr: &str, opts: NetOpts) -> Result<RemoteSource> {
        super::validate_host_port(addr)?;
        let resolved = addr
            .to_socket_addrs()
            .map_err(|e| Error::Net(format!("{addr}: resolve failed: {e}")))?
            .next()
            .ok_or_else(|| Error::Net(format!("{addr}: resolved to no address")))?;
        let mut src = RemoteSource {
            addr: resolved,
            label: addr.to_string(),
            n: 0,
            d: 0,
            opts,
            pool: Mutex::new(Vec::new()),
        };
        let (n, d) = src.fetch_meta()?;
        ensure_arg!(d >= 1, "{addr}: remote dataset has d=0");
        src.n = n;
        src.d = d;
        Ok(src)
    }

    /// The resolved server address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Round-trip liveness check; returns the request latency.
    pub fn ping(&self) -> Result<Duration> {
        let t = Instant::now();
        self.with_conn("ping", |conn| {
            write_frame(conn, OP_PING, &[])?;
            let (op, _) = read_frame(conn, 64)?;
            match op {
                OP_PONG => Ok(()),
                other => Err(unexpected(other, "Pong")),
            }
        })?;
        Ok(t.elapsed())
    }

    fn fetch_meta(&self) -> Result<(usize, usize)> {
        self.with_conn("meta", |conn| {
            write_frame(conn, OP_META, &[])?;
            let (op, payload) = read_frame(conn, 64)?;
            match op {
                OP_META_RESP => {
                    let (n, d) = decode_meta(&payload)?;
                    let n = usize::try_from(n)
                        .map_err(|_| Error::Net(format!("remote n={n} exceeds usize")))?;
                    let d = usize::try_from(d)
                        .map_err(|_| Error::Net(format!("remote d={d} exceeds usize")))?;
                    Ok((n, d))
                }
                OP_ERR => Err(server_error(&payload)),
                other => Err(unexpected(other, "MetaResp")),
            }
        })
    }

    /// Dial a fresh connection with all deadlines armed.
    fn dial(&self) -> Result<TcpStream> {
        let conn = TcpStream::connect_timeout(&self.addr, self.opts.connect_timeout)
            .map_err(|e| Error::Net(format!("{}: connect failed: {e}", self.label)))?;
        conn.set_read_timeout(Some(self.opts.io_timeout))?;
        conn.set_write_timeout(Some(self.opts.io_timeout))?;
        let _ = conn.set_nodelay(true);
        Ok(conn)
    }

    /// Run one request on a pooled (or fresh) connection, retrying
    /// transient failures with exponential backoff. On success the
    /// connection returns to the pool; on any failure it is dropped —
    /// a half-read stream must never serve the next request.
    fn with_conn<T>(
        &self,
        what: &str,
        mut f: impl FnMut(&mut TcpStream) -> Result<T>,
    ) -> Result<T> {
        let mut last: Option<Error> = None;
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                let shift = (attempt - 1).min(4) as u32;
                std::thread::sleep(self.opts.backoff * (1u32 << shift));
            }
            let pooled = self.lock_pool().pop();
            let mut conn = match pooled {
                Some(c) => c,
                None => match self.dial() {
                    Ok(c) => c,
                    Err(e) => {
                        last = Some(e);
                        continue;
                    }
                },
            };
            match f(&mut conn) {
                Ok(v) => {
                    let mut pool = self.lock_pool();
                    if pool.len() < POOL_CAP {
                        pool.push(conn);
                    }
                    return Ok(v);
                }
                // Transport-class failures retry on a fresh connection;
                // everything else (server-reported InvalidArg) is final.
                Err(e @ (Error::Io(_) | Error::Net(_))) => last = Some(e),
                Err(e) => return Err(e),
            }
        }
        let last = last.expect("at least one attempt ran");
        Err(Error::Net(format!(
            "{}: {what} failed after {} attempts: {last}",
            self.label,
            self.opts.retries + 1
        )))
    }

    fn lock_pool(&self) -> std::sync::MutexGuard<'_, Vec<TcpStream>> {
        self.pool.lock().unwrap_or_else(|e| e.into_inner())
    }
}

impl DataSource for RemoteSource {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(start + len <= self.n, "read_rows: out of range");
        ensure_arg!(len >= 1, "read_rows: len must be >= 1");
        let expect = len * self.d * 4;
        self.with_conn("read_rows", |conn| {
            write_frame(conn, OP_READ_ROWS, &encode_read_rows(start as u64, len as u64))?;
            // Cap: the exact payload plus header slack; anything larger is
            // a corrupt frame, not a bigger answer.
            let (op, payload) = read_frame(conn, expect + 64)?;
            match op {
                OP_ROWS => decode_rows_into(&payload, len, self.d, buf),
                OP_ERR => Err(server_error(&payload)),
                other => Err(unexpected(other, "Rows")),
            }
        })
    }

    /// A network round-trip per chunk is a high-latency serial-ish
    /// backend: the walk planner schedules few walkers with deep
    /// prefetch and skips the local-storage probe.
    fn storage_hint(&self) -> Option<StorageProfile> {
        Some(StorageProfile::Remote)
    }
}

/// A server-reported failure: the request was delivered and rejected, so
/// retrying cannot help — surfaced as `InvalidArg`, the non-retryable
/// class.
fn server_error(payload: &[u8]) -> Error {
    Error::InvalidArg(format!("remote shard server: {}", String::from_utf8_lossy(payload)))
}

/// A well-formed frame of the wrong type: protocol confusion, treated as
/// transient (the retry gets a fresh connection and a clean stream).
fn unexpected(op: u8, want: &str) -> Error {
    Error::Net(format!("unexpected frame opcode {op:#04x} (want {want})"))
}
