//! The consuming side of remote shard execution: [`RemoteSource`] is a
//! [`DataSource`] whose `read_rows` crosses the network as `USPEC/1` /
//! `USPEC/2` frames ([`crate::net::proto`]).
//!
//! Robustness model — a remote read must never hang and never return a
//! silently partial chunk:
//!
//! * **Timeouts everywhere.** Connects use [`NetOpts::connect_timeout`];
//!   every established socket carries [`NetOpts::io_timeout`] read/write
//!   deadlines. A dead or wedged server surfaces as an error within one
//!   timeout, not as a stuck walker.
//! * **Bounded retry with backoff.** Transport failures (connect/read
//!   timeouts, disconnects, corrupt frames — [`crate::Error::Io`] and
//!   [`crate::Error::Net`]) are retried up to [`NetOpts::retries`] times
//!   with exponential backoff on a *fresh* connection. Application
//!   errors the server reports (`OP_ERR`: out-of-range rows, bad
//!   request) come back as [`crate::Error::InvalidArg`] and are **not**
//!   retried — resending a bad request cannot fix it.
//! * **Typed surfacing.** Exhausted retries return [`crate::Error::Net`];
//!   through [`crate::pipeline::for_each_chunk_sharded`] that aborts the
//!   whole walk via the existing first-error-wins path, exactly like a
//!   failed disk read.
//!
//! Three purely operational fast paths (none changes a single bit the
//! engine sees):
//!
//! * **Request pipelining.** One chunk read is split into up to
//!   [`PIPELINE_DEPTH`] sub-range requests written back-to-back before
//!   the first response is read, so the server reads/encodes/sends part
//!   `i + 1` while the client checksums and decodes part `i` — instead
//!   of paying a full round trip per chunk with both ends idle half the
//!   time. Responses arrive strictly in request order on the one
//!   connection; bytes are appended in order, so the assembled chunk is
//!   byte-identical to a single-frame read. Any failure mid-exchange
//!   drops the connection with all in-flight state and retries the whole
//!   chunk fresh — a half-read stream never serves the next request.
//! * **Compression** ([`NetOpts::compress`], default from the
//!   `USPEC_NET_COMPRESS` knob). After the server advertises `USPEC/2`
//!   in its Pong capability bytes, row requests carry `FLAG_COMPRESS`
//!   and responses may arrive as `OP_ROWS_C` ([`crate::net::codec`] —
//!   bit-exactly invertible byte-shuffle + RLE). Against a v1 server the
//!   source speaks plain `USPEC/1` forever.
//! * **A decoded-chunk LRU** ([`NetOpts::cache_bytes`], default off;
//!   wired from `ExecOpts::net_cache` by the CLI). U-SENC's `1 + m`
//!   sweeps re-read the same row ranges — repeat reads hit memory, not
//!   the wire. A hit copies the exact decoded floats a miss would have
//!   produced and touches no socket at all.
//!
//! Reads either fill the buffer with the exact bytes a local read would
//! produce (frames are checksummed and size-validated, f32 payloads
//! round-trip bit-exactly) or fail — so every bit-identity invariant the
//! engine pins holds over the wire. A small connection pool
//! (`USPEC_NET_POOL`) amortizes dials across the chunk stream;
//! [`DataSource::storage_hint`] reports [`StorageProfile::Remote`] so
//! the adaptive walk planner schedules few walkers with a deep prefetch
//! queue instead of probing the link.

use std::net::{SocketAddr, TcpStream, ToSocketAddrs};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::linalg::Mat;
use crate::pipeline::{DataSource, StorageProfile};
use crate::{ensure_arg, Error, Result};

use super::cache::ByteLru;
use super::proto::{
    decode_meta, encode_read_rows, encode_read_rows_v2, read_frame, write_frame, FLAG_COMPRESS,
    OP_ERR, OP_META, OP_META_RESP, OP_PING, OP_PONG, OP_READ_ROWS, OP_ROWS, OP_ROWS_C, PROTO_V2,
};
use super::{net_compress, net_pool, net_retries, net_timeout_ms};

/// Sub-requests kept in flight per connection when one chunk read is
/// pipelined — matches the walk planner's Remote prefetch depth
/// ([`crate::pipeline::shard::REMOTE_PREFETCH_DEPTH`]), so the wire
/// stays as busy as the prefetch queue it feeds.
pub const PIPELINE_DEPTH: usize = crate::pipeline::shard::REMOTE_PREFETCH_DEPTH;

/// Network behavior knobs. [`NetOpts::default`] reads the env knobs
/// `USPEC_NET_TIMEOUT_MS`, `USPEC_NET_RETRIES`, and `USPEC_NET_COMPRESS`
/// (crate docs) — all operational: they bound waiting and byte counts,
/// never change any result.
#[derive(Debug, Clone, Copy)]
pub struct NetOpts {
    /// Deadline for establishing a connection.
    pub connect_timeout: Duration,
    /// Read/write deadline on every established socket.
    pub io_timeout: Duration,
    /// Transient-failure retries after the first attempt (0 = one
    /// attempt only).
    pub retries: usize,
    /// Backoff before the first retry; doubles per retry (capped at
    /// 16×).
    pub backoff: Duration,
    /// Decoded-chunk LRU budget in bytes; 0 (the default) disables the
    /// cache. The streaming peak model charges this budget.
    pub cache_bytes: usize,
    /// Request compressed row frames when the server advertises
    /// `USPEC/2`. Defaults to the `USPEC_NET_COMPRESS` env knob (on
    /// unless set to `0`).
    pub compress: bool,
}

impl Default for NetOpts {
    fn default() -> Self {
        let t = Duration::from_millis(net_timeout_ms());
        let backoff = Duration::from_millis(50);
        NetOpts {
            connect_timeout: t,
            io_timeout: t,
            retries: net_retries(),
            backoff,
            cache_bytes: 0,
            compress: net_compress(),
        }
    }
}

/// A [`DataSource`] served by a remote [`crate::net::ShardServer`]. The
/// shape (`n`, `d`) and the server's protocol capabilities are fetched
/// once at connect time; every `read_rows` is a pipelined framed
/// exchange on a pooled connection (or a cache hit that never leaves
/// the process).
pub struct RemoteSource {
    addr: SocketAddr,
    /// The `host:port` the caller gave us, for error messages.
    label: String,
    n: usize,
    d: usize,
    opts: NetOpts,
    /// The server advertised `USPEC/2` in its Pong capability bytes.
    peer_v2: bool,
    pool: Mutex<Vec<TcpStream>>,
    /// Decoded row-range chunks, keyed by `(start, len)`. `None` when the
    /// budget is 0 — a disabled cache is a true no-op (no map, no stats,
    /// no lock on the read path), not an always-missing one.
    cache: Option<Mutex<ByteLru<(u64, u64), Vec<f32>>>>,
}

impl RemoteSource {
    /// Connect to `host:port` with default [`NetOpts`], negotiate the
    /// protocol revision, and fetch the dataset shape. Fails fast
    /// (typed, within the connect timeout × retries) on a malformed
    /// address or an unreachable endpoint.
    pub fn connect(addr: &str) -> Result<RemoteSource> {
        RemoteSource::connect_with(addr, NetOpts::default())
    }

    /// [`RemoteSource::connect`] with explicit [`NetOpts`].
    pub fn connect_with(addr: &str, opts: NetOpts) -> Result<RemoteSource> {
        super::validate_host_port(addr)?;
        let resolved = addr
            .to_socket_addrs()
            .map_err(|e| Error::Net(format!("{addr}: resolve failed: {e}")))?
            .next()
            .ok_or_else(|| Error::Net(format!("{addr}: resolved to no address")))?;
        let mut src = RemoteSource {
            addr: resolved,
            label: addr.to_string(),
            n: 0,
            d: 0,
            opts,
            peer_v2: false,
            pool: Mutex::new(Vec::new()),
            cache: (opts.cache_bytes > 0).then(|| Mutex::new(ByteLru::new(opts.cache_bytes))),
        };
        src.peer_v2 = src.negotiate()?;
        let (n, d) = src.fetch_meta()?;
        ensure_arg!(d >= 1, "{addr}: remote dataset has d=0");
        src.n = n;
        src.d = d;
        Ok(src)
    }

    /// The resolved server address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// True when the server advertised `USPEC/2` (compressed row frames
    /// may be negotiated). A v1 server downgrades this source to plain
    /// `USPEC/1` for its whole lifetime.
    pub fn peer_v2(&self) -> bool {
        self.peer_v2
    }

    /// `(hits, misses)` of the decoded-chunk cache — operational
    /// telemetry; always `(0, 0)` when the cache is disabled (a zero
    /// budget constructs no cache at all, so nothing is ever counted).
    pub fn cache_stats(&self) -> (u64, u64) {
        match &self.cache {
            Some(c) => c.lock().unwrap_or_else(|e| e.into_inner()).stats(),
            None => (0, 0),
        }
    }

    /// Round-trip liveness check; returns the request latency.
    pub fn ping(&self) -> Result<Duration> {
        let t = Instant::now();
        self.with_conn("ping", |conn| {
            write_frame(conn, OP_PING, &[PROTO_V2])?;
            let (op, _) = read_frame(conn, 64)?;
            match op {
                OP_PONG => Ok(()),
                other => Err(unexpected(other, "Pong")),
            }
        })?;
        Ok(t.elapsed())
    }

    /// Capability negotiation, run once at connect: advertise `USPEC/2`
    /// in the Ping payload and look for the server's [`PROTO_V2`]
    /// capability byte in the Pong. A v1 server ignores the request
    /// payload and answers an empty Pong — the downgrade path.
    fn negotiate(&self) -> Result<bool> {
        self.with_conn("negotiate", |conn| {
            write_frame(conn, OP_PING, &[PROTO_V2])?;
            let (op, caps) = read_frame(conn, 64)?;
            match op {
                OP_PONG => Ok(caps.contains(&PROTO_V2)),
                OP_ERR => Err(server_error(&caps)),
                other => Err(unexpected(other, "Pong")),
            }
        })
    }

    fn fetch_meta(&self) -> Result<(usize, usize)> {
        self.with_conn("meta", |conn| {
            write_frame(conn, OP_META, &[])?;
            let (op, payload) = read_frame(conn, 64)?;
            match op {
                OP_META_RESP => {
                    let (n, d) = decode_meta(&payload)?;
                    let n = usize::try_from(n)
                        .map_err(|_| Error::Net(format!("remote n={n} exceeds usize")))?;
                    let d = usize::try_from(d)
                        .map_err(|_| Error::Net(format!("remote d={d} exceeds usize")))?;
                    Ok((n, d))
                }
                OP_ERR => Err(server_error(&payload)),
                other => Err(unexpected(other, "MetaResp")),
            }
        })
    }

    /// Dial a fresh connection with all deadlines armed.
    fn dial(&self) -> Result<TcpStream> {
        let conn = TcpStream::connect_timeout(&self.addr, self.opts.connect_timeout)
            .map_err(|e| Error::Net(format!("{}: connect failed: {e}", self.label)))?;
        conn.set_read_timeout(Some(self.opts.io_timeout))?;
        conn.set_write_timeout(Some(self.opts.io_timeout))?;
        let _ = conn.set_nodelay(true);
        Ok(conn)
    }

    /// Run one request on a pooled (or fresh) connection, retrying
    /// transient failures with exponential backoff. On success the
    /// connection returns to the pool (capped by `USPEC_NET_POOL`); on
    /// any failure it is dropped — a half-read stream, pipelined
    /// in-flight frames included, must never serve the next request.
    fn with_conn<T>(
        &self,
        what: &str,
        mut f: impl FnMut(&mut TcpStream) -> Result<T>,
    ) -> Result<T> {
        let mut last: Option<Error> = None;
        for attempt in 0..=self.opts.retries {
            if attempt > 0 {
                let shift = (attempt - 1).min(4) as u32;
                std::thread::sleep(self.opts.backoff * (1u32 << shift));
            }
            let pooled = self.lock_pool().pop();
            let mut conn = match pooled {
                Some(c) => c,
                None => match self.dial() {
                    Ok(c) => c,
                    Err(e) => {
                        last = Some(e);
                        continue;
                    }
                },
            };
            match f(&mut conn) {
                Ok(v) => {
                    let mut pool = self.lock_pool();
                    if pool.len() < net_pool() {
                        pool.push(conn);
                    }
                    return Ok(v);
                }
                // Transport-class failures retry on a fresh connection;
                // everything else (server-reported InvalidArg) is final.
                Err(e @ (Error::Io(_) | Error::Net(_))) => last = Some(e),
                Err(e) => return Err(e),
            }
        }
        let last = last.expect("at least one attempt ran");
        Err(Error::Net(format!(
            "{}: {what} failed after {} attempts: {last}",
            self.label,
            self.opts.retries + 1
        )))
    }

    /// The pipelined row exchange: write every sub-range request, then
    /// read the responses in order, appending decoded floats into `buf`.
    /// The split is purely operational — the assembled bytes are
    /// identical to a single-frame read of `[start, start + len)`.
    fn exchange_rows(
        &self,
        conn: &mut TcpStream,
        start: usize,
        len: usize,
        buf: &mut Mat,
    ) -> Result<()> {
        let d = self.d;
        let compress = self.peer_v2 && self.opts.compress;
        let parts = PIPELINE_DEPTH.min(len);
        let (base, rem) = (len / parts, len % parts);
        let mut ranges = Vec::with_capacity(parts);
        let mut at = start;
        for i in 0..parts {
            let l = base + usize::from(i < rem);
            ranges.push((at, l));
            at += l;
        }
        for &(s, l) in &ranges {
            if compress {
                let req = encode_read_rows_v2(s as u64, l as u64, FLAG_COMPRESS);
                write_frame(conn, OP_READ_ROWS, &req)?;
            } else {
                write_frame(conn, OP_READ_ROWS, &encode_read_rows(s as u64, l as u64))?;
            }
        }
        buf.rows = len;
        buf.cols = d;
        buf.data.clear();
        buf.data.reserve(len * d);
        for &(s, l) in &ranges {
            let expect = l * d * 4;
            // Cap: the exact payload plus header slack; compressed frames
            // are strictly smaller by construction. Anything larger is a
            // corrupt frame, not a bigger answer.
            let (op, payload) = read_frame(conn, expect + 64)?;
            match op {
                OP_ROWS => append_rows(&payload, expect, &mut buf.data)?,
                OP_ROWS_C if compress => {
                    let raw = super::codec::decompress(&payload, expect)?;
                    append_rows(&raw, expect, &mut buf.data)?;
                }
                OP_ERR => return Err(server_error(&payload)),
                other => {
                    return Err(unexpected(other, if compress { "Rows/RowsC" } else { "Rows" }))
                }
            }
            debug_assert_eq!(buf.data.len(), (s + l - start) * d);
        }
        Ok(())
    }

    fn lock_pool(&self) -> std::sync::MutexGuard<'_, Vec<TcpStream>> {
        self.pool.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Validate a raw-rows payload length and append its decoded f32s.
fn append_rows(payload: &[u8], expect: usize, out: &mut Vec<f32>) -> Result<()> {
    if payload.len() != expect {
        return Err(Error::Net(format!(
            "Rows payload {} bytes, want {expect}",
            payload.len()
        )));
    }
    out.extend(payload.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())));
    Ok(())
}

impl DataSource for RemoteSource {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(start + len <= self.n, "read_rows: out of range");
        ensure_arg!(len >= 1, "read_rows: len must be >= 1");
        let key = (start as u64, len as u64);
        if let Some(cache) = &self.cache {
            if let Some(rows) = cache.lock().unwrap_or_else(|e| e.into_inner()).get(&key) {
                buf.rows = len;
                buf.cols = self.d;
                buf.data.clear();
                buf.data.extend_from_slice(rows);
                return Ok(());
            }
        }
        self.with_conn("read_rows", |conn| self.exchange_rows(conn, start, len, buf))?;
        if let Some(cache) = &self.cache {
            cache
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .insert(key, buf.data.clone(), len * self.d * 4);
        }
        Ok(())
    }

    /// A network round-trip per chunk is a high-latency serial-ish
    /// backend: the walk planner schedules few walkers with deep
    /// prefetch and skips the local-storage probe.
    fn storage_hint(&self) -> Option<StorageProfile> {
        Some(StorageProfile::Remote)
    }
}

/// A server-reported failure: the request was delivered and rejected, so
/// retrying cannot help — surfaced as `InvalidArg`, the non-retryable
/// class.
fn server_error(payload: &[u8]) -> Error {
    Error::InvalidArg(format!("remote shard server: {}", String::from_utf8_lossy(payload)))
}

/// A well-formed frame of the wrong type: protocol confusion, treated as
/// transient (the retry gets a fresh connection and a clean stream).
fn unexpected(op: u8, want: &str) -> Error {
    Error::Net(format!("unexpected frame opcode {op:#04x} (want {want})"))
}
