//! A bounded-byte LRU — the chunk cache both ends of the wire share.
//!
//! The client keeps decoded row-range chunks (so U-SENC's `1 + m`
//! repeated sweeps over the selection/KNR window hit memory instead of
//! the wire); the server keeps encoded frame payloads (so `m` clients
//! asking for the same shard reuse one compression pass). Both are
//! instances of the same structure: a map from a small key to a value
//! with a known byte weight, evicting least-recently-used entries until
//! the total stays within a fixed byte budget.
//!
//! Caching is *purely operational*: a hit returns exactly the bytes a
//! miss would have produced (sources are immutable for the lifetime of a
//! run, like the on-disk `BinDataset`), so the pinned
//! labels/sigma/embedding invariant cannot observe it. A budget of 0
//! disables the cache entirely — [`ByteLru::insert`] refuses every
//! entry, and lookups always miss.

use std::collections::{BTreeMap, HashMap};
use std::hash::Hash;

/// An LRU map bounded by total value bytes rather than entry count.
/// Recency is a monotone tick: `order` maps tick → key, so the smallest
/// tick is always the eviction victim (O(log len) per touch).
#[derive(Debug)]
pub struct ByteLru<K, V> {
    budget: usize,
    bytes: usize,
    tick: u64,
    map: HashMap<K, Entry<V>>,
    order: BTreeMap<u64, K>,
    hits: u64,
    misses: u64,
}

#[derive(Debug)]
struct Entry<V> {
    value: V,
    bytes: usize,
    tick: u64,
}

impl<K: Eq + Hash + Clone, V> ByteLru<K, V> {
    /// An empty cache holding at most `budget` bytes of values.
    pub fn new(budget: usize) -> ByteLru<K, V> {
        ByteLru {
            budget,
            bytes: 0,
            tick: 0,
            map: HashMap::new(),
            order: BTreeMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    /// The configured byte budget.
    pub fn budget(&self) -> usize {
        self.budget
    }

    /// Bytes currently held — never exceeds [`ByteLru::budget`].
    pub fn bytes(&self) -> usize {
        self.bytes
    }

    /// Resident entries.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    /// True when nothing is cached.
    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    /// `(hits, misses)` across the cache's lifetime — operational
    /// telemetry for tests and stats lines.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: &K) -> Option<&V> {
        let Some(entry) = self.map.get_mut(key) else {
            self.misses += 1;
            return None;
        };
        self.hits += 1;
        self.order.remove(&entry.tick);
        self.tick += 1;
        entry.tick = self.tick;
        self.order.insert(self.tick, key.clone());
        Some(&entry.value)
    }

    /// Insert `key → value` weighing `bytes`, evicting LRU entries until
    /// it fits. A value larger than the whole budget (or a zero budget)
    /// is simply not cached — the caller's read path already has the
    /// data; the cache only ever declines to remember it.
    pub fn insert(&mut self, key: K, value: V, bytes: usize) {
        // A zero budget declines even zero-weight entries: a disabled
        // cache must never grow a map (callers that want a *true* no-op —
        // no stats, no allocation — skip constructing the cache entirely,
        // like `RemoteSource` does for `cache_bytes == 0`).
        if self.budget == 0 || bytes > self.budget {
            return;
        }
        if let Some(old) = self.map.remove(&key) {
            self.order.remove(&old.tick);
            self.bytes -= old.bytes;
        }
        while self.bytes + bytes > self.budget {
            let (&oldest, _) = self.order.iter().next().expect("bytes > 0 implies entries");
            let victim = self.order.remove(&oldest).expect("tick just observed");
            let evicted = self.map.remove(&victim).expect("order and map agree");
            self.bytes -= evicted.bytes;
        }
        self.tick += 1;
        self.bytes += bytes;
        self.map.insert(key.clone(), Entry { value, bytes, tick: self.tick });
        self.order.insert(self.tick, key);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stays_within_budget_under_eviction_pressure_and_evicts_lru() {
        let mut lru: ByteLru<u32, Vec<u8>> = ByteLru::new(100);
        for k in 0..50u32 {
            lru.insert(k, vec![0; 10], 10);
            assert!(lru.bytes() <= lru.budget(), "after {k}: {} bytes", lru.bytes());
        }
        // budget 100 / 10-byte entries: exactly the 10 most recent remain
        assert_eq!((lru.len(), lru.bytes()), (10, 100));
        assert!(lru.get(&0).is_none(), "oldest entries were evicted");
        assert!(lru.get(&49).is_some(), "newest entries survive");
    }

    #[test]
    fn get_refreshes_recency() {
        let mut lru: ByteLru<u32, ()> = ByteLru::new(3);
        lru.insert(1, (), 1);
        lru.insert(2, (), 1);
        lru.insert(3, (), 1);
        // touch 1 → 2 becomes the LRU victim
        assert!(lru.get(&1).is_some());
        lru.insert(4, (), 1);
        assert!(lru.get(&2).is_none(), "untouched entry evicted");
        assert!(lru.get(&1).is_some(), "touched entry kept");
        let (hits, misses) = lru.stats();
        assert!(hits >= 2 && misses >= 1, "hits={hits} misses={misses}");
    }

    #[test]
    fn oversized_values_and_zero_budget_are_never_cached() {
        let mut lru: ByteLru<u32, Vec<u8>> = ByteLru::new(8);
        lru.insert(1, vec![0; 9], 9);
        assert!(lru.is_empty(), "oversized value must be declined");
        // a zero budget declines everything — even zero-weight entries —
        // so a disabled cache never grows a map and every lookup misses
        let mut off: ByteLru<u32, ()> = ByteLru::new(0);
        off.insert(1, (), 0);
        off.insert(2, (), 4);
        assert!(off.is_empty(), "zero-budget cache must stay empty");
        assert_eq!((off.len(), off.bytes()), (0, 0));
        assert!(off.get(&1).is_none() && off.get(&2).is_none());
        assert_eq!(off.stats(), (0, 2), "both lookups are misses");
    }

    #[test]
    fn reinserting_a_key_replaces_it_and_adjusts_bytes() {
        let mut lru: ByteLru<u32, Vec<u8>> = ByteLru::new(20);
        lru.insert(7, vec![1; 8], 8);
        lru.insert(7, vec![2; 12], 12);
        assert_eq!((lru.len(), lru.bytes()), (1, 12));
        assert_eq!(lru.get(&7).unwrap()[0], 2, "replacement value served");
    }
}
