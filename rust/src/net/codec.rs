//! Lossless row compression for `USPEC/2` compressed-Rows frames:
//! byte-shuffle + run-length coding, dependency-free.
//!
//! Row payloads are raw little-endian f32 values. The codec **shuffles**
//! the 4 bytes of every float into 4 contiguous planes (all byte-0s,
//! then all byte-1s, …) and then runs a byte-oriented **RLE** pass over
//! the planes. Stretches of *identical* floats — exact zeros in sparse
//! feature rows, padded dimensions, constant or saturated features —
//! become four long byte runs after the shuffle, which is where the wire
//! savings come from; dense rows whose mantissa *and* exponent bytes
//! vary float-to-float produce no runs, the encoding comes out larger
//! than the input, and [`compress`] declines so the server falls back to
//! a plain frame (measured in `BENCH_hotpath.json`'s `net` section). The
//! transform is exactly invertible — decoding reproduces the input
//! bit-for-bit, NaN payloads, denormals and `-0.0` included — so
//! compression can never touch the pinned labels/sigma/embedding
//! invariant.
//!
//! Encoded stream layout (the `OP_ROWS_C` frame payload):
//!
//! ```text
//! offset  size  field
//! 0       4     raw length R (u32 LE) — the decoded byte count
//! 4       ..    RLE stream over the shuffled bytes
//! ```
//!
//! RLE tokens: a control byte `c` followed by data. `c < 0x80` is a
//! literal run — the next `c + 1` bytes (1..=128) are copied verbatim;
//! `c >= 0x80` is a repeat run — the next single byte repeats
//! `(c - 0x80) + 3` times (3..=130). Runs shorter than 3 are folded into
//! literals, so worst-case expansion is 1 control byte per 128 literals
//! (< 0.8%); [`compress`] additionally refuses to return an encoding
//! that is not strictly smaller than the input, so the wire never
//! carries a regression — the server falls back to a plain `OP_ROWS`
//! frame instead.
//!
//! The whole frame (header + compressed payload) still carries the
//! standard FNV-1a trailer, so corruption is caught before decoding;
//! [`decompress`] re-validates every token bound and the declared raw
//! length and rejects malformed streams with [`Error::Net`] (the
//! retryable transport class — a corrupt frame, not a bad request).

use crate::{Error, Result};

/// Shortest byte run worth a repeat token (below this, literals win).
const MIN_RUN: usize = 3;
/// Longest run one repeat token can express: `(0xFF - 0x80) + MIN_RUN`.
const MAX_RUN: usize = 130;
/// Longest literal stretch one control byte can express.
const MAX_LIT: usize = 128;
/// Bytes of the `u32` raw-length prefix.
const LEN_PREFIX: usize = 4;

/// Transpose `raw` (groups of 4 bytes, one per f32) into 4 byte planes.
fn shuffle(raw: &[u8], out: &mut Vec<u8>) {
    debug_assert_eq!(raw.len() % 4, 0);
    out.clear();
    out.reserve(raw.len());
    for plane in 0..4 {
        out.extend(raw[plane..].iter().step_by(4));
    }
}

/// Inverse of [`shuffle`]: interleave 4 byte planes back into f32 bytes.
fn unshuffle(planes: &[u8], out: &mut Vec<u8>) {
    debug_assert_eq!(planes.len() % 4, 0);
    let stride = planes.len() / 4;
    out.clear();
    out.resize(planes.len(), 0);
    for plane in 0..4 {
        for (i, &b) in planes[plane * stride..(plane + 1) * stride].iter().enumerate() {
            out[i * 4 + plane] = b;
        }
    }
}

/// RLE-encode `input`, appending tokens to `out`.
fn rle_encode(input: &[u8], out: &mut Vec<u8>) {
    let n = input.len();
    let mut i = 0;
    while i < n {
        // length of the run starting at i
        let mut run = 1;
        while i + run < n && input[i + run] == input[i] && run < MAX_RUN {
            run += 1;
        }
        if run >= MIN_RUN {
            out.push(0x80 + (run - MIN_RUN) as u8);
            out.push(input[i]);
            i += run;
            continue;
        }
        // literal stretch: until a worthwhile run starts or the token caps
        let start = i;
        while i < n && i - start < MAX_LIT {
            if i + MIN_RUN <= n && input[i..i + MIN_RUN].iter().all(|&b| b == input[i]) {
                break;
            }
            i += 1;
        }
        out.push((i - start - 1) as u8);
        out.extend_from_slice(&input[start..i]);
    }
}

/// RLE-decode `stream` into exactly `expect` bytes. Any out-of-bounds
/// token, trailing garbage, or length mismatch is a malformed stream.
fn rle_decode(stream: &[u8], expect: usize, out: &mut Vec<u8>) -> Result<()> {
    out.clear();
    out.reserve(expect);
    let mut i = 0;
    while i < stream.len() {
        let c = stream[i] as usize;
        i += 1;
        if c < 0x80 {
            let lit = c + 1;
            if i + lit > stream.len() {
                return Err(malformed("literal token overruns the stream"));
            }
            out.extend_from_slice(&stream[i..i + lit]);
            i += lit;
        } else {
            let run = (c - 0x80) + MIN_RUN;
            if i >= stream.len() {
                return Err(malformed("repeat token missing its byte"));
            }
            out.resize(out.len() + run, stream[i]);
            i += 1;
        }
        if out.len() > expect {
            return Err(malformed("decoded length exceeds the declared raw length"));
        }
    }
    if out.len() != expect {
        return Err(malformed("decoded length short of the declared raw length"));
    }
    Ok(())
}

/// Compress a raw row payload (little-endian f32 bytes, length a
/// multiple of 4). Returns `None` when the encoding is not strictly
/// smaller than `raw` — the caller then sends the plain frame, so
/// incompressible data costs nothing extra on the wire.
pub fn compress(raw: &[u8]) -> Option<Vec<u8>> {
    if raw.is_empty() || raw.len() % 4 != 0 {
        return None;
    }
    let mut planes = Vec::new();
    shuffle(raw, &mut planes);
    let mut out = Vec::with_capacity(LEN_PREFIX + raw.len() / 2);
    out.extend_from_slice(&(raw.len() as u32).to_le_bytes());
    rle_encode(&planes, &mut out);
    (out.len() < raw.len()).then_some(out)
}

/// Decompress an `OP_ROWS_C` payload back into raw f32 bytes,
/// validating the declared raw length against `expect_raw` (the byte
/// count of the rows the client asked for) and every token bound.
pub fn decompress(comp: &[u8], expect_raw: usize) -> Result<Vec<u8>> {
    if comp.len() < LEN_PREFIX {
        return Err(malformed("payload shorter than the length prefix"));
    }
    let declared = u32::from_le_bytes(comp[..LEN_PREFIX].try_into().unwrap()) as usize;
    if declared != expect_raw {
        return Err(malformed(&format!(
            "declared raw length {declared}, want {expect_raw}"
        )));
    }
    if declared % 4 != 0 {
        return Err(malformed("raw length is not a whole number of f32s"));
    }
    let mut planes = Vec::new();
    rle_decode(&comp[LEN_PREFIX..], declared, &mut planes)?;
    let mut raw = Vec::new();
    unshuffle(&planes, &mut raw);
    Ok(raw)
}

fn malformed(what: &str) -> Error {
    Error::Net(format!("compressed rows: {what}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn raw_bytes(vals: &[f32]) -> Vec<u8> {
        vals.iter().flat_map(|v| v.to_le_bytes()).collect()
    }

    fn roundtrip(raw: &[u8]) -> Option<Vec<u8>> {
        compress(raw).map(|c| decompress(&c, raw.len()).unwrap())
    }

    #[test]
    fn adversarial_values_roundtrip_bit_exactly() {
        // NaN payload bits, denormals, ±0.0, ±inf, extremes — repeated so
        // the stream is compressible and the repeat-token path runs too.
        let mut vals = Vec::new();
        for _ in 0..64 {
            vals.extend_from_slice(&[
                f32::from_bits(0x7FC0_0001), // quiet NaN with payload
                f32::from_bits(0xFF80_0001), // signalling NaN pattern
                f32::MIN_POSITIVE / 2.0,     // denormal
                3.25e-40,                    // denormal
                0.0,
                -0.0,
                f32::INFINITY,
                f32::NEG_INFINITY,
                f32::MAX,
                f32::MIN,
                1.5,
                -7.125,
            ]);
        }
        let raw = raw_bytes(&vals);
        let back = roundtrip(&raw).expect("repetitive stream must compress");
        assert_eq!(raw, back, "byte-exact roundtrip");
    }

    #[test]
    fn incompressible_random_rows_fall_back_to_plain() {
        let mut rng = Rng::new(0xC0DEC);
        let raw: Vec<u8> = (0..4096).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        // uniform random bytes cannot shrink: compress declines...
        assert!(compress(&raw).is_none(), "random bytes must not 'compress'");
        // ...but a forced encode of the same planes still roundtrips
        let mut planes = Vec::new();
        shuffle(&raw, &mut planes);
        let mut enc = Vec::new();
        rle_encode(&planes, &mut enc);
        let mut dec = Vec::new();
        rle_decode(&enc, planes.len(), &mut dec).unwrap();
        assert_eq!(planes, dec);
    }

    #[test]
    fn sparse_clustered_rows_shrink_at_least_2x() {
        // The wire's compressible workload: sparse feature rows
        // (MNIST-style) — each row carries a couple of active dims near
        // its cluster's center and exact 0.0 everywhere else, so every
        // shuffled byte plane is mostly zero runs. (Dense rows whose
        // bytes vary float-to-float produce no runs and fall back to
        // plain frames — the random-rows test above.)
        let mut rng = Rng::new(7);
        let (d, active) = (16usize, 2usize);
        let centers = [[1.5f32, -0.75], [0.5, 2.25]];
        let mut vals = vec![0.0f32; 2048 * d];
        for i in 0..2048 {
            let c = &centers[i % 2];
            let off = (i % 2) * active; // disjoint active dims per center
            for (j, &base) in c.iter().enumerate() {
                let jitter = ((rng.next_u64() & 0xFF) as f32 / 255.0 - 0.5) * 1e-3;
                vals[i * d + off + j] = base + jitter;
            }
        }
        let raw = raw_bytes(&vals);
        let comp = compress(&raw).expect("sparse clustered rows must compress");
        assert!(
            comp.len() * 2 <= raw.len(),
            "want >= 2x on sparse clustered data, got {} -> {} bytes",
            raw.len(),
            comp.len()
        );
        assert_eq!(decompress(&comp, raw.len()).unwrap(), raw);
    }

    #[test]
    fn run_length_edges_roundtrip() {
        // exact MIN_RUN, exact MAX_RUN, MAX_RUN+1, and a MAX_LIT literal
        for n in [MIN_RUN, MAX_RUN, MAX_RUN + 1, 4 * MAX_LIT] {
            let mut input = vec![0xABu8; n];
            if n == 4 * MAX_LIT {
                // strictly alternating: no run ever reaches MIN_RUN
                for (i, b) in input.iter_mut().enumerate() {
                    *b = (i % 2) as u8;
                }
            }
            let mut enc = Vec::new();
            rle_encode(&input, &mut enc);
            let mut dec = Vec::new();
            rle_decode(&enc, input.len(), &mut dec).unwrap();
            assert_eq!(input, dec, "n={n}");
        }
    }

    #[test]
    fn malformed_streams_are_typed_errors() {
        let vals: Vec<f32> = (0..256).map(|i| (i / 8) as f32).collect();
        let raw = raw_bytes(&vals);
        let comp = compress(&raw).unwrap();
        // truncated payload: literal/repeat token overruns
        let err = decompress(&comp[..comp.len() - 1], raw.len()).unwrap_err();
        assert!(matches!(err, Error::Net(_)), "{err}");
        // declared length lies
        let err = decompress(&comp, raw.len() + 4).unwrap_err();
        assert!(err.to_string().contains("declared raw length"), "{err}");
        // shorter than the length prefix at all
        assert!(decompress(&[1, 2], 8).is_err());
        // non-f32 declared length
        let mut bad = comp.clone();
        bad[..4].copy_from_slice(&3u32.to_le_bytes());
        assert!(decompress(&bad, 3).is_err());
        // stream decodes past the declared length
        let mut long = comp.clone();
        long.extend_from_slice(&[0x00, 0xEE]); // one extra literal byte
        let err = decompress(&long, raw.len()).unwrap_err();
        assert!(matches!(err, Error::Net(_)), "{err}");
    }
}
