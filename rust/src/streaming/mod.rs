//! Out-of-core execution: the on-disk dataset format plus thin wrappers
//! over the staged engine in [`crate::pipeline`].
//!
//! This module owns [`BinDataset`] — a flat row-major f32 file — and its
//! [`DataSource`] implementation. The clustering itself contains **no
//! pipeline logic of its own** anymore: [`stream_uspec`] is
//! `Pipeline::run` with the caller's execution knobs, and
//! [`stream_usenc`] is [`crate::usenc::usenc_opts`]. Because the engine's
//! sweeps are chunk-size, shard-count, and source invariant, an on-disk
//! run produces labels bit-identical to the in-memory run for the same
//! seed (`rust/tests/pipeline_equivalence.rs`,
//! `rust/tests/sharded_equivalence.rs`) — with `shards > 1`, the KNR
//! passes walk disjoint row ranges of the file concurrently, each
//! prefetching its next chunk while computing on the current one. How
//! many walkers run at once and how deep each one prefetches is chosen
//! by the adaptive walk planner ([`crate::pipeline::plan_walk`]), seeded
//! either by a storage probe or by an explicit
//! [`crate::pipeline::StorageProfile`] hint.
//!
//! Resident peak of an out-of-core run is
//! `O(N·K + walkers·depth·chunk·d + p·d)` — independent of `N·d`, which
//! only ever streams off disk (each concurrent walker holds
//! `depth + 1` chunk buffers for its prefetch pipeline). The paper's
//! motivation is "ten-million-level datasets on a PC with 64 GB memory"
//! (§1); the on-disk path takes the limited-resource premise one step
//! further.

use crate::affinity::DistanceBackend;
use crate::linalg::Mat;
use crate::pipeline::{
    plan_walk, reservoir_multi, DataSource, ExecOpts, Pipeline, StorageProfile,
};
use crate::usenc::{usenc_opts, UsencParams, UsencResult};
use crate::uspec::UspecParams;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Error, Result};
use std::io::{BufWriter, Read, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

/// Magic bytes of the on-disk format (`USPECBIN` v1).
const MAGIC: &[u8; 8] = b"USPECB01";

/// A dense row-major f32 dataset on disk: 8-byte magic, u64 n, u64 d,
/// then `n·d` little-endian f32 values. Labels (if any) live elsewhere —
/// the clustering path never needs them.
pub struct BinDataset {
    path: PathBuf,
    n: usize,
    d: usize,
}

impl BinDataset {
    /// Create a file and stream rows into it via the returned writer.
    pub fn create(path: &Path, d: usize) -> Result<BinWriter> {
        ensure_arg!(d >= 1, "BinDataset: d must be >= 1");
        let file = std::fs::File::create(path)?;
        let mut w = BufWriter::new(file);
        w.write_all(MAGIC)?;
        w.write_all(&0u64.to_le_bytes())?; // n patched on finish
        w.write_all(&(d as u64).to_le_bytes())?;
        Ok(BinWriter { w: Some(w), path: path.to_path_buf(), d, n: 0 })
    }

    /// Open an existing file, validating the header.
    pub fn open(path: &Path) -> Result<BinDataset> {
        let mut f = std::fs::File::open(path)?;
        let mut header = [0u8; 24];
        f.read_exact(&mut header)
            .map_err(|_| Error::InvalidArg(format!("{}: truncated header", path.display())))?;
        if &header[..8] != MAGIC {
            return Err(Error::InvalidArg(format!("{}: not a USPECB01 file", path.display())));
        }
        let n64 = u64::from_le_bytes(header[8..16].try_into().unwrap());
        let d64 = u64::from_le_bytes(header[16..24].try_into().unwrap());
        ensure_arg!(d64 >= 1, "{}: d=0", path.display());
        // Checked u64 math throughout: a corrupt header must produce a
        // clear error, never an overflowed size that happens to match.
        let expect = n64
            .checked_mul(d64)
            .and_then(|v| v.checked_mul(4))
            .and_then(|v| v.checked_add(24))
            .ok_or_else(|| {
                Error::InvalidArg(format!(
                    "{}: header n={n64} d={d64} overflows the format",
                    path.display()
                ))
            })?;
        let len = f.metadata()?.len();
        if len != expect {
            return Err(Error::InvalidArg(format!(
                "{}: size {len} != expected {expect} (n={n64}, d={d64})",
                path.display()
            )));
        }
        let n = usize::try_from(n64)
            .map_err(|_| Error::InvalidArg(format!("{}: n={n64} exceeds usize", path.display())))?;
        let d = usize::try_from(d64)
            .map_err(|_| Error::InvalidArg(format!("{}: d={d64} exceeds usize", path.display())))?;
        Ok(BinDataset { path: path.to_path_buf(), n, d })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn d(&self) -> usize {
        self.d
    }

    /// Read rows `[start, start+len)` into a dense matrix.
    pub fn read_chunk(&self, start: usize, len: usize) -> Result<Mat> {
        let mut m = Mat::zeros(0, self.d);
        self.read_rows(start, len, &mut m)?;
        Ok(m)
    }

    /// Sequentially visit the dataset in chunks of `chunk` rows.
    pub fn for_each_chunk(
        &self,
        chunk: usize,
        f: impl FnMut(usize, &Mat) -> Result<()>,
    ) -> Result<()> {
        crate::pipeline::for_each_chunk(self, chunk, f)
    }

    /// Write an in-memory matrix to disk (test/example helper).
    pub fn write_mat(path: &Path, x: &Mat) -> Result<BinDataset> {
        let mut w = BinDataset::create(path, x.cols)?;
        for i in 0..x.rows {
            w.push_row(x.row(i))?;
        }
        w.finish()
    }
}

impl DataSource for BinDataset {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(start + len <= self.n, "read_rows: out of range");
        let mut f = std::fs::File::open(&self.path)?;
        let offset = 24 + (start as u64) * (self.d as u64) * 4;
        f.seek(SeekFrom::Start(offset))?;
        let mut bytes = vec![0u8; len * self.d * 4];
        // A short read means the file shrank or was swapped out from
        // under us — name the range instead of surfacing a bare EOF, and
        // fill nothing: the caller sees an error, never partial rows.
        f.read_exact(&mut bytes).map_err(|e| {
            Error::InvalidArg(format!(
                "{}: truncated read of rows [{start}, {}): {e} (file changed since open?)",
                self.path.display(),
                start + len
            ))
        })?;
        buf.rows = len;
        buf.cols = self.d;
        buf.data.clear();
        buf.data.extend(
            bytes.chunks_exact(4).map(|b| f32::from_le_bytes(b.try_into().unwrap())),
        );
        Ok(())
    }
}

/// Incremental writer returned by [`BinDataset::create`].
pub struct BinWriter {
    w: Option<BufWriter<std::fs::File>>,
    path: PathBuf,
    d: usize,
    n: usize,
}

impl BinWriter {
    pub fn push_row(&mut self, row: &[f32]) -> Result<()> {
        ensure_arg!(row.len() == self.d, "push_row: got {} dims, want {}", row.len(), self.d);
        let w = self.w.as_mut().expect("writer finished");
        for v in row {
            w.write_all(&v.to_le_bytes())?;
        }
        self.n += 1;
        Ok(())
    }

    /// Flush, patch the row count into the header, and reopen read-only.
    pub fn finish(mut self) -> Result<BinDataset> {
        let w = self.w.take().expect("writer finished twice");
        let mut file = w.into_inner().map_err(|e| Error::Io(e.into_error()))?;
        file.seek(SeekFrom::Start(8))?;
        file.write_all(&(self.n as u64).to_le_bytes())?;
        file.sync_all()?;
        drop(file);
        BinDataset::open(&self.path)
    }
}

/// Resource limits for the streaming wrappers.
#[derive(Debug, Clone)]
pub struct StreamParams {
    /// Rows per chunk in every sweep (the resident working set is
    /// `shards × chunk × d` f32s plus the growing sparse B).
    pub chunk: usize,
    /// Row-range shards walked concurrently per order-free pass (KNR
    /// queries); selection sweeps stay row-ordered but prefetch. Never
    /// changes the labels.
    pub shards: usize,
    /// Storage profile hint for the walk planner (`Auto` probes the
    /// source once per sharded pass). Operational only, like `shards`.
    pub storage: StorageProfile,
    /// Decoded-chunk LRU budget in bytes the caller gave its remote
    /// source ([`crate::net::NetOpts::cache_bytes`]); 0 = no cache. The
    /// peak model charges it — the cache is resident memory traded for
    /// wire round-trips, so the budget must show up in the N/A model.
    pub net_cache: usize,
    /// U-SPEC hyper-parameters (p, K, k, solver, ...). Random and hybrid
    /// selection sweep the disk; k-means-full needs resident data and is
    /// rejected for on-disk sources.
    pub base: UspecParams,
}

impl Default for StreamParams {
    fn default() -> Self {
        StreamParams {
            chunk: crate::pipeline::DEFAULT_CHUNK,
            shards: 1,
            storage: StorageProfile::Auto,
            net_cache: 0,
            base: UspecParams::default(),
        }
    }
}

/// Streaming result: labels plus the observed resident-memory model.
#[derive(Debug)]
pub struct StreamResult {
    pub labels: Vec<u32>,
    /// Estimated peak resident bytes of the pipeline (B + chunk + index).
    pub peak_bytes: u64,
    pub timer: PhaseTimer,
}

/// Single-pass reservoir sample of `size` rows (Vitter's Algorithm R),
/// reading the dataset sequentially in `chunk`-row blocks. Thin wrapper
/// over [`crate::pipeline::reservoir_multi`].
pub fn reservoir_sample(ds: &BinDataset, size: usize, chunk: usize, seed: u64) -> Result<Mat> {
    let size = size.min(ds.n());
    ensure_arg!(size >= 1, "reservoir_sample: empty sample");
    let mut specs = vec![(size, Rng::new(seed ^ 0x9E5E_2B01))];
    let mut outs = reservoir_multi(ds, chunk, &mut specs)?;
    Ok(outs.pop().expect("one reservoir"))
}

/// Modeled resident peak of an out-of-core run: sparse B
/// (idx u32 + d2 f32 + csr f64) + chunk buffers (`depth + 1` per
/// concurrent shard walker, mirroring [`plan_walk`]) + representative
/// index + embedding. A source that knows its backend
/// ([`DataSource::storage_hint`], e.g. a remote source) pins the buffer
/// count to that profile's walk shape; since an `Auto` run over an
/// unhinted source resolves its profile only at walk time, the model
/// then takes the max over the profiles the planner can pick. A
/// non-zero `net_cache` (the remote decoded-chunk LRU budget) is
/// charged in full: the LRU fills to its budget on any multi-pass run.
fn peak_model(
    n: usize,
    d: usize,
    chunk: usize,
    shards: usize,
    net_cache: usize,
    base: &UspecParams,
    hint: Option<StorageProfile>,
) -> u64 {
    let k_nn = base.k_nn.min(base.p);
    let budget = crate::util::par::num_threads().max(1);
    let bufs = |profile| {
        let wp = plan_walk(profile, shards.max(1), budget);
        wp.walkers * (wp.prefetch_depth + 1)
    };
    let chunk_bufs = match hint {
        Some(p) => bufs(p),
        None => bufs(StorageProfile::Serial).max(bufs(StorageProfile::Parallel)),
    };
    (n * k_nn) as u64 * (4 + 4 + 8 + 4)
        + (chunk_bufs * chunk * d) as u64 * 4
        + (base.p * d) as u64 * 4
        + (n * base.k) as u64 * 4
        + net_cache as u64
}

/// Out-of-core U-SPEC over any non-resident source — an on-disk
/// [`BinDataset`], a [`crate::net::RemoteSource`], or a mixed
/// [`crate::pipeline::SegmentedSource`]: [`Pipeline::run`] with the
/// caller's execution knobs.
pub fn stream_uspec(
    ds: &dyn DataSource,
    params: &StreamParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<StreamResult> {
    let base = params.base.clamped(ds.n());
    let opts = ExecOpts {
        chunk: params.chunk,
        shards: params.shards,
        storage: params.storage,
        net_cache: params.net_cache,
    };
    let res = Pipeline::new(backend).with_opts(opts).run(ds, &base, seed)?;
    let peak_bytes = peak_model(
        ds.n(),
        ds.d(),
        params.chunk,
        params.shards,
        params.net_cache,
        &base,
        ds.storage_hint(),
    );
    Ok(StreamResult { labels: res.labels, peak_bytes, timer: res.timer })
}

/// Out-of-core U-SENC over any non-resident source:
/// [`crate::usenc::usenc_opts`] with the caller's execution knobs. The m
/// candidate sweeps share one pass over the source; each base clusterer
/// streams its own KNR pass (shard-parallel when `opts.shards > 1`), so
/// the resident peak stays at single-clusterer scale.
pub fn stream_usenc(
    ds: &dyn DataSource,
    params: &UsencParams,
    opts: ExecOpts,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<UsencResult> {
    usenc_opts(ds, params, seed, backend, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::data::synthetic::{concentric_circles, two_moons};
    use crate::metrics::nmi;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join("uspec_stream_tests");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn bin_roundtrip() {
        let ds = two_moons(257, 0.05, 1); // deliberately not chunk-aligned
        let path = tmp("roundtrip.bin");
        let bin = BinDataset::write_mat(&path, &ds.x).unwrap();
        assert_eq!(bin.n(), 257);
        assert_eq!(bin.d(), 2);
        let back = bin.read_chunk(0, 257).unwrap();
        assert_eq!(back.data, ds.x.data);
        // chunked reads agree with one-shot
        let mut rows = 0;
        bin.for_each_chunk(100, |start, m| {
            for i in 0..m.rows {
                assert_eq!(m.row(i), ds.x.row(start + i));
            }
            rows += m.rows;
            Ok(())
        })
        .unwrap();
        assert_eq!(rows, 257);
    }

    #[test]
    fn open_rejects_corruption() {
        let path = tmp("corrupt.bin");
        std::fs::write(&path, b"not a dataset").unwrap();
        assert!(BinDataset::open(&path).is_err());
        // truncated payload
        let ds = two_moons(50, 0.05, 2);
        let good = tmp("trunc.bin");
        BinDataset::write_mat(&good, &ds.x).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        std::fs::write(&good, &bytes[..bytes.len() - 4]).unwrap();
        assert!(BinDataset::open(&good).is_err());
    }

    #[test]
    fn clipped_file_read_is_a_proper_error_not_a_short_read() {
        let ds = two_moons(100, 0.05, 13);
        let path = tmp("clipped.bin");
        let bin = BinDataset::write_mat(&path, &ds.x).unwrap();
        // clip the payload after open: only the first 50 rows survive
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..24 + 50 * 2 * 4]).unwrap();
        let mut buf = Mat::zeros(0, 2);
        // reads inside the surviving prefix still work...
        bin.read_rows(0, 50, &mut buf).unwrap();
        assert_eq!(buf.rows, 50);
        // ...reads past the cut are a named error, never partial rows
        let err = bin.read_rows(40, 20, &mut buf).unwrap_err();
        assert!(err.to_string().contains("truncated"), "{err}");
        assert!(err.to_string().contains("[40, 60)"), "{err}");
        // and a fresh open rejects the size mismatch outright
        assert!(BinDataset::open(&path).is_err());
    }

    #[test]
    fn reservoir_uniformity() {
        // sample 1 row from n=100 many times: each row should appear
        // roughly uniformly (chi-square-lite bound).
        let mut x = Mat::zeros(100, 1);
        for i in 0..100 {
            x.set(i, 0, i as f32);
        }
        let path = tmp("reservoir.bin");
        let bin = BinDataset::write_mat(&path, &x).unwrap();
        let mut counts = vec![0u32; 100];
        for s in 0..3000 {
            let m = reservoir_sample(&bin, 1, 17, s).unwrap();
            counts[m.at(0, 0) as usize] += 1;
        }
        let (min, max) = (counts.iter().min().unwrap(), counts.iter().max().unwrap());
        assert!(*min >= 5, "min count {min}");
        assert!(*max <= 70, "max count {max}");
    }

    #[test]
    fn streamed_uspec_clusters_circles() {
        let ds = concentric_circles(3000, 5);
        let path = tmp("circles.bin");
        let bin = BinDataset::write_mat(&path, &ds.x).unwrap();
        let params = StreamParams {
            chunk: 700, // force multiple chunks per sweep
            shards: 1,
            base: UspecParams { k: 3, p: 250, ..Default::default() },
            ..Default::default()
        };
        let res = stream_uspec(&bin, &params, 42, &NativeBackend).unwrap();
        let score = nmi(&res.labels, &ds.y);
        assert!(score > 0.9, "nmi={score}");
        // resident model must be far below the dense footprint
        let dense = (bin.n() * bin.d() * 4) as u64;
        assert!(res.peak_bytes < 40 * dense, "peak={} dense={dense}", res.peak_bytes);
    }

    #[test]
    fn streamed_equals_in_memory() {
        // The wrapper claim made precise: one engine, so the on-disk run
        // IS the in-memory run for a fixed seed.
        let ds = two_moons(2000, 0.06, 9);
        let path = tmp("moons.bin");
        let bin = BinDataset::write_mat(&path, &ds.x).unwrap();
        let params = StreamParams {
            chunk: 512,
            shards: 3, // sharded walk must still be the in-memory run
            base: UspecParams { k: 2, p: 200, ..Default::default() },
            ..Default::default()
        };
        let streamed = stream_uspec(&bin, &params, 7, &NativeBackend).unwrap();
        let in_mem = crate::uspec::uspec(
            &ds.x,
            &UspecParams { k: 2, p: 200, ..Default::default() },
            7,
        )
        .unwrap();
        assert_eq!(streamed.labels, in_mem.labels);
        let s_nmi = nmi(&streamed.labels, &ds.y);
        assert!(s_nmi > 0.85, "streamed nmi={s_nmi}");
    }

    #[test]
    fn streamed_usenc_runs_from_disk() {
        let ds = two_moons(900, 0.06, 12);
        let path = tmp("usenc.bin");
        let bin = BinDataset::write_mat(&path, &ds.x).unwrap();
        let params = UsencParams {
            k: 2,
            m: 4,
            k_min: 4,
            k_max: 9,
            base: UspecParams { p: 90, ..Default::default() },
        };
        let opts = ExecOpts { chunk: 256, shards: 2, ..ExecOpts::default() };
        let res = stream_usenc(&bin, &params, opts, 21, &NativeBackend).unwrap();
        assert_eq!(res.ensemble.m(), 4);
        let score = nmi(&res.labels, &ds.y);
        assert!(score > 0.8, "streamed usenc nmi={score}");
    }

    #[test]
    fn writer_validates_dims() {
        let path = tmp("dims.bin");
        let mut w = BinDataset::create(&path, 3).unwrap();
        assert!(w.push_row(&[1.0, 2.0]).is_err());
        w.push_row(&[1.0, 2.0, 3.0]).unwrap();
        let bin = w.finish().unwrap();
        assert_eq!((bin.n(), bin.d()), (1, 3));
    }
}
