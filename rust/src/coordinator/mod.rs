//! Leader/worker coordinator for ensemble generation (L3's orchestration
//! role). The leader materializes the m base-clusterer job specs up front
//! via [`crate::usenc::derive_jobs`] (so seeds — and therefore results —
//! are identical no matter how many workers run or how jobs interleave)
//! and runs the shared candidate sweeps (one pass over the source per
//! group of [`crate::usenc::sweep_group_size`] jobs — usually one pass
//! for all m selections). Workers claim jobs from an atomic cursor and
//! resume each from its pre-swept candidates; all kernel work funnels through
//! the shared [`crate::runtime::KernelPool`], whose dynamic batcher
//! coalesces concurrent distance requests.
//!
//! The source is any [`DataSource`]: a resident `Mat`, an on-disk
//! `BinDataset`, or a [`crate::net::RemoteSource`] served by another
//! machine — workers stream their own KNR passes, so out-of-core (or
//! over-the-wire) ensembles never materialize the full N×d matrix.

use crate::affinity::DistanceBackend;
use crate::pipeline::{DataSource, ExecOpts, Pipeline};
use crate::usenc::{
    consensus_bipartite, run_job, sweep_job_candidates, Ensemble, UsencParams, UsencResult,
};
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Error, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

pub use crate::usenc::{derive_jobs, JobSpec};

/// Per-job outcome (kept for the coordinator's state/metrics report).
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: usize,
    pub labels: Vec<u32>,
    pub secs: f64,
}

/// Progress observer (job_done, total).
pub type Progress<'a> = &'a (dyn Fn(usize, usize) + Sync);

/// Run the base clusterers across `workers` threads.
/// Results are ordered by job id; identical for any worker count.
pub fn run_base_clusterers(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    workers: usize,
    progress: Option<Progress>,
) -> Result<Ensemble> {
    run_base_clusterers_opts(source, params, seed, backend, workers, progress, ExecOpts::default())
}

/// [`run_base_clusterers`] with explicit execution knobs: every sweep a
/// worker's job streams uses `opts.chunk` rows per chunk and walks the
/// source across `opts.shards` row-range shards (operational only — the
/// ensemble is identical for any knob values).
pub fn run_base_clusterers_opts(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    workers: usize,
    progress: Option<Progress>,
    opts: ExecOpts,
) -> Result<Ensemble> {
    ensure_arg!(params.m >= 1, "coordinator: m must be >= 1");
    let workers = workers.clamp(1, params.m);
    let pipe = Pipeline::new(backend).with_opts(opts);
    let jobs = derive_jobs(params, source.n(), seed);
    let total = jobs.len();
    let group = crate::usenc::sweep_group_size(params, source.n(), source.d()).max(1);
    let abort = AtomicBool::new(false);
    let results: Mutex<Vec<Option<JobResult>>> = Mutex::new((0..total).map(|_| None).collect());
    let first_error: Mutex<Option<Error>> = Mutex::new(None);
    let done = AtomicUsize::new(0);

    // Groups bound the resident candidate sets (see
    // [`crate::usenc::SWEEP_BUDGET_BYTES`]): the leader sweeps one group's
    // reservoirs in a single pass, workers drain that group's jobs from an
    // atomic cursor, then the next group is swept. Results are ordered by
    // job id and identical for any worker count or group size.
    for (g, group_jobs) in jobs.chunks(group).enumerate() {
        if abort.load(Ordering::Relaxed) {
            break;
        }
        let cands = sweep_job_candidates(&pipe, source, params, group_jobs)?;
        let base_idx = g * group;
        let cursor = AtomicUsize::new(0);
        std::thread::scope(|s| {
            for _ in 0..workers.min(group_jobs.len()) {
                s.spawn(|| loop {
                    if abort.load(Ordering::Relaxed) {
                        break;
                    }
                    let i = cursor.fetch_add(1, Ordering::Relaxed);
                    if i >= group_jobs.len() {
                        break;
                    }
                    let job = &group_jobs[i];
                    let t0 = std::time::Instant::now();
                    match run_job(&pipe, source, params, job, cands.as_ref().map(|c| &c[i])) {
                        Ok(labels) => {
                            results.lock().unwrap()[base_idx + i] = Some(JobResult {
                                id: job.id,
                                labels,
                                secs: t0.elapsed().as_secs_f64(),
                            });
                            let d = done.fetch_add(1, Ordering::Relaxed) + 1;
                            if let Some(p) = progress {
                                p(d, total);
                            }
                        }
                        Err(e) => {
                            *first_error.lock().unwrap() = Some(e);
                            abort.store(true, Ordering::Relaxed);
                            break;
                        }
                    }
                });
            }
        });
    }

    if let Some(e) = first_error.into_inner().unwrap() {
        return Err(e);
    }
    let mut ens = Ensemble::default();
    for r in results.into_inner().unwrap() {
        let r = r.ok_or_else(|| Error::Runtime("coordinator: missing job result".into()))?;
        ens.push(r.labels);
    }
    Ok(ens)
}

/// Full U-SENC through the coordinator: scheduled ensemble generation +
/// bipartite consensus. Equivalent to [`crate::usenc::usenc`] output-wise.
pub fn usenc_coordinated(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    workers: usize,
    progress: Option<Progress>,
) -> Result<UsencResult> {
    usenc_coordinated_opts(source, params, seed, backend, workers, progress, ExecOpts::default())
}

/// [`usenc_coordinated`] with explicit execution knobs for the sweeps.
pub fn usenc_coordinated_opts(
    source: &dyn DataSource,
    params: &UsencParams,
    seed: u64,
    backend: &dyn DistanceBackend,
    workers: usize,
    progress: Option<Progress>,
    opts: ExecOpts,
) -> Result<UsencResult> {
    let mut timer = PhaseTimer::new();
    let ensemble = timer.time("generation", || {
        run_base_clusterers_opts(source, params, seed, backend, workers, progress, opts)
    })?;
    let labels = timer.time("consensus", || {
        consensus_bipartite(&ensemble, params.k, params.base.solver, seed ^ 0xC075)
    })?;
    Ok(UsencResult { labels, ensemble, timer })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::data::synthetic::two_moons;
    use crate::usenc::generate_ensemble;
    use crate::uspec::UspecParams;

    fn params() -> UsencParams {
        UsencParams {
            k: 2,
            m: 4,
            k_min: 4,
            k_max: 8,
            base: UspecParams { p: 60, ..Default::default() },
        }
    }

    #[test]
    fn derive_jobs_matches_sequential_seed_stream() {
        let ds = two_moons(200, 0.05, 1);
        let p = params();
        let jobs = derive_jobs(&p, ds.n(), 77);
        assert_eq!(jobs.len(), 4);
        // parallel-coordinated ensemble == sequential ensemble
        let seq = generate_ensemble(&ds.x, &p, 77, &NativeBackend).unwrap();
        let par = run_base_clusterers(&ds.x, &p, 77, &NativeBackend, 3, None).unwrap();
        assert_eq!(seq.labelings, par.labelings);
    }

    #[test]
    fn worker_count_invariance() {
        let ds = two_moons(200, 0.05, 2);
        let p = params();
        let a = run_base_clusterers(&ds.x, &p, 5, &NativeBackend, 1, None).unwrap();
        let b = run_base_clusterers(&ds.x, &p, 5, &NativeBackend, 4, None).unwrap();
        assert_eq!(a.labelings, b.labelings);
        // sharded sweeps under the scheduler change nothing either
        let opts = ExecOpts { chunk: 64, shards: 3, ..ExecOpts::default() };
        let c = run_base_clusterers_opts(&ds.x, &p, 5, &NativeBackend, 4, None, opts).unwrap();
        assert_eq!(a.labelings, c.labelings);
    }

    #[test]
    fn every_job_executes_exactly_once() {
        let ds = two_moons(150, 0.05, 3);
        let p = UsencParams { m: 7, ..params() };
        let counter = std::sync::atomic::AtomicUsize::new(0);
        let progress = |_d: usize, _t: usize| {
            counter.fetch_add(1, Ordering::Relaxed);
        };
        let ens =
            run_base_clusterers(&ds.x, &p, 9, &NativeBackend, 3, Some(&progress)).unwrap();
        assert_eq!(ens.m(), 7);
        assert_eq!(counter.load(Ordering::Relaxed), 7);
    }

    #[test]
    fn coordinated_usenc_matches_plain() {
        let ds = two_moons(300, 0.05, 4);
        let p = params();
        let plain = crate::usenc::usenc(&ds.x, &p, 11, &NativeBackend).unwrap();
        let coord = usenc_coordinated(&ds.x, &p, 11, &NativeBackend, 2, None).unwrap();
        assert_eq!(plain.labels, coord.labels);
    }

    #[test]
    fn coordinated_usenc_over_remote_source_matches_local() {
        let ds = two_moons(240, 0.05, 6);
        let p = params();
        let server = crate::net::ShardServer::bind(
            "127.0.0.1:0",
            std::sync::Arc::new(ds.x.clone()),
        )
        .unwrap();
        let remote = crate::net::RemoteSource::connect(&server.addr().to_string()).unwrap();
        let local = usenc_coordinated(&ds.x, &p, 13, &NativeBackend, 2, None).unwrap();
        let wire = usenc_coordinated(&remote, &p, 13, &NativeBackend, 2, None).unwrap();
        assert_eq!(local.labels, wire.labels);
        assert_eq!(local.ensemble.labelings, wire.ensemble.labelings);
    }

    #[test]
    fn error_propagates() {
        let ds = two_moons(50, 0.05, 5);
        let mut p = params();
        p.base.k_nn = 5;
        p.k_min = 0; // k=0 draws clamp to 2, so break differently: p too big is clamped...
        p.base.p = 60;
        // Force an error via k > n in the consensus instead:
        let ens = run_base_clusterers(&ds.x, &p, 1, &NativeBackend, 2, None).unwrap();
        assert!(consensus_bipartite(&ens, 9999, crate::bipartite::EigSolver::Auto, 1).is_err());
    }
}
