//! Run configuration: a JSON-file-backed config with CLI overrides — the
//! launcher's single source of truth (serde is unavailable offline; the
//! in-tree [`crate::util::json`] does the (de)serialization).

use crate::pipeline::StorageProfile;
use crate::util::json::Json;
use crate::{Error, Result};
use std::path::Path;

/// Distance-backend choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust blocked gemm path.
    Native,
    /// AOT-compiled JAX/Pallas kernels via PJRT (requires `make artifacts`).
    Pjrt,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.to_ascii_lowercase().as_str() {
            "native" => Ok(BackendKind::Native),
            "pjrt" | "xla" | "kernel" => Ok(BackendKind::Pjrt),
            other => Err(Error::Config(format!("unknown backend '{other}'"))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
        }
    }
}

/// Full run configuration (defaults follow the paper's §4.2 settings).
#[derive(Debug, Clone)]
pub struct RunConfig {
    /// Benchmark dataset name (Table 3) or a CSV path.
    pub dataset: String,
    /// Synthetic-size multiplier (1.0 = paper sizes).
    pub scale: f64,
    /// Clustering method name.
    pub method: String,
    /// Cluster count; None = dataset ground truth k.
    pub k: Option<usize>,
    /// Representatives / landmarks p.
    pub p: usize,
    /// Nearest representatives K.
    pub k_nn: usize,
    /// Ensemble size m.
    pub m: usize,
    /// Base-clusterer cluster range.
    pub k_min: usize,
    pub k_max: usize,
    /// Distance backend.
    pub backend: BackendKind,
    /// Coordinator worker threads for ensemble generation.
    pub workers: usize,
    /// Row-range shards walked concurrently per streaming pass
    /// (operational only — labels never depend on it). Must be >= 1;
    /// `stream` additionally rejects values above the dataset size.
    pub shards: usize,
    /// Storage profile hint for the sharded walk planner (`auto` probes;
    /// operational only, like `shards`).
    pub storage: StorageProfile,
    /// Remote data source for `stream`: `remote://host:port` of a
    /// `serve-shard` endpoint; None (default) streams the local dataset.
    pub source: Option<String>,
    /// Decoded-chunk LRU budget in bytes for a remote source (0 = no
    /// cache). Operational only, like `shards` — labels never depend on
    /// it; the streaming peak model charges the budget.
    pub net_cache: usize,
    /// Repetitions for mean±std reporting.
    pub runs: usize,
    /// Master seed.
    pub seed: u64,
    /// Simulated memory budget in bytes for the N/A model (paper: 64 GB).
    pub budget_bytes: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "TB-1M".into(),
            scale: 0.002,
            method: "u-spec".into(),
            k: None,
            p: 1000,
            k_nn: 5,
            m: 20,
            k_min: 20,
            k_max: 60,
            backend: BackendKind::Native,
            workers: crate::util::par::num_threads(),
            shards: 1,
            storage: StorageProfile::Auto,
            source: None,
            net_cache: 0,
            runs: 3,
            seed: 42,
            budget_bytes: 64 * (1 << 30),
        }
    }
}

impl RunConfig {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::Str(self.dataset.clone())),
            ("scale", Json::Num(self.scale)),
            ("method", Json::Str(self.method.clone())),
            ("k", self.k.map(|v| Json::Num(v as f64)).unwrap_or(Json::Null)),
            ("p", Json::Num(self.p as f64)),
            ("k_nn", Json::Num(self.k_nn as f64)),
            ("m", Json::Num(self.m as f64)),
            ("k_min", Json::Num(self.k_min as f64)),
            ("k_max", Json::Num(self.k_max as f64)),
            ("backend", Json::Str(self.backend.name().into())),
            ("workers", Json::Num(self.workers as f64)),
            ("shards", Json::Num(self.shards as f64)),
            ("storage", Json::Str(self.storage.name().into())),
            (
                "source",
                self.source.as_ref().map(|s| Json::Str(s.clone())).unwrap_or(Json::Null),
            ),
            ("net_cache", Json::Num(self.net_cache as f64)),
            ("runs", Json::Num(self.runs as f64)),
            ("seed", Json::Num(self.seed as f64)),
            ("budget_bytes", Json::Num(self.budget_bytes as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let obj = v.as_obj().ok_or_else(|| Error::Config("config must be an object".into()))?;
        for (key, val) in obj {
            cfg.set(key, &json_to_string(val))?;
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let v = Json::parse(&text).map_err(Error::Config)?;
        Self::from_json(&v)
    }

    /// Apply one `--key value` override.
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let parse_usize = |v: &str| -> Result<usize> {
            v.parse().map_err(|e| Error::Config(format!("{key}: {e}")))
        };
        match key {
            "dataset" => self.dataset = value.to_string(),
            "scale" => {
                self.scale = value.parse().map_err(|e| Error::Config(format!("scale: {e}")))?
            }
            "method" => self.method = value.to_string(),
            "k" => self.k = if value == "null" { None } else { Some(parse_usize(value)?) },
            "p" => self.p = parse_usize(value)?,
            "k_nn" | "K" => self.k_nn = parse_usize(value)?,
            "m" => self.m = parse_usize(value)?,
            "k_min" => self.k_min = parse_usize(value)?,
            "k_max" => self.k_max = parse_usize(value)?,
            "backend" => self.backend = BackendKind::parse(value)?,
            "workers" => self.workers = parse_usize(value)?.max(1),
            "shards" => {
                let s = parse_usize(value)?;
                if s == 0 {
                    return Err(Error::Config("shards: must be >= 1".into()));
                }
                self.shards = s;
            }
            "storage" => self.storage = StorageProfile::parse(value)?,
            "source" => {
                if value == "null" {
                    self.source = None;
                } else {
                    let hostport = value.strip_prefix("remote://").ok_or_else(|| {
                        Error::Config(format!(
                            "source: '{value}' (want remote://host:port or null)"
                        ))
                    })?;
                    crate::net::validate_host_port(hostport)
                        .map_err(|e| Error::Config(format!("source: {e}")))?;
                    self.source = Some(value.to_string());
                }
            }
            "net_cache" => self.net_cache = parse_usize(value)?,
            "runs" => self.runs = parse_usize(value)?.max(1),
            "seed" => {
                self.seed = value.parse().map_err(|e| Error::Config(format!("seed: {e}")))?
            }
            "budget_bytes" => {
                self.budget_bytes =
                    value.parse().map_err(|e| Error::Config(format!("budget: {e}")))?
            }
            "budget_gb" => {
                let gb: f64 = value.parse().map_err(|e| Error::Config(format!("budget: {e}")))?;
                self.budget_bytes = (gb * (1u64 << 30) as f64) as u64;
            }
            other => return Err(Error::Config(format!("unknown config key '{other}'"))),
        }
        Ok(())
    }
}

fn json_to_string(v: &Json) -> String {
    match v {
        Json::Str(s) => s.clone(),
        other => other.to_string(),
    }
}

/// A fit-job specification — the `OP_SUBMIT_FIT` payload the `submit-fit`
/// CLI sends and the `repro serve` daemon executes. `data` is a
/// [`crate::streaming::BinDataset`] path as seen by the *server*. The
/// seed is serialized as a string: the in-tree JSON number is an f64 and
/// would silently round u64 seeds above 2^53.
#[derive(Debug, Clone, PartialEq)]
pub struct FitSpec {
    /// "u-spec" or "u-senc".
    pub method: String,
    /// Server-visible BinDataset path to fit on.
    pub data: String,
    /// Output (consensus) cluster count.
    pub k: usize,
    /// Representatives p per (base) clusterer.
    pub p: usize,
    /// Nearest representatives K.
    pub k_nn: usize,
    /// Ensemble size m (u-senc only).
    pub m: usize,
    /// Base-clusterer cluster range (u-senc only).
    pub k_min: usize,
    pub k_max: usize,
    /// Pipeline seed.
    pub seed: u64,
}

impl FitSpec {
    /// Derive a spec from a [`RunConfig`] (the CLI path: shared `--k`,
    /// `--p`, … flags) plus the data path.
    pub fn from_config(cfg: &RunConfig, data: &str) -> FitSpec {
        FitSpec {
            // CLI --method is case-insensitive; the wire form is canonical
            method: cfg.method.to_ascii_lowercase(),
            data: data.to_string(),
            k: cfg.k.unwrap_or(2),
            p: cfg.p,
            k_nn: cfg.k_nn,
            m: cfg.m,
            k_min: cfg.k_min,
            k_max: cfg.k_max,
            seed: cfg.seed,
        }
    }

    /// Reject specs the daemon could only fail on later.
    pub fn validate(&self) -> Result<()> {
        match self.method.as_str() {
            "u-spec" | "u-senc" => {}
            other => {
                return Err(Error::Config(format!(
                    "fit spec: unknown method '{other}' (want u-spec or u-senc)"
                )))
            }
        }
        if self.data.is_empty() {
            return Err(Error::Config("fit spec: empty data path".into()));
        }
        if self.k == 0 {
            return Err(Error::Config("fit spec: k must be >= 1".into()));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("method", Json::Str(self.method.clone())),
            ("data", Json::Str(self.data.clone())),
            ("k", Json::Num(self.k as f64)),
            ("p", Json::Num(self.p as f64)),
            ("k_nn", Json::Num(self.k_nn as f64)),
            ("m", Json::Num(self.m as f64)),
            ("k_min", Json::Num(self.k_min as f64)),
            ("k_max", Json::Num(self.k_max as f64)),
            ("seed", Json::Str(self.seed.to_string())),
        ])
    }

    pub fn from_json(v: &Json) -> Result<FitSpec> {
        let obj =
            v.as_obj().ok_or_else(|| Error::Config("fit spec must be a JSON object".into()))?;
        let str_field = |key: &str| -> Result<String> {
            obj.get(key)
                .and_then(|v| v.as_str())
                .map(str::to_string)
                .ok_or_else(|| Error::Config(format!("fit spec: missing string '{key}'")))
        };
        let num_field = |key: &str, default: usize| -> Result<usize> {
            match obj.get(key) {
                None => Ok(default),
                Some(v) => v
                    .as_usize()
                    .ok_or_else(|| Error::Config(format!("fit spec: bad number '{key}'"))),
            }
        };
        let seed = match obj.get("seed") {
            None => RunConfig::default().seed,
            Some(Json::Str(s)) => s
                .parse()
                .map_err(|e| Error::Config(format!("fit spec: seed: {e}")))?,
            Some(Json::Num(n)) => *n as u64,
            Some(_) => return Err(Error::Config("fit spec: bad seed".into())),
        };
        let spec = FitSpec {
            method: str_field("method")?,
            data: str_field("data")?,
            k: num_field("k", 2)?,
            p: num_field("p", 1000)?,
            k_nn: num_field("k_nn", 5)?,
            m: num_field("m", 20)?,
            k_min: num_field("k_min", 20)?,
            k_max: num_field("k_max", 60)?,
            seed,
        };
        spec.validate()?;
        Ok(spec)
    }

    /// Parse a spec from JSON text (the wire form).
    pub fn parse(text: &str) -> Result<FitSpec> {
        let v = Json::parse(text).map_err(Error::Config)?;
        FitSpec::from_json(&v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut cfg = RunConfig::default();
        cfg.set("dataset", "MNIST").unwrap();
        cfg.set("p", "500").unwrap();
        cfg.set("backend", "pjrt").unwrap();
        cfg.set("budget_gb", "8").unwrap();
        let j = cfg.to_json().to_string();
        let back = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.dataset, "MNIST");
        assert_eq!(back.p, 500);
        assert_eq!(back.backend, BackendKind::Pjrt);
        assert_eq!(back.budget_bytes, 8 * (1 << 30));
    }

    #[test]
    fn rejects_unknown_key() {
        let mut cfg = RunConfig::default();
        assert!(cfg.set("nope", "1").is_err());
        assert!(cfg.set("scale", "abc").is_err());
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn shards_key_roundtrips_and_rejects_zero() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.shards, 1);
        cfg.set("shards", "4").unwrap();
        assert_eq!(cfg.shards, 4);
        assert!(cfg.set("shards", "0").is_err());
        assert!(cfg.set("shards", "x").is_err());
        let j = cfg.to_json().to_string();
        let back = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.shards, 4);
    }

    #[test]
    fn source_key_roundtrips_and_rejects_junk() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.source, None);
        cfg.set("source", "remote://127.0.0.1:7000").unwrap();
        assert_eq!(cfg.source.as_deref(), Some("remote://127.0.0.1:7000"));
        // roundtrip through JSON keeps the endpoint
        let j = cfg.to_json().to_string();
        let back = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.source.as_deref(), Some("remote://127.0.0.1:7000"));
        // null clears it, and the None default roundtrips too
        cfg.set("source", "null").unwrap();
        assert_eq!(cfg.source, None);
        let j = cfg.to_json().to_string();
        let back = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.source, None);
        // malformed spellings are config errors, not deferred failures
        for bad in ["ftp://h:1", "remote://", "remote://host", "remote://:1", "remote://h:x"] {
            assert!(cfg.set("source", bad).is_err(), "{bad} must be rejected");
        }
    }

    #[test]
    fn net_cache_key_roundtrips_and_rejects_junk() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.net_cache, 0);
        cfg.set("net_cache", "1048576").unwrap();
        assert_eq!(cfg.net_cache, 1 << 20);
        // 0 is a valid spelling of "no cache"
        cfg.set("net_cache", "0").unwrap();
        assert_eq!(cfg.net_cache, 0);
        assert!(cfg.set("net_cache", "-1").is_err());
        assert!(cfg.set("net_cache", "big").is_err());
        cfg.set("net_cache", "4096").unwrap();
        let j = cfg.to_json().to_string();
        let back = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.net_cache, 4096);
    }

    #[test]
    fn fit_spec_roundtrips_with_u64_seed_and_rejects_junk() {
        let mut cfg = RunConfig::default();
        cfg.set("method", "u-senc").unwrap();
        cfg.set("k", "3").unwrap();
        // a seed above 2^53 would round through an f64 JSON number
        cfg.set("seed", "18446744073709551615").unwrap();
        let spec = FitSpec::from_config(&cfg, "/data/train.bin");
        let back = FitSpec::parse(&spec.to_json().to_string()).unwrap();
        assert_eq!(back, spec);
        assert_eq!(back.seed, u64::MAX, "u64 seeds must survive the wire");
        assert_eq!((back.method.as_str(), back.k), ("u-senc", 3));
        // malformed specs are typed config errors
        assert!(FitSpec::parse("[1,2]").is_err());
        assert!(FitSpec::parse(r#"{"method":"magic","data":"x"}"#).is_err());
        assert!(FitSpec::parse(r#"{"method":"u-spec"}"#).is_err());
        assert!(FitSpec::parse(r#"{"method":"u-spec","data":"x","k":0}"#).is_err());
    }

    #[test]
    fn storage_key_roundtrips_and_rejects_junk() {
        let mut cfg = RunConfig::default();
        assert_eq!(cfg.storage, StorageProfile::Auto);
        cfg.set("storage", "serial").unwrap();
        assert_eq!(cfg.storage, StorageProfile::Serial);
        cfg.set("storage", "nvme").unwrap();
        assert_eq!(cfg.storage, StorageProfile::Parallel);
        assert!(cfg.set("storage", "tape").is_err());
        let j = cfg.to_json().to_string();
        let back = RunConfig::from_json(&Json::parse(&j).unwrap()).unwrap();
        assert_eq!(back.storage, StorageProfile::Parallel);
    }
}
