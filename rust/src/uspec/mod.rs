//! **U-SPEC** — Ultra-Scalable Spectral Clustering (paper §3.1).
//!
//! Pipeline: hybrid representative selection → approximate K-nearest
//! representatives → sparse Gaussian cross-affinity `B` → transfer-cut
//! bipartite partitioning → k-means discretization. Dominant complexity
//! O(N·p^½·d) time and O(N·p^½) memory.
//!
//! [`uspec_with_backend`] is a thin wrapper over the staged engine in
//! [`crate::pipeline`] — the same stages run the out-of-core path
//! ([`crate::streaming`]) and the ensemble layer ([`crate::usenc`]), so
//! in-memory and on-disk sources produce bit-identical labels for a
//! fixed seed.

use crate::affinity::{DistanceBackend, NativeBackend, SelectStrategy};
use crate::bipartite::EigSolver;
use crate::linalg::Mat;
use crate::pipeline::Pipeline;
use crate::util::timer::PhaseTimer;
use crate::Result;

pub mod estimate;

/// Exact vs approximate K-nearest-representative search (Tables 15–16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnrMode {
    /// The paper's coarse-to-fine approximation, O(N·p^½·d).
    Approx,
    /// LSC-style exact search, O(N·p·d).
    Exact,
}

/// U-SPEC hyper-parameters (paper defaults: p=1000, K=5, K′=10K, p′=10p).
#[derive(Debug, Clone)]
pub struct UspecParams {
    /// Number of clusters in the output.
    pub k: usize,
    /// Number of representatives p.
    pub p: usize,
    /// Number of nearest representatives K kept per object.
    pub k_nn: usize,
    /// Candidate neighborhood size K′ as a multiple of K.
    pub k_prime_factor: usize,
    /// Representative selection strategy (hybrid by default).
    pub selection: SelectStrategy,
    /// K-nearest-representative mode.
    pub knr: KnrMode,
    /// k-means iteration cap (selection, rep-clusters, discretization).
    pub kmeans_iters: usize,
    /// Eigen solver for the reduced problem.
    pub solver: EigSolver,
}

impl Default for UspecParams {
    fn default() -> Self {
        UspecParams {
            k: 2,
            p: 1000,
            k_nn: 5,
            k_prime_factor: 10,
            selection: SelectStrategy::Hybrid { candidate_factor: 10 },
            knr: KnrMode::Approx,
            kmeans_iters: 100,
            solver: EigSolver::Auto,
        }
    }
}

impl UspecParams {
    /// Clamp p (and derived sizes) to the dataset size — small inputs in
    /// tests/benches keep the paper defaults otherwise.
    pub fn clamped(&self, n: usize) -> UspecParams {
        let mut p = self.p.min(n);
        p = p.max(self.k.min(n));
        UspecParams { p, ..self.clone() }
    }
}

/// U-SPEC output.
#[derive(Debug, Clone)]
pub struct UspecResult {
    pub labels: Vec<u32>,
    /// Spectral embedding (N×k) the labels were discretized from.
    pub embedding: Mat,
    /// Per-phase wall-clock timings.
    pub timer: PhaseTimer,
    /// Gaussian bandwidth used for the affinity.
    pub sigma: f64,
}

/// Run U-SPEC with an explicit distance backend (native or PJRT).
/// Thin wrapper over [`Pipeline::run`] with the default chunk size — the
/// engine's chunked sweeps are chunk-size invariant, so this matches the
/// out-of-core path bit-for-bit.
pub fn uspec_with_backend(
    x: &Mat,
    params: &UspecParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<UspecResult> {
    Pipeline::new(backend).run(x, params, seed)
}

/// Run U-SPEC on the pure-Rust backend.
pub fn uspec(x: &Mat, params: &UspecParams, seed: u64) -> Result<UspecResult> {
    uspec_with_backend(x, params, seed, &NativeBackend)
}

// Re-exports for the doc example.
pub use crate::metrics;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{concentric_circles, two_bananas, two_moons};
    use crate::metrics::{ca, nmi};

    #[test]
    fn solves_two_moons() {
        let ds = two_moons(2000, 0.06, 7);
        let params = UspecParams { k: 2, p: 200, ..Default::default() };
        let res = uspec(&ds.x, &params, 42).unwrap();
        let score = nmi(&res.labels, &ds.y);
        assert!(score > 0.9, "nmi={score}");
        assert!(res.sigma > 0.0);
        assert!(res.timer.total() > 0.0);
    }

    #[test]
    fn solves_nonlinear_shapes_where_kmeans_fails() {
        // The paper's headline qualitative claim (Tables 4–5, TB/CC rows).
        let ds = concentric_circles(3000, 8);
        let res = uspec(&ds.x, &UspecParams { k: 3, p: 300, ..Default::default() }, 1).unwrap();
        let uspec_nmi = nmi(&res.labels, &ds.y);
        let km = crate::kmeans::kmeans(
            &ds.x,
            &crate::kmeans::KmeansParams { k: 3, ..Default::default() },
            1,
        )
        .unwrap();
        let km_nmi = nmi(&km.labels, &ds.y);
        assert!(uspec_nmi > 0.95, "uspec nmi={uspec_nmi}");
        assert!(km_nmi < 0.1, "kmeans nmi={km_nmi}");
    }

    #[test]
    fn bananas_ca_high() {
        let ds = two_bananas(3000, 9);
        let res = uspec(&ds.x, &UspecParams { k: 2, p: 250, ..Default::default() }, 5).unwrap();
        let acc = ca(&res.labels, &ds.y);
        assert!(acc > 0.9, "ca={acc}");
    }

    #[test]
    fn exact_mode_works() {
        let ds = two_moons(800, 0.05, 10);
        let params = UspecParams { k: 2, p: 100, knr: KnrMode::Exact, ..Default::default() };
        let res = uspec(&ds.x, &params, 3).unwrap();
        assert!(nmi(&res.labels, &ds.y) > 0.85);
    }

    #[test]
    fn clamps_oversized_p() {
        let ds = two_moons(150, 0.05, 11);
        let params = UspecParams { k: 2, p: 1000, ..Default::default() };
        let res = uspec(&ds.x, &params, 3).unwrap();
        assert_eq!(res.labels.len(), 150);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = two_moons(500, 0.05, 12);
        let params = UspecParams { k: 2, p: 80, ..Default::default() };
        let a = uspec(&ds.x, &params, 99).unwrap();
        let b = uspec(&ds.x, &params, 99).unwrap();
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn rejects_degenerate() {
        let ds = two_moons(10, 0.05, 13);
        assert!(uspec(&ds.x, &UspecParams { k: 0, ..Default::default() }, 1).is_err());
        assert!(uspec(&ds.x, &UspecParams { k: 11, ..Default::default() }, 1).is_err());
    }
}
