//! **U-SPEC** — Ultra-Scalable Spectral Clustering (paper §3.1).
//!
//! Pipeline: hybrid representative selection → approximate K-nearest
//! representatives → sparse Gaussian cross-affinity `B` → transfer-cut
//! bipartite partitioning → k-means discretization. Dominant complexity
//! O(N·p^½·d) time and O(N·p^½) memory.

use crate::affinity::{
    build_affinity, knr::KnrIndex, select, DistanceBackend, NativeBackend, SelectStrategy,
};
use crate::bipartite::{transfer_cut, EigSolver};
use crate::kmeans::{kmeans, KmeansParams};
use crate::linalg::Mat;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Result};

pub mod estimate;

/// Exact vs approximate K-nearest-representative search (Tables 15–16).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnrMode {
    /// The paper's coarse-to-fine approximation, O(N·p^½·d).
    Approx,
    /// LSC-style exact search, O(N·p·d).
    Exact,
}

/// U-SPEC hyper-parameters (paper defaults: p=1000, K=5, K′=10K, p′=10p).
#[derive(Debug, Clone)]
pub struct UspecParams {
    /// Number of clusters in the output.
    pub k: usize,
    /// Number of representatives p.
    pub p: usize,
    /// Number of nearest representatives K kept per object.
    pub k_nn: usize,
    /// Candidate neighborhood size K′ as a multiple of K.
    pub k_prime_factor: usize,
    /// Representative selection strategy (hybrid by default).
    pub selection: SelectStrategy,
    /// K-nearest-representative mode.
    pub knr: KnrMode,
    /// k-means iteration cap (selection, rep-clusters, discretization).
    pub kmeans_iters: usize,
    /// Eigen solver for the reduced problem.
    pub solver: EigSolver,
}

impl Default for UspecParams {
    fn default() -> Self {
        UspecParams {
            k: 2,
            p: 1000,
            k_nn: 5,
            k_prime_factor: 10,
            selection: SelectStrategy::Hybrid { candidate_factor: 10 },
            knr: KnrMode::Approx,
            kmeans_iters: 100,
            solver: EigSolver::Auto,
        }
    }
}

impl UspecParams {
    /// Clamp p (and derived sizes) to the dataset size — small inputs in
    /// tests/benches keep the paper defaults otherwise.
    pub fn clamped(&self, n: usize) -> UspecParams {
        let mut p = self.p.min(n);
        p = p.max(self.k.min(n));
        UspecParams { p, ..self.clone() }
    }
}

/// U-SPEC output.
#[derive(Debug, Clone)]
pub struct UspecResult {
    pub labels: Vec<u32>,
    /// Spectral embedding (N×k) the labels were discretized from.
    pub embedding: Mat,
    /// Per-phase wall-clock timings.
    pub timer: PhaseTimer,
    /// Gaussian bandwidth used for the affinity.
    pub sigma: f64,
}

/// Run U-SPEC with an explicit distance backend (native or PJRT).
pub fn uspec_with_backend(
    x: &Mat,
    params: &UspecParams,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<UspecResult> {
    let n = x.rows;
    ensure_arg!(n >= 2, "uspec: need at least 2 objects");
    let params = params.clamped(n);
    ensure_arg!(params.k >= 1 && params.k <= n, "uspec: bad k={}", params.k);
    ensure_arg!(params.k <= params.p, "uspec: k={} > p={}", params.k, params.p);
    let mut rng = Rng::new(seed);
    let mut timer = PhaseTimer::new();

    // Phase 1: representative selection (§3.1.1). Selection only needs a
    // coarse vector quantization — cap its k-means iterations (the paper's
    // small `t`), independent of the discretization budget.
    let sel_seed = rng.next_u64();
    let sel_iters = params.kmeans_iters.min(20);
    let reps = timer.time("select", || {
        select(x, params.selection, params.p, sel_iters, sel_seed)
    })?;

    // Phase 2: K-nearest representatives + sparse affinity (§3.1.2).
    let k_prime = (params.k_nn * params.k_prime_factor).max(params.k_nn + 1);
    let index = timer.time("knr_index", || {
        KnrIndex::build(&reps, k_prime, params.kmeans_iters.min(30), backend)
    })?;
    let knr = timer.time("knr_query", || match params.knr {
        KnrMode::Approx => index.approx_knr(x, params.k_nn, backend),
        KnrMode::Exact => index.exact_knr(x, params.k_nn, backend),
    });
    let aff = timer.time("affinity", || build_affinity(n, index.p(), knr.k, &knr));

    // Phase 3: transfer-cut bipartite partitioning (§3.1.3).
    let tc_seed = rng.next_u64();
    let tc = timer.time("transfer_cut", || {
        transfer_cut(&aff.b, params.k.min(index.p()), params.solver, tc_seed)
    })?;

    // Phase 4: k-means discretization (row-normalized, NJW-style).
    let km_seed = rng.next_u64();
    let mut emb = tc.embedding.clone();
    crate::bipartite::row_normalize(&mut emb);
    let km = timer.time("discretize", || {
        kmeans(
            &emb,
            &KmeansParams { k: params.k, max_iter: params.kmeans_iters, ..Default::default() },
            km_seed,
        )
    })?;

    Ok(UspecResult { labels: km.labels, embedding: tc.embedding, timer, sigma: aff.sigma })
}

/// Run U-SPEC on the pure-Rust backend.
pub fn uspec(x: &Mat, params: &UspecParams, seed: u64) -> Result<UspecResult> {
    uspec_with_backend(x, params, seed, &NativeBackend)
}

// Re-exports for the doc example.
pub use crate::metrics;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::{concentric_circles, two_bananas, two_moons};
    use crate::metrics::{ca, nmi};

    #[test]
    fn solves_two_moons() {
        let ds = two_moons(2000, 0.06, 7);
        let params = UspecParams { k: 2, p: 200, ..Default::default() };
        let res = uspec(&ds.x, &params, 42).unwrap();
        let score = nmi(&res.labels, &ds.y);
        assert!(score > 0.9, "nmi={score}");
        assert!(res.sigma > 0.0);
        assert!(res.timer.total() > 0.0);
    }

    #[test]
    fn solves_nonlinear_shapes_where_kmeans_fails() {
        // The paper's headline qualitative claim (Tables 4–5, TB/CC rows).
        let ds = concentric_circles(3000, 8);
        let res = uspec(&ds.x, &UspecParams { k: 3, p: 300, ..Default::default() }, 1).unwrap();
        let uspec_nmi = nmi(&res.labels, &ds.y);
        let km = crate::kmeans::kmeans(
            &ds.x,
            &crate::kmeans::KmeansParams { k: 3, ..Default::default() },
            1,
        )
        .unwrap();
        let km_nmi = nmi(&km.labels, &ds.y);
        assert!(uspec_nmi > 0.95, "uspec nmi={uspec_nmi}");
        assert!(km_nmi < 0.1, "kmeans nmi={km_nmi}");
    }

    #[test]
    fn bananas_ca_high() {
        let ds = two_bananas(3000, 9);
        let res = uspec(&ds.x, &UspecParams { k: 2, p: 250, ..Default::default() }, 5).unwrap();
        let acc = ca(&res.labels, &ds.y);
        assert!(acc > 0.9, "ca={acc}");
    }

    #[test]
    fn exact_mode_works() {
        let ds = two_moons(800, 0.05, 10);
        let params = UspecParams { k: 2, p: 100, knr: KnrMode::Exact, ..Default::default() };
        let res = uspec(&ds.x, &params, 3).unwrap();
        assert!(nmi(&res.labels, &ds.y) > 0.85);
    }

    #[test]
    fn clamps_oversized_p() {
        let ds = two_moons(150, 0.05, 11);
        let params = UspecParams { k: 2, p: 1000, ..Default::default() };
        let res = uspec(&ds.x, &params, 3).unwrap();
        assert_eq!(res.labels.len(), 150);
    }

    #[test]
    fn deterministic_given_seed() {
        let ds = two_moons(500, 0.05, 12);
        let params = UspecParams { k: 2, p: 80, ..Default::default() };
        let a = uspec(&ds.x, &params, 99).unwrap();
        let b = uspec(&ds.x, &params, 99).unwrap();
        assert_eq!(a.labels, b.labels);
    }

    #[test]
    fn rejects_degenerate() {
        let ds = two_moons(10, 0.05, 13);
        assert!(uspec(&ds.x, &UspecParams { k: 0, ..Default::default() }, 1).is_err());
        assert!(uspec(&ds.x, &UspecParams { k: 11, ..Default::default() }, 1).is_err());
    }
}
