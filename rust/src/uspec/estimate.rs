//! Model selection: estimate the number of clusters from the transfer-cut
//! spectrum (eigengap heuristic, von Luxburg §8.3 — ref. [2] of the
//! paper). The paper's evaluation fixes k to the ground truth (§4.2); this
//! extension covers the deployment case where k is unknown.
//!
//! The reduced problem's eigenvalues 0 = λ₁ ≤ λ₂ ≤ … measure how cleanly
//! the bipartite graph separates: with k well-formed clusters the first k
//! values sit near 0 and λ_{k+1} jumps. We probe `k_max` eigenpairs once
//! and return the argmax of the (relative) eigengap.

use crate::affinity::{build_affinity, knr::KnrIndex, select, DistanceBackend};
use crate::bipartite::{transfer_cut, EigSolver};
use crate::linalg::Mat;
use crate::uspec::UspecParams;
use crate::{ensure_arg, Result};

/// Pick k from an ascending eigenvalue sequence by the largest *relative*
/// gap (λ_{k+1} − λ_k)/λ_{k+1} over k ∈ [k_min, len−1]. The relative form
/// matters: transfer-cut spectra grow roughly linearly past the cluster
/// block, so absolute gaps systematically favor the tail, while the
/// near-zero cluster eigenvalues make the relative gap at the true k ≈ 1.
/// Ties break toward smaller k.
pub fn eigengap_k(lambdas: &[f64], k_min: usize) -> usize {
    let k_min = k_min.max(1);
    if lambdas.len() < k_min + 1 {
        return lambdas.len().max(1);
    }
    // Floor the denominator at a fraction of the spectrum scale so a pair
    // of numerically-zero eigenvalues (λ ~ 1e-17 vs 1e-5 — both "zero" in
    // cluster terms) does not register as a giant relative gap.
    let scale = lambdas.iter().cloned().fold(0.0, f64::max).max(1e-12);
    let floor = 1e-3 * scale;
    let mut best_k = k_min;
    let mut best_gap = f64::NEG_INFINITY;
    for k in k_min..lambdas.len() {
        let hi = lambdas[k].max(0.0);
        let lo = lambdas[k - 1].max(0.0);
        let gap = (hi - lo) / hi.max(floor);
        if gap > best_gap + 1e-15 {
            best_gap = gap;
            best_k = k;
        }
    }
    best_k
}

/// Estimate of the cluster count plus the evidence it was based on.
#[derive(Debug, Clone)]
pub struct KEstimate {
    pub k: usize,
    /// The probed spectrum (ascending, len = k_max).
    pub lambdas: Vec<f64>,
    /// λ_{k+1} − λ_k at the chosen k.
    pub gap: f64,
}

/// Run the U-SPEC front end (selection → KNR → affinity → transfer cut
/// probing `k_max` eigenpairs) and return the eigengap estimate of k.
/// Costs one extra transfer cut at k_max — still `O(N·p^½·d + p³)`.
pub fn estimate_k(
    x: &Mat,
    params: &UspecParams,
    k_min: usize,
    k_max: usize,
    seed: u64,
    backend: &dyn DistanceBackend,
) -> Result<KEstimate> {
    let n = x.rows;
    ensure_arg!(n >= 4, "estimate_k: need at least 4 objects");
    ensure_arg!(k_min >= 1 && k_min < k_max, "estimate_k: bad range [{k_min}, {k_max}]");
    let p = params.p.min(n / 2).max(k_max.min(n));
    let k_max = k_max.min(p);
    let reps = select(x, params.selection, p, params.kmeans_iters, seed ^ 0xE57)?;
    let index = KnrIndex::build(
        &reps,
        params.k_prime_factor * params.k_nn,
        params.kmeans_iters,
        backend,
    )?;
    let k_nn = params.k_nn.min(p);
    let knr = index.approx_knr(x, k_nn, backend);
    let aff = build_affinity(n, index.p(), k_nn, &knr);
    // probe k_max + 1 pairs when possible so the gap AT k_max is visible
    let probe = (k_max + 1).min(aff.b.cols);
    let tc = transfer_cut(&aff.b, probe, EigSolver::Dense, seed ^ 0xE58)?;
    let k = eigengap_k(&tc.lambdas, k_min).min(k_max);
    let gap = if k < tc.lambdas.len() { tc.lambdas[k] - tc.lambdas[k - 1] } else { 0.0 };
    Ok(KEstimate { k, lambdas: tc.lambdas, gap })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::data::synthetic::{concentric_circles, smiling_face, two_moons};

    #[test]
    fn eigengap_picks_planted_gap() {
        // spectrum with 3 near-zero values then a jump
        let lam = vec![0.0, 1e-4, 3e-4, 0.42, 0.55, 0.6];
        assert_eq!(eigengap_k(&lam, 2), 3);
        // k_min forces past an early gap
        let lam2 = vec![0.0, 0.5, 0.52, 0.53, 0.9];
        assert_eq!(eigengap_k(&lam2, 2), 4);
        // degenerate input
        assert_eq!(eigengap_k(&[0.0], 2), 1);
    }

    #[test]
    fn recovers_k_on_moons_and_circles() {
        let moons = two_moons(1500, 0.05, 7);
        let params = UspecParams { p: 150, ..Default::default() };
        let est = estimate_k(&moons.x, &params, 2, 8, 3, &NativeBackend).unwrap();
        assert_eq!(est.k, 2, "moons: spectrum {:?}", est.lambdas);

        // the estimate needs p large enough to resolve the thinnest
        // structure: at p=150 the middle circle blurs (λ₃ ≉ 0), from
        // p≈300 up the estimate is a stable 3 across seeds.
        let circles = concentric_circles(2000, 9);
        let params = UspecParams { p: 400, ..Default::default() };
        let est = estimate_k(&circles.x, &params, 2, 8, 3, &NativeBackend).unwrap();
        assert_eq!(est.k, 3, "circles: spectrum {:?}", est.lambdas);
    }

    #[test]
    fn estimate_on_smiling_face() {
        // 4 components (two eyes, nose, mouth/face arc)
        let ds = smiling_face(3000, 5);
        let params = UspecParams { p: 250, ..Default::default() };
        let est = estimate_k(&ds.x, &params, 2, 10, 11, &NativeBackend).unwrap();
        assert!(
            (3..=6).contains(&est.k),
            "smiling face estimate {} (spectrum {:?})",
            est.k,
            est.lambdas
        );
    }

    #[test]
    fn rejects_bad_ranges() {
        let ds = two_moons(100, 0.05, 1);
        let params = UspecParams { p: 30, ..Default::default() };
        assert!(estimate_k(&ds.x, &params, 5, 5, 1, &NativeBackend).is_err());
        assert!(estimate_k(&ds.x, &params, 0, 0, 1, &NativeBackend).is_err());
    }
}
