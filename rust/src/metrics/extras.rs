//! Additional clustering-quality measures beyond the paper's NMI/CA:
//! purity, pairwise precision/recall/F, Rand and Jaccard indices, and the
//! V-measure family (homogeneity / completeness). Used by the extended
//! examples and the consensus-function ablation bench, and as
//! cross-checks in the property tests (e.g. ARI and Rand must agree on
//! their fixed points).

use super::{contingency, Contingency};

/// Purity: each predicted cluster votes for its majority class;
/// purity = (Σ_c max_j n_cj) / N, in (0, 1].
pub fn purity(pred: &[u32], truth: &[u32]) -> f64 {
    let c = contingency(pred, truth);
    if c.n == 0 {
        return 0.0;
    }
    let mut total = 0u64;
    for i in 0..c.k1 {
        total += (0..c.k2).map(|j| c.table[i * c.k2 + j]).max().unwrap_or(0);
    }
    total as f64 / c.n as f64
}

/// Pair-counting statistics (a, b, c, d):
/// a = pairs together in both, b = together in pred only,
/// c = together in truth only, d = separated in both. a+b+c+d = C(n,2).
pub fn pair_counts(pred: &[u32], truth: &[u32]) -> (f64, f64, f64, f64) {
    let ct = contingency(pred, truth);
    let comb2 = |x: u64| -> f64 { (x as f64) * (x as f64 - 1.0) / 2.0 };
    let sum_ij: f64 = ct.table.iter().map(|&x| comb2(x)).sum();
    let sum_rows: f64 = ct.row_sums.iter().map(|&x| comb2(x)).sum();
    let sum_cols: f64 = ct.col_sums.iter().map(|&x| comb2(x)).sum();
    let total = comb2(ct.n);
    let a = sum_ij;
    let b = sum_rows - sum_ij;
    let c = sum_cols - sum_ij;
    let d = total - a - b - c;
    (a, b, c, d)
}

/// (Unadjusted) Rand index: (a + d) / C(n,2), in [0, 1].
pub fn rand_index(pred: &[u32], truth: &[u32]) -> f64 {
    let (a, b, c, d) = pair_counts(pred, truth);
    let total = a + b + c + d;
    if total <= 0.0 {
        return 0.0;
    }
    (a + d) / total
}

/// Jaccard index over pairs: a / (a + b + c), in [0, 1].
pub fn jaccard_index(pred: &[u32], truth: &[u32]) -> f64 {
    let (a, b, c, _) = pair_counts(pred, truth);
    if a + b + c <= 0.0 {
        return 0.0;
    }
    a / (a + b + c)
}

/// Pairwise precision, recall, and F1 of the "same cluster" relation.
pub fn pairwise_f(pred: &[u32], truth: &[u32]) -> (f64, f64, f64) {
    let (a, b, c, _) = pair_counts(pred, truth);
    let precision = if a + b > 0.0 { a / (a + b) } else { 0.0 };
    let recall = if a + c > 0.0 { a / (a + c) } else { 0.0 };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    (precision, recall, f1)
}

fn entropy(sums: &[u64], n: f64) -> f64 {
    sums.iter()
        .filter(|&&s| s > 0)
        .map(|&s| {
            let p = s as f64 / n;
            -p * p.ln()
        })
        .sum()
}

fn conditional_entropy_truth_given_pred(c: &Contingency) -> f64 {
    let n = c.n as f64;
    let mut h = 0.0;
    for i in 0..c.k1 {
        let ni = c.row_sums[i] as f64;
        if ni == 0.0 {
            continue;
        }
        for j in 0..c.k2 {
            let nij = c.table[i * c.k2 + j] as f64;
            if nij > 0.0 {
                h -= (nij / n) * (nij / ni).ln();
            }
        }
    }
    h
}

/// Homogeneity: 1 − H(truth|pred)/H(truth). 1 ⇔ every predicted cluster
/// contains members of a single class.
pub fn homogeneity(pred: &[u32], truth: &[u32]) -> f64 {
    let c = contingency(pred, truth);
    let n = c.n as f64;
    if n == 0.0 {
        return 1.0;
    }
    let h_truth = entropy(&c.col_sums, n);
    if h_truth <= 0.0 {
        return 1.0;
    }
    (1.0 - conditional_entropy_truth_given_pred(&c) / h_truth).clamp(0.0, 1.0)
}

/// Completeness: 1 − H(pred|truth)/H(pred). 1 ⇔ every class is contained
/// in a single predicted cluster. (Homogeneity with arguments swapped.)
pub fn completeness(pred: &[u32], truth: &[u32]) -> f64 {
    homogeneity(truth, pred)
}

/// V-measure: harmonic mean of homogeneity and completeness
/// (Rosenberg & Hirschberg).
pub fn v_measure(pred: &[u32], truth: &[u32]) -> f64 {
    let h = homogeneity(pred, truth);
    let c = completeness(pred, truth);
    if h + c <= 0.0 {
        return 0.0;
    }
    2.0 * h * c / (h + c)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::{ari, nmi};
    use crate::util::rng::Rng;

    #[test]
    fn purity_bounds_and_known() {
        let truth = vec![0, 0, 0, 1, 1, 1];
        assert_eq!(purity(&truth, &truth), 1.0);
        // one predicted cluster over two equal classes → purity 1/2
        let one = vec![0; 6];
        assert!((purity(&one, &truth) - 0.5).abs() < 1e-12);
        // singletons are trivially pure
        let singles: Vec<u32> = (0..6).collect();
        assert_eq!(purity(&singles, &truth), 1.0);
    }

    #[test]
    fn pair_counts_sum_to_total() {
        let mut rng = Rng::new(7);
        for _ in 0..20 {
            let n = 120;
            let a: Vec<u32> = (0..n).map(|_| rng.usize(4) as u32).collect();
            let b: Vec<u32> = (0..n).map(|_| rng.usize(3) as u32).collect();
            let (pa, pb, pc, pd) = pair_counts(&a, &b);
            let total = (n * (n - 1) / 2) as f64;
            assert!((pa + pb + pc + pd - total).abs() < 1e-6);
            assert!(pa >= 0.0 && pb >= 0.0 && pc >= 0.0 && pd >= 0.0);
        }
    }

    #[test]
    fn rand_jaccard_fixed_points() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert_eq!(rand_index(&a, &a), 1.0);
        assert_eq!(jaccard_index(&a, &a), 1.0);
        let relabeled = vec![7, 7, 3, 3, 5, 5];
        assert_eq!(rand_index(&a, &relabeled), 1.0);
        // pairwise F on identical partitions
        let (p, r, f1) = pairwise_f(&a, &relabeled);
        assert_eq!((p, r, f1), (1.0, 1.0, 1.0));
    }

    #[test]
    fn rand_vs_ari_consistency() {
        // ARI = (RI − E[RI]) / (max − E[RI]); both must rank candidate
        // clusterings identically against a fixed truth when k matches.
        let truth: Vec<u32> = (0..200).map(|i| (i / 50) as u32).collect();
        let mut rng = Rng::new(3);
        let noisy = |flip: f64, rng: &mut Rng| -> Vec<u32> {
            truth
                .iter()
                .map(|&l| if rng.f64() < flip { rng.usize(4) as u32 } else { l })
                .collect()
        };
        let good = noisy(0.05, &mut rng);
        let bad = noisy(0.5, &mut rng);
        assert!(rand_index(&good, &truth) > rand_index(&bad, &truth));
        assert!(ari(&good, &truth) > ari(&bad, &truth));
        assert!(jaccard_index(&good, &truth) > jaccard_index(&bad, &truth));
    }

    #[test]
    fn v_measure_family() {
        let truth = vec![0, 0, 0, 1, 1, 1];
        // singletons: perfectly homogeneous, incomplete
        let singles: Vec<u32> = (0..6).collect();
        assert!((homogeneity(&singles, &truth) - 1.0).abs() < 1e-12);
        assert!(completeness(&singles, &truth) < 0.5);
        // one blob: complete but not homogeneous
        let blob = vec![0; 6];
        assert!((completeness(&blob, &truth) - 1.0).abs() < 1e-12);
        assert_eq!(homogeneity(&blob, &truth), 0.0);
        // v-measure is symmetric
        let pred = vec![0, 0, 1, 1, 2, 2];
        assert!((v_measure(&pred, &truth) - v_measure(&truth, &pred)).abs() < 1e-12);
        assert_eq!(v_measure(&truth, &truth), 1.0);
    }

    #[test]
    fn v_measure_tracks_nmi() {
        // V-measure and NMI are both normalized MI variants: they must
        // order a clean vs a noisy clustering the same way.
        let truth: Vec<u32> = (0..300).map(|i| (i / 100) as u32).collect();
        let mut rng = Rng::new(11);
        let noisy: Vec<u32> = truth
            .iter()
            .map(|&l| if rng.f64() < 0.3 { rng.usize(3) as u32 } else { l })
            .collect();
        assert!(v_measure(&truth, &truth) > v_measure(&noisy, &truth));
        assert!(nmi(&truth, &truth) > nmi(&noisy, &truth));
    }
}
