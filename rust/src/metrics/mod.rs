//! Clustering evaluation: NMI (Strehl & Ghosh normalization), clustering
//! accuracy CA (optimal label matching via the Hungarian algorithm), and
//! ARI. These are the two measures used throughout the paper's §4.

pub mod hungarian;
pub mod extras;

pub use extras::{
    completeness, homogeneity, jaccard_index, pair_counts, pairwise_f, purity, rand_index,
    v_measure,
};

use std::collections::HashMap;

/// Contingency table between two labelings (dense, k₁×k₂) plus marginals.
pub struct Contingency {
    pub table: Vec<u64>,
    pub k1: usize,
    pub k2: usize,
    pub row_sums: Vec<u64>,
    pub col_sums: Vec<u64>,
    pub n: u64,
}

/// Remap arbitrary labels to 0..k-1 (dense ids).
pub fn densify_labels(labels: &[u32]) -> (Vec<u32>, usize) {
    let mut map = HashMap::new();
    let mut out = Vec::with_capacity(labels.len());
    for &l in labels {
        let next = map.len() as u32;
        let id = *map.entry(l).or_insert(next);
        out.push(id);
    }
    (out, map.len())
}

/// Build the contingency table of two labelings over the same objects.
pub fn contingency(a: &[u32], b: &[u32]) -> Contingency {
    assert_eq!(a.len(), b.len(), "labelings must cover the same objects");
    let (da, k1) = densify_labels(a);
    let (db, k2) = densify_labels(b);
    let mut table = vec![0u64; k1 * k2];
    for (&x, &y) in da.iter().zip(&db) {
        table[x as usize * k2 + y as usize] += 1;
    }
    let mut row_sums = vec![0u64; k1];
    let mut col_sums = vec![0u64; k2];
    for i in 0..k1 {
        for j in 0..k2 {
            row_sums[i] += table[i * k2 + j];
            col_sums[j] += table[i * k2 + j];
        }
    }
    Contingency { table, k1, k2, row_sums, col_sums, n: a.len() as u64 }
}

/// Normalized mutual information, NMI = I(A;B) / sqrt(H(A)·H(B))
/// (Strehl–Ghosh), in [0, 1]. Degenerate single-cluster labelings give 0.
pub fn nmi(a: &[u32], b: &[u32]) -> f64 {
    let c = contingency(a, b);
    let n = c.n as f64;
    if n == 0.0 {
        return 0.0;
    }
    let mut mi = 0.0;
    for i in 0..c.k1 {
        for j in 0..c.k2 {
            let nij = c.table[i * c.k2 + j] as f64;
            if nij > 0.0 {
                let pij = nij / n;
                let pi = c.row_sums[i] as f64 / n;
                let pj = c.col_sums[j] as f64 / n;
                mi += pij * (pij / (pi * pj)).ln();
            }
        }
    }
    let h = |sums: &[u64]| -> f64 {
        sums.iter()
            .filter(|&&s| s > 0)
            .map(|&s| {
                let p = s as f64 / n;
                -p * p.ln()
            })
            .sum()
    };
    let ha = h(&c.row_sums);
    let hb = h(&c.col_sums);
    if ha <= 0.0 || hb <= 0.0 {
        return 0.0;
    }
    (mi / (ha * hb).sqrt()).clamp(0.0, 1.0)
}

/// Clustering accuracy: fraction of objects whose predicted cluster, under
/// the best one-to-one cluster↔class matching (Hungarian on the negated
/// contingency), equals the ground-truth class.
pub fn ca(pred: &[u32], truth: &[u32]) -> f64 {
    let c = contingency(pred, truth);
    if c.n == 0 {
        return 0.0;
    }
    let k = c.k1.max(c.k2);
    // Pad to square cost matrix; maximize matches = minimize (max - table).
    let maxv = *c.table.iter().max().unwrap_or(&0) as i64;
    let mut cost = vec![0i64; k * k];
    for i in 0..k {
        for j in 0..k {
            let v = if i < c.k1 && j < c.k2 { c.table[i * c.k2 + j] as i64 } else { 0 };
            cost[i * k + j] = maxv - v;
        }
    }
    let assign = hungarian::solve(&cost, k);
    let mut matched = 0u64;
    for (i, &j) in assign.iter().enumerate() {
        if i < c.k1 && j < c.k2 {
            matched += c.table[i * c.k2 + j];
        }
    }
    matched as f64 / c.n as f64
}

/// Adjusted Rand index (Hubert & Arabie).
pub fn ari(a: &[u32], b: &[u32]) -> f64 {
    let c = contingency(a, b);
    let n = c.n;
    if n < 2 {
        return 0.0;
    }
    let comb2 = |x: u64| -> f64 { (x as f64) * (x as f64 - 1.0) / 2.0 };
    let sum_ij: f64 = c.table.iter().map(|&x| comb2(x)).sum();
    let sum_a: f64 = c.row_sums.iter().map(|&x| comb2(x)).sum();
    let sum_b: f64 = c.col_sums.iter().map(|&x| comb2(x)).sum();
    let total = comb2(n);
    let expected = sum_a * sum_b / total;
    let maxi = 0.5 * (sum_a + sum_b);
    if (maxi - expected).abs() < 1e-12 {
        return 0.0;
    }
    (sum_ij - expected) / (maxi - expected)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn nmi_identity_and_permutation() {
        let a = vec![0, 0, 1, 1, 2, 2, 2];
        assert!((nmi(&a, &a) - 1.0).abs() < 1e-12);
        let perm = vec![5, 5, 9, 9, 1, 1, 1]; // same partition, relabeled
        assert!((nmi(&a, &perm) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn nmi_degenerate() {
        let a = vec![0, 0, 0, 0];
        let b = vec![0, 1, 2, 3];
        assert_eq!(nmi(&a, &b), 0.0);
        assert_eq!(nmi(&[], &[]), 0.0);
    }

    #[test]
    fn nmi_independent_low() {
        let mut rng = Rng::new(1);
        let n = 20_000;
        let a: Vec<u32> = (0..n).map(|_| rng.usize(4) as u32).collect();
        let b: Vec<u32> = (0..n).map(|_| rng.usize(4) as u32).collect();
        assert!(nmi(&a, &b) < 0.01);
    }

    #[test]
    fn ca_perfect_and_permuted() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        assert_eq!(ca(&truth, &truth), 1.0);
        let pred = vec![2, 2, 0, 0, 1, 1];
        assert_eq!(ca(&pred, &truth), 1.0);
    }

    #[test]
    fn ca_known_value() {
        // 1 of 6 objects misassigned under the optimal matching.
        let truth = vec![0, 0, 0, 1, 1, 1];
        let pred = vec![0, 0, 1, 1, 1, 1];
        assert!((ca(&pred, &truth) - 5.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn ca_different_k() {
        let truth = vec![0, 0, 1, 1];
        let pred = vec![0, 1, 2, 3]; // over-clustered
        // best matching pairs 2 of 4
        assert!((ca(&pred, &truth) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn ari_properties() {
        let a = vec![0, 0, 1, 1, 2, 2];
        assert!((ari(&a, &a) - 1.0).abs() < 1e-12);
        let mut rng = Rng::new(2);
        let n = 30_000;
        let x: Vec<u32> = (0..n).map(|_| rng.usize(3) as u32).collect();
        let y: Vec<u32> = (0..n).map(|_| rng.usize(3) as u32).collect();
        assert!(ari(&x, &y).abs() < 0.01);
    }

    #[test]
    fn ca_at_least_plurality() {
        // CA can never be below the best single-class share under matching
        let mut rng = Rng::new(3);
        for _ in 0..20 {
            let n = 200;
            let t: Vec<u32> = (0..n).map(|_| rng.usize(3) as u32).collect();
            let p: Vec<u32> = (0..n).map(|_| rng.usize(5) as u32).collect();
            let acc = ca(&p, &t);
            assert!(acc > 0.0 && acc <= 1.0);
        }
    }
}
