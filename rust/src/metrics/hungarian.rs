//! Kuhn–Munkres (Hungarian) assignment on an n×n integer cost matrix,
//! O(n³) shortest-augmenting-path formulation. Substrate for the CA
//! metric's optimal cluster↔class matching.

/// Solve min-cost perfect assignment. `cost` is row-major n×n.
/// Returns `assign[row] = col`.
pub fn solve(cost: &[i64], n: usize) -> Vec<usize> {
    assert_eq!(cost.len(), n * n);
    if n == 0 {
        return Vec::new();
    }
    const INF: i64 = i64::MAX / 4;
    // Potentials + matching over 1-based arrays (classic formulation).
    let mut u = vec![0i64; n + 1];
    let mut v = vec![0i64; n + 1];
    let mut p = vec![0usize; n + 1]; // p[col] = row matched to col (0 = none)
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![INF; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = INF;
            let mut j1 = 0usize;
            for j in 1..=n {
                if !used[j] {
                    let cur = cost[(i0 - 1) * n + (j - 1)] - u[i0] - v[j];
                    if cur < minv[j] {
                        minv[j] = cur;
                        way[j] = j0;
                    }
                    if minv[j] < delta {
                        delta = minv[j];
                        j1 = j;
                    }
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut assign = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            assign[p[j] - 1] = j - 1;
        }
    }
    assign
}

/// Total cost of an assignment.
pub fn assignment_cost(cost: &[i64], n: usize, assign: &[usize]) -> i64 {
    assign.iter().enumerate().map(|(i, &j)| cost[i * n + j]).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn brute_force(cost: &[i64], n: usize) -> i64 {
        // permutations up to n=7
        let mut perm: Vec<usize> = (0..n).collect();
        let mut best = i64::MAX;
        permute(&mut perm, 0, cost, n, &mut best);
        best
    }

    fn permute(perm: &mut Vec<usize>, k: usize, cost: &[i64], n: usize, best: &mut i64) {
        if k == n {
            let c = assignment_cost(cost, n, perm);
            if c < *best {
                *best = c;
            }
            return;
        }
        for i in k..n {
            perm.swap(k, i);
            permute(perm, k + 1, cost, n, best);
            perm.swap(k, i);
        }
    }

    #[test]
    fn known_3x3() {
        // classic example, optimum = 5 (0->1, 1->0, 2->2): 1+2+2
        let cost = vec![4, 1, 3, 2, 0, 5, 3, 2, 2];
        let a = solve(&cost, 3);
        assert_eq!(assignment_cost(&cost, 3, &a), 5);
    }

    #[test]
    fn identity_when_diag_cheapest() {
        let cost = vec![0, 9, 9, 9, 0, 9, 9, 9, 0];
        assert_eq!(solve(&cost, 3), vec![0, 1, 2]);
    }

    #[test]
    fn matches_brute_force_random() {
        let mut rng = Rng::new(17);
        for trial in 0..50 {
            let n = 2 + rng.usize(5); // 2..6
            let cost: Vec<i64> = (0..n * n).map(|_| rng.usize(50) as i64).collect();
            let a = solve(&cost, n);
            // valid permutation
            let mut seen = vec![false; n];
            for &j in &a {
                assert!(!seen[j], "trial {trial}: column used twice");
                seen[j] = true;
            }
            assert_eq!(assignment_cost(&cost, n, &a), brute_force(&cost, n), "trial {trial}");
        }
    }

    #[test]
    fn handles_negative_costs() {
        let cost = vec![-5, 0, 0, -5];
        let a = solve(&cost, 2);
        assert_eq!(assignment_cost(&cost, 2, &a), -10);
    }

    #[test]
    fn empty() {
        assert_eq!(solve(&[], 0), Vec::<usize>::new());
    }
}
