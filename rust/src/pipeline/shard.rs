//! Sharded execution over any [`DataSource`] — the row-range layer
//! between the chunk walkers ([`crate::pipeline::source`]) and multi-node
//! execution.
//!
//! A [`ShardPlan`] splits a source's `n` rows into contiguous row ranges;
//! a [`ShardView`] is a `DataSource` over one such range of a parent
//! source, translating local row offsets to global ones. The engine runs
//! its order-free passes shard-parallel through
//! [`for_each_chunk_sharded`]: scoped walker threads claim shards from an
//! atomic cursor, each walking its range with the double-buffered
//! prefetch of [`for_each_chunk_prefetch`] — so I/O on every shard
//! overlaps with compute on every other, while each chunk's kernel work
//! still fans out across the PR-1 worker pool (walkers are not pool
//! tasks, so the pool's nested-inline rule never serializes the compute).
//!
//! # The shard-invariance contract
//!
//! The shard count is an **operational knob, never a semantic one** —
//! exactly like the chunk size and the thread count before it
//! (`rust/tests/sharded_equivalence.rs` pins all three at once):
//!
//! * **Order-free passes** (KNR queries: each row's answer depends only
//!   on that row and the shared index) run shard-parallel; every chunk
//!   callback receives its *global* start row, so per-shard results land
//!   in their global row slots and the assembled output is byte-identical
//!   to the sequential walk's, for any shard count.
//! * **Order-dependent passes** (the reservoir sweeps: each draw
//!   conditions on the rows seen before it) keep their per-range merge
//!   order — ranges are contiguous and processed in ascending row order,
//!   so the sweep sees the same row stream regardless of how the plan
//!   cuts it, and only the prefetch (not the merge) is concurrent.
//!
//! A `ShardView` is also the unit a future remote executor ships: a
//! remote shard is just a `DataSource` whose `read_rows` crosses the
//! network, and the contract above already guarantees the merged result
//! is independent of how many such shards serve a pass.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::linalg::Mat;
use crate::util::par;
use crate::{ensure_arg, Error, Result};

use super::source::{for_each_chunk_prefetch, DataSource};

/// Process-wide count of live shard walkers, capping the *total* number
/// of concurrent walker threads at the `USPEC_THREADS` budget even when
/// many sharded passes run at once (e.g. coordinator workers each
/// streaming their own KNR pass). Every pass is still granted at least
/// one walker, so the cap degrades concurrency, never progress.
static ACTIVE_WALKERS: AtomicUsize = AtomicUsize::new(0);

/// Reserve up to `desired` walkers from the process budget (≥ 1 always).
fn reserve_walkers(desired: usize, budget: usize) -> usize {
    let mut cur = ACTIVE_WALKERS.load(Ordering::Relaxed);
    loop {
        let free = budget.saturating_sub(cur);
        let take = desired.min(free).max(1);
        match ACTIVE_WALKERS.compare_exchange_weak(
            cur,
            cur + take,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return take,
            Err(now) => cur = now,
        }
    }
}

/// Error message of the cancellation sentinel a walker raises to unwind
/// its own walk once another shard failed. Cancellation is detected via
/// a walker-local flag — never by matching this text — so a genuine
/// callback error with identical wording can't be swallowed, and the
/// sentinel itself is never surfaced to callers.
const ABORTED: &str = "sharded walk aborted";

/// A partition of `n` rows into contiguous, non-empty row ranges.
///
/// Ranges differ in length by at most one row (the first `n % shards`
/// ranges take the extra row), and a request for more shards than rows is
/// clamped to one row per shard — a plan never contains an empty shard.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    n: usize,
    ranges: Vec<(usize, usize)>,
}

impl ShardPlan {
    /// Plan `shards` row ranges over `n` rows. `shards == 0` is an error;
    /// `shards > n` is clamped to `n` (for `n == 0` the plan is empty).
    pub fn new(n: usize, shards: usize) -> Result<ShardPlan> {
        ensure_arg!(shards >= 1, "shard plan: shards must be >= 1 (got 0)");
        let s = shards.min(n);
        let mut ranges = Vec::with_capacity(s);
        if n > 0 {
            let base = n / s;
            let rem = n % s;
            let mut start = 0;
            for i in 0..s {
                let len = base + usize::from(i < rem);
                ranges.push((start, len));
                start += len;
            }
            debug_assert_eq!(start, n);
        }
        Ok(ShardPlan { n, ranges })
    }

    /// Total rows the plan covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of shards (≤ the requested count; 0 only when `n == 0`).
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// The `(start, len)` row ranges, ascending and contiguous.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// The `i`-th shard as a [`DataSource`] view over `src`.
    pub fn view<'a>(&self, src: &'a dyn DataSource, i: usize) -> Result<ShardView<'a>> {
        ensure_arg!(i < self.ranges.len(), "shard plan: shard {i} of {}", self.ranges.len());
        let (start, len) = self.ranges[i];
        ShardView::new(src, start, len)
    }
}

/// A [`DataSource`] over rows `[start, start + len)` of a parent source.
///
/// Local row `r` maps to parent row `start + r`; reads outside the range
/// are rejected, so a shard can never observe another shard's rows. The
/// view never exposes the parent's resident matrix (`as_mat` stays
/// `None`) — a shard is the unit of *streaming*, and the sharded walk
/// takes the parent-level zero-copy fast path itself when the whole
/// source is resident.
pub struct ShardView<'a> {
    parent: &'a dyn DataSource,
    start: usize,
    len: usize,
}

impl<'a> ShardView<'a> {
    /// View rows `[start, start + len)` of `parent`.
    pub fn new(parent: &'a dyn DataSource, start: usize, len: usize) -> Result<ShardView<'a>> {
        ensure_arg!(
            start + len <= parent.n(),
            "shard view: rows [{start}, {}) out of range (parent n={})",
            start + len,
            parent.n()
        );
        Ok(ShardView { parent, start, len })
    }

    /// First parent row of this view (the local→global offset).
    pub fn global_start(&self) -> usize {
        self.start
    }
}

impl DataSource for ShardView<'_> {
    fn n(&self) -> usize {
        self.len
    }

    fn d(&self) -> usize {
        self.parent.d()
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(
            start + len <= self.len,
            "shard view: read_rows [{start}, {}) out of shard range (len={})",
            start + len,
            self.len
        );
        self.parent.read_rows(self.start + start, len, buf)
    }
}

/// Walk `src` **shard-parallel**: dedicated walker threads claim shards
/// of `plan` from an atomic cursor (the coordinator's scheduling idiom),
/// each walking its row range with double-buffered prefetch. `f` receives
/// *global* chunk start rows and may be invoked concurrently from
/// different shards, so it must only touch state owned by its own rows
/// (disjoint global row slots) — order-dependent algorithms belong on
/// [`for_each_chunk_prefetch`] instead.
///
/// Walkers are scoped OS threads, **not** pool tasks: a pool task would
/// trip the pool's nested-inline rule and serialize the chunk compute,
/// whereas from a walker thread each chunk callback still dispatches its
/// kernels across the whole PR-1 pool. At most
/// [`crate::util::par::num_threads`] *walkers* run at once process-wide
/// (every pass keeps at least one), so arbitrarily many concurrent
/// sharded passes — e.g. coordinator workers — stay bounded and an
/// over-wide plan degrades gracefully. Thread accounting: each walker
/// pairs with one prefetch reader (I/O-blocked), and a walker computing
/// a chunk participates in its own pool dispatch alongside the pool's
/// workers — so compute threads can reach walkers + pool ≈ 2× the budget
/// when every shard is compute-bound at once. Sharding targets
/// I/O-dominated out-of-core passes, where walkers spend most of their
/// time blocked on reads; for compute-bound resident data, leave
/// `shards` at 1 (the resident fast path ignores it anyway).
///
/// Resident sources take the zero-copy single-chunk fast path (there is
/// no I/O to parallelize); a single-shard plan degrades to one prefetched
/// walk. The first error encountered cancels the walk — unclaimed shards
/// are skipped and in-flight shards stop at their next chunk — and is
/// the error returned.
pub fn for_each_chunk_sharded(
    src: &dyn DataSource,
    plan: &ShardPlan,
    chunk: usize,
    f: impl Fn(usize, &Mat) -> Result<()> + Sync,
) -> Result<()> {
    ensure_arg!(chunk >= 1, "for_each_chunk_sharded: chunk must be >= 1 (got 0)");
    ensure_arg!(
        plan.n() == src.n(),
        "shard plan covers {} rows but source has {}",
        plan.n(),
        src.n()
    );
    if let Some(m) = src.as_mat() {
        if m.rows == 0 {
            return Ok(());
        }
        return f(0, m);
    }
    if plan.ranges.is_empty() {
        return Ok(()); // n == 0
    }
    if plan.shards() == 1 {
        return for_each_chunk_prefetch(src, chunk, f);
    }
    /// Walk one shard; `Ok` covers both completion and cancellation (a
    /// cancelled walker rechecks `abort` at its loop head and exits).
    fn walk_shard(
        plan: &ShardPlan,
        src: &dyn DataSource,
        chunk: usize,
        f: &(impl Fn(usize, &Mat) -> Result<()> + Sync),
        abort: &AtomicBool,
        i: usize,
    ) -> Result<()> {
        let (start, _) = plan.ranges[i];
        let view = plan.view(src, i)?;
        // Out-of-band cancellation marker: only the check below sets it,
        // so a genuine `f` error can never be mistaken for cancellation.
        let cancelled = Cell::new(false);
        let r = for_each_chunk_prefetch(&view, chunk, |local, m| {
            // Stop at the next chunk once any shard failed: the sentinel
            // unwinds this walk but is never reported (the real error is).
            if abort.load(Ordering::Relaxed) {
                cancelled.set(true);
                return Err(Error::Runtime(ABORTED.into()));
            }
            f(start + local, m)
        });
        match r {
            Err(_) if cancelled.get() => Ok(()),
            other => other,
        }
    }

    /// Returns the reservation even when a walker panic unwinds the scope.
    struct WalkerLease(usize);

    impl Drop for WalkerLease {
        fn drop(&mut self) {
            ACTIVE_WALKERS.fetch_sub(self.0, Ordering::Relaxed);
        }
    }

    let nshards = plan.shards();
    let desired = nshards.min(par::num_threads()).max(1);
    let walkers = reserve_walkers(desired, par::num_threads().max(1));
    let _lease = WalkerLease(walkers);
    let cursor = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let first_error: Mutex<Option<Error>> = Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..walkers {
            s.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= nshards {
                    break;
                }
                if let Err(e) = walk_shard(plan, src, chunk, &f, &abort, i) {
                    abort.store(true, Ordering::Relaxed);
                    let mut fe = first_error.lock().unwrap();
                    if fe.is_none() {
                        *fe = Some(e);
                    }
                    break;
                }
            });
        }
    });
    if let Some(e) = first_error.into_inner().unwrap() {
        return Err(e);
    }
    // The sentinel can only trail a recorded real error, so reaching here
    // means no shard failed and the cursor drained every shard.
    debug_assert!(!abort.load(Ordering::Relaxed));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::pipeline::testutil::NonResident;

    #[test]
    fn plan_covers_rows_contiguously_with_balanced_tails() {
        for (n, shards) in [(10usize, 3usize), (7, 7), (100, 1), (9, 4), (257, 8)] {
            let plan = ShardPlan::new(n, shards).unwrap();
            assert_eq!(plan.shards(), shards.min(n));
            let mut next = 0;
            let mut lens: Vec<usize> = Vec::new();
            for &(start, len) in plan.ranges() {
                assert_eq!(start, next, "ranges must be contiguous");
                assert!(len >= 1, "no empty shards");
                lens.push(len);
                next = start + len;
            }
            assert_eq!(next, n, "ranges must cover all rows");
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "uneven tail must differ by at most one row");
        }
    }

    #[test]
    fn plan_edge_cases() {
        // shards == 0 is a configuration error
        assert!(ShardPlan::new(100, 0).is_err());
        // n smaller than the shard count: clamp to one row per shard
        let plan = ShardPlan::new(3, 8).unwrap();
        assert_eq!(plan.shards(), 3);
        assert_eq!(plan.ranges(), &[(0, 1), (1, 1), (2, 1)]);
        // single-row shards by request
        let plan = ShardPlan::new(5, 5).unwrap();
        assert_eq!(plan.ranges(), &[(0, 1), (1, 1), (2, 1), (3, 1), (4, 1)]);
        // empty source: an empty (but valid) plan
        let plan = ShardPlan::new(0, 4).unwrap();
        assert_eq!(plan.shards(), 0);
        assert_eq!(plan.n(), 0);
    }

    #[test]
    fn view_translates_ranges_at_shard_boundaries() {
        let mut x = Mat::zeros(20, 1);
        for i in 0..20 {
            x.set(i, 0, i as f32);
        }
        let src = NonResident(&x);
        let plan = ShardPlan::new(20, 3).unwrap(); // ranges 7 + 7 + 6
        assert_eq!(plan.ranges(), &[(0, 7), (7, 7), (14, 6)]);
        let view = plan.view(&src, 1).unwrap();
        assert_eq!((view.n(), view.d(), view.global_start()), (7, 1, 7));
        let mut buf = Mat::zeros(0, 1);
        // first local row is the parent row at the shard boundary
        view.read_rows(0, 1, &mut buf).unwrap();
        assert_eq!(buf.at(0, 0), 7.0);
        // last local row maps to the row just before the next boundary
        view.read_rows(6, 1, &mut buf).unwrap();
        assert_eq!(buf.at(0, 0), 13.0);
        // a read spanning the whole shard translates every row
        view.read_rows(0, 7, &mut buf).unwrap();
        let got: Vec<f32> = (0..7).map(|i| buf.at(i, 0)).collect();
        assert_eq!(got, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0]);
        // reads past the shard end are rejected, even though the parent
        // has those rows
        assert!(view.read_rows(6, 2, &mut buf).is_err());
        assert!(view.read_rows(7, 1, &mut buf).is_err());
        // views past the parent end are rejected at construction
        assert!(ShardView::new(&src, 15, 6).is_err());
    }

    #[test]
    fn sharded_walk_covers_every_row_once_at_global_offsets() {
        let ds = two_moons(257, 0.05, 31);
        let src = NonResident(&ds.x);
        for shards in [1usize, 2, 3, 7, 257] {
            let plan = ShardPlan::new(257, shards).unwrap();
            let seen = Mutex::new(vec![0u32; 257]);
            for_each_chunk_sharded(&src, &plan, 50, |start, m| {
                let mut seen = seen.lock().unwrap();
                for i in 0..m.rows {
                    assert_eq!(m.row(i), ds.x.row(start + i), "row {} content", start + i);
                    seen[start + i] += 1;
                }
                Ok(())
            })
            .unwrap();
            assert!(
                seen.into_inner().unwrap().iter().all(|&c| c == 1),
                "every row exactly once (shards={shards})"
            );
        }
    }

    #[test]
    fn sharded_walk_takes_resident_fast_path_and_validates() {
        let ds = two_moons(64, 0.05, 32);
        let plan = ShardPlan::new(64, 4).unwrap();
        let calls = Mutex::new(0usize);
        for_each_chunk_sharded(&ds.x, &plan, 10, |start, m| {
            assert_eq!((start, m.rows), (0, 64));
            *calls.lock().unwrap() += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls.into_inner().unwrap(), 1);
        // chunk == 0 and a mismatched plan are errors
        let src = NonResident(&ds.x);
        assert!(for_each_chunk_sharded(&src, &plan, 0, |_, _| Ok(())).is_err());
        let wrong = ShardPlan::new(63, 4).unwrap();
        assert!(for_each_chunk_sharded(&src, &wrong, 10, |_, _| Ok(())).is_err());
    }

    #[test]
    fn sharded_walk_propagates_the_first_failing_shard() {
        let ds = two_moons(100, 0.05, 33);
        let src = NonResident(&ds.x);
        let plan = ShardPlan::new(100, 4).unwrap();
        let err = for_each_chunk_sharded(&src, &plan, 10, |start, _| {
            crate::ensure_arg!(start < 50, "shard failure at {start}");
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("shard failure"), "{err}");
    }
}
