//! Sharded execution over any [`DataSource`] — the row-range layer
//! between the chunk walkers ([`crate::pipeline::source`]) and multi-node
//! execution.
//!
//! A [`ShardPlan`] splits a source's `n` rows into contiguous row ranges;
//! a [`ShardView`] is a `DataSource` over one such range of a parent
//! source, translating local row offsets to global ones. The engine runs
//! its order-free passes shard-parallel through
//! [`for_each_chunk_sharded`]: scoped walker threads claim shards from an
//! atomic cursor, each walking its range with the prefetch of
//! [`for_each_chunk_prefetch_depth`] — so I/O on every shard overlaps
//! with compute on every other, while each chunk's kernel work still fans
//! out across the PR-1 worker pool (walkers are not pool tasks, so the
//! pool's nested-inline rule never serializes the compute).
//!
//! # The adaptive walk planner
//!
//! How many walkers a pass should run is a property of the *storage*,
//! not of the shard count. One walker per shard (the old fixed knob) is
//! exactly wrong on a single disk: N prefetch readers seek-thrash one
//! spindle, and N walkers all dispatching chunk kernels compete with the
//! worker pool for cores (compute threads ≈ walkers + pool ≈ 2× the
//! budget) — the committed `shard_sweep` bench degraded 2.35× → 1.87×
//! from 1 to 8 shards on one disk. [`plan_walk`] therefore derives the
//! walker count and per-walker prefetch depth from a
//! [`StorageProfile`]: serialized storage gets at most two walkers with
//! a deep prefetch queue (the device streams; the queue hides uneven
//! compute bursts), parallel storage scales walkers toward *half* the
//! thread budget (leaving the other half for the pool the walkers
//! dispatch into). The profile comes from the `ExecOpts` hint, or —
//! when left at [`StorageProfile::Auto`] — from a one-shot timing probe
//! that reads one chunk from two distant shards sequentially and then
//! concurrently. Everything the planner decides is **operational**:
//! shards are still claimed off the same cursor, results are
//! bit-identical for every profile, walker count, and depth.
//!
//! # The shard-invariance contract
//!
//! The shard count is an **operational knob, never a semantic one** —
//! exactly like the chunk size and the thread count before it
//! (`rust/tests/sharded_equivalence.rs` pins all three at once):
//!
//! * **Order-free passes** (KNR queries: each row's answer depends only
//!   on that row and the shared index) run shard-parallel; every chunk
//!   callback receives its *global* start row, so per-shard results land
//!   in their global row slots and the assembled output is byte-identical
//!   to the sequential walk's, for any shard count.
//! * **Order-dependent passes** (the reservoir sweeps: each draw
//!   conditions on the rows seen before it) keep their per-range merge
//!   order — ranges are contiguous and processed in ascending row order,
//!   so the sweep sees the same row stream regardless of how the plan
//!   cuts it, and only the prefetch (not the merge) is concurrent.
//!
//! A `ShardView` is also the unit the remote executor ships: a remote
//! shard is just a `DataSource` whose `read_rows` crosses the network
//! ([`crate::net::RemoteSource`]), and the contract above already
//! guarantees the merged result is independent of how many such shards
//! serve a pass. A remote backend announces itself through
//! [`DataSource::storage_hint`] — [`StorageProfile::Remote`] plans like
//! serialized storage but with a deeper prefetch queue (each read pays a
//! network round-trip, so the queue hides latency, not seeks) — and a
//! composite source mixing backends announces its boundaries through
//! [`DataSource::segments`], which [`ShardPlan::aligned`] respects so no
//! shard straddles two backends.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::linalg::Mat;
use crate::util::par;
use crate::{ensure_arg, Error, Result};

use super::source::{for_each_chunk_prefetch_depth, DataSource};

/// Process-wide count of live shard walkers, capping the *total* number
/// of concurrent walker threads at the `USPEC_THREADS` budget even when
/// many sharded passes run at once (e.g. coordinator workers each
/// streaming their own KNR pass). Every pass is still granted at least
/// one walker, so the cap degrades concurrency, never progress.
static ACTIVE_WALKERS: AtomicUsize = AtomicUsize::new(0);

/// Reserve up to `desired` walkers from the process budget (≥ 1 always).
fn reserve_walkers(desired: usize, budget: usize) -> usize {
    let mut cur = ACTIVE_WALKERS.load(Ordering::Relaxed);
    loop {
        let free = budget.saturating_sub(cur);
        let take = desired.min(free).max(1);
        match ACTIVE_WALKERS.compare_exchange_weak(
            cur,
            cur + take,
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => return take,
            Err(now) => cur = now,
        }
    }
}

/// Error message of the cancellation sentinel a walker raises to unwind
/// its own walk once another shard failed. Cancellation is detected via
/// a walker-local flag — never by matching this text — so a genuine
/// callback error with identical wording can't be swallowed, and the
/// sentinel itself is never surfaced to callers.
const ABORTED: &str = "sharded walk aborted";

/// How a source's backing storage responds to concurrent readers — the
/// input to the adaptive walk planner (module docs). Purely operational:
/// the profile picks walker count and prefetch depth, never any result.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum StorageProfile {
    /// Probe on first sharded walk: time one chunk read from two distant
    /// shards sequentially, then concurrently; classify [`Self::Serial`]
    /// when the concurrent pair costs closer to the sum than to the max.
    /// Page-cache-fast reads skip the concurrent leg and classify
    /// [`Self::Parallel`] (at µs read times reader contention is
    /// irrelevant and the timing would be pure noise).
    #[default]
    Auto,
    /// Reads serialize (single spindle, one network connection): few
    /// walkers, deeper per-walker prefetch to keep the device streaming.
    Serial,
    /// Reads scale with concurrency (page cache, NVMe, striped array):
    /// walkers scale toward half the thread budget.
    Parallel,
    /// Reads cross a network round-trip ([`crate::net::RemoteSource`]):
    /// few walkers (the link serializes anyway), deepest prefetch queue
    /// (the queue hides latency, not seeks).
    Remote,
}

impl StorageProfile {
    /// Parse the CLI/config spelling: `auto`, `serial`, `parallel`, or
    /// `remote` (device aliases `hdd` → serial, `ssd`/`nvme` → parallel,
    /// `net`/`network` → remote).
    pub fn parse(s: &str) -> Result<StorageProfile> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Ok(StorageProfile::Auto),
            "serial" | "hdd" => Ok(StorageProfile::Serial),
            "parallel" | "ssd" | "nvme" => Ok(StorageProfile::Parallel),
            "remote" | "net" | "network" => Ok(StorageProfile::Remote),
            other => Err(Error::Config(format!(
                "unknown storage profile '{other}' (want auto, serial, parallel, or remote)"
            ))),
        }
    }

    /// Canonical spelling, inverse of [`StorageProfile::parse`].
    pub fn name(&self) -> &'static str {
        match self {
            StorageProfile::Auto => "auto",
            StorageProfile::Serial => "serial",
            StorageProfile::Parallel => "parallel",
            StorageProfile::Remote => "remote",
        }
    }
}

/// Prefetch depth on serialized storage: a deep queue keeps the one
/// device streaming across the consumer's compute bursts.
const SERIAL_PREFETCH_DEPTH: usize = 4;
/// Prefetch depth on parallel storage: per-walker double buffering plus
/// one chunk of slack.
const PARALLEL_PREFETCH_DEPTH: usize = 2;
/// Walker cap on serialized storage: a second walker overlaps one
/// shard's compute tail with the next shard's reads; more walkers only
/// multiply seeks.
const SERIAL_MAX_WALKERS: usize = 2;
/// Prefetch depth on remote storage: each read pays a network round-trip,
/// so a deep in-flight queue keeps the link busy across compute bursts.
/// Public because the remote client's request pipelining
/// ([`crate::net::client::PIPELINE_DEPTH`]) matches this depth — the
/// wire keeps as many frames in flight as the prefetch queue it feeds.
pub const REMOTE_PREFETCH_DEPTH: usize = 6;
/// Walker cap on remote storage: like a spindle, one TCP link serializes;
/// a second walker overlaps shard tails, more only contend.
const REMOTE_MAX_WALKERS: usize = 2;
/// Probe classification floor: when both sequential probe reads finish
/// inside this budget, the source is page-cache fast and the concurrent
/// leg would time scheduler noise, not storage.
const PROBE_FAST: Duration = Duration::from_millis(2);

/// Resolved execution shape of one sharded pass.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WalkPlan {
    /// Concurrent shard walkers to request from the `ACTIVE_WALKERS`
    /// budget (the reservation may grant fewer, never zero).
    pub walkers: usize,
    /// Chunks each walker's prefetch reader keeps in flight.
    pub prefetch_depth: usize,
}

/// Derive the walker count and prefetch depth for a sharded pass from
/// the storage profile, the shard count, and the thread budget (module
/// docs lay out the contention math). [`StorageProfile::Auto`] is
/// resolved by the probe before planning; an unresolved `Auto` here is
/// planned like [`StorageProfile::Parallel`].
pub fn plan_walk(profile: StorageProfile, shards: usize, budget: usize) -> WalkPlan {
    let shards = shards.max(1);
    let budget = budget.max(1);
    match profile {
        StorageProfile::Serial => WalkPlan {
            walkers: shards.min(SERIAL_MAX_WALKERS),
            prefetch_depth: SERIAL_PREFETCH_DEPTH,
        },
        StorageProfile::Remote => WalkPlan {
            walkers: shards.min(REMOTE_MAX_WALKERS),
            prefetch_depth: REMOTE_PREFETCH_DEPTH,
        },
        StorageProfile::Auto | StorageProfile::Parallel => WalkPlan {
            // Half the budget: each walker computing a chunk dispatches
            // into the worker pool, so walkers ≈ budget would put
            // walkers + pool ≈ 2× budget compute threads on the cores —
            // the diagnosed shard_sweep cliff.
            walkers: shards.min((budget / 2).max(1)),
            prefetch_depth: PARALLEL_PREFETCH_DEPTH,
        },
    }
}

/// Resolve [`StorageProfile::Auto`] by timing one chunk read from the
/// first and the middle shard, sequentially and then concurrently.
/// Serialized storage completes the concurrent pair in ≈ the sum of the
/// two solo times; parallel storage in ≈ their max — classify `Serial`
/// when the concurrent time lands in the upper half of that interval.
/// The probe re-reads rows the walk is about to read anyway (≤ 4 extra
/// chunk reads), and a probe read error defers to the walk: the profile
/// defaults to `Parallel` and the real pass surfaces the error in its
/// normal path.
fn probe_storage(src: &dyn DataSource, chunk: usize, plan: &ShardPlan) -> StorageProfile {
    let ranges = plan.ranges();
    debug_assert!(ranges.len() >= 2, "probe needs two shards");
    let (g0, l0) = ranges[0];
    let (g1, l1) = ranges[ranges.len() / 2];
    let len0 = chunk.min(l0);
    let len1 = chunk.min(l1);
    let mut b0 = Mat::zeros(0, src.d());
    let mut b1 = Mat::zeros(0, src.d());
    let t = Instant::now();
    if src.read_rows(g0, len0, &mut b0).is_err() {
        return StorageProfile::Parallel;
    }
    let ta = t.elapsed();
    let t = Instant::now();
    if src.read_rows(g1, len1, &mut b1).is_err() {
        return StorageProfile::Parallel;
    }
    let tb = t.elapsed();
    if ta + tb < PROBE_FAST {
        return StorageProfile::Parallel;
    }
    let t = Instant::now();
    std::thread::scope(|s| {
        s.spawn(|| {
            let _ = src.read_rows(g0, len0, &mut b0);
        });
        let _ = src.read_rows(g1, len1, &mut b1);
    });
    let conc = t.elapsed();
    let lone = ta.max(tb);
    let seq = ta + tb;
    if conc >= lone + (seq - lone) / 2 {
        StorageProfile::Serial
    } else {
        StorageProfile::Parallel
    }
}

/// A partition of `n` rows into contiguous, non-empty row ranges, plus
/// the storage profile the walk planner should assume (default
/// [`StorageProfile::Auto`]; see [`ShardPlan::with_storage`]).
///
/// Ranges differ in length by at most one row (the first `n % shards`
/// ranges take the extra row), and a request for more shards than rows is
/// clamped to one row per shard — a plan never contains an empty shard.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    n: usize,
    ranges: Vec<(usize, usize)>,
    storage: StorageProfile,
}

impl ShardPlan {
    /// Plan `shards` row ranges over `n` rows. `shards == 0` is an error;
    /// `shards > n` is clamped to `n` (for `n == 0` the plan is empty).
    pub fn new(n: usize, shards: usize) -> Result<ShardPlan> {
        ensure_arg!(shards >= 1, "shard plan: shards must be >= 1 (got 0)");
        let s = shards.min(n);
        let mut ranges = Vec::with_capacity(s);
        if n > 0 {
            let base = n / s;
            let rem = n % s;
            let mut start = 0;
            for i in 0..s {
                let len = base + usize::from(i < rem);
                ranges.push((start, len));
                start += len;
            }
            debug_assert_eq!(start, n);
        }
        Ok(ShardPlan { n, ranges, storage: StorageProfile::Auto })
    }

    /// Plan from explicit `(start, len)` ranges, which must be non-empty,
    /// contiguous from row 0, and individually non-empty. This is how a
    /// caller with natural boundaries (file segments, remote endpoints)
    /// dictates exactly where shards cut.
    pub fn from_ranges(ranges: Vec<(usize, usize)>) -> Result<ShardPlan> {
        ensure_arg!(!ranges.is_empty(), "shard plan: no ranges");
        let mut next = 0usize;
        for &(start, len) in &ranges {
            ensure_arg!(
                start == next,
                "shard plan: range [{start}, {}) not contiguous (expected start {next})",
                start + len
            );
            ensure_arg!(len >= 1, "shard plan: empty range at row {start}");
            next = start + len;
        }
        Ok(ShardPlan { n: next, ranges, storage: StorageProfile::Auto })
    }

    /// Plan up to `shards` ranges over `n` rows, **aligned** to the given
    /// segment boundaries (contiguous from 0, covering `n` — the
    /// [`DataSource::segments`] contract): every segment gets at least one
    /// shard and no shard straddles two segments, so a composite source
    /// never serves one shard from two backends. Shards are distributed
    /// across segments proportionally to their row counts.
    pub fn aligned(n: usize, shards: usize, segments: &[(usize, usize)]) -> Result<ShardPlan> {
        ensure_arg!(shards >= 1, "shard plan: shards must be >= 1 (got 0)");
        ensure_arg!(!segments.is_empty(), "shard plan: no segments");
        let mut next = 0usize;
        for &(start, len) in segments {
            ensure_arg!(
                start == next && len >= 1,
                "shard plan: segment [{start}, {}) invalid (expected contiguous from {next})",
                start + len
            );
            next = start + len;
        }
        ensure_arg!(next == n, "shard plan: segments cover {next} rows, source has {n}");
        let mut ranges = Vec::new();
        for &(start, len) in segments {
            // Proportional share of the shard budget, at least one shard
            // per segment, never more shards than rows.
            let share = (shards * len).div_ceil(n).max(1).min(len);
            let sub = ShardPlan::new(len, share)?;
            for &(s, l) in sub.ranges() {
                ranges.push((start + s, l));
            }
        }
        Ok(ShardPlan { n, ranges, storage: StorageProfile::Auto })
    }

    /// Pin the storage profile the walk planner assumes, skipping the
    /// [`StorageProfile::Auto`] probe. Operational only — results are
    /// bit-identical for every profile.
    pub fn with_storage(mut self, storage: StorageProfile) -> ShardPlan {
        self.storage = storage;
        self
    }

    /// The storage profile the walk planner will assume.
    pub fn storage(&self) -> StorageProfile {
        self.storage
    }

    /// Total rows the plan covers.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of shards (≤ the requested count; 0 only when `n == 0`).
    pub fn shards(&self) -> usize {
        self.ranges.len()
    }

    /// The `(start, len)` row ranges, ascending and contiguous.
    pub fn ranges(&self) -> &[(usize, usize)] {
        &self.ranges
    }

    /// The `i`-th shard as a [`DataSource`] view over `src`.
    pub fn view<'a>(&self, src: &'a dyn DataSource, i: usize) -> Result<ShardView<'a>> {
        ensure_arg!(i < self.ranges.len(), "shard plan: shard {i} of {}", self.ranges.len());
        let (start, len) = self.ranges[i];
        ShardView::new(src, start, len)
    }
}

/// A [`DataSource`] over rows `[start, start + len)` of a parent source.
///
/// Local row `r` maps to parent row `start + r`; reads outside the range
/// are rejected, so a shard can never observe another shard's rows. The
/// view never exposes the parent's resident matrix (`as_mat` stays
/// `None`) — a shard is the unit of *streaming*, and the sharded walk
/// takes the parent-level zero-copy fast path itself when the whole
/// source is resident.
pub struct ShardView<'a> {
    parent: &'a dyn DataSource,
    start: usize,
    len: usize,
}

impl<'a> ShardView<'a> {
    /// View rows `[start, start + len)` of `parent`.
    pub fn new(parent: &'a dyn DataSource, start: usize, len: usize) -> Result<ShardView<'a>> {
        ensure_arg!(
            start + len <= parent.n(),
            "shard view: rows [{start}, {}) out of range (parent n={})",
            start + len,
            parent.n()
        );
        Ok(ShardView { parent, start, len })
    }

    /// First parent row of this view (the local→global offset).
    pub fn global_start(&self) -> usize {
        self.start
    }
}

impl DataSource for ShardView<'_> {
    fn n(&self) -> usize {
        self.len
    }

    fn d(&self) -> usize {
        self.parent.d()
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(
            start + len <= self.len,
            "shard view: read_rows [{start}, {}) out of shard range (len={})",
            start + len,
            self.len
        );
        self.parent.read_rows(self.start + start, len, buf)
    }

    /// A view is backed by whatever backs its parent.
    fn storage_hint(&self) -> Option<StorageProfile> {
        self.parent.storage_hint()
    }
}

/// Walk `src` **shard-parallel**: dedicated walker threads claim shards
/// of `plan` from an atomic cursor (the coordinator's scheduling idiom),
/// each walking its row range with double-buffered prefetch. `f` receives
/// *global* chunk start rows and may be invoked concurrently from
/// different shards, so it must only touch state owned by its own rows
/// (disjoint global row slots) — order-dependent algorithms belong on
/// [`for_each_chunk_prefetch`] instead.
///
/// Walkers are scoped OS threads, **not** pool tasks: a pool task would
/// trip the pool's nested-inline rule and serialize the chunk compute,
/// whereas from a walker thread each chunk callback still dispatches its
/// kernels across the whole PR-1 pool. How many walkers a pass runs, and
/// how deep each walker's prefetch queue is, comes from [`plan_walk`] on
/// the plan's [`StorageProfile`] (probing once when left at `Auto`) —
/// the module docs lay out the contention diagnosis behind the shapes.
/// Whatever the planner asks for is still charged against the
/// process-wide `ACTIVE_WALKERS` ledger (every pass keeps at least one
/// walker), so arbitrarily many concurrent sharded passes — e.g.
/// coordinator workers — stay bounded and an over-wide plan degrades
/// gracefully. Sharding targets I/O-dominated out-of-core passes, where
/// walkers spend most of their time blocked on reads; for compute-bound
/// resident data, leave `shards` at 1 (the resident fast path ignores it
/// anyway).
///
/// Resident sources take the zero-copy single-chunk fast path (there is
/// no I/O to parallelize, and no probe runs); a single-shard plan
/// degrades to one prefetched walk at the profile's depth. The first
/// error encountered cancels the walk — unclaimed shards are skipped and
/// in-flight shards stop at their next chunk — and is the error
/// returned.
pub fn for_each_chunk_sharded(
    src: &dyn DataSource,
    plan: &ShardPlan,
    chunk: usize,
    f: impl Fn(usize, &Mat) -> Result<()> + Sync,
) -> Result<()> {
    ensure_arg!(chunk >= 1, "for_each_chunk_sharded: chunk must be >= 1 (got 0)");
    ensure_arg!(
        plan.n() == src.n(),
        "shard plan covers {} rows but source has {}",
        plan.n(),
        src.n()
    );
    if let Some(m) = src.as_mat() {
        if m.rows == 0 {
            return Ok(());
        }
        return f(0, m);
    }
    if plan.ranges.is_empty() {
        return Ok(()); // n == 0
    }
    if plan.shards() == 1 {
        // One walker either way; an explicit Serial/Remote hint still gets
        // its deeper prefetch queue. Auto is NOT probed here — with a
        // single walker there is no reader concurrency to classify for —
        // but a source that *knows* its backend still shapes the queue.
        let depth = match plan.storage {
            StorageProfile::Serial => SERIAL_PREFETCH_DEPTH,
            StorageProfile::Remote => REMOTE_PREFETCH_DEPTH,
            StorageProfile::Auto => match src.storage_hint() {
                Some(StorageProfile::Serial) => SERIAL_PREFETCH_DEPTH,
                Some(StorageProfile::Remote) => REMOTE_PREFETCH_DEPTH,
                _ => 1,
            },
            StorageProfile::Parallel => 1,
        };
        return for_each_chunk_prefetch_depth(src, chunk, depth, f);
    }
    /// Walk one shard; `Ok` covers both completion and cancellation (a
    /// cancelled walker rechecks `abort` at its loop head and exits).
    fn walk_shard(
        plan: &ShardPlan,
        src: &dyn DataSource,
        chunk: usize,
        depth: usize,
        f: &(impl Fn(usize, &Mat) -> Result<()> + Sync),
        abort: &AtomicBool,
        i: usize,
    ) -> Result<()> {
        let (start, _) = plan.ranges[i];
        let view = plan.view(src, i)?;
        // Out-of-band cancellation marker: only the check below sets it,
        // so a genuine `f` error can never be mistaken for cancellation.
        let cancelled = Cell::new(false);
        let r = for_each_chunk_prefetch_depth(&view, chunk, depth, |local, m| {
            // Stop at the next chunk once any shard failed: the sentinel
            // unwinds this walk but is never reported (the real error is).
            if abort.load(Ordering::Relaxed) {
                cancelled.set(true);
                return Err(Error::Runtime(ABORTED.into()));
            }
            f(start + local, m)
        });
        match r {
            Err(_) if cancelled.get() => Ok(()),
            other => other,
        }
    }

    /// Returns the reservation even when a walker panic unwinds the scope.
    struct WalkerLease(usize);

    impl Drop for WalkerLease {
        fn drop(&mut self) {
            ACTIVE_WALKERS.fetch_sub(self.0, Ordering::Relaxed);
        }
    }

    let nshards = plan.shards();
    let profile = match plan.storage {
        // A source that knows its backend (remote link, composite) skips
        // the probe; only a genuinely unknown backing is timed.
        StorageProfile::Auto => match src.storage_hint() {
            Some(p) => p,
            None => probe_storage(src, chunk, plan),
        },
        pinned => pinned,
    };
    let wp = plan_walk(profile, nshards, par::num_threads());
    let walkers = reserve_walkers(wp.walkers, par::num_threads().max(1));
    let _lease = WalkerLease(walkers);
    let cursor = AtomicUsize::new(0);
    let abort = AtomicBool::new(false);
    let first_error: Mutex<Option<Error>> = Mutex::new(None);
    std::thread::scope(|s| {
        for _ in 0..walkers {
            s.spawn(|| loop {
                if abort.load(Ordering::Relaxed) {
                    break;
                }
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= nshards {
                    break;
                }
                if let Err(e) = walk_shard(plan, src, chunk, wp.prefetch_depth, &f, &abort, i) {
                    abort.store(true, Ordering::Relaxed);
                    let mut fe = first_error.lock().unwrap();
                    if fe.is_none() {
                        *fe = Some(e);
                    }
                    break;
                }
            });
        }
    });
    if let Some(e) = first_error.into_inner().unwrap() {
        return Err(e);
    }
    // The sentinel can only trail a recorded real error, so reaching here
    // means no shard failed and the cursor drained every shard.
    debug_assert!(!abort.load(Ordering::Relaxed));
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::pipeline::testutil::NonResident;

    #[test]
    fn plan_covers_rows_contiguously_with_balanced_tails() {
        for (n, shards) in [(10usize, 3usize), (7, 7), (100, 1), (9, 4), (257, 8)] {
            let plan = ShardPlan::new(n, shards).unwrap();
            assert_eq!(plan.shards(), shards.min(n));
            let mut next = 0;
            let mut lens: Vec<usize> = Vec::new();
            for &(start, len) in plan.ranges() {
                assert_eq!(start, next, "ranges must be contiguous");
                assert!(len >= 1, "no empty shards");
                lens.push(len);
                next = start + len;
            }
            assert_eq!(next, n, "ranges must cover all rows");
            let (min, max) = (lens.iter().min().unwrap(), lens.iter().max().unwrap());
            assert!(max - min <= 1, "uneven tail must differ by at most one row");
        }
    }

    #[test]
    fn plan_edge_cases() {
        // shards == 0 is a configuration error
        assert!(ShardPlan::new(100, 0).is_err());
        // n smaller than the shard count: clamp to one row per shard
        let plan = ShardPlan::new(3, 8).unwrap();
        assert_eq!(plan.shards(), 3);
        assert_eq!(plan.ranges(), &[(0, 1), (1, 1), (2, 1)]);
        // single-row shards by request
        let plan = ShardPlan::new(5, 5).unwrap();
        assert_eq!(plan.ranges(), &[(0, 1), (1, 1), (2, 1), (3, 1), (4, 1)]);
        // empty source: an empty (but valid) plan
        let plan = ShardPlan::new(0, 4).unwrap();
        assert_eq!(plan.shards(), 0);
        assert_eq!(plan.n(), 0);
    }

    #[test]
    fn from_ranges_accepts_contiguous_covers_and_rejects_gaps() {
        let plan = ShardPlan::from_ranges(vec![(0, 7), (7, 13)]).unwrap();
        assert_eq!((plan.n(), plan.shards()), (20, 2));
        assert!(ShardPlan::from_ranges(vec![]).is_err());
        assert!(ShardPlan::from_ranges(vec![(1, 5)]).is_err()); // not from 0
        assert!(ShardPlan::from_ranges(vec![(0, 5), (6, 5)]).is_err()); // gap
        assert!(ShardPlan::from_ranges(vec![(0, 5), (5, 0)]).is_err()); // empty
    }

    #[test]
    fn aligned_plan_never_straddles_a_segment_boundary() {
        // 700 local + 500 remote rows, various shard budgets
        let segs = [(0usize, 700usize), (700, 500)];
        for shards in [1usize, 2, 4, 8] {
            let plan = ShardPlan::aligned(1200, shards, &segs).unwrap();
            assert_eq!(plan.n(), 1200);
            let mut next = 0;
            for &(start, len) in plan.ranges() {
                assert_eq!(start, next);
                assert!(len >= 1);
                // a shard lies entirely inside one segment
                let inside = segs
                    .iter()
                    .any(|&(s0, l0)| start >= s0 && start + len <= s0 + l0);
                assert!(inside, "shard [{start}, {}) straddles", start + len);
                next = start + len;
            }
            assert_eq!(next, 1200);
            // every segment got at least one shard
            for &(s0, _) in &segs {
                assert!(plan.ranges().iter().any(|&(s, _)| s == s0));
            }
        }
        // degenerate: segments must cover n exactly
        assert!(ShardPlan::aligned(1200, 4, &[(0, 700)]).is_err());
        assert!(ShardPlan::aligned(1200, 0, &segs).is_err());
        // tiny segments: share clamps to the segment length
        let plan = ShardPlan::aligned(5, 8, &[(0, 1), (1, 4)]).unwrap();
        assert!(plan.ranges().iter().all(|&(_, l)| l >= 1));
        assert_eq!(plan.ranges().iter().map(|&(_, l)| l).sum::<usize>(), 5);
    }

    #[test]
    fn remote_profile_plans_few_walkers_deep_prefetch() {
        let wp = plan_walk(StorageProfile::Remote, 8, 8);
        assert_eq!(
            wp,
            WalkPlan { walkers: REMOTE_MAX_WALKERS, prefetch_depth: REMOTE_PREFETCH_DEPTH }
        );
        assert_eq!(plan_walk(StorageProfile::Remote, 1, 8).walkers, 1);
        assert_eq!(StorageProfile::parse("remote").unwrap(), StorageProfile::Remote);
        assert_eq!(StorageProfile::parse("network").unwrap(), StorageProfile::Remote);
        assert_eq!(StorageProfile::Remote.name(), "remote");
    }

    /// A wrapper that reports a fixed storage hint, for planner-path
    /// coverage without a real network.
    struct Hinted<'a>(&'a Mat, StorageProfile);

    impl DataSource for Hinted<'_> {
        fn n(&self) -> usize {
            self.0.rows
        }

        fn d(&self) -> usize {
            self.0.cols
        }

        fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
            let src = NonResident(self.0);
            src.read_rows(start, len, buf)
        }

        fn storage_hint(&self) -> Option<StorageProfile> {
            Some(self.1)
        }
    }

    #[test]
    fn storage_hint_steers_auto_without_changing_results() {
        let ds = two_moons(257, 0.05, 35);
        for hint in [StorageProfile::Serial, StorageProfile::Remote, StorageProfile::Parallel] {
            let src = Hinted(&ds.x, hint);
            for shards in [1usize, 4] {
                let plan = ShardPlan::new(257, shards).unwrap(); // storage: Auto
                let seen = Mutex::new(vec![0u32; 257]);
                for_each_chunk_sharded(&src, &plan, 50, |start, m| {
                    let mut seen = seen.lock().unwrap();
                    for i in 0..m.rows {
                        assert_eq!(m.row(i), ds.x.row(start + i));
                        seen[start + i] += 1;
                    }
                    Ok(())
                })
                .unwrap();
                assert!(
                    seen.into_inner().unwrap().iter().all(|&c| c == 1),
                    "every row exactly once (hint={hint:?} shards={shards})"
                );
            }
            // the hint survives a ShardView wrapper
            let src = Hinted(&ds.x, hint);
            let view = ShardView::new(&src, 10, 50).unwrap();
            assert_eq!(view.storage_hint(), Some(hint));
        }
    }

    #[test]
    fn view_translates_ranges_at_shard_boundaries() {
        let mut x = Mat::zeros(20, 1);
        for i in 0..20 {
            x.set(i, 0, i as f32);
        }
        let src = NonResident(&x);
        let plan = ShardPlan::new(20, 3).unwrap(); // ranges 7 + 7 + 6
        assert_eq!(plan.ranges(), &[(0, 7), (7, 7), (14, 6)]);
        let view = plan.view(&src, 1).unwrap();
        assert_eq!((view.n(), view.d(), view.global_start()), (7, 1, 7));
        let mut buf = Mat::zeros(0, 1);
        // first local row is the parent row at the shard boundary
        view.read_rows(0, 1, &mut buf).unwrap();
        assert_eq!(buf.at(0, 0), 7.0);
        // last local row maps to the row just before the next boundary
        view.read_rows(6, 1, &mut buf).unwrap();
        assert_eq!(buf.at(0, 0), 13.0);
        // a read spanning the whole shard translates every row
        view.read_rows(0, 7, &mut buf).unwrap();
        let got: Vec<f32> = (0..7).map(|i| buf.at(i, 0)).collect();
        assert_eq!(got, vec![7.0, 8.0, 9.0, 10.0, 11.0, 12.0, 13.0]);
        // reads past the shard end are rejected, even though the parent
        // has those rows
        assert!(view.read_rows(6, 2, &mut buf).is_err());
        assert!(view.read_rows(7, 1, &mut buf).is_err());
        // views past the parent end are rejected at construction
        assert!(ShardView::new(&src, 15, 6).is_err());
    }

    #[test]
    fn sharded_walk_covers_every_row_once_at_global_offsets() {
        let ds = two_moons(257, 0.05, 31);
        let src = NonResident(&ds.x);
        for shards in [1usize, 2, 3, 7, 257] {
            let plan = ShardPlan::new(257, shards).unwrap();
            let seen = Mutex::new(vec![0u32; 257]);
            for_each_chunk_sharded(&src, &plan, 50, |start, m| {
                let mut seen = seen.lock().unwrap();
                for i in 0..m.rows {
                    assert_eq!(m.row(i), ds.x.row(start + i), "row {} content", start + i);
                    seen[start + i] += 1;
                }
                Ok(())
            })
            .unwrap();
            assert!(
                seen.into_inner().unwrap().iter().all(|&c| c == 1),
                "every row exactly once (shards={shards})"
            );
        }
    }

    #[test]
    fn sharded_walk_takes_resident_fast_path_and_validates() {
        let ds = two_moons(64, 0.05, 32);
        let plan = ShardPlan::new(64, 4).unwrap();
        let calls = Mutex::new(0usize);
        for_each_chunk_sharded(&ds.x, &plan, 10, |start, m| {
            assert_eq!((start, m.rows), (0, 64));
            *calls.lock().unwrap() += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls.into_inner().unwrap(), 1);
        // chunk == 0 and a mismatched plan are errors
        let src = NonResident(&ds.x);
        assert!(for_each_chunk_sharded(&src, &plan, 0, |_, _| Ok(())).is_err());
        let wrong = ShardPlan::new(63, 4).unwrap();
        assert!(for_each_chunk_sharded(&src, &wrong, 10, |_, _| Ok(())).is_err());
    }

    #[test]
    fn plan_walk_shapes_follow_the_profile() {
        // serialized storage: at most two walkers, deep prefetch queue
        let wp = plan_walk(StorageProfile::Serial, 8, 8);
        assert_eq!(
            wp,
            WalkPlan { walkers: SERIAL_MAX_WALKERS, prefetch_depth: SERIAL_PREFETCH_DEPTH }
        );
        assert_eq!(plan_walk(StorageProfile::Serial, 1, 8).walkers, 1);
        // parallel storage: walkers scale to half the budget, floor one
        assert_eq!(plan_walk(StorageProfile::Parallel, 8, 8).walkers, 4);
        assert_eq!(plan_walk(StorageProfile::Parallel, 3, 8).walkers, 3);
        assert_eq!(plan_walk(StorageProfile::Parallel, 8, 2).walkers, 1);
        assert_eq!(
            plan_walk(StorageProfile::Parallel, 8, 8).prefetch_depth,
            PARALLEL_PREFETCH_DEPTH
        );
        // unresolved Auto plans like Parallel; degenerate inputs clamp
        assert_eq!(
            plan_walk(StorageProfile::Auto, 8, 8),
            plan_walk(StorageProfile::Parallel, 8, 8)
        );
        let wp = plan_walk(StorageProfile::Parallel, 0, 0);
        assert!(wp.walkers >= 1 && wp.prefetch_depth >= 1);
    }

    /// A source whose reads sleep; with a `gate`, a mutex forces reads to
    /// queue like a single spindle would.
    struct SlowSource<'a> {
        x: &'a Mat,
        delay: std::time::Duration,
        gate: Option<Mutex<()>>,
    }

    impl DataSource for SlowSource<'_> {
        fn n(&self) -> usize {
            self.x.rows
        }

        fn d(&self) -> usize {
            self.x.cols
        }

        fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
            let _g = self.gate.as_ref().map(|m| m.lock().unwrap());
            std::thread::sleep(self.delay);
            buf.rows = len;
            buf.cols = self.x.cols;
            buf.data.clear();
            buf.data
                .extend_from_slice(&self.x.data[start * self.x.cols..(start + len) * self.x.cols]);
            Ok(())
        }
    }

    #[test]
    fn probe_classifies_serialized_and_parallel_reads() {
        let x = Mat::zeros(400, 2);
        let plan = ShardPlan::new(400, 4).unwrap();
        let ms = std::time::Duration::from_millis;
        // reads gated by one lock: the concurrent pair costs the sum → Serial
        let serial = SlowSource { x: &x, delay: ms(15), gate: Some(Mutex::new(())) };
        assert_eq!(probe_storage(&serial, 100, &plan), StorageProfile::Serial);
        // ungated reads overlap: the concurrent pair costs ≈ the max → Parallel
        let overlapping = SlowSource { x: &x, delay: ms(15), gate: None };
        assert_eq!(probe_storage(&overlapping, 100, &plan), StorageProfile::Parallel);
        // page-cache-fast reads skip the concurrent leg entirely → Parallel
        let fast = SlowSource { x: &x, delay: ms(0), gate: Some(Mutex::new(())) };
        assert_eq!(probe_storage(&fast, 100, &plan), StorageProfile::Parallel);
    }

    #[test]
    fn sharded_walk_is_profile_invariant() {
        let ds = two_moons(257, 0.05, 34);
        let src = NonResident(&ds.x);
        for profile in [StorageProfile::Auto, StorageProfile::Serial, StorageProfile::Parallel] {
            for shards in [1usize, 3, 7] {
                let plan = ShardPlan::new(257, shards).unwrap().with_storage(profile);
                let seen = Mutex::new(vec![0u32; 257]);
                for_each_chunk_sharded(&src, &plan, 50, |start, m| {
                    let mut seen = seen.lock().unwrap();
                    for i in 0..m.rows {
                        assert_eq!(m.row(i), ds.x.row(start + i));
                        seen[start + i] += 1;
                    }
                    Ok(())
                })
                .unwrap();
                assert!(
                    seen.into_inner().unwrap().iter().all(|&c| c == 1),
                    "every row exactly once (profile={profile:?} shards={shards})"
                );
            }
        }
    }

    #[test]
    fn sharded_walk_propagates_the_first_failing_shard() {
        let ds = two_moons(100, 0.05, 33);
        let src = NonResident(&ds.x);
        let plan = ShardPlan::new(100, 4).unwrap();
        let err = for_each_chunk_sharded(&src, &plan, 10, |start, _| {
            crate::ensure_arg!(start < 50, "shard failure at {start}");
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("shard failure"), "{err}");
    }
}
