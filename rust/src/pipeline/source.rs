//! The data-access layer of the staged pipeline: a [`DataSource`] is
//! anything that can report its shape and hand out contiguous row chunks
//! into a caller-provided buffer. The engine never assumes the data is
//! resident — an in-memory [`Mat`], an on-disk
//! [`crate::streaming::BinDataset`], a loader-produced
//! [`crate::data::Dataset`], and (later) a remote shard all drive the
//! same stages.
//!
//! Chunked iteration is strictly sequential and row-ordered, so every
//! algorithm built on it (reservoir sampling, chunked KNR queries) is
//! *chunk-size invariant*: the chunk is an operational knob (resident
//! working set, I/O granularity), never a semantic one. That invariance
//! is what lets one engine serve in-memory and out-of-core execution
//! with bit-identical results — see `rust/tests/pipeline_equivalence.rs`.
//!
//! Two walkers deliver chunks:
//!
//! * [`for_each_chunk`] — plain sequential read-then-compute alternation.
//! * [`for_each_chunk_prefetch`] — same chunk sequence and callback
//!   order, but a background reader fills the *next* chunk while the
//!   callback computes on the current one (double buffering), so a pass
//!   over a slow source overlaps I/O with compute. Because the delivered
//!   `(start, chunk)` sequence is identical, swapping walkers never
//!   changes any result.
//!
//! [`crate::pipeline::shard`] extends the same contract across row-range
//! shards: order-free per-row passes (KNR queries) run shard-parallel,
//! order-dependent ones (the reservoir sweeps here) stay row-ordered —
//! either way the *shard count is operational, never semantic*, which is
//! the shard-invariance contract `rust/tests/sharded_equivalence.rs`
//! pins.

use crate::linalg::Mat;
use crate::util::rng::Rng;
use crate::{ensure_arg, Result};

/// A clustering input: `n` rows of dimension `d`, readable in contiguous
/// row chunks. Implementations must be cheap to query for shape and must
/// fill the caller's buffer (reusing its allocation) on `read_rows`.
pub trait DataSource: Sync {
    /// Number of objects (rows).
    fn n(&self) -> usize;

    /// Feature dimension (columns).
    fn d(&self) -> usize;

    /// Fill `buf` with rows `[start, start+len)`. `buf` is resized to
    /// `len × d` and its allocation is reused across calls.
    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()>;

    /// Zero-copy access to the full matrix when the data is resident.
    /// Stages that genuinely need all rows at once (e.g. k-means-full
    /// selection) use this; everything else goes through `read_rows`.
    fn as_mat(&self) -> Option<&Mat> {
        None
    }

    /// What kind of backend this source *knows* it is, if any. A source
    /// that can answer (e.g. a remote source is always a high-latency
    /// network link) lets the shard planner skip the storage probe; `None`
    /// (the default) means "probe me". Operational only — the hint steers
    /// walker count and prefetch depth, never any result.
    fn storage_hint(&self) -> Option<crate::pipeline::StorageProfile> {
        None
    }

    /// Natural row-range boundaries, if the source is a composite of
    /// differently-backed pieces (e.g. [`crate::pipeline::SegmentedSource`]
    /// mixing local and remote rows). The shard planner aligns shard
    /// boundaries to these so no shard straddles two backends. `None` (the
    /// default) means one uniform backing. Ranges must be contiguous from
    /// 0 and cover `n`.
    fn segments(&self) -> Option<Vec<(usize, usize)>> {
        None
    }
}

impl DataSource for Mat {
    fn n(&self) -> usize {
        self.rows
    }

    fn d(&self) -> usize {
        self.cols
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(start + len <= self.rows, "read_rows: out of range");
        buf.rows = len;
        buf.cols = self.cols;
        buf.data.clear();
        buf.data.extend_from_slice(&self.data[start * self.cols..(start + len) * self.cols]);
        Ok(())
    }

    fn as_mat(&self) -> Option<&Mat> {
        Some(self)
    }
}

impl DataSource for crate::data::Dataset {
    fn n(&self) -> usize {
        self.x.rows
    }

    fn d(&self) -> usize {
        self.x.cols
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        self.x.read_rows(start, len, buf)
    }

    fn as_mat(&self) -> Option<&Mat> {
        Some(&self.x)
    }
}

/// Sequentially visit `src` in chunks of at most `chunk` rows, reusing a
/// single `chunk × d` buffer for the whole sweep. A resident source
/// ([`DataSource::as_mat`]) is delivered zero-copy as one full chunk:
/// every algorithm the engine builds on this iterator is row-ordered and
/// chunk-size invariant, so the fast path changes no result — only the
/// N×d memcpy an in-memory pass would otherwise pay.
///
/// `chunk == 0` is rejected with an error (it used to be silently
/// clamped, which hid misconfigured callers).
pub fn for_each_chunk(
    src: &dyn DataSource,
    chunk: usize,
    mut f: impl FnMut(usize, &Mat) -> Result<()>,
) -> Result<()> {
    ensure_arg!(chunk >= 1, "for_each_chunk: chunk must be >= 1 (got 0)");
    if let Some(m) = src.as_mat() {
        if m.rows == 0 {
            return Ok(());
        }
        return f(0, m);
    }
    let n = src.n();
    let mut buf = Mat::zeros(0, src.d());
    let mut start = 0;
    while start < n {
        let len = chunk.min(n - start);
        src.read_rows(start, len, &mut buf)?;
        // Enforce the DataSource contract at the boundary: consumers
        // (including unsafe global-slot writers) size work by buf.rows.
        ensure_arg!(
            buf.rows == len,
            "read_rows returned {} rows, requested {len}",
            buf.rows
        );
        f(start, &buf)?;
        start += len;
    }
    Ok(())
}

/// [`for_each_chunk`] with **double-buffered prefetch**: a scoped reader
/// thread fills chunk `i + 1` while the caller's `f` computes on chunk
/// `i`, so a pass over a slow source (disk, network) overlaps I/O with
/// compute instead of alternating. Equivalent to
/// [`for_each_chunk_prefetch_depth`] at depth 1.
pub fn for_each_chunk_prefetch(
    src: &dyn DataSource,
    chunk: usize,
    f: impl FnMut(usize, &Mat) -> Result<()>,
) -> Result<()> {
    for_each_chunk_prefetch_depth(src, chunk, 1, f)
}

/// [`for_each_chunk`] with a reader thread keeping up to `depth` chunks
/// in flight ahead of the consumer (`depth + 1` buffers cycle free →
/// reader fills → full → consumer computes → free; depth 1 is classic
/// double buffering). Deeper queues keep a serialized device streaming
/// when the consumer's compute bursts are uneven — the adaptive shard
/// planner picks the depth from the storage profile. The callback still
/// runs on the calling thread, in strict row order, over exactly the
/// chunk sequence [`for_each_chunk`] would deliver — results are
/// bit-identical for every depth, by construction.
///
/// Resident sources take the same zero-copy single-chunk fast path (there
/// is no I/O to hide). Errors surface in callback order: an `f` error on
/// chunk `i` wins over a read error on any later chunk.
pub fn for_each_chunk_prefetch_depth(
    src: &dyn DataSource,
    chunk: usize,
    depth: usize,
    mut f: impl FnMut(usize, &Mat) -> Result<()>,
) -> Result<()> {
    ensure_arg!(chunk >= 1, "for_each_chunk: chunk must be >= 1 (got 0)");
    ensure_arg!(depth >= 1, "for_each_chunk_prefetch: depth must be >= 1 (got 0)");
    let n = src.n();
    if src.as_mat().is_some() || n <= chunk {
        // Nothing to overlap: zero-copy fast path or a single chunk.
        return for_each_chunk(src, chunk, f);
    }
    // Buffers cycle: free → reader fills → full → consumer computes → free.
    let (free_tx, free_rx) = std::sync::mpsc::channel::<Mat>();
    let (full_tx, full_rx) = std::sync::mpsc::sync_channel::<(usize, Mat)>(depth + 1);
    for _ in 0..=depth {
        free_tx.send(Mat::zeros(0, src.d())).expect("free channel open");
    }
    let mut result: Result<()> = Ok(());
    std::thread::scope(|s| {
        let reader = s.spawn(move || -> Result<()> {
            let mut start = 0;
            while start < n {
                // A closed channel means the consumer bailed; just stop.
                let Ok(mut buf) = free_rx.recv() else { return Ok(()) };
                let len = chunk.min(n - start);
                src.read_rows(start, len, &mut buf)?;
                // Same DataSource-contract check as the sequential walker.
                ensure_arg!(
                    buf.rows == len,
                    "read_rows returned {} rows, requested {len}",
                    buf.rows
                );
                if full_tx.send((start, buf)).is_err() {
                    return Ok(());
                }
                start += len;
            }
            Ok(())
        });
        let mut consumed = 0;
        while consumed < n {
            // A closed channel means the reader stopped early on an error
            // (picked up from the join below).
            let Ok((start, buf)) = full_rx.recv() else { break };
            consumed += buf.rows;
            if let Err(e) = f(start, &buf) {
                result = Err(e);
                break;
            }
            let _ = free_tx.send(buf);
        }
        // Close both channels so a still-running reader exits, then join.
        drop(free_tx);
        drop(full_rx);
        match reader.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                if result.is_ok() {
                    result = Err(e);
                }
            }
            Err(p) => std::panic::resume_unwind(p),
        }
    });
    result
}

/// Multi-target single-pass reservoir sample (Vitter's Algorithm R): one
/// sequential sweep over `src` fills one independent reservoir per spec,
/// each driven by its own RNG. Per target, the draw stream is exactly
/// what an independent single-target sweep would consume, so sharing the
/// pass never changes any sample — this is how an ensemble amortizes its
/// m candidate sweeps into one read of the data.
///
/// The reservoir update is order-dependent (each draw conditions on the
/// number of rows seen so far), so the sweep is row-ordered and cannot
/// run shard-parallel — but its I/O can hide: the walk goes through
/// [`for_each_chunk_prefetch`], merging ranges in order while the next
/// chunk streams in. Each `(size, rng)` spec is advanced in place; sizes
/// are clamped to `src.n()`.
pub fn reservoir_multi(
    src: &dyn DataSource,
    chunk: usize,
    specs: &mut [(usize, Rng)],
) -> Result<Vec<Mat>> {
    let n = src.n();
    let d = src.d();
    let sizes: Vec<usize> = specs.iter().map(|(s, _)| (*s).min(n)).collect();
    ensure_arg!(sizes.iter().all(|&s| s >= 1), "reservoir: empty sample");
    let mut outs: Vec<Mat> = sizes.iter().map(|&s| Mat::zeros(s, d)).collect();
    let mut seen = 0usize;
    for_each_chunk_prefetch(src, chunk, |_, m| {
        for i in 0..m.rows {
            let row = m.row(i);
            for (t, (_, rng)) in specs.iter_mut().enumerate() {
                let size = sizes[t];
                if seen < size {
                    outs[t].row_mut(seen).copy_from_slice(row);
                } else {
                    let j = rng.usize(seen + 1);
                    if j < size {
                        outs[t].row_mut(j).copy_from_slice(row);
                    }
                }
            }
            seen += 1;
        }
        Ok(())
    })?;
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;
    use crate::pipeline::testutil::NonResident;

    #[test]
    fn chunks_cover_all_rows() {
        let ds = two_moons(257, 0.05, 1);
        let src = NonResident(&ds.x);
        let mut rows = 0usize;
        let mut calls = 0usize;
        for_each_chunk(&src, 100, |start, m| {
            for i in 0..m.rows {
                assert_eq!(m.row(i), ds.x.row(start + i));
            }
            rows += m.rows;
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(rows, 257);
        assert_eq!(calls, 3); // 100 + 100 + 57

        // a resident Mat is delivered zero-copy as one full chunk
        let mut calls = 0usize;
        for_each_chunk(&ds.x, 100, |start, m| {
            assert_eq!(start, 0);
            assert_eq!(m.rows, 257);
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 1);
        assert_eq!(ds.x.as_mat().unwrap().rows, 257);
    }

    #[test]
    fn chunk_zero_is_an_error_not_a_panic() {
        let ds = two_moons(64, 0.05, 17);
        let src = NonResident(&ds.x);
        assert!(for_each_chunk(&src, 0, |_, _| Ok(())).is_err());
        assert!(for_each_chunk_prefetch(&src, 0, |_, _| Ok(())).is_err());
        // resident sources validate too — the knob is wrong either way
        assert!(for_each_chunk(&ds.x, 0, |_, _| Ok(())).is_err());
        let mut specs = vec![(10usize, Rng::new(1))];
        assert!(reservoir_multi(&src, 0, &mut specs).is_err());
    }

    #[test]
    fn prefetch_delivers_the_sequential_chunk_stream() {
        let ds = two_moons(257, 0.05, 18);
        let src = NonResident(&ds.x);
        let mut seq: Vec<(usize, usize)> = Vec::new();
        for_each_chunk(&src, 100, |start, m| {
            seq.push((start, m.rows));
            Ok(())
        })
        .unwrap();
        let mut pre: Vec<(usize, usize)> = Vec::new();
        for_each_chunk_prefetch(&src, 100, |start, m| {
            for i in 0..m.rows {
                assert_eq!(m.row(i), ds.x.row(start + i));
            }
            pre.push((start, m.rows));
            Ok(())
        })
        .unwrap();
        assert_eq!(seq, pre);
        // every prefetch depth delivers the same stream
        for depth in [1usize, 2, 4, 9] {
            let mut deep: Vec<(usize, usize)> = Vec::new();
            for_each_chunk_prefetch_depth(&src, 100, depth, |start, m| {
                for i in 0..m.rows {
                    assert_eq!(m.row(i), ds.x.row(start + i));
                }
                deep.push((start, m.rows));
                Ok(())
            })
            .unwrap();
            assert_eq!(seq, deep, "depth={depth}");
        }
        // depth 0 is a config error, like chunk 0
        assert!(for_each_chunk_prefetch_depth(&src, 100, 0, |_, _| Ok(())).is_err());
        // resident fast path: one zero-copy chunk, like for_each_chunk
        let mut calls = 0;
        for_each_chunk_prefetch(&ds.x, 100, |start, m| {
            assert_eq!((start, m.rows), (0, 257));
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 1);
    }

    /// A source whose reads fail past a row threshold, for error-path
    /// coverage of the prefetching walker.
    struct FailingSource {
        rows: usize,
        fail_from: usize,
    }

    impl DataSource for FailingSource {
        fn n(&self) -> usize {
            self.rows
        }

        fn d(&self) -> usize {
            1
        }

        fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
            crate::ensure_arg!(start < self.fail_from, "injected read failure");
            buf.rows = len;
            buf.cols = 1;
            buf.data.clear();
            buf.data.extend((start..start + len).map(|i| i as f32));
            Ok(())
        }
    }

    #[test]
    fn prefetch_surfaces_read_and_callback_errors() {
        let src = FailingSource { rows: 1000, fail_from: 500 };
        let mut delivered = 0usize;
        let err = for_each_chunk_prefetch(&src, 100, |_, m| {
            delivered += m.rows;
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("injected read failure"), "{err}");
        assert_eq!(delivered, 500, "all chunks before the failure delivered");

        // a callback error wins over any later read error and stops the walk
        let src = FailingSource { rows: 1000, fail_from: 1000 };
        let err = for_each_chunk_prefetch(&src, 100, |start, _| {
            crate::ensure_arg!(start < 300, "callback bailed");
            Ok(())
        })
        .unwrap_err();
        assert!(err.to_string().contains("callback bailed"), "{err}");
    }

    #[test]
    fn dataset_source_delegates() {
        let ds = two_moons(64, 0.05, 2);
        assert_eq!(DataSource::n(&ds), 64);
        assert_eq!(DataSource::d(&ds), 2);
        let mut buf = Mat::zeros(0, 2);
        ds.read_rows(10, 5, &mut buf).unwrap();
        assert_eq!(buf.row(0), ds.x.row(10));
    }

    #[test]
    fn shared_sweep_matches_independent_sweeps() {
        let ds = two_moons(500, 0.05, 3);
        let src = NonResident(&ds.x);
        let mut shared = vec![(40usize, Rng::new(7)), (25usize, Rng::new(8))];
        let outs = reservoir_multi(&src, 128, &mut shared).unwrap();
        for (i, &(size, seed)) in [(40usize, 7u64), (25, 8)].iter().enumerate() {
            let mut solo = vec![(size, Rng::new(seed))];
            let alone = reservoir_multi(&src, 128, &mut solo).unwrap();
            assert_eq!(outs[i].data, alone[0].data, "target {i} diverged");
        }
    }

    #[test]
    fn reservoir_chunk_size_and_residency_invariant() {
        let ds = two_moons(300, 0.05, 4);
        let src = NonResident(&ds.x);
        let sample = |src: &dyn DataSource, chunk: usize| {
            let mut specs = vec![(50usize, Rng::new(11))];
            reservoir_multi(src, chunk, &mut specs).unwrap().pop().unwrap()
        };
        let a = sample(&src, 17);
        let b = sample(&src, 300);
        let c = sample(&ds.x, 17); // resident fast path
        assert_eq!(a.data, b.data);
        assert_eq!(a.data, c.data);
    }
}
