//! The data-access layer of the staged pipeline: a [`DataSource`] is
//! anything that can report its shape and hand out contiguous row chunks
//! into a caller-provided buffer. The engine never assumes the data is
//! resident — an in-memory [`Mat`], an on-disk
//! [`crate::streaming::BinDataset`], a loader-produced
//! [`crate::data::Dataset`], and (later) a remote shard all drive the
//! same stages.
//!
//! Chunked iteration is strictly sequential and row-ordered, so every
//! algorithm built on it (reservoir sampling, chunked KNR queries) is
//! *chunk-size invariant*: the chunk is an operational knob (resident
//! working set, I/O granularity), never a semantic one. That invariance
//! is what lets one engine serve in-memory and out-of-core execution
//! with bit-identical results — see `rust/tests/pipeline_equivalence.rs`.

use crate::linalg::Mat;
use crate::util::rng::Rng;
use crate::{ensure_arg, Result};

/// A clustering input: `n` rows of dimension `d`, readable in contiguous
/// row chunks. Implementations must be cheap to query for shape and must
/// fill the caller's buffer (reusing its allocation) on `read_rows`.
pub trait DataSource: Sync {
    /// Number of objects (rows).
    fn n(&self) -> usize;

    /// Feature dimension (columns).
    fn d(&self) -> usize;

    /// Fill `buf` with rows `[start, start+len)`. `buf` is resized to
    /// `len × d` and its allocation is reused across calls.
    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()>;

    /// Zero-copy access to the full matrix when the data is resident.
    /// Stages that genuinely need all rows at once (e.g. k-means-full
    /// selection) use this; everything else goes through `read_rows`.
    fn as_mat(&self) -> Option<&Mat> {
        None
    }
}

impl DataSource for Mat {
    fn n(&self) -> usize {
        self.rows
    }

    fn d(&self) -> usize {
        self.cols
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(start + len <= self.rows, "read_rows: out of range");
        buf.rows = len;
        buf.cols = self.cols;
        buf.data.clear();
        buf.data.extend_from_slice(&self.data[start * self.cols..(start + len) * self.cols]);
        Ok(())
    }

    fn as_mat(&self) -> Option<&Mat> {
        Some(self)
    }
}

impl DataSource for crate::data::Dataset {
    fn n(&self) -> usize {
        self.x.rows
    }

    fn d(&self) -> usize {
        self.x.cols
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        self.x.read_rows(start, len, buf)
    }

    fn as_mat(&self) -> Option<&Mat> {
        Some(&self.x)
    }
}

/// Sequentially visit `src` in chunks of at most `chunk` rows, reusing a
/// single `chunk × d` buffer for the whole sweep. A resident source
/// ([`DataSource::as_mat`]) is delivered zero-copy as one full chunk:
/// every algorithm the engine builds on this iterator is row-ordered and
/// chunk-size invariant, so the fast path changes no result — only the
/// N×d memcpy an in-memory pass would otherwise pay.
pub fn for_each_chunk(
    src: &dyn DataSource,
    chunk: usize,
    mut f: impl FnMut(usize, &Mat) -> Result<()>,
) -> Result<()> {
    if let Some(m) = src.as_mat() {
        if m.rows == 0 {
            return Ok(());
        }
        return f(0, m);
    }
    let chunk = chunk.max(1);
    let n = src.n();
    let mut buf = Mat::zeros(0, src.d());
    let mut start = 0;
    while start < n {
        let len = chunk.min(n - start);
        src.read_rows(start, len, &mut buf)?;
        f(start, &buf)?;
        start += len;
    }
    Ok(())
}

/// Multi-target single-pass reservoir sample (Vitter's Algorithm R): one
/// sequential sweep over `src` fills one independent reservoir per spec,
/// each driven by its own RNG. Per target, the draw stream is exactly
/// what an independent single-target sweep would consume, so sharing the
/// pass never changes any sample — this is how an ensemble amortizes its
/// m candidate sweeps into one read of the data.
///
/// Each `(size, rng)` spec is advanced in place; sizes are clamped to
/// `src.n()`.
pub fn reservoir_multi(
    src: &dyn DataSource,
    chunk: usize,
    specs: &mut [(usize, Rng)],
) -> Result<Vec<Mat>> {
    let n = src.n();
    let d = src.d();
    let sizes: Vec<usize> = specs.iter().map(|(s, _)| (*s).min(n)).collect();
    ensure_arg!(sizes.iter().all(|&s| s >= 1), "reservoir: empty sample");
    let mut outs: Vec<Mat> = sizes.iter().map(|&s| Mat::zeros(s, d)).collect();
    let mut seen = 0usize;
    for_each_chunk(src, chunk, |_, m| {
        for i in 0..m.rows {
            let row = m.row(i);
            for (t, (_, rng)) in specs.iter_mut().enumerate() {
                let size = sizes[t];
                if seen < size {
                    outs[t].row_mut(seen).copy_from_slice(row);
                } else {
                    let j = rng.usize(seen + 1);
                    if j < size {
                        outs[t].row_mut(j).copy_from_slice(row);
                    }
                }
            }
            seen += 1;
        }
        Ok(())
    })?;
    Ok(outs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synthetic::two_moons;

    /// A `Mat` stripped of its resident fast path, so tests exercise the
    /// chunked `read_rows` iteration.
    struct NonResident<'a>(&'a Mat);

    impl DataSource for NonResident<'_> {
        fn n(&self) -> usize {
            self.0.rows
        }

        fn d(&self) -> usize {
            self.0.cols
        }

        fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
            self.0.read_rows(start, len, buf)
        }
    }

    #[test]
    fn chunks_cover_all_rows() {
        let ds = two_moons(257, 0.05, 1);
        let src = NonResident(&ds.x);
        let mut rows = 0usize;
        let mut calls = 0usize;
        for_each_chunk(&src, 100, |start, m| {
            for i in 0..m.rows {
                assert_eq!(m.row(i), ds.x.row(start + i));
            }
            rows += m.rows;
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(rows, 257);
        assert_eq!(calls, 3); // 100 + 100 + 57

        // a resident Mat is delivered zero-copy as one full chunk
        let mut calls = 0usize;
        for_each_chunk(&ds.x, 100, |start, m| {
            assert_eq!(start, 0);
            assert_eq!(m.rows, 257);
            calls += 1;
            Ok(())
        })
        .unwrap();
        assert_eq!(calls, 1);
        assert_eq!(ds.x.as_mat().unwrap().rows, 257);
    }

    #[test]
    fn dataset_source_delegates() {
        let ds = two_moons(64, 0.05, 2);
        assert_eq!(DataSource::n(&ds), 64);
        assert_eq!(DataSource::d(&ds), 2);
        let mut buf = Mat::zeros(0, 2);
        ds.read_rows(10, 5, &mut buf).unwrap();
        assert_eq!(buf.row(0), ds.x.row(10));
    }

    #[test]
    fn shared_sweep_matches_independent_sweeps() {
        let ds = two_moons(500, 0.05, 3);
        let src = NonResident(&ds.x);
        let mut shared = vec![(40usize, Rng::new(7)), (25usize, Rng::new(8))];
        let outs = reservoir_multi(&src, 128, &mut shared).unwrap();
        for (i, &(size, seed)) in [(40usize, 7u64), (25, 8)].iter().enumerate() {
            let mut solo = vec![(size, Rng::new(seed))];
            let alone = reservoir_multi(&src, 128, &mut solo).unwrap();
            assert_eq!(outs[i].data, alone[0].data, "target {i} diverged");
        }
    }

    #[test]
    fn reservoir_chunk_size_and_residency_invariant() {
        let ds = two_moons(300, 0.05, 4);
        let src = NonResident(&ds.x);
        let sample = |src: &dyn DataSource, chunk: usize| {
            let mut specs = vec![(50usize, Rng::new(11))];
            reservoir_multi(src, chunk, &mut specs).unwrap().pop().unwrap()
        };
        let a = sample(&src, 17);
        let b = sample(&src, 300);
        let c = sample(&ds.x, 17); // resident fast path
        assert_eq!(a.data, b.data);
        assert_eq!(a.data, c.data);
    }
}
