//! A composite [`DataSource`]: contiguous row segments, each backed by
//! its own source — the shape a mixed local + remote deployment has (some
//! rows on this machine's disk, some served by
//! [`crate::net::RemoteSource`] endpoints).
//!
//! A [`SegmentedSource`] presents the concatenation as one `n × d`
//! source. Reads that stay inside one segment forward directly (the
//! common case once shard boundaries are aligned); reads that straddle a
//! boundary are stitched from per-segment reads, so the contract is the
//! same either way: the exact bytes the backing sources hold, in global
//! row order. The composite reports its boundaries through
//! [`DataSource::segments`] — [`ShardPlan::aligned`]
//! (via [`crate::pipeline::Pipeline`]) aligns shard cuts to them so no
//! walker serves one shard from two backends — and its
//! [`DataSource::storage_hint`] is the *slowest* segment's hint, because
//! the walk planner must assume the pass is paced by its slowest backend.
//!
//! [`ShardPlan::aligned`]: crate::pipeline::ShardPlan::aligned

use crate::linalg::Mat;
use crate::pipeline::{DataSource, StorageProfile};
use crate::{ensure_arg, Result};

struct Segment {
    src: Box<dyn DataSource + Send + Sync>,
    /// First row of `src` this segment exposes.
    start: usize,
    /// Rows exposed.
    len: usize,
    /// Global row of the segment's first exposed row.
    global: usize,
}

/// Contiguous row segments over heterogeneous backing sources, presented
/// as one [`DataSource`]. Build with [`SegmentedSource::push`]; segments
/// concatenate in push order.
#[derive(Default)]
pub struct SegmentedSource {
    segs: Vec<Segment>,
    d: usize,
    n: usize,
}

impl SegmentedSource {
    /// An empty composite (0 × 0 until the first push).
    pub fn new() -> SegmentedSource {
        SegmentedSource::default()
    }

    /// Append rows `[start, start + len)` of `src` as the next global
    /// segment. All segments must agree on `d`; `len == 0` or a range
    /// outside `src` is rejected.
    pub fn push(
        &mut self,
        src: impl DataSource + Send + Sync + 'static,
        start: usize,
        len: usize,
    ) -> Result<()> {
        ensure_arg!(len >= 1, "segmented source: empty segment");
        ensure_arg!(
            start + len <= src.n(),
            "segmented source: rows [{start}, {}) out of range (source n={})",
            start + len,
            src.n()
        );
        if self.segs.is_empty() {
            self.d = src.d();
        } else {
            ensure_arg!(
                src.d() == self.d,
                "segmented source: segment d={} but composite d={}",
                src.d(),
                self.d
            );
        }
        let global = self.n;
        self.segs.push(Segment { src: Box::new(src), start, len, global });
        self.n += len;
        Ok(())
    }

    /// Index of the segment containing global row `row`.
    fn locate(&self, row: usize) -> usize {
        debug_assert!(row < self.n);
        self.segs.partition_point(|s| s.global + s.len <= row)
    }
}

impl DataSource for SegmentedSource {
    fn n(&self) -> usize {
        self.n
    }

    fn d(&self) -> usize {
        self.d
    }

    fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
        ensure_arg!(len >= 1, "read_rows: len must be >= 1");
        ensure_arg!(start + len <= self.n, "read_rows: out of range");
        let first = self.locate(start);
        let seg = &self.segs[first];
        if start + len <= seg.global + seg.len {
            // Entirely inside one segment: forward, preserving the
            // caller's buffer-reuse contract.
            return seg.src.read_rows(seg.start + (start - seg.global), len, buf);
        }
        // Straddles a boundary: stitch per-segment reads in row order.
        buf.rows = len;
        buf.cols = self.d;
        buf.data.clear();
        let mut tmp = Mat::zeros(0, self.d);
        let mut row = start;
        let end = start + len;
        let mut i = first;
        while row < end {
            let seg = &self.segs[i];
            let local = row - seg.global;
            let take = (seg.len - local).min(end - row);
            seg.src.read_rows(seg.start + local, take, &mut tmp)?;
            ensure_arg!(
                tmp.rows == take,
                "segment read returned {} rows, requested {take}",
                tmp.rows
            );
            buf.data.extend_from_slice(&tmp.data);
            row += take;
            i += 1;
        }
        Ok(())
    }

    /// The global `(start, len)` boundaries, for shard alignment.
    fn segments(&self) -> Option<Vec<(usize, usize)>> {
        if self.segs.is_empty() {
            return None;
        }
        Some(self.segs.iter().map(|s| (s.global, s.len)).collect())
    }

    /// The slowest segment's hint: the walk planner must pace the pass by
    /// its slowest backend (Remote ≻ Serial ≻ Parallel). `None` when no
    /// segment knows its backing.
    fn storage_hint(&self) -> Option<StorageProfile> {
        fn rank(p: StorageProfile) -> u8 {
            match p {
                StorageProfile::Remote => 2,
                StorageProfile::Serial => 1,
                StorageProfile::Auto | StorageProfile::Parallel => 0,
            }
        }
        self.segs
            .iter()
            .filter_map(|s| s.src.storage_hint())
            .max_by_key(|&p| rank(p))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn numbered(n: usize, d: usize, base: f32) -> Mat {
        let mut m = Mat::zeros(n, d);
        for i in 0..n {
            for j in 0..d {
                m.set(i, j, base + (i * d + j) as f32);
            }
        }
        m
    }

    /// A Mat wrapper with a fixed storage hint and no resident fast path.
    struct Hinted(Mat, StorageProfile);

    impl DataSource for Hinted {
        fn n(&self) -> usize {
            self.0.rows
        }

        fn d(&self) -> usize {
            self.0.cols
        }

        fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
            self.0.read_rows(start, len, buf)
        }

        fn storage_hint(&self) -> Option<StorageProfile> {
            Some(self.1)
        }
    }

    #[test]
    fn construction_validates_shapes_and_ranges() {
        let mut s = SegmentedSource::new();
        assert!(s.push(numbered(10, 2, 0.0), 0, 0).is_err()); // empty
        assert!(s.push(numbered(10, 2, 0.0), 5, 6).is_err()); // past end
        s.push(numbered(10, 2, 0.0), 0, 10).unwrap();
        assert!(s.push(numbered(10, 3, 0.0), 0, 10).is_err()); // d mismatch
        s.push(numbered(8, 2, 100.0), 2, 6).unwrap(); // sub-range is fine
        assert_eq!((s.n(), s.d()), (16, 2));
        assert_eq!(s.segments(), Some(vec![(0, 10), (10, 6)]));
    }

    #[test]
    fn reads_match_the_concatenation_across_boundaries() {
        // expected concatenation: rows 0..10 of a, rows 2..8 of b
        let a = numbered(10, 2, 0.0);
        let b = numbered(8, 2, 100.0);
        let mut want = Mat::zeros(0, 2);
        want.data.extend_from_slice(&a.data);
        want.data.extend_from_slice(&b.data[2 * 2..8 * 2]);
        want.rows = 16;

        let mut s = SegmentedSource::new();
        s.push(a, 0, 10).unwrap();
        s.push(b, 2, 6).unwrap();
        let mut got = Mat::zeros(0, 2);
        // inside the first, inside the second, straddling, and full reads
        for (start, len) in [(0usize, 10usize), (10, 6), (8, 5), (0, 16), (9, 2)] {
            s.read_rows(start, len, &mut got).unwrap();
            assert_eq!((got.rows, got.cols), (len, 2));
            assert_eq!(
                got.data,
                &want.data[start * 2..(start + len) * 2],
                "[{start}, {})",
                start + len
            );
        }
        // out-of-range and empty reads are rejected
        assert!(s.read_rows(10, 7, &mut got).is_err());
        assert!(s.read_rows(0, 0, &mut got).is_err());
    }

    #[test]
    fn hint_escalates_to_the_slowest_segment() {
        let mk = |h| Hinted(numbered(4, 1, 0.0), h);
        let mut s = SegmentedSource::new();
        s.push(mk(StorageProfile::Parallel), 0, 4).unwrap();
        assert_eq!(s.storage_hint(), Some(StorageProfile::Parallel));
        s.push(mk(StorageProfile::Serial), 0, 4).unwrap();
        assert_eq!(s.storage_hint(), Some(StorageProfile::Serial));
        s.push(mk(StorageProfile::Remote), 0, 4).unwrap();
        assert_eq!(s.storage_hint(), Some(StorageProfile::Remote));
        // hint-less segments don't mask a known slow one
        let mut s = SegmentedSource::new();
        s.push(numbered(4, 1, 0.0), 0, 4).unwrap();
        assert_eq!(s.storage_hint(), None);
        s.push(mk(StorageProfile::Remote), 0, 4).unwrap();
        assert_eq!(s.storage_hint(), Some(StorageProfile::Remote));
    }
}
