//! The staged U-SPEC execution engine — **one core for in-memory,
//! out-of-core, and ensemble runs**.
//!
//! The paper's pipeline (§3.1) decomposes into four stages, each of which
//! only needs chunked row access to the data ([`DataSource`]):
//!
//! 1. [`SelectStage`] — representative selection. Random and hybrid
//!    selection run as a single-pass reservoir sweep (plus k-means
//!    refinement of the candidates for hybrid); k-means-full needs the
//!    resident matrix ([`DataSource::as_mat`]).
//! 2. [`KnrStage`] — K-nearest-representative search: build the
//!    [`KnrIndex`] over the p representatives once, then stream the
//!    objects chunk-by-chunk through the packed-panel query path.
//! 3. [`AffinityStage`] — the sparse Gaussian cross-affinity `B` from the
//!    KNR result (σ = mean object↔KNR distance).
//! 4. [`PartitionStage`] — transfer-cut bipartite partitioning plus the
//!    NJW k-means discretization of the row-normalized embedding.
//!
//! [`Pipeline::run`] drives the stages with one seed schedule, so the
//! *same* code produces the labels whether the source is a resident
//! [`Mat`], an on-disk [`crate::streaming::BinDataset`], or any future
//! shard. Every stage is chunk-size invariant (chunked iteration is
//! sequential and per-row; distance rows are computed independently), so
//! for a fixed seed the labels are bit-identical across sources and chunk
//! sizes — `rust/tests/pipeline_equivalence.rs` pins this.
//!
//! For ensembles, [`Pipeline::sweep_candidates`] runs the selection
//! sweeps of all m base clusterers in **one** pass over the data
//! ([`reservoir_multi`]) and [`Pipeline::run_from_candidates`] resumes a
//! per-clusterer run from its pre-swept candidate set — m base clusterers
//! cost one selection read of the data instead of m.
//!
//! Execution knobs ([`ExecOpts`]) are *operational, never semantic*: the
//! chunk size bounds the resident working set, and the shard count
//! ([`ShardPlan`]) decides how many row ranges walk the source
//! concurrently — KNR passes run shard-parallel with double-buffered
//! prefetch per shard, selection sweeps stay row-ordered but prefetch
//! their next chunk while merging the current one. Labels are
//! bit-identical for any `{source, chunk, shards, threads}` combination
//! (`rust/tests/pipeline_equivalence.rs`,
//! `rust/tests/sharded_equivalence.rs`).
//!
//! Resident peak of a full out-of-core run is
//! `O(N·K + shards·chunk·d + p·d)` — independent of `N·d`, which only
//! ever streams through the chunk buffers.

pub mod segment;
pub mod shard;
pub mod source;

pub use segment::SegmentedSource;
pub use shard::{
    for_each_chunk_sharded, plan_walk, ShardPlan, ShardView, StorageProfile, WalkPlan,
};
pub use source::{
    for_each_chunk, for_each_chunk_prefetch, for_each_chunk_prefetch_depth, reservoir_multi,
    DataSource,
};

use crate::affinity::{
    build_affinity, knr::exact_knr, knr::KnrIndex, knr::KnrResult, select, Affinity,
    DistanceBackend, SelectStrategy,
};
use crate::bipartite::{row_normalize, row_normalize_norms, row_scale, transfer_cut, EigSolver};
use crate::kmeans::{kmeans, Init, KmeansParams};
use crate::linalg::{Csr, Mat};
use crate::runtime::model::{UsencModel, UspecModel};
use crate::uspec::{KnrMode, UspecParams, UspecResult};
use crate::util::json::Json;
use crate::util::par;
use crate::util::rng::Rng;
use crate::util::timer::PhaseTimer;
use crate::{ensure_arg, Error, Result};

/// Default rows per chunk (the resident working set is `chunk × d` f32s).
pub const DEFAULT_CHUNK: usize = 8192;

/// Execution knobs shared by every pass over a source: rows per chunk,
/// how many row-range shards walk the source concurrently, and the
/// storage profile the adaptive walk planner assumes. All are
/// operational — none ever changes a label. `chunk == 0` or
/// `shards == 0` is rejected when a run validates; a shard count above
/// the source size is clamped by [`ShardPlan::new`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecOpts {
    /// Rows per chunk for every sweep (selection and KNR queries).
    pub chunk: usize,
    /// Row-range shards walked concurrently per pass (1 = sequential
    /// walk with prefetch).
    pub shards: usize,
    /// Storage hint for the sharded walk planner: walker count and
    /// prefetch depth follow the profile ([`StorageProfile::Auto`]
    /// probes the source on first sharded walk; see `pipeline::shard`).
    pub storage: StorageProfile,
    /// Decoded-chunk LRU budget in bytes for remote sources (0 — the
    /// default — disables caching). The pipeline itself never constructs
    /// sources, so this is a *wiring* knob: the CLI passes it into
    /// [`crate::net::NetOpts::cache_bytes`] when it connects a
    /// `remote://` source, and the streaming peak model charges it.
    /// Purely operational — repeat sweeps (U-SENC's `1 + m` passes) hit
    /// memory instead of the wire, bit-identically.
    pub net_cache: usize,
}

impl Default for ExecOpts {
    fn default() -> Self {
        ExecOpts { chunk: DEFAULT_CHUNK, shards: 1, storage: StorageProfile::Auto, net_cache: 0 }
    }
}

impl ExecOpts {
    /// Opts with a custom chunk size and no sharding.
    pub fn with_chunk(chunk: usize) -> ExecOpts {
        ExecOpts { chunk, ..ExecOpts::default() }
    }
}

/// Stage 1 — representative selection over chunks (paper §3.1.1).
#[derive(Debug, Clone, Copy)]
pub struct SelectStage {
    pub strategy: SelectStrategy,
    /// Number of representatives p (already clamped to the source size).
    pub p: usize,
    /// k-means refinement cap (the paper's small `t`).
    pub kmeans_iters: usize,
}

impl SelectStage {
    /// Derive the stage from (clamped) U-SPEC parameters. Selection only
    /// needs a coarse vector quantization, so its k-means budget is capped
    /// independently of the discretization budget.
    pub fn from_params(params: &UspecParams) -> SelectStage {
        SelectStage {
            strategy: params.selection,
            p: params.p,
            kmeans_iters: params.kmeans_iters.min(20),
        }
    }

    /// True when this strategy runs as a chunked reservoir sweep (random /
    /// hybrid); false for k-means-full, which needs the resident matrix.
    pub fn sweeps(&self) -> bool {
        !matches!(self.strategy, SelectStrategy::KmeansFull)
    }

    /// Rows the candidate sweep must retain for a source of `n` objects.
    pub fn candidate_size(&self, n: usize) -> usize {
        match self.strategy {
            SelectStrategy::Random => self.p.min(n),
            SelectStrategy::Hybrid { candidate_factor } => {
                (candidate_factor.max(1) * self.p).min(n)
            }
            SelectStrategy::KmeansFull => 0,
        }
    }

    /// Refine a swept candidate set into the p representatives (`rng` is
    /// the sweep's RNG, advanced past the reservoir draws). Candidate sets
    /// already at p rows pass through unchanged — the random strategy and
    /// the hybrid strategy at `p′ == p`.
    pub fn refine(&self, candidates: &Mat, rng: &mut Rng) -> Result<Mat> {
        if candidates.rows <= self.p {
            return Ok(candidates.clone());
        }
        let km = kmeans(
            candidates,
            &KmeansParams {
                k: self.p,
                max_iter: self.kmeans_iters,
                tol: 1e-3,
                init: Init::Random,
            },
            rng.next_u64(),
        )?;
        Ok(km.centers)
    }

    /// Full selection: sweep (or resident k-means) → p representatives.
    pub fn run(&self, src: &dyn DataSource, chunk: usize, seed: u64) -> Result<Mat> {
        if !self.sweeps() {
            let x = src.as_mat().ok_or_else(|| {
                Error::InvalidArg(
                    "k-means-full selection needs a resident dataset (DataSource::as_mat); \
                     use random or hybrid selection for out-of-core sources"
                        .into(),
                )
            })?;
            return select(x, self.strategy, self.p, self.kmeans_iters, seed);
        }
        let mut specs = vec![(self.candidate_size(src.n()), Rng::new(seed))];
        let mut outs = reservoir_multi(src, chunk, &mut specs)?;
        let candidates = outs.pop().expect("one sweep target");
        let (_, mut rng) = specs.pop().expect("one sweep target");
        self.refine(&candidates, &mut rng)
    }
}

/// Stage 2 — chunked K-nearest-representative queries (paper §3.1.2).
#[derive(Debug, Clone, Copy)]
pub struct KnrStage {
    pub k_nn: usize,
    pub mode: KnrMode,
}

impl KnrStage {
    /// Stream all rows of `src` through the index, **shard-parallel**:
    /// every shard of `plan` walks its row range with double-buffered
    /// prefetch, and each chunk's answers land in their global row slots
    /// of the flattened n×K result. Rows are queried independently, so
    /// the assembled result is byte-identical for any chunk size and any
    /// shard count (including the sequential `shards == 1` walk).
    pub fn query(
        &self,
        src: &dyn DataSource,
        index: &KnrIndex,
        plan: &ShardPlan,
        chunk: usize,
        backend: &dyn DistanceBackend,
    ) -> Result<KnrResult> {
        let k = self.k_nn.min(index.p());
        let n = src.n();
        let mut idx = vec![0u32; n * k];
        let mut d2 = vec![0.0f32; n * k];
        let idx_ptr = par::SendPtr(idx.as_mut_ptr());
        let d2_ptr = par::SendPtr(d2.as_mut_ptr());
        for_each_chunk_sharded(src, plan, chunk, |start, m| {
            let r = match self.mode {
                KnrMode::Approx => index.approx_knr(m, k, backend),
                KnrMode::Exact => index.exact_knr(m, k, backend),
            };
            // Hard checks (not debug-only): the raw slot writes below rely
            // on the chunk staying inside [0, n) — the walkers enforce the
            // read_rows contract, this is the last line of defense — and
            // on the KNR result being exactly m.rows × k.
            assert!(start + m.rows <= n, "chunk [{start}, {}) > n={n}", start + m.rows);
            assert_eq!(r.idx.len(), m.rows * k, "knr result shape");
            assert_eq!(r.d2.len(), m.rows * k, "knr result shape");
            // SAFETY: shards are disjoint row ranges and chunks within a
            // shard are disjoint too, so rows [start, start + m.rows) are
            // written exactly once; both vecs outlive the blocking walk.
            unsafe {
                let islots = idx_ptr.0.add(start * k);
                std::ptr::copy_nonoverlapping(r.idx.as_ptr(), islots, r.idx.len());
                let dslots = d2_ptr.0.add(start * k);
                std::ptr::copy_nonoverlapping(r.d2.as_ptr(), dslots, r.d2.len());
            }
            Ok(())
        })?;
        Ok(KnrResult { idx, d2, k })
    }
}

/// Stage 3 — sparse Gaussian cross-affinity from a KNR result (Eq. 5–6).
#[derive(Debug, Clone, Copy)]
pub struct AffinityStage;

impl AffinityStage {
    pub fn run(&self, n: usize, p: usize, knr: &KnrResult) -> Affinity {
        build_affinity(n, p, knr.k, knr)
    }
}

/// Stage 4 — transfer cut + NJW k-means discretization (paper §3.1.3–4).
#[derive(Debug, Clone, Copy)]
pub struct PartitionStage {
    /// Output cluster count for the discretization.
    pub k: usize,
    pub solver: EigSolver,
    pub kmeans_iters: usize,
}

impl PartitionStage {
    /// Partition the bipartite graph `b`, probing `tc_k` eigenpairs.
    /// Returns the labels and the un-normalized spectral embedding. The
    /// embedding buffer is reused in place (normalize → discretize →
    /// rescale) instead of cloned, so the returned rows may differ from
    /// the raw transfer-cut output by float rounding (≤ 1–2 ulp).
    pub fn run(
        &self,
        b: &Csr,
        tc_k: usize,
        tc_seed: u64,
        km_seed: u64,
        timer: &mut PhaseTimer,
    ) -> Result<(Vec<u32>, Mat)> {
        let tc = timer.time("transfer_cut", || transfer_cut(b, tc_k, self.solver, tc_seed))?;
        let mut emb = tc.embedding;
        let norms = row_normalize_norms(&mut emb);
        let km = timer.time("discretize", || {
            kmeans(
                &emb,
                &KmeansParams { k: self.k, max_iter: self.kmeans_iters, ..Default::default() },
                km_seed,
            )
        })?;
        row_scale(&mut emb, &norms);
        Ok((km.labels, emb))
    }

    /// Same partition, discarding the embedding (skips the rescale pass).
    pub fn run_labels(
        &self,
        b: &Csr,
        tc_k: usize,
        tc_seed: u64,
        km_seed: u64,
        timer: &mut PhaseTimer,
    ) -> Result<Vec<u32>> {
        let tc = timer.time("transfer_cut", || transfer_cut(b, tc_k, self.solver, tc_seed))?;
        let mut emb = tc.embedding;
        row_normalize(&mut emb);
        let km = timer.time("discretize", || {
            kmeans(
                &emb,
                &KmeansParams { k: self.k, max_iter: self.kmeans_iters, ..Default::default() },
                km_seed,
            )
        })?;
        Ok(km.labels)
    }
}

/// A swept candidate set: the reservoir output plus the RNG state a
/// resumed run needs for the k-means refinement seed.
#[derive(Debug, Clone)]
pub struct CandidateSet {
    pub candidates: Mat,
    rng: Rng,
}

/// The engine: execution knobs + distance backend driving the four
/// stages.
#[derive(Clone, Copy)]
pub struct Pipeline<'a> {
    /// Rows per chunk for every sweep (selection and KNR queries).
    pub chunk: usize,
    /// Row-range shards walked concurrently per order-free pass.
    pub shards: usize,
    /// Storage profile the sharded walk planner assumes.
    pub storage: StorageProfile,
    pub backend: &'a dyn DistanceBackend,
}

impl<'a> Pipeline<'a> {
    pub fn new(backend: &'a dyn DistanceBackend) -> Pipeline<'a> {
        Pipeline { chunk: DEFAULT_CHUNK, shards: 1, storage: StorageProfile::Auto, backend }
    }

    /// Set the chunk size. Stored verbatim; `chunk == 0` is rejected with
    /// a proper `Err` when the run validates (it used to be silently
    /// clamped to 1).
    pub fn with_chunk(mut self, chunk: usize) -> Pipeline<'a> {
        self.chunk = chunk;
        self
    }

    /// Set the shard count for order-free passes. Stored verbatim;
    /// `shards == 0` is rejected when the run validates, and a count
    /// above the source size is clamped by [`ShardPlan::new`].
    pub fn with_shards(mut self, shards: usize) -> Pipeline<'a> {
        self.shards = shards;
        self
    }

    /// Pin the storage profile the walk planner assumes (skipping the
    /// [`StorageProfile::Auto`] probe). Operational only.
    pub fn with_storage(mut self, storage: StorageProfile) -> Pipeline<'a> {
        self.storage = storage;
        self
    }

    /// Set all execution knobs at once.
    pub fn with_opts(mut self, opts: ExecOpts) -> Pipeline<'a> {
        self.chunk = opts.chunk;
        self.shards = opts.shards;
        self.storage = opts.storage;
        self
    }

    /// The selection-stage seed a run derives from its pipeline seed
    /// (first draw of the run's seed schedule). Exposed so ensemble
    /// drivers can sweep candidates for jobs they have not started yet.
    pub fn selection_seed(seed: u64) -> u64 {
        Rng::new(seed).next_u64()
    }

    /// Run the full U-SPEC pipeline on any source.
    pub fn run(
        &self,
        src: &dyn DataSource,
        params: &UspecParams,
        seed: u64,
    ) -> Result<UspecResult> {
        self.fit(src, params, seed).map(|f| f.result)
    }

    /// [`Pipeline::run`] that additionally captures a persistable
    /// [`UspecModel`] for out-of-sample assignment ([`Pipeline::assign`]).
    /// The result is byte-identical to what [`Pipeline::run`] returns for
    /// the same `(params, seed)` — the capture only reads state the run
    /// produces anyway (representatives, top-1 KNR anchors, σ, labels).
    pub fn fit(
        &self,
        src: &dyn DataSource,
        params: &UspecParams,
        seed: u64,
    ) -> Result<FitOutput> {
        let params = self.validate(src, params)?;
        let mut rng = Rng::new(seed);
        let mut timer = PhaseTimer::new();
        let sel_seed = rng.next_u64();
        let stage = SelectStage::from_params(&params);
        let reps = timer.time("select", || stage.run(src, self.chunk, sel_seed))?;
        self.finish(src, &params, rng, timer, reps, seed)
    }

    /// One shared pass over the data filling the candidate reservoirs of
    /// many runs: `specs` pairs each run's candidate size with its
    /// selection seed ([`Pipeline::selection_seed`] of the run seed).
    /// Per run, the result is identical to the sweep [`Pipeline::run`]
    /// would have done itself.
    pub fn sweep_candidates(
        &self,
        src: &dyn DataSource,
        specs: &[(usize, u64)],
    ) -> Result<Vec<CandidateSet>> {
        self.validate_opts()?;
        let mut pairs: Vec<(usize, Rng)> =
            specs.iter().map(|&(size, seed)| (size, Rng::new(seed))).collect();
        let outs = reservoir_multi(src, self.chunk, &mut pairs)?;
        Ok(outs
            .into_iter()
            .zip(pairs)
            .map(|(candidates, (_, rng))| CandidateSet { candidates, rng })
            .collect())
    }

    /// Resume a run whose selection sweep was already done by
    /// [`Pipeline::sweep_candidates`]. Produces exactly the labels
    /// [`Pipeline::run`] would for the same `(params, seed)`.
    pub fn run_from_candidates(
        &self,
        src: &dyn DataSource,
        params: &UspecParams,
        seed: u64,
        cand: &CandidateSet,
    ) -> Result<UspecResult> {
        self.fit_from_candidates(src, params, seed, cand).map(|f| f.result)
    }

    /// [`Pipeline::run_from_candidates`] with model capture — see
    /// [`Pipeline::fit`].
    pub fn fit_from_candidates(
        &self,
        src: &dyn DataSource,
        params: &UspecParams,
        seed: u64,
        cand: &CandidateSet,
    ) -> Result<FitOutput> {
        let params = self.validate(src, params)?;
        let mut rng = Rng::new(seed);
        let mut timer = PhaseTimer::new();
        let _sel_seed = rng.next_u64(); // consumed by the shared sweep
        let stage = SelectStage::from_params(&params);
        let reps = timer.time("select", || {
            let mut sel_rng = cand.rng.clone();
            stage.refine(&cand.candidates, &mut sel_rng)
        })?;
        self.finish(src, &params, rng, timer, reps, seed)
    }

    fn validate_opts(&self) -> Result<()> {
        ensure_arg!(self.chunk >= 1, "pipeline: chunk must be >= 1 (got 0)");
        ensure_arg!(self.shards >= 1, "pipeline: shards must be >= 1 (got 0)");
        Ok(())
    }

    fn validate(&self, src: &dyn DataSource, params: &UspecParams) -> Result<UspecParams> {
        self.validate_opts()?;
        let n = src.n();
        ensure_arg!(n >= 2, "pipeline: need at least 2 objects");
        let params = params.clamped(n);
        ensure_arg!(params.k >= 1 && params.k <= n, "pipeline: bad k={}", params.k);
        ensure_arg!(params.k <= params.p, "pipeline: k={} > p={}", params.k, params.p);
        Ok(params)
    }

    /// Stages 2–4, shared by every entry point, plus the model capture:
    /// a cluster label per representative (majority vote of the fit
    /// points anchored on it — top-1 KNR; vote-less representatives
    /// inherit the label of their nearest voted representative) alongside
    /// the representatives and σ the assignment path replays.
    fn finish(
        &self,
        src: &dyn DataSource,
        params: &UspecParams,
        mut rng: Rng,
        mut timer: PhaseTimer,
        reps: Mat,
        seed: u64,
    ) -> Result<FitOutput> {
        let n = src.n();
        let k_prime = (params.k_nn * params.k_prime_factor).max(params.k_nn + 1);
        let index = timer.time("knr_index", || {
            KnrIndex::build(&reps, k_prime, params.kmeans_iters.min(30), self.backend)
        })?;
        let knr_stage = KnrStage { k_nn: params.k_nn, mode: params.knr };
        // A composite source (e.g. mixed local + remote segments) dictates
        // where shards may cut; a uniform source gets the balanced split.
        let plan = match src.segments() {
            Some(segs) => ShardPlan::aligned(n, self.shards, &segs)?,
            None => ShardPlan::new(n, self.shards)?,
        }
        .with_storage(self.storage);
        let knr = timer.time("knr_query", || {
            knr_stage.query(src, &index, &plan, self.chunk, self.backend)
        })?;
        let aff = timer.time("affinity", || AffinityStage.run(n, index.p(), &knr));
        let tc_seed = rng.next_u64();
        let km_seed = rng.next_u64();
        let stage = PartitionStage {
            k: params.k,
            solver: params.solver,
            kmeans_iters: params.kmeans_iters,
        };
        let (labels, embedding) =
            stage.run(&aff.b, params.k.min(index.p()), tc_seed, km_seed, &mut timer)?;
        let rep_labels = derive_rep_labels(&index.reps, &knr, &labels, params.k);
        let provenance = Json::obj(vec![
            ("algo", Json::Str("uspec".into())),
            ("k", Json::Num(params.k as f64)),
            ("p", Json::Num(index.p() as f64)),
            ("k_nn", Json::Num(knr.k as f64)),
            ("seed", Json::Str(seed.to_string())),
        ])
        .to_string();
        let model = UspecModel {
            k: params.k as u32,
            k_nn: knr.k as u32,
            seed,
            sigma: aff.sigma,
            reps: index.reps,
            rep_labels,
            provenance,
        };
        let result = UspecResult { labels, embedding, timer, sigma: aff.sigma };
        Ok(FitOutput { result, model })
    }

    /// Label out-of-sample rows with a fitted model: exact KNR of every
    /// row against the stored representatives (packed-panel kernels, like
    /// the fit's query pass) followed by a Gaussian affinity vote with the
    /// stored σ over the representatives' cluster labels. The walk is
    /// chunked and shard-parallel exactly like [`KnrStage::query`], and
    /// rows are labeled independently — labels are bit-identical across
    /// `{chunk, shards, threads, SIMD dispatch}` like every other path.
    pub fn assign(&self, model: &UspecModel, src: &dyn DataSource) -> Result<Vec<u32>> {
        self.validate_opts()?;
        model.validate()?;
        ensure_arg!(
            src.d() == model.reps.cols,
            "assign: source dimension {} != model dimension {}",
            src.d(),
            model.reps.cols
        );
        let n = src.n();
        let mut labels = vec![0u32; n];
        let ptr = par::SendPtr(labels.as_mut_ptr());
        let plan = match src.segments() {
            Some(segs) => ShardPlan::aligned(n, self.shards, &segs)?,
            None => ShardPlan::new(n, self.shards)?,
        }
        .with_storage(self.storage);
        for_each_chunk_sharded(src, &plan, self.chunk, |start, m| {
            let out = assign_rows(
                m,
                model.k as usize,
                model.k_nn as usize,
                model.sigma,
                &model.reps,
                &model.rep_labels,
                self.backend,
            );
            assert!(start + m.rows <= n, "chunk [{start}, {}) > n={n}", start + m.rows);
            assert_eq!(out.len(), m.rows, "assign result shape");
            // SAFETY: shards are disjoint row ranges and chunks within a
            // shard are disjoint too, so rows [start, start + m.rows) are
            // written exactly once; `labels` outlives the blocking walk.
            unsafe {
                std::ptr::copy_nonoverlapping(out.as_ptr(), ptr.0.add(start), out.len());
            }
            Ok(())
        })?;
        Ok(labels)
    }

    /// Consensus assignment for a fitted U-SENC ensemble: every base model
    /// labels the row ([`assign_rows`] semantics per base), then the bases
    /// vote with their fit-time (base label → consensus label) co-label
    /// fractions; the consensus cluster with the highest summed vote wins
    /// (ties break to the smallest cluster id). Same chunk/shard/thread
    /// bit-identity contract as [`Pipeline::assign`].
    pub fn assign_consensus(&self, model: &UsencModel, src: &dyn DataSource) -> Result<Vec<u32>> {
        self.validate_opts()?;
        model.validate()?;
        ensure_arg!(
            src.d() == model.bases[0].reps.cols,
            "assign: source dimension {} != model dimension {}",
            src.d(),
            model.bases[0].reps.cols
        );
        let n = src.n();
        let kc = model.k as usize;
        // Row-normalize every base's vote table once (empty base-cluster
        // rows contribute nothing).
        let frac: Vec<Vec<f64>> = model
            .bases
            .iter()
            .map(|b| {
                let mut f = vec![0f64; b.votes.len()];
                for bl in 0..b.k as usize {
                    let row = &b.votes[bl * kc..(bl + 1) * kc];
                    let tot: u64 = row.iter().sum();
                    if tot > 0 {
                        for (fc, &v) in f[bl * kc..(bl + 1) * kc].iter_mut().zip(row) {
                            *fc = v as f64 / tot as f64;
                        }
                    }
                }
                f
            })
            .collect();
        let mut labels = vec![0u32; n];
        let ptr = par::SendPtr(labels.as_mut_ptr());
        let plan = match src.segments() {
            Some(segs) => ShardPlan::aligned(n, self.shards, &segs)?,
            None => ShardPlan::new(n, self.shards)?,
        }
        .with_storage(self.storage);
        for_each_chunk_sharded(src, &plan, self.chunk, |start, m| {
            let mut scores = vec![0f64; m.rows * kc];
            for (bi, b) in model.bases.iter().enumerate() {
                let base_labels = assign_rows(
                    m,
                    b.k as usize,
                    b.k_nn as usize,
                    b.sigma,
                    &b.reps,
                    &b.rep_labels,
                    self.backend,
                );
                for (ri, &bl) in base_labels.iter().enumerate() {
                    let f = &frac[bi][bl as usize * kc..(bl as usize + 1) * kc];
                    for (s, &v) in scores[ri * kc..(ri + 1) * kc].iter_mut().zip(f) {
                        *s += v;
                    }
                }
            }
            let out: Vec<u32> = (0..m.rows)
                .map(|ri| {
                    let row = &scores[ri * kc..(ri + 1) * kc];
                    let mut best = 0usize;
                    for (c, &s) in row.iter().enumerate().skip(1) {
                        if s > row[best] {
                            best = c;
                        }
                    }
                    best as u32
                })
                .collect();
            assert!(start + m.rows <= n, "chunk [{start}, {}) > n={n}", start + m.rows);
            // SAFETY: disjoint row ranges, exactly as in `assign`.
            unsafe {
                std::ptr::copy_nonoverlapping(out.as_ptr(), ptr.0.add(start), out.len());
            }
            Ok(())
        })?;
        Ok(labels)
    }
}

/// A fitted run: the usual result plus the persistable model
/// ([`crate::runtime::model`]) for out-of-sample assignment.
#[derive(Debug, Clone)]
pub struct FitOutput {
    pub result: UspecResult,
    pub model: UspecModel,
}

/// Majority-vote cluster label per representative: each fit point votes
/// for its top-1 KNR anchor; vote-less representatives inherit the label
/// of their nearest voted representative (scalar distances, tie to the
/// lower representative id). Sequential and thread-count independent.
fn derive_rep_labels(reps: &Mat, knr: &KnrResult, labels: &[u32], k: usize) -> Vec<u32> {
    let p = reps.rows;
    let mut counts = vec![0u64; p * k];
    for (i, &l) in labels.iter().enumerate() {
        let rep = knr.idx[i * knr.k] as usize;
        counts[rep * k + l as usize] += 1;
    }
    let mut rep_labels = vec![u32::MAX; p];
    for j in 0..p {
        let row = &counts[j * k..(j + 1) * k];
        let mut best = 0usize;
        for (c, &v) in row.iter().enumerate().skip(1) {
            if v > row[best] {
                best = c;
            }
        }
        if row[best] > 0 {
            rep_labels[j] = best as u32;
        }
    }
    let voted: Vec<usize> = (0..p).filter(|&j| rep_labels[j] != u32::MAX).collect();
    for j in 0..p {
        if rep_labels[j] != u32::MAX {
            continue;
        }
        let (mut best, mut best_d2) = (voted[0], f32::INFINITY);
        for &j2 in &voted {
            let mut d2 = 0.0f32;
            for (a, b) in reps.row(j).iter().zip(reps.row(j2)) {
                let diff = a - b;
                d2 += diff * diff;
            }
            if d2 < best_d2 {
                best = j2;
                best_d2 = d2;
            }
        }
        rep_labels[j] = rep_labels[best];
    }
    rep_labels
}

/// The assignment kernel shared by [`Pipeline::assign`] and every base of
/// [`Pipeline::assign_consensus`]: exact KNR of `x` against `reps`
/// (packed-panel fast path on the native backend), then per row a
/// Gaussian vote `exp(−d²/2σ²)` — the fit's affinity weights (Eq. 5–6)
/// with the *stored* σ — summed per representative label in
/// nearest-first order. The nearest representative's label seeds the
/// argmax, so far-from-everything rows (all weights underflow to 0) still
/// take their nearest representative's cluster and ties favor it. Rows
/// are independent: results are bit-identical for any chunking/threading
/// of the caller.
fn assign_rows(
    x: &Mat,
    k: usize,
    k_nn: usize,
    sigma: f64,
    reps: &Mat,
    rep_labels: &[u32],
    backend: &dyn DistanceBackend,
) -> Vec<u32> {
    let kq = k_nn.min(reps.rows).max(1);
    let r = exact_knr(x, reps, kq, backend);
    let denom = 2.0 * sigma * sigma;
    let mut scores = vec![0f64; k];
    let mut out = Vec::with_capacity(x.rows);
    for bi in 0..x.rows {
        scores.iter_mut().for_each(|s| *s = 0.0);
        for t in 0..r.k {
            let rep = r.idx[bi * r.k + t] as usize;
            let w = (-(r.d2[bi * r.k + t].max(0.0) as f64) / denom).exp();
            scores[rep_labels[rep] as usize] += w;
        }
        let mut best = rep_labels[r.idx[bi * r.k] as usize] as usize;
        for (c, &s) in scores.iter().enumerate() {
            if s > scores[best] {
                best = c;
            }
        }
        out.push(best as u32);
    }
    out
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::source::DataSource;
    use crate::linalg::Mat;
    use crate::Result;

    /// A `Mat` stripped of its resident fast path, so tests exercise the
    /// chunked `read_rows` iteration instead of the zero-copy shortcut.
    pub(crate) struct NonResident<'a>(pub(crate) &'a Mat);

    impl DataSource for NonResident<'_> {
        fn n(&self) -> usize {
            self.0.rows
        }

        fn d(&self) -> usize {
            self.0.cols
        }

        fn read_rows(&self, start: usize, len: usize, buf: &mut Mat) -> Result<()> {
            self.0.read_rows(start, len, buf)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::affinity::NativeBackend;
    use crate::data::synthetic::two_moons;
    use crate::metrics::nmi;

    #[test]
    fn engine_clusters_from_a_mat_source() {
        let ds = two_moons(1200, 0.06, 5);
        let params = UspecParams { k: 2, p: 150, ..Default::default() };
        let res = Pipeline::new(&NativeBackend).run(&ds.x, &params, 42).unwrap();
        assert!(nmi(&res.labels, &ds.y) > 0.9);
        assert!(res.sigma > 0.0);
        for phase in ["select", "knr_index", "knr_query", "affinity", "transfer_cut", "discretize"]
        {
            assert!(
                res.timer.phases.iter().any(|(n, _)| n == phase),
                "missing phase {phase}"
            );
        }
    }

    #[test]
    fn chunk_size_is_operational_not_semantic() {
        // A resident Mat takes the zero-copy single-chunk fast path, so
        // exercise real chunking through the on-disk source.
        let ds = two_moons(900, 0.06, 6);
        let dir = std::env::temp_dir().join("uspec_pipeline_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let bin =
            crate::streaming::BinDataset::write_mat(&dir.join("chunks.bin"), &ds.x).unwrap();
        let params = UspecParams { k: 2, p: 100, ..Default::default() };
        let a = Pipeline::new(&NativeBackend).with_chunk(64).run(&bin, &params, 9).unwrap();
        let b = Pipeline::new(&NativeBackend).with_chunk(8192).run(&bin, &params, 9).unwrap();
        let c = Pipeline::new(&NativeBackend).run(&ds.x, &params, 9).unwrap();
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.labels, c.labels);
        assert_eq!(a.sigma.to_bits(), b.sigma.to_bits());
        assert_eq!(a.sigma.to_bits(), c.sigma.to_bits());
    }

    #[test]
    fn shared_sweep_resumes_identically() {
        let ds = two_moons(700, 0.06, 7);
        let params = UspecParams { k: 2, p: 90, ..Default::default() };
        let pipe = Pipeline::new(&NativeBackend).with_chunk(256);
        let direct = pipe.run(&ds.x, &params, 33).unwrap();
        let clamped = params.clamped(ds.x.rows);
        let stage = SelectStage::from_params(&clamped);
        let specs = vec![(stage.candidate_size(ds.x.rows), Pipeline::selection_seed(33))];
        let cands = pipe.sweep_candidates(&ds.x, &specs).unwrap();
        let resumed = pipe.run_from_candidates(&ds.x, &params, 33, &cands[0]).unwrap();
        assert_eq!(direct.labels, resumed.labels);
        assert_eq!(direct.sigma.to_bits(), resumed.sigma.to_bits());
    }

    #[test]
    fn kmeans_full_requires_resident_data() {
        let ds = two_moons(300, 0.05, 8);
        let params = UspecParams {
            k: 2,
            p: 40,
            selection: SelectStrategy::KmeansFull,
            ..Default::default()
        };
        // resident: fine
        assert!(Pipeline::new(&NativeBackend).run(&ds.x, &params, 1).is_ok());
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let ds = two_moons(10, 0.05, 9);
        let pipe = Pipeline::new(&NativeBackend);
        assert!(pipe.run(&ds.x, &UspecParams { k: 0, ..Default::default() }, 1).is_err());
        assert!(pipe.run(&ds.x, &UspecParams { k: 11, ..Default::default() }, 1).is_err());
        let one = Mat::from_vec(1, 2, vec![0.0, 0.0]);
        assert!(pipe.run(&one, &UspecParams::default(), 1).is_err());
    }

    #[test]
    fn zero_exec_knobs_are_proper_errors() {
        let ds = two_moons(100, 0.05, 10);
        let params = UspecParams { k: 2, p: 30, ..Default::default() };
        let chunk0 = Pipeline::new(&NativeBackend).with_chunk(0);
        let err = chunk0.run(&ds.x, &params, 1).unwrap_err();
        assert!(err.to_string().contains("chunk"), "{err}");
        let shards0 = Pipeline::new(&NativeBackend).with_shards(0);
        let err = shards0.run(&ds.x, &params, 1).unwrap_err();
        assert!(err.to_string().contains("shards"), "{err}");
        // the shared-sweep entry validates the same knobs
        assert!(chunk0.sweep_candidates(&ds.x, &[(10, 7)]).is_err());
        assert!(shards0.sweep_candidates(&ds.x, &[(10, 7)]).is_err());
    }

    #[test]
    fn shard_count_is_operational_not_semantic() {
        // Real sharding needs a non-resident source; pin {1, 2, 7} shards
        // against each other and the resident run.
        let ds = two_moons(900, 0.06, 11);
        let dir = std::env::temp_dir().join("uspec_pipeline_tests");
        std::fs::create_dir_all(&dir).unwrap();
        let bin =
            crate::streaming::BinDataset::write_mat(&dir.join("shards.bin"), &ds.x).unwrap();
        let params = UspecParams { k: 2, p: 100, ..Default::default() };
        let resident = Pipeline::new(&NativeBackend).run(&ds.x, &params, 9).unwrap();
        for shards in [1usize, 2, 7] {
            let opts = ExecOpts { chunk: 128, shards, ..ExecOpts::default() };
            let run = Pipeline::new(&NativeBackend).with_opts(opts).run(&bin, &params, 9).unwrap();
            assert_eq!(run.labels, resident.labels, "shards={shards}");
            assert_eq!(run.sigma.to_bits(), resident.sigma.to_bits(), "shards={shards}");
        }
        // over-n shard counts clamp instead of erroring at the API level
        let many = Pipeline::new(&NativeBackend).with_shards(10_000);
        assert_eq!(many.run(&bin, &params, 9).unwrap().labels, resident.labels);
    }
}
