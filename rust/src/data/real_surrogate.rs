//! Surrogates for the paper's five real datasets (PenDigits, USPS, Letters,
//! MNIST, Covertype). The originals are UCI / Roweis downloads that this
//! offline environment cannot fetch, so we generate anisotropic Gaussian
//! mixtures with a nonlinear warp whose (N, d, #class) match Table 3 and
//! whose *difficulty* (class overlap) is tuned per dataset so the
//! evaluation reproduces the paper's qualitative ordering (e.g. Covertype
//! NMI collapses to single digits for every method; Letters is hard;
//! PenDigits/MNIST are moderate). See DESIGN.md "Substitutions".

use super::{Benchmark, Dataset};
use crate::linalg::Mat;
use crate::util::par;
use crate::util::rng::Rng;

/// Difficulty profile for a surrogate.
struct Profile {
    /// Mean separation between class centers, in units of within-class σ.
    sep: f64,
    /// Fraction of dimensions that carry class signal (rest pure noise).
    informative: f64,
    /// Strength of the shared nonlinear warp (makes clusters non-spherical,
    /// favoring spectral methods over k-means, as on the real data).
    warp: f64,
    /// Class imbalance exponent (1.0 = balanced; >1 = skewed like Covertype).
    imbalance: f64,
}

fn profile(b: Benchmark) -> Profile {
    match b {
        // Paper NMI levels (best methods): PenDigits ~0.80, USPS ~0.66,
        // Letters ~0.45, MNIST ~0.74, Covertype ~0.07.
        Benchmark::PenDigits => Profile { sep: 4.2, informative: 0.9, warp: 0.35, imbalance: 1.0 },
        Benchmark::Usps => Profile { sep: 3.0, informative: 0.35, warp: 0.40, imbalance: 1.0 },
        Benchmark::Letters => Profile { sep: 2.0, informative: 0.8, warp: 0.30, imbalance: 1.0 },
        Benchmark::Mnist => Profile { sep: 3.4, informative: 0.25, warp: 0.45, imbalance: 1.0 },
        Benchmark::Covertype => Profile { sep: 0.55, informative: 0.3, warp: 0.15, imbalance: 2.4 },
        _ => panic!("surrogate() is for the real datasets; use synthetic::*"),
    }
}

/// Generate the surrogate with `n` objects.
pub fn surrogate(b: Benchmark, n: usize, seed: u64) -> Dataset {
    let (_, d, k) = b.paper_shape();
    let prof = profile(b);
    let mut rng = Rng::new(seed ^ 0xC0FFEE);
    let d_inf = ((d as f64 * prof.informative) as usize).clamp(2, d);

    // Class centers on the informative subspace.
    let mut centers = vec![0.0f64; k * d_inf];
    for v in centers.iter_mut() {
        *v = rng.normal() * prof.sep / (d_inf as f64).sqrt() * (d_inf as f64).powf(0.25);
    }
    // Per-class anisotropic scales.
    let mut scales = vec![0.0f64; k * d_inf];
    for v in scales.iter_mut() {
        *v = 0.6 + 0.8 * rng.f64();
    }
    // Class proportions (power-law for imbalanced sets like Covertype).
    let mut props: Vec<f64> = (1..=k).map(|i| (i as f64).powf(-prof.imbalance + 1.0)).collect();
    let total: f64 = props.iter().sum();
    for p in props.iter_mut() {
        *p /= total;
    }
    let mut cum = vec![0.0f64; k];
    let mut acc = 0.0;
    for (i, &p) in props.iter().enumerate() {
        acc += p;
        cum[i] = acc;
    }

    // Shared random warp directions (second-order feature interactions).
    let n_warp = 8usize.min(d_inf);
    let warp_pairs: Vec<(usize, usize, f64)> = (0..n_warp)
        .map(|_| (rng.usize(d_inf), rng.usize(d_inf), (rng.f64() - 0.5) * 2.0 * prof.warp))
        .collect();

    let chunk = 8192;
    let nchunks = n.div_ceil(chunk);
    let centers_ref = &centers;
    let scales_ref = &scales;
    let cum_ref = &cum;
    let warp_ref = &warp_pairs;
    let parts: Vec<(Vec<f32>, Vec<u32>)> = par::par_map(nchunks, |ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(n);
        let mut rng = Rng::new(seed ^ (ci as u64).wrapping_mul(0x9E3779B97F4A7C15) ^ 0xDA7A);
        let mut xs = Vec::with_capacity((hi - lo) * d);
        let mut ys = Vec::with_capacity(hi - lo);
        let mut buf = vec![0.0f64; d_inf];
        for i in lo..hi {
            // deterministic class by quantile (keeps proportions exact-ish)
            let t = (i as f64 + 0.5) / n as f64;
            let c = crate::util::searchsorted(cum_ref, t);
            ys.push(c as u32);
            for (j, bv) in buf.iter_mut().enumerate() {
                *bv = centers_ref[c * d_inf + j] + rng.normal() * scales_ref[c * d_inf + j];
            }
            // warp: x_a += w * x_b²  (bends class manifolds)
            for &(a, bidx, w) in warp_ref {
                let vb = buf[bidx];
                buf[a] += w * vb * vb * 0.3;
            }
            for &bv in buf.iter() {
                xs.push(bv as f32);
            }
            // noise dims
            for _ in d_inf..d {
                xs.push((rng.normal() * 1.0) as f32);
            }
        }
        (xs, ys)
    });
    let mut data = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for (xs, ys) in parts {
        data.extend(xs);
        y.extend(ys);
    }
    Dataset::new(b.name(), Mat::from_vec(n, d, data), y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kmeans::{kmeans, KmeansParams};
    use crate::metrics::nmi;

    #[test]
    fn shapes_match_table3() {
        for b in [Benchmark::PenDigits, Benchmark::Usps, Benchmark::Covertype] {
            let (_, d, k) = b.paper_shape();
            let ds = surrogate(b, 2000.max(200 * k), 1);
            assert_eq!(ds.d(), d);
            assert_eq!(ds.k, k);
        }
    }

    #[test]
    fn difficulty_ordering() {
        // k-means NMI: PenDigits surrogate should be much easier than the
        // Covertype surrogate — mirroring Table 4 (66.7 vs 6.2).
        let easy = surrogate(Benchmark::PenDigits, 3000, 2);
        let hard = surrogate(Benchmark::Covertype, 3000, 2);
        let r_easy = kmeans(&easy.x, &KmeansParams { k: easy.k, ..Default::default() }, 5).unwrap();
        let r_hard = kmeans(&hard.x, &KmeansParams { k: hard.k, ..Default::default() }, 5).unwrap();
        let n_easy = nmi(&r_easy.labels, &easy.y);
        let n_hard = nmi(&r_hard.labels, &hard.y);
        assert!(n_easy > 0.5, "PenDigits surrogate too hard: {n_easy}");
        assert!(n_hard < 0.25, "Covertype surrogate too easy: {n_hard}");
        assert!(n_easy > n_hard + 0.3);
    }

    #[test]
    fn covertype_imbalanced() {
        let ds = surrogate(Benchmark::Covertype, 5000, 3);
        let mut counts = vec![0usize; ds.k];
        for &l in &ds.y {
            counts[l as usize] += 1;
        }
        let max = *counts.iter().max().unwrap() as f64;
        let min = *counts.iter().min().unwrap().max(&1) as f64;
        assert!(max / min > 3.0, "expected imbalance, got {counts:?}");
    }

    #[test]
    fn deterministic() {
        let a = surrogate(Benchmark::Letters, 1000, 7);
        let b = surrogate(Benchmark::Letters, 1000, 7);
        assert_eq!(a.x.data, b.x.data);
        assert_eq!(a.y, b.y);
    }
}
